//! Integration: the bound optimizer against the simulator — the (p, η)
//! choices Algorithm 1 makes from theory must actually improve the
//! simulated queueing profile, and the paper's headline numbers must land
//! in their reported ranges.

use fedqueue::bound::{relative_improvement, BoundParams, MiSource, TwoClusterStudy};
use fedqueue::simulator::{run, ServiceDist, ServiceFamily, SimConfig};

fn paper_study(mu_fast: f64, c: usize) -> TwoClusterStudy {
    TwoClusterStudy {
        params: BoundParams::worked_example(c),
        n_fast: 90,
        mu_fast,
        mu_slow: 1.0,
        source: MiSource::default(),
    }
}

#[test]
fn fig2_fig3_anchor_points() {
    // Paper: optimal p drops to ≈7.3e-3 and improvement reaches ≈55% at
    // μ_f=16; ≈30% at μ_f=2 (C=100 full concurrency).
    let lo = paper_study(2.0, 100);
    let (b2, u2) = lo.optimize_p(50).unwrap();
    let i2 = relative_improvement(b2.bound, u2.bound);
    let hi = paper_study(16.0, 100);
    let (b16, u16) = hi.optimize_p(50).unwrap();
    let i16 = relative_improvement(b16.bound, u16.bound);
    assert!(b16.p_fast < 1.0 / 100.0, "optimal p {} below uniform", b16.p_fast);
    assert!(i16 > i2, "improvement grows with speed: {i2} vs {i16}");
    assert!(i2 > 0.1 && i2 < 0.7, "μ_f=2 improvement {i2} (paper ≈30%)");
    assert!(i16 > 0.3 && i16 < 0.85, "μ_f=16 improvement {i16} (paper ≈55%)");
}

#[test]
fn optimizer_choice_improves_simulated_delays() {
    // close the loop: take the optimizer's p, run the SIMULATOR, verify the
    // weighted delay objective m̄ actually improved vs uniform sampling.
    let st = paper_study(8.0, 50);
    let (best, uniform) = st.optimize_p(40).unwrap();
    let simulate = |p_fast: f64, seed: u64| {
        let tc = st.cluster(p_fast);
        let cfg = SimConfig {
            seed,
            ..SimConfig::new(
                tc.p_vec(),
                ServiceDist::from_rates(&tc.mu_vec(), ServiceFamily::Exponential),
                50,
                200_000,
            )
        };
        let res = run(cfg).unwrap();
        // m̄ = Σ m_i/(n² p_i²) with empirical m_i
        let n = tc.p_vec().len() as f64;
        res.m_empirical()
            .iter()
            .zip(tc.p_vec())
            .filter(|(m, _)| m.is_finite())
            .map(|(m, p)| m / (n * n * p * p))
            .sum::<f64>()
    };
    let mbar_uni = simulate(uniform.p_fast, 0x51);
    let mbar_opt = simulate(best.p_fast, 0x52);
    assert!(
        mbar_opt < mbar_uni,
        "optimizer's p must reduce simulated m̄: {mbar_opt} vs {mbar_uni}"
    );
}

#[test]
fn eta_stays_within_cap_across_sweep() {
    for &mu in &[2.0, 8.0, 16.0] {
        for &c in &[10usize, 100] {
            let st = paper_study(mu, c);
            for p in st.p_grid(25) {
                if let Ok(pt) = st.evaluate(p) {
                    assert!(
                        pt.eta <= pt.eta_max * (1.0 + 1e-12),
                        "η {} exceeds cap {} at p={p}",
                        pt.eta,
                        pt.eta_max
                    );
                    assert!(pt.bound.is_finite() && pt.bound > 0.0);
                }
            }
        }
    }
}

#[test]
fn fig4_baselines_lose_across_grid() {
    for &mu in &[4.0, 8.0, 16.0] {
        let st = paper_study(mu, 50);
        let (best, _) = st.optimize_p(40).unwrap();
        let (g_fedbuff, g_async) = st.baseline_bounds().unwrap();
        assert!(
            best.bound < g_async && best.bound < g_fedbuff,
            "μ={mu}: gen {} vs fedbuff {g_fedbuff} async {g_async}",
            best.bound
        );
        // FedBuff's τ_max² n term makes it the weakest, increasingly so
        assert!(g_fedbuff > g_async);
    }
}

#[test]
fn physical_time_small_c_prefers_uniform() {
    // App E.2: "when the concurrency is small (w.r.t. n), uniform sampling
    // appears as the best strategy"
    let st = paper_study(4.0, 5);
    let (best, uniform) = st.optimize_p_physical(40, 1000.0).unwrap();
    let imp = relative_improvement(best.bound, uniform.bound);
    assert!(
        imp < 0.15,
        "small C: physical-time improvement should be small, got {imp}"
    );
}

#[test]
fn monte_carlo_and_theory_sources_agree_on_optimum_region() {
    let mut st = paper_study(8.0, 20);
    let (best_theory, _) = st.optimize_p(30).unwrap();
    st.source = MiSource::MonteCarlo {
        steps: 40_000,
        family: ServiceFamily::Exponential,
        seed: 3,
    };
    let (best_mc, _) = st.optimize_p(15).unwrap();
    let ratio = best_mc.p_fast / best_theory.p_fast;
    assert!(
        (0.2..5.0).contains(&ratio),
        "optima wildly disagree: theory {} vs MC {}",
        best_theory.p_fast,
        best_mc.p_fast
    );
}
