//! Zero-allocation steady state: after warm-up, a CS step allocates
//! NOTHING on any engine.
//!
//! Every hot-loop container is pre-sized at construction (event heaps,
//! task pools, per-node queues, scratch buffers) and the batch arena's
//! vectorized sampling + prefetched routing never build a per-step Rng,
//! so the steady-state step count can rise without a single trip to the
//! allocator.  A counting `#[global_allocator]` makes that a hard
//! invariant instead of a hope: 10^4 steps after a 10^3-step warm-up
//! must leave the allocation counter untouched, per engine, for both an
//! alias-backed static policy and the Fenwick adaptive policy.
//!
//! Release builds only: debug builds keep their fingerprint guards and
//! unoptimized container paths, which is not the configuration the
//! raw-speed contract targets (CI runs this under `--release` in the
//! stat-tests job).  Threaded sharded dispatch is exercised elsewhere
//! (`tests/threaded_driver.rs`) — its mailbox protocol allocates by
//! design, so the zero-alloc contract covers the sequential drivers.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fedqueue::coordinator::{FenwickAdaptivePolicy, SamplingPolicy, StaticPolicy};
use fedqueue::simulator::{with_engine, EngineConfig, ServiceDist, ServiceFamily, SimConfig};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const WARMUP: u64 = 1_000;
const MEASURED: u64 = 10_000;

fn cfg(engine: EngineConfig) -> SimConfig {
    let n = 16;
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 2.0 } else { 1.0 }).collect();
    SimConfig {
        seed: 42,
        engine,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            64,
            WARMUP + MEASURED,
        )
    }
}

/// Allocations made by `MEASURED` steps after `WARMUP` steps.
fn steady_state_allocs(c: SimConfig, policy: Box<dyn SamplingPolicy>) -> u64 {
    with_engine(c, policy, |net| {
        for _ in 0..WARMUP {
            net.advance().ok_or("network drained in warm-up")?;
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..MEASURED {
            net.advance().ok_or("network drained")?;
        }
        Ok(ALLOCS.load(Ordering::Relaxed) - before)
    })
    .unwrap()
}

#[test]
fn steady_state_steps_allocate_nothing() {
    if cfg!(debug_assertions) {
        return; // release-only contract; see module doc
    }
    let engines = [
        ("heap", EngineConfig::heap()),
        ("sharded_S4", EngineConfig::sharded(4, 1)),
        ("batch", EngineConfig::batch()),
    ];
    let policies: [(&str, fn(usize) -> Box<dyn SamplingPolicy>); 2] = [
        ("static", |n| {
            Box::new(StaticPolicy::new(vec![1.0 / n as f64; n]).unwrap())
        }),
        ("fenwick-adaptive", |n| {
            Box::new(FenwickAdaptivePolicy::new(vec![1.0 / n as f64; n], 0.8).unwrap())
        }),
    ];
    for (ename, engine) in engines {
        for (pname, mk) in policies {
            let got = steady_state_allocs(cfg(engine), mk(16));
            assert_eq!(
                got, 0,
                "{ename}/{pname}: {got} allocations in {MEASURED} steady-state steps"
            );
        }
    }
}
