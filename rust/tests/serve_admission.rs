//! Admission-control edge cases for `fedqueue serve` — the warm-up path,
//! boundary admission, pathological deadlines, mid-window joins, and the
//! bit-identity guarantee, all through the public [`ServeSetup`] surface.

use fedqueue::coordinator::{ServeConfig, ServeSetup};

/// Small two-cluster session that drains in well under a second.
fn base() -> ServeSetup {
    ServeSetup {
        clients: 16,
        concurrency: 4,
        dispatches: 300,
        slow_fraction: 0.5,
        mu_fast: 8.0,
        p_fast: None,
        gamma: 0.5,
        beta: 0.2,
        eta: 0.05,
        kappa: 0.1,
        policy: "delay-adaptive".to_string(),
        algo: "genasync-damped".to_string(),
        seed: 7,
        cfg: ServeConfig { t_sync: 10.0, server_time: 0.05, ..ServeConfig::default() },
    }
}

#[test]
fn infinite_warm_up_keeps_every_dispatch_unconditional() {
    let mut setup = base();
    setup.cfg.warm_up = u64::MAX;
    let report = setup.run().unwrap();
    assert_eq!(report.dispatched, setup.dispatches);
    assert_eq!(report.completed, setup.dispatches);
    assert_eq!(report.warm, report.dispatched, "no estimate may ever be trusted");
    assert_eq!(report.admitted, 0);
    assert_eq!(report.deferred, 0);
}

#[test]
fn zero_safety_buffer_admits_on_the_raw_estimate_and_drains() {
    let mut setup = base();
    setup.cfg.warm_up = 1;
    setup.cfg.safety_buffer = 0.0;
    let report = setup.run().unwrap();
    assert_eq!(report.completed, setup.dispatches);
    assert_eq!(
        report.warm + report.admitted + report.deferred,
        report.dispatched,
        "every dispatch takes exactly one admission branch"
    );
    assert!(report.admitted > 0, "post-warm-up estimates must drive admissions");
}

#[test]
fn pathological_deadlines_degrade_gracefully() {
    // Windows far shorter than any compute time: once estimates warm up,
    // every admission check fails (defer) and every completion lands past
    // its deadline — the session must still drain its whole budget.
    let mut setup = base();
    setup.cfg.t_sync = 0.001;
    setup.cfg.admission_tolerance = 0.0;
    setup.cfg.warm_up = 0;
    setup.cfg.server_time = 0.0;
    let report = setup.run().unwrap();
    assert_eq!(report.completed, setup.dispatches);
    assert!(report.deferred > 0, "estimates over the window must defer");
    assert!(
        report.deadline_misses as f64 >= 0.9 * report.completed as f64,
        "misses {} of {} completions — expected nearly all",
        report.deadline_misses,
        report.completed
    );
}

#[test]
fn ramped_clients_join_mid_session() {
    let mut setup = base();
    setup.cfg.ramp_time = 25.0;
    let report = setup.run().unwrap();
    assert_eq!(report.joins, setup.clients as u64 / 2, "odd-index clients ramp in");
    assert_eq!(report.completed, setup.dispatches, "joins must not strand budget");
}

#[test]
fn server_contention_shows_up_as_queue_time() {
    let mut setup = base();
    setup.concurrency = 8;
    setup.cfg.server_time = 0.5;
    let report = setup.run().unwrap();
    assert!(
        report.queue_time.mean() > 0.0,
        "sequential server bookkeeping must produce positive queue time, got {}",
        report.queue_time.mean()
    );
    assert!(report.delay.mean() > report.compute_time.mean());
}

#[test]
fn deterministic_report_is_bit_identical_across_runs() {
    let setup = base();
    let a = setup.run().unwrap().to_json_deterministic().render();
    let b = setup.run().unwrap().to_json_deterministic().render();
    assert_eq!(a, b, "deterministic core must be byte-identical on a shared seed");
}

/// Release-only scale smoke: 10^6 simulated clients as executor futures.
/// Debug builds skip it (the slab alone is hundreds of MB and unoptimized
/// polling is ~30x slower).
#[test]
#[cfg_attr(debug_assertions, ignore)]
fn million_client_session_drains() {
    let mut setup = base();
    setup.clients = 1_000_000;
    setup.concurrency = 1_000;
    setup.dispatches = 20_000;
    setup.cfg.server_time = 0.001;
    let report = setup.run().unwrap();
    assert_eq!(report.completed, 20_000);
    assert!(report.dispatches_per_sec() > 0.0);
}
