//! Determinism and scale contract of the sweep engine.
//!
//! * The aggregated JSON report is bit-identical regardless of worker
//!   thread count (per-seed RNG streams + ordered reduction) and across
//!   repeated runs.
//! * The acceptance grid — ≥ 3 scenario cells × 8 seeds on ≥ 4 threads —
//!   runs end to end and yields finite mean ± CI aggregates for every
//!   metric of every cell.
//! * A single replication with n = 100 000 nodes under the alias-backed
//!   uniform policy and the Fenwick-backed adaptive policy completes:
//!   routing is O(1)/O(log n) per dispatch, so node count no longer
//!   multiplies the per-step cost.

use fedqueue::coordinator::sweep::{run_sweep, SweepSpec};
use fedqueue::coordinator::{FenwickAdaptivePolicy, PolicyCtx, PolicyRegistry};
use fedqueue::simulator::{run_with_policy, ServiceDist, ServiceFamily, SimConfig};
use fedqueue::util::json::Json;

/// ≥ 3 scenario cells (2 client counts × 2 policies = 4), 8 seeds.
const ACCEPTANCE_GRID: &str = r#"
[sweep]
name = "acceptance"
mode = "simulate"
seeds = 8
base_seed = 1234
threads = 4

[grid]
clients = [10, 16]
concurrency = [6]
steps = [1500]
mu_fast = [4.0]
slow_fraction = [0.5]
gamma = [0.5]
policies = ["uniform", "adaptive"]
"#;

fn render_with_threads(threads: usize) -> String {
    let mut spec = SweepSpec::from_toml(ACCEPTANCE_GRID).unwrap();
    spec.threads = threads;
    // the deterministic core: perf blocks (events/sec, peak RSS) are
    // timing-derived by design and excluded from the comparison unit
    run_sweep(&spec).unwrap().to_json_deterministic().render()
}

#[test]
fn aggregated_json_is_bit_identical_across_thread_counts() {
    let one = render_with_threads(1);
    let four = render_with_threads(4);
    let seven = render_with_threads(7);
    assert_eq!(one, four, "1 vs 4 worker threads changed the aggregate");
    assert_eq!(four, seven, "4 vs 7 worker threads changed the aggregate");
    // and across repeated runs at the same thread count
    assert_eq!(four, render_with_threads(4), "rerun changed the aggregate");
}

#[test]
fn acceptance_grid_runs_end_to_end_with_cis() {
    let spec = SweepSpec::from_toml(ACCEPTANCE_GRID).unwrap();
    assert!(spec.cells.len() >= 3, "acceptance needs >= 3 cells");
    assert_eq!(spec.seeds, 8);
    assert_eq!(spec.threads, 4);
    let report = run_sweep(&spec).unwrap();
    assert_eq!(report.cells.len(), spec.cells.len());
    for c in &report.cells {
        for (k, w) in &c.metrics {
            assert_eq!(w.count(), 8, "{} metric {k}", c.cell.label());
            assert!(w.mean().is_finite(), "{} metric {k}", c.cell.label());
            assert!(
                w.ci95().is_finite(),
                "{} metric {k} must carry a CI over 8 seeds",
                c.cell.label()
            );
        }
        // an 8-seed mean ± CI is the whole point: intervals are nonzero
        assert!(c.metrics["total_time"].ci95() > 0.0, "{}", c.cell.label());
    }
    // the serialized report round-trips through the JSON substrate
    let json = Json::parse(&report.to_json().render()).unwrap();
    let cells = json.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), report.cells.len());
    let m0 = cells[0].get("metrics").unwrap().get("delay_all").unwrap();
    assert_eq!(m0.get("count").unwrap().as_f64().unwrap(), 8.0);
    assert!(m0.get("ci95").unwrap().as_f64().unwrap() >= 0.0);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: long replications (CI stat-tests job)")]
fn delay_adaptive_beats_static_mean_delay_on_two_cluster_cell() {
    // the ISSUE-5 acceptance criterion: at EQUAL step count on the
    // two-cluster cell, closing the loop on observed delay must lower the
    // mean delay τ below the static baseline — the delay-feedback policy
    // shifts dispatches away from nodes whose completions keep reporting
    // large M, so slow-node queues (the dominant delay contributor) drain.
    // Both cells share the grid, seeds, and step budget; only p differs.
    let grid = r#"
[sweep]
name = "delay_acceptance"
mode = "simulate"
seeds = 8
base_seed = 77
threads = 4

[grid]
clients = [20]
concurrency = [10]
steps = [20000]
mu_fast = [4.0]
slow_fraction = [0.5]
gamma = [0.1]
beta = [0.9]
policies = ["static", "delay-adaptive"]
"#;
    let spec = SweepSpec::from_toml(grid).unwrap();
    let report = run_sweep(&spec).unwrap();
    assert_eq!(report.cells.len(), 2);
    let delay_of = |policy: &str| -> (f64, f64) {
        let c = report
            .cells
            .iter()
            .find(|c| c.cell.policy == policy)
            .unwrap_or_else(|| panic!("missing {policy} cell"));
        let w = &c.metrics["delay_all"];
        assert_eq!(w.count(), 8, "{policy}: all seeds must report");
        (w.mean(), w.ci95())
    };
    let (d_static, ci_static) = delay_of("static");
    let (d_delay, ci_delay) = delay_of("delay-adaptive");
    assert!(
        d_delay < d_static,
        "delay-adaptive mean delay {d_delay} must undercut static {d_static}"
    );
    // not a fluke of seed noise: the gap must clear both 95% intervals
    assert!(
        d_delay + ci_delay < d_static - ci_static,
        "separation must exceed the CIs: {d_delay}±{ci_delay} vs {d_static}±{ci_static}"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: n = 100_000 nodes (CI stat-tests job)")]
fn hundred_thousand_node_replication_completes() {
    // n = 100_000, C = 256: a replication is feasible because the static
    // policy routes via the O(1) alias table, observation is skipped
    // entirely (incremental no-op), and queue-occupancy accounting touches
    // only the two queues that change per step.
    let n = 100_000;
    let steps = 50_000u64;
    let p = vec![1.0 / n as f64; n];
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    let cfg = SimConfig {
        seed: 9,
        ..SimConfig::new(
            p.clone(),
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            256,
            steps,
        )
    };
    let res = run_with_policy(
        cfg,
        PolicyRegistry::builtin()
            .build(
                "uniform",
                &PolicyCtx {
                    n,
                    base_p: p.clone(),
                    gamma: 0.0,
                    beta: 0.9,
                    n_fast: n / 2,
                    mu_fast: 4.0,
                    mu_slow: 1.0,
                    concurrency: 256,
                    steps,
                },
            )
            .unwrap(),
    )
    .unwrap();
    assert_eq!(res.completions.iter().sum::<u64>(), steps);
    assert!(res.total_time > 0.0);
    assert!(res.tau_max > 0);

    // the Fenwick-backed adaptive policy covers the same scale with
    // O(log n) observe/route
    let cfg = SimConfig {
        seed: 10,
        ..SimConfig::new(
            p.clone(),
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            256,
            steps,
        )
    };
    let policy = FenwickAdaptivePolicy::new(p, 0.3).unwrap();
    let res = run_with_policy(cfg, Box::new(policy)).unwrap();
    assert_eq!(res.completions.iter().sum::<u64>(), steps);
    assert!(res.mean_queue.iter().sum::<f64>() > 0.0);
}
