//! ThreadSanitizer target for the threaded sharded driver.
//!
//! The CI `tsan` job compiles this suite with `-Zsanitizer=thread` and
//! runs it at the ISSUE grid S ∈ {4, 8} × threads ∈ {2, 4}: every epoch
//! of the dispatcher/worker mailbox protocol executes under the race
//! detector while the digests are simultaneously pinned to the sequential
//! engine (so a data race AND a determinism break both fail here).  The
//! suite also runs in the plain test tier, where it doubles as coverage
//! of the thread grid the loom models abstract.

use fedqueue::coordinator::policy::{FenwickAdaptivePolicy, SamplingPolicy, StaticPolicy};
use fedqueue::simulator::{
    run_with_policy, EngineConfig, ServiceDist, ServiceFamily, SimConfig, SimResult,
};

const SHARD_GRID: [usize; 2] = [4, 8];
const THREAD_GRID: [usize; 2] = [2, 4];

fn two_cluster(n: usize, c: usize, steps: u64, seed: u64) -> SimConfig {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    SimConfig {
        seed,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    }
}

fn digest(r: &SimResult) -> Vec<u64> {
    let mut d = vec![r.tau_max, r.total_time.to_bits()];
    d.extend(r.completions.iter().copied());
    d.extend(r.dispatches.iter().copied());
    d.extend(r.tau_sum.iter().map(|&x| x.to_bits()));
    d.extend(r.mean_queue.iter().map(|&x| x.to_bits()));
    d
}

fn grid_matches_sequential(mk_policy: impl Fn() -> Box<dyn SamplingPolicy>) {
    let (n, c, steps) = (16, 10, 1_500);
    for s in SHARD_GRID {
        let mut cfg = two_cluster(n, c, steps, 23);
        cfg.engine = EngineConfig::sharded(s, 1);
        let oracle = digest(&run_with_policy(cfg, mk_policy()).unwrap());
        for t in THREAD_GRID {
            let mut cfg = two_cluster(n, c, steps, 23);
            cfg.engine = EngineConfig::sharded(s, t);
            let got = digest(&run_with_policy(cfg, mk_policy()).unwrap());
            assert_eq!(got, oracle, "S={s} threads={t} diverged from sequential");
        }
    }
}

#[test]
fn threaded_static_policy_grid() {
    let n = 16;
    grid_matches_sequential(|| Box::new(StaticPolicy::new(vec![1.0 / n as f64; n]).unwrap()));
}

#[test]
fn threaded_adaptive_policy_grid() {
    let n = 16;
    grid_matches_sequential(|| {
        Box::new(FenwickAdaptivePolicy::new(vec![1.0 / n as f64; n], 0.5).unwrap())
    });
}

#[test]
fn threaded_run_survives_repeated_pools() {
    // churn the worker pool itself: many short runs spin up and wind down
    // scoped workers; under TSan this exercises startup/shutdown ordering
    let n = 8;
    for seed in 0..6u64 {
        let mut cfg = two_cluster(n, 5, 200, 100 + seed);
        cfg.engine = EngineConfig::sharded(4, 2);
        let res = run_with_policy(cfg, Box::new(StaticPolicy::new(vec![1.0 / n as f64; n]).unwrap()));
        assert!(res.is_ok());
    }
}
