//! Property-based tests (hand-rolled harness, see util::proptest) on the
//! coordinator/queueing invariants the paper's analysis rests on.

use fedqueue::fl::{FedBuff, GenAsync, GradientCtx, ModelState, ServerStrategy};
use fedqueue::queueing::ClosedNetwork;
use fedqueue::simulator::{Network, ServiceDist, ServiceFamily, SimConfig};
use fedqueue::util::proptest::{check, Config, Gen, UsizeGen, WeightsGen};
use fedqueue::util::rng::{AliasTable, Rng};

fn normalize(w: &[f64]) -> Vec<f64> {
    let s: f64 = w.iter().sum();
    w.iter().map(|x| x / s).collect()
}

/// Population conservation (Σ X_i = C at every step) and constant
/// in-flight cardinality (Lemma 9.i) for random networks.
#[test]
fn prop_population_conserved() {
    let g = WeightsGen { len_lo: 2, len_hi: 12, w_lo: 0.05, w_hi: 5.0 };
    check("population-conserved", &g, &Config { cases: 40, ..Default::default() }, |w| {
        let n = w.len();
        let p = normalize(w);
        let rates: Vec<f64> = w.iter().map(|x| 0.2 + x).collect();
        let c = 1 + (n * 2) / 3;
        let cfg = SimConfig {
            seed: 0x1234,
            ..SimConfig::new(
                p,
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                c,
                0,
            )
        };
        let mut net = Network::new(cfg).map_err(|e| e)?;
        for step in 0..300 {
            if net.population() != c {
                return Err(format!("step {step}: population {} != C={c}", net.population()));
            }
            net.advance().ok_or("drained")?;
        }
        Ok(())
    });
}

/// FIFO within a node: completion order equals dispatch order per node.
#[test]
fn prop_fifo_per_node() {
    let g = UsizeGen { lo: 2, hi: 10 };
    check("fifo-per-node", &g, &Config { cases: 25, ..Default::default() }, |&n| {
        let p = vec![1.0 / n as f64; n];
        let rates: Vec<f64> = (0..n).map(|i| 0.5 + i as f64 * 0.3).collect();
        let cfg = SimConfig {
            seed: 42 + n as u64,
            record_tasks: true,
            ..SimConfig::new(
                p,
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                n,
                2_000,
            )
        };
        let res = fedqueue::simulator::run(cfg).map_err(|e| e)?;
        let mut last_dispatch = vec![None::<u64>; n];
        for t in &res.tasks {
            let node = t.node as usize;
            if let Some(prev) = last_dispatch[node] {
                if t.dispatch_step < prev {
                    return Err(format!(
                        "node {node}: completed dispatch {} after {}",
                        t.dispatch_step, prev
                    ));
                }
            }
            last_dispatch[node] = Some(t.dispatch_step);
        }
        Ok(())
    });
}

/// Routing empirical frequencies match p (χ²-style tolerance).
#[test]
fn prop_routing_matches_p() {
    let g = WeightsGen { len_lo: 2, len_hi: 8, w_lo: 0.1, w_hi: 3.0 };
    check("routing-matches-p", &g, &Config { cases: 20, ..Default::default() }, |w| {
        let p = normalize(w);
        let alias = AliasTable::new(&p).map_err(|e| e)?;
        let mut rng = Rng::new(7);
        let trials = 60_000;
        let mut counts = vec![0u64; p.len()];
        for _ in 0..trials {
            counts[alias.sample(&mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let f = c as f64 / trials as f64;
            let sd = (p[i] * (1.0 - p[i]) / trials as f64).sqrt();
            if (f - p[i]).abs() > 5.0 * sd + 1e-4 {
                return Err(format!("index {i}: freq {f} vs p {}", p[i]));
            }
        }
        Ok(())
    });
}

/// Generalized AsyncSGD unbiasedness: for random p and per-client constant
/// gradients, the expected applied step equals the uniform average.
#[test]
fn prop_gen_async_unbiased() {
    let g = WeightsGen { len_lo: 2, len_hi: 6, w_lo: 0.2, w_hi: 2.0 };
    check("gasync-unbiased", &g, &Config { cases: 12, ..Default::default() }, |w| {
        let p = normalize(w);
        let n = p.len();
        let alias = AliasTable::new(&p).map_err(|e| e)?;
        let mut rng = Rng::new(0xBEEF);
        let trials = 120_000;
        let mut total = 0.0f64;
        for _ in 0..trials {
            let i = alias.sample(&mut rng);
            let mut m = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
            let mut s = GenAsync::new(1.0, p.clone());
            let g = vec![vec![(i + 1) as f32]];
            s.on_gradient(&mut m, &GradientCtx::sampled(i, &p, &g));
            total += -m.tensors[0][0] as f64;
        }
        let mean = total / trials as f64;
        // E[step] = Σ_i p_i · g_i/(n p_i) = (1/n) Σ_i g_i  — independent of p
        let want = (1..=n).map(|v| v as f64).sum::<f64>() / n as f64;
        if (mean - want).abs() > 0.05 * want {
            return Err(format!("mean step {mean} vs unbiased target {want}"));
        }
        Ok(())
    });
}

/// Buzen marginals are valid distributions and means sum to C, for random
/// networks (theory-side invariant).
#[test]
fn prop_buzen_marginals_consistent() {
    let g = WeightsGen { len_lo: 2, len_hi: 9, w_lo: 0.05, w_hi: 4.0 };
    check("buzen-marginals", &g, &Config { cases: 50, ..Default::default() }, |w| {
        let p = normalize(w);
        let rates: Vec<f64> = w.iter().rev().map(|x| 0.1 + x).collect();
        let net = ClosedNetwork::new(p, rates).map_err(|e| e)?;
        let c = 3 + w.len();
        let b = net.buzen(c);
        let mut total_mean = 0.0;
        for i in 0..w.len() {
            let mut mass = 0.0;
            for k in 0..=c {
                let q = b.pmf(i, k, c);
                if !(0.0..=1.0 + 1e-9).contains(&q) {
                    return Err(format!("pmf out of range: node {i} k {k}: {q}"));
                }
                mass += q;
            }
            if (mass - 1.0).abs() > 1e-8 {
                return Err(format!("node {i}: pmf mass {mass}"));
            }
            total_mean += b.mean_queue(i, c);
        }
        if (total_mean - c as f64).abs() > 1e-6 {
            return Err(format!("Σ E[X_i] = {total_mean} != C={c}"));
        }
        Ok(())
    });
}

/// FedBuff applies exactly every z-th gradient regardless of arrival order.
#[test]
fn prop_fedbuff_cadence() {
    let g = UsizeGen { lo: 1, hi: 12 };
    check("fedbuff-cadence", &g, &Config { cases: 30, ..Default::default() }, |&z| {
        let mut m = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
        let mut s = FedBuff::new(0.1, z).map_err(|e| e)?;
        let p = vec![0.2; 5];
        let mut rng = Rng::new(z as u64);
        for k in 1..=(z * 7) {
            let node = rng.usize_below(5);
            let g = vec![vec![1.0f32]];
            let stepped = s.on_gradient(&mut m, &GradientCtx::sampled(node, &p, &g));
            if stepped != (k % z == 0) {
                return Err(format!("z={z}: step at gradient {k} unexpected"));
            }
        }
        if s.version() != 7 {
            return Err(format!("z={z}: {} versions, want 7", s.version()));
        }
        Ok(())
    });
}
