//! Statistical test harness for the routing samplers.
//!
//! Chi-square goodness-of-fit tests pin the O(1) alias sampler and the
//! O(log n) Fenwick sampler to their target distributions — including the
//! skewed two-cluster p of Theorem 1 and near-degenerate distributions —
//! and pin the Fenwick-backed adaptive policy's re-weighting to the exact
//! softmax-tilted distribution computed from first principles.  The fixed
//! linear CDF scan (`util::sampler::linear_route`) serves as the exact
//! oracle: the fast samplers must agree with it draw for draw on shared
//! uniform variates, and its own fall-through semantics are tested here.
//!
//! All tests use fixed seeds: the chi-square acceptances are exact
//! reproducible computations, not flaky thresholds.

use fedqueue::coordinator::policy::{
    AdaptiveQueuePolicy, DelayAdaptivePolicy, FenwickAdaptivePolicy, FenwickDelayAdaptivePolicy,
    SamplingPolicy,
};
use fedqueue::util::rng::{AliasTable, Rng};
use fedqueue::util::sampler::{linear_route, FenwickSampler};
use fedqueue::util::stats::{chi_square_cdf, chi_square_stat};

/// Assert the sampled `counts` are consistent with the model `p`: the
/// chi-square statistic's CDF quantile under H0 must stay below 1 − 10⁻⁵.
/// With fixed seeds this is a deterministic regression check (a genuinely
/// wrong sampler drives the quantile to 1 − 10⁻³⁰-ish), not a flaky
/// threshold.
fn assert_gof(label: &str, counts: &[u64], p: &[f64]) {
    let (stat, df) = chi_square_stat(counts, p);
    assert!(df > 0, "{label}: degenerate support");
    let q = chi_square_cdf(df as f64, stat);
    assert!(
        q < 0.99999,
        "{label}: chi2 = {stat:.2} at {df} df (CDF {q:.6}) — sampler does not match p"
    );
}

fn counts_from<F: FnMut(&mut Rng) -> usize>(n: usize, trials: u64, seed: u64, mut f: F) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut counts = vec![0u64; n];
    for _ in 0..trials {
        counts[f(&mut rng)] += 1;
    }
    counts
}

/// The three distribution shapes every sampler must reproduce.
fn target_distributions() -> Vec<(&'static str, Vec<f64>)> {
    // uniform over many nodes
    let uniform = vec![1.0 / 200.0; 200];
    // skewed two-cluster (Theorem-1 shape): 25 fast nodes carry p = 0.002,
    // 25 slow nodes carry the rest
    let pf = 0.002;
    let q = (1.0 - 25.0 * pf) / 25.0;
    let two_cluster: Vec<f64> = (0..50).map(|i| if i < 25 { pf } else { q }).collect();
    // near-degenerate: one node holds 99.9% of the mass
    let n = 20;
    let rest = 0.001 / (n - 1) as f64;
    let mut degenerate = vec![rest; n];
    degenerate[7] = 0.999;
    let sum: f64 = degenerate.iter().sum();
    for d in degenerate.iter_mut() {
        *d /= sum;
    }
    vec![
        ("uniform-200", uniform),
        ("two-cluster-skew", two_cluster),
        ("near-degenerate", degenerate),
    ]
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn alias_sampler_reproduces_target_distributions() {
    for (label, p) in target_distributions() {
        let alias = AliasTable::new(&p).unwrap();
        let trials = 400_000;
        let counts = counts_from(p.len(), trials, 0xA11A5, |rng| alias.sample(rng));
        assert_gof(&format!("alias/{label}"), &counts, &p);
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn fenwick_sampler_reproduces_target_distributions() {
    for (label, p) in target_distributions() {
        let fen = FenwickSampler::new(&p).unwrap();
        let trials = 400_000;
        let counts = counts_from(p.len(), trials, 0xFE9C, |rng| fen.sample(rng));
        assert_gof(&format!("fenwick/{label}"), &counts, &p);
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn fenwick_sampler_tracks_point_updates() {
    // after incremental re-weighting the tree must sample the *updated*
    // distribution, not the build-time one
    let n = 64;
    let mut fen = FenwickSampler::new(&vec![1.0; n]).unwrap();
    let mut rng = Rng::new(0x0BEEF);
    for _ in 0..5_000 {
        let i = rng.usize_below(n);
        fen.set(i, rng.uniform() * 4.0);
    }
    let total: f64 = fen.weights().iter().sum();
    let p: Vec<f64> = fen.weights().iter().map(|w| w / total).collect();
    let counts = counts_from(n, 400_000, 0xF00D, |rng| fen.sample(rng));
    assert_gof("fenwick/after-updates", &counts, &p);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn fenwick_agrees_with_linear_oracle_on_shared_variates() {
    // draw-for-draw agreement: on the same uniform variate the Fenwick
    // descent and the exact CDF scan pick the same index (up to fp ties
    // on interval boundaries, which must be vanishingly rare and adjacent
    // in CDF order)
    for (label, p) in target_distributions() {
        let fen = FenwickSampler::new(&p).unwrap();
        let total = fen.total();
        let mut rng = Rng::new(0x0DD5);
        let trials = 200_000;
        let mut mismatches = 0u64;
        for _ in 0..trials {
            let u = rng.uniform();
            let a = linear_route(&p, u);
            let b = fen.sample_at(u * total);
            if a != b {
                mismatches += 1;
                // any fp disagreement must sit on an interval boundary:
                // the cumulative masses up to the two answers bracket u
                let lo = a.min(b);
                let hi = a.max(b);
                let gap: f64 = p[lo + 1..=hi].iter().sum::<f64>() - p[hi];
                assert!(
                    gap.abs() < 1e-9,
                    "{label}: non-adjacent disagreement {a} vs {b} at u={u}"
                );
            }
        }
        assert!(
            (mismatches as f64) < trials as f64 * 1e-3,
            "{label}: {mismatches} oracle disagreements in {trials} draws"
        );
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn adaptive_reweighting_matches_exact_softmax_tilt() {
    // p_i ∝ base_i · exp(−γ·X_i): the Fenwick policy's probabilities after
    // incremental observations must equal the closed form to fp precision,
    // and its routed samples must pass goodness of fit against it
    let base = vec![
        0.05, 0.15, 0.02, 0.08, 0.20, 0.10, 0.05, 0.05, 0.25, 0.05,
    ];
    let gamma = 0.7;
    let lens: [u32; 10] = [0, 3, 1, 0, 8, 2, 0, 5, 1, 4];
    let mut policy = FenwickAdaptivePolicy::new(base.clone(), gamma).unwrap();
    for (i, &l) in lens.iter().enumerate() {
        policy.observe_node(i, l);
    }
    // exact softmax-tilted distribution
    let w: Vec<f64> = base
        .iter()
        .zip(lens.iter())
        .map(|(&b, &x)| b * (-gamma * x as f64).exp())
        .collect();
    let z: f64 = w.iter().sum();
    let exact: Vec<f64> = w.iter().map(|wi| wi / z).collect();
    for i in 0..base.len() {
        assert!(
            (policy.prob_of(i) - exact[i]).abs() < 1e-12,
            "node {i}: {} vs exact {}",
            policy.prob_of(i),
            exact[i]
        );
    }
    let counts = counts_from(base.len(), 400_000, 0xADA7, |rng| policy.route(rng));
    assert_gof("fenwick-adaptive/softmax-tilt", &counts, &exact);
}

#[test]
fn adaptive_fenwick_and_exact_policies_realize_the_same_distribution() {
    // the O(log n) policy and the O(n) oracle must stay in lockstep
    // through a churn of queue-length observations
    let n = 40;
    let base = vec![1.0 / n as f64; n];
    let mut fast = FenwickAdaptivePolicy::new(base.clone(), 0.4).unwrap();
    let mut exact = AdaptiveQueuePolicy::new(base, 0.4).unwrap();
    let mut lens = vec![0u32; n];
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..2_000 {
        let i = rng.usize_below(n);
        lens[i] = rng.usize_below(12) as u32;
        fast.observe_node(i, lens[i]);
        exact.observe(&lens);
        let j = rng.usize_below(n);
        assert!(
            (fast.prob_of(j) - exact.prob_of(j)).abs() < 1e-10,
            "node {j} after churn: {} vs {}",
            fast.prob_of(j),
            exact.prob_of(j)
        );
    }
    // full-distribution agreement at the end of the churn
    let pf = fast.probs();
    let pe = exact.probs();
    for i in 0..n {
        assert!((pf[i] - pe[i]).abs() < 1e-10, "node {i}: {} vs {}", pf[i], pe[i]);
    }
}

/// Completion histories that drive the delay EWMA into the three shapes
/// every sampler must survive: uniform estimates, a two-cluster skew, and
/// a near-degenerate state where one node keeps nearly all the mass.
/// Returns (label, n, gamma, beta, completions as (node, delay) events).
fn delay_histories() -> Vec<(&'static str, usize, f64, f64, Vec<(usize, u64)>)> {
    // uniform: every node observes the same delay — tilt cancels in the
    // normalization and the distribution must stay the base
    let n_u = 40;
    let uniform: Vec<(usize, u64)> = (0..n_u).flat_map(|i| [(i, 6u64), (i, 6u64)]).collect();
    // two-cluster skew: the slow half reports delays 20, the fast half 2
    let n_s = 30;
    let skew: Vec<(usize, u64)> = (0..n_s)
        .flat_map(|i| {
            let d = if i < n_s / 2 { 2u64 } else { 20 };
            [(i, d), (i, d), (i, d)]
        })
        .collect();
    // near-degenerate: every node but node 3 drowns in delay
    let n_d = 12;
    let degen: Vec<(usize, u64)> = (0..n_d)
        .flat_map(|i| {
            let d = if i == 3 { 0u64 } else { 35 };
            [(i, d), (i, d)]
        })
        .collect();
    vec![
        ("uniform-ewma", n_u, 0.4, 0.5, uniform),
        ("two-cluster-ewma", n_s, 0.25, 0.6, skew),
        ("near-degenerate-ewma", n_d, 0.3, 0.4, degen),
    ]
}

/// Closed-form EWMA trace of a completion history.
fn ewma_of(n: usize, beta: f64, events: &[(usize, u64)]) -> Vec<f64> {
    let mut d = vec![0.0f64; n];
    for &(i, delay) in events {
        d[i] = beta * d[i] + (1.0 - beta) * delay as f64;
    }
    d
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn delay_adaptive_reweighting_matches_exact_ewma_tilt() {
    // p_i ∝ base_i · exp(−γ·D̂_i): after a completion history, the Fenwick
    // policy's probabilities must equal the closed form to fp precision,
    // its exact oracle must agree, and its routed samples must pass
    // goodness of fit against the closed-form distribution
    for (label, n, gamma, beta, events) in delay_histories() {
        let base = vec![1.0 / n as f64; n];
        let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap();
        let mut exact = DelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap();
        for &(i, d) in &events {
            fast.observe_completion(i, d, d as f64);
            exact.observe_completion(i, d, d as f64);
        }
        let w: Vec<f64> = ewma_of(n, beta, &events)
            .iter()
            .zip(base.iter())
            .map(|(&d, &b)| b * (-gamma * d).exp())
            .collect();
        let z: f64 = w.iter().sum();
        let closed: Vec<f64> = w.iter().map(|wi| wi / z).collect();
        for i in 0..n {
            assert!(
                (fast.prob_of(i) - closed[i]).abs() < 1e-12,
                "{label} node {i}: fenwick {} vs closed form {}",
                fast.prob_of(i),
                closed[i]
            );
            assert!(
                (exact.prob_of(i) - closed[i]).abs() < 1e-12,
                "{label} node {i}: exact {} vs closed form {}",
                exact.prob_of(i),
                closed[i]
            );
        }
        let counts = counts_from(n, 400_000, 0xDE1A7, |rng| fast.route(rng));
        assert_gof(&format!("delay-adaptive/{label}"), &counts, &closed);
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn delay_adaptive_agrees_with_exact_oracle_draw_for_draw() {
    // identical completion histories + identical RNG streams: both
    // implementations consume exactly one uniform per route, so they must
    // pick the same node draw for draw — any fp disagreement must sit on
    // an interval boundary (adjacent in CDF order, vanishing mass between)
    for (label, n, gamma, beta, events) in delay_histories() {
        let base = vec![1.0 / n as f64; n];
        let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap();
        let mut exact = DelayAdaptivePolicy::new(base, gamma, beta).unwrap();
        for &(i, d) in &events {
            fast.observe_completion(i, d, d as f64);
            exact.observe_completion(i, d, d as f64);
        }
        let mut rng_a = Rng::new(0x0DD5E);
        let mut rng_b = Rng::new(0x0DD5E);
        let trials = 200_000u64;
        let mut mismatches = 0u64;
        for _ in 0..trials {
            let a = fast.route(&mut rng_a);
            let b = exact.route(&mut rng_b);
            if a != b {
                mismatches += 1;
                let probs = exact.probs();
                let lo = a.min(b);
                let hi = a.max(b);
                let gap: f64 = probs[lo + 1..=hi].iter().sum::<f64>() - probs[hi];
                assert!(
                    gap.abs() < 1e-9,
                    "{label}: non-adjacent disagreement {a} vs {b}"
                );
            }
        }
        assert!(
            (mismatches as f64) < trials as f64 * 1e-3,
            "{label}: {mismatches} oracle disagreements in {trials} draws"
        );
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn mass_collapse_fallback_realizes_the_masked_base_at_extreme_gamma() {
    // the satellite bug, pinned statistically: γ·D̂ so large that EVERY
    // tilted weight exp(−γ·D̂_i) underflows to exactly 0.0 — the total
    // mass collapses and the fallback must engage atomically, routing by
    // the BASE distribution conditioned on current membership.  Departed
    // nodes must never be drawn (the chi-square statistic goes infinite
    // if one is), and the surviving draws must pass goodness of fit
    // against the masked, renormalized base.
    let n = 16usize;
    let base = vec![1.0 / n as f64; n];
    let (gamma, beta) = (1e4, 0.5);
    let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap();
    let mut exact = DelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap();
    // one enormous delay per node: D̂ = (1−β)·100 = 50, γ·D̂ = 5·10⁵ ≫ 745
    // (the f64 exp underflow threshold), so every weight is exactly 0.0
    for i in 0..n {
        fast.observe_completion(i, 100, 100.0);
        exact.observe_completion(i, 100, 100.0);
    }
    // two nodes depart while the collapse is in force
    for node in [3usize, 11] {
        fast.observe_leave(node);
        exact.observe_leave(node);
    }
    let mut target = base.clone();
    target[3] = 0.0;
    target[11] = 0.0;
    let z: f64 = target.iter().sum();
    for t in target.iter_mut() {
        *t /= z;
    }
    for i in 0..n {
        assert!(
            (fast.prob_of(i) - target[i]).abs() < 1e-12,
            "node {i}: fenwick fallback {} vs masked base {}",
            fast.prob_of(i),
            target[i]
        );
        assert!(
            (exact.prob_of(i) - target[i]).abs() < 1e-12,
            "node {i}: exact fallback {} vs masked base {}",
            exact.prob_of(i),
            target[i]
        );
    }
    let counts = counts_from(n, 400_000, 0x0DD5E, |rng| fast.route(rng));
    assert_eq!(counts[3], 0, "mass-collapse fallback routed to departed node 3");
    assert_eq!(counts[11], 0, "mass-collapse fallback routed to departed node 11");
    assert_gof("mass-collapse/masked-base", &counts, &target);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn mass_collapse_fallback_agrees_with_exact_oracle_draw_for_draw() {
    // same collapse + membership state, shared RNG streams: the Fenwick
    // policy's masked one-uniform scan and the exact oracle's renormalized
    // CDF scan must pick the same node draw for draw (fp boundary ties
    // must be adjacent in CDF order with vanishing mass between)
    let n = 16usize;
    let base = vec![1.0 / n as f64; n];
    let (gamma, beta) = (1e4, 0.5);
    let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap();
    let mut exact = DelayAdaptivePolicy::new(base, gamma, beta).unwrap();
    for i in 0..n {
        fast.observe_completion(i, 100, 100.0);
        exact.observe_completion(i, 100, 100.0);
    }
    for node in [3usize, 11] {
        fast.observe_leave(node);
        exact.observe_leave(node);
    }
    let mut rng_a = Rng::new(0x0DD5E);
    let mut rng_b = Rng::new(0x0DD5E);
    let trials = 200_000u64;
    let mut mismatches = 0u64;
    for _ in 0..trials {
        let a = fast.route(&mut rng_a);
        let b = exact.route(&mut rng_b);
        assert!(a != 3 && a != 11, "fenwick fallback drew departed node {a}");
        assert!(b != 3 && b != 11, "exact fallback drew departed node {b}");
        if a != b {
            mismatches += 1;
            let probs = exact.probs();
            let lo = a.min(b);
            let hi = a.max(b);
            let gap: f64 = probs[lo + 1..=hi].iter().sum::<f64>() - probs[hi];
            assert!(gap.abs() < 1e-9, "non-adjacent disagreement {a} vs {b}");
        }
    }
    assert!(
        (mismatches as f64) < trials as f64 * 1e-3,
        "{mismatches} oracle disagreements in {trials} draws"
    );
}

#[test]
fn delay_fenwick_and_exact_policies_stay_in_lockstep_through_churn() {
    // the O(log n) policy and the O(n) oracle must realize the same
    // distribution through a long stream of completion observations
    let n = 40;
    let base = vec![1.0 / n as f64; n];
    let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), 0.3, 0.8).unwrap();
    let mut exact = DelayAdaptivePolicy::new(base, 0.3, 0.8).unwrap();
    let mut rng = Rng::new(0xC0FFE);
    for _ in 0..2_000 {
        let i = rng.usize_below(n);
        let d = rng.below(25);
        fast.observe_completion(i, d, d as f64);
        exact.observe_completion(i, d, d as f64);
        let j = rng.usize_below(n);
        assert!(
            (fast.prob_of(j) - exact.prob_of(j)).abs() < 1e-10,
            "node {j} after churn: {} vs {}",
            fast.prob_of(j),
            exact.prob_of(j)
        );
    }
    let pf = fast.probs();
    let pe = exact.probs();
    for i in 0..n {
        assert!((pf[i] - pe[i]).abs() < 1e-10, "node {i}: {} vs {}", pf[i], pe[i]);
    }
}

#[test]
fn linear_route_oracle_fallthrough_returns_last_positive_mass_node() {
    // the historical bug: trailing zero-probability nodes and u near 1
    // made the scan fall through to the last index even with p[last] = 0
    let p = [0.3, 0.7 - 1e-17, 0.0, 0.0, 0.0];
    for u in [1.0 - 1e-17, 0.9999999999999999] {
        let i = linear_route(&p, u);
        assert_eq!(i, 1, "u={u} must land on the last positive-mass node");
        assert!(p[i] > 0.0);
    }
    // interior zeros are skipped in normal operation too
    let p = [0.5, 0.0, 0.5];
    let mut rng = Rng::new(0x10E);
    for _ in 0..10_000 {
        let i = linear_route(&p, rng.uniform());
        assert_ne!(i, 1, "zero-mass node selected");
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: large sample counts (CI stat-tests job)")]
fn linear_route_oracle_reproduces_target_distributions() {
    // the oracle itself must pass its own harness — otherwise it can't
    // anchor the fast samplers
    for (label, p) in target_distributions() {
        let counts = counts_from(p.len(), 400_000, 0x11EA8, |rng| {
            linear_route(&p, rng.uniform())
        });
        assert_gof(&format!("linear/{label}"), &counts, &p);
    }
}
