//! Integration: the two artifact flavors — Pallas-kernel lowering vs
//! pure-jnp lowering — must be numerically interchangeable.  This is what
//! licenses running the multi-seed experiments on the fast jnp flavor
//! while the Pallas flavor remains the TPU-faithful path (§Perf).
//! Requires the PJRT backend (`--features pjrt`) and built artifacts.
#![cfg(feature = "pjrt")]

use fedqueue::data::Batch;
use fedqueue::runtime::{Backend, Manifest, PjrtBackend};
use fedqueue::util::rng::Rng;

fn ready() -> bool {
    let ok = Manifest::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("[skip] run `make artifacts`");
    }
    ok
}

fn batch(spec: &fedqueue::runtime::ModelSpec, b: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..b * spec.input_dim).map(|_| rng.normal() as f32).collect();
    let mut onehot = vec![0.0f32; b * spec.classes];
    for bi in 0..b {
        onehot[bi * spec.classes + rng.usize_below(spec.classes)] = 1.0;
    }
    Batch { x, onehot, batch: b }
}

#[test]
fn pallas_and_jnp_flavors_agree() {
    if !ready() {
        return;
    }
    let dir = Manifest::default_dir();
    let mut pallas = PjrtBackend::load(&dir, "tiny").unwrap();
    let mut jnp = PjrtBackend::load(&dir, "tiny_jnp").unwrap();
    let spec = pallas.spec().clone();
    let model = spec.init_model(31);
    let b = batch(&spec, spec.train_batch, 32);
    let (lp, gp) = pallas.train_step(&model, &b).unwrap();
    let (lj, gj) = jnp.train_step(&model, &b).unwrap();
    assert!((lp - lj).abs() < 1e-5 * (1.0 + lj.abs()), "loss {lp} vs {lj}");
    for (ti, (a, c)) in gp.iter().zip(&gj).enumerate() {
        let mut max_err = 0.0f64;
        for (x, y) in a.iter().zip(c) {
            max_err = max_err.max((*x as f64 - *y as f64).abs());
        }
        assert!(max_err < 1e-4, "tensor {ti}: flavor gradient gap {max_err}");
    }
    let eb = batch(&spec, spec.eval_batch, 33);
    let (l1, c1) = pallas.eval_batch(&model, &eb, spec.eval_batch).unwrap();
    let (l2, c2) = jnp.eval_batch(&model, &eb, spec.eval_batch).unwrap();
    assert!((l1 - l2).abs() < 1e-4 * (1.0 + l2.abs()));
    assert_eq!(c1, c2);
}

#[test]
fn manifest_carries_both_flavors_for_all_variants() {
    if !ready() {
        return;
    }
    let m = Manifest::load(&Manifest::default_dir()).unwrap();
    for base in ["tiny", "cifar", "wide", "tinyimg"] {
        let a = m.variant(base).unwrap();
        let b = m.variant(&format!("{base}_jnp")).unwrap();
        assert_eq!(a.n_params, b.n_params, "{base}: flavor param mismatch");
        assert_eq!(a.train_batch, b.train_batch);
        // the jnp lowering must be much smaller HLO (no interpreter loop)
        let sa = std::fs::metadata(&a.train_file).unwrap().len();
        let sb = std::fs::metadata(&b.train_file).unwrap().len();
        assert!(sb < sa, "{base}: jnp HLO {sb}B should be smaller than pallas {sa}B");
    }
}
