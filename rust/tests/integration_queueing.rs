//! Integration: theory ⇄ simulation cross-validation of the closed Jackson
//! network — the empirical engine and the exact product-form analysis must
//! agree on queue lengths, utilizations, throughput, and the paper's delay
//! quantities m_i, across service families and load regimes.

use fedqueue::coordinator::{optimal_two_cluster, PolicyCtx, SamplingPolicy};
use fedqueue::queueing::{ClosedNetwork, MiEstimator, TwoCluster};
use fedqueue::simulator::{run, ServiceDist, ServiceFamily, SimConfig};

fn sim(
    p: Vec<f64>,
    rates: Vec<f64>,
    c: usize,
    steps: u64,
    seed: u64,
) -> fedqueue::simulator::SimResult {
    let cfg = SimConfig {
        seed,
        ..SimConfig::new(
            p,
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    };
    run(cfg).unwrap()
}

#[test]
fn queue_lengths_match_theory_at_all_loads() {
    let p = vec![0.25, 0.25, 0.25, 0.25];
    let rates = vec![2.0, 1.5, 1.0, 0.5];
    let net = ClosedNetwork::new(p.clone(), rates.clone()).unwrap();
    for &c in &[1usize, 5, 20, 100] {
        let res = sim(p.clone(), rates.clone(), c, 400_000, 0xA1 + c as u64);
        let b = net.buzen(c);
        for i in 0..4 {
            let theory = b.mean_queue(i, c);
            let emp = res.mean_queue[i];
            let tol = 0.03 * c as f64 + 0.05;
            assert!(
                (emp - theory).abs() < tol,
                "C={c} node {i}: sim {emp} vs theory {theory}"
            );
        }
    }
}

#[test]
fn throughput_matches_theory() {
    let p = vec![0.1, 0.2, 0.3, 0.4];
    let rates = vec![1.0, 2.0, 1.0, 3.0];
    let net = ClosedNetwork::new(p.clone(), rates.clone()).unwrap();
    for &c in &[2usize, 10, 50] {
        let res = sim(p.clone(), rates.clone(), c, 300_000, 0xB2 + c as u64);
        let theory = net.buzen(c).throughput(c);
        let emp = res.step_rate(300_000);
        assert!(
            (emp / theory - 1.0).abs() < 0.02,
            "C={c}: sim rate {emp} vs theory {theory}"
        );
    }
}

#[test]
fn delays_match_throughput_estimator() {
    // m_i (CS-step delays) from the simulator vs the arrival-theorem
    // Λ(C)-rate estimate: the paper's central quantity.
    let n = 10;
    let p = vec![0.1; 10];
    let rates: Vec<f64> = (0..n).map(|i| if i < 5 { 3.0 } else { 1.0 }).collect();
    let net = ClosedNetwork::new(p.clone(), rates.clone()).unwrap();
    for &c in &[5usize, 20, 100] {
        let res = sim(p.clone(), rates.clone(), c, 300_000, 0xC3 + c as u64);
        let an = net.mi_analysis(c, MiEstimator::Throughput);
        for i in [0usize, 9] {
            let emp = res.delay_steps[i].mean();
            let th = an.m[i];
            assert!(
                (emp / th - 1.0).abs() < 0.25,
                "C={c} node {i}: sim delay {emp} vs theory {th}"
            );
        }
        // and the Prop-5 upper bound really is an upper bound (within noise)
        let ub = net.mi_analysis(c, MiEstimator::UpperBound);
        for i in 0..n {
            assert!(
                res.delay_steps[i].mean() <= ub.m[i] * 1.1,
                "C={c} node {i}: delay {} exceeds UB {}",
                res.delay_steps[i].mean(),
                ub.m[i]
            );
        }
    }
}

#[test]
fn service_distribution_insensitivity() {
    // §2: deterministic vs exponential service with equal means barely
    // changes the delay profile (the paper's robustness claim).
    let n = 10;
    let p = vec![0.1; 10];
    let rates: Vec<f64> = (0..n).map(|i| if i < 5 { 2.0 } else { 1.0 }).collect();
    let mut means = Vec::new();
    for family in [ServiceFamily::Exponential, ServiceFamily::Deterministic] {
        let cfg = SimConfig {
            seed: 0xD4,
            ..SimConfig::new(
                p.clone(),
                ServiceDist::from_rates(&rates, family),
                20,
                200_000,
            )
        };
        let res = run(cfg).unwrap();
        means.push((res.cluster_delay(0..5), res.cluster_delay(5..10)));
    }
    let (ef, es) = means[0];
    let (df, ds) = means[1];
    assert!((ef / df - 1.0).abs() < 0.25, "fast: exp {ef} vs det {df}");
    assert!((es / ds - 1.0).abs() < 0.25, "slow: exp {es} vs det {ds}");
}

#[test]
fn fig5_protocol_full_cross_validation() {
    // n=10, μ=(1.2, 1.0), C=1000: simulator vs paper's empirical anchors
    let n = 10;
    let p = vec![0.1; 10];
    let rates: Vec<f64> = (0..n).map(|i| if i < 5 { 1.2 } else { 1.0 }).collect();
    let res = sim(p.clone(), rates.clone(), 1000, 400_000, 0xE5);
    let fast = res.cluster_delay(0..5);
    let slow = res.cluster_delay(5..10);
    // paper: 59 and 1938 over 1e6 steps
    assert!((fast - 59.0).abs() < 12.0, "fast {fast}, paper 59");
    assert!((slow - 1938.0).abs() < 120.0, "slow {slow}, paper 1938");
    // scaling closed forms stay above the empirical means
    let tc = TwoCluster::uniform(10, 5, 1.2, 1.0, 1000);
    let (bf, bs) = tc.delay_bounds();
    assert!(bf > fast * 0.8 && bs > slow * 0.95, "bounds {bf}/{bs}");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: 1.2M-step runs (CI stat-tests job)")]
fn product_form_regression_uniform_and_theorem1_optimal_p() {
    // Empirical stationary queue lengths from a long run must match the
    // closed Jackson product form (Buzen) node by node — under the uniform
    // distribution AND under the Theorem-1 bound-optimal p that the
    // `optimal` policy actually routes with.  This pins the simulator and
    // `queueing::jackson` to each other through the exact distribution the
    // paper's headline experiments use.
    let n = 10;
    let n_fast = 5;
    let c = 50;
    let rates: Vec<f64> = (0..n).map(|i| if i < n_fast { 1.2 } else { 1.0 }).collect();
    let optimal = optimal_two_cluster(&PolicyCtx {
        n,
        base_p: vec![0.1; n],
        gamma: 0.0,
        beta: 0.9,
        n_fast,
        mu_fast: 1.2,
        mu_slow: 1.0,
        concurrency: c,
        steps: 10_000,
    })
    .unwrap();
    let p_opt = optimal.probs();
    assert!(p_opt[0] < 0.1, "optimal must tilt away from fast nodes");
    for (label, p) in [("uniform", vec![0.1; n]), ("optimal", p_opt)] {
        let res = sim(p.clone(), rates.clone(), c, 600_000, 0xF8);
        let net = ClosedNetwork::new(p, rates.clone()).unwrap();
        let b = net.buzen(c);
        let mut total_theory = 0.0;
        for i in 0..n {
            let theory = b.mean_queue(i, c);
            let emp = res.mean_queue[i];
            total_theory += theory;
            let tol = 0.1 * theory + 0.15;
            assert!(
                (emp - theory).abs() < tol,
                "{label} node {i}: sim E[X] {emp} vs product form {theory}"
            );
        }
        // the marginals must account for the whole population C
        assert!(
            (total_theory - c as f64).abs() < 1e-6,
            "{label}: product-form marginals sum to {total_theory}, C = {c}"
        );
        assert_eq!(
            res.mean_queue.iter().sum::<f64>().round() as usize,
            c,
            "{label}: simulated time-average population must be C"
        );
    }
}

#[test]
fn optimal_sampling_effect_matches_app_f2() {
    // p_fast = 7.5e-3: fast delay ÷~10, slow ÷~2 vs uniform (paper App F.2)
    let n = 10;
    let rates: Vec<f64> = (0..n).map(|i| if i < 5 { 1.2 } else { 1.0 }).collect();
    let uni = sim(vec![0.1; 10], rates.clone(), 1000, 300_000, 0xF6);
    let pf = 7.5e-3;
    let q = (1.0 - 5.0 * pf) / 5.0;
    let p: Vec<f64> = (0..n).map(|i| if i < 5 { pf } else { q }).collect();
    let opt = sim(p, rates, 1000, 300_000, 0xF7);
    let ratio_fast = uni.cluster_delay(0..5) / opt.cluster_delay(0..5);
    let ratio_slow = uni.cluster_delay(5..10) / opt.cluster_delay(5..10);
    assert!(ratio_fast > 5.0, "fast delay ratio {ratio_fast}, paper ~10");
    assert!(
        ratio_slow > 1.5 && ratio_slow < 3.0,
        "slow delay ratio {ratio_slow}, paper ~2"
    );
}
