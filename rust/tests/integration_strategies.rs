//! Integration: the trait-based strategy & sampling-policy API.
//!
//! * Registry round-trip: every registered strategy name constructs and
//!   drives 10 real coordinator steps.
//! * Unbiasedness property: Generalized AsyncSGD's inverse-probability
//!   scaling keeps the mean applied update equal to the uniform-sampling
//!   reference under `static`, `optimal`, and the time-varying `adaptive`
//!   policy — through the actual closed-network event stream.
//! * `--policy optimal` reproduces the historical `--optimal-p` behavior:
//!   identical delays for identical seeds.

use fedqueue::coordinator::policy::{
    optimal_two_cluster, AdaptiveQueuePolicy, FenwickDelayAdaptivePolicy, PolicyCtx,
    PolicyRegistry, SamplingPolicy, StaticPolicy,
};
use fedqueue::coordinator::{build_loaders, Driver, DriverConfig, Experiment};
use fedqueue::data::{generate, EvalBatches, Partition, PartitionScheme, SynthSpec};
use fedqueue::fl::{GenAsync, GradientCtx, ModelState, ServerStrategy, StrategyRegistry};
use fedqueue::fl::StrategyParams;
use fedqueue::runtime::{Backend, NativeBackend};
use fedqueue::simulator::{Network, ServiceDist, ServiceFamily, SimConfig};

#[test]
fn strategy_registry_round_trip_runs_ten_steps() {
    // every registered name constructs and runs 10 steps end to end
    let reg = StrategyRegistry::builtin();
    assert!(reg.names().len() >= 5, "expected the 5 built-ins");
    for name in reg.names() {
        let n = 6;
        let spec = SynthSpec::tiny_test();
        let train = std::sync::Arc::new(generate(&spec, 400, 61));
        let val = generate(&spec, 100, 62);
        let part = Partition::build(&train, n, PartitionScheme::Iid, 63).unwrap();
        let mut backend = NativeBackend::tiny();
        let loaders =
            build_loaders(train, &part, backend.spec().train_batch, false, 64).unwrap();
        let val_b = EvalBatches::new(&val, backend.spec().eval_batch);
        let rates = vec![1.5; n];
        let sim = SimConfig {
            seed: 65,
            ..SimConfig::new(
                vec![1.0 / n as f64; n],
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                3,
                10,
            )
        };
        let prm = StrategyParams::new(0.05, sim.p.clone());
        let strategy = reg.build(&name, &prm).unwrap();
        let mut model = backend.spec().init_model(66);
        let cfg = DriverConfig::with_strategy(sim, strategy).unwrap();
        let mut driver = Driver::new(&mut backend, loaders, val_b);
        let res = driver.run(cfg, &mut model).unwrap();
        assert_eq!(res.steps, 10, "{name}");
        assert_eq!(res.strategy, name);
        assert_eq!(res.curve.len(), 1, "{name}: final eval only");
        assert!(res.final_accuracy.is_finite(), "{name}");
    }
}

/// Drive GenAsync through the real event stream under `policy` with
/// per-client constant gradients g_i = i+1 and return the mean applied
/// step per CS step.
fn mean_step_under_policy(policy: Box<dyn SamplingPolicy>, n: usize, steps: u64) -> f64 {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    let cfg = SimConfig {
        seed: 0x5EED,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            n / 2,
            steps,
        )
    };
    let mut net = Network::with_policy(cfg, policy).unwrap();
    let mut strat = GenAsync::new(1.0, vec![1.0 / n as f64; n]);
    let mut model = ModelState { tensors: vec![vec![0.0f32]], shapes: vec![vec![1]] };
    let mut total = 0.0f64;
    for k in 0..steps {
        let out = net.advance().unwrap();
        let node = out.completed_node as usize;
        let g = vec![vec![(node + 1) as f32]];
        let before = model.tensors[0][0] as f64;
        strat.on_gradient(
            &mut model,
            &GradientCtx {
                node,
                step: k,
                time: out.time,
                delay_steps: out.record.delay_steps(),
                dispatch_prob: out.record.dispatch_prob,
                grads: &g,
            },
        );
        total += before - model.tensors[0][0] as f64; // applied descent step
        // keep the iterate bounded so f32 precision holds
        model.tensors[0][0] = 0.0;
    }
    total / steps as f64
}

#[test]
fn gasync_unbiased_under_static_optimal_and_adaptive_policies() {
    // E[applied step] = Σ p_i·(g_i/(n p_i)) = (1/n)Σ g_i for ANY sampling
    // distribution — including the queue-length-adaptive one, because the
    // scale uses the dispatch-time probability.
    let n = 4;
    let steps = 120_000u64;
    let uniform_reference = (1..=n).map(|v| v as f64).sum::<f64>() / n as f64; // 2.5
    let tilted = StaticPolicy::new(vec![0.1, 0.2, 0.3, 0.4]).unwrap();
    let optimal = optimal_two_cluster(&PolicyCtx {
        n,
        base_p: vec![0.25; n],
        gamma: 0.0,
        beta: 0.9,
        n_fast: 2,
        mu_fast: 4.0,
        mu_slow: 1.0,
        concurrency: 2,
        steps: 10_000,
    })
    .unwrap();
    let adaptive = AdaptiveQueuePolicy::new(vec![0.25; n], 0.8).unwrap();
    // mild delay tilt: the IPW correction must absorb the delay-feedback
    // drift exactly like the queue-length one (a strong tilt would only
    // inflate the estimator's variance, not its mean)
    let delay_adaptive = FenwickDelayAdaptivePolicy::new(vec![0.25; n], 0.02, 0.9).unwrap();
    let cases: Vec<(&str, Box<dyn SamplingPolicy>)> = vec![
        ("static", Box::new(tilted)),
        ("optimal", Box::new(optimal)),
        ("adaptive", Box::new(adaptive)),
        ("delay-adaptive", Box::new(delay_adaptive)),
    ];
    for (label, policy) in cases {
        let mean = mean_step_under_policy(policy, n, steps);
        let rel = (mean - uniform_reference).abs() / uniform_reference;
        assert!(
            rel < 0.05,
            "{label}: mean applied step {mean} deviates {rel:.3} from the \
             uniform reference {uniform_reference}"
        );
    }
}

#[test]
fn policy_registry_round_trip() {
    let reg = PolicyRegistry::builtin();
    let ctx = PolicyCtx {
        n: 8,
        base_p: vec![0.125; 8],
        gamma: 0.5,
        beta: 0.9,
        n_fast: 4,
        mu_fast: 4.0,
        mu_slow: 1.0,
        concurrency: 4,
        steps: 500,
    };
    for name in reg.names() {
        let policy = reg.build(&name, &ctx).unwrap();
        let rates: Vec<f64> = (0..8).map(|i| if i < 4 { 4.0 } else { 1.0 }).collect();
        let cfg = SimConfig {
            seed: 71,
            ..SimConfig::new(
                vec![0.125; 8],
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                4,
                0,
            )
        };
        let mut net = Network::with_policy(cfg, policy).unwrap();
        for _ in 0..500 {
            let out = net.advance().unwrap();
            assert_eq!(net.population(), 4, "{name}");
            assert!(out.record.dispatch_prob > 0.0, "{name}");
        }
        let sum: f64 = net.current_probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "{name}: probs sum {sum}");
    }
}

#[test]
fn damped_strategy_with_delay_policy_trains_deterministically() {
    // the full delay-feedback stack end to end: genasync-damped consuming
    // delay-damped steps while delay-adaptive reshapes the routing
    // distribution from observed completions.  The run must be
    // reproducible bit for bit (the feedback channel is RNG-free) and
    // carry the right provenance labels.
    let base = Experiment::builder()
        .variant("tiny")
        .algo("genasync-damped")
        .policy("delay-adaptive")
        .clients(8)
        .concurrency(4)
        .steps(60)
        .eta(0.05)
        .adaptive_gamma(0.1)
        .delay_beta(0.8)
        .damping_kappa(0.4)
        .n_train(600)
        .n_val(150)
        .eval_every(0)
        .seed(5)
        .build()
        .unwrap();
    let a = base.run().unwrap();
    let b = base.run().unwrap();
    assert_eq!(a.strategy, "genasync-damped");
    assert!(a.policy.starts_with("delay-adaptive"), "{}", a.policy);
    assert_eq!(a.versions, 60, "damped GenAsync applies every gradient");
    assert!(a.final_accuracy.is_finite());
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    assert_eq!(a.final_val_loss.to_bits(), b.final_val_loss.to_bits());
    assert_eq!(
        a.total_virtual_time.to_bits(),
        b.total_virtual_time.to_bits()
    );
    assert_eq!(a.tau_max, b.tau_max);
    // kappa = 0 with the same policy degrades to plain gasync exactly:
    // identical event stream, identical model trajectory
    let mut plain = base.clone();
    plain.algo = "gasync".into();
    let mut undamped = base.clone();
    undamped.kappa = 0.0;
    let p = plain.run().unwrap();
    let u = undamped.run().unwrap();
    assert_eq!(p.final_accuracy.to_bits(), u.final_accuracy.to_bits());
    assert_eq!(p.final_val_loss.to_bits(), u.final_val_loss.to_bits());
    assert_eq!(
        p.total_virtual_time.to_bits(),
        u.total_virtual_time.to_bits()
    );
}

#[test]
fn optimal_policy_reproduces_optimal_p_static_tilt() {
    // acceptance: `--policy optimal` must generate the same dynamics as
    // the historical `--optimal-p` (compute p_fast, then run static p)
    let base = Experiment::builder()
        .variant("tiny")
        .algo("gasync")
        .clients(12)
        .concurrency(4)
        .steps(80)
        .eta(0.05)
        .n_train(800)
        .n_val(200)
        .eval_every(0)
        .seed(13)
        .build()
        .unwrap();
    let mut via_policy = base.clone();
    via_policy.policy = "optimal".into();
    let res_policy = via_policy.run().unwrap();
    // the old flag's code path: resolve p_fast first, then run static
    let mut via_pfast = base.clone();
    via_pfast.p_fast = Some(base.optimal_p_fast().unwrap());
    via_pfast.policy = "static".into();
    let res_static = via_pfast.run().unwrap();
    assert_eq!(res_policy.tau_max, res_static.tau_max);
    for (a, b) in res_policy.mean_delay.iter().zip(&res_static.mean_delay) {
        assert_eq!(a.to_bits(), b.to_bits(), "delays must match exactly");
    }
    assert_eq!(
        res_policy.total_virtual_time.to_bits(),
        res_static.total_virtual_time.to_bits()
    );
    assert_eq!(
        res_policy.final_accuracy.to_bits(),
        res_static.final_accuracy.to_bits()
    );
}
