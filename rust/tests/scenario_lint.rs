//! Scenario lint: every TOML under `scenarios/` must parse through the
//! validator that owns its format, so a stale file fails `cargo test`
//! instead of a user's sweep (or a CI smoke job) hours later.
//!
//! Format detection mirrors the CLI surfaces: files with a `[sweep]` or
//! `[grid]` table are sweep grids (`fedqueue sweep --grid`), everything
//! else is a train scenario (`fedqueue train --scenario`).  Both parsers
//! run their full structural validation at parse time (axis types, policy
//! and algorithm registry membership, two-cluster shape for `optimal`,
//! engine names), which is exactly what this lint wants to pin.

use fedqueue::coordinator::{Experiment, SweepSpec};
use fedqueue::util::toml::Doc;
use std::path::PathBuf;

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn scenario_files() -> Vec<PathBuf> {
    let dir = scenarios_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("scenario dir {}: {e}", dir.display()))
        .map(|entry| entry.expect("readable dir entry").path())
        .filter(|p| p.extension().map(|x| x == "toml").unwrap_or(false))
        .collect();
    files.sort();
    files
}

#[test]
fn every_scenario_file_parses_through_its_validator() {
    let files = scenario_files();
    assert!(
        files.len() >= 6,
        "only {} scenario files found — wrong directory?",
        files.len()
    );
    let mut grids = 0usize;
    let mut trains = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let doc =
            Doc::parse(&text).unwrap_or_else(|e| panic!("{}: TOML: {e}", path.display()));
        if doc.tables.contains_key("sweep") || doc.tables.contains_key("grid") {
            let spec = SweepSpec::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: sweep grid: {e}", path.display()));
            assert!(!spec.cells.is_empty(), "{}: zero cells", path.display());
            grids += 1;
        } else {
            let exp = Experiment::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: train scenario: {e}", path.display()));
            exp.validate()
                .unwrap_or_else(|e| panic!("{}: train scenario: {e}", path.display()));
            trains += 1;
        }
    }
    assert!(grids >= 2, "expected sweep grids among scenarios/, found {grids}");
    assert!(trains >= 3, "expected train scenarios among scenarios/, found {trains}");
}

#[test]
fn explicit_lognormal_cv_flows_from_grid_toml_to_cell_labels() {
    // `service = ["lognormal:<cv>"]` parses through the same FromStr the
    // CLI uses, and the cv survives into the cell label so two lognormal
    // legs with different tails never collide in a report
    let grid = "[sweep]\nseeds = 1\n[grid]\nclients = [10]\n\
                service = [\"lognormal\", \"lognormal:1.2\"]\n";
    let spec = SweepSpec::from_toml(grid).unwrap();
    assert_eq!(spec.cells.len(), 2);
    let labels: Vec<String> = spec.cells.iter().map(|c| c.scenario.label()).collect();
    assert!(labels[0].ends_with("lognormal"), "{}", labels[0]);
    assert!(labels[1].ends_with("lognormal:1.2"), "{}", labels[1]);
    // degenerate tails die at parse time, naming the cv
    for bad_cv in ["0", "-0.5", "nan"] {
        let bad = format!(
            "[sweep]\nseeds = 1\n[grid]\nclients = [10]\nservice = [\"lognormal:{bad_cv}\"]\n"
        );
        let err = SweepSpec::from_toml(&bad).unwrap_err();
        assert!(err.contains("cv"), "lognormal:{bad_cv}: {err}");
    }
}

#[test]
fn stale_scenario_keys_fail_the_lint_not_the_user() {
    // the detection rule routes each format to the validator that rejects
    // its mistakes: a typoed grid key and a typoed experiment key both
    // die at parse time
    let bad_grid = "[sweep]\nseeds = 2\n[grid]\nclinets = [10]\n";
    let doc = Doc::parse(bad_grid).unwrap();
    assert!(doc.tables.contains_key("sweep"));
    assert!(SweepSpec::from_toml(bad_grid).unwrap_err().contains("clinets"));
    let bad_train = "[experiment]\nvariannt = \"tiny\"\n";
    let doc = Doc::parse(bad_train).unwrap();
    assert!(!doc.tables.contains_key("sweep") && !doc.tables.contains_key("grid"));
    assert!(Experiment::from_toml(bad_train).unwrap_err().contains("variannt"));
}
