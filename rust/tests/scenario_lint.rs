//! Scenario lint: every TOML under `scenarios/` must parse through the
//! validator that owns its format, so a stale file fails `cargo test`
//! instead of a user's sweep (or a CI smoke job) hours later.
//!
//! Format detection mirrors the CLI surfaces: files with a `[sweep]` or
//! `[grid]` table are sweep grids (`fedqueue sweep --grid`), everything
//! else is a train/serve scenario (`fedqueue train|serve --scenario`).
//! Both parsers run their full structural validation at parse time (axis
//! types, policy and algorithm registry membership, two-cluster shape for
//! `optimal`, engine names), which is exactly what this lint wants to pin.
//!
//! The second half cross-checks `docs/SCENARIOS.md` against the parsers'
//! own known-key tables, in both directions: a key the parsers accept but
//! the page doesn't document fails, and so does a documented key the
//! parsers no longer accept.

use fedqueue::coordinator::experiment::{EXPERIMENT_KEYS, POLICY_KEYS, STRATEGY_KEYS};
use fedqueue::coordinator::serve::SERVE_KEYS;
use fedqueue::coordinator::sweep::{GRID_KEYS, SWEEP_KEYS, TRAIN_KEYS};
use fedqueue::coordinator::{Experiment, SweepSpec};
use fedqueue::simulator::CHURN_KEYS;
use fedqueue::util::toml::Doc;
use std::collections::BTreeSet;
use std::path::PathBuf;

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn scenario_files() -> Vec<PathBuf> {
    let dir = scenarios_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("scenario dir {}: {e}", dir.display()))
        .map(|entry| entry.expect("readable dir entry").path())
        .filter(|p| p.extension().map(|x| x == "toml").unwrap_or(false))
        .collect();
    files.sort();
    files
}

#[test]
fn every_scenario_file_parses_through_its_validator() {
    let files = scenario_files();
    assert!(
        files.len() >= 6,
        "only {} scenario files found — wrong directory?",
        files.len()
    );
    let mut grids = 0usize;
    let mut trains = 0usize;
    let mut serves = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let doc =
            Doc::parse(&text).unwrap_or_else(|e| panic!("{}: TOML: {e}", path.display()));
        if doc.tables.contains_key("sweep") || doc.tables.contains_key("grid") {
            let spec = SweepSpec::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: sweep grid: {e}", path.display()));
            assert!(!spec.cells.is_empty(), "{}: zero cells", path.display());
            grids += 1;
        } else {
            let exp = Experiment::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: train scenario: {e}", path.display()));
            exp.validate()
                .unwrap_or_else(|e| panic!("{}: train scenario: {e}", path.display()));
            if doc.tables.contains_key("serve") {
                serves += 1;
            }
            trains += 1;
        }
    }
    assert!(grids >= 2, "expected sweep grids among scenarios/, found {grids}");
    assert!(trains >= 3, "expected train scenarios among scenarios/, found {trains}");
    assert!(
        serves >= 2,
        "expected serve scenarios ([serve] table) among scenarios/, found {serves}"
    );
}

/// Every (table, key) row of the docs reference, parsed from its markdown
/// tables: `| `[table]` | `key` | … |`.
fn documented_keys() -> BTreeSet<(String, String)> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../docs/SCENARIOS.md");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("docs reference {}: {e}", path.display()));
    let mut rows = BTreeSet::new();
    for line in text.lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // cells[0] is the empty slice before the leading '|'
        if cells.len() < 3 {
            continue;
        }
        let (table, key) = (cells[1], cells[2]);
        let backticked = |s: &str| s.len() > 2 && s.starts_with('`') && s.ends_with('`');
        if !backticked(table) || !backticked(key) {
            continue;
        }
        let table = table.trim_matches('`');
        if !(table.starts_with('[') && table.ends_with(']')) {
            continue;
        }
        rows.insert((
            table[1..table.len() - 1].to_string(),
            key.trim_matches('`').to_string(),
        ));
    }
    assert!(
        rows.len() >= 40,
        "only {} documented (table, key) rows parsed from {} — format drift?",
        rows.len(),
        path.display()
    );
    rows
}

/// The parsers' own known-key tables — the same consts the strict
/// unknown-key checks run against, so there is exactly one authority.
fn parsed_keys() -> BTreeSet<(String, String)> {
    let tables: &[(&str, &[&str])] = &[
        ("experiment", EXPERIMENT_KEYS),
        ("policy", POLICY_KEYS),
        ("strategy", STRATEGY_KEYS),
        ("serve", SERVE_KEYS),
        ("churn", CHURN_KEYS),
        ("sweep", SWEEP_KEYS),
        ("grid", GRID_KEYS),
        ("train", TRAIN_KEYS),
    ];
    tables
        .iter()
        .flat_map(|(t, keys)| keys.iter().map(move |k| (t.to_string(), k.to_string())))
        .collect()
}

#[test]
fn every_parsed_key_is_documented_and_vice_versa() {
    let documented = documented_keys();
    let parsed = parsed_keys();
    let undocumented: Vec<_> = parsed.difference(&documented).collect();
    assert!(
        undocumented.is_empty(),
        "keys the parsers accept but docs/SCENARIOS.md does not document: {undocumented:?}"
    );
    let stale: Vec<_> = documented.difference(&parsed).collect();
    assert!(
        stale.is_empty(),
        "keys docs/SCENARIOS.md documents but no parser accepts: {stale:?}"
    );
}

#[test]
fn explicit_lognormal_cv_flows_from_grid_toml_to_cell_labels() {
    // `service = ["lognormal:<cv>"]` parses through the same FromStr the
    // CLI uses, and the cv survives into the cell label so two lognormal
    // legs with different tails never collide in a report
    let grid = "[sweep]\nseeds = 1\n[grid]\nclients = [10]\n\
                service = [\"lognormal\", \"lognormal:1.2\"]\n";
    let spec = SweepSpec::from_toml(grid).unwrap();
    assert_eq!(spec.cells.len(), 2);
    let labels: Vec<String> = spec.cells.iter().map(|c| c.scenario.label()).collect();
    assert!(labels[0].ends_with("lognormal"), "{}", labels[0]);
    assert!(labels[1].ends_with("lognormal:1.2"), "{}", labels[1]);
    // degenerate tails die at parse time, naming the cv
    for bad_cv in ["0", "-0.5", "nan"] {
        let bad = format!(
            "[sweep]\nseeds = 1\n[grid]\nclients = [10]\nservice = [\"lognormal:{bad_cv}\"]\n"
        );
        let err = SweepSpec::from_toml(&bad).unwrap_err();
        assert!(err.contains("cv"), "lognormal:{bad_cv}: {err}");
    }
}

#[test]
fn stale_scenario_keys_fail_the_lint_not_the_user() {
    // the detection rule routes each format to the validator that rejects
    // its mistakes: a typoed grid key and a typoed experiment key both
    // die at parse time
    let bad_grid = "[sweep]\nseeds = 2\n[grid]\nclinets = [10]\n";
    let doc = Doc::parse(bad_grid).unwrap();
    assert!(doc.tables.contains_key("sweep"));
    assert!(SweepSpec::from_toml(bad_grid).unwrap_err().contains("clinets"));
    let bad_train = "[experiment]\nvariannt = \"tiny\"\n";
    let doc = Doc::parse(bad_train).unwrap();
    assert!(!doc.tables.contains_key("sweep") && !doc.tables.contains_key("grid"));
    assert!(Experiment::from_toml(bad_train).unwrap_err().contains("variannt"));
}
