//! Trace-equivalence contract of the event engines.
//!
//! `engine = "heap"` (the original monolithic `Network`) is the oracle;
//! `engine = "sharded"` must produce a bit-identical `SimResult` for
//! every shard count and every thread count on a shared seed, and
//! `engine = "batch"` for every batch width R — each batched replication
//! equals its seed run alone on the heap — across policies (static /
//! uniform / optimal / adaptive / adaptive-exact), service families, and
//! initial placements.  The equivalence holds because routing draws come
//! from one per-replication sequential stream consumed in CS-step order
//! and service durations are keyed by (node, service count) — see
//! `simulator::engine`.
//!
//! Also carries the million-node acceptance check: a sweep cell with
//! n = 10^6 clients completes through the sharded engine, and a 10^5-node
//! replication matches the log-space Buzen product form.

use fedqueue::coordinator::policy::{
    AdaptiveQueuePolicy, DelayAdaptivePolicy, FenwickAdaptivePolicy, FenwickDelayAdaptivePolicy,
    PolicyCtx, PolicyRegistry, SamplingPolicy,
};
use fedqueue::coordinator::sweep::{run_sweep, SweepSpec};
use fedqueue::queueing::ClosedNetwork;
use fedqueue::simulator::{
    run_batch, run_with_policy, ChurnConfig, EngineConfig, EngineKind, InitPlacement, ServiceDist,
    ServiceFamily, SimConfig, SimResult,
};
use fedqueue::util::proptest::{check, Config as PropConfig, Gen};
use fedqueue::util::rng::{stream_seed, Rng};

/// Every field of a `SimResult`, flattened to bits — the comparison unit.
fn digest(r: &SimResult) -> Vec<u64> {
    let mut d = Vec::new();
    let f = |x: f64| x.to_bits();
    for w in r.delay_steps.iter().chain(r.delay_time.iter()) {
        d.push(w.count());
        d.push(f(w.mean()));
        d.push(f(w.min()));
        d.push(f(w.max()));
    }
    d.extend(r.completions.iter().copied());
    d.extend(r.dispatches.iter().copied());
    d.push(r.tau_max);
    d.push(f(r.tau_c));
    d.extend(r.tau_sum.iter().map(|&x| f(x)));
    d.push(f(r.total_time));
    d.extend(r.mean_queue.iter().map(|&x| f(x)));
    for t in &r.tasks {
        d.push(t.node as u64);
        d.push(t.dispatch_step);
        d.push(t.complete_step);
        d.push(f(t.dispatch_time));
        d.push(f(t.complete_time));
        d.push(f(t.dispatch_prob));
    }
    for (step, qs) in &r.queue_samples {
        d.push(*step);
        d.extend(qs.iter().map(|&q| q as u64));
    }
    d
}

const SHARD_GRID: [usize; 3] = [1, 4, 7];
const THREAD_GRID: [usize; 2] = [1, 4];

/// Assert heap ≡ sharded for every (S, threads) combination, and ≡ the
/// width-1 batch arena behind the same `run_with_policy` surface.
fn assert_equivalent(
    mut cfg: SimConfig,
    mk_policy: impl Fn() -> Box<dyn SamplingPolicy>,
) -> Result<(), String> {
    cfg.record_tasks = true;
    cfg.queue_sample_every = 97;
    cfg.engine = EngineConfig::heap();
    let oracle = digest(&run_with_policy(cfg.clone(), mk_policy())?);
    for s in SHARD_GRID {
        for t in THREAD_GRID {
            let mut c = cfg.clone();
            c.engine = EngineConfig { kind: EngineKind::Sharded, shards: s, threads: t };
            let got = digest(&run_with_policy(c, mk_policy())?);
            if got != oracle {
                return Err(format!("sharded(S={s}, threads={t}) diverged from heap"));
            }
        }
    }
    let mut c = cfg.clone();
    c.engine = EngineConfig::batch();
    if digest(&run_with_policy(c, mk_policy())?) != oracle {
        return Err("batch(R=1) diverged from heap".into());
    }
    Ok(())
}

fn two_cluster(n: usize, c: usize, steps: u64, seed: u64, family: ServiceFamily) -> SimConfig {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    SimConfig {
        seed,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, family),
            c,
            steps,
        )
    }
}

fn ctx(n: usize, c: usize, steps: u64, gamma: f64) -> PolicyCtx {
    PolicyCtx {
        n,
        base_p: vec![1.0 / n as f64; n],
        gamma,
        beta: 0.9,
        n_fast: n / 2,
        mu_fast: 4.0,
        mu_slow: 1.0,
        concurrency: c,
        steps,
    }
}

#[test]
fn sharded_matches_heap_for_every_builtin_policy() {
    // the registry list includes the delay-feedback pair, so this loop
    // also pins the observe_completion channel across engines
    let (n, c, steps) = (14, 9, 2_000);
    for policy in PolicyRegistry::builtin().names() {
        let cfg = two_cluster(n, c, steps, 31, ServiceFamily::Exponential);
        let pc = ctx(n, c, steps, 0.6);
        assert_equivalent(cfg, || PolicyRegistry::builtin().build(&policy, &pc).unwrap())
            .unwrap_or_else(|e| panic!("policy {policy}: {e}"));
    }
}

#[test]
fn delay_feedback_keeps_engines_bit_identical_under_aggressive_tilt() {
    // the delay-feedback channel makes the distribution genuinely
    // time-varying (every completion moves it), which is exactly the
    // regime where a mis-ordered observe_completion call in one engine
    // would break the trace — stress it with strong tilts and both the
    // Fenwick policy and its exact oracle
    let (n, c, steps) = (12, 8, 2_500u64);
    for (gamma, beta) in [(0.2, 0.5), (1.0, 0.9), (0.05, 0.0)] {
        let cfg = two_cluster(n, c, steps, 17, ServiceFamily::Exponential);
        let base = cfg.p.clone();
        assert_equivalent(cfg, || {
            Box::new(FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap())
        })
        .unwrap_or_else(|e| panic!("fenwick gamma={gamma} beta={beta}: {e}"));
        let cfg = two_cluster(n, c, steps, 17, ServiceFamily::Exponential);
        let base = cfg.p.clone();
        assert_equivalent(cfg, || {
            Box::new(DelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap())
        })
        .unwrap_or_else(|e| panic!("exact gamma={gamma} beta={beta}: {e}"));
    }
}

/// Batch widths of the ISSUE-4 acceptance criterion.
const BATCH_WIDTHS: [usize; 3] = [1, 4, 32];

#[test]
fn batch_arena_matches_heap_for_every_builtin_policy_and_width() {
    // R ∈ {1, 4, 32}: every replication of a batch arena must be
    // bit-identical to its seed run ALONE on the heap oracle, whatever
    // else shares the arena — for all builtin policies, with task records
    // and queue samples included in the digest
    let (n, c, steps) = (14usize, 9usize, 1_500u64);
    let pc = ctx(n, c, steps, 0.6);
    for policy in PolicyRegistry::builtin().names() {
        let mut base = two_cluster(n, c, steps, 0, ServiceFamily::Exponential);
        base.record_tasks = true;
        base.queue_sample_every = 97;
        let mk = || PolicyRegistry::builtin().build(&policy, &pc).unwrap();
        // the sweep's seed layout: stream_seed(base, [cell, seed_idx])
        let seeds: Vec<u64> = (0..32u64).map(|s| stream_seed(42, &[0, s])).collect();
        let oracles: Vec<Vec<u64>> = seeds
            .iter()
            .map(|&seed| {
                let mut cfg = base.clone();
                cfg.seed = seed;
                digest(&run_with_policy(cfg, mk()).unwrap())
            })
            .collect();
        for r in BATCH_WIDTHS {
            let results = run_batch(&base, &seeds[..r], |_| Ok(mk())).unwrap();
            assert_eq!(results.len(), r, "{policy}: R={r}");
            for (i, res) in results.iter().enumerate() {
                assert_eq!(
                    digest(res),
                    oracles[i],
                    "{policy}: batch R={r} rep {i} diverged from its heap oracle"
                );
            }
        }
    }
}

#[test]
fn batch_arena_matches_heap_across_service_families() {
    // every single-family cell takes a vectorized block kernel now
    // (exponential / deterministic / lognormal each have one); the scalar
    // fallback only fires for genuinely mixed cells, pinned separately in
    // `engine::batch::tests`
    for family in [
        ServiceFamily::Exponential,
        ServiceFamily::Deterministic,
        ServiceFamily::LogNormal(0.5),
        ServiceFamily::LogNormal(1.2),
    ] {
        let mut base = two_cluster(10, 6, 1_000, 0, family);
        base.record_tasks = true;
        let p = base.p.clone();
        let mk = || -> Box<dyn SamplingPolicy> {
            Box::new(fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap())
        };
        let seeds = [3u64, 5, 8, 13];
        let results = run_batch(&base, &seeds, |_| Ok(mk())).unwrap();
        for (i, res) in results.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.seed = seeds[i];
            let oracle = digest(&run_with_policy(cfg, mk()).unwrap());
            assert_eq!(digest(res), oracle, "{family:?} rep {i}");
        }
    }
}

#[test]
fn sharded_matches_heap_across_service_families() {
    for family in [
        ServiceFamily::Exponential,
        ServiceFamily::Deterministic,
        ServiceFamily::LogNormal(0.5),
    ] {
        let cfg = two_cluster(10, 6, 1_500, 7, family);
        let p = cfg.p.clone();
        assert_equivalent(cfg, || {
            Box::new(fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap())
        })
        .unwrap_or_else(|e| panic!("{family:?}: {e}"));
    }
}

#[test]
fn sharded_matches_heap_across_initial_placements() {
    for init in [InitPlacement::OnePerNode, InitPlacement::RoundRobin, InitPlacement::Routed] {
        let c = if init == InitPlacement::OnePerNode { 12 } else { 5 };
        let mut cfg = two_cluster(12, c, 1_200, 13, ServiceFamily::Exponential);
        cfg.init = init;
        let p = cfg.p.clone();
        assert_equivalent(cfg, || {
            Box::new(fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap())
        })
        .unwrap_or_else(|e| panic!("{init:?}: {e}"));
    }
}

/// Randomized configuration for the property harness.
#[derive(Clone, Debug)]
struct SimCase {
    n: usize,
    c: usize,
    steps: u64,
    seed: u64,
    gamma: f64,
    beta: f64,
    family: usize,
    policy: usize,
}

struct SimCaseGen;

impl Gen for SimCaseGen {
    type Value = SimCase;

    fn generate(&self, rng: &mut Rng) -> SimCase {
        SimCase {
            n: 2 + rng.usize_below(19),
            c: 1 + rng.usize_below(24),
            steps: 200 + rng.below(1_000),
            seed: rng.next_u64(),
            gamma: rng.range_f64(0.0, 1.5),
            beta: rng.range_f64(0.0, 0.95),
            family: rng.usize_below(3),
            policy: rng.usize_below(5),
        }
    }

    fn shrink(&self, v: &SimCase) -> Vec<SimCase> {
        let mut out = Vec::new();
        if v.n > 2 {
            out.push(SimCase { n: 2 + (v.n - 2) / 2, ..v.clone() });
        }
        if v.c > 1 {
            out.push(SimCase { c: 1 + (v.c - 1) / 2, ..v.clone() });
        }
        if v.steps > 200 {
            out.push(SimCase { steps: 200 + (v.steps - 200) / 2, ..v.clone() });
        }
        out
    }
}

#[test]
fn proptest_sharded_equals_heap_on_random_configs() {
    check(
        "sharded-equals-heap",
        &SimCaseGen,
        &PropConfig { cases: 32, ..Default::default() },
        |case| {
            let family = [
                ServiceFamily::Exponential,
                ServiceFamily::Deterministic,
                ServiceFamily::LogNormal(0.5),
            ][case.family];
            let cfg = two_cluster(case.n, case.c, case.steps, case.seed, family);
            let base = cfg.p.clone();
            let gamma = case.gamma;
            let beta = case.beta;
            match case.policy {
                0 => assert_equivalent(cfg, || {
                    Box::new(fedqueue::coordinator::StaticPolicy::new(base.clone()).unwrap())
                }),
                1 => assert_equivalent(cfg, || {
                    Box::new(FenwickAdaptivePolicy::new(base.clone(), gamma).unwrap())
                }),
                2 => assert_equivalent(cfg, || {
                    Box::new(AdaptiveQueuePolicy::new(base.clone(), gamma).unwrap())
                }),
                3 => assert_equivalent(cfg, || {
                    Box::new(FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap())
                }),
                _ => assert_equivalent(cfg, || {
                    Box::new(DelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap())
                }),
            }
        },
    );
}

/// An aggressive open-network lifecycle: joins, leaves, stalls, and
/// rate switches all active, with `initial_active` nodes live at t = 0.
fn churny(initial_active: usize) -> ChurnConfig {
    ChurnConfig {
        arrival_rate: 0.7,
        mean_lifetime: 2.5,
        stall_rate: 0.5,
        mean_stall: 0.4,
        rate_change_rate: 0.6,
        rate_factor_min: 0.5,
        rate_factor_max: 2.0,
        initial_active,
        max_events: 300,
    }
}

#[test]
fn churn_keeps_every_builtin_policy_engine_invariant() {
    // the tentpole acceptance criterion: with nonzero churn the heap
    // oracle, the sharded engine (every S x threads combination), and the
    // width-1 batch arena stay bit-identical for every builtin policy —
    // membership deltas, FIFO re-dispatch order, and rate-scale reads all
    // have to decompose identically for this to hold
    let (n, c, steps) = (14, 9, 1_500);
    for policy in PolicyRegistry::builtin().names() {
        let mut cfg = two_cluster(n, c, steps, 29, ServiceFamily::Exponential);
        cfg.churn = Some(churny(10));
        let pc = ctx(n, c, steps, 0.6);
        assert_equivalent(cfg, || PolicyRegistry::builtin().build(&policy, &pc).unwrap())
            .unwrap_or_else(|e| panic!("policy {policy} under churn: {e}"));
    }
}

#[test]
fn churny_batch_widths_match_their_heap_oracles() {
    // batch arenas at R in {1, 4, 32}: each replication derives its own
    // churn schedule from its own seed, so packing must not leak events
    // across reps — every one equals its seed run alone on the heap
    let (n, c, steps) = (14usize, 9usize, 1_000u64);
    let pc = ctx(n, c, steps, 0.6);
    for policy in PolicyRegistry::builtin().names() {
        let mut base = two_cluster(n, c, steps, 0, ServiceFamily::Exponential);
        base.churn = Some(churny(10));
        base.record_tasks = true;
        base.queue_sample_every = 97;
        let mk = || PolicyRegistry::builtin().build(&policy, &pc).unwrap();
        let seeds: Vec<u64> = (0..32u64).map(|s| stream_seed(1771, &[0, s])).collect();
        let oracles: Vec<Vec<u64>> = seeds
            .iter()
            .map(|&seed| {
                let mut cfg = base.clone();
                cfg.seed = seed;
                digest(&run_with_policy(cfg, mk()).unwrap())
            })
            .collect();
        for r in BATCH_WIDTHS {
            let results = run_batch(&base, &seeds[..r], |_| Ok(mk())).unwrap();
            for (i, res) in results.iter().enumerate() {
                assert_eq!(
                    digest(res),
                    oracles[i],
                    "{policy}: churny batch R={r} rep {i} diverged from its heap oracle"
                );
            }
        }
    }
}

#[test]
fn lognormal_high_cv_with_churn_keeps_engines_bit_identical() {
    // the raw-speed grid leg: a heavy-tailed `lognormal:1.2` cell with the
    // full churn lifecycle on, across the heap oracle, every (S, threads)
    // sharded combination, and the batch arena — the vectorized lognormal
    // block kernel and the prefetched routing draws must both decompose
    // identically while joins/leaves interleave with the CS-step stream
    let (n, c, steps) = (12usize, 8usize, 1_200u64);
    let mut cfg = two_cluster(n, c, steps, 53, ServiceFamily::LogNormal(1.2));
    cfg.churn = Some(churny(9));
    let p = cfg.p.clone();
    assert_equivalent(cfg.clone(), || {
        Box::new(fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap())
    })
    .unwrap_or_else(|e| panic!("static lognormal:1.2 + churn: {e}"));
    assert_equivalent(cfg.clone(), || {
        Box::new(FenwickAdaptivePolicy::new(p.clone(), 0.6).unwrap())
    })
    .unwrap_or_else(|e| panic!("adaptive lognormal:1.2 + churn: {e}"));
    // and at real batch widths: each replication draws its own churn
    // schedule AND its own lognormal blocks from the shared arena
    cfg.record_tasks = true;
    let mk = || -> Box<dyn SamplingPolicy> {
        Box::new(fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap())
    };
    let seeds: Vec<u64> = (0..32u64).map(|s| stream_seed(2026, &[0, s])).collect();
    for r in BATCH_WIDTHS {
        let results = run_batch(&cfg, &seeds[..r], |_| Ok(mk())).unwrap();
        for (i, res) in results.iter().enumerate() {
            let mut solo = cfg.clone();
            solo.seed = seeds[i];
            let oracle = digest(&run_with_policy(solo, mk()).unwrap());
            assert_eq!(
                digest(res),
                oracle,
                "lognormal:1.2 churny batch R={r} rep {i} diverged from its heap oracle"
            );
        }
    }
}

/// Shared membership log handle for the draw-guard recorder below.
type MembershipLog = std::rc::Rc<std::cell::RefCell<Vec<(char, usize)>>>;

/// A static policy instrumented to record every membership notification.
/// Its callbacks touch no RNG, so a run with the recorder must be
/// bit-identical to a run with the bare policy — any engine that slipped
/// a draw (or a skipped notification) into the join/leave path would
/// break one of the two assertions.
struct MembershipRecorder {
    inner: fedqueue::coordinator::StaticPolicy,
    log: MembershipLog,
}

impl SamplingPolicy for MembershipRecorder {
    fn name(&self) -> String {
        "membership-recorder".into()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn prob_of(&self, i: usize) -> f64 {
        self.inner.prob_of(i)
    }

    fn observe_join(&mut self, node: usize) {
        self.log.borrow_mut().push(('j', node));
        self.inner.observe_join(node);
    }

    fn observe_leave(&mut self, node: usize) {
        self.log.borrow_mut().push(('l', node));
        self.inner.observe_leave(node);
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        self.inner.route(rng)
    }
}

#[test]
fn observe_join_and_leave_are_draw_free_and_engine_invariant() {
    // R1's runtime face: membership callbacks are pure notifications.
    // In debug builds this run also exercises the engines' routing-stream
    // fingerprint guards around observe_join/observe_leave.
    let (n, c, steps) = (12usize, 6usize, 800u64);
    let mut cfg = two_cluster(n, c, steps, 47, ServiceFamily::Exponential);
    cfg.churn = Some(churny(8));
    cfg.record_tasks = true;
    let p = cfg.p.clone();
    let bare = || -> Box<dyn SamplingPolicy> {
        Box::new(fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap())
    };
    let recorded = |log: &MembershipLog| -> Box<dyn SamplingPolicy> {
        Box::new(MembershipRecorder {
            inner: fedqueue::coordinator::StaticPolicy::new(p.clone()).unwrap(),
            log: log.clone(),
        })
    };
    let mut heap_cfg = cfg.clone();
    heap_cfg.engine = EngineConfig::heap();
    let oracle = digest(&run_with_policy(heap_cfg.clone(), bare()).unwrap());
    let heap_log: MembershipLog = Default::default();
    let with_recorder = digest(&run_with_policy(heap_cfg, recorded(&heap_log)).unwrap());
    assert_eq!(
        oracle, with_recorder,
        "membership notifications must not perturb the trace"
    );
    let heap_events = heap_log.borrow().clone();
    assert!(
        heap_events.iter().any(|&(k, _)| k == 'l'),
        "initial_active = 8 of 12 must fire observe_leave at t = 0"
    );
    assert!(heap_events.iter().all(|&(_, node)| node < n));
    // every other engine must fire the identical notification sequence
    for engine in [
        EngineConfig { kind: EngineKind::Sharded, shards: 4, threads: 1 },
        EngineConfig::batch(),
    ] {
        let mut c = cfg.clone();
        c.engine = engine;
        let log: MembershipLog = Default::default();
        let got = digest(&run_with_policy(c, recorded(&log)).unwrap());
        assert_eq!(got, oracle, "{engine:?} diverged under churn");
        assert_eq!(*log.borrow(), heap_events, "{engine:?} membership order");
    }
}

/// Randomized open-network lifecycle for the property harness.
#[derive(Clone, Debug)]
struct ChurnCase {
    sim: SimCase,
    arrival: f64,
    lifetime: f64,
    stall: f64,
    mean_stall: f64,
    rate_change: f64,
    factor_min: f64,
    factor_spread: f64,
    initial_active: usize,
}

struct ChurnCaseGen;

impl Gen for ChurnCaseGen {
    type Value = ChurnCase;

    fn generate(&self, rng: &mut Rng) -> ChurnCase {
        let mut sim = SimCaseGen.generate(rng);
        sim.n = 2 + rng.usize_below(12);
        sim.steps = 200 + rng.below(500);
        ChurnCase {
            initial_active: rng.usize_below(sim.n + 1),
            sim,
            arrival: rng.range_f64(0.1, 1.5),
            lifetime: rng.range_f64(0.5, 5.0),
            stall: rng.range_f64(0.0, 1.0),
            mean_stall: rng.range_f64(0.1, 1.0),
            rate_change: rng.range_f64(0.0, 1.0),
            factor_min: rng.range_f64(0.3, 1.0),
            factor_spread: rng.range_f64(0.0, 2.0),
        }
    }

    fn shrink(&self, v: &ChurnCase) -> Vec<ChurnCase> {
        SimCaseGen
            .shrink(&v.sim)
            .into_iter()
            .map(|sim| ChurnCase {
                initial_active: v.initial_active.min(sim.n),
                sim,
                ..v.clone()
            })
            .collect()
    }
}

#[test]
fn proptest_random_churn_schedules_keep_engines_equivalent() {
    check(
        "churn-engines-equivalent",
        &ChurnCaseGen,
        &PropConfig { cases: 24, ..Default::default() },
        |case| {
            let family = [
                ServiceFamily::Exponential,
                ServiceFamily::Deterministic,
                ServiceFamily::LogNormal(0.5),
            ][case.sim.family];
            let mut cfg =
                two_cluster(case.sim.n, case.sim.c, case.sim.steps, case.sim.seed, family);
            cfg.churn = Some(ChurnConfig {
                arrival_rate: case.arrival,
                mean_lifetime: case.lifetime,
                stall_rate: case.stall,
                mean_stall: case.mean_stall,
                rate_change_rate: case.rate_change,
                rate_factor_min: case.factor_min,
                rate_factor_max: case.factor_min + case.factor_spread,
                initial_active: case.initial_active,
                max_events: 400,
            });
            let base = cfg.p.clone();
            let gamma = case.sim.gamma;
            let beta = case.sim.beta;
            match case.sim.policy {
                0 => assert_equivalent(cfg, || {
                    Box::new(fedqueue::coordinator::StaticPolicy::new(base.clone()).unwrap())
                }),
                1 => assert_equivalent(cfg, || {
                    Box::new(FenwickAdaptivePolicy::new(base.clone(), gamma).unwrap())
                }),
                2 => assert_equivalent(cfg, || {
                    Box::new(AdaptiveQueuePolicy::new(base.clone(), gamma).unwrap())
                }),
                3 => assert_equivalent(cfg, || {
                    Box::new(FenwickDelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap())
                }),
                _ => assert_equivalent(cfg, || {
                    Box::new(DelayAdaptivePolicy::new(base.clone(), gamma, beta).unwrap())
                }),
            }
        },
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: n = 100_000 nodes (CI stat-tests job)")]
fn sharded_engine_matches_product_form_at_scale() {
    // n = 10^5 heterogeneous nodes through the sharded engine with shard
    // workers; the time-weighted mean queues must match the log-space
    // Buzen reference (which the old linear-space table could not even
    // represent at this n).
    let n = 100_000usize;
    let c = 512usize;
    let steps = 2_000_000u64;
    let p = vec![1.0 / n as f64; n];
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    let cfg = SimConfig {
        seed: 23,
        engine: EngineConfig::sharded(8, 4),
        ..SimConfig::new(
            p.clone(),
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    };
    let policy = PolicyRegistry::builtin()
        .build("uniform", &ctx(n, c, steps, 0.0))
        .unwrap();
    let res = run_with_policy(cfg, policy).unwrap();
    assert_eq!(res.completions.iter().sum::<u64>(), steps);
    // exact invariant: the time-weighted queue lengths always sum to C
    let total_q: f64 = res.mean_queue.iter().sum();
    assert!(
        (total_q - c as f64).abs() < 1e-6 * c as f64,
        "Σ mean_queue = {total_q}, want {c}"
    );
    let b = ClosedNetwork::new(p, rates).unwrap().buzen(c);
    let sim_fast: f64 = res.mean_queue[..n / 2].iter().sum::<f64>() / (n / 2) as f64;
    let sim_slow: f64 = res.mean_queue[n / 2..].iter().sum::<f64>() / (n - n / 2) as f64;
    let th_fast = b.mean_queue(0, c);
    let th_slow = b.mean_queue(n - 1, c);
    assert!(sim_slow > sim_fast, "slow queues dominate: {sim_fast} vs {sim_slow}");
    assert!(
        (sim_fast - th_fast).abs() < 0.25 * th_fast,
        "fast cluster: sim {sim_fast} vs product form {th_fast}"
    );
    assert!(
        (sim_slow - th_slow).abs() < 0.25 * th_slow,
        "slow cluster: sim {sim_slow} vs product form {th_slow}"
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: n = 10^6 nodes (CI stat-tests job)")]
fn million_node_sweep_cell_completes_on_sharded_engine() {
    // the ISSUE-3 acceptance criterion: `fedqueue sweep` completes an
    // n = 10^6 replication cell via the sharded engine (alias routing +
    // Fenwick adaptive both covered), with perf telemetry attached
    let grid = r#"
[sweep]
name = "million"
mode = "simulate"
seeds = 1
base_seed = 99
threads = 4
engine = "sharded"
shards = 8
big_n = 500000

[grid]
clients = [1000000]
concurrency = [50000]
steps = [200000]
mu_fast = [4.0]
slow_fraction = [0.5]
gamma = [0.3]
policies = ["uniform", "adaptive"]
"#;
    let spec = SweepSpec::from_toml(grid).unwrap();
    // wide cells: the scheduler hands each replication the thread budget
    for cell in &spec.cells {
        let e = spec.engine_for_cell(cell, 4);
        assert_eq!(e.kind, EngineKind::Sharded);
        assert_eq!(e.threads, 4);
    }
    let report = run_sweep(&spec).unwrap();
    assert_eq!(report.cells.len(), 2);
    for c in &report.cells {
        assert_eq!(c.engine, "sharded(S=8)");
        assert_eq!(c.metrics["total_time"].count(), 1, "{}", c.cell.label());
        assert!(c.metrics["delay_slow"].mean() > c.metrics["delay_fast"].mean());
        assert!(c.perf["events_per_sec"].mean() > 0.0);
    }
}
