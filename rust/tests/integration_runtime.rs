//! Integration: the PJRT path (AOT JAX+Pallas HLO executed via the xla
//! crate) must agree numerically with the native Rust backend — this is
//! the L1/L2 ⇄ L3 contract.  Requires `make artifacts`; tests skip with a
//! notice when artifacts are absent (plain `cargo test` before `make`).
//! Requires the PJRT backend (`--features pjrt`).
#![cfg(feature = "pjrt")]

use fedqueue::data::Batch;
use fedqueue::runtime::{Backend, Manifest, NativeBackend, PjrtBackend};
use fedqueue::util::rng::Rng;

fn artifacts_ready() -> bool {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        true
    } else {
        eprintln!("[skip] artifacts not built — run `make artifacts`");
        false
    }
}

fn random_batch(b: usize, d: usize, c: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let mut onehot = vec![0.0f32; b * c];
    for bi in 0..b {
        onehot[bi * c + rng.usize_below(c)] = 1.0;
    }
    Batch { x, onehot, batch: b }
}

#[test]
fn pjrt_loads_and_reports_platform() {
    if !artifacts_ready() {
        return;
    }
    let be = PjrtBackend::load(&Manifest::default_dir(), "tiny").unwrap();
    assert_eq!(be.platform(), "cpu");
    assert_eq!(be.variant_name(), "tiny");
    assert_eq!(be.spec().input_dim, 48);
}

#[test]
fn pjrt_train_step_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let mut pj = PjrtBackend::load(&Manifest::default_dir(), "tiny").unwrap();
    let spec = pj.spec().clone();
    let mut nat = NativeBackend::new(spec.clone());
    let model = spec.init_model(42);
    let batch = random_batch(spec.train_batch, spec.input_dim, spec.classes, 7);

    let (loss_p, grads_p) = pj.train_step(&model, &batch).unwrap();
    let (loss_n, grads_n) = nat.train_step(&model, &batch).unwrap();
    assert!(
        (loss_p - loss_n).abs() < 1e-4 * (1.0 + loss_n.abs()),
        "loss: pjrt {loss_p} vs native {loss_n}"
    );
    assert_eq!(grads_p.len(), grads_n.len());
    for (ti, (gp, gn)) in grads_p.iter().zip(&grads_n).enumerate() {
        assert_eq!(gp.len(), gn.len(), "tensor {ti} length");
        let mut max_err = 0.0f64;
        for (a, b) in gp.iter().zip(gn) {
            max_err = max_err.max((*a as f64 - *b as f64).abs());
        }
        assert!(max_err < 5e-4, "tensor {ti}: max grad err {max_err}");
    }
}

#[test]
fn pjrt_eval_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let mut pj = PjrtBackend::load(&Manifest::default_dir(), "tiny").unwrap();
    let spec = pj.spec().clone();
    let mut nat = NativeBackend::new(spec.clone());
    let model = spec.init_model(3);
    let batch = random_batch(spec.eval_batch, spec.input_dim, spec.classes, 9);
    let (lp, cp) = pj.eval_batch(&model, &batch, spec.eval_batch).unwrap();
    let (ln, cn) = nat.eval_batch(&model, &batch, spec.eval_batch).unwrap();
    assert!((lp - ln).abs() < 1e-3 * (1.0 + ln.abs()), "loss {lp} vs {ln}");
    assert_eq!(cp, cn, "correct counts must match exactly");
}

#[test]
fn pjrt_eval_partial_batch_correction() {
    if !artifacts_ready() {
        return;
    }
    let mut pj = PjrtBackend::load(&Manifest::default_dir(), "tiny").unwrap();
    let spec = pj.spec().clone();
    let mut nat = NativeBackend::new(spec.clone());
    let model = spec.init_model(5);
    // a batch whose tail rows duplicate the last valid row (loader padding)
    let mut batch = random_batch(spec.eval_batch, spec.input_dim, spec.classes, 11);
    let valid = spec.eval_batch - 7;
    let d = spec.input_dim;
    let c = spec.classes;
    for bi in valid..spec.eval_batch {
        let src_x: Vec<f32> = batch.x[(valid - 1) * d..valid * d].to_vec();
        batch.x[bi * d..(bi + 1) * d].copy_from_slice(&src_x);
        let src_y: Vec<f32> = batch.onehot[(valid - 1) * c..valid * c].to_vec();
        batch.onehot[bi * c..(bi + 1) * c].copy_from_slice(&src_y);
    }
    let (lp, cp) = pj.eval_batch(&model, &batch, valid).unwrap();
    let (ln, cn) = nat.eval_batch(&model, &batch, valid).unwrap();
    assert!((lp - ln).abs() < 1e-3 * (1.0 + ln.abs()), "loss {lp} vs {ln}");
    assert!((cp - cn).abs() < 1e-6, "correct {cp} vs {cn}");
}

#[test]
fn pjrt_sgd_training_reduces_loss() {
    if !artifacts_ready() {
        return;
    }
    let mut pj = PjrtBackend::load(&Manifest::default_dir(), "tiny").unwrap();
    let spec = pj.spec().clone();
    let mut model = spec.init_model(8);
    let batch = random_batch(spec.train_batch, spec.input_dim, spec.classes, 13);
    let (l0, _) = pj.train_step(&model, &batch).unwrap();
    for _ in 0..25 {
        let (_, g) = pj.train_step(&model, &batch).unwrap();
        model.apply_update(&g, 0.1);
    }
    let (l1, _) = pj.train_step(&model, &batch).unwrap();
    assert!(l1 < l0 * 0.7, "pjrt training loss {l0} -> {l1}");
    assert!(pj.train_calls >= 27);
}

#[test]
fn pjrt_rejects_shape_mismatches() {
    if !artifacts_ready() {
        return;
    }
    let mut pj = PjrtBackend::load(&Manifest::default_dir(), "tiny").unwrap();
    let spec = pj.spec().clone();
    let model = spec.init_model(1);
    let mut batch = random_batch(spec.train_batch, spec.input_dim, spec.classes, 1);
    batch.batch = spec.train_batch + 1;
    assert!(pj.train_step(&model, &batch).is_err());
    // wrong tensor count
    let mut bad = model.clone();
    bad.tensors.pop();
    let batch = random_batch(spec.train_batch, spec.input_dim, spec.classes, 1);
    assert!(pj.train_step(&bad, &batch).is_err());
}

#[test]
fn malformed_artifact_fails_cleanly() {
    // failure injection: corrupt HLO text must produce an error, not UB
    let dir = std::env::temp_dir().join("fedqueue_bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("tiny_train.hlo.txt"), "HloModule garbage ENTRY {").unwrap();
    std::fs::write(dir.join("tiny_eval.hlo.txt"), "not hlo at all").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","variants":{"tiny":{
            "name":"tiny","input_dim":48,"hidden":[32],"classes":10,
            "train_batch":16,"eval_batch":32,"n_params":1898,
            "params":[{"name":"w0","shape":[48,32]}],
            "train":{"file":"tiny_train.hlo.txt","outputs":5},
            "eval":{"file":"tiny_eval.hlo.txt","outputs":2}}}}"#,
    )
    .unwrap();
    let err = PjrtBackend::load(&dir, "tiny");
    assert!(err.is_err());
    std::fs::remove_dir_all(&dir).ok();
}
