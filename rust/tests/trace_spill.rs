//! Disk-spilled task traces: the `SimConfig::trace_path` contract.
//!
//! * Spilled records are bit-identical to the resident `record_tasks`
//!   records, on every engine (the writer sits on the shared collect
//!   loop, after the aggregator folds the step).
//! * Batched replications spill one `.rep<r>` file each, each matching
//!   that replication's resident records.
//! * At 10^6 steps the spill keeps record memory flat: the run's RSS
//!   high-water delta stays far below the ~44 MB a resident Vec of
//!   records would add (release builds only — debug stepping is too slow
//!   for a million-step horizon).

use fedqueue::coordinator::{SamplingPolicy, StaticPolicy};
use fedqueue::simulator::{
    run_batch, run_with_policy, EngineConfig, ServiceDist, ServiceFamily, SimConfig,
};
use fedqueue::util::mem::peak_rss_bytes;
use fedqueue::util::trace::{read_trace, RECORD_SIZE, TraceReader};

fn cfg(n: usize, c: usize, steps: u64, seed: u64) -> SimConfig {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 2.0 } else { 1.0 }).collect();
    SimConfig {
        seed,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    }
}

fn static_policy(n: usize) -> Box<dyn SamplingPolicy> {
    Box::new(StaticPolicy::new(vec![1.0 / n as f64; n]).unwrap())
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("fq_trace_spill");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().into_owned()
}

#[test]
fn spilled_trace_equals_resident_records_on_every_engine() {
    for (label, engine) in [
        ("heap", EngineConfig::heap()),
        ("sharded", EngineConfig::sharded(4, 1)),
        ("batch", EngineConfig::batch()),
    ] {
        let mut resident = cfg(8, 6, 5_000, 7);
        resident.engine = engine;
        resident.record_tasks = true;
        let mut spilled = resident.clone();
        let path = tmp(&format!("roundtrip_{label}.trace"));
        spilled.record_tasks = false;
        spilled.trace_path = Some(path.clone());

        let want = run_with_policy(resident, static_policy(8)).unwrap();
        let got = run_with_policy(spilled, static_policy(8)).unwrap();
        assert!(got.tasks.is_empty(), "{label}: spill must not keep records resident");

        let trace = read_trace(&path).unwrap();
        assert_eq!(trace.len(), want.tasks.len(), "{label}");
        for (a, b) in want.tasks.iter().zip(&trace) {
            assert_eq!(a.node, b.node, "{label}");
            assert_eq!(a.dispatch_step, b.dispatch_step, "{label}");
            assert_eq!(a.complete_step, b.complete_step, "{label}");
            assert_eq!(a.dispatch_time.to_bits(), b.dispatch_time.to_bits(), "{label}");
            assert_eq!(a.complete_time.to_bits(), b.complete_time.to_bits(), "{label}");
            assert_eq!(a.dispatch_prob.to_bits(), b.dispatch_prob.to_bits(), "{label}");
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn batched_replications_spill_one_trace_file_each() {
    let base = cfg(6, 4, 2_000, 0);
    let seeds = [11u64, 12, 13];
    let path = tmp("batch.trace");
    let mut spilled = base.clone();
    spilled.trace_path = Some(path.clone());
    run_batch(&spilled, &seeds, |_| Ok(static_policy(6))).unwrap();

    for (r, &seed) in seeds.iter().enumerate() {
        // each replication's file matches that seed run alone, resident
        let mut solo = base.clone();
        solo.seed = seed;
        solo.record_tasks = true;
        let want = run_with_policy(solo, static_policy(6)).unwrap();
        let trace = read_trace(&format!("{path}.rep{r}")).unwrap();
        assert_eq!(trace.len(), want.tasks.len(), "rep {r}");
        for (a, b) in want.tasks.iter().zip(&trace) {
            assert_eq!(a.node, b.node, "rep {r}");
            assert_eq!(a.complete_time.to_bits(), b.complete_time.to_bits(), "rep {r}");
        }
        std::fs::remove_file(format!("{path}.rep{r}")).ok();
    }
}

#[test]
fn million_step_spill_keeps_record_memory_flat() {
    if cfg!(debug_assertions) {
        return; // debug stepping is ~50× slower; the release CI runs this
    }
    let steps: u64 = 1_000_000;
    let path = tmp("million.trace");
    let mut c = cfg(10, 100, steps, 3);
    c.trace_path = Some(path.clone());
    let before = peak_rss_bytes();
    let res = run_with_policy(c, static_policy(10)).unwrap();
    let after = peak_rss_bytes();
    assert!(res.tasks.is_empty());
    assert_eq!(res.completions.iter().sum::<u64>(), steps);

    // the trace holds all 10^6 records on disk...
    let mut r = TraceReader::open(&path).unwrap();
    assert_eq!(r.declared_len(), Some(steps));
    let meta = std::fs::metadata(&path).unwrap().len();
    assert_eq!(meta, 24 + steps * RECORD_SIZE as u64);
    let first = r.next_record().unwrap().unwrap();
    assert!(first.complete_time > 0.0);

    // ...while resident memory never grew by anything like the ~44 MB a
    // record_tasks Vec would take (VmHWM is Linux-only; skip elsewhere)
    if let (Some(b), Some(a)) = (before, after) {
        let delta = a.saturating_sub(b);
        assert!(
            delta < 16 << 20,
            "RSS high-water grew by {delta} bytes during a spilled run"
        );
    }
    std::fs::remove_file(&path).ok();
}
