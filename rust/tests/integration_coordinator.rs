//! Integration: the full asynchronous coordinator over the native backend —
//! end-to-end learning, algorithm comparisons, and experiment-runner
//! plumbing (builder, scenarios, multi-seed sweeps, theory summaries).

use fedqueue::coordinator::{run_experiment, seed_sweep, table2_seeds, Experiment};
use fedqueue::figures::dl_figs::fig6_config;
use fedqueue::runtime::BackendKind;

fn quick(algo: &str, seed: u64) -> Experiment {
    let mut cfg = fig6_config(algo, true);
    cfg.backend = BackendKind::Native;
    cfg.seed = seed;
    cfg
}

#[test]
fn full_protocol_learns_on_all_algorithms() {
    // per-algorithm tuned η as in the paper ("we have finetuned the
    // learning rate for each method") — FedBuff applies only T/Z averaged
    // updates, so it needs a larger step size at this tiny scale.
    for (algo, eta, floor) in [("gasync", 0.05, 0.25), ("async", 0.05, 0.25), ("fedbuff", 0.4, 0.2)]
    {
        let mut cfg = quick(algo, 5);
        cfg.eta = eta;
        let res = run_experiment(&cfg).unwrap();
        assert!(
            res.final_accuracy > floor,
            "{algo}: accuracy {} vs 0.1 chance",
            res.final_accuracy
        );
        assert_eq!(res.steps, 120);
        assert_eq!(res.strategy, algo);
        assert!(!res.curve.is_empty());
    }
}

#[test]
fn fedavg_and_favano_run_via_registry() {
    // the semi-synchronous engines are reachable from the same train path
    // as the async strategies — `--algo fedavg|favano` end to end
    for (algo, eta) in [("fedavg", 0.3), ("favano", 0.5)] {
        let mut cfg = quick(algo, 5);
        cfg.eta = eta;
        cfg.favano_interval = 2.0;
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.strategy, algo);
        assert_eq!(res.steps, 120);
        assert!(res.versions > 0, "{algo}: no server update ever applied");
        assert!(res.versions < 120, "{algo}: buffered engine cannot step every gradient");
        assert!(
            res.final_accuracy.is_finite() && res.final_accuracy > 0.05,
            "{algo}: accuracy {}",
            res.final_accuracy
        );
    }
}

#[test]
fn gasync_with_optimal_policy_cuts_fast_delays() {
    let uni = run_experiment(&quick("async", 6)).unwrap();
    let mut opt_cfg = quick("gasync", 6);
    opt_cfg.policy = "optimal".into();
    assert!(opt_cfg.optimal_p_fast().unwrap() < 1.0 / opt_cfg.n_clients as f64);
    let opt = run_experiment(&opt_cfg).unwrap();
    assert_eq!(opt.policy, "optimal");
    let nf = opt_cfg.n_fast();
    let mean = |d: &[f64]| {
        let v: Vec<f64> = d.iter().cloned().filter(|v| v.is_finite()).collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let fast_uni = mean(&uni.mean_delay[..nf]);
    let fast_opt = mean(&opt.mean_delay[..nf]);
    assert!(
        fast_opt < fast_uni,
        "optimal sampling must reduce fast-node delays: {fast_opt} vs {fast_uni}"
    );
}

#[test]
fn seed_sweep_is_deterministic_and_aggregates() {
    let seeds = table2_seeds(3);
    assert_eq!(seeds, table2_seeds(3));
    let sweep = seed_sweep(&quick("async", 0), &seeds).unwrap();
    assert_eq!(sweep.accuracies.len(), 3);
    assert!(sweep.mean > 0.15 && sweep.mean < 1.0);
    assert!(sweep.std.is_finite());
    // re-running gives identical numbers
    let sweep2 = seed_sweep(&quick("async", 0), &seeds).unwrap();
    assert_eq!(sweep.accuracies, sweep2.accuracies);
}

#[test]
fn theory_summary_matches_experiment_delays() {
    let cfg = quick("async", 9);
    let (m_theory, rate) = fedqueue::coordinator::experiment::theory_summary(&cfg).unwrap();
    assert_eq!(m_theory.len(), cfg.n_clients);
    assert!(rate > 0.0);
    let res = run_experiment(&cfg).unwrap();
    // cluster-level agreement within a factor ~2 (short run, MC noise)
    let nf = cfg.n_fast();
    let t_slow = m_theory[nf..].iter().sum::<f64>() / (cfg.n_clients - nf) as f64;
    let finite: Vec<f64> = res.mean_delay[nf..]
        .iter()
        .cloned()
        .filter(|v| v.is_finite())
        .collect();
    let e_slow = finite.iter().sum::<f64>() / finite.len().max(1) as f64;
    assert!(
        e_slow / t_slow < 2.5 && t_slow / e_slow < 2.5,
        "slow delays: sim {e_slow} vs theory {t_slow}"
    );
}

#[test]
fn fedbuff_insensitive_to_z_only_in_cadence() {
    let mut a = quick("fedbuff", 11);
    a.fedbuff_z = 2;
    let mut b = quick("fedbuff", 11);
    b.fedbuff_z = 20;
    let ra = run_experiment(&a).unwrap();
    let rb = run_experiment(&b).unwrap();
    // both learn, but the big buffer must slow early progress
    // (fewer server model updates for the same gradient budget)
    assert!(ra.final_accuracy > 0.2);
    assert!(rb.curve[0].val_accuracy <= ra.curve[0].val_accuracy + 0.05);
    assert_eq!(ra.versions, 120 / 2);
    assert_eq!(rb.versions, 120 / 20);
}

#[test]
fn misconfigured_algorithms_fail_cleanly_with_registry_listing() {
    let mut cfg = quick("gasync", 1);
    cfg.algo = "sync-sgd".into();
    let err = run_experiment(&cfg).unwrap_err();
    assert!(err.contains("unknown"), "{err}");
    // the error enumerates the registry, not a hard-coded string
    for name in ["gasync", "async", "fedbuff", "fedavg", "favano"] {
        assert!(err.contains(name), "error should list '{name}': {err}");
    }
    let mut cfg = quick("gasync", 1);
    cfg.policy = "no-such-policy".into();
    let err = run_experiment(&cfg).unwrap_err();
    assert!(err.contains("unknown sampling policy"), "{err}");
    assert!(err.contains("adaptive"), "{err}");
}
