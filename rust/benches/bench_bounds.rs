//! Bound-machinery bench: Theorem-1 evaluation, cubic η solve, Table-1
//! comparators, and the Fig-2/3/4 regeneration cost per grid point.

use fedqueue::bound::{BoundParams, EtaPoly, MiSource, Theorem1, TwoClusterStudy};
use fedqueue::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::default();
    println!("# bench_bounds");
    let params = BoundParams::worked_example(100);
    let p = vec![0.01; 100];
    let m = vec![10.0; 100];
    let th = Theorem1::new(params, p, m).unwrap();
    b.run("theorem1/optimize_eta", || {
        black_box(th.optimize_eta().1);
    });
    let poly = EtaPoly { inv: 0.01, lin: 20.0, quad: 4e5 };
    b.run("cubic/unconstrained_min", || {
        black_box(poly.unconstrained_min());
    });
    let study = TwoClusterStudy {
        params,
        n_fast: 90,
        mu_fast: 8.0,
        mu_slow: 1.0,
        source: MiSource::default(),
    };
    b.run("study/evaluate-one-p (theory m_i)", || {
        black_box(study.evaluate(0.005).unwrap().bound);
    });
    b.run("study/baseline_bounds (Table 1)", || {
        black_box(study.baseline_bounds().unwrap().0);
    });
    b.run("study/physical-time-point (App E.2)", || {
        black_box(study.evaluate_physical_time(0.005, 1000.0).unwrap().bound);
    });
}
