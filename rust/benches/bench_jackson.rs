//! Theory bench: Buzen convolution + m_i analysis cost — this sits inside
//! the (p, η) optimizer's inner loop, so it must stay microseconds-fast.

use fedqueue::queueing::{ClosedNetwork, MiEstimator};
use fedqueue::util::bench::{black_box, Bencher};

fn net(n: usize) -> ClosedNetwork {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    ClosedNetwork::new(vec![1.0 / n as f64; n], rates).unwrap()
}

fn main() {
    let b = Bencher::default();
    println!("# bench_jackson — exact theory kernels");
    for (n, c) in [(10usize, 1000usize), (100, 10), (100, 100), (100, 1000), (1000, 1000)] {
        let network = net(n);
        b.run(&format!("buzen/n={n}/C={c}"), || {
            black_box(network.buzen(c).log_g[c]);
        });
        b.run(&format!("mi_analysis/n={n}/C={c}"), || {
            black_box(network.mi_analysis(c, MiEstimator::Throughput).m[0]);
        });
    }
    // the full optimizer sweep used by Algorithm 1's setup step
    use fedqueue::bound::{BoundParams, MiSource, TwoClusterStudy};
    let study = TwoClusterStudy {
        params: BoundParams::worked_example(100),
        n_fast: 90,
        mu_fast: 8.0,
        mu_slow: 1.0,
        source: MiSource::default(),
    };
    b.run("optimize_p/50-point-grid/C=100", || {
        black_box(study.optimize_p(50).unwrap().0.bound);
    });
}
