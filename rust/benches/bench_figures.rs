//! Figure-regeneration bench: wall time of each paper table/figure target
//! in quick mode — the "does the whole evaluation stay runnable" guardrail.

use fedqueue::figures;
use std::time::Instant;

fn main() {
    let out = std::env::temp_dir().join("fedqueue_bench_figures");
    std::fs::create_dir_all(&out).unwrap();
    println!("# bench_figures — quick-mode regeneration wall time");
    for target in ["fig1", "fig3", "fig4", "fig5", "fig8", "fig9", "fig11", "fig12", "table1"] {
        let t0 = Instant::now();
        match figures::run_target(target, &out, true) {
            Ok(_) => println!("{target:<8} {:>8.2}s", t0.elapsed().as_secs_f64()),
            Err(e) => println!("{target:<8} FAILED: {e}"),
        }
    }
    std::fs::remove_dir_all(&out).ok();
}
