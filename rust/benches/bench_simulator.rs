//! L3 bench: event-driven simulator throughput (CS steps/sec).
//! §Perf target: ≥ 5M steps/s on the Fig-5 network (n=10, C=1000).

use fedqueue::simulator::{run, ServiceDist, ServiceFamily, SimConfig};
use fedqueue::util::bench::{black_box, Bencher};

fn cfg(n: usize, c: usize, steps: u64, family: ServiceFamily) -> SimConfig {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 1.2 } else { 1.0 }).collect();
    SimConfig {
        seed: 1,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, family),
            c,
            steps,
        )
    }
}

fn main() {
    let b = Bencher::default();
    println!("# bench_simulator — event-engine throughput");
    for (label, n, c) in [
        ("fig5-network n=10 C=1000", 10usize, 1000usize),
        ("fig1-small   n=10 C=10", 10, 10),
        ("dl-protocol  n=100 C=10", 100, 10),
        ("large        n=1000 C=1000", 1000, 1000),
    ] {
        let steps = 100_000u64;
        let r = b.run(&format!("sim/{label}/100k-steps"), || {
            let res = run(cfg(n, c, steps, ServiceFamily::Exponential)).unwrap();
            black_box(res.tau_max);
        });
        println!("    -> {:.2} M steps/s", r.throughput(steps as f64) / 1e6);
    }
    // service family overhead comparison
    for fam in [
        ServiceFamily::Exponential,
        ServiceFamily::Deterministic,
        ServiceFamily::LogNormal(0.5),
    ] {
        let steps = 100_000u64;
        let r = b.run(&format!("sim/family/{fam:?}"), || {
            black_box(run(cfg(10, 100, steps, fam)).unwrap().tau_c);
        });
        println!("    -> {:.2} M steps/s", r.throughput(steps as f64) / 1e6);
    }
}
