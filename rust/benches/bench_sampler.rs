//! Routing-sampler bench: dispatch throughput of the O(n) linear CDF scan
//! vs the O(1) alias table vs the O(log n) Fenwick tree, the full
//! adaptive-policy step (observe + route) exact vs Fenwick-backed, and
//! the batched keyed service paths (exponential and lognormal block
//! kernels) vs per-draw generator construction.
//!
//! Doubles as the CI regression gate: `--assert-speedup X` exits nonzero
//! unless the alias sampler beats the linear scan by at least X× at
//! n = 10_000 (the ISSUE-2 acceptance floor is 10×).  `--json <path>`
//! writes every throughput + the gate ratio as a JSON artifact (the CI
//! perf-trajectory upload).
//!
//!     cargo bench --bench bench_sampler -- --quick --assert-speedup 10 \
//!         --json BENCH_sampler.json

use fedqueue::coordinator::policy::{AdaptiveQueuePolicy, FenwickAdaptivePolicy, SamplingPolicy};
use fedqueue::util::bench::{black_box, Bencher, JsonReport};
use fedqueue::util::cli::Args;
use fedqueue::util::rng::{stream_seed, AliasTable, Rng};
use fedqueue::util::sampler::{batch_exponential, batch_lognormal, linear_route, FenwickSampler};

/// Two-cluster distribution with mild skew (the paper's shape).
fn two_cluster_p(n: usize) -> Vec<f64> {
    let pf = 0.5 / n as f64;
    let q = (1.0 - (n / 2) as f64 * pf) / (n - n / 2) as f64;
    (0..n).map(|i| if i < n / 2 { pf } else { q }).collect()
}

const DRAWS_PER_ITER: u64 = 1_000;

fn bench_draws(
    b: &Bencher,
    report: &mut JsonReport,
    name: &str,
    mut draw: impl FnMut(&mut Rng) -> usize,
) -> f64 {
    let mut rng = Rng::new(7);
    let r = b.run(name, || {
        let mut acc = 0usize;
        for _ in 0..DRAWS_PER_ITER {
            acc = acc.wrapping_add(draw(&mut rng));
        }
        black_box(acc);
    });
    let per_sec = r.throughput(DRAWS_PER_ITER as f64);
    println!("    -> {:.2} M draws/s", per_sec / 1e6);
    report.throughput(name, per_sec);
    per_sec
}

fn main() {
    // `cargo bench` hands harness=false binaries an extra `--bench` flag;
    // accept it as a no-value flag so it can't eat the next option.  A
    // parse failure is fatal — silently dropping args here would disable
    // the CI regression gate while staying green.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &["quick", "bench"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bench_sampler: {e}");
            std::process::exit(2);
        }
    };
    let b = if args.has("quick") { Bencher::quick() } else { Bencher::default() };
    let mut report = JsonReport::new("bench_sampler");
    println!("# bench_sampler — routing dispatch throughput");

    let mut gate: Option<(f64, f64)> = None; // (linear, alias) at n = 10_000
    for n in [1_000usize, 10_000, 100_000] {
        let p = two_cluster_p(n);
        let linear = bench_draws(&b, &mut report, &format!("route/linear-scan/n={n}"), |rng| {
            linear_route(&p, rng.uniform())
        });
        let alias_t = AliasTable::new(&p).unwrap();
        let alias = bench_draws(&b, &mut report, &format!("route/alias/n={n}"), |rng| {
            alias_t.sample(rng)
        });
        let fen = FenwickSampler::new(&p).unwrap();
        let fenwick = bench_draws(&b, &mut report, &format!("route/fenwick/n={n}"), |rng| {
            fen.sample(rng)
        });
        println!(
            "    == n={n}: alias {:.0}x, fenwick {:.0}x over linear",
            alias / linear,
            fenwick / linear
        );
        if n == 10_000 {
            gate = Some((linear, alias));
        }
    }

    // full adaptive step: one queue-length observation + one route
    let n = 10_000;
    let base = vec![1.0 / n as f64; n];
    let mut lens = vec![0u32; n];
    let mut exact = AdaptiveQueuePolicy::new(base.clone(), 0.5).unwrap();
    let mut i = 0usize;
    let exact_rate = bench_draws(&b, &mut report, "adaptive-step/exact-O(n)/n=10000", |rng| {
        i = (i + 1) % n;
        lens[i] = (lens[i] + 1) % 8;
        exact.observe(&lens);
        exact.route(rng)
    });
    let mut fast = FenwickAdaptivePolicy::new(base, 0.5).unwrap();
    let mut lens2 = vec![0u32; n];
    let mut j = 0usize;
    let fast_rate =
        bench_draws(&b, &mut report, "adaptive-step/fenwick-O(log n)/n=10000", |rng| {
            j = (j + 1) % n;
            lens2[j] = (lens2[j] + 1) % 8;
            fast.observe_node(j, lens2[j]);
            fast.route(rng)
        });
    println!(
        "    == adaptive step: fenwick {:.0}x over exact renormalization",
        fast_rate / exact_rate
    );

    // keyed service durations: per-draw generator construction (the
    // scalar engine path) vs the chunked block sampler the batch arena
    // feeds — both produce bit-identical values
    let block = 4_096usize;
    let seeds: Vec<u64> = (0..block as u64).map(|k| stream_seed(9, &[k, 7])).collect();
    let rates: Vec<f64> = (0..block).map(|k| if k < block / 2 { 4.0 } else { 1.0 }).collect();
    let mut out = vec![0.0f64; block];
    let scalar = {
        let r = b.run(&format!("service/scalar-keyed/block={block}"), || {
            for k in 0..block {
                out[k] = Rng::new(seeds[k]).exponential(rates[k]);
            }
            black_box(out[block - 1]);
        });
        let per_sec = r.throughput(block as f64);
        println!("    -> {:.2} M draws/s", per_sec / 1e6);
        report.throughput(&format!("service/scalar-keyed/block={block}"), per_sec);
        per_sec
    };
    let batched = {
        let r = b.run(&format!("service/batched-exp/block={block}"), || {
            batch_exponential(&seeds, &rates, &mut out);
            black_box(out[block - 1]);
        });
        let per_sec = r.throughput(block as f64);
        println!("    -> {:.2} M draws/s", per_sec / 1e6);
        report.throughput(&format!("service/batched-exp/block={block}"), per_sec);
        per_sec
    };
    println!(
        "    == keyed exponential: batched {:.1}x over per-draw construction",
        batched / scalar
    );
    report.speedup("batched_exp_vs_scalar_block=4096", batched / scalar);

    // the same comparison for the lognormal kernel (two uniforms +
    // Box-Muller per draw): per-draw generator construction vs the
    // chunked block sampler — again bit-identical values
    let cvs: Vec<f64> = (0..block).map(|k| if k < block / 2 { 0.5 } else { 1.2 }).collect();
    let means: Vec<f64> = rates.iter().map(|r| 1.0 / r).collect();
    let scalar_ln = {
        let r = b.run(&format!("service/scalar-lognormal/block={block}"), || {
            for k in 0..block {
                out[k] = Rng::new(seeds[k]).lognormal_mean_cv(means[k], cvs[k]);
            }
            black_box(out[block - 1]);
        });
        let per_sec = r.throughput(block as f64);
        println!("    -> {:.2} M draws/s", per_sec / 1e6);
        report.throughput(&format!("service/scalar-lognormal/block={block}"), per_sec);
        per_sec
    };
    let batched_ln = {
        let r = b.run(&format!("service/batched-lognormal/block={block}"), || {
            batch_lognormal(&seeds, &means, &cvs, &mut out);
            black_box(out[block - 1]);
        });
        let per_sec = r.throughput(block as f64);
        println!("    -> {:.2} M draws/s", per_sec / 1e6);
        report.throughput(&format!("service/batched-lognormal/block={block}"), per_sec);
        per_sec
    };
    println!(
        "    == keyed lognormal: batched {:.1}x over per-draw construction",
        batched_ln / scalar_ln
    );
    report.speedup("batched_lognormal_vs_scalar_block=4096", batched_ln / scalar_ln);

    let (linear, alias) = gate.expect("n = 10_000 case always runs");
    let speedup = alias / linear;
    report.speedup("alias_vs_linear_n=10000", speedup);

    // write the artifact BEFORE gating so a regression still leaves its
    // measurements behind for the perf-trajectory diff
    if let Some(path) = args.get("json") {
        if let Err(e) = report.write(path) {
            eprintln!("bench_sampler: --json {e}");
            std::process::exit(2);
        }
        println!("wrote {path}");
    }

    if let Some(min) = args.get("assert-speedup") {
        let min: f64 = min.parse().expect("--assert-speedup expects a number");
        if speedup < min {
            eprintln!(
                "FAIL: alias sampler only {speedup:.1}x over linear scan at n=10_000 \
                 (required {min}x)"
            );
            std::process::exit(1);
        }
        println!("OK: alias sampler {speedup:.1}x over linear scan at n=10_000 (>= {min}x)");
    }
}
