//! Engine throughput: the monolithic heap oracle vs the sharded SoA
//! engine vs the batch replication arena, full replications (construction
//! + run, exactly what a sweep cell pays per seed).
//!
//! Doubles as the CI regression gate: `--assert-speedup X` exits nonzero
//! unless BOTH
//!
//! * the sequential sharded engine beats the heap engine by at least X×
//!   at n = 10^5, S = 8 (the ISSUE-3 acceptance floor is 2×), and
//! * the batch arena beats the one-arena-per-replication loop by at least
//!   X× at n = 10^4, R = 32 (the ISSUE-4 acceptance floor is 2×; the
//!   raw-speed push holds CI to 4× via `--assert-batch-speedup`) — the
//!   loop baseline is R separate heap replications, i.e. exactly what the
//!   sweep scheduler ran per small-n cell before the batch engine.
//!
//! `--assert-batch-speedup Y` overrides the batch floor independently of
//! the shard floor.  `--json <path>` additionally writes every measured
//! throughput and the gate ratios as a JSON artifact (the CI
//! perf-trajectory upload).
//!
//!     cargo bench --bench bench_engine -- --quick --assert-speedup 2 \
//!         --assert-batch-speedup 4 --json BENCH_engine.json

use fedqueue::coordinator::StaticPolicy;
use fedqueue::simulator::{
    run_batch, run_with_policy, ChurnConfig, EngineConfig, ServiceDist, ServiceFamily, SimConfig,
};
use fedqueue::util::bench::{black_box, Bencher, JsonReport};
use fedqueue::util::cli::Args;
use fedqueue::util::rng::stream_seed;

fn cfg(n: usize, c: usize, steps: u64, engine: EngineConfig) -> SimConfig {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    SimConfig {
        seed: 1,
        engine,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    }
}

/// One full replication (policy + engine construction + run), per-second
/// step throughput.
fn bench_replication(b: &Bencher, report: &mut JsonReport, name: &str, base: &SimConfig) -> f64 {
    let steps = base.steps;
    let r = b.run(name, || {
        let policy = Box::new(StaticPolicy::new(base.p.clone()).unwrap());
        let res = run_with_policy(base.clone(), policy).unwrap();
        black_box(res.tau_max);
    });
    let per_sec = r.throughput(steps as f64);
    println!("    -> {:.2} M steps/s", per_sec / 1e6);
    report.throughput(name, per_sec);
    per_sec
}

/// The sweep cell's ensemble unit: R replications on independent streams.
/// `engine = None` runs the batch arena; `Some(e)` runs the
/// one-arena-per-replication loop on engine `e`.  Throughput counts ALL
/// R·steps events, so the ratio is a true wall-clock speedup.
fn bench_ensemble(
    b: &Bencher,
    report: &mut JsonReport,
    name: &str,
    base: &SimConfig,
    reps: usize,
    engine: Option<EngineConfig>,
) -> f64 {
    let seeds: Vec<u64> = (0..reps as u64).map(|s| stream_seed(7, &[0, s])).collect();
    let r = b.run(name, || match engine {
        None => {
            let out = run_batch(base, &seeds, |_| {
                Ok(Box::new(StaticPolicy::new(base.p.clone()).unwrap()))
            })
            .unwrap();
            black_box(out.len());
        }
        Some(e) => {
            for &seed in &seeds {
                let mut c = base.clone();
                c.seed = seed;
                c.engine = e;
                // same routing distribution as the batch arm — the gate
                // must compare identical systems
                let policy = Box::new(StaticPolicy::new(base.p.clone()).unwrap());
                let res = run_with_policy(c, policy).unwrap();
                black_box(res.tau_max);
            }
        }
    });
    let per_sec = r.throughput((reps as u64 * base.steps) as f64);
    println!("    -> {:.2} M steps/s across R={reps}", per_sec / 1e6);
    report.throughput(name, per_sec);
    per_sec
}

fn main() {
    // `cargo bench` hands harness=false binaries an extra `--bench` flag;
    // accept it as a no-value flag so it can't eat the next option.  A
    // parse failure is fatal — silently dropping args here would disable
    // the CI regression gate while staying green.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &["quick", "bench"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bench_engine: {e}");
            std::process::exit(2);
        }
    };
    let b = if args.has("quick") { Bencher::quick() } else { Bencher::default() };
    let mut report = JsonReport::new("bench_engine");
    println!("# bench_engine — heap vs sharded vs batch replication throughput");

    let mut shard_gate: Option<(f64, f64)> = None; // (heap, sharded S=8) at n = 1e5
    for (n, c, steps) in [
        (10_000usize, 10_000usize, 20_000u64),
        (100_000, 100_000, 25_000),
    ] {
        let heap = bench_replication(
            &b,
            &mut report,
            &format!("engine/heap/n={n}"),
            &cfg(n, c, steps, EngineConfig::heap()),
        );
        let s1 = bench_replication(
            &b,
            &mut report,
            &format!("engine/sharded-S1/n={n}"),
            &cfg(n, c, steps, EngineConfig::sharded(1, 1)),
        );
        let s8 = bench_replication(
            &b,
            &mut report,
            &format!("engine/sharded-S8/n={n}"),
            &cfg(n, c, steps, EngineConfig::sharded(8, 1)),
        );
        println!(
            "    == n={n}: sharded S=1 {:.2}x, S=8 {:.2}x over heap",
            s1 / heap,
            s8 / heap
        );
        if n == 100_000 {
            shard_gate = Some((heap, s8));
        }
    }

    // the batch gate: a 32-seed ensemble at n = 10^4, arena vs loop —
    // amortized construction + vectorized exponential sampling vs 32
    // arenas built and torn down in sequence
    let (n, c, steps, reps) = (10_000usize, 10_000usize, 5_000u64, 32usize);
    let base = cfg(n, c, steps, EngineConfig::batch());
    let loop_heap = bench_ensemble(
        &b,
        &mut report,
        &format!("ensemble/loop-heap/n={n}/R={reps}"),
        &base,
        reps,
        Some(EngineConfig::heap()),
    );
    let loop_soa = bench_ensemble(
        &b,
        &mut report,
        &format!("ensemble/loop-sharded-S1/n={n}/R={reps}"),
        &base,
        reps,
        Some(EngineConfig::sharded(1, 1)),
    );
    let batched = bench_ensemble(
        &b,
        &mut report,
        &format!("ensemble/batch-arena/n={n}/R={reps}"),
        &base,
        reps,
        None,
    );
    println!(
        "    == ensemble n={n} R={reps}: batch {:.2}x over heap loop, {:.2}x over SoA loop",
        batched / loop_heap,
        batched / loop_soa
    );

    // churn overhead: the same heap replication with the open-network
    // lifecycle stream off and on.  The churn-off number is the cross-PR
    // anchor — the CI perf-trajectory diff over the BENCH artifacts holds
    // it within 5% of the pre-churn baseline's engine/heap/n=10000 entry;
    // that gate lives in the artifact diff, not in this binary.
    let (n, c, steps) = (10_000usize, 10_000usize, 20_000u64);
    let off = cfg(n, c, steps, EngineConfig::heap());
    let mut on = off.clone();
    on.churn = Some(ChurnConfig {
        arrival_rate: 0.8,
        mean_lifetime: 40.0,
        stall_rate: 0.3,
        mean_stall: 2.0,
        rate_change_rate: 0.5,
        rate_factor_min: 0.5,
        rate_factor_max: 2.0,
        initial_active: 0,
        max_events: 10_000,
    });
    let churn_off = bench_replication(&b, &mut report, &format!("churn/off/heap/n={n}"), &off);
    let churn_on = bench_replication(&b, &mut report, &format!("churn/on/heap/n={n}"), &on);
    println!(
        "    == n={n}: churn-on runs at {:.2}x of churn-off throughput",
        churn_on / churn_off
    );
    report.speedup("churn_on_vs_off_heap_n=10000", churn_on / churn_off);

    let (heap, sharded) = shard_gate.expect("n = 100_000 case always runs");
    let shard_speedup = sharded / heap;
    let batch_speedup = batched / loop_heap;
    report.speedup("sharded_S8_vs_heap_n=100000", shard_speedup);
    report.speedup("batch_R32_vs_heap_loop_n=10000", batch_speedup);
    report.speedup("batch_R32_vs_soa_loop_n=10000", batched / loop_soa);

    // write the artifact BEFORE gating so a regression still leaves its
    // measurements behind for the perf-trajectory diff
    if let Some(path) = args.get("json") {
        if let Err(e) = report.write(path) {
            eprintln!("bench_engine: --json {e}");
            std::process::exit(2);
        }
        println!("wrote {path}");
    }

    // --assert-speedup X gates BOTH engines at X; --assert-batch-speedup Y
    // raises (or sets) the batch arena's floor independently, so CI can
    // hold the vectorized batch loop to a stricter multiple than the
    // sharded engine's 2x acceptance floor
    let shard_min: Option<f64> = args
        .get("assert-speedup")
        .map(|m| m.parse().expect("--assert-speedup expects a number"));
    let batch_min: Option<f64> = args
        .get("assert-batch-speedup")
        .map(|m| m.parse().expect("--assert-batch-speedup expects a number"))
        .or(shard_min);
    let mut failed = false;
    if let Some(min) = shard_min {
        if shard_speedup < min {
            eprintln!(
                "FAIL: sharded engine only {shard_speedup:.2}x over heap at n=100_000, S=8 \
                 (required {min}x)"
            );
            failed = true;
        } else {
            println!(
                "OK: sharded engine {shard_speedup:.2}x over heap at n=100_000, S=8 (>= {min}x)"
            );
        }
    }
    if let Some(min) = batch_min {
        if batch_speedup < min {
            eprintln!(
                "FAIL: batch arena only {batch_speedup:.2}x over the per-replication loop at \
                 n=10_000, R=32 (required {min}x)"
            );
            failed = true;
        } else {
            println!(
                "OK: batch arena {batch_speedup:.2}x over the per-replication loop at n=10_000, \
                 R=32 (>= {min}x)"
            );
        }
    }
    if failed {
        std::process::exit(1);
    }
}
