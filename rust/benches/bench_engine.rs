//! Engine throughput: the monolithic heap oracle vs the sharded SoA
//! engine, full replications (construction + run, exactly what a sweep
//! cell pays per seed).
//!
//! Doubles as the CI regression gate: `--assert-speedup X` exits nonzero
//! unless the sequential sharded engine beats the heap engine by at least
//! X× at n = 10^5, S = 8 (the ISSUE-3 acceptance floor is 2×).  At that
//! scale the heap engine allocates ~n `VecDeque`s and walks a single
//! ~megabyte event heap, while the sharded engine runs on five flat
//! arrays and eight L2-resident calendars.
//!
//!     cargo bench --bench bench_engine -- --quick --assert-speedup 2

use fedqueue::coordinator::StaticPolicy;
use fedqueue::simulator::{
    run_with_policy, EngineConfig, ServiceDist, ServiceFamily, SimConfig,
};
use fedqueue::util::bench::{black_box, Bencher};
use fedqueue::util::cli::Args;

fn cfg(n: usize, c: usize, steps: u64, engine: EngineConfig) -> SimConfig {
    let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
    SimConfig {
        seed: 1,
        engine,
        ..SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    }
}

/// One full replication (policy + engine construction + run), per-second
/// step throughput.
fn bench_replication(b: &Bencher, name: &str, base: &SimConfig) -> f64 {
    let steps = base.steps;
    let r = b.run(name, || {
        let policy = Box::new(StaticPolicy::new(base.p.clone()).unwrap());
        let res = run_with_policy(base.clone(), policy).unwrap();
        black_box(res.tau_max);
    });
    let per_sec = r.throughput(steps as f64);
    println!("    -> {:.2} M steps/s", per_sec / 1e6);
    per_sec
}

fn main() {
    // `cargo bench` hands harness=false binaries an extra `--bench` flag;
    // accept it as a no-value flag so it can't eat the next option.  A
    // parse failure is fatal — silently dropping args here would disable
    // the CI regression gate while staying green.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &["quick", "bench"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bench_engine: {e}");
            std::process::exit(2);
        }
    };
    let b = if args.has("quick") { Bencher::quick() } else { Bencher::default() };
    println!("# bench_engine — heap vs sharded replication throughput");

    let mut gate: Option<(f64, f64)> = None; // (heap, sharded S=8) at n = 1e5
    for (n, c, steps) in [
        (10_000usize, 10_000usize, 20_000u64),
        (100_000, 100_000, 25_000),
    ] {
        let heap = bench_replication(
            &b,
            &format!("engine/heap/n={n}"),
            &cfg(n, c, steps, EngineConfig::heap()),
        );
        let s1 = bench_replication(
            &b,
            &format!("engine/sharded-S1/n={n}"),
            &cfg(n, c, steps, EngineConfig::sharded(1, 1)),
        );
        let s8 = bench_replication(
            &b,
            &format!("engine/sharded-S8/n={n}"),
            &cfg(n, c, steps, EngineConfig::sharded(8, 1)),
        );
        println!(
            "    == n={n}: sharded S=1 {:.2}x, S=8 {:.2}x over heap",
            s1 / heap,
            s8 / heap
        );
        if n == 100_000 {
            gate = Some((heap, s8));
        }
    }

    if let Some(min) = args.get("assert-speedup") {
        let min: f64 = min.parse().expect("--assert-speedup expects a number");
        let (heap, sharded) = gate.expect("n = 100_000 case always runs");
        let speedup = sharded / heap;
        if speedup < min {
            eprintln!(
                "FAIL: sharded engine only {speedup:.2}x over heap at n=100_000, S=8 \
                 (required {min}x)"
            );
            std::process::exit(1);
        }
        println!("OK: sharded engine {speedup:.2}x over heap at n=100_000, S=8 (>= {min}x)");
    }
}
