//! End-to-end coordinator bench: CS steps/sec through the full async loop
//! (simulator + snapshots + update rule + native backend), and the
//! coordinator-only overhead (zero-cost gradient) — §Perf: coordinator
//! overhead must be < 5% of the step budget at n=100.

use fedqueue::coordinator::{build_loaders, Driver, DriverConfig};
use fedqueue::data::{generate, EvalBatches, Partition, PartitionScheme, SynthSpec};
use fedqueue::fl::GenAsync;
use fedqueue::runtime::{Backend, NativeBackend};
use fedqueue::simulator::{ServiceDist, ServiceFamily, SimConfig};
use fedqueue::util::bench::Bencher;
use std::sync::Arc;

fn main() {
    let b = Bencher::quick();
    println!("# bench_coordinator — full async loop (native backend, tiny model)");
    for (n, c, steps) in [(20usize, 5usize, 200u64), (100, 10, 200)] {
        let spec = SynthSpec::tiny_test();
        let train = Arc::new(generate(&spec, 2000, 1));
        let val = generate(&spec, 200, 2);
        let part = Partition::build(
            &train,
            n,
            PartitionScheme::ClassSubset { classes_per_client: 7 },
            3,
        )
        .unwrap();
        let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
        let r = b.run(&format!("coordinator/n={n}/C={c}/{steps}-steps"), || {
            let mut backend = NativeBackend::tiny();
            let loaders =
                build_loaders(train.clone(), &part, backend.spec().train_batch, true, 4).unwrap();
            let val_b = EvalBatches::new(&val, backend.spec().eval_batch);
            let p = vec![1.0 / n as f64; n];
            let sim = SimConfig {
                seed: 5,
                ..SimConfig::new(
                    p.clone(),
                    ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                    c,
                    steps,
                )
            };
            let mut model = backend.spec().init_model(6);
            let mut driver = Driver::new(&mut backend, loaders, val_b);
            let mut dc =
                DriverConfig::with_strategy(sim, Box::new(GenAsync::new(0.05, p))).unwrap();
            dc.loss_window = 10;
            let res = driver.run(dc, &mut model).unwrap();
            std::hint::black_box(res.final_accuracy);
        });
        println!("    -> {:.0} CS steps/s end-to-end", r.throughput(steps as f64));
    }
    // coordinator overhead: same loop with the cheapest possible model —
    // gradient cost ~ 0, exposing snapshot/bookkeeping costs
    {
        let n = 100;
        let steps = 2000u64;
        let spec = SynthSpec::tiny_test();
        let train = Arc::new(generate(&spec, 500, 7));
        let val = generate(&spec, 50, 8);
        let part = Partition::build(&train, n, PartitionScheme::Iid, 9).unwrap();
        let r = b.run("coordinator-overhead/n=100/tiny-model", || {
            let mut backend = NativeBackend::tiny();
            let loaders =
                build_loaders(train.clone(), &part, backend.spec().train_batch, false, 10)
                    .unwrap();
            let val_b = EvalBatches::new(&val, backend.spec().eval_batch);
            let p = vec![0.01; n];
            let rates = vec![1.0; n];
            let sim = SimConfig {
                seed: 11,
                ..SimConfig::new(
                    p.clone(),
                    ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                    10,
                    steps,
                )
            };
            let mut model = backend.spec().init_model(12);
            let mut driver = Driver::new(&mut backend, loaders, val_b);
            let mut dc =
                DriverConfig::with_strategy(sim, Box::new(GenAsync::new(0.05, p))).unwrap();
            dc.loss_window = 10;
            let res = driver.run(dc, &mut model).unwrap();
            std::hint::black_box(res.final_accuracy);
        });
        println!("    -> {:.0} CS steps/s with ~free gradients", r.throughput(steps as f64));
    }
}
