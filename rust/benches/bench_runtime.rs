//! L1/L2 bench: gradient-computation latency through the PJRT path (AOT
//! JAX + Pallas HLO) vs the native backend, per model variant.
//! §Perf target: PJRT cifar train_step competitive with native (see
//! EXPERIMENTS.md §Perf for the optimization log).

use fedqueue::data::Batch;
use fedqueue::runtime::{Backend, Manifest, NativeBackend, PjrtBackend};
use fedqueue::util::bench::{black_box, Bencher};
use fedqueue::util::rng::Rng;

fn batch_for(spec: &fedqueue::runtime::ModelSpec, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let b = spec.train_batch;
    let x: Vec<f32> = (0..b * spec.input_dim).map(|_| rng.normal() as f32).collect();
    let mut onehot = vec![0.0f32; b * spec.classes];
    for bi in 0..b {
        onehot[bi * spec.classes + rng.usize_below(spec.classes)] = 1.0;
    }
    Batch { x, onehot, batch: b }
}

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("# bench_runtime SKIPPED — run `make artifacts` first");
        return;
    }
    let b = Bencher::default();
    println!("# bench_runtime — gradient latency per backend/variant");
    for variant in ["tiny", "cifar", "cifar_jnp"] {
        let mut pj = PjrtBackend::load(&dir, variant).unwrap();
        let spec = pj.spec().clone();
        let model = spec.init_model(1);
        let batch = batch_for(&spec, 2);
        let flops = 6.0
            * spec.train_batch as f64
            * spec
                .layer_dims()
                .iter()
                .map(|(a, o)| (a * o) as f64)
                .sum::<f64>();
        let r = b.run(&format!("pjrt/{variant}/train_step"), || {
            black_box(pj.train_step(&model, &batch).unwrap().0);
        });
        println!("    -> {:.2} GFLOP/s", flops / r.mean_ns);
        let mut nat = NativeBackend::new(spec.clone());
        let r = b.run(&format!("native/{variant}/train_step"), || {
            black_box(nat.train_step(&model, &batch).unwrap().0);
        });
        println!("    -> {:.2} GFLOP/s", flops / r.mean_ns);
        // eval latency
        let eb = {
            let mut rng = Rng::new(3);
            let bsz = spec.eval_batch;
            let x: Vec<f32> = (0..bsz * spec.input_dim).map(|_| rng.normal() as f32).collect();
            let mut onehot = vec![0.0f32; bsz * spec.classes];
            for bi in 0..bsz {
                onehot[bi * spec.classes + rng.usize_below(spec.classes)] = 1.0;
            }
            Batch { x, onehot, batch: bsz }
        };
        b.run(&format!("pjrt/{variant}/eval_batch"), || {
            black_box(pj.eval_batch(&model, &eb, eb.batch).unwrap().0);
        });
    }
}
