//! The determinism lint must pass clean on the real crate — the same
//! invariant the CI `static-analysis` job gates merges on.

use std::path::PathBuf;

#[test]
fn fedqueue_src_is_lint_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src");
    let violations = xtask::lint_root(&src);
    assert!(
        violations.is_empty(),
        "determinism lint violations in src/:\n{}",
        violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
