//! One deliberate violation per rule R1–R8, plus suppression behavior
//! (doc comments, nested block comments, stale allows) and the JSON
//! rendering, each asserting the exact diagnostic.

use std::path::PathBuf;

use xtask::{lint_report, lint_root, render_json, Violation};

fn fixture_root(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn fixture(name: &str) -> Vec<Violation> {
    lint_root(&fixture_root(name))
}

#[test]
fn r1_observe_path_rng_draw() {
    let v = fixture("r1");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R1");
    assert_eq!(v[0].file, "coordinator/policy.rs");
    assert_eq!(v[0].line, 11);
    assert!(v[0].msg.contains("observe_completion"), "{}", v[0].msg);
}

#[test]
fn r1_membership_callbacks_rng_draw() {
    // observe_join / observe_leave are R1 roots: the membership channel
    // fires inside every engine's churn event loop, so a draw there would
    // desynchronize the routing stream exactly like one in observe()
    let v = fixture("r1_membership");
    assert_eq!(v.len(), 2, "diagnostics: {v:?}");
    for violation in &v {
        assert_eq!(violation.rule.name(), "R1");
        assert_eq!(violation.file, "coordinator/policy.rs");
    }
    assert!(v[0].msg.contains("observe_join"), "{}", v[0].msg);
    assert!(v[1].msg.contains("observe_leave"), "{}", v[1].msg);
}

#[test]
fn r2_hashmap_in_digest_region() {
    // the fixture file never names a module from the old hard-coded list;
    // it is tainted because digest_step touches StepAggregator and calls
    // tally, which owns the HashMap
    let v = fixture("r2");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R2");
    assert_eq!(v[0].file, "simulator/state.rs");
    assert_eq!(v[0].line, 4);
    assert!(
        v[0].msg.contains("tainted via digest_step -> tally"),
        "witness chain must name the taint path: {}",
        v[0].msg
    );
}

#[test]
fn r3_instant_in_digest_region() {
    let v = fixture("r3");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R3");
    assert_eq!(v[0].file, "simulator/clock.rs");
    assert_eq!(v[0].line, 4);
    assert!(
        v[0].msg.contains("tainted via digest_step -> stamp_secs"),
        "witness chain must name the taint path: {}",
        v[0].msg
    );
}

#[test]
fn r4_bare_literal_seed() {
    let v = fixture("r4");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R4");
    assert_eq!(v[0].file, "coordinator/experiment.rs");
    assert_eq!(v[0].line, 5, "keyed construction below must not fire");
}

#[test]
fn r5_bare_float_accumulation() {
    let v = fixture("r5");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R5");
    assert_eq!(v[0].file, "simulator/engine/accum.rs");
    assert_eq!(v[0].line, 12, "StepAggregator impl below must not fire");
}

#[test]
fn r6_bare_literal_stream_key() {
    let v = fixture("r6");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R6");
    assert_eq!(v[0].file, "coordinator/streams.rs");
    assert_eq!(v[0].line, 5, "the *_STREAM const derive below must not fire");
    assert_eq!(
        v[0].msg,
        "RNG stream derived from bare literal `0xBAD_5EED` — key streams off a named \
         `*_STREAM` constant so ids stay collision-auditable"
    );
}

#[test]
fn r6_stream_constant_collision() {
    // two *_STREAM consts in different modules share a value; the later
    // site (files sorted) carries the diagnostic and names the earlier one
    let v = fixture("r6_collision");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R6");
    assert_eq!(v[0].file, "simulator/engine/mod.rs");
    assert_eq!(v[0].line, 3);
    assert_eq!(
        v[0].msg,
        "stream constant ROUTE_STREAM (0x5e47) collides with SERVE_STREAM at \
         coordinator/serve.rs:4 — colliding ids correlate supposedly-independent RNG streams"
    );
}

#[test]
fn r7_blocking_call_reachable_from_async() {
    // thread::sleep lives in a sync helper two hops from the async fn; the
    // diagnostic lands on the sleep and reports the call chain
    let v = fixture("r7");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R7");
    assert_eq!(v[0].file, "runtime/task.rs");
    assert_eq!(v[0].line, 8);
    assert!(v[0].msg.contains("`sleep`"), "{}", v[0].msg);
    assert!(
        v[0].msg.contains("chain: client_loop -> pace"),
        "chain must start at the async root: {}",
        v[0].msg
    );
}

#[test]
fn r8_float_reduction_in_sink_file() {
    // the .sum::<f64>() outside the Welford impl fires; the identical
    // reduction inside the impl is the sink's own accumulator and is exempt
    let v = fixture("r8");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R8");
    assert_eq!(v[0].file, "figures/band.rs");
    assert_eq!(v[0].line, 5, "Welford impl below must not fire");
    assert!(v[0].msg.contains("`.sum()`"), "{}", v[0].msg);
}

#[test]
fn valid_lint_allow_suppresses() {
    let report = lint_report(&fixture_root("allowed"));
    assert!(
        report.violations.is_empty(),
        "expected clean, got: {:?}",
        report.violations
    );
    assert_eq!(report.allows.len(), 2, "census: {:?}", report.allows);
    assert!(
        report.allows.iter().all(|a| a.used),
        "both allows are live: {:?}",
        report.allows
    );
}

#[test]
fn lint_allow_without_reason_is_rejected() {
    let v = fixture("missing_reason");
    let rules: Vec<&str> = v.iter().map(|v| v.rule.name()).collect();
    assert!(
        rules.contains(&"lint-allow-syntax"),
        "missing reason must be diagnosed: {v:?}"
    );
    assert!(
        rules.contains(&"R2"),
        "malformed allow must not suppress: {v:?}"
    );
}

#[test]
fn stale_allow_fails_live_allow_survives() {
    // one file, two allows: the R2 one suppresses a real HashMap and stays
    // silent; the R3 one covers nothing and must itself be a violation
    let report = lint_report(&fixture_root("stale_allow"));
    assert_eq!(
        report.violations.len(),
        1,
        "only the stale allow fails: {:?}",
        report.violations
    );
    let v = &report.violations[0];
    assert_eq!(v.rule.name(), "stale-allow");
    assert_eq!(v.file, "coordinator/audit.rs");
    assert_eq!(v.line, 18);
    assert_eq!(
        v.msg,
        "lint-allow(R3) suppresses nothing — remove the stale suppression or \
         restore the code it covered"
    );
    let used: Vec<bool> = report.allows.iter().map(|a| a.used).collect();
    assert_eq!(used, [true, false], "census: {:?}", report.allows);
}

#[test]
fn doc_comment_allow_does_not_suppress() {
    // `/// lint-allow(R2): ...` is documentation, not a directive
    let v = fixture("doc_allow");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R2");
    assert_eq!(v[0].file, "coordinator/doc.rs");
    assert_eq!(v[0].line, 6);
}

#[test]
fn nested_block_comment_allow_suppresses() {
    // the allow sits on the closing line of a nested block comment; a lexer
    // that ends the comment at the first `*/` would mis-attribute it
    let report = lint_report(&fixture_root("nested_comment"));
    assert!(
        report.violations.is_empty(),
        "expected clean, got: {:?}",
        report.violations
    );
    assert_eq!(report.allows.len(), 1);
    assert!(report.allows[0].used);
}

#[test]
fn json_report_matches_golden() {
    // the machine-readable shape is a contract with CI (problem matcher +
    // artifact consumers): pin it byte-for-byte against a committed golden
    let report = lint_report(&fixture_root("stale_allow"));
    let got = render_json(&report);
    let golden_path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/stale_allow.json");
    let want = std::fs::read_to_string(&golden_path).expect("golden file");
    assert_eq!(
        got.trim_end(),
        want.trim_end(),
        "JSON shape drifted from {}",
        golden_path.display()
    );
}
