//! One deliberate violation per rule R1–R5, plus suppression behavior,
//! each asserting the exact rule-name diagnostic.

use std::path::PathBuf;

use xtask::{lint_root, Violation};

fn fixture(name: &str) -> Vec<Violation> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    lint_root(&root)
}

#[test]
fn r1_observe_path_rng_draw() {
    let v = fixture("r1");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R1");
    assert_eq!(v[0].file, "coordinator/policy.rs");
    assert_eq!(v[0].line, 11);
    assert!(v[0].msg.contains("observe_completion"), "{}", v[0].msg);
}

#[test]
fn r1_membership_callbacks_rng_draw() {
    // observe_join / observe_leave are R1 roots: the membership channel
    // fires inside every engine's churn event loop, so a draw there would
    // desynchronize the routing stream exactly like one in observe()
    let v = fixture("r1_membership");
    assert_eq!(v.len(), 2, "diagnostics: {v:?}");
    for violation in &v {
        assert_eq!(violation.rule.name(), "R1");
        assert_eq!(violation.file, "coordinator/policy.rs");
    }
    assert!(v[0].msg.contains("observe_join"), "{}", v[0].msg);
    assert!(v[1].msg.contains("observe_leave"), "{}", v[1].msg);
}

#[test]
fn r2_hashmap_in_deterministic_module() {
    let v = fixture("r2");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R2");
    assert_eq!(v[0].file, "simulator/state.rs");
    assert_eq!(v[0].line, 4);
}

#[test]
fn r3_instant_in_deterministic_module() {
    let v = fixture("r3");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R3");
    assert_eq!(v[0].file, "simulator/clock.rs");
    assert_eq!(v[0].line, 4);
}

#[test]
fn r4_bare_literal_seed() {
    let v = fixture("r4");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R4");
    assert_eq!(v[0].file, "coordinator/experiment.rs");
    assert_eq!(v[0].line, 5, "keyed construction below must not fire");
}

#[test]
fn r5_bare_float_accumulation() {
    let v = fixture("r5");
    assert_eq!(v.len(), 1, "diagnostics: {v:?}");
    assert_eq!(v[0].rule.name(), "R5");
    assert_eq!(v[0].file, "simulator/engine/accum.rs");
    assert_eq!(v[0].line, 12, "StepAggregator impl below must not fire");
}

#[test]
fn valid_lint_allow_suppresses() {
    let v = fixture("allowed");
    assert!(v.is_empty(), "expected clean, got: {v:?}");
}

#[test]
fn lint_allow_without_reason_is_rejected() {
    let v = fixture("missing_reason");
    let rules: Vec<&str> = v.iter().map(|v| v.rule.name()).collect();
    assert!(
        rules.contains(&"lint-allow-syntax"),
        "missing reason must be diagnosed: {v:?}"
    );
    assert!(
        rules.contains(&"R2"),
        "malformed allow must not suppress: {v:?}"
    );
}
