// Fixture: R6 — RNG stream derived from a bare literal key instead of a
// named `*_STREAM` constant.

pub fn split(rng: &mut Rng) -> Rng {
    rng.derive(0xBAD_5EED) // deliberate violation
}

pub const FIXTURE_STREAM: u64 = 0x0F17;

pub fn split_named(rng: &mut Rng) -> Rng {
    rng.derive(FIXTURE_STREAM) // named constant: fine
}
