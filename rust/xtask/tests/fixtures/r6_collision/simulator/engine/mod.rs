// Fixture: R6 collision — ROUTE_STREAM reuses SERVE_STREAM's value.

pub const ROUTE_STREAM: u64 = 0x5E47; // deliberate violation
