// Fixture: R6 collision — distinct stream constants are fine; a reused
// value elsewhere in the crate is not.

pub const SERVE_STREAM: u64 = 0x5E47;
pub const JOIN_STREAM: u64 = 0x5E48;
