// Fixture: a justified `lint-allow` suppression keeps the file clean.

pub fn scratch(n: usize) -> usize {
    // lint-allow(R2): scratch map is drained and len() is order-independent
    let mut m = std::collections::HashMap::new();
    for i in 0..n {
        m.insert(i, ());
    }
    m.len()
}

pub fn inline_allowed() -> usize {
    let s = std::collections::HashSet::<u32>::new(); // lint-allow(R2): empty set, never iterated
    s.len()
}

#[cfg(test)]
mod tests {
    // Test modules are exempt from every rule.
    use std::collections::HashMap;
    use std::time::Instant;

    #[test]
    fn scratch_counts() {
        let _ = (HashMap::<u32, u32>::new(), Instant::now());
        assert_eq!(super::scratch(3), 3);
    }
}
