// Fixture scaffold: `digest_step` touches the StepAggregator sink, so the
// taint pass pulls everything it (transitively) calls into the digest
// region — including the file under test.

pub fn digest_step(agg: &mut StepAggregator, n: usize) -> usize {
    let a = scratch(n);
    let b = inline_allowed();
    agg.push_step((a + b) as f64);
    a + b
}
