// Fixture scaffold: `digest_step` touches the StepAggregator sink, so the
// taint pass pulls everything it (transitively) calls into the digest
// region — including the file under test.

pub fn digest_step(agg: &mut StepAggregator, xs: &[u32]) -> usize {
    let n = tally(xs);
    agg.push_step(n as f64);
    n
}
