// Fixture: R2 — unordered collection in a deterministic module.

pub fn tally(xs: &[u32]) -> usize {
    let mut seen = std::collections::HashMap::new(); // deliberate violation
    for &x in xs {
        seen.insert(x, ());
    }
    seen.len()
}
