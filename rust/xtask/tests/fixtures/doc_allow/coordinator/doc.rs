// Fixture: doc-comment text never mints a suppression — the `///` line
// below is documentation, not a directive, so R2 must still fire.

pub fn digest_step(agg: &mut StepAggregator, xs: &[u32]) -> usize {
    /// lint-allow(R2): this is prose, not a suppression
    let mut m = std::collections::HashMap::new();
    m.insert(xs.len(), ());
    m.len()
}
