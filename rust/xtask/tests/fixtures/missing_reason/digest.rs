// Fixture scaffold: `digest_step` touches the StepAggregator sink, so the
// taint pass pulls everything it (transitively) calls into the digest
// region — including the file under test.

pub fn digest_step(agg: &mut StepAggregator, n: usize) -> usize {
    let k = bad(n);
    agg.push_step(k as f64);
    k
}
