// Fixture: a `lint-allow` without a reason is itself a diagnostic and does
// NOT suppress the underlying violation.

pub fn bad(n: usize) -> usize {
    // lint-allow(R2)
    let mut m = std::collections::HashMap::new();
    m.insert(n, ());
    m.len()
}
