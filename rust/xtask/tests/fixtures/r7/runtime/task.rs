// Fixture: R7 — blocking sleep reachable from an executor future.

async fn client_loop(h: &Handle) {
    pace(h);
}

fn pace(_h: &Handle) {
    std::thread::sleep(std::time::Duration::from_millis(1)); // deliberate violation
}
