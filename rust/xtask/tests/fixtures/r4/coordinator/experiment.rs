// Fixture: R4 — RNG constructed from a bare literal seed outside
// util/rng.rs.

pub fn adhoc_stream() -> u64 {
    let mut rng = Rng::new(0xDEAD_BEEF); // deliberate violation
    rng.next_u64()
}

pub fn keyed_is_fine(seed: u64, node: u32) -> u64 {
    // Keyed streams and named seeds must NOT trip the rule.
    let mut rng = Rng::new(stream_seed(seed, &[node as u64]));
    rng.next_u64()
}
