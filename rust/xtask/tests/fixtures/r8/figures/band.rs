// Fixture: R8 — ad-hoc float reduction in a digest-sink file; the same
// reduction inside the Welford impl is the blessed accumulator and exempt.

pub fn band_means(w: &Welford, xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64 // deliberate violation
}

pub struct Welford {
    total: f64,
}

impl Welford {
    pub fn merge_sum(&mut self, xs: &[f64]) {
        self.total += xs.iter().sum::<f64>(); // sink impl: allowed
    }
}
