// Fixture: stale-allow — the live suppression below keeps working; the
// one covering removed code must itself be diagnosed.

pub fn digest_step(agg: &mut StepAggregator, xs: &[u32]) -> usize {
    count_kinds(xs)
}

pub fn count_kinds(xs: &[u32]) -> usize {
    // lint-allow(R2): drained scratch map; len() is order-independent
    let mut m = std::collections::HashMap::new();
    for &x in xs {
        m.insert(x, ());
    }
    m.len()
}

pub fn tidy() -> u32 {
    // lint-allow(R3): the Instant this covered was removed in a refactor
    42
}
