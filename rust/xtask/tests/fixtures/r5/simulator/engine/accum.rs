// Fixture: R5 — bare float accumulation in an engine step path, outside
// StepAggregator/Welford.

pub struct Arena {
    total_delay: f64,
    last: u64,
    steps: u64,
}

impl Arena {
    pub fn step_rep(&mut self) {
        self.total_delay += self.last as f64; // deliberate violation
        self.steps += 1; // integer accumulation is fine
    }
}

impl StepAggregator {
    pub fn push_step(&mut self, d: u64) {
        self.area += d as f64; // allowed context: StepAggregator owns fp order
    }
}
