// Fixture: R1 — membership callbacks that consume RNG.
// Not compiled; parsed by the lint only.

pub struct ShufflingPolicy {
    rng: Rng,
    order: Vec<usize>,
    active: Vec<bool>,
}

impl SamplingPolicy for ShufflingPolicy {
    fn observe_join(&mut self, node: usize) {
        self.active[node] = true;
        self.rng.shuffle(&mut self.order); // deliberate violation: draws on the join path
    }

    fn observe_leave(&mut self, node: usize) {
        self.active[node] = false;
        let _ = self.rng.usize_below(self.order.len()); // deliberate violation: leave-path draw
    }
}
