// Fixture: R1 — an observe implementation that consumes RNG.
// Not compiled; parsed by the lint only.

pub struct JitteryPolicy {
    rng: Rng,
    last: f64,
}

impl SamplingPolicy for JitteryPolicy {
    fn observe_completion(&mut self, _node: usize, _delay_steps: u64, _delay_time: f64) {
        self.last = self.rng.uniform(); // deliberate violation: draws in an observe path
    }
}
