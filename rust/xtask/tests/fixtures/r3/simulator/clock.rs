// Fixture: R3 — wall-clock time in a deterministic module.

pub fn stamp_secs() -> f64 {
    let t0 = std::time::Instant::now(); // deliberate violation
    t0.elapsed().as_secs_f64()
}
