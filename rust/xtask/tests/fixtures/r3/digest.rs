// Fixture scaffold: `digest_step` touches the StepAggregator sink, so the
// taint pass pulls everything it (transitively) calls into the digest
// region — including the file under test.

pub fn digest_step(agg: &mut StepAggregator) -> f64 {
    let t = stamp_secs();
    agg.push_step(t);
    t
}
