// Fixture: nested block comments terminate correctly, and a lint-allow on
// the last line of a block comment suppresses the code directly below.

pub fn digest_step(agg: &mut StepAggregator, xs: &[u32]) -> usize {
    /* scratch bookkeeping /* nested: not the end */ continues here;
       lint-allow(R2): drained map; len() is order-independent */
    let mut m = std::collections::HashMap::new();
    m.insert(xs.len(), ());
    m.len()
}
