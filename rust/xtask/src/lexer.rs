//! Minimal Rust lexer for the determinism lint.
//!
//! The container this repo builds in is offline and the crate is
//! dependency-free by design, so the lint cannot pull in `syn`.  The rules
//! in `rules.rs` only need token streams with line numbers, comment text
//! (for `lint-allow` suppressions), and brace structure — a hand-rolled
//! lexer covers that.  It understands line/block comments (nested, with
//! per-line text attribution so multi-line blocks participate in the
//! contiguous-comment suppression walk), doc comments (`///`, `//!`,
//! `/**`, `/*!` — kept in a separate table so prose can *mention*
//! `lint-allow` without minting a suppression), string and raw-string
//! literals, byte strings, char literals vs. lifetimes, and numeric
//! literals with suffixes; everything else is an ident or punct.

use std::collections::{BTreeMap, BTreeSet};

/// Token class.  Puncts are single chars except the compound operators the
/// rules care about (`::`, `+=`, `->`, `=>`), which are fused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    IntLit,
    FloatLit,
    StrLit,
    CharLit,
    Lifetime,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }

    pub fn is_punct(&self, text: &str) -> bool {
        self.is(TokKind::Punct, text)
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.is(TokKind::Ident, text)
    }
}

/// Lexed file: token stream plus the side tables the suppression logic
/// needs (comment text per line, and which lines hold actual code).
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// Concatenated non-doc comment text per 1-based line.  Multi-line
    /// block comments contribute to EVERY line they span, so a
    /// `lint-allow` on the last line of a block sits adjacent to the code
    /// it suppresses.
    pub comments: BTreeMap<u32, String>,
    /// Doc-comment text (`///`, `//!`, `/**`, `/*!`) per line.  Kept apart
    /// from [`Lexed::comments`]: documentation may cite the suppression
    /// syntax without creating one.
    pub doc_comments: BTreeMap<u32, String>,
    /// Lines that contain at least one token (i.e. are not comment/blank).
    pub code_lines: BTreeSet<u32>,
}

pub fn lex(src: &str) -> Lexed {
    let bytes: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let n = bytes.len();

    let push = |out: &mut Lexed, kind: TokKind, text: String, line: u32| {
        out.code_lines.insert(line);
        out.toks.push(Tok { kind, text, line });
    };
    let note = |map: &mut BTreeMap<u32, String>, line: u32, text: &str| {
        let text = text.trim();
        if text.is_empty() {
            // Blank interior lines of a block comment still count as
            // comment lines for the contiguous-suppression walk.
        }
        let slot = map.entry(line).or_default();
        if !slot.is_empty() && !text.is_empty() {
            slot.push(' ');
        }
        slot.push_str(text);
    };

    while i < n {
        let c = bytes[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.  `///` and `//!` are doc comments; `////...` is
        // rustc-normal but we keep it with the docs — it never carries
        // suppressions in this repo.
        if c == '/' && i + 1 < n && bytes[i + 1] == '/' {
            let start = i;
            while i < n && bytes[i] != '\n' {
                i += 1;
            }
            let text: String = bytes[start..i].iter().collect();
            let is_doc = text.starts_with("///") || text.starts_with("//!");
            let map = if is_doc { &mut out.doc_comments } else { &mut out.comments };
            note(map, line, &text);
            continue;
        }
        // Block comment (nested).  `/**` (but not the empty `/**/`) and
        // `/*!` are doc comments.  Text is attributed PER LINE so the
        // suppression logic sees every line the block covers.
        if c == '/' && i + 1 < n && bytes[i + 1] == '*' {
            let is_doc = (i + 2 < n && bytes[i + 2] == '*' && !(i + 3 < n && bytes[i + 3] == '/'))
                || (i + 2 < n && bytes[i + 2] == '!');
            let mut depth = 1usize;
            i += 2;
            let mut buf = String::new();
            while i < n && depth > 0 {
                if bytes[i] == '/' && i + 1 < n && bytes[i + 1] == '*' {
                    depth += 1;
                    buf.push_str("/*");
                    i += 2;
                } else if bytes[i] == '*' && i + 1 < n && bytes[i + 1] == '/' {
                    depth -= 1;
                    if depth > 0 {
                        buf.push_str("*/");
                    }
                    i += 2;
                } else if bytes[i] == '\n' {
                    let map = if is_doc { &mut out.doc_comments } else { &mut out.comments };
                    note(map, line, &buf);
                    buf.clear();
                    line += 1;
                    i += 1;
                } else {
                    buf.push(bytes[i]);
                    i += 1;
                }
            }
            let map = if is_doc { &mut out.doc_comments } else { &mut out.comments };
            note(map, line, &buf);
            continue;
        }
        // String-ish literals, including raw and byte prefixes.
        if c == '"' || starts_string_prefix(&bytes, i) {
            let start_line = line;
            let (end, newlines) = scan_string(&bytes, i);
            line += newlines;
            push(&mut out, TokKind::StrLit, String::from("\"...\""), start_line);
            i = end;
            continue;
        }
        // Char literal vs. lifetime.
        if c == '\'' {
            if is_lifetime(&bytes, i) {
                let mut j = i + 1;
                while j < n && (bytes[j].is_alphanumeric() || bytes[j] == '_') {
                    j += 1;
                }
                let text: String = bytes[i..j].iter().collect();
                push(&mut out, TokKind::Lifetime, text, line);
                i = j;
            } else {
                let mut j = i + 1;
                if j < n && bytes[j] == '\\' {
                    j += 2;
                }
                while j < n && bytes[j] != '\'' {
                    j += 1;
                }
                push(&mut out, TokKind::CharLit, String::from("'.'"), line);
                i = (j + 1).min(n);
            }
            continue;
        }
        // Numeric literal.
        if c.is_ascii_digit() {
            let (end, kind, text) = scan_number(&bytes, i);
            push(&mut out, kind, text, line);
            i = end;
            continue;
        }
        // Ident / keyword (incl. raw idents).
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            if c == 'r' && i + 1 < n && bytes[i + 1] == '#' && i + 2 < n && is_ident_start(bytes[i + 2]) {
                j = i + 2; // raw ident r#type -> lex as `type`
            }
            let start = j;
            while j < n && (bytes[j].is_alphanumeric() || bytes[j] == '_') {
                j += 1;
            }
            let text: String = bytes[start..j].iter().collect();
            push(&mut out, TokKind::Ident, text, line);
            i = j;
            continue;
        }
        // Punct, fusing the compounds the rules look for.
        let two: Option<&str> = if i + 1 < n {
            match (c, bytes[i + 1]) {
                (':', ':') => Some("::"),
                ('+', '=') => Some("+="),
                ('-', '>') => Some("->"),
                ('=', '>') => Some("=>"),
                _ => None,
            }
        } else {
            None
        };
        if let Some(t) = two {
            push(&mut out, TokKind::Punct, t.to_string(), line);
            i += 2;
        } else {
            push(&mut out, TokKind::Punct, c.to_string(), line);
            i += 1;
        }
    }
    out
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

/// True when position `i` starts a string literal via a prefix:
/// `r"`, `r#`, `b"`, `br"`, `br#`.
fn starts_string_prefix(bytes: &[char], i: usize) -> bool {
    let n = bytes.len();
    let at = |k: usize| if i + k < n { bytes[i + k] } else { '\0' };
    match at(0) {
        'r' => at(1) == '"' || (at(1) == '#' && !is_ident_start(at(2))),
        'b' => {
            at(1) == '"'
                || (at(1) == 'r' && (at(2) == '"' || at(2) == '#'))
        }
        _ => false,
    }
}

/// Scan a (possibly raw, possibly byte) string starting at `i`; return the
/// index just past the closing quote and the number of newlines inside.
fn scan_string(bytes: &[char], i: usize) -> (usize, u32) {
    let n = bytes.len();
    let mut j = i;
    // Skip prefix chars (r, b, br).
    while j < n && (bytes[j] == 'r' || bytes[j] == 'b') {
        j += 1;
    }
    let mut hashes = 0usize;
    while j < n && bytes[j] == '#' {
        hashes += 1;
        j += 1;
    }
    let raw = hashes > 0 || (j > i && bytes[i..j].contains(&'r'));
    debug_assert!(j < n && bytes[j] == '"');
    j += 1; // opening quote
    let mut newlines = 0u32;
    while j < n {
        let c = bytes[j];
        if c == '\n' {
            newlines += 1;
            j += 1;
            continue;
        }
        if !raw && c == '\\' {
            j += 2;
            continue;
        }
        if c == '"' {
            if raw {
                // need `hashes` trailing #'s
                let mut k = 0usize;
                while k < hashes && j + 1 + k < n && bytes[j + 1 + k] == '#' {
                    k += 1;
                }
                if k == hashes {
                    return (j + 1 + hashes, newlines);
                }
                j += 1;
                continue;
            }
            return (j + 1, newlines);
        }
        j += 1;
    }
    (n, newlines)
}

/// `'a` (lifetime/label) vs `'a'` (char literal).
fn is_lifetime(bytes: &[char], i: usize) -> bool {
    let n = bytes.len();
    if i + 1 >= n || !is_ident_start(bytes[i + 1]) {
        return false;
    }
    let mut j = i + 1;
    while j < n && (bytes[j].is_alphanumeric() || bytes[j] == '_') {
        j += 1;
    }
    !(j < n && bytes[j] == '\'')
}

/// Scan a numeric literal; classify int vs float (exponent, decimal point,
/// or f32/f64 suffix).
fn scan_number(bytes: &[char], i: usize) -> (usize, TokKind, String) {
    let n = bytes.len();
    let mut j = i;
    let mut float = false;
    let hex = bytes[i] == '0' && i + 1 < n && (bytes[i + 1] == 'x' || bytes[i + 1] == 'X');
    if hex || (bytes[i] == '0' && i + 1 < n && matches!(bytes[i + 1], 'b' | 'o')) {
        j = i + 2;
        while j < n && (bytes[j].is_ascii_alphanumeric() || bytes[j] == '_') {
            j += 1;
        }
        let text: String = bytes[i..j].iter().collect();
        return (j, TokKind::IntLit, text);
    }
    while j < n {
        let c = bytes[j];
        if c.is_ascii_digit() || c == '_' {
            j += 1;
        } else if c == '.' {
            // `1..x` is a range, `1.method()` is a call — only a digit (or
            // end-of-number position) after the dot makes this a float.
            if j + 1 < n && (bytes[j + 1] == '.' || is_ident_start(bytes[j + 1])) {
                break;
            }
            float = true;
            j += 1;
        } else if c == 'e' || c == 'E' {
            if j + 1 < n && (bytes[j + 1].is_ascii_digit() || bytes[j + 1] == '+' || bytes[j + 1] == '-') {
                float = true;
                j += 1;
                if bytes[j] == '+' || bytes[j] == '-' {
                    j += 1;
                }
            } else {
                break;
            }
        } else if c.is_alphanumeric() {
            // suffix: u64, i32, f64, usize, ...
            let start = j;
            let mut k = j;
            while k < n && (bytes[k].is_alphanumeric() || bytes[k] == '_') {
                k += 1;
            }
            let suffix: String = bytes[start..k].iter().collect();
            if suffix.starts_with('f') {
                float = true;
            }
            j = k;
            break;
        } else {
            break;
        }
    }
    let text: String = bytes[i..j].iter().collect();
    let kind = if float { TokKind::FloatLit } else { TokKind::IntLit };
    (j, kind, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuses_compound_puncts() {
        let l = lex("a += b::c;");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["a", "+=", "b", "::", "c", ";"]);
    }

    #[test]
    fn comments_and_code_lines() {
        let l = lex("// lint-allow(R2): demo\nlet x = 1; // trailing\n");
        assert!(l.comments.get(&1).unwrap().contains("lint-allow(R2)"));
        assert!(l.comments.get(&2).unwrap().contains("trailing"));
        assert!(!l.code_lines.contains(&1));
        assert!(l.code_lines.contains(&2));
    }

    #[test]
    fn float_vs_int_vs_range() {
        let l = lex("1.5 0x7AB 2e-3 1..4 7.max(2) 3f64");
        let kinds: Vec<TokKind> = l.toks.iter().map(|t| t.kind).collect();
        assert_eq!(kinds[0], TokKind::FloatLit);
        assert_eq!(kinds[1], TokKind::IntLit);
        assert_eq!(kinds[2], TokKind::FloatLit);
        assert_eq!(kinds[3], TokKind::IntLit); // 1 (then ..)
        assert!(l.toks.iter().any(|t| t.is_ident("max")));
        assert_eq!(kinds.last().copied(), Some(TokKind::FloatLit));
    }

    #[test]
    fn lifetimes_and_chars() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; }");
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Lifetime));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::CharLit));
    }

    #[test]
    fn raw_strings_do_not_leak() {
        let l = lex("let s = r#\"HashMap \" inside\"#; let t = 1;");
        assert!(!l.toks.iter().any(|t| t.is_ident("HashMap")));
        assert!(l.toks.iter().any(|t| t.is_ident("t")));
    }

    #[test]
    fn nested_block_comment_terminates_correctly() {
        // Regression: `/* outer /* inner */ tail */` must consume the
        // whole comment (depth-counted), not resume lexing at the first
        // `*/`.  `tail` and the inner text are comment, not code.
        let l = lex("/* outer /* inner */ tail */ let live = 1;");
        assert!(!l.toks.iter().any(|t| t.is_ident("tail")));
        assert!(!l.toks.iter().any(|t| t.is_ident("inner")));
        assert!(l.toks.iter().any(|t| t.is_ident("live")));
    }

    #[test]
    fn block_comment_text_attributed_per_line() {
        // Regression: text inside a multi-line block comment used to be
        // attributed wholesale to the block's FIRST line, so a
        // `lint-allow` on the last line of the block was invisible to the
        // contiguous-suppression walk.  Every spanned line must now carry
        // its own text and count as a comment line.
        let l = lex("/* one\n   two lint-allow(R5): why\n   three */\nlet x = 1;\n");
        assert!(l.comments.get(&1).unwrap().contains("one"));
        assert!(l.comments.get(&2).unwrap().contains("lint-allow(R5)"));
        assert!(l.comments.get(&3).unwrap().contains("three"));
        assert!(!l.code_lines.contains(&2));
        assert!(l.code_lines.contains(&4));
    }

    #[test]
    fn doc_comments_are_segregated() {
        let src = "//! module doc lint-allow(R2): not a suppression\n/// item doc\n/** block doc */\nfn f() {}\n";
        let l = lex(src);
        assert!(l.comments.is_empty(), "doc text must not land in comments: {:?}", l.comments);
        assert!(l.doc_comments.get(&1).unwrap().contains("lint-allow"));
        assert!(l.doc_comments.contains_key(&2));
        assert!(l.doc_comments.contains_key(&3));
    }

    #[test]
    fn empty_block_comment_is_not_doc() {
        // `/**/` is an empty ordinary comment, not an unterminated doc
        // block.
        let l = lex("/**/ let x = 1;");
        assert!(l.toks.iter().any(|t| t.is_ident("x")));
        assert!(l.doc_comments.is_empty());
    }
}
