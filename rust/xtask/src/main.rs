//! `cargo xtask <command>` — repo-local tooling.
//!
//! Commands:
//!   lint [PATH] [--json FILE] [--allow-report]
//!       Run the determinism lint (R1–R8) over PATH, defaulting to the
//!       fedqueue crate's src/ directory.  `--json FILE` additionally
//!       writes the full machine-readable report (violations, the
//!       lint-allow census, and the digest-region map) to FILE; `-` means
//!       stdout.  `--allow-report` prints the suppression census to
//!       stderr — every `lint-allow` with its reason and whether it still
//!       suppresses anything (stale allows are also hard failures).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => {
            let mut root: Option<PathBuf> = None;
            let mut json_out: Option<String> = None;
            let mut allow_report = false;
            while let Some(arg) = args.next() {
                match arg.as_str() {
                    "--json" => match args.next() {
                        Some(path) => json_out = Some(path),
                        None => {
                            eprintln!("xtask lint: --json requires a file path (or `-`)");
                            return ExitCode::FAILURE;
                        }
                    },
                    "--allow-report" => allow_report = true,
                    other if root.is_none() && !other.starts_with('-') => {
                        root = Some(PathBuf::from(other));
                    }
                    other => {
                        eprintln!("xtask lint: unknown argument `{other}`");
                        return ExitCode::FAILURE;
                    }
                }
            }
            let root = root.unwrap_or_else(default_src);
            if !root.is_dir() {
                eprintln!("xtask lint: no such directory: {}", root.display());
                return ExitCode::FAILURE;
            }
            let report = xtask::lint_report(&root);
            for v in &report.violations {
                println!("{v}");
            }
            if let Some(path) = json_out {
                let rendered = xtask::render_json(&report);
                if path == "-" {
                    print!("{rendered}");
                } else if let Err(e) = std::fs::write(&path, rendered) {
                    eprintln!("xtask lint: cannot write {path}: {e}");
                    return ExitCode::FAILURE;
                }
            }
            if allow_report {
                eprintln!(
                    "xtask lint: {} lint-allow site(s) across {} file(s):",
                    report.allows.len(),
                    report.files_linted
                );
                for a in &report.allows {
                    eprintln!(
                        "  {}:{}: lint-allow({}) [{}] — {}",
                        a.file,
                        a.line,
                        a.rule,
                        if a.used { "used" } else { "STALE" },
                        a.reason
                    );
                }
            }
            if report.violations.is_empty() {
                eprintln!("xtask lint: clean ({})", root.display());
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "xtask lint: {} violation(s); suppress a justified site with \
                     `// lint-allow(<rule>): <reason>`",
                    report.violations.len()
                );
                ExitCode::FAILURE
            }
        }
        Some(other) => {
            eprintln!("xtask: unknown command `{other}` (try: lint)");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint [PATH] [--json FILE] [--allow-report]");
            ExitCode::FAILURE
        }
    }
}

/// The fedqueue `src/` directory, located relative to this crate so the
/// command works from any working directory.
fn default_src() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src")
}
