//! `cargo xtask <command>` — repo-local tooling.
//!
//! Commands:
//!   lint [PATH]   run the determinism lint (R1–R5) over PATH, defaulting
//!                 to the fedqueue crate's src/ directory.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => {
            let root = args.next().map(PathBuf::from).unwrap_or_else(default_src);
            if !root.is_dir() {
                eprintln!("xtask lint: no such directory: {}", root.display());
                return ExitCode::FAILURE;
            }
            let violations = xtask::lint_root(&root);
            for v in &violations {
                println!("{v}");
            }
            if violations.is_empty() {
                eprintln!("xtask lint: clean ({})", root.display());
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "xtask lint: {} violation(s); suppress a justified site with \
                     `// lint-allow(<rule>): <reason>`",
                    violations.len()
                );
                ExitCode::FAILURE
            }
        }
        Some(other) => {
            eprintln!("xtask: unknown command `{other}` (try: lint)");
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint [PATH]");
            ExitCode::FAILURE
        }
    }
}

/// The fedqueue `src/` directory, located relative to this crate so the
/// command works from any working directory.
fn default_src() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src")
}
