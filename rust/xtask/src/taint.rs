//! Crate-wide nondeterminism taint analysis.
//!
//! The determinism contract used to be scoped by a hand-curated module
//! list (`DETERMINISTIC_MODULES`) that every new subsystem had to remember
//! to join.  This pass derives the scope from the code instead:
//!
//! * **Sinks** are where bit-identity is asserted: `to_json_deterministic`
//!   (the cross-engine comparison payload) and the `StepAggregator` /
//!   `Welford` accumulators whose summation order IS the contract.
//! * **Seed functions** touch a sink directly — their signature or body
//!   mentions a sink type, or they call `to_json_deterministic`.
//! * The **digest region** is the forward closure of the seeds over the
//!   crate call graph: everything a seed function (transitively) calls can
//!   feed values into a digest, so nondeterminism *sources* (`Instant`,
//!   `HashMap` iteration, `thread_rng`, env reads, ...) anywhere in the
//!   region are violations (R2/R3) unless suppressed with a reason.
//!
//! Closure edges are name-matched (qualified `Type::method` calls narrow
//! to impls of `Type` when any exist) and filtered through a stoplist of
//! ubiquitous method names (`new`, `push`, `get`, ...) that would
//! otherwise glue every file to every other via accidental name collision.
//! The stoplist applies to REGION GROWTH only — R1's observe-path walk
//! keeps full edges, because a false edge there costs a written reason
//! while a missed edge costs a corrupted digest hours later.
//!
//! Region membership is tracked at FILE granularity: one tainted function
//! taints its whole file (minus `#[cfg(test)]` ranges).  Functions share
//! file-local state too freely for per-fn scoping to be sound, and the
//! coarser grain keeps diagnostics stable under refactors.
//!
//! The same machinery also computes the **R7 region**: the forward
//! closure of every `async fn` / future `poll` implementation, i.e. the
//! code that runs on the virtual-clock executor and must never block on
//! the wall clock or the OS.  R7 is tracked at FUNCTION granularity — a
//! file may legitimately host both a blocking CLI entry point and
//! executor-driven futures.

use std::collections::{BTreeMap, BTreeSet};

use crate::model::FileModel;

/// One parsed source file plus its root-relative path.
pub struct FileEntry {
    pub rel: String,
    pub model: FileModel,
}

/// Ubiquitous method names excluded from region-growth edges.  Every name
/// here is defined by many unrelated types; following it would merge the
/// whole crate into one region through accidental collisions (`Vec::push`
/// vs `StepAggregator::push`).  Deliberately NOT on the list: `run` —
/// in this crate `run` methods are exactly the report-producing surfaces
/// (engine run, driver run, serve run), so those edges are load-bearing.
const STOPLIST: &[&str] = &[
    "abs", "as_ref", "as_str", "build", "ceil", "clamp", "clear", "clone", "cmp", "collect",
    "contains", "default", "drop", "eq", "exp", "expect", "extend", "filter", "floor", "flush",
    "fmt", "fold", "from", "get", "hash", "insert", "into", "is_empty", "iter", "len", "ln",
    "map", "max", "min", "name", "new", "next", "parse", "pop", "powf", "powi", "push", "read",
    "remove", "set", "sqrt", "sum", "to_string", "unwrap", "write",
];

/// Identifiers that mark a function as sink-adjacent when they appear in
/// its signature or body.
const SINK_IDENTS: &[&str] = &["StepAggregator", "Welford"];

/// The call-by-name sink: serializing the deterministic comparison
/// payload.
const SINK_CALL: &str = "to_json_deterministic";

/// Output of the taint pass.
#[derive(Debug, Default)]
pub struct TaintAnalysis {
    /// Files in the digest region: rel path -> witness chain (seed fn
    /// first, `->`-separated) explaining WHY the file is in scope.
    pub digest_files: BTreeMap<String, String>,
    /// Files containing a seed function (direct sink contact).  R8's
    /// float-reduction scan runs here: a reduction in the same file as a
    /// digest sink can plausibly flow into it, while reductions further
    /// up the closure are per-node model math.
    pub seed_files: BTreeSet<String>,
    /// Function-granular R7 region: (file index, fn index, witness chain)
    /// for every fn reachable from an executor future.
    pub executor_fns: Vec<(usize, usize, String)>,
}

/// Global fn identity: (file index, fn index).
type FnId = (usize, usize);

struct Graph<'a> {
    files: &'a [FileEntry],
    by_name: BTreeMap<&'a str, Vec<FnId>>,
}

impl<'a> Graph<'a> {
    fn build(files: &'a [FileEntry]) -> Self {
        let mut by_name: BTreeMap<&str, Vec<FnId>> = BTreeMap::new();
        for (fi, f) in files.iter().enumerate() {
            for (di, d) in f.model.fns.iter().enumerate() {
                if !f.model.in_test(d.line) {
                    by_name.entry(d.name.as_str()).or_default().push((fi, di));
                }
            }
        }
        Graph { files, by_name }
    }

    /// Candidate definitions for one call site, honoring the stoplist and
    /// narrowing `Type::method` calls to impls of `Type` when possible.
    fn targets(&self, call: &crate::model::Call) -> Vec<FnId> {
        if STOPLIST.contains(&call.name.as_str()) {
            return Vec::new();
        }
        let Some(cands) = self.by_name.get(call.name.as_str()) else {
            return Vec::new();
        };
        if let Some(q) = &call.qualifier {
            let narrowed: Vec<FnId> = cands
                .iter()
                .copied()
                .filter(|&(fi, di)| {
                    self.files[fi].model.fns[di].impl_target.as_deref() == Some(q.as_str())
                })
                .collect();
            if !narrowed.is_empty() {
                return narrowed;
            }
        }
        cands.clone()
    }

    /// Forward closure from `roots`, returning fn -> witness chain.
    fn closure(&self, roots: &[FnId]) -> BTreeMap<FnId, String> {
        let mut via: BTreeMap<FnId, String> = BTreeMap::new();
        let mut work: Vec<FnId> = Vec::new();
        for &r in roots {
            let (fi, di) = r;
            via.entry(r)
                .or_insert_with(|| self.files[fi].model.fns[di].name.clone());
            work.push(r);
        }
        while let Some(id) = work.pop() {
            let chain = via[&id].clone();
            let (fi, di) = id;
            for call in &self.files[fi].model.fns[di].calls {
                for tgt in self.targets(call) {
                    if !via.contains_key(&tgt) {
                        via.insert(tgt, format!("{chain} -> {}", call.name));
                        work.push(tgt);
                    }
                }
            }
        }
        via
    }
}

/// Run the taint pass over the whole file set.
pub fn analyze(files: &[FileEntry]) -> TaintAnalysis {
    let graph = Graph::build(files);

    // Seeds: non-test fns in direct contact with a sink.
    let mut seeds: Vec<FnId> = Vec::new();
    let mut seed_files: BTreeSet<String> = BTreeSet::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.model.fns.iter().enumerate() {
            if f.model.in_test(d.line) {
                continue;
            }
            let touches_sink = SINK_IDENTS.iter().any(|s| f.model.fn_mentions(d, s))
                || d.calls.iter().any(|c| c.name == SINK_CALL);
            if touches_sink {
                seeds.push((fi, di));
                seed_files.insert(f.rel.clone());
            }
        }
    }

    let region = graph.closure(&seeds);
    let mut digest_files: BTreeMap<String, String> = BTreeMap::new();
    for (&(fi, _), chain) in &region {
        digest_files
            .entry(files[fi].rel.clone())
            .and_modify(|existing| {
                // Prefer the shortest witness for readability.
                if chain.len() < existing.len() {
                    *existing = chain.clone();
                }
            })
            .or_insert_with(|| chain.clone());
    }

    // R7 roots: async fns (incl. fns spawning async blocks) and future
    // poll implementations.
    let mut r7_roots: Vec<FnId> = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.model.fns.iter().enumerate() {
            if f.model.in_test(d.line) {
                continue;
            }
            if d.is_async || (d.name == "poll" && f.model.sig_mentions(d, "Context")) {
                r7_roots.push((fi, di));
            }
        }
    }
    let r7 = graph.closure(&r7_roots);
    let executor_fns = r7
        .into_iter()
        .map(|((fi, di), chain)| (fi, di, chain))
        .collect();

    TaintAnalysis {
        digest_files,
        seed_files,
        executor_fns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FileModel;

    fn entry(rel: &str, src: &str) -> FileEntry {
        FileEntry {
            rel: rel.to_string(),
            model: FileModel::parse(src),
        }
    }

    #[test]
    fn region_crosses_files_from_sink_seed() {
        let files = vec![
            entry(
                "digest.rs",
                "pub fn collect_digest(agg: &StepAggregator) -> f64 { tally_all(agg) }\n",
            ),
            entry("state.rs", "pub fn tally_all(agg: &Agg) -> f64 { helper_sum(agg) }\n"),
            entry("free.rs", "pub fn unrelated() {}\n"),
        ];
        let t = analyze(&files);
        assert!(t.digest_files.contains_key("digest.rs"));
        assert!(t.digest_files.contains_key("state.rs"), "{:?}", t.digest_files);
        assert!(!t.digest_files.contains_key("free.rs"));
        assert!(t.seed_files.contains("digest.rs"));
        assert!(!t.seed_files.contains("state.rs"));
        assert!(t.digest_files["state.rs"].starts_with("collect_digest"));
    }

    #[test]
    fn stoplist_blocks_collision_edges() {
        let files = vec![
            entry("digest.rs", "pub fn report(w: &Welford) { acc.push(1.0); }\n"),
            entry("bench.rs", "pub fn push(x: f64) { wall_clock_things(); }\n"),
        ];
        let t = analyze(&files);
        assert!(!t.digest_files.contains_key("bench.rs"), "{:?}", t.digest_files);
    }

    #[test]
    fn qualified_calls_narrow_to_impl() {
        let files = vec![
            entry("digest.rs", "pub fn report(w: &Welford) { Exact::emit_rows(w); }\n"),
            entry(
                "exact.rs",
                "impl Exact {\n    pub fn emit_rows(w: &W) {}\n}\nimpl Other {\n    pub fn emit_rows(w: &W) { never_here(); }\n}\n",
            ),
        ];
        let t = analyze(&files);
        // Both impls live in exact.rs so the file lands in region either
        // way; the narrowing is visible in the witness chain count — no
        // panic means the filter path ran.
        assert!(t.digest_files.contains_key("exact.rs"));
    }

    #[test]
    fn r7_region_covers_async_callees() {
        let files = vec![entry(
            "serve.rs",
            "async fn client_loop() { tick_once(); }\nfn tick_once() {}\nfn not_async() {}\n",
        )];
        let t = analyze(&files);
        let names: Vec<&str> = t
            .executor_fns
            .iter()
            .map(|&(fi, di, _)| files[fi].model.fns[di].name.as_str())
            .collect();
        assert!(names.contains(&"client_loop"));
        assert!(names.contains(&"tick_once"));
        assert!(!names.contains(&"not_async"));
    }
}
