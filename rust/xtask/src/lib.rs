//! Repo-local developer tooling for the fedqueue crate.
//!
//! The only subcommand today is `lint`: a dependency-free static-analysis
//! pass (the build container is offline, so no `syn`) that enforces the
//! determinism contract as rules R1–R5.  See [`rules`] for the rule
//! definitions and the `lint-allow` suppression syntax, and
//! docs/ARCHITECTURE.md "Determinism contract" for the rationale.

pub mod lexer;
pub mod model;
pub mod rules;

pub use rules::{lint_root, Rule, Violation};
