//! Repo-local developer tooling for the fedqueue crate.
//!
//! The only subcommand today is `lint`: a dependency-free static-analysis
//! pass (the build container is offline, so no `syn`) that enforces the
//! determinism contract as rules R1–R8.  The scope of the digest rules
//! (R2/R3) is computed by a crate-wide taint pass ([`taint`]) rather than
//! a hand-curated module list.  See [`rules`] for the rule definitions,
//! the `lint-allow` suppression syntax, and the stale-suppression audit;
//! docs/LINTS.md for the user-facing catalogue; and docs/ARCHITECTURE.md
//! "Determinism contract" for the rationale.

pub mod lexer;
pub mod model;
pub mod rules;
pub mod taint;

pub use rules::{lint_report, lint_root, render_json, AllowRecord, LintReport, Rule, Violation};
