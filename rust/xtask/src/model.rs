//! Token-level structural model of one Rust file.
//!
//! Built on top of [`crate::lexer`], this extracts just enough structure
//! for the determinism rules: which line ranges are `#[cfg(test)]` (and
//! `#[test]`) code, which line ranges belong to which `impl` target, and a
//! table of function definitions with the names they call (the module-level
//! call graph R1 walks).  It is deliberately conservative: names are
//! matched without path resolution, so an edge `a -> b` exists whenever
//! some function named `b` is called from `a`'s body.  That over-
//! approximates reachability, which is the correct direction for a
//! determinism lint — false negatives corrupt digests, false positives
//! cost a `lint-allow` with a written reason.

use crate::lexer::{lex, Lexed, Tok, TokKind};

/// Inclusive line range.
#[derive(Clone, Copy, Debug)]
pub struct LineRange {
    pub start: u32,
    pub end: u32,
}

impl LineRange {
    pub fn contains(&self, line: u32) -> bool {
        self.start <= line && line <= self.end
    }
}

/// An `impl` block and the (unqualified) name of its self type.
#[derive(Clone, Debug)]
pub struct ImplBlock {
    pub target: String,
    pub range: LineRange,
}

/// One `fn` definition: name, where it lives, whether its signature
/// mentions `Rng`, and every name it calls (with call-site lines).
#[derive(Clone, Debug)]
pub struct FnDef {
    pub name: String,
    pub line: u32,
    pub range: LineRange,
    pub sig_has_rng: bool,
    pub calls: Vec<(String, u32)>,
}

/// Parsed file model.
#[derive(Debug, Default)]
pub struct FileModel {
    pub lexed: Lexed,
    /// Line ranges under `#[cfg(test)] mod`, `#[cfg(all(loom, test))]
    /// mod`, or `#[test] fn` — excluded from every rule.
    pub test_ranges: Vec<LineRange>,
    pub impls: Vec<ImplBlock>,
    pub fns: Vec<FnDef>,
}

impl FileModel {
    pub fn parse(src: &str) -> Self {
        let lexed = lex(src);
        let mut model = FileModel {
            test_ranges: Vec::new(),
            impls: Vec::new(),
            fns: Vec::new(),
            lexed,
        };
        model.scan();
        model
    }

    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|r| r.contains(line))
    }

    /// Name of the innermost `impl` target covering `line`, if any.
    pub fn impl_target_at(&self, line: u32) -> Option<&str> {
        self.impls
            .iter()
            .filter(|b| b.range.contains(line))
            .min_by_key(|b| b.range.end - b.range.start)
            .map(|b| b.target.as_str())
    }

    fn scan(&mut self) {
        let toks = &self.lexed.toks;
        let n = toks.len();
        let mut i = 0usize;
        // `true` after an attribute list mentioning `test` or `loom`, until
        // the next item keyword consumes it.
        let mut pending_test_attr = false;
        while i < n {
            let t = &toks[i];
            if t.is_punct("#") && i + 1 < n && toks[i + 1].is_punct("[") {
                let close = match_bracket(toks, i + 1, "[", "]");
                // `#[test]`, `#[cfg(test)]`, `#[cfg(all(loom, test))]` all
                // contain the bare ident `test`; `#[cfg(not(loom))]` does
                // not, so non-loom production code stays linted.
                let has_test = toks[i + 1..close].iter().any(|t| t.is_ident("test"));
                pending_test_attr = pending_test_attr || has_test;
                i = close + 1;
                continue;
            }
            if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "mod" => {
                        if let Some(body) = item_body(toks, i) {
                            if pending_test_attr {
                                self.test_ranges.push(body.lines);
                            }
                            // Recurse into the module body by just
                            // continuing the linear scan: nested items are
                            // picked up naturally.
                        }
                        pending_test_attr = false;
                        i += 1;
                        continue;
                    }
                    "impl" => {
                        // `-> impl Trait` / `: impl Trait` is a type
                        // position, not an item; only item-position `impl`
                        // opens a block.
                        let type_position = i > 0
                            && matches!(
                                toks[i - 1].text.as_str(),
                                "->" | ":" | "(" | "," | "=" | "<" | "+" | "&"
                            );
                        if !type_position {
                            if let Some((target, body)) = impl_header(toks, i) {
                                self.impls.push(ImplBlock {
                                    target,
                                    range: body.lines,
                                });
                            }
                        }
                        pending_test_attr = false;
                        i += 1;
                        continue;
                    }
                    "fn" => {
                        if let Some(def) = fn_def(toks, i) {
                            if pending_test_attr {
                                self.test_ranges.push(def.range);
                            }
                            self.fns.push(def);
                        }
                        pending_test_attr = false;
                        i += 1;
                        continue;
                    }
                    "struct" | "enum" | "trait" | "use" | "static" | "const" | "type" => {
                        pending_test_attr = false;
                    }
                    _ => {}
                }
            }
            i += 1;
        }
    }
}

struct Body {
    lines: LineRange,
}

/// Index of the punct matching the opener at `open_idx` (which must hold
/// `open`).  Returns the last token index on unbalanced input.
fn match_bracket(toks: &[Tok], open_idx: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    let mut i = open_idx;
    while i < toks.len() {
        if toks[i].is_punct(open) {
            depth += 1;
        } else if toks[i].is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// For an item keyword at `kw` (`mod`), find the `{ ... }` body if the item
/// has one (`mod x;` has none).
fn item_body(toks: &[Tok], kw: usize) -> Option<Body> {
    let mut i = kw + 1;
    while i < toks.len() {
        if toks[i].is_punct(";") {
            return None;
        }
        if toks[i].is_punct("{") {
            let close = match_bracket(toks, i, "{", "}");
            return Some(Body {
                lines: LineRange {
                    start: toks[i].line,
                    end: toks[close].line,
                },
            });
        }
        i += 1;
    }
    None
}

/// Parse an `impl` header starting at the `impl` keyword: returns the
/// unqualified self-type name and the body range.  Handles
/// `impl<G> Type<G>`, `impl Trait for Type`, and `impl<G> Trait for
/// Type<G>`.
fn impl_header(toks: &[Tok], kw: usize) -> Option<(String, Body)> {
    let n = toks.len();
    let mut i = kw + 1;
    // Skip generic parameter list.
    if i < n && toks[i].is_punct("<") {
        let mut depth = 0i32;
        while i < n {
            if toks[i].is_punct("<") {
                depth += 1;
            } else if toks[i].is_punct(">") {
                depth -= 1;
                if depth == 0 {
                    i += 1;
                    break;
                }
            }
            i += 1;
        }
    }
    // First path; if followed by `for`, the self type is the next path.
    let mut target = first_path_ident(toks, &mut i)?;
    skip_generic_args(toks, &mut i);
    if i < n && toks[i].is_ident("for") {
        i += 1;
        // Skip `&`, lifetimes, `mut`, `dyn`.
        while i < n
            && (toks[i].is_punct("&")
                || toks[i].kind == TokKind::Lifetime
                || toks[i].is_ident("mut")
                || toks[i].is_ident("dyn"))
        {
            i += 1;
        }
        target = first_path_ident(toks, &mut i)?;
        skip_generic_args(toks, &mut i);
    }
    // Find the body `{`.
    while i < n && !toks[i].is_punct("{") {
        if toks[i].is_punct(";") {
            return None;
        }
        i += 1;
    }
    if i >= n {
        return None;
    }
    let close = match_bracket(toks, i, "{", "}");
    Some((
        target,
        Body {
            lines: LineRange {
                start: toks[i].line,
                end: toks[close].line,
            },
        },
    ))
}

/// Read `seg(::seg)*` at `*i`; return the LAST segment (the type name for
/// a qualified path like `util::stats::Welford`) and advance past it.
fn first_path_ident(toks: &[Tok], i: &mut usize) -> Option<String> {
    let n = toks.len();
    let mut last: Option<String> = None;
    loop {
        if *i < n && toks[*i].kind == TokKind::Ident {
            last = Some(toks[*i].text.clone());
            *i += 1;
            if *i < n && toks[*i].is_punct("::") {
                *i += 1;
                continue;
            }
        }
        break;
    }
    last
}

/// Skip a `<...>` generic-argument list at `*i`, if present.
fn skip_generic_args(toks: &[Tok], i: &mut usize) {
    let n = toks.len();
    if *i < n && toks[*i].is_punct("<") {
        let mut depth = 0i32;
        while *i < n {
            if toks[*i].is_punct("<") {
                depth += 1;
            } else if toks[*i].is_punct(">") {
                depth -= 1;
                if depth == 0 {
                    *i += 1;
                    return;
                }
            }
            *i += 1;
        }
    }
}

const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "let", "fn", "in", "as", "move",
    "mut", "ref", "break", "continue", "unsafe", "where", "impl", "dyn", "Self", "self", "super",
    "crate", "pub", "use", "mod", "struct", "enum", "trait", "type", "const", "static",
];

/// Parse a `fn` definition starting at the `fn` keyword.
fn fn_def(toks: &[Tok], kw: usize) -> Option<FnDef> {
    let n = toks.len();
    let name_idx = kw + 1;
    if name_idx >= n || toks[name_idx].kind != TokKind::Ident {
        return None;
    }
    let name = toks[name_idx].text.clone();
    let line = toks[name_idx].line;
    // Parameter list.
    let mut i = name_idx + 1;
    if i < n && toks[i].is_punct("<") {
        skip_generic_args(toks, &mut i);
    }
    if i >= n || !toks[i].is_punct("(") {
        return None;
    }
    let params_close = match_bracket(toks, i, "(", ")");
    let sig_has_rng = toks[i..params_close].iter().any(|t| t.is_ident("Rng"));
    // Find body `{` or trait-decl `;`.
    let mut j = params_close + 1;
    let mut brace = None;
    while j < n {
        if toks[j].is_punct(";") {
            break;
        }
        if toks[j].is_punct("{") {
            brace = Some(j);
            break;
        }
        j += 1;
    }
    let (range, calls) = match brace {
        Some(open) => {
            let close = match_bracket(toks, open, "{", "}");
            (
                LineRange {
                    start: line,
                    end: toks[close].line,
                },
                collect_calls(&toks[open..=close.min(n - 1)]),
            )
        }
        None => (LineRange { start: line, end: line }, Vec::new()),
    };
    Some(FnDef {
        name,
        line,
        range,
        sig_has_rng,
        calls,
    })
}

/// Every `name(` or `.name(` in a body slice, excluding macro invocations
/// (`name!(...)`) and keywords.
fn collect_calls(body: &[Tok]) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let n = body.len();
    for i in 0..n {
        if body[i].kind != TokKind::Ident {
            continue;
        }
        let name = body[i].text.as_str();
        if KEYWORDS.contains(&name) {
            continue;
        }
        // `fn name(` is a nested definition, not a call.
        if i > 0 && body[i - 1].is_ident("fn") {
            continue;
        }
        if i + 1 < n && body[i + 1].is_punct("(") {
            out.push((name.to_string(), body[i].line));
        } else if i + 1 < n && body[i + 1].is_punct("!") {
            // macro — skip
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_test_mod_range() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() { rng.uniform(); }\n}\n";
        let m = FileModel::parse(src);
        assert!(!m.in_test(1));
        assert!(m.in_test(4));
    }

    #[test]
    fn impl_targets() {
        let src = "impl StepAggregator {\n    fn push(&mut self) { self.area += 1.0; }\n}\nimpl<D: Drv> Core<D> {\n    fn go(&self) {}\n}\nimpl Policy for Fixed {\n    fn observe(&mut self) {}\n}\n";
        let m = FileModel::parse(src);
        assert_eq!(m.impl_target_at(2), Some("StepAggregator"));
        assert_eq!(m.impl_target_at(5), Some("Core"));
        assert_eq!(m.impl_target_at(8), Some("Fixed"));
    }

    #[test]
    fn fn_calls_and_rng_sig() {
        let src = "fn draw(rng: &mut Rng) -> f64 { rng.uniform() }\nfn outer() { let v = draw(&mut r); helper_macro!(x); }\n";
        let m = FileModel::parse(src);
        let draw = m.fns.iter().find(|f| f.name == "draw").unwrap();
        assert!(draw.sig_has_rng);
        assert!(draw.calls.iter().any(|(c, _)| c == "uniform"));
        let outer = m.fns.iter().find(|f| f.name == "outer").unwrap();
        assert!(outer.calls.iter().any(|(c, _)| c == "draw"));
        assert!(!outer.calls.iter().any(|(c, _)| c == "helper_macro"));
    }

    #[test]
    fn trait_decl_without_body() {
        let src = "trait P {\n    fn observe(&mut self, lens: &[u32]) {}\n    fn route(&self, rng: &mut Rng) -> usize;\n}\n";
        let m = FileModel::parse(src);
        let route = m.fns.iter().find(|f| f.name == "route").unwrap();
        assert!(route.sig_has_rng);
        assert!(route.calls.is_empty());
    }
}
