//! Token-level structural model of one Rust file.
//!
//! Built on top of [`crate::lexer`], this extracts just enough structure
//! for the determinism rules: which line ranges are `#[cfg(test)]` (and
//! `#[test]`) code, which line ranges belong to which `impl` target, a
//! table of function definitions with the names they call (the crate-level
//! call graph R1 and the taint pass walk), `use` aliases (so
//! `use std::collections::HashMap as Map` still reads as a source), and
//! the crate's named `*_STREAM` constants (R6 collision audit).  It is
//! deliberately conservative: names are matched without full path
//! resolution, so an edge `a -> b` exists whenever some function named `b`
//! is called from `a`'s body — qualified calls (`Type::b(..)`) narrow the
//! candidates to impls of `Type` when any exist.  That over-approximates
//! reachability, which is the correct direction for a determinism lint —
//! false negatives corrupt digests, false positives cost a `lint-allow`
//! with a written reason.

use std::collections::BTreeMap;

use crate::lexer::{lex, Lexed, Tok, TokKind};

/// Inclusive line range.
#[derive(Clone, Copy, Debug)]
pub struct LineRange {
    pub start: u32,
    pub end: u32,
}

impl LineRange {
    pub fn contains(&self, line: u32) -> bool {
        self.start <= line && line <= self.end
    }
}

/// An `impl` block and the (unqualified) name of its self type.
#[derive(Clone, Debug)]
pub struct ImplBlock {
    pub target: String,
    pub range: LineRange,
}

/// One call site inside a function body.  `qualifier` is set for
/// `Type::name(..)` paths — the taint pass uses it to narrow candidate
/// definitions to impls of `Type`; plain `name(..)` and `.name(..)` calls
/// stay name-only.
#[derive(Clone, Debug)]
pub struct Call {
    pub name: String,
    pub qualifier: Option<String>,
    pub line: u32,
}

/// One `fn` definition: name, where it lives, whether its signature
/// mentions `Rng`, every name it calls (with call-site lines), whether it
/// is `async` (or spawns an `async` block), which impl it sits in, and its
/// token spans so rules can scan the signature/body directly.
#[derive(Clone, Debug)]
pub struct FnDef {
    pub name: String,
    pub line: u32,
    pub range: LineRange,
    pub sig_has_rng: bool,
    pub is_async: bool,
    /// Self type of the enclosing `impl`, if any (filled post-scan).
    pub impl_target: Option<String>,
    pub calls: Vec<Call>,
    /// Token index of the `fn` keyword (signature start).
    pub tok_sig: usize,
    /// Token index range of the body `{ ... }`, inclusive; `None` for
    /// bodiless trait declarations.
    pub tok_body: Option<(usize, usize)>,
}

/// A `const NAME_STREAM: u64 = <value>;` item — the named stream keys the
/// R6 collision audit compares crate-wide.
#[derive(Clone, Debug)]
pub struct StreamConst {
    pub name: String,
    /// Parsed literal value when the initializer is a single integer
    /// literal; `None` for computed initializers (still collision-checked
    /// by name only).
    pub value: Option<u64>,
    pub line: u32,
}

/// Parsed file model.
#[derive(Debug, Default)]
pub struct FileModel {
    pub lexed: Lexed,
    /// Line ranges under `#[cfg(test)] mod`, `#[cfg(all(loom, test))]
    /// mod`, or `#[test] fn` — excluded from every rule.
    pub test_ranges: Vec<LineRange>,
    pub impls: Vec<ImplBlock>,
    pub fns: Vec<FnDef>,
    /// `use .. as alias` map: alias -> canonical (last path segment).
    pub use_aliases: BTreeMap<String, String>,
    /// Named `*_STREAM` constants defined in this file.
    pub stream_consts: Vec<StreamConst>,
}

impl FileModel {
    pub fn parse(src: &str) -> Self {
        let lexed = lex(src);
        let mut model = FileModel {
            test_ranges: Vec::new(),
            impls: Vec::new(),
            fns: Vec::new(),
            use_aliases: BTreeMap::new(),
            stream_consts: Vec::new(),
            lexed,
        };
        model.scan();
        // Attribute each fn to its innermost enclosing impl; impl blocks
        // are only complete once the scan has finished.
        let targets: Vec<Option<String>> = model
            .fns
            .iter()
            .map(|f| model.impl_target_at(f.line).map(str::to_string))
            .collect();
        for (f, t) in model.fns.iter_mut().zip(targets) {
            f.impl_target = t;
        }
        model
    }

    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|r| r.contains(line))
    }

    /// Name of the innermost `impl` target covering `line`, if any.
    pub fn impl_target_at(&self, line: u32) -> Option<&str> {
        self.impls
            .iter()
            .filter(|b| b.range.contains(line))
            .min_by_key(|b| b.range.end - b.range.start)
            .map(|b| b.target.as_str())
    }

    /// Resolve an identifier through this file's `use .. as ..` aliases.
    pub fn resolve<'a>(&'a self, name: &'a str) -> &'a str {
        self.use_aliases.get(name).map(String::as_str).unwrap_or(name)
    }

    /// Does `f`'s signature-or-body mention `name` as a bare identifier?
    pub fn fn_mentions(&self, f: &FnDef, name: &str) -> bool {
        let end = f.tok_body.map(|(_, e)| e).unwrap_or(f.tok_sig);
        self.lexed.toks[f.tok_sig..=end.min(self.lexed.toks.len() - 1)]
            .iter()
            .any(|t| t.is_ident(name))
    }

    /// Does `f`'s signature (up to the body `{` / trailing `;`) mention
    /// `name`?
    pub fn sig_mentions(&self, f: &FnDef, name: &str) -> bool {
        let end = f.tok_body.map(|(o, _)| o).unwrap_or(self.lexed.toks.len());
        self.lexed.toks[f.tok_sig..end.min(self.lexed.toks.len())]
            .iter()
            .any(|t| t.is_ident(name))
    }

    fn scan(&mut self) {
        let toks = &self.lexed.toks;
        let n = toks.len();
        let mut i = 0usize;
        // `true` after an attribute list mentioning `test` or `loom`, until
        // the next item keyword consumes it.
        let mut pending_test_attr = false;
        let mut test_ranges = Vec::new();
        let mut impls = Vec::new();
        let mut fns = Vec::new();
        let mut use_aliases = BTreeMap::new();
        let mut stream_consts = Vec::new();
        while i < n {
            let t = &toks[i];
            if t.is_punct("#") && i + 1 < n && toks[i + 1].is_punct("[") {
                let close = match_bracket(toks, i + 1, "[", "]");
                // `#[test]`, `#[cfg(test)]`, `#[cfg(all(loom, test))]` all
                // contain the bare ident `test`; `#[cfg(not(loom))]` does
                // not, so non-loom production code stays linted.
                let has_test = toks[i + 1..close].iter().any(|t| t.is_ident("test"));
                pending_test_attr = pending_test_attr || has_test;
                i = close + 1;
                continue;
            }
            if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "mod" => {
                        if let Some(body) = item_body(toks, i) {
                            if pending_test_attr {
                                test_ranges.push(body.lines);
                            }
                            // Recurse into the module body by just
                            // continuing the linear scan: nested items are
                            // picked up naturally.
                        }
                        pending_test_attr = false;
                        i += 1;
                        continue;
                    }
                    "impl" => {
                        // `-> impl Trait` / `: impl Trait` is a type
                        // position, not an item; only item-position `impl`
                        // opens a block.
                        let type_position = i > 0
                            && matches!(
                                toks[i - 1].text.as_str(),
                                "->" | ":" | "(" | "," | "=" | "<" | "+" | "&"
                            );
                        if !type_position {
                            if let Some((target, body)) = impl_header(toks, i) {
                                impls.push(ImplBlock {
                                    target,
                                    range: body.lines,
                                });
                            }
                        }
                        pending_test_attr = false;
                        i += 1;
                        continue;
                    }
                    "fn" => {
                        if let Some(def) = fn_def(toks, i) {
                            if pending_test_attr {
                                test_ranges.push(def.range);
                            }
                            fns.push(def);
                        }
                        pending_test_attr = false;
                        i += 1;
                        continue;
                    }
                    "use" => {
                        collect_use_aliases(toks, i, &mut use_aliases);
                        pending_test_attr = false;
                    }
                    "const" => {
                        if let Some(sc) = stream_const(toks, i) {
                            stream_consts.push(sc);
                        }
                        pending_test_attr = false;
                    }
                    "struct" | "enum" | "trait" | "static" | "type" => {
                        pending_test_attr = false;
                    }
                    _ => {}
                }
            }
            i += 1;
        }
        self.test_ranges = test_ranges;
        self.impls = impls;
        self.fns = fns;
        self.use_aliases = use_aliases;
        self.stream_consts = stream_consts;
    }
}

struct Body {
    lines: LineRange,
}

/// Index of the punct matching the opener at `open_idx` (which must hold
/// `open`).  Returns the last token index on unbalanced input.
fn match_bracket(toks: &[Tok], open_idx: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    let mut i = open_idx;
    while i < toks.len() {
        if toks[i].is_punct(open) {
            depth += 1;
        } else if toks[i].is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// For an item keyword at `kw` (`mod`), find the `{ ... }` body if the item
/// has one (`mod x;` has none).
fn item_body(toks: &[Tok], kw: usize) -> Option<Body> {
    let mut i = kw + 1;
    while i < toks.len() {
        if toks[i].is_punct(";") {
            return None;
        }
        if toks[i].is_punct("{") {
            let close = match_bracket(toks, i, "{", "}");
            return Some(Body {
                lines: LineRange {
                    start: toks[i].line,
                    end: toks[close].line,
                },
            });
        }
        i += 1;
    }
    None
}

/// Record `use path::Orig as Alias` pairs (including inside `use a::{x as
/// y, z}` groups): alias -> Orig.  Walks the statement up to its `;`.
fn collect_use_aliases(toks: &[Tok], kw: usize, out: &mut BTreeMap<String, String>) {
    let n = toks.len();
    let mut i = kw + 1;
    let mut last: Option<&str> = None;
    while i < n && !toks[i].is_punct(";") {
        let t = &toks[i];
        if t.kind == TokKind::Ident {
            if t.text == "as" {
                if let (Some(orig), Some(alias)) = (last, toks.get(i + 1)) {
                    if alias.kind == TokKind::Ident {
                        out.insert(alias.text.clone(), orig.to_string());
                        i += 2;
                        continue;
                    }
                }
            }
            last = Some(t.text.as_str());
        }
        i += 1;
    }
}

/// Parse `const NAME_STREAM: <ty> = <int literal>;` starting at the
/// `const` keyword.  Only `*_STREAM`-named constants are recorded.
fn stream_const(toks: &[Tok], kw: usize) -> Option<StreamConst> {
    let name_tok = toks.get(kw + 1)?;
    if name_tok.kind != TokKind::Ident || !name_tok.text.ends_with("_STREAM") {
        return None;
    }
    let mut i = kw + 2;
    while i < toks.len() && !toks[i].is_punct("=") && !toks[i].is_punct(";") {
        i += 1;
    }
    let mut value = None;
    if i < toks.len() && toks[i].is_punct("=") {
        if let Some(v) = toks.get(i + 1) {
            if v.kind == TokKind::IntLit {
                value = parse_int_literal(&v.text);
            }
        }
    }
    Some(StreamConst {
        name: name_tok.text.clone(),
        value,
        line: name_tok.line,
    })
}

/// Parse a Rust integer literal (`0x...`, `0b...`, `0o...`, decimal, with
/// `_` separators and an optional type suffix).
pub fn parse_int_literal(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|c| *c != '_').collect();
    let (digits, radix) = if let Some(h) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        (h, 16)
    } else if let Some(b) = t.strip_prefix("0b") {
        (b, 2)
    } else if let Some(o) = t.strip_prefix("0o") {
        (o, 8)
    } else {
        (t.as_str(), 10)
    };
    // Trim a type suffix (u64, usize, ...) if present.
    let end = digits
        .char_indices()
        .find(|(_, c)| !c.is_digit(radix))
        .map(|(i, _)| i)
        .unwrap_or(digits.len());
    u64::from_str_radix(&digits[..end], radix).ok()
}

/// Parse an `impl` header starting at the `impl` keyword: returns the
/// unqualified self-type name and the body range.  Handles
/// `impl<G> Type<G>`, `impl Trait for Type`, and `impl<G> Trait for
/// Type<G>`.
fn impl_header(toks: &[Tok], kw: usize) -> Option<(String, Body)> {
    let n = toks.len();
    let mut i = kw + 1;
    // Skip generic parameter list.
    if i < n && toks[i].is_punct("<") {
        let mut depth = 0i32;
        while i < n {
            if toks[i].is_punct("<") {
                depth += 1;
            } else if toks[i].is_punct(">") {
                depth -= 1;
                if depth == 0 {
                    i += 1;
                    break;
                }
            }
            i += 1;
        }
    }
    // First path; if followed by `for`, the self type is the next path.
    let mut target = first_path_ident(toks, &mut i)?;
    skip_generic_args(toks, &mut i);
    if i < n && toks[i].is_ident("for") {
        i += 1;
        // Skip `&`, lifetimes, `mut`, `dyn`.
        while i < n
            && (toks[i].is_punct("&")
                || toks[i].kind == TokKind::Lifetime
                || toks[i].is_ident("mut")
                || toks[i].is_ident("dyn"))
        {
            i += 1;
        }
        target = first_path_ident(toks, &mut i)?;
        skip_generic_args(toks, &mut i);
    }
    // Find the body `{`.
    while i < n && !toks[i].is_punct("{") {
        if toks[i].is_punct(";") {
            return None;
        }
        i += 1;
    }
    if i >= n {
        return None;
    }
    let close = match_bracket(toks, i, "{", "}");
    Some((
        target,
        Body {
            lines: LineRange {
                start: toks[i].line,
                end: toks[close].line,
            },
        },
    ))
}

/// Read `seg(::seg)*` at `*i`; return the LAST segment (the type name for
/// a qualified path like `util::stats::Welford`) and advance past it.
fn first_path_ident(toks: &[Tok], i: &mut usize) -> Option<String> {
    let n = toks.len();
    let mut last: Option<String> = None;
    loop {
        if *i < n && toks[*i].kind == TokKind::Ident {
            last = Some(toks[*i].text.clone());
            *i += 1;
            if *i < n && toks[*i].is_punct("::") {
                *i += 1;
                continue;
            }
        }
        break;
    }
    last
}

/// Skip a `<...>` generic-argument list at `*i`, if present.
fn skip_generic_args(toks: &[Tok], i: &mut usize) {
    let n = toks.len();
    if *i < n && toks[*i].is_punct("<") {
        let mut depth = 0i32;
        while *i < n {
            if toks[*i].is_punct("<") {
                depth += 1;
            } else if toks[*i].is_punct(">") {
                depth -= 1;
                if depth == 0 {
                    *i += 1;
                    return;
                }
            }
            *i += 1;
        }
    }
}

const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "let", "fn", "in", "as", "move",
    "mut", "ref", "break", "continue", "unsafe", "where", "impl", "dyn", "Self", "self", "super",
    "crate", "pub", "use", "mod", "struct", "enum", "trait", "type", "const", "static", "async",
    "await",
];

/// Parse a `fn` definition starting at the `fn` keyword.
fn fn_def(toks: &[Tok], kw: usize) -> Option<FnDef> {
    let n = toks.len();
    let name_idx = kw + 1;
    if name_idx >= n || toks[name_idx].kind != TokKind::Ident {
        return None;
    }
    let name = toks[name_idx].text.clone();
    let line = toks[name_idx].line;
    let header_async = kw > 0 && toks[kw - 1].is_ident("async");
    // Parameter list.
    let mut i = name_idx + 1;
    if i < n && toks[i].is_punct("<") {
        skip_generic_args(toks, &mut i);
    }
    if i >= n || !toks[i].is_punct("(") {
        return None;
    }
    let params_close = match_bracket(toks, i, "(", ")");
    let sig_has_rng = toks[i..params_close].iter().any(|t| t.is_ident("Rng"));
    // Find body `{` or trait-decl `;`.
    let mut j = params_close + 1;
    let mut brace = None;
    while j < n {
        if toks[j].is_punct(";") {
            break;
        }
        if toks[j].is_punct("{") {
            brace = Some(j);
            break;
        }
        j += 1;
    }
    let (range, calls, tok_body, body_async) = match brace {
        Some(open) => {
            let close = match_bracket(toks, open, "{", "}");
            let close = close.min(n - 1);
            let body = &toks[open..=close];
            (
                LineRange {
                    start: line,
                    end: toks[close].line,
                },
                collect_calls(body),
                Some((open, close)),
                body.iter().any(|t| t.is_ident("async")),
            )
        }
        None => (LineRange { start: line, end: line }, Vec::new(), None, false),
    };
    Some(FnDef {
        name,
        line,
        range,
        sig_has_rng,
        is_async: header_async || body_async,
        impl_target: None,
        calls,
        tok_sig: kw,
        tok_body,
    })
}

/// Every `name(`, `.name(`, or `Type::name(` in a body slice, excluding
/// macro invocations (`name!(...)`) and keywords.  `Type::name(` records
/// `Type` as the call's qualifier.
fn collect_calls(body: &[Tok]) -> Vec<Call> {
    let mut out = Vec::new();
    let n = body.len();
    for i in 0..n {
        if body[i].kind != TokKind::Ident {
            continue;
        }
        let name = body[i].text.as_str();
        if KEYWORDS.contains(&name) {
            continue;
        }
        // `fn name(` is a nested definition, not a call.
        if i > 0 && body[i - 1].is_ident("fn") {
            continue;
        }
        if i + 1 < n && body[i + 1].is_punct("(") {
            let qualifier = if i >= 2 && body[i - 1].is_punct("::") && body[i - 2].kind == TokKind::Ident
            {
                Some(body[i - 2].text.clone())
            } else {
                None
            };
            out.push(Call {
                name: name.to_string(),
                qualifier,
                line: body[i].line,
            });
        } else if i + 1 < n && body[i + 1].is_punct("!") {
            // macro — skip
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_test_mod_range() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() { rng.uniform(); }\n}\n";
        let m = FileModel::parse(src);
        assert!(!m.in_test(1));
        assert!(m.in_test(4));
    }

    #[test]
    fn impl_targets() {
        let src = "impl StepAggregator {\n    fn push(&mut self) { self.area += 1.0; }\n}\nimpl<D: Drv> Core<D> {\n    fn go(&self) {}\n}\nimpl Policy for Fixed {\n    fn observe(&mut self) {}\n}\n";
        let m = FileModel::parse(src);
        assert_eq!(m.impl_target_at(2), Some("StepAggregator"));
        assert_eq!(m.impl_target_at(5), Some("Core"));
        assert_eq!(m.impl_target_at(8), Some("Fixed"));
        let push = m.fns.iter().find(|f| f.name == "push").unwrap();
        assert_eq!(push.impl_target.as_deref(), Some("StepAggregator"));
    }

    #[test]
    fn fn_calls_and_rng_sig() {
        let src = "fn draw(rng: &mut Rng) -> f64 { rng.uniform() }\nfn outer() { let v = draw(&mut r); helper_macro!(x); }\n";
        let m = FileModel::parse(src);
        let draw = m.fns.iter().find(|f| f.name == "draw").unwrap();
        assert!(draw.sig_has_rng);
        assert!(draw.calls.iter().any(|c| c.name == "uniform"));
        let outer = m.fns.iter().find(|f| f.name == "outer").unwrap();
        assert!(outer.calls.iter().any(|c| c.name == "draw"));
        assert!(!outer.calls.iter().any(|c| c.name == "helper_macro"));
    }

    #[test]
    fn trait_decl_without_body() {
        let src = "trait P {\n    fn observe(&mut self, lens: &[u32]) {}\n    fn route(&self, rng: &mut Rng) -> usize;\n}\n";
        let m = FileModel::parse(src);
        let route = m.fns.iter().find(|f| f.name == "route").unwrap();
        assert!(route.sig_has_rng);
        assert!(route.calls.is_empty());
    }

    #[test]
    fn qualified_calls_record_their_type() {
        let src = "fn f() { let a = Welford::merge(x, y); plain(); obj.method(); }\n";
        let m = FileModel::parse(src);
        let f = &m.fns[0];
        let merge = f.calls.iter().find(|c| c.name == "merge").unwrap();
        assert_eq!(merge.qualifier.as_deref(), Some("Welford"));
        assert!(f.calls.iter().any(|c| c.name == "plain" && c.qualifier.is_none()));
        assert!(f.calls.iter().any(|c| c.name == "method" && c.qualifier.is_none()));
    }

    #[test]
    fn async_fns_and_async_blocks() {
        let src = "async fn task() {}\nfn spawns() { h.spawn(async move { tick() }); }\nfn plain() {}\n";
        let m = FileModel::parse(src);
        assert!(m.fns.iter().find(|f| f.name == "task").unwrap().is_async);
        assert!(m.fns.iter().find(|f| f.name == "spawns").unwrap().is_async);
        assert!(!m.fns.iter().find(|f| f.name == "plain").unwrap().is_async);
    }

    #[test]
    fn use_aliases_resolve() {
        let src = "use std::collections::HashMap as Map;\nuse std::collections::{HashSet as Set, BTreeMap};\nfn f() {}\n";
        let m = FileModel::parse(src);
        assert_eq!(m.resolve("Map"), "HashMap");
        assert_eq!(m.resolve("Set"), "HashSet");
        assert_eq!(m.resolve("BTreeMap"), "BTreeMap");
    }

    #[test]
    fn stream_consts_collected_and_parsed() {
        let src = "pub const ROUTE_STREAM: u64 = 0x51_3A_77;\nconst OTHER: u64 = 7;\nconst DEC_STREAM: u64 = 42;\n";
        let m = FileModel::parse(src);
        assert_eq!(m.stream_consts.len(), 2);
        assert_eq!(m.stream_consts[0].name, "ROUTE_STREAM");
        assert_eq!(m.stream_consts[0].value, Some(0x513A77));
        assert_eq!(m.stream_consts[1].value, Some(42));
    }
}
