//! The determinism contract, as named machine-checked rules.
//!
//! Every guarantee the crate reproduces (Theorem-1 optimal sampling, the
//! delay-adaptive policies, η/(n·p_i) weighting) rests on bit-identity
//! between the heap oracle, the sharded engine, the batch arena, and the
//! event-driven serve coordinator.  The conventions that keep them in
//! lockstep used to live in doc comments ("MUST consume no RNG"); this
//! module enforces them at lint time:
//!
//! * **R1** — no RNG consumption reachable from any
//!   `SamplingPolicy::observe_*` implementation.  Policies are observed at
//!   different moments in each engine; a single stray draw in an observe
//!   path desynchronizes the routing stream and shows up only as a digest
//!   mismatch hours later.
//! * **R2** — no `HashMap`/`HashSet`/`RandomState` in the **digest
//!   region**: the forward call-closure of every function in direct
//!   contact with a determinism sink (`to_json_deterministic`,
//!   `StepAggregator`, `Welford`), computed by [`crate::taint`].  New
//!   modules are covered the day they are written — there is no module
//!   list to enroll in.
//! * **R3** — no wall-clock / OS-entropy reads (`Instant`, `SystemTime`,
//!   `thread_rng`, `available_parallelism`, `thread::current`,
//!   `env::var` & friends) in the digest region.  `util/bench.rs` is the
//!   blessed perf-measurement home, exactly as `util/rng.rs` is the
//!   entropy home — wall-clock readings must live somewhere, and keeping
//!   them in one audited module is the point.
//! * **R4** — RNG construction from a bare integer-literal seed
//!   (`Rng::new(0x...)`, `stream_seed(12345, ..)`) only inside
//!   `util/rng.rs`; everywhere else seeds must arrive via keyed streams or
//!   named config so replications stay counter-addressable.
//! * **R5** — float accumulation (`+=` with an f32/f64 operand) in engine
//!   step paths must route through `StepAggregator`/`Welford`, whose
//!   summation order is part of the cross-engine contract.
//! * **R6** — RNG stream discipline: `.derive(..)` stream keys and
//!   `stream_seed(seed, &[..])` id arrays must start from a named
//!   `*_STREAM` constant, and no two stream constants may share a value —
//!   a collision silently correlates routing, churn, and serve draws.
//! * **R7** — nothing blocking on the virtual-clock executor: no
//!   `thread::sleep`, blocking file I/O, or wall-clock reads reachable
//!   from an `async fn` / future `poll` (function-granular closure).
//! * **R8** — float reductions (`.sum()`, `fold(0.0, ..)`, bare float
//!   accumulators) in digest-sink files outside `StepAggregator`/`Welford`
//!   (the generalization of R5 beyond engine step paths; `util/stats.rs`
//!   is the blessed float-reduction home).
//!
//! Each rule is individually suppressible at the violation site with
//! `// lint-allow(<rule>): <reason>` — the reason string is mandatory and
//! its absence is itself a diagnostic (`lint-allow-syntax`).  Doc comments
//! (`///`, `//!`) never mint suppressions.  A suppression that no longer
//! suppresses anything is itself a violation (`stale-allow`), so the allow
//! census can only shrink unless a new written reason is added.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::lexer::{Tok, TokKind};
use crate::taint::{self, FileEntry, TaintAnalysis};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    R7,
    R8,
    /// Malformed `lint-allow` (missing rule or reason).
    AllowSyntax,
    /// A `lint-allow` that suppresses nothing.
    StaleAllow,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::R6 => "R6",
            Rule::R7 => "R7",
            Rule::R8 => "R8",
            Rule::AllowSyntax => "lint-allow-syntax",
            Rule::StaleAllow => "stale-allow",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One diagnostic: `file:line: RULE: msg`.
#[derive(Clone, Debug)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One `lint-allow` site, as reported by the suppression census.
#[derive(Clone, Debug)]
pub struct AllowRecord {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub reason: String,
    pub used: bool,
}

/// Full lint output: surviving violations plus the census and region data
/// the `--json` / `--allow-report` surfaces expose.
#[derive(Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    pub allows: Vec<AllowRecord>,
    /// Digest-region membership: (file, witness chain).
    pub digest_region: Vec<(String, String)>,
    pub files_linted: usize,
}

/// Engine step paths (R5): everything that feeds the cross-engine digest.
fn is_engine_step(rel: &str) -> bool {
    rel.starts_with("simulator/engine/") || rel == "simulator/network.rs"
}

/// The one module allowed to mint RNG state from raw literals (R4) and
/// hold the stream-derivation plumbing R6 audits everywhere else.
fn is_rng_home(rel: &str) -> bool {
    rel == "util/rng.rs"
}

/// The one module allowed to read the wall clock inside the digest region
/// (R3): the bench harness measures elapsed time by design, and its
/// readings feed only the perf block that `to_json_deterministic()`
/// excludes.
fn is_perf_home(rel: &str) -> bool {
    rel == "util/bench.rs"
}

/// The one module allowed free-form float reductions inside digest-sink
/// files (R8): the stats substrate (Welford/StepAggregator/quantiles) IS
/// the blessed reduction order.
fn is_stats_home(rel: &str) -> bool {
    rel == "util/stats.rs"
}

/// Names whose call consumes routing/service RNG state (R1 markers), plus
/// the usual suspects from external RNG crates so future code can't sneak
/// them in under a dependency.
const RNG_CONSUMERS: &[&str] = &[
    "next_u64",
    "uniform",
    "uniform_pos",
    "below",
    "usize_below",
    "range_f64",
    "exponential",
    "normal",
    "normal_with",
    "lognormal_mean_cv",
    "shuffle",
    "sample_distinct",
    "he_normal",
    "sample",
    "gen",
    "gen_range",
    "thread_rng",
];

/// Roots of the R1 reachability walk — every policy callback that sits on
/// an engine's central dispatcher path, including the membership channel
/// (`observe_join` / `observe_leave` fire inside the churn event loop).
const OBSERVE_ROOTS: &[&str] = &[
    "observe",
    "observe_node",
    "observe_completion",
    "observe_join",
    "observe_leave",
];

/// Impl targets whose float accumulation IS the contract (R5/R8
/// contexts).
const FLOAT_SINKS: &[&str] = &["StepAggregator", "Welford", "Ewma", "Histogram"];

/// Wall-clock / OS-entropy identifiers (R3 sources).
const R3_SOURCES: &[&str] = &["Instant", "SystemTime", "thread_rng", "available_parallelism"];

/// `std::env` readers (R3 sources when qualified as `env::<name>`).
const ENV_READERS: &[&str] = &["var", "vars", "var_os", "args", "args_os", "temp_dir"];

/// Blocking / wall-clock identifiers forbidden on the executor (R7).
const R7_BLOCKING: &[&str] = &[
    "Instant",
    "SystemTime",
    "File",
    "OpenOptions",
    "read_to_string",
    "read_dir",
    "stdin",
    "thread_rng",
];

/// Lint every `.rs` file under `src_root` and return just the surviving
/// diagnostics (the shape the fixture tests and CI text output consume).
pub fn lint_root(src_root: &Path) -> Vec<Violation> {
    lint_report(src_root).violations
}

/// Lint every `.rs` file under `src_root` (the crate's `src/` directory,
/// or a fixture tree mirroring its layout).  Returns the surviving
/// diagnostics, the `lint-allow` census, and the digest-region map,
/// deterministically ordered.
pub fn lint_report(src_root: &Path) -> LintReport {
    let mut files = Vec::new();
    let mut paths = Vec::new();
    walk(src_root, &mut paths);
    paths.sort();
    for path in &paths {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(src) = fs::read_to_string(path) else {
            continue;
        };
        files.push(FileEntry {
            rel,
            model: crate::model::FileModel::parse(&src),
        });
    }

    let taint = taint::analyze(&files);

    let mut violations = Vec::new();
    for f in &files {
        check_tokens(f, &taint, &mut violations);
    }
    check_observe_reachability(&files, &mut violations);
    check_executor_blocking(&files, &taint, &mut violations);
    check_stream_collisions(&files, &mut violations);

    // Allow-comment pass: drop suppressed violations (marking their allow
    // as used), add syntax diagnostics for malformed allows, then turn
    // every unused allow into a stale-allow violation.
    let mut out = Vec::new();
    let mut census: Vec<AllowRecord> = Vec::new();
    for f in &files {
        let mut allows = parse_allows(f, &mut out);
        for v in violations.iter().filter(|v| v.file == f.rel) {
            match find_suppressor(f, &allows, v) {
                Some(i) => allows[i].used = true,
                None => out.push(v.clone()),
            }
        }
        for a in &allows {
            if !a.used {
                out.push(Violation {
                    file: f.rel.clone(),
                    line: a.line,
                    rule: Rule::StaleAllow,
                    msg: format!(
                        "lint-allow({}) suppresses nothing — remove the stale \
                         suppression or restore the code it covered",
                        a.rule
                    ),
                });
            }
            census.push(AllowRecord {
                file: f.rel.clone(),
                line: a.line,
                rule: a.rule.clone(),
                reason: a.reason.clone(),
                used: a.used,
            });
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule);
    census.sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));

    LintReport {
        violations: out,
        allows: census,
        digest_region: taint
            .digest_files
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect(),
        files_linted: files.len(),
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Token-local rules: R2, R3 (taint-scoped), R4, R5, R6 call sites, R8.
fn check_tokens(f: &FileEntry, taint: &TaintAnalysis, out: &mut Vec<Violation>) {
    let rel = f.rel.as_str();
    let model = &f.model;
    let toks = &model.lexed.toks;
    let in_digest_region = taint.digest_files.contains_key(rel);
    let region_via = taint.digest_files.get(rel).map(String::as_str).unwrap_or("");
    let is_seed_file = taint.seed_files.contains(rel);
    let engine_step = is_engine_step(rel);
    let rng_home = is_rng_home(rel);

    let push = |out: &mut Vec<Violation>, line: u32, rule: Rule, msg: String| {
        out.push(Violation {
            file: rel.to_string(),
            line,
            rule,
            msg,
        });
    };

    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident && !t.is_punct("+=") {
            continue;
        }
        if model.in_test(t.line) {
            continue;
        }
        let canon = if t.kind == TokKind::Ident {
            model.resolve(&t.text)
        } else {
            ""
        };
        // R2: unordered collections anywhere in the digest region.
        if in_digest_region && matches!(canon, "HashMap" | "HashSet" | "RandomState") {
            push(
                out,
                t.line,
                Rule::R2,
                format!(
                    "`{}` in the digest region (tainted via {region_via}) — iteration \
                     order is process-random; use BTreeMap/Vec or suppress with a reason",
                    t.text
                ),
            );
        }
        // R3: wall-clock / OS entropy anywhere in the digest region,
        // except the audited perf home.
        if in_digest_region && !is_perf_home(rel) {
            let source: Option<String> = if R3_SOURCES.contains(&canon) {
                Some(t.text.clone())
            } else if ENV_READERS.contains(&canon) && qualified_by(toks, i, "env") {
                Some(format!("env::{}", t.text))
            } else if t.text == "current" && qualified_by(toks, i, "thread") {
                Some("thread::current".to_string())
            } else {
                None
            };
            if let Some(what) = source {
                push(
                    out,
                    t.line,
                    Rule::R3,
                    format!(
                        "`{what}` in the digest region (tainted via {region_via}) — \
                         results here flow through to_json_deterministic(); timing \
                         belongs in the perf block only"
                    ),
                );
            }
        }
        // R4: ad-hoc RNG seeds outside util/rng.rs.
        if !rng_home {
            let seed_call = (t.is_ident("Rng")
                && toks.get(i + 1).is_some_and(|t| t.is_punct("::"))
                && toks.get(i + 2).is_some_and(|t| t.is_ident("new"))
                && toks.get(i + 3).is_some_and(|t| t.is_punct("(")))
            .then_some(i + 3)
            .or_else(|| {
                ((t.is_ident("stream_seed") || t.is_ident("first_u64_of"))
                    && toks.get(i + 1).is_some_and(|t| t.is_punct("(")))
                .then_some(i + 1)
            });
            if let Some(open) = seed_call {
                if first_arg_is_bare_int(toks, open) {
                    push(
                        out,
                        t.line,
                        Rule::R4,
                        "RNG constructed from a bare literal seed — derive via \
                         stream_seed(seed, [..]) keyed streams or a named config seed"
                            .to_string(),
                    );
                }
            }
        }
        // R5: float accumulation outside StepAggregator/Welford in engine
        // step paths.
        if engine_step && t.is_punct("+=") {
            let in_sink = model
                .impl_target_at(t.line)
                .is_some_and(|target| FLOAT_SINKS.contains(&target));
            if !in_sink && rhs_is_floaty(toks, i) {
                push(
                    out,
                    t.line,
                    Rule::R5,
                    "bare float `+=` in an engine step path — route the \
                     accumulation through StepAggregator/Welford so summation \
                     order stays part of the contract"
                        .to_string(),
                );
            }
        }
        // R6: stream keys must be named `*_STREAM` constants.
        if !rng_home {
            // `.derive(<key>)` — the preceding `.` distinguishes the RNG
            // stream API from `#[derive(..)]` attributes.
            if t.is_ident("derive")
                && i > 0
                && toks[i - 1].is_punct(".")
                && toks.get(i + 1).is_some_and(|t| t.is_punct("("))
            {
                match toks.get(i + 2) {
                    Some(arg) if arg.kind == TokKind::IntLit => push(
                        out,
                        t.line,
                        Rule::R6,
                        format!(
                            "RNG stream derived from bare literal `{}` — key streams \
                             off a named `*_STREAM` constant so ids stay \
                             collision-auditable",
                            arg.text
                        ),
                    ),
                    Some(arg)
                        if arg.kind == TokKind::Ident
                            && !model.resolve(&arg.text).ends_with("_STREAM")
                            && !arg.is_ident("self") =>
                    {
                        push(
                            out,
                            t.line,
                            Rule::R6,
                            format!(
                                "RNG stream key `{}` is not a named `*_STREAM` \
                                 constant — stream ids must be auditable for \
                                 collisions",
                                arg.text
                            ),
                        )
                    }
                    _ => {}
                }
            }
            // `stream_seed(seed, &[<id>, ..])` — the id array must not
            // start with a bare literal.
            if t.is_ident("stream_seed") && toks.get(i + 1).is_some_and(|t| t.is_punct("(")) {
                if let Some(first_id) = stream_id_first_element(toks, i + 1) {
                    if first_id.kind == TokKind::IntLit {
                        push(
                            out,
                            first_id.line,
                            Rule::R6,
                            format!(
                                "stream id array starts with bare literal `{}` — use \
                                 a named `*_STREAM` constant",
                                first_id.text
                            ),
                        );
                    }
                }
            }
        }
        // R8: float reductions in digest-sink files outside the blessed
        // accumulators.  Engine-step `+=` stays R5's domain.
        if is_seed_file && !is_stats_home(rel) {
            let in_sink = model
                .impl_target_at(t.line)
                .is_some_and(|target| FLOAT_SINKS.contains(&target));
            if !in_sink {
                if t.is_ident("sum") && i > 0 && toks[i - 1].is_punct(".") {
                    let turbofish_float = toks.get(i + 1).is_some_and(|t| t.is_punct("::"))
                        && toks
                            .get(i + 3)
                            .is_some_and(|t| t.is_ident("f64") || t.is_ident("f32"));
                    let ascribed_float = !turbofish_float
                        && toks.get(i + 1).is_some_and(|t| t.is_punct("("))
                        && stmt_span_mentions_float(toks, i);
                    if turbofish_float || ascribed_float {
                        push(
                            out,
                            t.line,
                            Rule::R8,
                            "float reduction (`.sum()`) in a digest-sink file outside \
                             StepAggregator/Welford — summation order is part of the \
                             cross-engine contract; use util/stats helpers or suppress \
                             with a reason"
                                .to_string(),
                        );
                    }
                }
                if t.is_ident("fold")
                    && toks.get(i + 1).is_some_and(|t| t.is_punct("("))
                    && toks.get(i + 2).is_some_and(|t| t.kind == TokKind::FloatLit)
                {
                    push(
                        out,
                        t.line,
                        Rule::R8,
                        "float reduction (`fold` with float init) in a digest-sink \
                         file outside StepAggregator/Welford — summation order is \
                         part of the cross-engine contract"
                            .to_string(),
                    );
                }
                if t.is_punct("+=") && !engine_step && rhs_is_floaty(toks, i) {
                    push(
                        out,
                        t.line,
                        Rule::R8,
                        "bare float `+=` accumulator in a digest-sink file outside \
                         StepAggregator/Welford — summation order is part of the \
                         cross-engine contract"
                            .to_string(),
                    );
                }
            }
        }
    }
}

/// Is token `i` path-qualified as `<qual>::<tok i>`?
fn qualified_by(toks: &[Tok], i: usize, qual: &str) -> bool {
    i >= 2 && toks[i - 1].is_punct("::") && toks[i - 2].is_ident(qual)
}

/// For `stream_seed(` with the `(` at `open`, find the first element of
/// the second argument's `&[..]` id array.
fn stream_id_first_element(toks: &[Tok], open: usize) -> Option<&Tok> {
    let mut depth = 0i32;
    let mut i = open;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct("(") || t.is_punct("[") {
            depth += 1;
        } else if t.is_punct(")") || t.is_punct("]") {
            depth -= 1;
            if depth == 0 {
                return None;
            }
        } else if t.is_punct(",") && depth == 1 {
            // Skip `&` / `[` prefix tokens of the array expression.
            let mut j = i + 1;
            while j < toks.len() && (toks[j].is_punct("&") || toks[j].is_punct("[")) {
                j += 1;
            }
            return toks.get(j);
        }
        i += 1;
    }
    None
}

/// Backscan from a `.sum()` call to the start of its statement (the
/// previous `;`, `{`, or `}`): does the span mention f32/f64?  Catches
/// `let x: f64 = xs.iter().sum();` while leaving integer sums and
/// tail-expression sums (whose `-> f64` sits outside the body) alone.
fn stmt_span_mentions_float(toks: &[Tok], sum_idx: usize) -> bool {
    let mut i = sum_idx;
    while i > 0 {
        i -= 1;
        let t = &toks[i];
        if t.is_punct(";") || t.is_punct("{") || t.is_punct("}") {
            break;
        }
        if t.is_ident("f64") || t.is_ident("f32") {
            return true;
        }
    }
    false
}

/// First argument of the call whose `(` sits at `open`: bare integer
/// literal iff the tokens up to the first top-level `,` or the closing `)`
/// are exactly one `IntLit`.
fn first_arg_is_bare_int(toks: &[Tok], open: usize) -> bool {
    let mut depth = 0i32;
    let mut arg_toks = 0usize;
    let mut bare = false;
    for t in &toks[open..] {
        if t.is_punct("(") || t.is_punct("[") {
            depth += 1;
            if depth == 1 {
                continue;
            }
        } else if t.is_punct(")") || t.is_punct("]") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if depth == 1 && t.is_punct(",") {
            break;
        }
        if depth >= 1 {
            arg_toks += 1;
            bare = arg_toks == 1 && t.kind == TokKind::IntLit;
        }
    }
    bare
}

/// Tokens from the `+=` to the statement's `;` mention f32/f64 (cast,
/// typed temporary, or float literal).
fn rhs_is_floaty(toks: &[Tok], op: usize) -> bool {
    let mut depth = 0i32;
    for t in &toks[op + 1..] {
        match t.text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
            ";" if depth == 0 => break,
            _ => {}
        }
        if t.kind == TokKind::FloatLit || t.is_ident("f64") || t.is_ident("f32") {
            return true;
        }
    }
    false
}

/// R1: walk the name-based call graph from every `observe_*` definition;
/// any path to an RNG-consuming name (or to a function taking `Rng` in its
/// signature) is a violation at the offending call site.  R1 keeps FULL
/// edges (no stoplist): a false edge costs a written reason, a missed
/// edge costs a corrupted digest.
fn check_observe_reachability(files: &[FileEntry], out: &mut Vec<Violation>) {
    // Global fn table: name -> [(file index, fn index)].
    let mut by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.model.fns.iter().enumerate() {
            if !f.model.in_test(d.line) {
                by_name.entry(d.name.as_str()).or_default().push((fi, di));
            }
        }
    }
    for (&root_name, roots) in &by_name {
        if !OBSERVE_ROOTS.contains(&root_name) {
            continue;
        }
        for &(rfi, rdi) in roots {
            let mut visited: Vec<(usize, usize)> = Vec::new();
            let mut stack: Vec<((usize, usize), Vec<String>)> =
                vec![((rfi, rdi), vec![root_name.to_string()])];
            while let Some(((fi, di), chain)) = stack.pop() {
                if visited.contains(&(fi, di)) {
                    continue;
                }
                visited.push((fi, di));
                for call in &files[fi].model.fns[di].calls {
                    let callee = &call.name;
                    let line = call.line;
                    if RNG_CONSUMERS.contains(&callee.as_str()) {
                        out.push(Violation {
                            file: files[fi].rel.clone(),
                            line,
                            rule: Rule::R1,
                            msg: format!(
                                "RNG consumption reachable from `{}` \
                                 (chain: {} -> {callee}) — observe paths must not \
                                 move the routing stream",
                                root_name,
                                chain.join(" -> "),
                            ),
                        });
                        continue;
                    }
                    if let Some(callees) = by_name.get(callee.as_str()) {
                        for &(cfi, cdi) in callees {
                            if files[cfi].model.fns[cdi].sig_has_rng {
                                out.push(Violation {
                                    file: files[fi].rel.clone(),
                                    line,
                                    rule: Rule::R1,
                                    msg: format!(
                                        "`{callee}` takes an Rng and is reachable \
                                         from `{}` (chain: {}) — observe paths must \
                                         not move the routing stream",
                                        root_name,
                                        chain.join(" -> "),
                                    ),
                                });
                            } else {
                                let mut next = chain.clone();
                                next.push(callee.clone());
                                stack.push(((cfi, cdi), next));
                            }
                        }
                    }
                }
            }
        }
    }
}

/// R7: scan every function reachable from an executor future for blocking
/// or wall-clock operations.
fn check_executor_blocking(files: &[FileEntry], taint: &TaintAnalysis, out: &mut Vec<Violation>) {
    for &(fi, di, ref chain) in &taint.executor_fns {
        let f = &files[fi];
        let d = &f.model.fns[di];
        let Some((lo, hi)) = d.tok_body else {
            continue;
        };
        let toks = &f.model.lexed.toks;
        for i in lo..=hi.min(toks.len() - 1) {
            let t = &toks[i];
            if t.kind != TokKind::Ident || f.model.in_test(t.line) {
                continue;
            }
            let canon = f.model.resolve(&t.text);
            // `sleep` exactly (the virtual-clock `sleep_until` is a
            // different token); a leading `.` would be a method on our own
            // handle types, which is fine.
            let blocking = (R7_BLOCKING.contains(&canon))
                || (t.text == "sleep" && !(i > 0 && toks[i - 1].is_punct(".")));
            if blocking {
                out.push(Violation {
                    file: f.rel.clone(),
                    line: t.line,
                    rule: Rule::R7,
                    msg: format!(
                        "`{}` is blocking/wall-clock and runs on the virtual-clock \
                         executor (chain: {chain}) — futures must advance via the \
                         virtual clock only",
                        t.text
                    ),
                });
            }
        }
    }
}

/// R6 (crate-wide part): no two `*_STREAM` constants may share a value.
fn check_stream_collisions(files: &[FileEntry], out: &mut Vec<Violation>) {
    // (value -> first-seen (name, file, line)), in deterministic file
    // order (files arrive sorted by path).
    let mut seen: BTreeMap<u64, (String, String, u32)> = BTreeMap::new();
    for f in files {
        for c in &f.model.stream_consts {
            if f.model.in_test(c.line) {
                continue;
            }
            let Some(v) = c.value else {
                continue;
            };
            match seen.get(&v) {
                Some((name, file, line)) if *name != c.name || *file != f.rel => {
                    out.push(Violation {
                        file: f.rel.clone(),
                        line: c.line,
                        rule: Rule::R6,
                        msg: format!(
                            "stream constant {} ({v:#x}) collides with {name} at \
                             {file}:{line} — colliding ids correlate \
                             supposedly-independent RNG streams",
                            c.name
                        ),
                    });
                }
                Some(_) => {}
                None => {
                    seen.insert(v, (c.name.clone(), f.rel.clone(), c.line));
                }
            }
        }
    }
}

/// A parsed `// lint-allow(<rule>): <reason>` comment.
struct Allow {
    line: u32,
    rule: String,
    reason: String,
    used: bool,
}

/// Extract allows from a file's non-doc comments; malformed ones (no rule,
/// or no non-empty reason after `:`) become `lint-allow-syntax`
/// diagnostics.  The marker is `lint-allow(` — prose that merely mentions
/// the words is ignored — and doc comments are deliberately not consulted:
/// documentation may cite the syntax without minting a suppression.
fn parse_allows(f: &FileEntry, out: &mut Vec<Violation>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (&line, text) in &f.model.lexed.comments {
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("lint-allow(") {
            let stripped = &rest[pos + "lint-allow(".len()..];
            let Some(close) = stripped.find(')') else {
                out.push(syntax_err(f, line, "unclosed rule name in lint-allow"));
                break;
            };
            let rule = stripped[..close].trim().to_string();
            let after = &stripped[close + 1..];
            let reason: Option<String> = after.strip_prefix(':').and_then(|r| {
                let r = r.trim();
                let end = r.find("lint-allow(").unwrap_or(r.len());
                let r = r[..end].trim();
                (!r.is_empty()).then(|| r.to_string())
            });
            if rule.is_empty() {
                out.push(syntax_err(f, line, "empty rule name in lint-allow"));
            } else if let Some(reason) = reason {
                allows.push(Allow {
                    line,
                    rule,
                    reason,
                    used: false,
                });
            } else {
                out.push(syntax_err(
                    f,
                    line,
                    &format!("lint-allow({rule}) requires a reason: `lint-allow({rule}): <why>`"),
                ));
            }
            rest = after;
        }
    }
    allows
}

fn syntax_err(f: &FileEntry, line: u32, msg: &str) -> Violation {
    Violation {
        file: f.rel.clone(),
        line,
        rule: Rule::AllowSyntax,
        msg: msg.to_string(),
    }
}

/// A violation is suppressed by a matching allow on the same line, or on
/// the contiguous run of comment-only lines directly above it.  Returns
/// the index of the suppressing allow so the census can mark it used.
fn find_suppressor(f: &FileEntry, allows: &[Allow], v: &Violation) -> Option<usize> {
    let matches_at = |line: u32| {
        allows
            .iter()
            .position(|a| a.line == line && a.rule == v.rule.name())
    };
    if let Some(i) = matches_at(v.line) {
        return Some(i);
    }
    let mut line = v.line;
    while line > 1 {
        line -= 1;
        let comment_only = f.model.lexed.comments.contains_key(&line)
            && !f.model.lexed.code_lines.contains(&line);
        if !comment_only {
            return None;
        }
        if let Some(i) = matches_at(line) {
            return Some(i);
        }
    }
    None
}

/// Render the report as deterministic, dependency-free JSON (the
/// `--json` output the CI problem matcher and trend tooling consume).
pub fn render_json(report: &LintReport) -> String {
    fn esc(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    let mut s = String::new();
    s.push_str("{\n  \"version\": 1,\n  \"violations\": [");
    for (i, v) in report.violations.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"msg\": \"{}\"}}",
            esc(&v.file),
            v.line,
            v.rule.name(),
            esc(&v.msg)
        ));
    }
    if !report.violations.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("],\n  \"allows\": [");
    for (i, a) in report.allows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"reason\": \"{}\", \"used\": {}}}",
            esc(&a.file),
            a.line,
            esc(&a.rule),
            esc(&a.reason),
            a.used
        ));
    }
    if !report.allows.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("],\n  \"digest_region\": [");
    for (i, (file, via)) in report.digest_region.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"via\": \"{}\"}}",
            esc(file),
            esc(via)
        ));
    }
    if !report.digest_region.is_empty() {
        s.push_str("\n  ");
    }
    let stale = report.allows.iter().filter(|a| !a.used).count();
    s.push_str(&format!(
        "],\n  \"summary\": {{\"files_linted\": {}, \"violations\": {}, \"allows\": {}, \"stale_allows\": {}}}\n}}\n",
        report.files_linted,
        report.violations.len(),
        report.allows.len(),
        stale
    ));
    s
}
