//! The determinism contract, as named machine-checked rules.
//!
//! Every guarantee the crate reproduces (Theorem-1 optimal sampling, the
//! delay-adaptive policies, η/(n·p_i) weighting) rests on bit-identity
//! between the heap oracle, the sharded engine, and the batch arena.  The
//! conventions that keep them in lockstep used to live in doc comments
//! ("MUST consume no RNG"); this module enforces them at lint time:
//!
//! * **R1** — no RNG consumption reachable from any
//!   `SamplingPolicy::observe_*` implementation.  Policies are observed at
//!   different moments in each engine; a single stray draw in an observe
//!   path desynchronizes the routing stream and shows up only as a digest
//!   mismatch hours later.
//! * **R2** — no `HashMap`/`HashSet` in deterministic modules
//!   (`simulator/**`, `coordinator/policy.rs`, `coordinator/serve.rs`,
//!   `coordinator/sweep.rs`, `runtime/executor.rs`, `util/stats.rs`).
//!   Iteration order is randomized per process; one `for (k, v) in map`
//!   in a result path breaks run-to-run identity.
//! * **R3** — no `Instant`/`SystemTime`/`thread_rng` in those same
//!   modules, where results flow into `to_json_deterministic()`.
//! * **R4** — RNG construction from a bare integer-literal seed
//!   (`Rng::new(0x...)`, `stream_seed(12345, ..)`) only inside
//!   `util/rng.rs`; everywhere else seeds must arrive via keyed streams or
//!   named config so replications stay counter-addressable.
//! * **R5** — float accumulation (`+=` with an f32/f64 operand) in engine
//!   step paths must route through `StepAggregator`/`Welford`, whose
//!   summation order is part of the cross-engine contract.
//!
//! Each rule is individually suppressible at the violation site with
//! `// lint-allow(<rule>): <reason>` — the reason string is mandatory and
//! its absence is itself a diagnostic.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::lexer::TokKind;
use crate::model::{FileModel, FnDef};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    /// Malformed `lint-allow` (missing rule or reason).
    AllowSyntax,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::AllowSyntax => "lint-allow-syntax",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One diagnostic: `file:line: RULE: msg`.
#[derive(Clone, Debug)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Deterministic modules (R2/R3): the engines, the policies, the sweep
/// serializer, the serve coordinator and its async executor, and the
/// stats substrate.
fn is_deterministic(rel: &str) -> bool {
    rel.starts_with("simulator/")
        || rel == "coordinator/policy.rs"
        || rel == "coordinator/serve.rs"
        || rel == "coordinator/sweep.rs"
        || rel == "runtime/executor.rs"
        || rel == "util/stats.rs"
}

/// Engine step paths (R5): everything that feeds the cross-engine digest.
fn is_engine_step(rel: &str) -> bool {
    rel.starts_with("simulator/engine/") || rel == "simulator/network.rs"
}

/// The one module allowed to mint RNG state from raw literals (R4).
fn is_rng_home(rel: &str) -> bool {
    rel == "util/rng.rs"
}

/// Names whose call consumes routing/service RNG state (R1 markers), plus
/// the usual suspects from external RNG crates so future code can't sneak
/// them in under a dependency.
const RNG_CONSUMERS: &[&str] = &[
    "next_u64",
    "uniform",
    "uniform_pos",
    "below",
    "usize_below",
    "range_f64",
    "exponential",
    "normal",
    "normal_with",
    "lognormal_mean_cv",
    "shuffle",
    "sample_distinct",
    "he_normal",
    "sample",
    "gen",
    "gen_range",
    "thread_rng",
];

/// Roots of the R1 reachability walk — every policy callback that sits on
/// an engine's central dispatcher path, including the membership channel
/// (`observe_join` / `observe_leave` fire inside the churn event loop).
const OBSERVE_ROOTS: &[&str] = &[
    "observe",
    "observe_node",
    "observe_completion",
    "observe_join",
    "observe_leave",
];

/// Impl targets whose float accumulation IS the contract (R5 contexts).
const FLOAT_SINKS: &[&str] = &["StepAggregator", "Welford"];

struct LintedFile {
    rel: String,
    model: FileModel,
}

/// Lint every `.rs` file under `src_root` (the crate's `src/` directory,
/// or a fixture tree mirroring its layout).  Returns the surviving
/// diagnostics, deterministically ordered.
pub fn lint_root(src_root: &Path) -> Vec<Violation> {
    let mut files = Vec::new();
    let mut paths = Vec::new();
    walk(src_root, &mut paths);
    paths.sort();
    for path in &paths {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(src) = fs::read_to_string(path) else {
            continue;
        };
        files.push(LintedFile {
            rel,
            model: FileModel::parse(&src),
        });
    }

    let mut violations = Vec::new();
    for f in &files {
        check_tokens(f, &mut violations);
    }
    check_observe_reachability(&files, &mut violations);

    // Allow-comment pass: drop suppressed violations, add syntax
    // diagnostics for malformed allows.
    let mut out = Vec::new();
    for f in &files {
        let allows = parse_allows(f, &mut out);
        for v in violations.iter().filter(|v| v.file == f.rel) {
            if !is_suppressed(f, &allows, v) {
                out.push(v.clone());
            }
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule);
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Token-local rules: R2, R3, R4, R5.
fn check_tokens(f: &LintedFile, out: &mut Vec<Violation>) {
    let rel = f.rel.as_str();
    let model = &f.model;
    let toks = &model.lexed.toks;
    let deterministic = is_deterministic(rel);
    let engine_step = is_engine_step(rel);
    let rng_home = is_rng_home(rel);

    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident && !t.is_punct("+=") {
            continue;
        }
        if model.in_test(t.line) {
            continue;
        }
        // R2: unordered collections in deterministic modules.
        if deterministic && (t.is_ident("HashMap") || t.is_ident("HashSet")) {
            out.push(Violation {
                file: rel.to_string(),
                line: t.line,
                rule: Rule::R2,
                msg: format!(
                    "`{}` in deterministic module — iteration order is \
                     process-random; use BTreeMap/Vec or suppress with a reason",
                    t.text
                ),
            });
        }
        // R3: wall-clock / OS entropy in deterministic modules.
        if deterministic
            && (t.is_ident("Instant") || t.is_ident("SystemTime") || t.is_ident("thread_rng"))
        {
            out.push(Violation {
                file: rel.to_string(),
                line: t.line,
                rule: Rule::R3,
                msg: format!(
                    "`{}` in a module whose results flow through \
                     to_json_deterministic() — timing belongs in the perf block only",
                    t.text
                ),
            });
        }
        // R4: ad-hoc RNG seeds outside util/rng.rs.
        if !rng_home {
            let seed_call = (t.is_ident("Rng")
                && toks.get(i + 1).is_some_and(|t| t.is_punct("::"))
                && toks.get(i + 2).is_some_and(|t| t.is_ident("new"))
                && toks.get(i + 3).is_some_and(|t| t.is_punct("(")))
            .then_some(i + 3)
            .or_else(|| {
                ((t.is_ident("stream_seed") || t.is_ident("first_u64_of"))
                    && toks.get(i + 1).is_some_and(|t| t.is_punct("(")))
                .then_some(i + 1)
            });
            if let Some(open) = seed_call {
                if first_arg_is_bare_int(toks, open) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: t.line,
                        rule: Rule::R4,
                        msg: "RNG constructed from a bare literal seed — derive via \
                              stream_seed(seed, [..]) keyed streams or a named config seed"
                            .to_string(),
                    });
                }
            }
        }
        // R5: float accumulation outside StepAggregator/Welford in engine
        // step paths.
        if engine_step && t.is_punct("+=") {
            let in_sink = model
                .impl_target_at(t.line)
                .is_some_and(|target| FLOAT_SINKS.contains(&target));
            if !in_sink && rhs_is_floaty(toks, i) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: t.line,
                    rule: Rule::R5,
                    msg: "bare float `+=` in an engine step path — route the \
                          accumulation through StepAggregator/Welford so summation \
                          order stays part of the contract"
                        .to_string(),
                });
            }
        }
    }
}

/// First argument of the call whose `(` sits at `open`: bare integer
/// literal iff the tokens up to the first top-level `,` or the closing `)`
/// are exactly one `IntLit`.
fn first_arg_is_bare_int(toks: &[crate::lexer::Tok], open: usize) -> bool {
    let mut depth = 0i32;
    let mut arg_toks = 0usize;
    let mut bare = false;
    for t in &toks[open..] {
        if t.is_punct("(") || t.is_punct("[") {
            depth += 1;
            if depth == 1 {
                continue;
            }
        } else if t.is_punct(")") || t.is_punct("]") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if depth == 1 && t.is_punct(",") {
            break;
        }
        if depth >= 1 {
            arg_toks += 1;
            bare = arg_toks == 1 && t.kind == TokKind::IntLit;
        }
    }
    bare
}

/// Tokens from the `+=` to the statement's `;` mention f32/f64 (cast,
/// typed temporary, or float literal).
fn rhs_is_floaty(toks: &[crate::lexer::Tok], op: usize) -> bool {
    let mut depth = 0i32;
    for t in &toks[op + 1..] {
        match t.text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
            ";" if depth == 0 => break,
            _ => {}
        }
        if t.kind == TokKind::FloatLit || t.is_ident("f64") || t.is_ident("f32") {
            return true;
        }
    }
    false
}

/// R1: walk the name-based call graph from every `observe_*` definition;
/// any path to an RNG-consuming name (or to a function taking `Rng` in its
/// signature) is a violation at the offending call site.
fn check_observe_reachability(files: &[LintedFile], out: &mut Vec<Violation>) {
    // Global fn table: name -> [(file index, fn index)].
    let mut by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.model.fns.iter().enumerate() {
            if !f.model.in_test(d.line) {
                by_name.entry(d.name.as_str()).or_default().push((fi, di));
            }
        }
    }
    let def = |fi: usize, di: usize| -> &FnDef { &files[fi].model.fns[di] };

    for (&root_name, roots) in &by_name {
        if !OBSERVE_ROOTS.contains(&root_name) {
            continue;
        }
        for &(rfi, rdi) in roots {
            let mut visited: Vec<(usize, usize)> = Vec::new();
            let mut stack: Vec<((usize, usize), Vec<String>)> =
                vec![((rfi, rdi), vec![root_name.to_string()])];
            while let Some(((fi, di), chain)) = stack.pop() {
                if visited.contains(&(fi, di)) {
                    continue;
                }
                visited.push((fi, di));
                for (callee, line) in &def(fi, di).calls {
                    if RNG_CONSUMERS.contains(&callee.as_str()) {
                        out.push(Violation {
                            file: files[fi].rel.clone(),
                            line: *line,
                            rule: Rule::R1,
                            msg: format!(
                                "RNG consumption reachable from `{}` \
                                 (chain: {} -> {callee}) — observe paths must not \
                                 move the routing stream",
                                root_name,
                                chain.join(" -> "),
                            ),
                        });
                        continue;
                    }
                    if let Some(callees) = by_name.get(callee.as_str()) {
                        for &(cfi, cdi) in callees {
                            if def(cfi, cdi).sig_has_rng {
                                out.push(Violation {
                                    file: files[fi].rel.clone(),
                                    line: *line,
                                    rule: Rule::R1,
                                    msg: format!(
                                        "`{callee}` takes an Rng and is reachable \
                                         from `{}` (chain: {}) — observe paths must \
                                         not move the routing stream",
                                        root_name,
                                        chain.join(" -> "),
                                    ),
                                });
                            } else {
                                let mut next = chain.clone();
                                next.push(callee.clone());
                                stack.push(((cfi, cdi), next));
                            }
                        }
                    }
                }
            }
        }
    }
}

/// A parsed `// lint-allow(<rule>): <reason>` comment.
struct Allow {
    line: u32,
    rule: String,
}

/// Extract allows from a file's comments; malformed ones (no rule, or no
/// non-empty reason after `:`) become `lint-allow-syntax` diagnostics.
fn parse_allows(f: &LintedFile, out: &mut Vec<Violation>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (&line, text) in &f.model.lexed.comments {
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("lint-allow") {
            rest = &rest[pos + "lint-allow".len()..];
            let Some(stripped) = rest.strip_prefix('(') else {
                out.push(syntax_err(f, line, "expected `lint-allow(<rule>): <reason>`"));
                continue;
            };
            let Some(close) = stripped.find(')') else {
                out.push(syntax_err(f, line, "unclosed rule name in lint-allow"));
                break;
            };
            let rule = stripped[..close].trim().to_string();
            let after = &stripped[close + 1..];
            let reason_ok = after
                .strip_prefix(':')
                .map(|r| {
                    let r = r.trim();
                    let end = r.find("lint-allow").unwrap_or(r.len());
                    !r[..end].trim().is_empty()
                })
                .unwrap_or(false);
            if rule.is_empty() {
                out.push(syntax_err(f, line, "empty rule name in lint-allow"));
            } else if !reason_ok {
                out.push(syntax_err(
                    f,
                    line,
                    &format!("lint-allow({rule}) requires a reason: `lint-allow({rule}): <why>`"),
                ));
            } else {
                allows.push(Allow { line, rule });
            }
            rest = after;
        }
    }
    allows
}

fn syntax_err(f: &LintedFile, line: u32, msg: &str) -> Violation {
    Violation {
        file: f.rel.clone(),
        line,
        rule: Rule::AllowSyntax,
        msg: msg.to_string(),
    }
}

/// A violation is suppressed by a matching allow on the same line, or on
/// the contiguous run of comment-only lines directly above it.
fn is_suppressed(f: &LintedFile, allows: &[Allow], v: &Violation) -> bool {
    let matches_at = |line: u32| {
        allows
            .iter()
            .any(|a| a.line == line && a.rule == v.rule.name())
    };
    if matches_at(v.line) {
        return true;
    }
    let mut line = v.line;
    while line > 1 {
        line -= 1;
        let comment_only = f.model.lexed.comments.contains_key(&line)
            && !f.model.lexed.code_lines.contains(&line);
        if !comment_only {
            return false;
        }
        if matches_at(line) {
            return true;
        }
    }
    false
}
