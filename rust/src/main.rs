//! fedqueue CLI — the leader entrypoint.
//!
//! Subcommands are registered in the [`COMMANDS`] table; the usage text
//! and the unknown-command error enumerate that table, and the algorithm
//! and policy lists are generated from the strategy/policy registries —
//! registering a new strategy makes it reachable from `train` with no
//! CLI changes.

use fedqueue::bound::{BoundParams, MiSource, TwoClusterStudy};
use fedqueue::coordinator::{Experiment, PolicyRegistry};
use fedqueue::figures;
use fedqueue::fl::StrategyRegistry;
use fedqueue::queueing::ClosedNetwork;
use fedqueue::runtime::{BackendKind, Manifest};
use fedqueue::simulator::{run as sim_run, EngineConfig, ServiceDist, ServiceFamily, SimConfig};
use fedqueue::util::cli::Args;
use fedqueue::util::table::Series;
use std::path::Path;

/// Every subcommand with a one-line summary.  `usage()` and the
/// unknown-command error are rendered from this table, so the list the
/// user sees is always the list `main()` dispatches on.
const COMMANDS: &[(&str, &str)] = &[
    ("train", "run one asynchronous FL experiment (Algorithm 1 + baselines)"),
    ("simulate", "run the closed-network simulator and report delay stats"),
    ("serve", "event-driven coordinator session with admission control"),
    ("sweep", "multi-seed scenario grid -> mean +/- CI JSON"),
    ("bounds", "evaluate/optimize the Theorem-1 bound for a 2-cluster setup"),
    ("figure", "regenerate one paper figure/table (fig1..fig12, table1/2)"),
    ("figures", "regenerate every table/figure into --out"),
    ("info", "runtime/artifact diagnostics"),
    ("help", "print this help"),
];

/// `train|simulate|serve|...` — for the unknown-command error.
fn command_list() -> String {
    COMMANDS.iter().map(|(n, _)| *n).collect::<Vec<_>>().join("|")
}

fn usage() -> String {
    let strategies = StrategyRegistry::builtin();
    let policies = PolicyRegistry::builtin();
    let algo_list = strategies.names().join("|");
    let policy_list = policies.names().join("|");
    let bullets = |pairs: Vec<(String, String)>| -> String {
        pairs
            .iter()
            .map(|(n, s)| format!("  {n:<10} {s}"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    format!(
        "\
fedqueue — Queuing dynamics of asynchronous Federated Learning (AISTATS 2024)

USAGE: fedqueue <command> [options]

COMMANDS (from the command table)
{cmds}

OPTIONS BY COMMAND
  train     --scenario scenarios/NAME.toml | flags:
            --algo {algo_list}
            --policy {policy_list}
            --variant tiny|cifar|wide|tinyimg --backend pjrt|native
            --steps N --clients N --concurrency C --eta F --mu-fast F
            --p-fast F --gamma F --beta F (delay-adaptive EWMA momentum)
            --kappa F (genasync-damped staleness damping)
            --fedbuff-z Z --fedavg-s S
            --favano-interval D --optimal-p (= --policy optimal)
            --seed S --out results/train.csv
  simulate  --n N --c C --steps N --mu-fast F --n-fast N --p-fast F --seed S
            --engine heap|sharded|batch --shards S --shard-threads T
            (engines are bit-identical; sharded scales to n = 10^6 nodes)
  serve     --scenario scenarios/serve_quick.toml
            [--clients N --concurrency C --dispatches N --seed S]
            [--out results/serve.json]
            simulated clients on the deterministic async executor; the
            scenario's [serve] table sets t_sync/warm_up/safety_buffer/
            admission_tolerance/server_time/ramp_time; the report JSON is
            bit-identical across runs except its `perf` block
  sweep     --grid scenarios/sweep_fig6.toml [--threads N] [--seeds S]
            [--engine auto|heap|sharded|batch] [--batch-width R]
            [--out results/sweep.json]
            multi-seed grid -> mean ± CI JSON (+ per-cell events/sec and
            peak-RSS perf block) + error-band CSV (keys: docs/SCENARIOS.md);
            small-n cells batch R seeds through one SoA arena
  bounds    --c C --mu-fast F --n N --n-fast N [--physical-time U]
  figure    <fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|table1|table2>
            [--out DIR] [--quick]
  figures   [--out DIR] [--quick]      regenerate every table/figure
  info      print artifact + backend diagnostics

ALGORITHMS (server strategies, from the registry)
{algos}

POLICIES (sampling distributions, from the registry)
{pols}
",
        cmds = bullets(
            COMMANDS
                .iter()
                .map(|(n, s)| (n.to_string(), s.to_string()))
                .collect()
        ),
        algos = bullets(strategies.summaries()),
        pols = bullets(policies.summaries()),
    )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..], &["quick", "optimal-p", "record-tasks"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "bounds" => cmd_bounds(&args),
        "figure" => cmd_figure(&args),
        "figures" => cmd_figures(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!(
            "unknown command '{other}' ({})\n\n{}",
            command_list(),
            usage()
        )),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &Args) -> Result<(), String> {
    // base: scenario file if given, otherwise the historical CLI defaults
    let mut cfg = match args.get("scenario") {
        Some(path) => Experiment::from_scenario(Path::new(path))?,
        None => Experiment::builder()
            .variant("cifar")
            .backend(BackendKind::Pjrt)
            .clients(100)
            .concurrency(10)
            .steps(200)
            .eta(0.05)
            .n_train(20_000)
            .n_val(2_000)
            .classes_per_client(7)
            .eval_every(20)
            .build()?,
    };
    // CLI flags override whichever base was chosen
    if let Some(v) = args.get("variant") {
        cfg.variant = v.to_string();
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = v.parse::<BackendKind>()?;
    }
    if let Some(v) = args.get("algo") {
        cfg.algo = v.to_string();
    }
    if let Some(v) = args.get("policy") {
        cfg.policy = v.to_string();
    }
    cfg.n_clients = args.usize_or("clients", cfg.n_clients)?;
    cfg.concurrency = args.usize_or("concurrency", cfg.concurrency)?;
    cfg.steps = args.u64_or("steps", cfg.steps)?;
    cfg.eta = args.f64_or("eta", cfg.eta)?;
    cfg.fedbuff_z = args.usize_or("fedbuff-z", cfg.fedbuff_z)?;
    cfg.fedavg_s = args.usize_or("fedavg-s", cfg.fedavg_s)?;
    cfg.favano_interval = args.f64_or("favano-interval", cfg.favano_interval)?;
    cfg.slow_fraction = args.f64_or("slow-fraction", cfg.slow_fraction)?;
    cfg.mu_fast = args.f64_or("mu-fast", cfg.mu_fast)?;
    if let Some(v) = args.get("p-fast") {
        cfg.p_fast = Some(v.parse().map_err(|_| "bad --p-fast")?);
    }
    cfg.gamma = args.f64_or("gamma", cfg.gamma)?;
    cfg.beta = args.f64_or("beta", cfg.beta)?;
    cfg.kappa = args.f64_or("kappa", cfg.kappa)?;
    cfg.n_train = args.usize_or("n-train", cfg.n_train)?;
    cfg.n_val = args.usize_or("n-val", cfg.n_val)?;
    cfg.classes_per_client = args.usize_or("classes-per-client", cfg.classes_per_client)?;
    cfg.eval_every = args.u64_or("eval-every", cfg.eval_every)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    if args.has("optimal-p") {
        // historical alias for --policy optimal
        cfg.policy = "optimal".to_string();
    }
    cfg.validate()?;
    println!("# algo {} | policy {}", cfg.algo, cfg.policy);
    // resolve the policy ONCE: for `optimal` every construction is a full
    // bound-optimizer sweep
    let policy = cfg.build_policy()?;
    if cfg.policy == "optimal" {
        println!(
            "# optimal p_fast = {:.4e} (uniform would be {:.4e})",
            policy.probs()[0],
            1.0 / cfg.n_clients as f64
        );
    }
    let (m_theory, rate) =
        fedqueue::coordinator::experiment::theory_summary_with(&cfg, &policy.probs())?;
    println!(
        "# theory: CS step rate {:.2}/unit-time; mean delay fast {:.1} / slow {:.1} steps",
        rate,
        m_theory[..cfg.n_fast()].iter().sum::<f64>() / cfg.n_fast() as f64,
        m_theory[cfg.n_fast()..].iter().sum::<f64>() / (cfg.n_clients - cfg.n_fast()) as f64
    );
    let strategy =
        StrategyRegistry::builtin().build(&cfg.algo, &cfg.strategy_params(&policy.probs()))?;
    let res = cfg.run_with(strategy, policy)?;
    let mut s = Series::new(&["step", "virtual_time", "train_loss", "val_loss", "val_acc"]);
    for c in &res.curve {
        s.push(vec![c.step as f64, c.virtual_time, c.train_loss, c.val_loss, c.val_accuracy]);
    }
    println!("{}", s.ascii(50));
    let out = args.str_or("out", "results/train.csv");
    s.write_csv(Path::new(&out)).map_err(|e| e.to_string())?;
    println!(
        "final: acc {:.4}, val loss {:.4}, τ_max {}, versions {}, backend {:.1}s / wall {:.1}s → {}",
        res.final_accuracy,
        res.final_val_loss,
        res.tau_max,
        res.versions,
        res.backend_secs,
        res.wall_secs,
        out
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let n = args.usize_or("n", 10)?;
    let c = args.usize_or("c", 1000)?;
    let steps = args.u64_or("steps", 1_000_000)?;
    let mu_fast = args.f64_or("mu-fast", 1.2)?;
    let n_fast = args.usize_or("n-fast", n / 2)?;
    let p_fast = args.f64_or("p-fast", 1.0 / n as f64)?;
    let family: ServiceFamily = args.str_or("service", "exp").parse()?;
    let q = (1.0 - n_fast as f64 * p_fast) / (n - n_fast) as f64;
    if q <= 0.0 {
        return Err(format!("p-fast {p_fast} leaves no mass for slow nodes"));
    }
    let p: Vec<f64> = (0..n).map(|i| if i < n_fast { p_fast } else { q }).collect();
    let rates: Vec<f64> = (0..n).map(|i| if i < n_fast { mu_fast } else { 1.0 }).collect();
    let engine = EngineConfig {
        kind: args.str_or("engine", "heap").parse()?,
        shards: args.usize_or("shards", 0)?,
        threads: args.usize_or("shard-threads", 1)?,
    };
    let cfg = SimConfig {
        seed: args.u64_or("seed", 0)?,
        engine,
        ..SimConfig::new(p.clone(), ServiceDist::from_rates(&rates, family), c, steps)
    };
    let res = sim_run(cfg)?;
    let net = ClosedNetwork::new(p, rates)?;
    let an = net.mi_analysis(c, fedqueue::queueing::MiEstimator::Throughput);
    println!("node  mean_delay(sim)  m_i(theory)  mean_queue(sim)  E[X_i](theory)");
    let b = net.buzen(c);
    for i in 0..n {
        println!(
            "{i:>4}  {:>14.1}  {:>11.1}  {:>15.2}  {:>14.2}",
            res.delay_steps[i].mean(),
            an.m[i],
            res.mean_queue[i],
            b.mean_queue(i, c)
        );
    }
    println!(
        "τ_max {} | τ_c {:.2} | CS step rate {:.3} (theory {:.3}) | virtual time {:.0}",
        res.tau_max,
        res.tau_c,
        res.step_rate(steps),
        an.cs_rate,
        res.total_time
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let scenario = args
        .get("scenario")
        .ok_or("serve: --scenario scenarios/serve_quick.toml is required")?;
    let mut exp = Experiment::from_scenario(Path::new(scenario))?;
    exp.n_clients = args.usize_or("clients", exp.n_clients)?;
    exp.concurrency = args.usize_or("concurrency", exp.concurrency)?;
    exp.seed = args.u64_or("seed", exp.seed)?;
    let mut setup = fedqueue::coordinator::ServeSetup::from_experiment(&exp);
    setup.dispatches = args.u64_or("dispatches", setup.dispatches)?;
    let report = setup.run()?;
    print!("{}", report.summary());
    let out = args.str_or("out", "results/serve.json");
    let out_path = Path::new(&out);
    if let Some(dir) = out_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
    }
    std::fs::write(out_path, report.to_json().render()).map_err(|e| e.to_string())?;
    println!(
        "wrote {}  [{:.1}s wall, {:.0} dispatches/sec]",
        out_path.display(),
        report.wall_secs,
        report.dispatches_per_sec()
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let grid = args
        .get("grid")
        .ok_or("sweep: --grid scenarios/NAME.toml is required")?;
    let mut spec = fedqueue::coordinator::SweepSpec::from_path(Path::new(grid))?;
    spec.threads = args.usize_or("threads", spec.threads)?;
    if let Some(engine) = args.get("engine") {
        fedqueue::coordinator::sweep::validate_engine_choice(engine)
            .map_err(|e| format!("--engine: {e}"))?;
        spec.engine = engine.to_string();
    }
    let seeds = args.u64_or("seeds", spec.seeds)?;
    if seeds == 0 {
        return Err("--seeds must be >= 1".into());
    }
    spec.seeds = seeds;
    spec.batch_width = args.usize_or("batch-width", spec.batch_width)?;
    let out = args.str_or("out", &spec.out);
    println!(
        "# sweep '{}': {} cells x {} seeds = {} replications on {} threads",
        spec.name,
        spec.cells.len(),
        spec.seeds,
        spec.cells.len() * spec.seeds as usize,
        if spec.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            spec.threads
        }
    );
    let t0 = std::time::Instant::now();
    let report = fedqueue::coordinator::run_sweep(&spec)?;
    print!("{}", report.summary());
    let out_path = Path::new(&out);
    if let Some(dir) = out_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
    }
    std::fs::write(out_path, report.to_json().render()).map_err(|e| e.to_string())?;
    let bands = figures::sweep_figs::metric_bands(
        &report,
        &figures::sweep_figs::default_metrics(&report),
    );
    let bands_path = out_path.with_extension("bands.csv");
    bands.write_csv(&bands_path).map_err(|e| e.to_string())?;
    println!(
        "wrote {} + {}  [{:.1}s]",
        out_path.display(),
        bands_path.display(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_bounds(args: &Args) -> Result<(), String> {
    let c = args.usize_or("c", 10)?;
    let n = args.usize_or("n", 100)?;
    let n_fast = args.usize_or("n-fast", 90)?;
    let mu_fast = args.f64_or("mu-fast", 8.0)?;
    let study = TwoClusterStudy {
        params: BoundParams {
            a: args.f64_or("a", 100.0)?,
            b: args.f64_or("b", 20.0)?,
            l: args.f64_or("l", 1.0)?,
            c,
            t: args.u64_or("t", 10_000)?,
            n,
        },
        n_fast,
        mu_fast,
        mu_slow: 1.0,
        source: MiSource::default(),
    };
    let (best, uniform) = if let Some(u) = args.get("physical-time") {
        let u: f64 = u.parse().map_err(|_| "bad --physical-time")?;
        study.optimize_p_physical(50, u)?
    } else {
        study.optimize_p(50)?
    };
    println!("uniform : p={:.4e} η={:.3e} bound={:.4}", uniform.p_fast, uniform.eta, uniform.bound);
    println!(
        "optimal : p={:.4e} η={:.3e} bound={:.4}  (improvement {:.1}%)",
        best.p_fast,
        best.eta,
        best.bound,
        100.0 * (uniform.bound - best.bound) / uniform.bound
    );
    println!(
        "delays  : uniform fast/slow {:.1}/{:.1} → optimal {:.1}/{:.1} CS steps",
        uniform.m_fast, uniform.m_slow, best.m_fast, best.m_slow
    );
    let (g_fedbuff, g_async) = study.baseline_bounds()?;
    println!("baselines: FedBuff {g_fedbuff:.4}, AsyncSGD {g_async:.4}");
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<(), String> {
    let target = args
        .positional
        .first()
        .ok_or("figure: which one? e.g. `fedqueue figure fig5`")?;
    let out = args.str_or("out", "results");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let summary = figures::run_target(target, Path::new(&out), args.has("quick"))?;
    println!("{summary}");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<(), String> {
    let out = args.str_or("out", "results");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let quick = args.has("quick");
    let mut summaries = Vec::new();
    for target in figures::ALL.iter().chain(figures::EXTRA.iter()) {
        println!("=== {target} ===");
        let t0 = std::time::Instant::now();
        match figures::run_target(target, Path::new(&out), quick) {
            Ok(s) => {
                println!("{s}  [{:.1}s]", t0.elapsed().as_secs_f64());
                summaries.push(s);
            }
            Err(e) => {
                println!("FAILED: {e}");
                summaries.push(format!("{target}: FAILED {e}"));
            }
        }
    }
    let all = summaries.join("\n");
    std::fs::write(Path::new(&out).join("SUMMARY.txt"), &all).map_err(|e| e.to_string())?;
    println!("\n=== summary ===\n{all}");
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    let dir = Manifest::default_dir();
    println!("artifact dir: {}", dir.display());
    match Manifest::load(&dir) {
        Ok(m) => {
            for v in &m.variants {
                println!(
                    "  {}: {}→{:?}→{} ({} params, train batch {})",
                    v.name, v.input_dim, v.hidden, v.classes, v.n_params, v.train_batch
                );
            }
        }
        Err(e) => println!("  (no artifacts: {e})"),
    }
    let strategies = StrategyRegistry::builtin();
    let policies = PolicyRegistry::builtin();
    println!("strategies: {}", strategies.names().join(", "));
    println!("policies:   {}", policies.names().join(", "));
    #[cfg(feature = "pjrt")]
    {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("{e:?}"))?;
        println!(
            "PJRT: platform {} ({}), {} device(s)",
            client.platform_name(),
            client.platform_version(),
            client.device_count()
        );
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT: not compiled in (build with `--features pjrt`)");
    Ok(())
}
