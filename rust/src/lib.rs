//! # fedqueue
//!
//! Reproduction of **"Queuing dynamics of asynchronous Federated Learning"**
//! (Leconte, Jonckheere, Samsonov, Moulines — AISTATS 2024):
//! **Generalized AsyncSGD**, an asynchronous FL server with non-uniform
//! client sampling chosen by minimizing a convergence bound driven by exact
//! closed-Jackson-network delay analysis.
//!
//! Architecture (see DESIGN.md): Rust coordinator (this crate, L3) executes
//! AOT-compiled JAX models (L2) whose hot-spots are Pallas kernels (L1),
//! via PJRT; Python never runs on the request path.
//!
//! Top-level modules:
//! * [`queueing`] — exact product-form theory (Buzen, arrival theorem, m_i)
//! * [`simulator`] — event-driven closed-network dynamics
//! * [`bound`] — Theorem 1 convergence bound + (p, η) optimizer
//! * [`fl`] — algorithm zoo: Generalized AsyncSGD + 4 baselines
//! * [`data`] — synthetic datasets + non-iid partitioning
//! * [`runtime`] — PJRT executor for HLO artifacts + native backend
//! * [`coordinator`] — the asynchronous central server event loop
//! * [`figures`] — regeneration of every paper table/figure
//! * [`util`] — offline substrates (PRNG, stats, TOML/JSON, CLI, bench)
//!
//! The determinism contract between the three engines is machine-checked:
//! `cargo xtask lint` enforces rules R1–R8 via a sources/sinks taint
//! pass (see docs/LINTS.md), and the loom/Miri/TSan suites model-check the
//! concurrency seams the static pass cannot see.

// `cfg(loom)` is a custom cfg set via RUSTFLAGS by the loom CI leg; the
// MSRV toolchain predates the `unexpected_cfgs` check, hence the
// `unknown_lints` escort.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]
// Docs ratchet: every public item should carry rustdoc.  Modules that
// predate the ratchet carry an explicit `#[allow(missing_docs)]` at their
// declaration (here or in their layer's mod.rs); new modules must comply
// — the CI docs job builds with `RUSTDOCFLAGS="-D warnings"`.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod bound;
pub mod coordinator;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod figures;
#[allow(missing_docs)]
pub mod fl;
#[allow(missing_docs)]
pub mod queueing;
pub mod runtime;
pub mod simulator;
pub mod util;
