//! Server strategies — the open algorithm surface of the coordinator.
//!
//! A [`ServerStrategy`] is a pure state machine over gradient arrivals and
//! task dispatches, independent of the queueing dynamics and the gradient
//! backend (hence unit-testable on synthetic oracles).  The built-in zoo:
//!
//! * [`GenAsync`] — the paper's contribution: immediate update scaled by
//!   `η/(n p_i)` to keep the aggregate direction unbiased under non-uniform
//!   sampling (line 10 of Algorithm 1).  The scale uses the *dispatch-time*
//!   selection probability carried in the [`GradientCtx`], so unbiasedness
//!   survives time-varying sampling policies.
//! * [`GenAsyncDamped`] — staleness-damped Generalized AsyncSGD
//!   (arXiv:2502.08206-style): the per-gradient step size is damped by the
//!   observed staleness M, `η_M = η/(1 + κ·M)`, while the dispatch-time
//!   `1/(n p_i)` inverse-probability weight is kept — stale gradients are
//!   trusted less without biasing the sampling correction (κ = 0
//!   degenerates to [`GenAsync`] exactly).
//! * [`AsyncSgd`] — Koloskova et al.: uniform sampling, immediate update
//!   `w ← w − η g` (the special case p_i = 1/n of the above).
//! * [`FedBuff`] — Nguyen et al.: server buffers Z client updates, then
//!   applies their average once.
//! * [`FedAvgStrategy`] — the synchronous FedAvg round barrier adapted to
//!   the asynchronous event stream: the server collects gradients until `s`
//!   *distinct* clients have reported (repeat completions by the same
//!   client within a round play the role of extra local steps), then
//!   applies the averaged update once.
//! * [`FavanoStrategy`] — FAVANO/QuAFL-style time-sliced averaging: the
//!   model steps on a fixed virtual-time interval Δ; every gradient that
//!   arrives within a slice joins the slice's buffer, and at each boundary
//!   the buffer is applied with the 1/(n+1) server-averaging weight.  Fast
//!   clients naturally contribute more gradients per slice.
//!
//! Strategies are constructed through a string → constructor
//! [`StrategyRegistry`], so new algorithms plug into `fedqueue train`, the
//! experiment builder, and scenario files without touching the driver.

use super::model::ModelState;

/// Everything a strategy may want to know about one arriving gradient.
pub struct GradientCtx<'a> {
    /// client i the gradient came from
    pub node: usize,
    /// central-server step k at which it arrived
    pub step: u64,
    /// virtual time of the arrival
    pub time: f64,
    /// staleness in CS steps (the paper's delay M)
    pub delay_steps: u64,
    /// probability with which `node` was selected when this gradient's task
    /// was dispatched — the inverse-probability weight that keeps GenAsync
    /// unbiased under any (possibly time-varying) sampling policy
    pub dispatch_prob: f64,
    /// the gradient tensors
    pub grads: &'a [Vec<f32>],
}

impl<'a> GradientCtx<'a> {
    /// Oracle-style context for tests and synthetic studies: `node` was
    /// drawn i.i.d. from the fixed distribution `p` (no queueing).
    pub fn sampled(node: usize, p: &[f64], grads: &'a [Vec<f32>]) -> GradientCtx<'a> {
        GradientCtx {
            node,
            step: 0,
            time: 0.0,
            delay_steps: 0,
            dispatch_prob: p[node],
            grads,
        }
    }
}

/// The server-side algorithm interface consumed by the coordinator driver.
pub trait ServerStrategy {
    /// Registry name (curve labels, diagnostics).
    fn name(&self) -> &'static str;

    /// A fresh task was dispatched to `node` at CS step `step`.
    fn on_dispatch(&mut self, _node: usize, _step: u64, _time: f64) {}

    /// A gradient arrived at the server; apply or buffer it.
    /// Returns true iff the global model stepped (version bumped).
    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool;

    /// Nominal per-gradient scale for client `node` (diagnostics + tests).
    fn scale_for(&self, node: usize) -> f64;

    /// CS model version counter (k in the paper): bumps on every applied
    /// server update.
    fn version(&self) -> u64;

    /// Total gradients received (≥ version for buffered strategies).
    fn received(&self) -> u64;
}

// ---------------------------------------------------------------------------
// Generalized AsyncSGD (Algorithm 1)
// ---------------------------------------------------------------------------

/// Dispatch-time inverse-probability scale `η/(n·p)`: prefers the
/// context's recorded dispatch probability, falls back to the reference
/// distribution, and yields 0.0 (drop the gradient) when neither is a
/// usable probability — an inf/NaN scale must never reach the model.
fn ipw_scale(eta: f64, p: &[f64], ctx: &GradientCtx) -> f64 {
    let prob = if ctx.dispatch_prob.is_finite() && ctx.dispatch_prob > 0.0 {
        ctx.dispatch_prob
    } else {
        p[ctx.node]
    };
    if prob.is_finite() && prob > 0.0 {
        eta / (p.len() as f64 * prob)
    } else {
        0.0
    }
}

/// Nominal `η/(n·p_i)` from a reference distribution, guarded: a zero-mass
/// (or malformed) entry reports 0.0 — such a node is never sampled, so an
/// inf scale in diagnostics would be noise, not signal.  The guard matters
/// because `SimConfig::validate` only rejects p_i = 0 on *active* nodes; a
/// reference vector may legitimately carry zero-mass entries.
fn reference_scale(eta: f64, p: &[f64], node: usize) -> f64 {
    let pi = p[node];
    if pi.is_finite() && pi > 0.0 {
        eta / (p.len() as f64 * pi)
    } else {
        0.0
    }
}

pub struct GenAsync {
    pub eta: f64,
    /// reference sampling distribution: used by `scale_for` diagnostics and
    /// as a fallback when a context carries no usable dispatch probability
    pub p: Vec<f64>,
    version: u64,
    received: u64,
}

impl GenAsync {
    pub fn new(eta: f64, p: Vec<f64>) -> GenAsync {
        GenAsync { eta, p, version: 0, received: 0 }
    }
}

impl ServerStrategy for GenAsync {
    fn name(&self) -> &'static str {
        "gasync"
    }

    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool {
        self.received += 1;
        let scale = ipw_scale(self.eta, &self.p, ctx) as f32;
        model.apply_update(ctx.grads, scale);
        self.version += 1;
        true
    }

    fn scale_for(&self, node: usize) -> f64 {
        reference_scale(self.eta, &self.p, node)
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// Staleness-damped Generalized AsyncSGD (arXiv:2502.08206-style)
// ---------------------------------------------------------------------------

/// Generalized AsyncSGD with a staleness-damped step size: a gradient that
/// arrives M CS steps after its dispatch (the paper's delay M) is applied
/// with `η/(1 + κ·M)` instead of η, while the dispatch-time `1/(n·p_i)`
/// inverse-probability weight is kept unchanged.  Damping only the step
/// size — never the IPW correction — trades staleness-induced drift for
/// update magnitude without re-biasing the sampling distribution; κ = 0 is
/// bit-identical to [`GenAsync`].
pub struct GenAsyncDamped {
    pub eta: f64,
    /// staleness-damping strength κ ≥ 0
    pub kappa: f64,
    /// reference sampling distribution (diagnostics + fallback)
    pub p: Vec<f64>,
    version: u64,
    received: u64,
}

impl GenAsyncDamped {
    pub fn new(eta: f64, kappa: f64, p: Vec<f64>) -> Result<GenAsyncDamped, String> {
        if !(kappa >= 0.0) || !kappa.is_finite() {
            return Err(format!(
                "genasync-damped: kappa {kappa} must be finite and >= 0"
            ));
        }
        Ok(GenAsyncDamped { eta, kappa, p, version: 0, received: 0 })
    }
}

impl ServerStrategy for GenAsyncDamped {
    fn name(&self) -> &'static str {
        "genasync-damped"
    }

    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool {
        self.received += 1;
        let damp = 1.0 + self.kappa * ctx.delay_steps as f64;
        let scale = (ipw_scale(self.eta, &self.p, ctx) / damp) as f32;
        model.apply_update(ctx.grads, scale);
        self.version += 1;
        true
    }

    fn scale_for(&self, node: usize) -> f64 {
        // nominal (fresh-gradient, M = 0) scale
        reference_scale(self.eta, &self.p, node)
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// AsyncSGD (Koloskova et al.)
// ---------------------------------------------------------------------------

pub struct AsyncSgd {
    pub eta: f64,
    version: u64,
    received: u64,
}

impl AsyncSgd {
    pub fn new(eta: f64) -> AsyncSgd {
        AsyncSgd { eta, version: 0, received: 0 }
    }
}

impl ServerStrategy for AsyncSgd {
    fn name(&self) -> &'static str {
        "async"
    }

    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool {
        self.received += 1;
        model.apply_update(ctx.grads, self.eta as f32);
        self.version += 1;
        true
    }

    fn scale_for(&self, _node: usize) -> f64 {
        self.eta
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// FedBuff (Nguyen et al.)
// ---------------------------------------------------------------------------

pub struct FedBuff {
    pub eta: f64,
    pub z: usize,
    buffer: Option<Vec<Vec<f64>>>,
    buffered: usize,
    version: u64,
    received: u64,
}

impl FedBuff {
    pub fn new(eta: f64, z: usize) -> Result<FedBuff, String> {
        if z == 0 {
            return Err("fedbuff: buffer size Z must be >= 1".into());
        }
        Ok(FedBuff { eta, z, buffer: None, buffered: 0, version: 0, received: 0 })
    }

    pub fn pending_in_buffer(&self) -> usize {
        self.buffered
    }
}

impl ServerStrategy for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool {
        self.received += 1;
        let buf = self.buffer.get_or_insert_with(|| model.accumulator());
        ModelState::accumulate(buf, ctx.grads, 1.0);
        self.buffered += 1;
        if self.buffered >= self.z {
            let buf = self.buffer.take().unwrap();
            model.apply_accumulator(&buf, self.eta / self.z as f64);
            self.buffered = 0;
            self.version += 1;
            true
        } else {
            false
        }
    }

    fn scale_for(&self, _node: usize) -> f64 {
        self.eta / self.z as f64
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// FedAvg round barrier over the asynchronous event stream
// ---------------------------------------------------------------------------

pub struct FedAvgStrategy {
    pub eta: f64,
    /// distinct clients required to close a round
    pub s: usize,
    buffer: Option<Vec<Vec<f64>>>,
    in_round: Vec<bool>,
    distinct: usize,
    grads_in_round: usize,
    version: u64,
    received: u64,
}

impl FedAvgStrategy {
    pub fn new(eta: f64, s: usize, n: usize) -> Result<FedAvgStrategy, String> {
        if s == 0 || s > n {
            return Err(format!("fedavg: round size s={s} must be in 1..={n}"));
        }
        Ok(FedAvgStrategy {
            eta,
            s,
            buffer: None,
            in_round: vec![false; n],
            distinct: 0,
            grads_in_round: 0,
            version: 0,
            received: 0,
        })
    }
}

impl ServerStrategy for FedAvgStrategy {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool {
        self.received += 1;
        let buf = self.buffer.get_or_insert_with(|| model.accumulator());
        ModelState::accumulate(buf, ctx.grads, 1.0);
        self.grads_in_round += 1;
        if !self.in_round[ctx.node] {
            self.in_round[ctx.node] = true;
            self.distinct += 1;
        }
        if self.distinct >= self.s {
            let buf = self.buffer.take().unwrap();
            model.apply_accumulator(&buf, self.eta / self.grads_in_round as f64);
            for b in self.in_round.iter_mut() {
                *b = false;
            }
            self.distinct = 0;
            self.grads_in_round = 0;
            self.version += 1;
            true
        } else {
            false
        }
    }

    fn scale_for(&self, _node: usize) -> f64 {
        self.eta / self.s as f64
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// FAVANO time-sliced averaging over the asynchronous event stream
// ---------------------------------------------------------------------------

pub struct FavanoStrategy {
    pub eta: f64,
    /// server update interval Δ (virtual time)
    pub interval: f64,
    n: usize,
    next_boundary: f64,
    buffer: Option<Vec<Vec<f64>>>,
    buffered: usize,
    version: u64,
    received: u64,
}

impl FavanoStrategy {
    pub fn new(eta: f64, interval: f64, n: usize) -> Result<FavanoStrategy, String> {
        if !(interval > 0.0) || !interval.is_finite() {
            return Err(format!("favano: interval Δ={interval} must be positive"));
        }
        if n == 0 {
            return Err("favano: need at least one client".into());
        }
        Ok(FavanoStrategy {
            eta,
            interval,
            n,
            next_boundary: interval,
            buffer: None,
            buffered: 0,
            version: 0,
            received: 0,
        })
    }
}

impl ServerStrategy for FavanoStrategy {
    fn name(&self) -> &'static str {
        "favano"
    }

    fn on_gradient(&mut self, model: &mut ModelState, ctx: &GradientCtx) -> bool {
        self.received += 1;
        let mut stepped = false;
        if ctx.time >= self.next_boundary {
            // close the previous slice before admitting this gradient
            if let Some(buf) = self.buffer.take() {
                model.apply_accumulator(&buf, self.eta / (self.n as f64 + 1.0));
                self.buffered = 0;
                self.version += 1;
                stepped = true;
            }
            while self.next_boundary <= ctx.time {
                self.next_boundary += self.interval;
            }
        }
        let buf = self.buffer.get_or_insert_with(|| model.accumulator());
        ModelState::accumulate(buf, ctx.grads, 1.0);
        self.buffered += 1;
        stepped
    }

    fn scale_for(&self, _node: usize) -> f64 {
        self.eta / (self.n as f64 + 1.0)
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Construction-time knobs shared by all strategies.  A constructor reads
/// what it needs and ignores the rest.
#[derive(Clone, Debug)]
pub struct StrategyParams {
    pub eta: f64,
    /// sampling distribution in force at construction (GenAsync reference)
    pub p: Vec<f64>,
    /// FedBuff buffer size Z
    pub fedbuff_z: usize,
    /// FedAvg round barrier (0 = auto: max(2, n/10))
    pub fedavg_s: usize,
    /// FAVANO slice length Δ in virtual time
    pub favano_interval: f64,
    /// genasync-damped staleness-damping strength κ (η/(1+κ·M))
    pub kappa: f64,
}

impl StrategyParams {
    pub fn new(eta: f64, p: Vec<f64>) -> StrategyParams {
        StrategyParams {
            eta,
            p,
            fedbuff_z: 10,
            fedavg_s: 0,
            favano_interval: 4.0,
            kappa: 0.5,
        }
    }

    pub fn n(&self) -> usize {
        self.p.len()
    }

    /// Resolved FedAvg round size (0 = auto).
    pub fn fedavg_s(&self) -> usize {
        if self.fedavg_s == 0 {
            (self.n() / 10).max(2).min(self.n().max(1))
        } else {
            self.fedavg_s
        }
    }
}

type StrategyCtor = Box<dyn Fn(&StrategyParams) -> Result<Box<dyn ServerStrategy>, String>>;

pub struct StrategyEntry {
    pub name: String,
    pub aliases: Vec<String>,
    pub summary: String,
    ctor: StrategyCtor,
}

/// String → constructor mapping for server strategies.  `builtin()` carries
/// the five paper algorithms; downstream code may `register` more without
/// touching the driver or the CLI.
pub struct StrategyRegistry {
    entries: Vec<StrategyEntry>,
}

impl StrategyRegistry {
    pub fn empty() -> StrategyRegistry {
        StrategyRegistry { entries: Vec::new() }
    }

    pub fn builtin() -> StrategyRegistry {
        let mut r = StrategyRegistry::empty();
        r.register(
            "gasync",
            &["generalized"],
            "Generalized AsyncSGD: immediate update scaled by eta/(n p_i) (Algorithm 1)",
            |prm| Ok(Box::new(GenAsync::new(prm.eta, prm.p.clone())) as Box<dyn ServerStrategy>),
        );
        r.register(
            "genasync-damped",
            &["gasync-damped"],
            "staleness-damped GenAsync: eta/(1+kappa*M) step size, keeps the eta/(n p_i) IPW",
            |prm| {
                Ok(Box::new(GenAsyncDamped::new(prm.eta, prm.kappa, prm.p.clone())?)
                    as Box<dyn ServerStrategy>)
            },
        );
        r.register(
            "async",
            &["asyncsgd"],
            "AsyncSGD (Koloskova et al.): immediate unscaled update w <- w - eta g",
            |prm| Ok(Box::new(AsyncSgd::new(prm.eta)) as Box<dyn ServerStrategy>),
        );
        r.register(
            "fedbuff",
            &[],
            "FedBuff (Nguyen et al.): buffer Z updates, apply their average once",
            |prm| {
                Ok(Box::new(FedBuff::new(prm.eta, prm.fedbuff_z)?) as Box<dyn ServerStrategy>)
            },
        );
        r.register(
            "fedavg",
            &[],
            "FedAvg round barrier over the async stream: average once s distinct clients report",
            |prm| {
                Ok(Box::new(FedAvgStrategy::new(prm.eta, prm.fedavg_s(), prm.n())?)
                    as Box<dyn ServerStrategy>)
            },
        );
        r.register(
            "favano",
            &[],
            "FAVANO time-sliced averaging: apply the slice buffer every Delta of virtual time",
            |prm| {
                Ok(Box::new(FavanoStrategy::new(prm.eta, prm.favano_interval, prm.n())?)
                    as Box<dyn ServerStrategy>)
            },
        );
        r
    }

    /// Register (or replace) a strategy constructor.
    pub fn register<F>(&mut self, name: &str, aliases: &[&str], summary: &str, ctor: F)
    where
        F: Fn(&StrategyParams) -> Result<Box<dyn ServerStrategy>, String> + 'static,
    {
        self.entries.retain(|e| e.name != name);
        self.entries.push(StrategyEntry {
            name: name.to_string(),
            aliases: aliases.iter().map(|a| a.to_string()).collect(),
            summary: summary.to_string(),
            ctor: Box::new(ctor),
        });
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries
            .iter()
            .any(|e| e.name == name || e.aliases.iter().any(|a| a == name))
    }

    pub fn build(
        &self,
        name: &str,
        params: &StrategyParams,
    ) -> Result<Box<dyn ServerStrategy>, String> {
        for e in &self.entries {
            if e.name == name || e.aliases.iter().any(|a| a == name) {
                return (e.ctor)(params);
            }
        }
        Err(format!(
            "unknown algorithm '{name}' (available: {})",
            self.names().join("|")
        ))
    }

    /// Primary names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// (name, summary) pairs for usage/help text.
    pub fn summaries(&self) -> Vec<(String, String)> {
        self.entries
            .iter()
            .map(|e| (e.name.clone(), e.summary.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{AliasTable, Rng};

    fn model1d(v: f32) -> ModelState {
        ModelState { tensors: vec![vec![v]], shapes: vec![vec![1]] }
    }

    #[test]
    fn gen_async_scaling_is_unbiased() {
        // E[update direction] = Σ p_i · (1/(n p_i)) g_i = (1/n) Σ g_i for
        // ANY p: estimate empirically with per-client constant gradients.
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let g_of = |i: usize| vec![vec![(i + 1) as f32]]; // g_i = i+1
        let mut rng = Rng::new(3);
        let alias = AliasTable::new(&p).unwrap();
        let mut total = 0.0f64;
        let trials = 200_000;
        for _ in 0..trials {
            let mut m = model1d(0.0);
            let mut s = GenAsync::new(1.0, p.clone());
            let i = alias.sample(&mut rng);
            let g = g_of(i);
            s.on_gradient(&mut m, &GradientCtx::sampled(i, &p, &g));
            total += -m.tensors[0][0] as f64; // applied step
        }
        let mean_step = total / trials as f64;
        let expected = (1.0 + 2.0 + 3.0 + 4.0) / 4.0; // (1/n)Σg_i · η
        assert!(
            (mean_step - expected).abs() < 0.02,
            "mean {mean_step} vs unbiased target {expected}"
        );
    }

    #[test]
    fn gen_async_uses_dispatch_time_probability() {
        // the ctx probability, not the reference p, drives the scale —
        // this is what keeps time-varying policies unbiased
        let p = vec![0.25; 4];
        let mut m = model1d(0.0);
        let mut s = GenAsync::new(1.0, p);
        let g = vec![vec![1.0f32]];
        let ctx = GradientCtx {
            node: 0,
            step: 0,
            time: 0.0,
            delay_steps: 0,
            dispatch_prob: 0.5, // policy had drifted to p_0 = 0.5
            grads: &g,
        };
        s.on_gradient(&mut m, &ctx);
        // scale = 1/(4·0.5) = 0.5
        assert!((m.tensors[0][0] + 0.5).abs() < 1e-7);
    }

    #[test]
    fn gen_async_damped_scales_by_inverse_staleness() {
        // a gradient with delay M = 3 under kappa = 0.5 is applied at
        // (η/(n·p))/(1 + 0.5·3) = (1/(4·0.25))/2.5 = 0.4
        let p = vec![0.25; 4];
        let mut m = model1d(0.0);
        let mut s = GenAsyncDamped::new(1.0, 0.5, p.clone()).unwrap();
        let g = vec![vec![1.0f32]];
        let ctx = GradientCtx {
            node: 1,
            step: 0,
            time: 0.0,
            delay_steps: 3,
            dispatch_prob: 0.25,
            grads: &g,
        };
        assert!(s.on_gradient(&mut m, &ctx));
        assert!((m.tensors[0][0] + 0.4).abs() < 1e-7, "got {}", m.tensors[0][0]);
        // a fresh gradient (M = 0) is not damped at all
        let mut m2 = model1d(0.0);
        let fresh = GradientCtx { delay_steps: 0, ..ctx };
        s.on_gradient(&mut m2, &fresh);
        assert!((m2.tensors[0][0] + 1.0).abs() < 1e-7);
        assert_eq!(s.version(), 2);
        assert_eq!(s.received(), 2);
    }

    #[test]
    fn gen_async_damped_with_zero_kappa_matches_gasync_bitwise() {
        // κ = 0 must reproduce GenAsync exactly — same fp operations
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let mut rng = Rng::new(29);
        let mut ma = model1d(0.0);
        let mut mb = model1d(0.0);
        let mut a = GenAsync::new(0.07, p.clone());
        let mut b = GenAsyncDamped::new(0.07, 0.0, p.clone()).unwrap();
        for k in 0..500 {
            let i = rng.usize_below(4);
            let g = vec![vec![(i as f32 + 0.5) * if k % 2 == 0 { 1.0 } else { -1.0 }]];
            let ctx = GradientCtx {
                node: i,
                step: k as u64,
                time: k as f64,
                delay_steps: (k % 7) as u64,
                dispatch_prob: p[i],
                grads: &g,
            };
            a.on_gradient(&mut ma, &ctx);
            b.on_gradient(&mut mb, &ctx);
        }
        assert_eq!(ma.tensors[0][0].to_bits(), mb.tensors[0][0].to_bits());
    }

    #[test]
    fn gen_async_damped_converges_on_stale_quadratics() {
        // ½(w − c_i)² oracle with artificial staleness: damping shrinks
        // steps but must not move the fixed point under uniform sampling
        let c = [1.0f32, 2.0, 3.0, 6.0];
        let opt = 3.0f32;
        let p = vec![0.25; 4];
        let mut m = model1d(0.0);
        let mut s = GenAsyncDamped::new(0.1, 0.3, p.clone()).unwrap();
        let mut rng = Rng::new(17);
        for _ in 0..8000 {
            let i = rng.usize_below(4);
            let g = vec![vec![m.tensors[0][0] - c[i]]];
            let mut ctx = GradientCtx::sampled(i, &p, &g);
            ctx.delay_steps = rng.usize_below(5) as u64;
            s.on_gradient(&mut m, &ctx);
        }
        let w = m.tensors[0][0];
        assert!((w - opt).abs() < 0.4, "converged to {w}, want ≈{opt}");
    }

    #[test]
    fn scale_for_guards_zero_mass_reference_entries() {
        // SimConfig only rejects p_i = 0 on ACTIVE nodes, so a reference
        // vector may carry zero-mass entries; the diagnostic scale must
        // report 0.0 for them, never inf/NaN
        let p = vec![0.0, 0.5, 0.5, 0.0];
        let a = GenAsync::new(1.0, p.clone());
        let b = GenAsyncDamped::new(1.0, 0.5, p.clone()).unwrap();
        for s in [&a as &dyn ServerStrategy, &b] {
            assert_eq!(s.scale_for(0), 0.0, "{}", s.name());
            assert_eq!(s.scale_for(3), 0.0, "{}", s.name());
            let mid = s.scale_for(1);
            assert!(mid.is_finite() && mid > 0.0, "{}: {mid}", s.name());
        }
        // malformed entries are guarded too
        let c = GenAsync::new(1.0, vec![f64::NAN, 1.0]);
        assert_eq!(c.scale_for(0), 0.0);
        // and an unusable dispatch probability WITH an unusable reference
        // entry drops the gradient instead of poisoning the model
        let mut m = model1d(1.0);
        let mut s = GenAsync::new(1.0, vec![0.0, 1.0]);
        let g = vec![vec![5.0f32]];
        let ctx = GradientCtx {
            node: 0,
            step: 0,
            time: 0.0,
            delay_steps: 0,
            dispatch_prob: 0.0,
            grads: &g,
        };
        s.on_gradient(&mut m, &ctx);
        assert_eq!(m.tensors[0][0], 1.0, "zero-scale update must be a no-op");
        assert!(m.tensors[0][0].is_finite());
    }

    #[test]
    fn async_sgd_is_gen_async_at_uniform() {
        let n = 5;
        let p = vec![1.0 / n as f64; n];
        let g = vec![vec![2.0f32]];
        let mut m1 = model1d(1.0);
        let mut m2 = model1d(1.0);
        let mut a = GenAsync::new(0.1, p.clone());
        let mut b = AsyncSgd::new(0.1);
        a.on_gradient(&mut m1, &GradientCtx::sampled(2, &p, &g));
        b.on_gradient(&mut m2, &GradientCtx::sampled(2, &p, &g));
        assert!((m1.tensors[0][0] - m2.tensors[0][0]).abs() < 1e-7);
    }

    #[test]
    fn fedbuff_waits_for_z() {
        let p = vec![0.2; 5];
        let mut m = model1d(0.0);
        let mut s = FedBuff::new(1.0, 3).unwrap();
        let g1 = vec![vec![3.0f32]];
        let g2 = vec![vec![6.0f32]];
        let g3 = vec![vec![9.0f32]];
        assert!(!s.on_gradient(&mut m, &GradientCtx::sampled(0, &p, &g1)));
        assert!(!s.on_gradient(&mut m, &GradientCtx::sampled(1, &p, &g2)));
        assert_eq!(m.tensors[0][0], 0.0); // nothing applied yet
        assert_eq!(s.pending_in_buffer(), 2);
        assert!(s.on_gradient(&mut m, &GradientCtx::sampled(2, &p, &g3)));
        // averaged update: (3+6+9)/3 = 6
        assert!((m.tensors[0][0] + 6.0).abs() < 1e-7);
        assert_eq!(s.version(), 1);
        assert_eq!(s.received(), 3);
        assert_eq!(s.pending_in_buffer(), 0);
    }

    #[test]
    fn fedbuff_multiple_rounds() {
        let p = vec![1.0 / 3.0; 3];
        let mut m = model1d(0.0);
        let mut s = FedBuff::new(0.5, 2).unwrap();
        let g = vec![vec![1.0f32]];
        for k in 0..10 {
            s.on_gradient(&mut m, &GradientCtx::sampled(k % 3, &p, &g));
        }
        assert_eq!(s.version(), 5);
        // each round applies 0.5 * avg(1,1) = 0.5
        assert!((m.tensors[0][0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn quadratic_convergence_all_immediate_rules() {
        // f_i(w) = ½(w − c_i)², optimum of the average = mean(c); the
        // immediate + buffered rules must converge there under uniform
        // arrivals.
        let c = [1.0f32, 2.0, 3.0, 6.0];
        let opt = 3.0f32;
        let p = vec![0.25; 4];
        let make: Vec<Box<dyn Fn() -> Box<dyn ServerStrategy>>> = vec![
            Box::new(|| Box::new(GenAsync::new(0.05, vec![0.25; 4])) as Box<dyn ServerStrategy>),
            Box::new(|| Box::new(AsyncSgd::new(0.05)) as Box<dyn ServerStrategy>),
            Box::new(|| Box::new(FedBuff::new(0.2, 4).unwrap()) as Box<dyn ServerStrategy>),
            Box::new(|| {
                Box::new(FedAvgStrategy::new(0.2, 4, 4).unwrap()) as Box<dyn ServerStrategy>
            }),
        ];
        for mk in make {
            let mut m = model1d(0.0);
            let mut s = mk();
            let mut rng = Rng::new(11);
            for _ in 0..4000 {
                let i = rng.usize_below(4);
                let g = vec![vec![m.tensors[0][0] - c[i]]];
                s.on_gradient(&mut m, &GradientCtx::sampled(i, &p, &g));
            }
            let w = m.tensors[0][0];
            assert!((w - opt).abs() < 0.4, "{} converged to {w}, want ≈{opt}", s.name());
        }
    }

    #[test]
    fn gen_async_nonuniform_still_converges_to_global_opt() {
        // the whole point of the 1/(np_i) scaling: heavily skewed sampling
        // must not bias the fixed point.
        let c = [0.0f32, 0.0, 0.0, 8.0];
        let opt = 2.0f32;
        let p = vec![0.4, 0.3, 0.2, 0.1]; // client 3 sampled rarely
        let alias = AliasTable::new(&p).unwrap();
        let mut m = model1d(0.0);
        let mut s = GenAsync::new(0.01, p.clone());
        let mut rng = Rng::new(13);
        let mut avg = 0.0f64;
        let steps = 60_000;
        for k in 0..steps {
            let i = alias.sample(&mut rng);
            let g = vec![vec![m.tensors[0][0] - c[i]]];
            s.on_gradient(&mut m, &GradientCtx::sampled(i, &p, &g));
            if k > steps / 2 {
                avg += m.tensors[0][0] as f64;
            }
        }
        let w = avg / (steps / 2 - 1) as f64;
        assert!((w - opt as f64).abs() < 0.25, "converged to {w}, want {opt}");
    }

    #[test]
    fn fedavg_round_closes_on_distinct_clients() {
        let p = vec![0.25; 4];
        let mut m = model1d(0.0);
        let mut s = FedAvgStrategy::new(1.0, 2, 4).unwrap();
        let g = vec![vec![4.0f32]];
        // two gradients from the SAME client do not close the round
        assert!(!s.on_gradient(&mut m, &GradientCtx::sampled(1, &p, &g)));
        assert!(!s.on_gradient(&mut m, &GradientCtx::sampled(1, &p, &g)));
        assert_eq!(m.tensors[0][0], 0.0);
        // a second distinct client does; the applied update averages all 3
        assert!(s.on_gradient(&mut m, &GradientCtx::sampled(3, &p, &g)));
        assert!((m.tensors[0][0] + 4.0).abs() < 1e-6);
        assert_eq!(s.version(), 1);
        assert_eq!(s.received(), 3);
    }

    #[test]
    fn favano_flushes_on_time_boundaries() {
        let p = vec![0.5; 2];
        let mut m = model1d(0.0);
        let mut s = FavanoStrategy::new(3.0, 1.0, 2).unwrap();
        let g = vec![vec![1.0f32]];
        let at = |t: f64, node: usize, g: &[Vec<f32>]| GradientCtx {
            node,
            step: 0,
            time: t,
            delay_steps: 0,
            dispatch_prob: 0.5,
            grads: g,
        };
        // two gradients inside the first slice: buffered, no step
        assert!(!s.on_gradient(&mut m, &at(0.2, 0, &g)));
        assert!(!s.on_gradient(&mut m, &at(0.9, 1, &g)));
        assert_eq!(m.tensors[0][0], 0.0);
        // first arrival past Δ=1 flushes the slice: 2 grads · η/(n+1) = 2·1 = 2
        assert!(s.on_gradient(&mut m, &at(1.4, 0, &g)));
        assert!((m.tensors[0][0] + 2.0).abs() < 1e-6);
        assert_eq!(s.version(), 1);
        // a long gap skips several boundaries but flushes only once
        assert!(s.on_gradient(&mut m, &at(7.9, 1, &g)));
        assert_eq!(s.version(), 2);
    }

    #[test]
    fn version_counts() {
        let p = vec![1.0];
        let mut m = model1d(0.0);
        let mut s = AsyncSgd::new(0.1);
        let g = vec![vec![0.5f32]];
        for _ in 0..7 {
            s.on_gradient(&mut m, &GradientCtx::sampled(0, &p, &g));
        }
        assert_eq!(s.version(), 7);
        assert_eq!(s.received(), 7);
    }

    #[test]
    fn registry_builds_every_builtin_and_aliases() {
        let reg = StrategyRegistry::builtin();
        let prm = StrategyParams::new(0.1, vec![0.25; 4]);
        assert_eq!(
            reg.names(),
            vec!["gasync", "genasync-damped", "async", "fedbuff", "fedavg", "favano"]
        );
        for name in reg.names() {
            let s = reg.build(&name, &prm).unwrap();
            assert_eq!(s.version(), 0);
        }
        assert_eq!(reg.build("generalized", &prm).unwrap().name(), "gasync");
        assert_eq!(reg.build("asyncsgd", &prm).unwrap().name(), "async");
        assert_eq!(
            reg.build("gasync-damped", &prm).unwrap().name(),
            "genasync-damped"
        );
        let err = reg.build("sync-sgd", &prm).unwrap_err();
        assert!(err.contains("unknown algorithm"), "{err}");
        assert!(err.contains("favano"), "error must list registered names: {err}");
    }

    #[test]
    fn registry_accepts_third_party_strategies() {
        let mut reg = StrategyRegistry::builtin();
        reg.register("frozen", &[], "applies nothing (test double)", |_prm| {
            struct Frozen {
                received: u64,
            }
            impl ServerStrategy for Frozen {
                fn name(&self) -> &'static str {
                    "frozen"
                }
                fn on_gradient(&mut self, _m: &mut ModelState, _c: &GradientCtx) -> bool {
                    self.received += 1;
                    false
                }
                fn scale_for(&self, _node: usize) -> f64 {
                    0.0
                }
                fn version(&self) -> u64 {
                    0
                }
                fn received(&self) -> u64 {
                    self.received
                }
            }
            Ok(Box::new(Frozen { received: 0 }) as Box<dyn ServerStrategy>)
        });
        let prm = StrategyParams::new(0.1, vec![0.5, 0.5]);
        let mut s = reg.build("frozen", &prm).unwrap();
        let mut m = model1d(1.0);
        let g = vec![vec![1.0f32]];
        assert!(!s.on_gradient(&mut m, &GradientCtx::sampled(0, &[0.5, 0.5], &g)));
        assert_eq!(m.tensors[0][0], 1.0);
    }

    #[test]
    fn constructors_validate() {
        assert!(GenAsyncDamped::new(0.1, -0.5, vec![0.5, 0.5]).is_err());
        assert!(GenAsyncDamped::new(0.1, f64::NAN, vec![0.5, 0.5]).is_err());
        assert!(GenAsyncDamped::new(0.1, 0.0, vec![0.5, 0.5]).is_ok());
        assert!(FedBuff::new(0.1, 0).is_err());
        assert!(FedAvgStrategy::new(0.1, 0, 4).is_err());
        assert!(FedAvgStrategy::new(0.1, 5, 4).is_err());
        assert!(FavanoStrategy::new(0.1, 0.0, 4).is_err());
        assert!(FavanoStrategy::new(0.1, -1.0, 4).is_err());
    }
}
