//! Server-side model state: a flat list of f32 tensors matching the AOT
//! artifact's parameter order (w0, b0, w1, b1, ...).

use crate::util::rng::Rng;

/// Stream id for He-normal weight initialization (R6: named so collisions
/// with other streams are auditable crate-wide).
const MODEL_INIT_STREAM: u64 = 0x1417;

#[derive(Clone, Debug)]
pub struct ModelState {
    pub tensors: Vec<Vec<f32>>,
    pub shapes: Vec<Vec<usize>>,
}

impl ModelState {
    /// He-normal init for 2-D weights (fan-in scaling), zeros for 1-D
    /// biases — mirrors the L2 model's scheme.
    pub fn init_he(shapes: &[Vec<usize>], seed: u64) -> ModelState {
        let mut rng = Rng::new(seed).derive(MODEL_INIT_STREAM);
        let tensors = shapes
            .iter()
            .map(|s| {
                let numel: usize = s.iter().product();
                let mut t = vec![0.0f32; numel];
                if s.len() == 2 {
                    rng.he_normal(s[0], &mut t);
                }
                t
            })
            .collect();
        ModelState { shapes: shapes.to_vec(), tensors }
    }

    pub fn zeros_like(&self) -> ModelState {
        ModelState {
            shapes: self.shapes.clone(),
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
        }
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// w ← w − scale · g   (the Generalized AsyncSGD server update with
    /// scale = η/(n p_i)).
    pub fn apply_update(&mut self, grads: &[Vec<f32>], scale: f32) {
        debug_assert_eq!(grads.len(), self.tensors.len());
        for (t, g) in self.tensors.iter_mut().zip(grads) {
            debug_assert_eq!(t.len(), g.len());
            for (w, gv) in t.iter_mut().zip(g) {
                *w -= scale * gv;
            }
        }
    }

    /// acc ← acc + scale · g  (buffer accumulation for FedBuff / FedAvg).
    pub fn accumulate(acc: &mut [Vec<f64>], grads: &[Vec<f32>], scale: f64) {
        for (a, g) in acc.iter_mut().zip(grads) {
            for (av, gv) in a.iter_mut().zip(g) {
                *av += scale * *gv as f64;
            }
        }
    }

    pub fn accumulator(&self) -> Vec<Vec<f64>> {
        self.tensors.iter().map(|t| vec![0.0f64; t.len()]).collect()
    }

    /// w ← w − scale · acc
    pub fn apply_accumulator(&mut self, acc: &[Vec<f64>], scale: f64) {
        for (t, a) in self.tensors.iter_mut().zip(acc) {
            for (w, av) in t.iter_mut().zip(a) {
                *w = (*w as f64 - scale * av) as f32;
            }
        }
    }

    /// Euclidean distance to another state (testing / drift metrics).
    pub fn l2_distance(&self, other: &ModelState) -> f64 {
        self.tensors
            .iter()
            .zip(&other.tensors)
            .map(|(a, b)| {
                a.iter()
                    .zip(b)
                    .map(|(x, y)| {
                        let d = *x as f64 - *y as f64;
                        d * d
                    })
                    .sum::<f64>()
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .map(|t| t.iter().map(|x| *x as f64 * *x as f64).sum::<f64>())
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![4, 3], vec![3], vec![3, 2], vec![2]]
    }

    #[test]
    fn init_shapes_and_determinism() {
        let m = ModelState::init_he(&shapes(), 5);
        assert_eq!(m.n_params(), 12 + 3 + 6 + 2);
        assert_eq!(m.tensors[1], vec![0.0; 3]); // bias zero
        assert!(m.tensors[0].iter().any(|&v| v != 0.0));
        let m2 = ModelState::init_he(&shapes(), 5);
        assert_eq!(m.tensors, m2.tensors);
        let m3 = ModelState::init_he(&shapes(), 6);
        assert_ne!(m.tensors, m3.tensors);
    }

    #[test]
    fn he_scale_reasonable() {
        let m = ModelState::init_he(&[vec![1000, 500]], 7);
        let var: f64 = m.tensors[0]
            .iter()
            .map(|v| *v as f64 * *v as f64)
            .sum::<f64>()
            / 500_000.0;
        assert!((var - 2.0 / 1000.0).abs() < 2e-4, "var={var}");
    }

    #[test]
    fn apply_update_is_sgd_step() {
        let mut m = ModelState::init_he(&shapes(), 1);
        let before = m.clone();
        let grads: Vec<Vec<f32>> = m.tensors.iter().map(|t| vec![1.0; t.len()]).collect();
        m.apply_update(&grads, 0.5);
        for (a, b) in m.tensors.iter().zip(&before.tensors) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - (y - 0.5)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn accumulator_roundtrip() {
        let mut m = ModelState::init_he(&shapes(), 2);
        let before = m.clone();
        let mut acc = m.accumulator();
        let g1: Vec<Vec<f32>> = m.tensors.iter().map(|t| vec![2.0; t.len()]).collect();
        let g2: Vec<Vec<f32>> = m.tensors.iter().map(|t| vec![4.0; t.len()]).collect();
        ModelState::accumulate(&mut acc, &g1, 0.5);
        ModelState::accumulate(&mut acc, &g2, 0.5);
        // acc = 3.0 everywhere; apply with scale 1/3 → each w drops by 1
        m.apply_accumulator(&acc, 1.0 / 3.0);
        let d = m.l2_distance(&before);
        assert!((d - (m.n_params() as f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn distances() {
        let m = ModelState::init_he(&shapes(), 3);
        assert_eq!(m.l2_distance(&m), 0.0);
        assert!(m.l2_norm() > 0.0);
    }
}
