//! FAVANO-style time-sliced asynchronous averaging (Leconte et al. 2023),
//! the second asynchronous baseline of Fig 7.
//!
//! No queues: the central server updates on a FIXED interval Δ.  Between
//! server updates every client keeps taking local SGD steps on its local
//! model (as many as fit in Δ given its speed, capped at `k_max`; a slow
//! client may contribute 0 — it is "interrupted").  At each boundary the
//! server averages its own model with all clients' local models and
//! re-broadcasts.  The paper's caveat reproduced here: Δ must be long
//! enough for slow clients to finish at least one gradient or their
//! information never enters the average.

use super::model::ModelState;
use super::oracle::GradOracle;
use crate::simulator::ServiceDist;
use crate::util::rng::Rng;

/// Stream id for FAVANO's service-time draws (R6: named so collisions
/// with other streams are auditable crate-wide).
const FAVANO_STREAM: u64 = 0xFA7A_0;

#[derive(Clone, Copy, Debug)]
pub struct FavanoConfig {
    /// server update interval Δ (virtual time)
    pub interval: f64,
    /// cap on local steps per interval (QuAFL's K)
    pub k_max: usize,
    pub eta_local: f64,
}

pub struct Favano {
    pub cfg: FavanoConfig,
    rng: Rng,
    /// per-client local models (synced to the server at each boundary)
    locals: Vec<ModelState>,
    /// per-client leftover service time carried across boundaries
    carry: Vec<f64>,
}

pub struct FavanoRound {
    pub duration: f64,
    pub mean_loss: f64,
    /// local steps contributed per client this round
    pub steps: Vec<usize>,
}

impl Favano {
    pub fn new(cfg: FavanoConfig, model: &ModelState, n: usize, seed: u64) -> Favano {
        Favano {
            cfg,
            rng: Rng::new(seed).derive(FAVANO_STREAM),
            locals: vec![model.clone(); n],
            carry: vec![0.0; n],
        }
    }

    pub fn round<O: GradOracle>(
        &mut self,
        model: &mut ModelState,
        oracle: &mut O,
        service: &[ServiceDist],
    ) -> FavanoRound {
        let n = self.locals.len();
        let mut steps = vec![0usize; n];
        let mut loss_sum = 0.0f64;
        let mut loss_cnt = 0usize;
        for ci in 0..n {
            let mut t = self.carry[ci];
            while steps[ci] < self.cfg.k_max {
                let dur = service[ci].sample(&mut self.rng);
                if t + dur > self.cfg.interval {
                    // interrupted mid-computation; remaining time carries
                    self.carry[ci] = 0.0; // interrupted work is discarded
                    break;
                }
                t += dur;
                let (loss, g) = oracle.grad(ci, &self.locals[ci]);
                self.locals[ci].apply_update(&g, self.cfg.eta_local as f32);
                steps[ci] += 1;
                loss_sum += loss;
                loss_cnt += 1;
            }
        }
        // server average: w ← (w + Σ_i w_i)/(n+1), then re-broadcast
        let mut acc = model.accumulator(); // Σ (w − w_i)
        for local in &self.locals {
            for (a, (wt, lt)) in acc.iter_mut().zip(model.tensors.iter().zip(&local.tensors)) {
                for (av, (wv, lv)) in a.iter_mut().zip(wt.iter().zip(lt)) {
                    *av += (*wv as f64) - (*lv as f64);
                }
            }
        }
        model.apply_accumulator(&acc, 1.0 / (n as f64 + 1.0));
        for local in self.locals.iter_mut() {
            *local = model.clone();
        }
        FavanoRound {
            duration: self.cfg.interval,
            mean_loss: if loss_cnt > 0 { loss_sum / loss_cnt as f64 } else { f64::NAN },
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::oracle::QuadraticOracle;
    use crate::simulator::ServiceFamily;

    #[test]
    fn fast_clients_contribute_more_steps() {
        let mut oracle = QuadraticOracle::new(vec![vec![1.0], vec![1.0]], 0.0, 1);
        let model = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
        let service = ServiceDist::from_rates(&[10.0, 0.5], ServiceFamily::Deterministic);
        let mut fv = Favano::new(
            FavanoConfig { interval: 1.0, k_max: 100, eta_local: 0.05 },
            &model,
            2,
            2,
        );
        let mut m = model.clone();
        let r = fv.round(&mut m, &mut oracle, &service);
        assert_eq!(r.steps[0], 10); // 10 services of 0.1 fit in Δ=1
        assert_eq!(r.steps[1], 0); // service of 2.0 never fits — interrupted
    }

    #[test]
    fn k_max_caps_fast_clients() {
        let mut oracle = QuadraticOracle::new(vec![vec![1.0]], 0.0, 3);
        let model = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
        let service = ServiceDist::from_rates(&[1000.0], ServiceFamily::Deterministic);
        let mut fv = Favano::new(
            FavanoConfig { interval: 1.0, k_max: 5, eta_local: 0.05 },
            &model,
            1,
            4,
        );
        let mut m = model.clone();
        let r = fv.round(&mut m, &mut oracle, &service);
        assert_eq!(r.steps[0], 5);
    }

    #[test]
    fn converges_on_quadratic() {
        let centers: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32]).collect();
        let mut oracle = QuadraticOracle::new(centers, 0.02, 5);
        let mut model = ModelState { tensors: vec![vec![10.0]], shapes: vec![vec![1]] };
        let service = ServiceDist::from_rates(&vec![2.0; 8], ServiceFamily::Exponential);
        let mut fv = Favano::new(
            FavanoConfig { interval: 2.0, k_max: 8, eta_local: 0.15 },
            &model,
            8,
            6,
        );
        for _ in 0..250 {
            fv.round(&mut model, &mut oracle, &service);
        }
        let w = model.tensors[0][0];
        assert!((w - 3.5).abs() < 0.5, "w={w}, want ≈3.5");
    }

    #[test]
    fn interval_too_short_stalls_slow_info() {
        // if NO client can finish a step, the model must stay unchanged
        let mut oracle = QuadraticOracle::new(vec![vec![5.0]], 0.0, 7);
        let mut model = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
        let service = ServiceDist::from_rates(&[0.1], ServiceFamily::Deterministic);
        let mut fv = Favano::new(
            FavanoConfig { interval: 1.0, k_max: 10, eta_local: 0.1 },
            &model,
            1,
            8,
        );
        let r = fv.round(&mut model, &mut oracle, &service);
        assert_eq!(r.steps[0], 0);
        assert_eq!(model.tensors[0][0], 0.0);
    }
}
