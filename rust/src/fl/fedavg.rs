//! FedAvg (McMahan et al. 2017) — the synchronous baseline of Fig 7.
//!
//! Each round the server samples `s` clients uniformly, broadcasts the
//! model, each client runs exactly `k_local` local SGD steps, and the
//! server averages the returned models.  The round's wall time is the MAX
//! over the selected clients of their total compute time (the synchronous
//! straggler penalty the asynchronous methods avoid).

use super::model::ModelState;
use super::oracle::GradOracle;
use crate::simulator::ServiceDist;
use crate::util::rng::Rng;

/// Stream id for FedAvg's client-sampling draws (R6: named so collisions
/// with other streams are auditable crate-wide).
const FEDAVG_STREAM: u64 = 0xFEDA;

#[derive(Clone, Copy, Debug)]
pub struct FedAvgConfig {
    /// clients per round
    pub s: usize,
    /// local steps per selected client
    pub k_local: usize,
    /// local learning rate
    pub eta_local: f64,
}

pub struct FedAvg {
    pub cfg: FedAvgConfig,
    rng: Rng,
}

/// Result of one synchronous round.
pub struct RoundOutcome {
    /// wall-clock (virtual) duration of the round = max client time
    pub duration: f64,
    /// mean local training loss over participating clients
    pub mean_loss: f64,
    pub participants: Vec<usize>,
}

impl FedAvg {
    pub fn new(cfg: FedAvgConfig, seed: u64) -> FedAvg {
        FedAvg { cfg, rng: Rng::new(seed).derive(FEDAVG_STREAM) }
    }

    pub fn round<O: GradOracle>(
        &mut self,
        model: &mut ModelState,
        oracle: &mut O,
        service: &[ServiceDist],
    ) -> RoundOutcome {
        let n = oracle.n_clients();
        let s = self.cfg.s.min(n);
        let participants = self.rng.sample_distinct(n, s);
        let mut acc = model.accumulator(); // sum of (w_i − w)
        let mut max_time = 0.0f64;
        let mut loss_sum = 0.0f64;
        for &ci in &participants {
            let mut local = model.clone();
            let mut t = 0.0;
            for _ in 0..self.cfg.k_local {
                let (loss, g) = oracle.grad(ci, &local);
                local.apply_update(&g, self.cfg.eta_local as f32);
                t += service[ci].sample(&mut self.rng);
                loss_sum += loss / (s * self.cfg.k_local) as f64;
            }
            // accumulate the model delta w − w_local (so apply_accumulator
            // with scale 1/s implements model averaging)
            for (a, (wt, lt)) in acc.iter_mut().zip(model.tensors.iter().zip(&local.tensors)) {
                for (av, (wv, lv)) in a.iter_mut().zip(wt.iter().zip(lt)) {
                    *av += (*wv as f64) - (*lv as f64);
                }
            }
            max_time = max_time.max(t);
        }
        model.apply_accumulator(&acc, 1.0 / s as f64);
        RoundOutcome { duration: max_time, mean_loss: loss_sum, participants }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::oracle::QuadraticOracle;
    use crate::simulator::ServiceFamily;

    fn service(n: usize) -> Vec<ServiceDist> {
        ServiceDist::from_rates(&vec![1.0; n], ServiceFamily::Exponential)
    }

    #[test]
    fn round_averages_models() {
        // two clients, deterministic gradients, s = n: after one round with
        // k_local=1, w moves toward mean of centers
        let mut oracle = QuadraticOracle::new(vec![vec![0.0], vec![4.0]], 0.0, 1);
        let mut model = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
        let mut fa = FedAvg::new(FedAvgConfig { s: 2, k_local: 1, eta_local: 0.5 }, 2);
        let out = fa.round(&mut model, &mut oracle, &service(2));
        // each local: w0=0: client0 grad 0 → stays 0; client1 grad −4 →
        // 0 + 0.5·4 = 2; average = 1
        assert!((model.tensors[0][0] - 1.0).abs() < 1e-6);
        assert_eq!(out.participants.len(), 2);
        assert!(out.duration > 0.0);
    }

    #[test]
    fn converges_to_global_mean() {
        let centers: Vec<Vec<f32>> = (0..10).map(|i| vec![i as f32]).collect();
        let mut oracle = QuadraticOracle::new(centers, 0.05, 3);
        let mut model = ModelState { tensors: vec![vec![20.0]], shapes: vec![vec![1]] };
        let mut fa = FedAvg::new(FedAvgConfig { s: 10, k_local: 3, eta_local: 0.2 }, 4);
        for _ in 0..100 {
            fa.round(&mut model, &mut oracle, &service(10));
        }
        let w = model.tensors[0][0];
        assert!((w - 4.5).abs() < 0.3, "w={w}, want ≈4.5");
    }

    #[test]
    fn partial_participation_still_converges() {
        let centers: Vec<Vec<f32>> = (0..20).map(|i| vec![(i % 5) as f32]).collect();
        let mut oracle = QuadraticOracle::new(centers, 0.0, 5);
        let mut model = ModelState { tensors: vec![vec![-3.0]], shapes: vec![vec![1]] };
        let mut fa = FedAvg::new(FedAvgConfig { s: 5, k_local: 2, eta_local: 0.3 }, 6);
        for _ in 0..300 {
            fa.round(&mut model, &mut oracle, &service(20));
        }
        let w = model.tensors[0][0];
        assert!((w - 2.0).abs() < 0.4, "w={w}, want ≈2.0");
    }

    #[test]
    fn straggler_penalty_round_time_is_max() {
        // one very slow client (rate 0.01): rounds including it take long
        let mut oracle = QuadraticOracle::new(vec![vec![0.0], vec![0.0]], 0.0, 7);
        let mut model = ModelState { tensors: vec![vec![0.0]], shapes: vec![vec![1]] };
        let service = ServiceDist::from_rates(&[100.0, 0.01], ServiceFamily::Deterministic);
        let mut fa = FedAvg::new(FedAvgConfig { s: 2, k_local: 1, eta_local: 0.1 }, 8);
        let out = fa.round(&mut model, &mut oracle, &service);
        assert!((out.duration - 100.0).abs() < 1e-9, "round limited by straggler");
    }
}
