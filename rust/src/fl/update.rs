//! Server update rules for the asynchronous algorithms (Algorithm 1 and
//! baselines).  Pure state machines over gradient arrivals — independent of
//! the queueing dynamics and the gradient backend, hence unit-testable on
//! synthetic oracles.
//!
//! * `GenAsync` — the paper's contribution: immediate update scaled by
//!   `η/(n p_i)` to keep the aggregate direction unbiased under non-uniform
//!   sampling (line 10 of Algorithm 1).
//! * `AsyncSgd` — Koloskova et al.: uniform sampling, immediate update
//!   `w ← w − η g` (the special case p_i = 1/n of the above).
//! * `FedBuff` — Nguyen et al.: server buffers Z client updates, then
//!   applies their average once.

use super::model::ModelState;

#[derive(Clone, Debug)]
pub enum UpdateRule {
    GenAsync { eta: f64, p: Vec<f64> },
    AsyncSgd { eta: f64 },
    FedBuff { eta: f64, z: usize },
}

/// Mutable server-side algorithm state.
pub struct ServerAlgo {
    pub rule: UpdateRule,
    buffer: Option<Vec<Vec<f64>>>,
    buffered: usize,
    /// CS model version counter (k in the paper): bumps on every applied
    /// server update
    pub version: u64,
    /// total gradients received (≥ version for FedBuff)
    pub received: u64,
}

impl ServerAlgo {
    pub fn new(rule: UpdateRule) -> ServerAlgo {
        ServerAlgo { rule, buffer: None, buffered: 0, version: 0, received: 0 }
    }

    /// Effective per-gradient scale for client i (diagnostics + tests).
    pub fn scale_for(&self, node: usize) -> f64 {
        match &self.rule {
            UpdateRule::GenAsync { eta, p } => eta / (p.len() as f64 * p[node]),
            UpdateRule::AsyncSgd { eta } => *eta,
            UpdateRule::FedBuff { eta, z } => eta / *z as f64,
        }
    }

    /// A gradient from client `node` arrives at the server.
    /// Returns true iff the global model stepped (version bumped).
    pub fn on_gradient(
        &mut self,
        model: &mut ModelState,
        node: usize,
        grads: &[Vec<f32>],
    ) -> bool {
        self.received += 1;
        match &self.rule {
            UpdateRule::GenAsync { eta, p } => {
                let scale = (*eta / (p.len() as f64 * p[node])) as f32;
                model.apply_update(grads, scale);
                self.version += 1;
                true
            }
            UpdateRule::AsyncSgd { eta } => {
                model.apply_update(grads, *eta as f32);
                self.version += 1;
                true
            }
            UpdateRule::FedBuff { eta, z } => {
                let (eta, z) = (*eta, *z);
                let buf = self.buffer.get_or_insert_with(|| model.accumulator());
                ModelState::accumulate(buf, grads, 1.0);
                self.buffered += 1;
                if self.buffered >= z {
                    let buf = self.buffer.take().unwrap();
                    model.apply_accumulator(&buf, eta / z as f64);
                    self.buffered = 0;
                    self.version += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    pub fn pending_in_buffer(&self) -> usize {
        self.buffered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{AliasTable, Rng};

    fn model1d(v: f32) -> ModelState {
        ModelState { tensors: vec![vec![v]], shapes: vec![vec![1]] }
    }

    #[test]
    fn gen_async_scaling_is_unbiased() {
        // E[update direction] = Σ p_i · (1/(n p_i)) g_i = (1/n) Σ g_i for
        // ANY p: estimate empirically with per-client constant gradients.
        let n = 4;
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let g_of = |i: usize| vec![vec![(i + 1) as f32]]; // g_i = i+1
        let mut rng = Rng::new(3);
        let alias = AliasTable::new(&p).unwrap();
        let eta = 1.0;
        let mut total = 0.0f64;
        let trials = 200_000;
        for _ in 0..trials {
            let mut m = model1d(0.0);
            let mut s = ServerAlgo::new(UpdateRule::GenAsync { eta, p: p.clone() });
            let i = alias.sample(&mut rng);
            s.on_gradient(&mut m, i, &g_of(i));
            total += -m.tensors[0][0] as f64; // applied step
        }
        let mean_step = total / trials as f64;
        let expected = (1.0 + 2.0 + 3.0 + 4.0) / 4.0; // (1/n)Σg_i · η
        assert!(
            (mean_step - expected).abs() < 0.02,
            "mean {mean_step} vs unbiased target {expected}"
        );
    }

    #[test]
    fn async_sgd_is_gen_async_at_uniform() {
        let n = 5;
        let p = vec![1.0 / n as f64; n];
        let g = vec![vec![2.0f32]];
        let mut m1 = model1d(1.0);
        let mut m2 = model1d(1.0);
        let mut a = ServerAlgo::new(UpdateRule::GenAsync { eta: 0.1, p });
        let mut b = ServerAlgo::new(UpdateRule::AsyncSgd { eta: 0.1 });
        a.on_gradient(&mut m1, 2, &g);
        b.on_gradient(&mut m2, 2, &g);
        assert!((m1.tensors[0][0] - m2.tensors[0][0]).abs() < 1e-7);
    }

    #[test]
    fn fedbuff_waits_for_z() {
        let mut m = model1d(0.0);
        let mut s = ServerAlgo::new(UpdateRule::FedBuff { eta: 1.0, z: 3 });
        assert!(!s.on_gradient(&mut m, 0, &[vec![3.0]]));
        assert!(!s.on_gradient(&mut m, 1, &[vec![6.0]]));
        assert_eq!(m.tensors[0][0], 0.0); // nothing applied yet
        assert_eq!(s.pending_in_buffer(), 2);
        assert!(s.on_gradient(&mut m, 2, &[vec![9.0]]));
        // averaged update: (3+6+9)/3 = 6
        assert!((m.tensors[0][0] + 6.0).abs() < 1e-7);
        assert_eq!(s.version, 1);
        assert_eq!(s.received, 3);
        assert_eq!(s.pending_in_buffer(), 0);
    }

    #[test]
    fn fedbuff_multiple_rounds() {
        let mut m = model1d(0.0);
        let mut s = ServerAlgo::new(UpdateRule::FedBuff { eta: 0.5, z: 2 });
        for k in 0..10 {
            s.on_gradient(&mut m, k % 3, &[vec![1.0]]);
        }
        assert_eq!(s.version, 5);
        // each round applies 0.5 * avg(1,1) = 0.5
        assert!((m.tensors[0][0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn quadratic_convergence_all_rules() {
        // f_i(w) = ½(w − c_i)², optimum of the average = mean(c); all three
        // rules must converge there under uniform arrivals.
        let c = [1.0f32, 2.0, 3.0, 6.0];
        let opt = 3.0f32;
        for rule in [
            UpdateRule::GenAsync { eta: 0.05, p: vec![0.25; 4] },
            UpdateRule::AsyncSgd { eta: 0.05 },
            UpdateRule::FedBuff { eta: 0.2, z: 4 },
        ] {
            let mut m = model1d(0.0);
            let mut s = ServerAlgo::new(rule.clone());
            let mut rng = Rng::new(11);
            for _ in 0..4000 {
                let i = rng.usize_below(4);
                let g = vec![vec![m.tensors[0][0] - c[i]]];
                s.on_gradient(&mut m, i, &g);
            }
            let w = m.tensors[0][0];
            assert!(
                (w - opt).abs() < 0.4,
                "{rule:?} converged to {w}, want ≈{opt}"
            );
        }
    }

    #[test]
    fn gen_async_nonuniform_still_converges_to_global_opt() {
        // the whole point of the 1/(np_i) scaling: heavily skewed sampling
        // must not bias the fixed point.
        let c = [0.0f32, 0.0, 0.0, 8.0];
        let opt = 2.0f32;
        let p = vec![0.4, 0.3, 0.2, 0.1]; // client 3 sampled rarely
        let alias = AliasTable::new(&p).unwrap();
        let mut m = model1d(0.0);
        let mut s = ServerAlgo::new(UpdateRule::GenAsync { eta: 0.01, p: p.clone() });
        let mut rng = Rng::new(13);
        let mut avg = 0.0f64;
        let steps = 60_000;
        for k in 0..steps {
            let i = alias.sample(&mut rng);
            let g = vec![vec![m.tensors[0][0] - c[i]]];
            s.on_gradient(&mut m, i, &g);
            if k > steps / 2 {
                avg += m.tensors[0][0] as f64;
            }
        }
        let w = avg / (steps / 2 - 1) as f64;
        assert!((w - opt as f64).abs() < 0.25, "converged to {w}, want {opt}");
    }

    #[test]
    fn version_counts() {
        let mut m = model1d(0.0);
        let mut s = ServerAlgo::new(UpdateRule::AsyncSgd { eta: 0.1 });
        for _ in 0..7 {
            s.on_gradient(&mut m, 0, &[vec![0.5]]);
        }
        assert_eq!(s.version, 7);
        assert_eq!(s.received, 7);
    }
}
