//! Gradient-oracle abstraction: the algorithms only need "stochastic
//! gradient of client i at model w".  Production oracle = runtime backend
//! (PJRT / native) over the client's data shard; test oracle = synthetic
//! quadratics.

use super::model::ModelState;

pub trait GradOracle {
    /// Stochastic gradient of client `client`'s objective at `model`.
    /// Returns (loss, grads) with grads matching model.tensors layout.
    fn grad(&mut self, client: usize, model: &ModelState) -> (f64, Vec<Vec<f32>>);

    /// Number of clients.
    fn n_clients(&self) -> usize;
}

/// f_i(w) = ½‖w − c_i‖² with optional additive Gaussian-ish noise — the
/// classic testbed: the global optimum is the mean of the c_i.
pub struct QuadraticOracle {
    pub centers: Vec<Vec<f32>>,
    pub noise: f32,
    rng: crate::util::rng::Rng,
}

impl QuadraticOracle {
    pub fn new(centers: Vec<Vec<f32>>, noise: f32, seed: u64) -> QuadraticOracle {
        QuadraticOracle { centers, noise, rng: crate::util::rng::Rng::new(seed) }
    }

    pub fn optimum(&self) -> Vec<f32> {
        let d = self.centers[0].len();
        let mut opt = vec![0.0f32; d];
        for c in &self.centers {
            for (o, v) in opt.iter_mut().zip(c) {
                *o += v / self.centers.len() as f32;
            }
        }
        opt
    }
}

impl GradOracle for QuadraticOracle {
    fn grad(&mut self, client: usize, model: &ModelState) -> (f64, Vec<Vec<f32>>) {
        let c = &self.centers[client];
        let w = &model.tensors[0];
        let mut g = Vec::with_capacity(w.len());
        let mut loss = 0.0f64;
        for (wv, cv) in w.iter().zip(c) {
            let d = wv - cv;
            loss += 0.5 * (d as f64) * (d as f64);
            g.push(d + self.noise * self.rng.normal() as f32);
        }
        (loss, vec![g])
    }

    fn n_clients(&self) -> usize {
        self.centers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_oracle_gradient_points_at_center() {
        let mut o = QuadraticOracle::new(vec![vec![2.0, -1.0]], 0.0, 1);
        let m = ModelState { tensors: vec![vec![0.0, 0.0]], shapes: vec![vec![2]] };
        let (loss, g) = o.grad(0, &m);
        assert_eq!(g[0], vec![-2.0, 1.0]);
        assert!((loss - 2.5).abs() < 1e-12);
    }

    #[test]
    fn optimum_is_mean() {
        let o = QuadraticOracle::new(vec![vec![0.0], vec![4.0]], 0.0, 1);
        assert_eq!(o.optimum(), vec![2.0]);
    }
}
