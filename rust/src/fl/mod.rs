//! Federated-learning algorithm zoo: the paper's **Generalized AsyncSGD**
//! plus the baselines it is evaluated against (AsyncSGD, FedBuff, FedAvg,
//! FAVANO).  Algorithms are expressed as backend-agnostic update rules /
//! round engines over a [`oracle::GradOracle`]; the coordinator binds them
//! to queueing dynamics and the PJRT/native gradient backends.

pub mod favano;
pub mod fedavg;
pub mod model;
pub mod oracle;
pub mod update;

pub use favano::{Favano, FavanoConfig};
pub use fedavg::{FedAvg, FedAvgConfig};
pub use model::ModelState;
pub use oracle::{GradOracle, QuadraticOracle};
pub use update::{ServerAlgo, UpdateRule};
