//! Federated-learning algorithm zoo: the paper's **Generalized AsyncSGD**
//! plus the baselines it is evaluated against (AsyncSGD, FedBuff, FedAvg,
//! FAVANO).  Asynchronous algorithms implement the open [`ServerStrategy`]
//! trait and are constructed through the [`StrategyRegistry`]; the
//! round-based FedAvg/FAVANO engines additionally exist as virtual-time
//! round engines over a [`oracle::GradOracle`] for the Fig-7 comparison.
//! The coordinator binds strategies to queueing dynamics and the
//! PJRT/native gradient backends.

pub mod favano;
pub mod fedavg;
pub mod model;
pub mod oracle;
pub mod strategy;

pub use favano::{Favano, FavanoConfig};
pub use fedavg::{FedAvg, FedAvgConfig};
pub use model::ModelState;
pub use oracle::{GradOracle, QuadraticOracle};
pub use strategy::{
    AsyncSgd, FavanoStrategy, FedAvgStrategy, FedBuff, GenAsync, GenAsyncDamped, GradientCtx,
    ServerStrategy, StrategyParams, StrategyRegistry,
};
