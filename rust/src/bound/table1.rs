//! Table 1 comparators: the FedBuff and AsyncSGD non-convex bounds, which
//! depend on the intractable delay statistics τ_max / τ_c / τ_sum instead
//! of the expected queueing delays m_i.
//!
//!   FedBuff   : A/(η(T+1)) + ηLB + η² τ_max² L² B n,   η ≤ 1/(L √τ_max³)
//!   AsyncSGD  : A/(η(T+1)) + ηLB + η² τ_c L² B Σ_i τ_sum^i/(T+1),
//!                                                  η ≤ 1/(L √(τ_c τ_max))
//!
//! τ quantities come either from a simulation run (`DelayStats::from_sim`)
//! or from the deterministic-service worst case the paper uses for Fig 4:
//! τ_max = C × (work time of a slow client) × (CS step rate) — with
//! deterministic service every queued task of a slow node waits the full
//! queue ahead of it.  With exponential service τ_max is unbounded and
//! these bounds are vacuous (the paper's point); `exponential_tau_max`
//! returns the (finite) empirical max which GROWS with T.

use super::theorem1::{BoundParams, EtaPoly};
use crate::simulator::SimResult;

/// Delay statistics consumed by the baseline bounds.
#[derive(Clone, Debug)]
pub struct DelayStats {
    /// maximum delay in CS steps
    pub tau_max: f64,
    /// average number of concurrently active (busy) nodes
    pub tau_c: f64,
    /// Koloskova's τ_sum^i = Σ_{k≤T} m_{i,k}^T — the per-node delay summed
    /// over server steps; in the stationary regime τ_sum^i/(T+1) = m_i, so
    /// we store Σ_i τ_sum^i/(T+1) = Σ_i m_i directly.
    pub tau_sum_avg: f64,
}

impl DelayStats {
    pub fn from_sim(res: &SimResult, _t: u64) -> Self {
        DelayStats {
            tau_max: res.tau_max as f64,
            tau_c: res.tau_c,
            tau_sum_avg: res
                .delay_steps
                .iter()
                .map(|w| if w.count() > 0 { w.mean() } else { 0.0 })
                .sum(),
        }
    }

    /// Deterministic-service worst case of the paper's Fig-4 scenario:
    /// all C tasks pile on one slow client ⇒ the newest waits C services,
    /// during which every other node keeps stepping: τ_max ≈ C · λ/μ_slow
    /// CS steps (λ = Σμ: every service elsewhere is one step).
    /// The paper uses the cruder "C × work-time of a slow client" measured
    /// in steps via the mean step rate; both are exposed.
    pub fn deterministic_worst_case(
        c: usize,
        mu_slow: f64,
        lambda_total: f64,
        tau_c: f64,
        tau_sum_avg: f64,
    ) -> Self {
        DelayStats {
            tau_max: c as f64 * lambda_total / mu_slow,
            tau_c,
            tau_sum_avg,
        }
    }
}

/// FedBuff bound (Nguyen et al. 2022, as summarized in Table 1).
pub fn fedbuff_poly(params: &BoundParams, stats: &DelayStats) -> EtaPoly {
    EtaPoly {
        inv: params.a / (params.t as f64 + 1.0),
        lin: params.l * params.b,
        quad: stats.tau_max * stats.tau_max * params.l * params.l * params.b * params.n as f64,
    }
}

/// Table 1 states all bounds "up to numerical constants".  For a fair
/// cross-method comparison we instantiate every step-size cap with the SAME
/// constant convention as Theorem 1's η_max (which carries an explicit
/// 1/(4L) prefactor) — otherwise the comparison would hinge on constants
/// the analyses never optimized.
const CAP_CONST: f64 = 0.25;

pub fn fedbuff_eta_max(params: &BoundParams, stats: &DelayStats) -> f64 {
    CAP_CONST / (params.l * stats.tau_max.powf(1.5))
}

/// AsyncSGD bound (Koloskova et al. 2022, Table 1).
pub fn async_sgd_poly(params: &BoundParams, stats: &DelayStats) -> EtaPoly {
    EtaPoly {
        inv: params.a / (params.t as f64 + 1.0),
        lin: params.l * params.b,
        quad: stats.tau_c * params.l * params.l * params.b * stats.tau_sum_avg,
    }
}

pub fn async_sgd_eta_max(params: &BoundParams, stats: &DelayStats) -> f64 {
    CAP_CONST / (params.l * (stats.tau_c * stats.tau_max).sqrt())
}

/// Optimize a baseline bound over η within its step-size cap.
pub fn optimize(poly: &EtaPoly, eta_cap: f64) -> (f64, f64) {
    let eta = poly.unconstrained_min().min(eta_cap);
    (eta, poly.eval(eta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{run, ServiceDist, ServiceFamily, SimConfig};

    fn params() -> BoundParams {
        BoundParams::worked_example(10)
    }

    fn sim_stats() -> DelayStats {
        let n = 10;
        let rates: Vec<f64> = (0..n).map(|i| if i < 5 { 2.0 } else { 1.0 }).collect();
        let cfg = SimConfig {
            seed: 11,
            ..SimConfig::new(
                vec![0.1; 10],
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                10,
                10_000,
            )
        };
        let res = run(cfg).unwrap();
        DelayStats::from_sim(&res, 10_000)
    }

    #[test]
    fn stats_from_sim_sane() {
        let s = sim_stats();
        assert!(s.tau_max > 0.0);
        assert!(s.tau_c > 1.0 && s.tau_c <= 10.0);
        assert!(s.tau_sum_avg > 0.0);
        // τ_max far exceeds the per-node average delay (the paper's
        // argument for dropping τ_max-based analyses)
        assert!(s.tau_max > s.tau_sum_avg / 10.0);
    }

    #[test]
    fn fedbuff_bound_blows_up_with_tau_max() {
        let p = params();
        let mut s = sim_stats();
        let (_, g1) = optimize(&fedbuff_poly(&p, &s), fedbuff_eta_max(&p, &s));
        s.tau_max *= 100.0;
        let (_, g2) = optimize(&fedbuff_poly(&p, &s), fedbuff_eta_max(&p, &s));
        assert!(g2 > g1, "τ_max↑ must worsen FedBuff bound: {g1} -> {g2}");
    }

    #[test]
    fn async_sgd_eta_cap_shrinks_with_tau() {
        let p = params();
        let s = sim_stats();
        let cap = async_sgd_eta_max(&p, &s);
        let s2 = DelayStats { tau_max: s.tau_max * 4.0, ..s.clone() };
        assert!(async_sgd_eta_max(&p, &s2) < cap);
    }

    #[test]
    fn deterministic_worst_case_scales_with_c() {
        let a = DelayStats::deterministic_worst_case(10, 1.0, 15.0, 5.0, 10.0);
        let b = DelayStats::deterministic_worst_case(100, 1.0, 15.0, 5.0, 10.0);
        assert!((b.tau_max / a.tau_max - 10.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_bounds_positive_and_finite() {
        let p = params();
        let s = sim_stats();
        for (poly, cap) in [
            (fedbuff_poly(&p, &s), fedbuff_eta_max(&p, &s)),
            (async_sgd_poly(&p, &s), async_sgd_eta_max(&p, &s)),
        ] {
            let (eta, g) = optimize(&poly, cap);
            assert!(eta > 0.0 && eta.is_finite());
            assert!(g > 0.0 && g.is_finite());
        }
    }
}
