//! The (p, η) optimizer — Algorithm 1's "Compute optimal (p, η) by
//! minimizing (3)" step, specialized (as in the paper's §2 worked example
//! and all figures) to 2-cluster fast/slow populations where p is a single
//! scalar: the probability of selecting each fast client.
//!
//! m_i can be supplied by exact Jackson theory (fast — default) or by the
//! Monte-Carlo simulator (the paper's own approach in App E); they agree
//! within MC noise (see integration tests).

use super::table1::{self, DelayStats};
use super::theorem1::{BoundParams, Theorem1};
use crate::queueing::{ClosedNetwork, MiEstimator, TwoCluster};
use crate::simulator::{run, ServiceDist, ServiceFamily, SimConfig};

/// Where the delay estimates m_i come from.
#[derive(Clone, Copy, Debug)]
pub enum MiSource {
    /// exact arrival-theorem analysis, with the chosen step-rate estimator
    Theory(MiEstimator),
    /// event-driven simulation: (steps, service family, seed)
    MonteCarlo { steps: u64, family: ServiceFamily, seed: u64 },
}

impl Default for MiSource {
    fn default() -> Self {
        // Throughput-rate refinement: CS steps accrue at the stationary step
        // rate Λ(C), not the total capacity Σμ.  In light traffic (C ≪ n)
        // the Prop-5 bound with λ = Σμ overestimates m_i by orders of
        // magnitude; Λ(C) tracks the simulator within a few percent at all
        // loads (see tests + integration tests).
        MiSource::Theory(MiEstimator::Throughput)
    }
}

/// Study of the bound over the fast-selection probability p.
#[derive(Clone, Debug)]
pub struct TwoClusterStudy {
    pub params: BoundParams,
    pub n_fast: usize,
    pub mu_fast: f64,
    pub mu_slow: f64,
    pub source: MiSource,
}

/// One evaluated point of the study.
#[derive(Clone, Copy, Debug)]
pub struct BoundPoint {
    /// per-fast-node selection probability
    pub p_fast: f64,
    /// optimal step size at this p
    pub eta: f64,
    /// η_max(p)
    pub eta_max: f64,
    /// optimized bound value G(p, η*)
    pub bound: f64,
    /// fast/slow delay estimates used
    pub m_fast: f64,
    pub m_slow: f64,
    /// stationary CS step rate λ(p) (physical-time studies)
    pub cs_rate: f64,
}

impl TwoClusterStudy {
    pub fn cluster(&self, p_fast: f64) -> TwoCluster {
        TwoCluster {
            n: self.params.n,
            n_fast: self.n_fast,
            mu_fast: self.mu_fast,
            mu_slow: self.mu_slow,
            p_fast,
            c: self.params.c,
        }
    }

    /// Largest admissible p (slow-node probability must stay positive).
    pub fn p_max(&self) -> f64 {
        1.0 / self.n_fast as f64
    }

    /// Per-node delays m_i and the CS step rate for a given p.
    pub fn delays(&self, p_fast: f64) -> Result<(Vec<f64>, f64), String> {
        let tc = self.cluster(p_fast);
        tc.valid()?;
        match self.source {
            MiSource::Theory(est) => {
                let net = ClosedNetwork::new(tc.p_vec(), tc.mu_vec())?;
                let an = net.mi_analysis(self.params.c, est);
                Ok((an.m, an.cs_rate))
            }
            MiSource::MonteCarlo { steps, family, seed } => {
                let cfg = SimConfig {
                    seed,
                    ..SimConfig::new(
                        tc.p_vec(),
                        ServiceDist::from_rates(&tc.mu_vec(), family),
                        self.params.c,
                        steps,
                    )
                };
                let res = run(cfg)?;
                // unobserved nodes fall back to the theory estimate
                let net = ClosedNetwork::new(tc.p_vec(), tc.mu_vec())?;
                let theory = net.mi_analysis(self.params.c, MiEstimator::Throughput);
                let m: Vec<f64> = res
                    .m_empirical()
                    .iter()
                    .zip(&theory.m)
                    .map(|(&emp, &th)| if emp.is_nan() { th } else { emp })
                    .collect();
                Ok((m, res.step_rate(steps)))
            }
        }
    }

    /// Evaluate the optimized bound at a given p.
    pub fn evaluate(&self, p_fast: f64) -> Result<BoundPoint, String> {
        let tc = self.cluster(p_fast);
        tc.valid()?;
        let (m, cs_rate) = self.delays(p_fast)?;
        let th = Theorem1::new(self.params, tc.p_vec(), m.clone())?;
        let (eta, bound) = th.optimize_eta();
        let n_f = self.n_fast;
        Ok(BoundPoint {
            p_fast,
            eta,
            eta_max: th.eta_max(),
            bound,
            m_fast: m[..n_f].iter().sum::<f64>() / n_f as f64,
            m_slow: m[n_f..].iter().sum::<f64>() / (self.params.n - n_f) as f64,
            cs_rate,
        })
    }

    /// Physical-time variant (App E.2): fix a time budget U and set
    /// T = λ(p)·U, so slower-stepping configurations get fewer CS steps.
    pub fn evaluate_physical_time(&self, p_fast: f64, u: f64) -> Result<BoundPoint, String> {
        let tc = self.cluster(p_fast);
        tc.valid()?;
        let (m, cs_rate) = self.delays(p_fast)?;
        let t_eff = (cs_rate * u).max(1.0) as u64;
        let params = BoundParams { t: t_eff, ..self.params };
        let th = Theorem1::new(params, tc.p_vec(), m.clone())?;
        let (eta, bound) = th.optimize_eta();
        let n_f = self.n_fast;
        Ok(BoundPoint {
            p_fast,
            eta,
            eta_max: th.eta_max(),
            bound,
            m_fast: m[..n_f].iter().sum::<f64>() / n_f as f64,
            m_slow: m[n_f..].iter().sum::<f64>() / (self.params.n - n_f) as f64,
            cs_rate,
        })
    }

    /// Log-spaced grid over (p_lo, p_max) — the paper sweeps 50 values.
    pub fn p_grid(&self, points: usize) -> Vec<f64> {
        let lo: f64 = (self.p_max() * 1e-3).max(1e-6);
        let hi = self.p_max() * 0.999;
        (0..points)
            .map(|i| {
                let t = i as f64 / (points - 1) as f64;
                lo * (hi / lo).powf(t)
            })
            .collect()
    }

    /// Minimize over the grid; returns (best point, uniform point).
    pub fn optimize_p(&self, points: usize) -> Result<(BoundPoint, BoundPoint), String> {
        let uniform = self.evaluate(1.0 / self.params.n as f64)?;
        let mut best = uniform;
        for p in self.p_grid(points) {
            if let Ok(pt) = self.evaluate(p) {
                if pt.bound < best.bound {
                    best = pt;
                }
            }
        }
        Ok((best, uniform))
    }

    /// Same sweep under the physical-time objective.
    pub fn optimize_p_physical(
        &self,
        points: usize,
        u: f64,
    ) -> Result<(BoundPoint, BoundPoint), String> {
        let uniform = self.evaluate_physical_time(1.0 / self.params.n as f64, u)?;
        let mut best = uniform;
        for p in self.p_grid(points) {
            if let Ok(pt) = self.evaluate_physical_time(p, u) {
                if pt.bound < best.bound {
                    best = pt;
                }
            }
        }
        Ok((best, uniform))
    }

    /// FedBuff / AsyncSGD comparators at uniform sampling (Fig 4), using
    /// the deterministic-service worst case for τ_max and theory-derived
    /// τ_c, τ_sum (τ_sum^i ≈ m_i · p_i · T completions).
    pub fn baseline_bounds(&self) -> Result<(f64, f64), String> {
        let p_uni = 1.0 / self.params.n as f64;
        let tc = self.cluster(p_uni);
        let net = ClosedNetwork::new(tc.p_vec(), tc.mu_vec())?;
        let an = net.mi_analysis(self.params.c, MiEstimator::Throughput);
        let b = net.buzen(self.params.c);
        let tau_c: f64 = (0..self.params.n)
            .map(|i| b.utilization(i, self.params.c))
            .sum();
        // τ_sum^i/(T+1) → m_i stationarily; Σ_i gives the Table-1 quantity
        let tau_sum_avg: f64 = an.m.iter().sum();
        let stats = DelayStats::deterministic_worst_case(
            self.params.c,
            self.mu_slow,
            tc.lambda_total(),
            tau_c,
            tau_sum_avg,
        );
        let (_, g_fedbuff) = table1::optimize(
            &table1::fedbuff_poly(&self.params, &stats),
            table1::fedbuff_eta_max(&self.params, &stats),
        );
        let (_, g_async) = table1::optimize(
            &table1::async_sgd_poly(&self.params, &stats),
            table1::async_sgd_eta_max(&self.params, &stats),
        );
        Ok((g_fedbuff, g_async))
    }
}

/// Relative improvement of `better` over `worse` (paper's Figs 3/4/9).
pub fn relative_improvement(better: f64, worse: f64) -> f64 {
    (worse - better) / worse
}

#[cfg(test)]
mod tests {
    use super::*;

    fn study(mu_fast: f64, c: usize) -> TwoClusterStudy {
        TwoClusterStudy {
            params: BoundParams::worked_example(c),
            n_fast: 90,
            mu_fast,
            mu_slow: 1.0,
            source: MiSource::default(),
        }
    }

    #[test]
    fn uniform_point_evaluates() {
        let s = study(4.0, 10);
        let pt = s.evaluate(0.01).unwrap();
        assert!(pt.bound > 0.0 && pt.bound.is_finite());
        assert!(pt.eta > 0.0 && pt.eta <= pt.eta_max);
        assert!(pt.m_slow > pt.m_fast);
    }

    #[test]
    fn grid_is_increasing_and_bounded() {
        let s = study(4.0, 10);
        let g = s.p_grid(50);
        assert_eq!(g.len(), 50);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(*g.last().unwrap() < s.p_max());
    }

    #[test]
    fn optimal_p_below_uniform_and_improves() {
        // the paper's headline: fast clients should be sampled LESS than
        // uniformly, improving the bound by ~30-55% for μ_f in [2,16]
        let s = study(8.0, 10);
        let (best, uniform) = s.optimize_p(50).unwrap();
        assert!(
            best.p_fast < 0.01,
            "optimal p {} should be below uniform 0.01",
            best.p_fast
        );
        let imp = relative_improvement(best.bound, uniform.bound);
        assert!(imp > 0.15, "improvement {imp} too small");
        assert!(imp < 0.9, "improvement {imp} implausibly large");
    }

    #[test]
    fn improvement_grows_with_speed_ratio() {
        let imp = |mu: f64| {
            let s = study(mu, 10);
            let (b, u) = s.optimize_p(40).unwrap();
            relative_improvement(b.bound, u.bound)
        };
        let (i2, i16) = (imp(2.0), imp(16.0));
        assert!(
            i16 > i2,
            "improvement should grow with μ_f: {i2} (μ=2) vs {i16} (μ=16)"
        );
    }

    #[test]
    fn optimal_sampling_cuts_fast_delays() {
        // App F.2: optimal p divides fast delay by ~10, slow by ~2
        let s = TwoClusterStudy {
            params: BoundParams { n: 10, c: 1000, ..BoundParams::worked_example(1000) },
            n_fast: 5,
            mu_fast: 1.2,
            mu_slow: 1.0,
            source: MiSource::default(),
        };
        let uni = s.evaluate(0.1).unwrap();
        let opt = s.evaluate(0.0075).unwrap();
        assert!(
            opt.m_fast < uni.m_fast / 5.0,
            "fast delay {} vs uniform {}",
            opt.m_fast,
            uni.m_fast
        );
        assert!(
            opt.m_slow < uni.m_slow,
            "slow delay should also drop: {} vs {}",
            opt.m_slow,
            uni.m_slow
        );
    }

    #[test]
    fn gen_async_sgd_beats_baselines() {
        // Fig 4: massive improvement over FedBuff/AsyncSGD bounds
        let s = study(8.0, 10);
        let (best, _) = s.optimize_p(40).unwrap();
        let (g_fedbuff, g_async) = s.baseline_bounds().unwrap();
        assert!(best.bound < g_async, "{} !< {g_async}", best.bound);
        assert!(best.bound < g_fedbuff, "{} !< {g_fedbuff}", best.bound);
        // FedBuff (τ_max²·n) should be the weakest
        assert!(g_fedbuff > g_async);
    }

    #[test]
    fn physical_time_variant_penalizes_slow_stepping() {
        // App E.2: under a fixed time budget, tilting mass to slow nodes
        // reduces the CS step rate; the optimizer must account for it.
        let s = study(4.0, 100);
        let (best, uniform) = s.optimize_p_physical(40, 1000.0).unwrap();
        assert!(best.bound <= uniform.bound);
        assert!(best.cs_rate > 0.0);
    }

    #[test]
    fn monte_carlo_source_agrees_with_theory() {
        let mut s = study(4.0, 10);
        let th_pt = s.evaluate(0.01).unwrap();
        s.source = MiSource::MonteCarlo {
            steps: 60_000,
            family: ServiceFamily::Exponential,
            seed: 7,
        };
        let mc_pt = s.evaluate(0.01).unwrap();
        // Throughput-rate theory should track MC within ~20%
        assert!(
            (mc_pt.m_slow / th_pt.m_slow - 1.0).abs() < 0.2,
            "mc {} vs theory {}",
            mc_pt.m_slow,
            th_pt.m_slow
        );
        assert!(
            (mc_pt.m_fast / th_pt.m_fast - 1.0).abs() < 0.25,
            "mc {} vs theory {}",
            mc_pt.m_fast,
            th_pt.m_fast
        );
    }

    #[test]
    fn invalid_p_rejected() {
        let s = study(4.0, 10);
        assert!(s.evaluate(0.2).is_err()); // q would be negative
        assert!(s.evaluate(0.0).is_err());
    }
}
