//! Convergence-bound machinery: Theorem 1 (`theorem1`), the FedBuff /
//! AsyncSGD comparators of Table 1 (`table1`), and the (p, η) optimizer of
//! Algorithm 1 (`optimizer`).

pub mod optimizer;
pub mod table1;
pub mod theorem1;

pub use optimizer::{relative_improvement, BoundPoint, MiSource, TwoClusterStudy};
pub use table1::DelayStats;
pub use theorem1::{BoundParams, EtaPoly, Theorem1};
