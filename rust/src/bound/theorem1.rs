//! Theorem 1: the non-convex convergence bound of Generalized AsyncSGD.
//!
//! For learning rate η ≤ η_max(p), the average gradient norm obeys
//!
//!   Σ_k E‖∇f(w_k)‖² / 8(T+1)  ≤  G(p, η)
//!     :=  A/(η(T+1))
//!       + η L B Σ_i 1/(n² p_i)
//!       + η² L² B C Σ_i m̄_i /(n² p_i²)
//!
//! with A = E[f(μ_0) − f(μ_{T+1})], B = 2G² + σ², and m̄_i the (stationary)
//! per-node delay in CS steps.  (We fold the paper's  Σ_k m_{i,k}^T/(T+1)
//! into its stationary limit m_i — Prop 3 — which the paper itself uses for
//! all numerical studies.)
//!
//! The optimal step size for fixed p minimizes φ(η) = a/η + bη + cη², a
//! strictly convex problem on (0, η_max]; the stationary point solves the
//! cubic 2cη³ + bη² − a = 0 (unique positive root), clamped to η_max.

/// Problem constants of the bound.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// A = E[f(μ_0) − f_*] — initialization gap
    pub a: f64,
    /// B = 2G² + σ² — heterogeneity + gradient noise
    pub b: f64,
    /// L — smoothness
    pub l: f64,
    /// C — concurrency (tasks in flight)
    pub c: usize,
    /// T — number of CS steps
    pub t: u64,
    /// n — number of clients
    pub n: usize,
}

impl BoundParams {
    /// The paper's worked example (§2): n=100, L=1, B=20, A=100, T=1e4.
    pub fn worked_example(c: usize) -> Self {
        BoundParams { a: 100.0, b: 20.0, l: 1.0, c, t: 10_000, n: 100 }
    }
}

/// The three coefficients of φ(η) = a/η + b·η + c·η² for given (p, m).
#[derive(Clone, Copy, Debug)]
pub struct EtaPoly {
    pub inv: f64,  // a
    pub lin: f64,  // b
    pub quad: f64, // c
}

impl EtaPoly {
    pub fn eval(&self, eta: f64) -> f64 {
        self.inv / eta + self.lin * eta + self.quad * eta * eta
    }

    /// Unique positive root of φ'(η) = −a/η² + b + 2cη = 0, i.e. the
    /// unconstrained minimizer of φ.  Solved by safeguarded Newton.
    pub fn unconstrained_min(&self) -> f64 {
        let (a, b, c) = (self.inv, self.lin, self.quad);
        debug_assert!(a > 0.0 && b >= 0.0 && c >= 0.0);
        if b == 0.0 && c == 0.0 {
            return f64::INFINITY;
        }
        // g(η) = 2cη³ + bη² − a; g(0) = −a < 0, g increasing for η>0.
        let mut hi = 1.0;
        while 2.0 * c * hi * hi * hi + b * hi * hi < a {
            hi *= 2.0;
            if hi > 1e12 {
                break;
            }
        }
        let mut lo = 0.0;
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            let g = 2.0 * c * mid * mid * mid + b * mid * mid - a;
            if g < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
            if (hi - lo) < 1e-15 * hi {
                break;
            }
        }
        0.5 * (lo + hi)
    }
}

/// Theorem 1 bound evaluator for a concrete sampling distribution.
#[derive(Clone, Debug)]
pub struct Theorem1 {
    pub params: BoundParams,
    /// sampling probabilities p_i (sum 1)
    pub p: Vec<f64>,
    /// stationary delays m_i (CS steps)
    pub m: Vec<f64>,
}

impl Theorem1 {
    pub fn new(params: BoundParams, p: Vec<f64>, m: Vec<f64>) -> Result<Self, String> {
        if p.len() != params.n || m.len() != params.n {
            return Err(format!(
                "p/m must have n={} entries (got {}/{})",
                params.n,
                p.len(),
                m.len()
            ));
        }
        if p.iter().any(|&x| x <= 0.0) {
            return Err("all p_i must be > 0 (unbiasedness needs full support)".into());
        }
        let s: f64 = p.iter().sum();
        if (s - 1.0).abs() > 1e-8 {
            return Err(format!("p sums to {s}"));
        }
        if m.iter().any(|&x| x < 0.0 || !x.is_finite()) {
            return Err("delays m_i must be finite and >= 0".into());
        }
        Ok(Theorem1 { params, p, m })
    }

    /// Σ_i 1/(n² p_i)
    pub fn inv_p_sum(&self) -> f64 {
        let n = self.params.n as f64;
        self.p.iter().map(|p| 1.0 / (n * n * p)).sum()
    }

    /// m̄ = Σ_i m_i/(n² p_i²)  (the paper's stationary m_k^T)
    pub fn m_bar(&self) -> f64 {
        let n = self.params.n as f64;
        self.m
            .iter()
            .zip(&self.p)
            .map(|(m, p)| m / (n * n * p * p))
            .sum()
    }

    /// η_max(p) = (1/4L) · min( (C·m̄)^{-1/2}, 2 / Σ 1/(n²p_i) ).
    pub fn eta_max(&self) -> f64 {
        let l = self.params.l;
        let c = self.params.c as f64;
        let mbar = self.m_bar();
        let lhs = if mbar > 0.0 { 1.0 / (c * mbar).sqrt() } else { f64::INFINITY };
        let rhs = 2.0 / self.inv_p_sum();
        (lhs.min(rhs)) / (4.0 * l)
    }

    /// Coefficients of G(p, ·).
    pub fn poly(&self) -> EtaPoly {
        let q = &self.params;
        EtaPoly {
            inv: q.a / (q.t as f64 + 1.0),
            lin: q.l * q.b * self.inv_p_sum(),
            quad: q.l * q.l * q.b * q.c as f64 * self.m_bar(),
        }
    }

    /// G(p, η) for a specific η.
    pub fn bound_at(&self, eta: f64) -> f64 {
        self.poly().eval(eta)
    }

    /// (η*, G(p, η*)) with η* the constrained optimum.
    pub fn optimize_eta(&self) -> (f64, f64) {
        let poly = self.poly();
        let eta = poly.unconstrained_min().min(self.eta_max());
        (eta, poly.eval(eta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<f64> {
        vec![1.0 / n as f64; n]
    }

    fn t1(n: usize, c: usize, m: Vec<f64>) -> Theorem1 {
        let params = BoundParams { a: 100.0, b: 20.0, l: 1.0, c, t: 10_000, n };
        Theorem1::new(params, uniform(n), m).unwrap()
    }

    #[test]
    fn construction_validates() {
        let params = BoundParams::worked_example(10);
        assert!(Theorem1::new(params, uniform(100), vec![1.0; 100]).is_ok());
        assert!(Theorem1::new(params, uniform(50), vec![1.0; 100]).is_err());
        let mut p = uniform(100);
        p[0] = 0.0;
        p[1] += 0.01;
        assert!(Theorem1::new(params, p, vec![1.0; 100]).is_err());
        assert!(Theorem1::new(params, uniform(100), vec![f64::NAN; 100]).is_err());
    }

    #[test]
    fn uniform_p_identities() {
        // uniform p: Σ 1/(n²p_i) = 1 and m̄ = Σ m_i
        let th = t1(10, 10, vec![2.0; 10]);
        assert!((th.inv_p_sum() - 1.0).abs() < 1e-12);
        assert!((th.m_bar() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn cubic_minimizer_is_stationary() {
        let poly = EtaPoly { inv: 0.01, lin: 20.0, quad: 400.0 };
        let e = poly.unconstrained_min();
        let d = -poly.inv / (e * e) + poly.lin + 2.0 * poly.quad * e;
        assert!(d.abs() < 1e-6, "derivative {d} at η={e}");
        // and it's a minimum: φ larger on both sides
        assert!(poly.eval(e * 0.9) > poly.eval(e));
        assert!(poly.eval(e * 1.1) > poly.eval(e));
    }

    #[test]
    fn cubic_no_quadratic_term() {
        // c=0 ⇒ η* = sqrt(a/b)
        let poly = EtaPoly { inv: 4.0, lin: 1.0, quad: 0.0 };
        assert!((poly.unconstrained_min() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn eta_max_decreases_with_delays() {
        let lo = t1(10, 10, vec![1.0; 10]);
        let hi = t1(10, 10, vec![100.0; 10]);
        assert!(hi.eta_max() < lo.eta_max());
    }

    #[test]
    fn optimized_bound_beats_arbitrary_eta() {
        let th = t1(100, 50, vec![10.0; 100]);
        let (eta, g) = th.optimize_eta();
        assert!(eta > 0.0 && eta <= th.eta_max());
        for &scale in &[0.25, 0.5, 2.0] {
            let e2 = (eta * scale).min(th.eta_max());
            if (e2 - eta).abs() > 1e-12 {
                assert!(th.bound_at(e2) >= g - 1e-12);
            }
        }
    }

    #[test]
    fn larger_t_improves_bound() {
        let params_small = BoundParams { t: 100, ..BoundParams::worked_example(10) };
        let params_big = BoundParams { t: 100_000, ..BoundParams::worked_example(10) };
        let m = vec![5.0; 100];
        let a = Theorem1::new(params_small, uniform(100), m.clone()).unwrap();
        let b = Theorem1::new(params_big, uniform(100), m).unwrap();
        assert!(b.optimize_eta().1 < a.optimize_eta().1);
    }

    #[test]
    fn t_to_infinity_prefers_uniform() {
        // §3: as T → ∞ the second term dominates; Σ 1/p_i is minimized by
        // uniform p, so any tilt must not improve the optimized bound.
        let params = BoundParams { t: 100_000_000, ..BoundParams::worked_example(10) };
        let m = vec![3.0; 100];
        let uni = Theorem1::new(params, uniform(100), m.clone()).unwrap();
        let mut tilted_p = uniform(100);
        for (i, item) in tilted_p.iter_mut().enumerate() {
            *item = if i < 50 { 0.015 } else { 0.005 };
        }
        let tilted = Theorem1::new(params, tilted_p, m).unwrap();
        assert!(uni.optimize_eta().1 <= tilted.optimize_eta().1);
    }

    #[test]
    fn delay_penalty_monotone_in_m() {
        let lo = t1(10, 10, vec![1.0; 10]);
        let hi = t1(10, 10, vec![50.0; 10]);
        assert!(lo.optimize_eta().1 <= hi.optimize_eta().1);
    }
}
