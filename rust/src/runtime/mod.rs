//! Runtime layer: loads AOT-compiled HLO artifacts (L2 JAX model + L1
//! Pallas kernels) and executes them via the PJRT C API (`xla` crate,
//! behind the `pjrt` feature) — plus a pure-Rust `native` backend with
//! identical semantics for fast sweeps and numerical cross-checks.
//! Python never runs here.
//!
//! The layer also hosts [`executor`], the deterministic single-threaded
//! async executor (slab task pool, virtual clock) that `fedqueue serve`
//! schedules its simulated clients on.

// `executor` is fully documented; the older modules still carry the
// missing_docs debt marker (see the crate-root docs ratchet note).
#[allow(missing_docs)]
pub mod artifact;
#[allow(missing_docs)]
pub mod backend;
pub mod executor;
#[allow(missing_docs)]
pub mod native;
#[cfg(feature = "pjrt")]
#[allow(missing_docs)]
pub mod pjrt;

pub use artifact::{Manifest, VariantMeta};
pub use backend::{Backend, EvalSummary, ModelSpec};
pub use executor::{Executor, Handle, TaskId};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

/// Backend selector used by CLI/config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference backend (always available).
    Native,
    /// PJRT C-API backend over AOT HLO artifacts (`pjrt` cargo feature).
    Pjrt,
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend '{other}' (native|pjrt)")),
        }
    }
}

/// Construct a backend.  For PJRT the `variant` must exist in the artifact
/// manifest; for native the spec is taken from the manifest when available
/// (keeping shapes identical across backends), from the given fallback, or
/// — for the `tiny` test variant — from the built-in spec so tests and CI
/// run without artifacts.
pub fn make_backend(
    kind: BackendKind,
    variant: &str,
    fallback: Option<ModelSpec>,
) -> Result<Box<dyn Backend>, String> {
    let dir = Manifest::default_dir();
    match kind {
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(&dir, variant)?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => Err(
            "pjrt backend not compiled in (rebuild with `--features pjrt`), \
             or use --backend native"
                .into(),
        ),
        BackendKind::Native => {
            let spec = match Manifest::load(&dir) {
                Ok(m) => {
                    let v = m.variant(variant)?;
                    ModelSpec {
                        input_dim: v.input_dim,
                        hidden: v.hidden.clone(),
                        classes: v.classes,
                        train_batch: v.train_batch,
                        eval_batch: v.eval_batch,
                    }
                }
                Err(e) => match fallback {
                    Some(spec) => spec,
                    None if variant.trim_end_matches("_jnp") == "tiny" => {
                        NativeBackend::tiny().spec().clone()
                    }
                    None => return Err(format!("no manifest and no fallback spec: {e}")),
                },
            };
            Ok(Box::new(NativeBackend::new(spec)))
        }
    }
}
