//! Deterministic single-threaded async executor with a virtual clock.
//!
//! This is the substrate under `fedqueue serve` (see
//! `coordinator::serve`): simulated clients run as spawned futures, and
//! every interleaving decision is made here, deterministically, so a
//! serve run is bit-identical across machines and repetitions on a
//! shared seed.  The design follows the single-threaded simulation
//! executors used by discrete-event frameworks (nexosim's
//! `st_executor` shape):
//!
//! - **Slab task pool** — tasks live in a `Vec` of slots with a LIFO
//!   free list, so completing or cancelling a task recycles its slot
//!   (and allocation) for the next spawn.  A `(slot, generation)` pair
//!   ([`TaskId`]) names a task; the generation is bumped on release so
//!   stale wakes and stale cancels are rejected instead of hitting an
//!   unrelated task that reused the slot.
//! - **Cancellable futures** — [`Executor::cancel`] drops a pending
//!   task's future in place.  Timers it registered stay in the heap but
//!   fire into a dead generation, which is filtered at wake time.
//! - **FIFO runnable queue** — woken tasks are polled in the order they
//!   were woken, never by pointer identity or hash order.
//! - **Virtual clock** — there is no real time here.  [`Handle::
//!   sleep_until`] registers a `(time, sequence)`-ordered timer; when no
//!   task is runnable the executor advances `now` to the earliest timer
//!   and wakes it.  Equal-time timers fire in registration order.
//!
//! [`Executor::run`] drives the loop until *quiescence*: no runnable
//! task and no pending timer.  Tasks still parked on an external waker
//! (e.g. a channel nobody will ever write to) are simply left in the
//! slab — that is the graceful-termination path the serve loop relies
//! on when the dispatch budget is exhausted.
//!
//! The module is on the determinism contract's module list: `cargo
//! xtask lint` rules R1–R8 apply (no wall clock, no RNG, no
//! hash-ordered containers).

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Wake, Waker};

/// Handle naming a spawned task: slab slot plus the generation the slot
/// had at spawn time.  Stale ids (the task completed or was cancelled,
/// and the slot possibly reused) are detected and ignored by
/// [`Executor::cancel`] / [`Executor::is_alive`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskId {
    slot: usize,
    generation: u64,
}

impl TaskId {
    /// Slab slot index (mainly useful to assert slot reuse in tests).
    pub fn slot(&self) -> usize {
        self.slot
    }
}

/// One task: the future, the waker that re-queues it, and a flag that
/// keeps it from being enqueued twice.
struct TaskEntry {
    future: Pin<Box<dyn Future<Output = ()>>>,
    waker: Waker,
    queued: bool,
}

/// A slab slot.  `generation` counts releases; `task` is `None` while
/// the slot is free (or while its future is temporarily moved out to be
/// polled).
struct Slot {
    generation: u64,
    task: Option<TaskEntry>,
}

/// Pending virtual-clock timer.  Ordered by `(at_bits, seq)`: virtual
/// times are non-negative finite `f64`s, whose IEEE-754 bit patterns
/// order identically to their values, and `seq` breaks ties in
/// registration order.  The waker does not participate in the ordering.
struct TimerEntry {
    at_bits: u64,
    seq: u64,
    waker: Waker,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at_bits == other.at_bits && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at_bits, self.seq).cmp(&(other.at_bits, other.seq))
    }
}

/// Wakes land here, outside the executor's `RefCell`, so a future may
/// wake any task (including itself) while the executor is mid-poll.
struct WakeQueue {
    woken: Mutex<Vec<(usize, u64)>>,
}

/// The `std::task::Wake` implementation: waking pushes the task's
/// `(slot, generation)` onto the shared wake queue.  Generation-stale
/// wakes are filtered when the queue is drained.
struct TaskWaker {
    slot: usize,
    generation: u64,
    queue: Arc<WakeQueue>,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }
    fn wake_by_ref(self: &Arc<Self>) {
        self.queue.woken.lock().unwrap().push((self.slot, self.generation));
    }
}

/// Mutable executor state behind the `Rc<RefCell<…>>` shared with every
/// [`Handle`].
struct Inner {
    slots: Vec<Slot>,
    free: Vec<usize>,
    runnable: VecDeque<(usize, u64)>,
    timers: BinaryHeap<Reverse<TimerEntry>>,
    timer_seq: u64,
    now: f64,
    live: usize,
    spawned: u64,
}

/// The deterministic single-threaded executor.  See the module docs for
/// the design; see [`Handle`] for the API visible to spawned futures.
pub struct Executor {
    inner: Rc<RefCell<Inner>>,
    wakes: Arc<WakeQueue>,
}

/// Cheap clonable handle passed into spawned futures: spawn more tasks,
/// read the virtual clock, and sleep on it.
#[derive(Clone)]
pub struct Handle {
    inner: Rc<RefCell<Inner>>,
    wakes: Arc<WakeQueue>,
}

/// Future returned by [`Handle::sleep_until`]: pending until the
/// virtual clock reaches `at`.  A deadline at or before the current
/// virtual time completes immediately without registering a timer.
pub struct Sleep {
    inner: Rc<RefCell<Inner>>,
    at: f64,
}

impl Future for Sleep {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let this = self.get_mut();
        let mut g = this.inner.borrow_mut();
        if g.now >= this.at {
            return Poll::Ready(());
        }
        let seq = g.timer_seq;
        g.timer_seq += 1;
        g.timers.push(Reverse(TimerEntry {
            at_bits: this.at.max(0.0).to_bits(),
            seq,
            waker: cx.waker().clone(),
        }));
        Poll::Pending
    }
}

fn cancel_in(inner: &Rc<RefCell<Inner>>, id: TaskId) -> bool {
    let entry = {
        let mut g = inner.borrow_mut();
        let Some(s) = g.slots.get_mut(id.slot) else { return false };
        if s.generation != id.generation || s.task.is_none() {
            return false;
        }
        let entry = s.task.take();
        s.generation += 1;
        g.free.push(id.slot);
        g.live -= 1;
        entry
    };
    // Drop the future outside the borrow in case its Drop impl re-enters
    // the executor (spawning cleanup tasks, reading now()).
    drop(entry);
    true
}

fn spawn_into(
    inner: &Rc<RefCell<Inner>>,
    wakes: &Arc<WakeQueue>,
    future: impl Future<Output = ()> + 'static,
) -> TaskId {
    let mut g = inner.borrow_mut();
    let slot = match g.free.pop() {
        Some(s) => s,
        None => {
            g.slots.push(Slot { generation: 0, task: None });
            g.slots.len() - 1
        }
    };
    let generation = g.slots[slot].generation;
    let waker = Waker::from(Arc::new(TaskWaker {
        slot,
        generation,
        queue: Arc::clone(wakes),
    }));
    g.slots[slot].task = Some(TaskEntry { future: Box::pin(future), waker, queued: true });
    g.runnable.push_back((slot, generation));
    g.live += 1;
    g.spawned += 1;
    TaskId { slot, generation }
}

impl Handle {
    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.inner.borrow().now
    }

    /// Spawn a task; it is queued runnable and will be polled in FIFO
    /// order relative to other pending wakes.
    pub fn spawn(&self, future: impl Future<Output = ()> + 'static) -> TaskId {
        spawn_into(&self.inner, &self.wakes, future)
    }

    /// Sleep until virtual time `at` (completes immediately if `at` is
    /// already in the past).
    pub fn sleep_until(&self, at: f64) -> Sleep {
        debug_assert!(!at.is_nan(), "sleep_until(NaN)");
        Sleep { inner: Rc::clone(&self.inner), at }
    }

    /// Cancel another task from inside a running one — identical to
    /// [`Executor::cancel`].
    pub fn cancel(&self, id: TaskId) -> bool {
        cancel_in(&self.inner, id)
    }
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new()
    }
}

impl Executor {
    /// New executor with an empty slab and the virtual clock at 0.
    pub fn new() -> Executor {
        Executor {
            inner: Rc::new(RefCell::new(Inner {
                slots: Vec::new(),
                free: Vec::new(),
                runnable: VecDeque::new(),
                timers: BinaryHeap::new(),
                timer_seq: 0,
                now: 0.0,
                live: 0,
                spawned: 0,
            })),
            wakes: Arc::new(WakeQueue { woken: Mutex::new(Vec::new()) }),
        }
    }

    /// Handle for use inside spawned futures.
    pub fn handle(&self) -> Handle {
        Handle { inner: Rc::clone(&self.inner), wakes: Arc::clone(&self.wakes) }
    }

    /// Spawn a task from outside the executor (identical to
    /// [`Handle::spawn`]).
    pub fn spawn(&self, future: impl Future<Output = ()> + 'static) -> TaskId {
        spawn_into(&self.inner, &self.wakes, future)
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.inner.borrow().now
    }

    /// Tasks alive in the slab (spawned, not yet completed/cancelled).
    pub fn live(&self) -> usize {
        self.inner.borrow().live
    }

    /// Total tasks ever spawned.
    pub fn spawned(&self) -> u64 {
        self.inner.borrow().spawned
    }

    /// Slab capacity (total slots ever allocated — stays flat when the
    /// free list recycles slots).
    pub fn slot_count(&self) -> usize {
        self.inner.borrow().slots.len()
    }

    /// Whether `id` still names a live task.
    pub fn is_alive(&self, id: TaskId) -> bool {
        let g = self.inner.borrow();
        g.slots
            .get(id.slot)
            .is_some_and(|s| s.generation == id.generation && s.task.is_some())
    }

    /// Cancel a pending task: its future is dropped, its slot is
    /// recycled, and any timers or queued wakes it left behind are
    /// invalidated via the generation bump.  Returns `false` for a
    /// stale id — or for the task currently being polled, which cannot
    /// cancel itself.
    pub fn cancel(&self, id: TaskId) -> bool {
        cancel_in(&self.inner, id)
    }

    /// Move pending wakes into the runnable queue, dropping stale
    /// generations and de-duplicating via the per-task `queued` flag.
    fn drain_wakes(&self) {
        let woken: Vec<(usize, u64)> = {
            let mut q = self.wakes.woken.lock().unwrap();
            std::mem::take(&mut *q)
        };
        if woken.is_empty() {
            return;
        }
        let mut g = self.inner.borrow_mut();
        for (slot, generation) in woken {
            let enqueue = match g.slots.get_mut(slot) {
                Some(s) if s.generation == generation => match s.task.as_mut() {
                    Some(entry) if !entry.queued => {
                        entry.queued = true;
                        true
                    }
                    _ => false,
                },
                _ => false,
            };
            if enqueue {
                g.runnable.push_back((slot, generation));
            }
        }
    }

    /// Pop the earliest timer, advance the clock to it, and fire its
    /// waker.  Returns `false` when no timers remain.
    fn fire_next_timer(&self) -> bool {
        let entry = {
            let mut g = self.inner.borrow_mut();
            match g.timers.pop() {
                Some(Reverse(e)) => {
                    let at = f64::from_bits(e.at_bits);
                    if at > g.now {
                        g.now = at;
                    }
                    e
                }
                None => return false,
            }
        };
        entry.waker.wake();
        true
    }

    /// Run to quiescence: poll runnable tasks in FIFO wake order; when
    /// none are runnable, advance the virtual clock to the earliest
    /// timer.  Returns when there is neither a runnable task nor a
    /// pending timer.  Tasks parked on wakers nobody will fire are left
    /// alive in the slab (inspect with [`Executor::live`]).
    pub fn run(&self) {
        loop {
            self.drain_wakes();
            let next = self.inner.borrow_mut().runnable.pop_front();
            if let Some((slot, generation)) = next {
                // Move the future out of the slab to poll it without
                // holding the RefCell: the poll may spawn, sleep, wake,
                // or (unsuccessfully) try to cancel itself.
                let taken = {
                    let mut g = self.inner.borrow_mut();
                    match g.slots.get_mut(slot) {
                        Some(s) if s.generation == generation => {
                            if let Some(entry) = s.task.as_mut() {
                                entry.queued = false;
                            }
                            s.task.take()
                        }
                        _ => None,
                    }
                };
                let Some(mut entry) = taken else { continue };
                let mut cx = Context::from_waker(&entry.waker);
                let poll = entry.future.as_mut().poll(&mut cx);
                let mut g = self.inner.borrow_mut();
                let s = &mut g.slots[slot];
                debug_assert_eq!(s.generation, generation, "slot reused mid-poll");
                match poll {
                    Poll::Ready(()) => {
                        s.generation += 1;
                        g.free.push(slot);
                        g.live -= 1;
                        drop(g);
                        drop(entry);
                    }
                    Poll::Pending => {
                        s.task = Some(entry);
                    }
                }
                continue;
            }
            if !self.fire_next_timer() {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    /// Always-pending future that never registers its waker: parks its
    /// task forever (until cancelled).
    struct Forever;
    impl Future for Forever {
        type Output = ();
        fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
            Poll::Pending
        }
    }

    #[test]
    fn tasks_run_in_spawn_order() {
        let ex = Executor::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for i in 0..3 {
            let order = Rc::clone(&order);
            ex.spawn(async move { order.borrow_mut().push(i) });
        }
        ex.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2]);
        assert_eq!(ex.live(), 0);
    }

    #[test]
    fn virtual_clock_orders_timers_not_spawns() {
        let ex = Executor::new();
        let h = ex.handle();
        let order = Rc::new(RefCell::new(Vec::new()));
        for (i, at) in [5.0, 1.0, 3.0].into_iter().enumerate() {
            let (h, order) = (h.clone(), Rc::clone(&order));
            ex.spawn(async move {
                h.sleep_until(at).await;
                order.borrow_mut().push((i, at));
            });
        }
        ex.run();
        assert_eq!(*order.borrow(), vec![(1, 1.0), (2, 3.0), (0, 5.0)]);
        assert_eq!(ex.now(), 5.0);
    }

    #[test]
    fn equal_time_timers_fire_in_registration_order() {
        let ex = Executor::new();
        let h = ex.handle();
        let order = Rc::new(RefCell::new(Vec::new()));
        for i in 0..4 {
            let (h, order) = (h.clone(), Rc::clone(&order));
            ex.spawn(async move {
                h.sleep_until(2.5).await;
                order.borrow_mut().push(i);
            });
        }
        ex.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn sleep_in_the_past_is_immediate() {
        let ex = Executor::new();
        let h = ex.handle();
        let done = Rc::new(Cell::new(false));
        let flag = Rc::clone(&done);
        ex.spawn(async move {
            h.sleep_until(0.0).await;
            h.sleep_until(-1.0).await;
            flag.set(true);
        });
        ex.run();
        assert!(done.get());
        assert_eq!(ex.now(), 0.0);
    }

    #[test]
    fn cancel_frees_the_slot_and_the_next_spawn_reuses_it() {
        let ex = Executor::new();
        ex.spawn(async {}); // slot 0, completes immediately on run
        let parked = ex.spawn(Forever); // slot 1
        ex.run();
        assert_eq!(ex.live(), 1);
        assert!(ex.is_alive(parked));
        assert!(ex.cancel(parked));
        assert!(!ex.is_alive(parked));
        assert!(!ex.cancel(parked), "stale cancel must be a no-op");
        assert_eq!(ex.live(), 0);
        let next = ex.spawn(async {});
        assert_eq!(next.slot(), parked.slot(), "freed slot is recycled");
        assert!(ex.is_alive(next), "new generation is live despite stale id");
        assert_eq!(ex.slot_count(), 2, "slab did not grow");
        ex.run();
        assert_eq!(ex.live(), 0);
    }

    #[test]
    fn slab_stays_flat_under_spawn_complete_churn() {
        let ex = Executor::new();
        let h = ex.handle();
        // Each wave completes before the next spawns, so the free list
        // must absorb every slot: the slab never exceeds one wave.
        let driver = h.clone();
        ex.spawn(async move {
            for wave in 0..16u32 {
                for i in 0..8u32 {
                    let h2 = driver.clone();
                    let at = f64::from(wave) + f64::from(i) * 0.01;
                    driver.spawn(async move { h2.sleep_until(at).await });
                }
                driver.sleep_until(f64::from(wave) + 0.5).await;
            }
        });
        ex.run();
        assert_eq!(ex.live(), 0);
        assert_eq!(ex.spawned(), 16 * 8 + 1);
        assert!(
            ex.slot_count() <= 10,
            "slab grew to {} slots for 8-task waves",
            ex.slot_count()
        );
    }

    #[test]
    fn cancelled_sleeper_never_runs_and_stale_timer_is_harmless() {
        let ex = Executor::new();
        let h = ex.handle();
        let ran = Rc::new(Cell::new(false));
        let flag = Rc::clone(&ran);
        let sleeper = ex.spawn(async move {
            h.sleep_until(10.0).await;
            flag.set(true);
        });
        // A second task cancels the sleeper at t = 1.0, while the 10.0
        // timer is already registered.
        let h2 = ex.handle();
        let cancelled = Rc::new(Cell::new(false));
        let cflag = Rc::clone(&cancelled);
        ex.spawn(async move {
            h2.sleep_until(1.0).await;
            cflag.set(h2.cancel(sleeper));
        });
        // run() fires the stale 10.0 timer into a dead generation.
        ex.run();
        assert!(cancelled.get(), "mid-run cancel of a live sleeper");
        assert!(!ran.get(), "cancelled task must not run");
        assert_eq!(ex.live(), 0);
        assert_eq!(ex.now(), 10.0, "clock still advanced to the stale timer");
    }

    #[test]
    fn tasks_spawned_mid_run_are_polled() {
        let ex = Executor::new();
        let h = ex.handle();
        let count = Rc::new(Cell::new(0u32));
        let c = Rc::clone(&count);
        ex.spawn(async move {
            for _ in 0..3 {
                let c2 = Rc::clone(&c);
                h.spawn(async move { c2.set(c2.get() + 1) });
            }
        });
        ex.run();
        assert_eq!(count.get(), 3);
    }

    #[test]
    fn self_wake_yields_then_resumes() {
        /// Classic yield-now: wakes itself and returns Pending once.
        struct YieldOnce(bool);
        impl Future for YieldOnce {
            type Output = ();
            fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
                if self.0 {
                    Poll::Ready(())
                } else {
                    self.0 = true;
                    cx.waker().wake_by_ref();
                    Poll::Pending
                }
            }
        }
        let ex = Executor::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        let o1 = Rc::clone(&order);
        ex.spawn(async move {
            o1.borrow_mut().push("a1");
            YieldOnce(false).await;
            o1.borrow_mut().push("a2");
        });
        let o2 = Rc::clone(&order);
        ex.spawn(async move { o2.borrow_mut().push("b") });
        ex.run();
        // The yield put task A behind task B in the FIFO.
        assert_eq!(*order.borrow(), vec!["a1", "b", "a2"]);
    }
}
