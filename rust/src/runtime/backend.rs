//! The gradient-compute backend abstraction shared by the coordinator.
//!
//! Two implementations:
//! * [`crate::runtime::pjrt::PjrtBackend`] — the production path: executes
//!   the AOT-compiled HLO (JAX L2 + Pallas L1) on the PJRT CPU client.
//! * [`crate::runtime::native::NativeBackend`] — pure-Rust MLP fwd/bwd with
//!   identical semantics; used for fast multi-seed sweeps and as the
//!   numerical cross-check of the PJRT path.

use crate::data::{Batch, EvalBatches};
use crate::fl::ModelState;

/// Model geometry a backend exposes (mirrors the artifact manifest).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl ModelSpec {
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = vec![self.input_dim];
        dims.extend(&self.hidden);
        dims.push(self.classes);
        dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// Parameter shapes in artifact order (w0, b0, w1, b1, ...).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for (din, dout) in self.layer_dims() {
            out.push(vec![din, dout]);
            out.push(vec![dout]);
        }
        out
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum()
    }

    pub fn init_model(&self, seed: u64) -> ModelState {
        ModelState::init_he(&self.param_shapes(), seed)
    }
}

/// Evaluation summary over a validation set.
#[derive(Clone, Copy, Debug)]
pub struct EvalSummary {
    pub mean_loss: f64,
    pub accuracy: f64,
    pub n: usize,
}

pub trait Backend {
    fn spec(&self) -> &ModelSpec;

    /// One stochastic-gradient computation: (mean loss, grads).
    /// `batch.batch` must equal `spec().train_batch`.
    fn train_step(&mut self, model: &ModelState, batch: &Batch) -> Result<(f64, Vec<Vec<f32>>), String>;

    /// Sum of losses and number of correct predictions over the first
    /// `valid` rows of the batch (batch must be eval_batch-sized).
    fn eval_batch(
        &mut self,
        model: &ModelState,
        batch: &Batch,
        valid: usize,
    ) -> Result<(f64, f64), String>;

    /// Full-set evaluation.
    fn evaluate(&mut self, model: &ModelState, ev: &EvalBatches) -> Result<EvalSummary, String> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut n = 0usize;
        for (batch, valid) in &ev.batches {
            let (l, c) = self.eval_batch(model, batch, *valid)?;
            loss_sum += l;
            correct += c;
            n += valid;
        }
        Ok(EvalSummary { mean_loss: loss_sum / n as f64, accuracy: correct / n as f64, n })
    }

    /// Human-readable backend name for logs.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_shapes() {
        let s = ModelSpec {
            input_dim: 3072,
            hidden: vec![512, 256],
            classes: 10,
            train_batch: 128,
            eval_batch: 250,
        };
        assert_eq!(s.layer_dims(), vec![(3072, 512), (512, 256), (256, 10)]);
        assert_eq!(s.param_shapes().len(), 6);
        assert_eq!(s.n_params(), 3072 * 512 + 512 + 512 * 256 + 256 + 256 * 10 + 10);
        let m = s.init_model(1);
        assert_eq!(m.n_params(), s.n_params());
    }
}
