//! Artifact manifest: metadata for the AOT-compiled HLO modules produced by
//! `make artifacts` (python/compile/aot.py).  The Rust runtime trusts the
//! manifest for all I/O shapes — the HLO itself is validated at compile
//! time by XLA.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub n_params: usize,
    /// (name, shape) in artifact parameter order: w0, b0, w1, b1, ...
    pub params: Vec<(String, Vec<usize>)>,
    pub train_file: PathBuf,
    pub train_outputs: usize,
    pub eval_file: PathBuf,
    pub eval_outputs: usize,
}

impl VariantMeta {
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.params.iter().map(|(_, s)| s.clone()).collect()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<VariantMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| format!("manifest.json: {e}"))?;
        let fmt = j.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if fmt != "hlo-text" {
            return Err(format!("unsupported artifact format '{fmt}'"));
        }
        let vmap = j
            .get("variants")
            .and_then(|v| v.as_obj())
            .ok_or("manifest missing 'variants'")?;
        let mut variants = Vec::new();
        for (name, v) in vmap {
            let get_usize = |key: &str| -> Result<usize, String> {
                v.get(key)
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| format!("variant {name}: missing {key}"))
            };
            let params_json = v
                .get("params")
                .and_then(|p| p.as_arr())
                .ok_or_else(|| format!("variant {name}: missing params"))?;
            let mut params = Vec::new();
            for p in params_json {
                let pname = p
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or("param missing name")?
                    .to_string();
                let shape: Vec<usize> = p
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or("param missing shape")?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect();
                params.push((pname, shape));
            }
            let section = |key: &str| -> Result<(PathBuf, usize), String> {
                let s = v.get(key).ok_or_else(|| format!("variant {name}: missing {key}"))?;
                let file = s
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| format!("{key} missing file"))?;
                let outputs = s
                    .get("outputs")
                    .and_then(|o| o.as_usize())
                    .ok_or_else(|| format!("{key} missing outputs"))?;
                Ok((dir.join(file), outputs))
            };
            let (train_file, train_outputs) = section("train")?;
            let (eval_file, eval_outputs) = section("eval")?;
            let hidden = v
                .get("hidden")
                .and_then(|h| h.as_arr())
                .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
                .unwrap_or_default();
            variants.push(VariantMeta {
                name: name.clone(),
                input_dim: get_usize("input_dim")?,
                hidden,
                classes: get_usize("classes")?,
                train_batch: get_usize("train_batch")?,
                eval_batch: get_usize("eval_batch")?,
                n_params: get_usize("n_params")?,
                params,
                train_file,
                train_outputs,
                eval_file,
                eval_outputs,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantMeta, String> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| {
                format!(
                    "variant '{name}' not in manifest (have: {})",
                    self.variants
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Default artifact dir: $FEDQUEUE_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        // lint-allow(R3): env var picks where artifacts land on disk, never
        // what they contain — digest bytes are identical under any dir
        std::env::var("FEDQUEUE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "variants": {
        "tiny": {
          "name": "tiny", "input_dim": 48, "hidden": [32], "classes": 10,
          "train_batch": 16, "eval_batch": 32, "n_params": 1898,
          "params": [
            {"name": "w0", "shape": [48, 32]}, {"name": "b0", "shape": [32]},
            {"name": "w1", "shape": [32, 10]}, {"name": "b1", "shape": [10]}
          ],
          "train": {"file": "tiny_train.hlo.txt", "outputs": 5},
          "eval": {"file": "tiny_eval.hlo.txt", "outputs": 2}
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.input_dim, 48);
        assert_eq!(v.params.len(), 4);
        assert_eq!(v.params[0].1, vec![48, 32]);
        assert_eq!(v.train_outputs, 5);
        assert!(v.train_file.ends_with("tiny_train.hlo.txt"));
        assert_eq!(v.param_shapes()[3], vec![10]);
    }

    #[test]
    fn missing_variant_is_helpful() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let err = m.variant("resnet50").unwrap_err();
        assert!(err.contains("tiny"), "{err}");
    }

    #[test]
    fn rejects_wrong_format() {
        let err = Manifest::parse(Path::new("/tmp"), r#"{"format":"proto","variants":{}}"#)
            .unwrap_err();
        assert!(err.contains("format"));
    }

    #[test]
    fn rejects_malformed_sections() {
        let bad = r#"{"format":"hlo-text","variants":{"x":{"input_dim":3}}}"#;
        assert!(Manifest::parse(Path::new("/tmp"), bad).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // integration smoke vs `make artifacts` output (skip if absent)
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let v = m.variant("tiny").unwrap();
            assert_eq!(v.input_dim, 48);
            assert!(v.train_file.exists());
            assert!(v.eval_file.exists());
        }
    }
}
