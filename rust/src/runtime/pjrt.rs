//! PJRT backend — the production request path.
//!
//! Loads the HLO-text artifacts produced by `make artifacts`, compiles them
//! once on the PJRT CPU client (`xla` crate), and serves `train_step` /
//! `eval_batch` executions.  HLO *text* is the interchange format (jax ≥0.5
//! serialized protos are rejected by xla_extension 0.5.1 — see
//! python/compile/aot.py).
//!
//! Outputs were lowered with `return_tuple=True`, so each execution returns
//! a single tuple literal that is decomposed into (loss, grads...) /
//! (loss_sum, n_correct).

use super::artifact::{Manifest, VariantMeta};
use super::backend::{Backend, ModelSpec};
use crate::data::Batch;
use crate::fl::ModelState;
use std::path::Path;

pub struct PjrtBackend {
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    spec: ModelSpec,
    meta: VariantMeta,
    /// cumulative executions (diagnostics)
    pub train_calls: u64,
    pub eval_calls: u64,
}

fn err<E: std::fmt::Debug>(ctx: &str) -> impl FnOnce(E) -> String + '_ {
    move |e| format!("{ctx}: {e:?}")
}

impl PjrtBackend {
    /// Load a variant from the artifact directory.
    pub fn load(dir: &Path, variant: &str) -> Result<PjrtBackend, String> {
        let manifest = Manifest::load(dir)?;
        let meta = manifest.variant(variant)?.clone();
        let client = xla::PjRtClient::cpu().map_err(err("PjRtClient::cpu"))?;
        let train_exe = Self::compile(&client, &meta.train_file)?;
        let eval_exe = Self::compile(&client, &meta.eval_file)?;
        let spec = ModelSpec {
            input_dim: meta.input_dim,
            hidden: meta.hidden.clone(),
            classes: meta.classes,
            train_batch: meta.train_batch,
            eval_batch: meta.eval_batch,
        };
        Ok(PjrtBackend { client, train_exe, eval_exe, spec, meta, train_calls: 0, eval_calls: 0 })
    }

    fn compile(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable, String> {
        if !path.exists() {
            return Err(format!(
                "artifact {} missing — run `make artifacts`",
                path.display()
            ));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or("non-utf8 artifact path")?,
        )
        .map_err(err("parse HLO text"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(err("XLA compile"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn variant_name(&self) -> &str {
        &self.meta.name
    }

    /// Build the input DEVICE BUFFER list: params..., x, onehot.
    ///
    /// We upload host data through `buffer_from_host_buffer` and run via
    /// `execute_b` instead of the literal-taking `execute`: the crate's C
    /// shim for `execute` leaks every input device buffer it creates
    /// (`BufferFromHostLiteral(...).release()` with no matching free —
    /// ~13.6 MB/step at cifar size, found via RSS profiling; see
    /// EXPERIMENTS.md §Perf).  Buffers created here are owned by Rust
    /// `PjRtBuffer` values and freed on drop.  This also skips one
    /// host-side Literal copy per tensor.
    fn inputs(
        &self,
        model: &ModelState,
        batch: &Batch,
        batch_size: usize,
    ) -> Result<Vec<xla::PjRtBuffer>, String> {
        if model.tensors.len() != self.meta.params.len() {
            return Err(format!(
                "model has {} tensors, artifact expects {}",
                model.tensors.len(),
                self.meta.params.len()
            ));
        }
        let mut bufs = Vec::with_capacity(model.tensors.len() + 2);
        for (t, (name, shape)) in model.tensors.iter().zip(&self.meta.params) {
            let numel: usize = shape.iter().product();
            if t.len() != numel {
                return Err(format!("tensor {name}: {} elements, want {numel}", t.len()));
            }
            bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(t, shape, None)
                    .map_err(err("upload param"))?,
            );
        }
        let expect_x = batch_size * self.spec.input_dim;
        if batch.x.len() != expect_x {
            return Err(format!("x has {} elems, want {expect_x}", batch.x.len()));
        }
        bufs.push(
            self.client
                .buffer_from_host_buffer::<f32>(
                    &batch.x,
                    &[batch_size, self.spec.input_dim],
                    None,
                )
                .map_err(err("upload x"))?,
        );
        bufs.push(
            self.client
                .buffer_from_host_buffer::<f32>(
                    &batch.onehot,
                    &[batch_size, self.spec.classes],
                    None,
                )
                .map_err(err("upload onehot"))?,
        );
        Ok(bufs)
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::PjRtBuffer],
        n_outputs: usize,
    ) -> Result<Vec<xla::Literal>, String> {
        let bufs = exe.execute_b::<&xla::PjRtBuffer>(
            &inputs.iter().collect::<Vec<_>>(),
        )
        .map_err(err("execute"))?;
        let lit = bufs[0][0].to_literal_sync().map_err(err("to_literal"))?;
        let outs = lit.to_tuple().map_err(err("untuple"))?;
        if outs.len() != n_outputs {
            return Err(format!("expected {n_outputs} outputs, got {}", outs.len()));
        }
        Ok(outs)
    }
}

impl Backend for PjrtBackend {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        model: &ModelState,
        batch: &Batch,
    ) -> Result<(f64, Vec<Vec<f32>>), String> {
        if batch.batch != self.spec.train_batch {
            return Err(format!(
                "batch {} != train_batch {}",
                batch.batch, self.spec.train_batch
            ));
        }
        let inputs = self.inputs(model, batch, self.spec.train_batch)?;
        let outs = Self::run(&self.train_exe, &inputs, self.meta.train_outputs)?;
        self.train_calls += 1;
        let loss = outs[0]
            .get_first_element::<f32>()
            .map_err(err("loss scalar"))? as f64;
        let mut grads = Vec::with_capacity(outs.len() - 1);
        for o in &outs[1..] {
            grads.push(o.to_vec::<f32>().map_err(err("grad tensor"))?);
        }
        Ok((loss, grads))
    }

    fn eval_batch(
        &mut self,
        model: &ModelState,
        batch: &Batch,
        valid: usize,
    ) -> Result<(f64, f64), String> {
        if batch.batch != self.spec.eval_batch {
            return Err(format!(
                "batch {} != eval_batch {}",
                batch.batch, self.spec.eval_batch
            ));
        }
        // The artifact reduces over the WHOLE batch; padded rows repeat the
        // last valid sample.  For exact per-`valid` numbers we evaluate the
        // padded batch and correct by evaluating padding's contribution —
        // cheaper: when valid == batch there is nothing to correct; the
        // loaders only pad the final batch.
        let inputs = self.inputs(model, batch, self.spec.eval_batch)?;
        let outs = Self::run(&self.eval_exe, &inputs, self.meta.eval_outputs)?;
        self.eval_calls += 1;
        let loss_sum = outs[0]
            .get_first_element::<f32>()
            .map_err(err("loss_sum"))? as f64;
        let correct = outs[1]
            .get_first_element::<f32>()
            .map_err(err("n_correct"))? as f64;
        if valid == batch.batch {
            return Ok((loss_sum, correct));
        }
        // padded tail: all padded rows are copies of the last valid row —
        // compute its contribution once and subtract (batch.batch - valid)×.
        let pad = (batch.batch - valid) as f64;
        let c = self.spec.classes;
        let d = self.spec.input_dim;
        let last = valid - 1;
        // rerun a batch filled with the last row to get its per-row values
        let mut x1 = Vec::with_capacity(batch.batch * d);
        let mut y1 = Vec::with_capacity(batch.batch * c);
        for _ in 0..batch.batch {
            x1.extend_from_slice(&batch.x[last * d..(last + 1) * d]);
            y1.extend_from_slice(&batch.onehot[last * c..(last + 1) * c]);
        }
        let b1 = Batch { x: x1, onehot: y1, batch: batch.batch };
        let inputs1 = self.inputs(model, &b1, self.spec.eval_batch)?;
        let outs1 = Self::run(&self.eval_exe, &inputs1, self.meta.eval_outputs)?;
        let row_loss = outs1[0].get_first_element::<f32>().map_err(err("pad loss"))? as f64
            / batch.batch as f64;
        let row_correct = outs1[1].get_first_element::<f32>().map_err(err("pad corr"))? as f64
            / batch.batch as f64;
        Ok((loss_sum - pad * row_loss, correct - pad * row_correct))
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
