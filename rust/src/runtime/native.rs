//! Native backend: pure-Rust MLP forward/backward with EXACTLY the L2
//! model's semantics (dense → bias → ReLU on hidden layers, linear head,
//! mean softmax cross-entropy; gradients of the mean loss).
//!
//! Used for (a) fast multi-seed experiment sweeps, (b) numerically
//! cross-checking the PJRT path (see rust/tests/integration_runtime.rs),
//! and (c) CI-style tests that must not depend on artifacts being built.
//!
//! The matmuls use i-k-j loop order (row-major streaming) — see the §Perf
//! log in EXPERIMENTS.md for the optimization history.

use super::backend::{Backend, ModelSpec};
use crate::data::Batch;
use crate::fl::ModelState;

pub struct NativeBackend {
    spec: ModelSpec,
    /// scratch: activations per layer (input + hidden outputs + logits)
    acts: Vec<Vec<f32>>,
}

impl NativeBackend {
    pub fn new(spec: ModelSpec) -> NativeBackend {
        NativeBackend { spec, acts: Vec::new() }
    }

    /// Convenience spec used across tests: 48 → 32 → 10, batch 16.
    pub fn tiny() -> NativeBackend {
        NativeBackend::new(ModelSpec {
            input_dim: 48,
            hidden: vec![32],
            classes: 10,
            train_batch: 16,
            eval_batch: 32,
        })
    }

    /// out[b, n] (+)= x[b, k] * w[k, n]   (accumulating matmul).
    ///
    /// Loop order is k-outer / b-inner so each 4·n-byte weight row is read
    /// from DRAM exactly ONCE per call (the weight matrix is the only
    /// operand larger than L2).  The b-inner axpy keeps `out` (b×n) hot in
    /// L2 and auto-vectorizes.  §Perf: this order is ~2× faster than the
    /// classic ikj order on the cifar shapes (memory-bound; see
    /// EXPERIMENTS.md).
    fn matmul_acc(x: &[f32], w: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
        debug_assert_eq!(x.len(), b * k);
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(out.len(), b * n);
        for ki in 0..k {
            let wrow = &w[ki * n..(ki + 1) * n];
            for bi in 0..b {
                let xv = x[bi * k + ki];
                if xv == 0.0 {
                    continue; // ReLU sparsity
                }
                let orow = &mut out[bi * n..(bi + 1) * n];
                for (ov, &wv) in orow.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
        }
    }

    /// dx[b, k] = dy[b, n] * w[k, n]^T — k-outer so each w row streams once.
    fn matmul_nt(dy: &[f32], w: &[f32], dx: &mut [f32], b: usize, k: usize, n: usize) {
        for ki in 0..k {
            let wrow = &w[ki * n..(ki + 1) * n];
            for bi in 0..b {
                let dyrow = &dy[bi * n..(bi + 1) * n];
                let mut acc = 0.0f32;
                for (dv, wv) in dyrow.iter().zip(wrow) {
                    acc += dv * wv;
                }
                dx[bi * k + ki] = acc;
            }
        }
    }

    /// dw[k, n] += x[b, k]^T * dy[b, n] — k-outer: each dw row is built in
    /// registers/L1 across the whole batch, then written once.
    fn matmul_tn(x: &[f32], dy: &[f32], dw: &mut [f32], b: usize, k: usize, n: usize) {
        for ki in 0..k {
            let dwrow = &mut dw[ki * n..(ki + 1) * n];
            for bi in 0..b {
                let xv = x[bi * k + ki];
                if xv == 0.0 {
                    continue;
                }
                let dyrow = &dy[bi * n..(bi + 1) * n];
                for (dwv, &dv) in dwrow.iter_mut().zip(dyrow) {
                    *dwv += xv * dv;
                }
            }
        }
    }

    /// Forward pass through all layers; fills self.acts (acts[0] = input,
    /// acts[L] = logits).  Hidden activations are post-ReLU.
    fn forward(&mut self, model: &ModelState, x: &[f32], b: usize) {
        let dims = self.spec.layer_dims();
        self.acts.clear();
        self.acts.push(x.to_vec());
        for (li, &(din, dout)) in dims.iter().enumerate() {
            let w = &model.tensors[2 * li];
            let bias = &model.tensors[2 * li + 1];
            let mut out = vec![0.0f32; b * dout];
            // bias init then accumulate
            for bi in 0..b {
                out[bi * dout..(bi + 1) * dout].copy_from_slice(bias);
            }
            Self::matmul_acc(&self.acts[li], w, &mut out, b, din, dout);
            if li + 1 < dims.len() {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            self.acts.push(out);
        }
    }

    /// (per-row losses, probs) from logits.
    fn softmax_xent(logits: &[f32], onehot: &[f32], b: usize, c: usize) -> (Vec<f32>, Vec<f32>) {
        let mut loss = vec![0.0f32; b];
        let mut probs = vec![0.0f32; b * c];
        for bi in 0..b {
            let z = &logits[bi * c..(bi + 1) * c];
            let y = &onehot[bi * c..(bi + 1) * c];
            let zmax = z.iter().cloned().fold(f32::MIN, f32::max);
            let mut sez = 0.0f64;
            for &v in z {
                sez += ((v - zmax) as f64).exp();
            }
            let lse = (sez.ln() + zmax as f64) as f32;
            let mut dot = 0.0f32;
            for (zv, yv) in z.iter().zip(y) {
                dot += zv * yv;
            }
            loss[bi] = lse - dot;
            for (pi, &zv) in z.iter().enumerate() {
                probs[bi * c + pi] = (((zv - lse) as f64).exp()) as f32;
            }
        }
        (loss, probs)
    }
}

impl Backend for NativeBackend {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        model: &ModelState,
        batch: &Batch,
    ) -> Result<(f64, Vec<Vec<f32>>), String> {
        let b = batch.batch;
        if b != self.spec.train_batch {
            return Err(format!(
                "batch {b} != train_batch {}",
                self.spec.train_batch
            ));
        }
        let dims = self.spec.layer_dims();
        let c = self.spec.classes;
        self.forward(model, &batch.x, b);
        let logits = self.acts.last().unwrap();
        let (loss_rows, probs) = Self::softmax_xent(logits, &batch.onehot, b, c);
        let mean_loss =
            loss_rows.iter().map(|&v| v as f64).sum::<f64>() / b as f64;
        // backward
        let mut grads: Vec<Vec<f32>> = model.tensors.iter().map(|t| vec![0.0; t.len()]).collect();
        // dlogits = (probs − onehot)/B
        let mut dz: Vec<f32> = probs
            .iter()
            .zip(&batch.onehot)
            .map(|(p, y)| (p - y) / b as f32)
            .collect();
        for li in (0..dims.len()).rev() {
            let (din, dout) = dims[li];
            let h_in = &self.acts[li];
            // db
            for bi in 0..b {
                for (dbv, dzv) in grads[2 * li + 1]
                    .iter_mut()
                    .zip(&dz[bi * dout..(bi + 1) * dout])
                {
                    *dbv += dzv;
                }
            }
            // dW = h_in^T dz
            {
                let dw = &mut grads[2 * li];
                Self::matmul_tn(h_in, &dz, dw, b, din, dout);
            }
            if li > 0 {
                // dh = dz W^T, masked by ReLU (h_in > 0)
                let w = &model.tensors[2 * li];
                let mut dh = vec![0.0f32; b * din];
                Self::matmul_nt(&dz, w, &mut dh, b, din, dout);
                for (dhv, &hv) in dh.iter_mut().zip(h_in) {
                    if hv <= 0.0 {
                        *dhv = 0.0;
                    }
                }
                dz = dh;
            }
        }
        Ok((mean_loss, grads))
    }

    fn eval_batch(
        &mut self,
        model: &ModelState,
        batch: &Batch,
        valid: usize,
    ) -> Result<(f64, f64), String> {
        let b = batch.batch;
        let c = self.spec.classes;
        self.forward(model, &batch.x, b);
        let logits = self.acts.last().unwrap();
        let (loss_rows, _) = Self::softmax_xent(logits, &batch.onehot, b, c);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for bi in 0..valid.min(b) {
            loss_sum += loss_rows[bi] as f64;
            let z = &logits[bi * c..(bi + 1) * c];
            let pred = z
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            let label = batch.onehot[bi * c..(bi + 1) * c]
                .iter()
                .position(|&v| v == 1.0)
                .unwrap_or(0);
            if pred == label {
                correct += 1.0;
            }
        }
        Ok((loss_sum, correct))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, EvalBatches, SynthSpec};
    use crate::util::rng::Rng;

    fn batch_of(spec: &ModelSpec, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let b = spec.train_batch;
        let x: Vec<f32> = (0..b * spec.input_dim).map(|_| rng.normal() as f32).collect();
        let mut onehot = vec![0.0f32; b * spec.classes];
        for bi in 0..b {
            onehot[bi * spec.classes + rng.usize_below(spec.classes)] = 1.0;
        }
        Batch { x, onehot, batch: b }
    }

    #[test]
    fn loss_decreases_under_sgd() {
        let mut be = NativeBackend::tiny();
        let mut model = be.spec().init_model(3);
        let batch = batch_of(&be.spec().clone(), 4);
        let (l0, _) = be.train_step(&model, &batch).unwrap();
        for _ in 0..30 {
            let (_, g) = be.train_step(&model, &batch).unwrap();
            model.apply_update(&g, 0.1);
        }
        let (l1, _) = be.train_step(&model, &batch).unwrap();
        assert!(l1 < l0 * 0.7, "loss {l0} -> {l1}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut be = NativeBackend::new(ModelSpec {
            input_dim: 6,
            hidden: vec![5],
            classes: 3,
            train_batch: 4,
            eval_batch: 4,
        });
        let model = be.spec().init_model(7);
        let batch = batch_of(&be.spec().clone(), 8);
        let (_, grads) = be.train_step(&model, &batch).unwrap();
        let eps = 1e-3f32;
        let mut checked = 0;
        for ti in 0..model.tensors.len() {
            for wi in (0..model.tensors[ti].len()).step_by(5) {
                let mut mp = model.clone();
                mp.tensors[ti][wi] += eps;
                let (lp, _) = be.train_step(&mp, &batch).unwrap();
                let mut mm = model.clone();
                mm.tensors[ti][wi] -= eps;
                let (lm, _) = be.train_step(&mm, &batch).unwrap();
                let fd = (lp - lm) / (2.0 * eps as f64);
                let an = grads[ti][wi] as f64;
                assert!(
                    (fd - an).abs() < 2e-3 * (1.0 + fd.abs().max(an.abs())),
                    "tensor {ti} idx {wi}: fd {fd} vs analytic {an}"
                );
                checked += 1;
            }
        }
        assert!(checked > 10);
    }

    #[test]
    fn batch_size_validated() {
        let mut be = NativeBackend::tiny();
        let model = be.spec().init_model(1);
        let mut batch = batch_of(&be.spec().clone(), 1);
        batch.batch = 99;
        assert!(be.train_step(&model, &batch).is_err());
    }

    #[test]
    fn eval_counts_valid_rows_only() {
        let mut be = NativeBackend::tiny();
        let model = be.spec().init_model(2);
        let spec = be.spec().clone();
        let mut rng = Rng::new(5);
        let b = spec.eval_batch;
        let x: Vec<f32> = (0..b * spec.input_dim).map(|_| rng.normal() as f32).collect();
        let mut onehot = vec![0.0f32; b * spec.classes];
        for bi in 0..b {
            onehot[bi * spec.classes] = 1.0;
        }
        let batch = Batch { x, onehot, batch: b };
        let (l_all, c_all) = be.eval_batch(&model, &batch, b).unwrap();
        let (l_half, c_half) = be.eval_batch(&model, &batch, b / 2).unwrap();
        assert!(l_half < l_all);
        assert!(c_half <= c_all);
    }

    #[test]
    fn training_on_synthetic_data_beats_chance() {
        // end-to-end learnability on the synthetic task (native only)
        let spec = SynthSpec::tiny_test();
        let train = generate(&spec, 1500, 11);
        let val = generate(&spec, 400, 12);
        let mspec = ModelSpec {
            input_dim: spec.dim(),
            hidden: vec![32],
            classes: spec.classes,
            train_batch: 32,
            eval_batch: 50,
        };
        let mut be = NativeBackend::new(mspec.clone());
        let mut model = mspec.init_model(13);
        let mut loader = crate::data::ClientLoader::new(
            std::sync::Arc::new(train),
            (0..1500u32).collect(),
            32,
            false,
            14,
        )
        .unwrap();
        for _ in 0..150 {
            let batch = loader.next_batch();
            let (_, g) = be.train_step(&model, &batch).unwrap();
            model.apply_update(&g, 0.05);
        }
        let ev = EvalBatches::new(&val, 50);
        let summary = be.evaluate(&model, &ev).unwrap();
        assert!(
            summary.accuracy > 0.5,
            "val accuracy {} should beat 0.1 chance comfortably",
            summary.accuracy
        );
    }
}
