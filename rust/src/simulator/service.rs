//! Service-time distributions for client task processing.
//!
//! The paper's theory assumes exponential durations (Jackson network);
//! its worked example (§2) also studies deterministic durations and notes
//! the results barely change when means are preserved.  LogNormal is
//! provided as the "almost arbitrary distribution" stress case.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServiceDist {
    /// Exponential with given rate μ (mean 1/μ).
    Exp { rate: f64 },
    /// Deterministic duration (mean preserved vs Exp{rate: 1/mean}).
    Det { mean: f64 },
    /// LogNormal with target mean and coefficient of variation.
    LogNormal { mean: f64, cv: f64 },
}

impl ServiceDist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            ServiceDist::Exp { rate } => rng.exponential(rate),
            ServiceDist::Det { mean } => mean,
            ServiceDist::LogNormal { mean, cv } => rng.lognormal_mean_cv(mean, cv),
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            ServiceDist::Exp { rate } => 1.0 / rate,
            ServiceDist::Det { mean } => mean,
            ServiceDist::LogNormal { mean, .. } => mean,
        }
    }

    /// Service *rate* (1/mean) — the μ_i of the Jackson model.
    pub fn rate(&self) -> f64 {
        1.0 / self.mean()
    }

    /// Build a per-node vector from per-node rates with a common family.
    pub fn from_rates(rates: &[f64], family: ServiceFamily) -> Vec<ServiceDist> {
        rates
            .iter()
            .map(|&r| match family {
                ServiceFamily::Exponential => ServiceDist::Exp { rate: r },
                ServiceFamily::Deterministic => ServiceDist::Det { mean: 1.0 / r },
                ServiceFamily::LogNormal(cv) => ServiceDist::LogNormal { mean: 1.0 / r, cv },
            })
            .collect()
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServiceFamily {
    Exponential,
    Deterministic,
    LogNormal(f64),
}

impl std::str::FromStr for ServiceFamily {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "exp" | "exponential" => Ok(ServiceFamily::Exponential),
            "det" | "deterministic" => Ok(ServiceFamily::Deterministic),
            "lognormal" => Ok(ServiceFamily::LogNormal(0.5)),
            other => Err(format!("unknown service family '{other}' (exp|det|lognormal)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_are_preserved_across_families() {
        let mut rng = Rng::new(1);
        let n = 200_000;
        for fam in [
            ServiceFamily::Exponential,
            ServiceFamily::Deterministic,
            ServiceFamily::LogNormal(0.5),
        ] {
            let d = ServiceDist::from_rates(&[2.0], fam)[0];
            assert!((d.mean() - 0.5).abs() < 1e-12);
            let emp: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!((emp - 0.5).abs() < 0.01, "{fam:?}: emp mean {emp}");
        }
    }

    #[test]
    fn det_has_zero_variance() {
        let mut rng = Rng::new(2);
        let d = ServiceDist::Det { mean: 1.5 };
        for _ in 0..100 {
            assert_eq!(d.sample(&mut rng), 1.5);
        }
    }

    #[test]
    fn family_parsing() {
        assert_eq!("exp".parse::<ServiceFamily>().unwrap(), ServiceFamily::Exponential);
        assert_eq!("det".parse::<ServiceFamily>().unwrap(), ServiceFamily::Deterministic);
        assert!("weibull".parse::<ServiceFamily>().is_err());
    }

    #[test]
    fn rates_roundtrip() {
        let v = ServiceDist::from_rates(&[1.0, 4.0], ServiceFamily::Exponential);
        assert_eq!(v[1].rate(), 4.0);
    }
}
