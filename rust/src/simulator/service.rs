//! Service-time distributions for client task processing.
//!
//! The paper's theory assumes exponential durations (Jackson network);
//! its worked example (§2) also studies deterministic durations and notes
//! the results barely change when means are preserved.  LogNormal is
//! provided as the "almost arbitrary distribution" stress case.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServiceDist {
    /// Exponential with given rate μ (mean 1/μ).
    Exp { rate: f64 },
    /// Deterministic duration (mean preserved vs Exp{rate: 1/mean}).
    Det { mean: f64 },
    /// LogNormal with target mean and coefficient of variation.
    LogNormal { mean: f64, cv: f64 },
}

impl ServiceDist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            ServiceDist::Exp { rate } => rng.exponential(rate),
            ServiceDist::Det { mean } => mean,
            ServiceDist::LogNormal { mean, cv } => rng.lognormal_mean_cv(mean, cv),
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            ServiceDist::Exp { rate } => 1.0 / rate,
            ServiceDist::Det { mean } => mean,
            ServiceDist::LogNormal { mean, .. } => mean,
        }
    }

    /// Service *rate* (1/mean) — the μ_i of the Jackson model.
    pub fn rate(&self) -> f64 {
        1.0 / self.mean()
    }

    /// Build a per-node vector from per-node rates with a common family.
    pub fn from_rates(rates: &[f64], family: ServiceFamily) -> Vec<ServiceDist> {
        rates
            .iter()
            .map(|&r| match family {
                ServiceFamily::Exponential => ServiceDist::Exp { rate: r },
                ServiceFamily::Deterministic => ServiceDist::Det { mean: 1.0 / r },
                ServiceFamily::LogNormal(cv) => ServiceDist::LogNormal { mean: 1.0 / r, cv },
            })
            .collect()
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServiceFamily {
    Exponential,
    Deterministic,
    LogNormal(f64),
}

impl std::str::FromStr for ServiceFamily {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "exp" | "exponential" => Ok(ServiceFamily::Exponential),
            "det" | "deterministic" => Ok(ServiceFamily::Deterministic),
            // bare `lognormal` keeps the historical default cv
            "lognormal" => Ok(ServiceFamily::LogNormal(0.5)),
            other => {
                if let Some(cv_str) = other.strip_prefix("lognormal:") {
                    let cv: f64 = cv_str.parse().map_err(|_| {
                        format!("lognormal cv '{cv_str}' is not a number (want lognormal:<cv>)")
                    })?;
                    if !cv.is_finite() || cv <= 0.0 {
                        return Err(format!(
                            "lognormal cv must be finite and > 0, got {cv}"
                        ));
                    }
                    return Ok(ServiceFamily::LogNormal(cv));
                }
                Err(format!(
                    "unknown service family '{other}' (exp|det|lognormal|lognormal:<cv>)"
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_are_preserved_across_families() {
        let mut rng = Rng::new(1);
        let n = 200_000;
        for fam in [
            ServiceFamily::Exponential,
            ServiceFamily::Deterministic,
            ServiceFamily::LogNormal(0.5),
        ] {
            let d = ServiceDist::from_rates(&[2.0], fam)[0];
            assert!((d.mean() - 0.5).abs() < 1e-12);
            let emp: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!((emp - 0.5).abs() < 0.01, "{fam:?}: emp mean {emp}");
        }
    }

    #[test]
    fn det_has_zero_variance() {
        let mut rng = Rng::new(2);
        let d = ServiceDist::Det { mean: 1.5 };
        for _ in 0..100 {
            assert_eq!(d.sample(&mut rng), 1.5);
        }
    }

    #[test]
    fn family_parsing() {
        assert_eq!("exp".parse::<ServiceFamily>().unwrap(), ServiceFamily::Exponential);
        assert_eq!("det".parse::<ServiceFamily>().unwrap(), ServiceFamily::Deterministic);
        assert!("weibull".parse::<ServiceFamily>().is_err());
    }

    #[test]
    fn lognormal_parsing_accepts_explicit_cv() {
        assert_eq!(
            "lognormal".parse::<ServiceFamily>().unwrap(),
            ServiceFamily::LogNormal(0.5),
            "bare spelling keeps the historical default"
        );
        assert_eq!(
            "lognormal:1.2".parse::<ServiceFamily>().unwrap(),
            ServiceFamily::LogNormal(1.2)
        );
        assert_eq!(
            "lognormal:0.05".parse::<ServiceFamily>().unwrap(),
            ServiceFamily::LogNormal(0.05)
        );
        for bad in ["lognormal:0", "lognormal:-1", "lognormal:nan", "lognormal:inf"] {
            let err = bad.parse::<ServiceFamily>().unwrap_err();
            assert!(
                err.contains("cv"),
                "{bad}: error should name the cv: {err}"
            );
        }
        assert!("lognormal:abc".parse::<ServiceFamily>().is_err());
        assert!("lognormal:".parse::<ServiceFamily>().is_err());
    }

    #[test]
    fn rates_roundtrip() {
        let v = ServiceDist::from_rates(&[1.0, 4.0], ServiceFamily::Exponential);
        assert_eq!(v[1].rate(), 4.0);
    }
}
