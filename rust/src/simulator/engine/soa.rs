//! Struct-of-arrays node/task state for the sharded engine.
//!
//! The heap engine keeps one `VecDeque<Task>` per node — at n = 10^6 that
//! is a million separately allocated ring buffers walked through a layer
//! of pointers.  Tasks are homogeneous (dispatch step, dispatch time,
//! dispatch probability), and a closed network holds **exactly C of them
//! at all times**, so the sharded engine stores them in one flat pool of
//! capacity C with intrusive per-node FIFO lists:
//!
//! * task fields live in parallel `Vec`s indexed by pool slot,
//! * each node carries `head`/`tail` slot indices plus a flat `qlen`
//!   array (the busy flag is `qlen > 0`), and
//! * freed slots go to a free list; a CS step frees one slot (completion)
//!   and reuses it (the routed replacement), so the pool never grows.
//!
//! Total footprint is ~28 B per task + ~12 B per node, in five
//! allocations, regardless of n.
//!
//! The batch replication engine (`engine::batch`) reuses the pool
//! *replication-major*: R same-shape replications share one pool of R·n
//! virtual nodes (global index `rep·n + node`) and capacity R·C — one
//! allocation for all R task pools.  [`TaskPool::qlens_of`] /
//! [`TaskPool::population_of`] expose a single replication's contiguous
//! window of that layout.

use super::EngineError;

/// Null slot / null node sentinel for the intrusive lists.
pub(crate) const NIL: u32 = u32::MAX;

/// Flat task pool + per-node FIFO queues.
#[derive(Debug)]
pub(crate) struct TaskPool {
    // per-slot task fields (parallel arrays, capacity = C)
    dispatch_step: Vec<u64>,
    dispatch_time: Vec<f64>,
    dispatch_prob: Vec<f64>,
    /// next slot in the owning node's FIFO (or the free list)
    next: Vec<u32>,
    free_head: u32,
    capacity: usize,
    // per-node FIFO state
    head: Vec<u32>,
    tail: Vec<u32>,
    qlen: Vec<u32>,
}

impl TaskPool {
    pub fn new(nodes: usize, capacity: usize) -> TaskPool {
        let cap = capacity as u32;
        TaskPool {
            dispatch_step: vec![0; capacity],
            dispatch_time: vec![0.0; capacity],
            dispatch_prob: vec![0.0; capacity],
            // free list threads every slot: 0 -> 1 -> ... -> NIL
            next: (1..=cap).map(|i| if i == cap { NIL } else { i }).collect(),
            free_head: if capacity == 0 { NIL } else { 0 },
            capacity,
            head: vec![NIL; nodes],
            tail: vec![NIL; nodes],
            qlen: vec![0; nodes],
        }
    }

    #[inline]
    pub fn qlen(&self, node: usize) -> u32 {
        self.qlen[node]
    }

    /// The flat queue-length array (for bulk policy observation).
    #[inline]
    pub fn qlens(&self) -> &[u32] {
        &self.qlen
    }

    /// Append a task to `node`'s FIFO; returns the new queue length.
    /// Panics on an exhausted pool — the hot-path variant, valid once the
    /// population invariant is established (a CS step frees a slot before
    /// reusing it). Constructors placing the initial population use
    /// [`TaskPool::try_push`] so a mis-sized scenario errors instead.
    pub fn push(&mut self, node: usize, step: u64, time: f64, prob: f64) -> u32 {
        match self.try_push(node, step, time, prob) {
            Ok(len) => len,
            // keep the historical panic text: "task pool exhausted ..."
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible append: `EngineError::PoolExhausted` when no slot is free.
    pub fn try_push(
        &mut self,
        node: usize,
        step: u64,
        time: f64,
        prob: f64,
    ) -> Result<u32, EngineError> {
        let slot = self.free_head;
        if slot == NIL {
            return Err(EngineError::PoolExhausted {
                node,
                capacity: self.capacity,
            });
        }
        let s = slot as usize;
        self.free_head = self.next[s];
        self.dispatch_step[s] = step;
        self.dispatch_time[s] = time;
        self.dispatch_prob[s] = prob;
        self.next[s] = NIL;
        if self.tail[node] == NIL {
            self.head[node] = slot;
        } else {
            self.next[self.tail[node] as usize] = slot;
        }
        self.tail[node] = slot;
        self.qlen[node] += 1;
        Ok(self.qlen[node])
    }

    /// Pop the head of `node`'s FIFO; returns the task's
    /// (dispatch_step, dispatch_time, dispatch_prob) and the new length.
    pub fn pop(&mut self, node: usize) -> (u64, f64, f64, u32) {
        let slot = self.head[node];
        assert_ne!(slot, NIL, "completion event for empty queue");
        let s = slot as usize;
        self.head[node] = self.next[s];
        if self.head[node] == NIL {
            self.tail[node] = NIL;
        }
        self.qlen[node] -= 1;
        let out = (
            self.dispatch_step[s],
            self.dispatch_time[s],
            self.dispatch_prob[s],
            self.qlen[node],
        );
        self.next[s] = self.free_head;
        self.free_head = slot;
        out
    }

    /// Queue lengths of the `len` nodes starting at `lo` — one
    /// replication's window of a replication-major pool.
    #[inline]
    pub fn qlens_of(&self, lo: usize, len: usize) -> &[u32] {
        &self.qlen[lo..lo + len]
    }

    /// Total tasks currently queued (must equal C once initialized).
    pub fn population(&self) -> usize {
        self.qlen.iter().map(|&q| q as usize).sum()
    }

    /// Tasks queued in the `len`-node window starting at `lo` — a
    /// replication's population in a replication-major pool.
    pub fn population_of(&self, lo: usize, len: usize) -> usize {
        self.qlens_of(lo, len).iter().map(|&q| q as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_per_node() {
        let mut pool = TaskPool::new(3, 4);
        assert_eq!(pool.push(1, 10, 0.5, 0.25), 1);
        assert_eq!(pool.push(1, 11, 0.6, 0.30), 2);
        assert_eq!(pool.push(2, 12, 0.7, 0.45), 1);
        assert_eq!(pool.qlen(1), 2);
        assert_eq!(pool.population(), 3);
        let (step, time, prob, len) = pool.pop(1);
        assert_eq!((step, len), (10, 1));
        assert_eq!(time, 0.5);
        assert_eq!(prob, 0.25);
        let (step, _, _, len) = pool.pop(1);
        assert_eq!((step, len), (11, 0));
        assert_eq!(pool.qlen(1), 0);
        let (step, _, _, _) = pool.pop(2);
        assert_eq!(step, 12);
        assert_eq!(pool.population(), 0);
    }

    #[test]
    fn slots_are_recycled() {
        let mut pool = TaskPool::new(2, 2);
        pool.push(0, 1, 0.0, 0.5);
        pool.push(0, 2, 0.0, 0.5);
        // pool full: a pop frees exactly one slot for the next push
        pool.pop(0);
        pool.push(1, 3, 1.0, 0.5);
        pool.pop(0);
        pool.push(1, 4, 2.0, 0.5);
        assert_eq!(pool.qlen(0), 0);
        assert_eq!(pool.qlen(1), 2);
        let (a, _, _, _) = pool.pop(1);
        let (b, _, _, _) = pool.pop(1);
        assert_eq!((a, b), (3, 4), "FIFO survives slot reuse");
    }

    #[test]
    fn replication_major_windows_are_independent() {
        // two "replications" of 3 nodes sharing one 6-virtual-node pool
        let mut pool = TaskPool::new(6, 4);
        pool.push(0, 1, 0.0, 0.5); // rep 0, node 0
        pool.push(3, 2, 0.0, 0.5); // rep 1, node 0
        pool.push(4, 3, 0.0, 0.5); // rep 1, node 1
        assert_eq!(pool.qlens_of(0, 3), &[1, 0, 0]);
        assert_eq!(pool.qlens_of(3, 3), &[1, 1, 0]);
        assert_eq!(pool.population_of(0, 3), 1);
        assert_eq!(pool.population_of(3, 3), 2);
        assert_eq!(pool.population(), 3);
        let (step, _, _, _) = pool.pop(3);
        assert_eq!(step, 2, "rep 1's FIFO untouched by rep 0");
        assert_eq!(pool.population_of(0, 3), 1);
    }

    #[test]
    #[should_panic(expected = "task pool exhausted")]
    fn overfull_pool_panics() {
        let mut pool = TaskPool::new(1, 1);
        pool.push(0, 0, 0.0, 1.0);
        pool.push(0, 1, 0.0, 1.0);
    }

    #[test]
    fn overfull_pool_try_push_returns_typed_error() {
        let mut pool = TaskPool::new(2, 1);
        assert_eq!(pool.try_push(0, 0, 0.0, 1.0), Ok(1));
        let err = pool.try_push(1, 1, 0.0, 1.0).unwrap_err();
        assert_eq!(
            err,
            EngineError::PoolExhausted {
                node: 1,
                capacity: 1
            }
        );
        assert!(err.to_string().contains("task pool exhausted"), "{err}");
        // the failed push must not corrupt the pool: a pop frees the one
        // slot and the push then succeeds
        pool.pop(0);
        assert_eq!(pool.try_push(1, 1, 0.0, 1.0), Ok(1));
        assert_eq!(pool.population(), 1);
    }

    #[test]
    #[should_panic(expected = "empty queue")]
    fn popping_empty_queue_panics() {
        let mut pool = TaskPool::new(1, 1);
        pool.pop(0);
    }
}
