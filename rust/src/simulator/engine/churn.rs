//! Open-network churn: node join / leave / stall / rejoin / rate-change
//! lifecycle events layered on top of the closed Jackson network.
//!
//! The paper's analysis assumes a fixed node set with stationary service
//! rates; production asynchronous FL is an *open* system (arXiv:2603.26231)
//! where devices drop mid-training, stragglers stall, and speeds drift.
//! This module supplies the shared pieces every engine uses to model that:
//!
//! * [`ChurnConfig`] — the `[churn]` scenario knobs (arrival rate, Exp
//!   lifetime, stall/rejoin process, markov-modulated rate factors).
//! * [`generate_schedule`] — a *precomputed* event stream that is a pure
//!   function of `(seed, config, n)`. Every engine derives the identical
//!   schedule from `churn_seed(cfg.seed)`, so the heap oracle, the sharded
//!   engine (any shard/thread count), and the batch arena (any width)
//!   apply byte-identical membership deltas in the same total order.
//! * [`ChurnRuntime`] — the per-engine (per-replication, for the batch
//!   arena) runtime state: membership masks, per-node service-rate scale,
//!   the pending-completion sequence numbers that implement lazy
//!   cancellation in the `(time, seq)` calendars, and the queue-delta log
//!   consumed by `StepAggregator` so time-averaged metrics stay exact
//!   under churn.
//!
//! Determinism notes: the schedule generator owns its own RNG stream
//! (`CHURN_STREAM`), fully separate from the routing and service streams,
//! so enabling churn never perturbs those draws. The generator models
//! membership only (never queue contents) and maintains two liveness
//! invariants by construction: at least one *member* (routable node)
//! always remains, and at least one *running* (non-stalled member) node
//! always remains. When the event budget runs out, any still-stalled
//! nodes get a final `Rejoin` so no task is stranded forever.

use std::collections::BTreeMap;

use crate::util::rng::{stream_seed, Rng};
use crate::util::toml::Value;

/// Dedicated RNG stream tag for the churn schedule (cf. `ROUTE_STREAM`).
pub(crate) const CHURN_STREAM: u64 = 0xC4_FE_11;

/// Derive the churn-schedule seed from the experiment seed.
pub(crate) fn churn_seed(seed: u64) -> u64 {
    stream_seed(seed, &[CHURN_STREAM])
}

/// `[churn]` scenario block: an open-network lifecycle process.
///
/// All hazards are exponential, which makes the node lifecycle a
/// continuous-time Markov chain; `SetRate` events with Exp holding times
/// give a markov-modulated (piecewise-constant, time-varying) service
/// rate per node.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnConfig {
    /// Join hazard while at least one node is departed (0 = no joins).
    /// Joins reclaim the lowest-index departed slot — free-list order.
    pub arrival_rate: f64,
    /// Mean Exp membership lifetime; 0 = nodes never leave.
    pub mean_lifetime: f64,
    /// Per-running-node stall hazard (0 = no stalls).
    pub stall_rate: f64,
    /// Mean Exp stall duration (rejoin hazard is `1 / mean_stall`).
    pub mean_stall: f64,
    /// Per-member service-rate modulation hazard (0 = stationary rates).
    pub rate_change_rate: f64,
    /// `SetRate` duration scale drawn uniformly in `[min, max]`.
    /// Scales the *duration*, so a factor > 1 means a slower node.
    pub rate_factor_min: f64,
    pub rate_factor_max: f64,
    /// Number of nodes active at t = 0 (0 = all `n`); the remainder
    /// start departed and join through `arrival_rate`.
    pub initial_active: usize,
    /// Cap on generated lifecycle events (wind-down rejoins excluded).
    pub max_events: usize,
}

/// Every `[churn]` TOML key, in the order the error message lists them.
/// Shared by [`ChurnConfig::from_toml_table`] and the docs cross-check
/// (`tests/scenario_lint.rs`) so the parser and `docs/SCENARIOS.md`
/// cannot drift apart.
pub const CHURN_KEYS: &[&str] = &[
    "arrival_rate",
    "mean_lifetime",
    "stall_rate",
    "mean_stall",
    "rate_change_rate",
    "rate_factor_min",
    "rate_factor_max",
    "initial_active",
    "max_events",
];

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            arrival_rate: 0.0,
            mean_lifetime: 0.0,
            stall_rate: 0.0,
            mean_stall: 1.0,
            rate_change_rate: 0.0,
            rate_factor_min: 1.0,
            rate_factor_max: 1.0,
            initial_active: 0,
            max_events: 10_000,
        }
    }
}

impl ChurnConfig {
    /// Number of nodes active at t = 0 (`0` means "all of them").
    pub fn initial_active_count(&self, n: usize) -> usize {
        if self.initial_active == 0 {
            n
        } else {
            self.initial_active
        }
    }

    pub fn validate(&self, n: usize) -> Result<(), String> {
        let rates = [
            ("arrival_rate", self.arrival_rate),
            ("mean_lifetime", self.mean_lifetime),
            ("stall_rate", self.stall_rate),
            ("mean_stall", self.mean_stall),
            ("rate_change_rate", self.rate_change_rate),
        ];
        for (name, v) in rates {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("[churn] {name} must be finite and >= 0, got {v}"));
            }
        }
        if self.stall_rate > 0.0 && self.mean_stall <= 0.0 {
            return Err("[churn] stall_rate > 0 requires mean_stall > 0".into());
        }
        if !(self.rate_factor_min > 0.0)
            || !self.rate_factor_max.is_finite()
            || self.rate_factor_max < self.rate_factor_min
        {
            return Err(format!(
                "[churn] rate factors must satisfy 0 < min <= max < inf, got [{}, {}]",
                self.rate_factor_min, self.rate_factor_max
            ));
        }
        if self.initial_active > n {
            return Err(format!(
                "[churn] initial_active = {} exceeds node count n = {n}",
                self.initial_active
            ));
        }
        if self.initial_active == 0 && n == 0 {
            return Err("[churn] requires at least one node".into());
        }
        Ok(())
    }

    /// Parse a `[churn]` TOML table with the strict known-key contract
    /// used by the sweep and experiment loaders.
    pub fn from_toml_table(tbl: &BTreeMap<String, Value>) -> Result<ChurnConfig, String> {
        let mut cfg = ChurnConfig::default();
        let num = |k: &str, v: &Value| {
            v.as_f64()
                .ok_or_else(|| format!("[churn] {k} must be a number"))
        };
        let count = |k: &str, v: &Value| -> Result<usize, String> {
            match v.as_i64() {
                Some(i) if i >= 0 => Ok(i as usize),
                _ => Err(format!("[churn] {k} must be a non-negative integer")),
            }
        };
        for (k, v) in tbl {
            match k.as_str() {
                "arrival_rate" => cfg.arrival_rate = num(k, v)?,
                "mean_lifetime" => cfg.mean_lifetime = num(k, v)?,
                "stall_rate" => cfg.stall_rate = num(k, v)?,
                "mean_stall" => cfg.mean_stall = num(k, v)?,
                "rate_change_rate" => cfg.rate_change_rate = num(k, v)?,
                "rate_factor_min" => cfg.rate_factor_min = num(k, v)?,
                "rate_factor_max" => cfg.rate_factor_max = num(k, v)?,
                "initial_active" => cfg.initial_active = count(k, v)?,
                "max_events" => cfg.max_events = count(k, v)?,
                other => {
                    return Err(format!(
                        "unknown key '{other}' in [churn] ({})",
                        CHURN_KEYS.join("|")
                    ))
                }
            }
        }
        Ok(cfg)
    }
}

/// One lifecycle transition at [`ChurnEvent::time`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChurnEventKind {
    /// A departed slot rejoins the network (lowest-index slot first).
    Join { node: u32 },
    /// A member leaves; its queued tasks are re-routed by the policy.
    Leave { node: u32 },
    /// A running member stops serving; its queue freezes in place.
    Stall { node: u32 },
    /// A stalled member resumes serving with a fresh keyed service draw.
    Rejoin { node: u32 },
    /// Markov-modulated rate change: subsequent service *durations* on
    /// this node are multiplied by `scale`.
    SetRate { node: u32, scale: f64 },
}

impl ChurnEventKind {
    pub fn node(&self) -> u32 {
        match *self {
            ChurnEventKind::Join { node }
            | ChurnEventKind::Leave { node }
            | ChurnEventKind::Stall { node }
            | ChurnEventKind::Rejoin { node }
            | ChurnEventKind::SetRate { node, .. } => node,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    pub time: f64,
    pub kind: ChurnEventKind,
}

/// O(1) insert/remove set over node ids with stable deterministic
/// iteration order (insertion order with swap-remove holes).
struct SwapSet {
    items: Vec<u32>,
    /// Position of each node in `items`, `u32::MAX` if absent.
    pos: Vec<u32>,
}

impl SwapSet {
    fn new(n: usize) -> SwapSet {
        SwapSet {
            items: Vec::with_capacity(n),
            pos: vec![u32::MAX; n],
        }
    }

    fn len(&self) -> usize {
        self.items.len()
    }

    fn insert(&mut self, node: u32) {
        debug_assert_eq!(self.pos[node as usize], u32::MAX);
        self.pos[node as usize] = self.items.len() as u32;
        self.items.push(node);
    }

    fn remove(&mut self, node: u32) {
        let at = self.pos[node as usize] as usize;
        debug_assert_ne!(at as u32, u32::MAX);
        let last = self.items.pop().expect("remove from empty SwapSet");
        if at < self.items.len() {
            self.items[at] = last;
            self.pos[last as usize] = at as u32;
        }
        self.pos[node as usize] = u32::MAX;
    }

    fn get(&self, i: usize) -> u32 {
        self.items[i]
    }
}

/// Generate the churn schedule as a pure function of `(cfg, seed, n)`.
///
/// `seed` is the *experiment* seed; the generator derives its own stream
/// via [`churn_seed`]. Event times are strictly increasing except for the
/// wind-down `Rejoin` block, which shares the final timestamp (applied in
/// vector order, which is all the engines need).
pub fn generate_schedule(cfg: &ChurnConfig, seed: u64, n: usize) -> Vec<ChurnEvent> {
    let mut rng = Rng::new(churn_seed(seed));
    let k0 = cfg.initial_active_count(n);
    let mut running = SwapSet::new(n);
    let mut stalled = SwapSet::new(n);
    // Sorted ascending: joins always reclaim the lowest-index slot, the
    // same order the engines' free-lists hand slots back.
    let mut departed: Vec<u32> = (k0 as u32..n as u32).collect();
    for i in 0..k0 as u32 {
        running.insert(i);
    }
    let leave_rate = if cfg.mean_lifetime > 0.0 {
        1.0 / cfg.mean_lifetime
    } else {
        0.0
    };
    let rejoin_rate = if cfg.mean_stall > 0.0 {
        1.0 / cfg.mean_stall
    } else {
        0.0
    };

    let mut events = Vec::new();
    let mut t = 0.0f64;
    while events.len() < cfg.max_events {
        let members = running.len() + stalled.len();
        // A member may leave unless it is the sole running node (liveness)
        // or the sole member (routability).
        let eligible_leave = if running.len() <= 1 {
            stalled.len()
        } else {
            members
        };
        let lam_join = if departed.is_empty() {
            0.0
        } else {
            cfg.arrival_rate
        };
        let lam_leave = leave_rate * eligible_leave as f64;
        let lam_stall = if running.len() > 1 {
            cfg.stall_rate * running.len() as f64
        } else {
            0.0
        };
        let lam_rejoin = rejoin_rate * stalled.len() as f64;
        let lam_rate = cfg.rate_change_rate * members as f64;
        let total = lam_join + lam_leave + lam_stall + lam_rejoin + lam_rate;
        if !(total > 0.0) {
            break;
        }
        t += rng.exponential(total);
        let u = rng.uniform() * total;
        let kind = if u < lam_join {
            let node = departed.remove(0);
            running.insert(node);
            ChurnEventKind::Join { node }
        } else if u < lam_join + lam_leave {
            let k = rng.usize_below(eligible_leave);
            // Eligible set = stalled (always) + running when > 1, indexed
            // running-first so both branches scan the same way.
            let node = if running.len() <= 1 {
                stalled.get(k)
            } else if k < running.len() {
                running.get(k)
            } else {
                stalled.get(k - running.len())
            };
            if running.pos[node as usize] != u32::MAX {
                running.remove(node);
            } else {
                stalled.remove(node);
            }
            let at = departed.partition_point(|&d| d < node);
            departed.insert(at, node);
            ChurnEventKind::Leave { node }
        } else if u < lam_join + lam_leave + lam_stall {
            let node = running.get(rng.usize_below(running.len()));
            running.remove(node);
            stalled.insert(node);
            ChurnEventKind::Stall { node }
        } else if u < lam_join + lam_leave + lam_stall + lam_rejoin {
            let node = stalled.get(rng.usize_below(stalled.len()));
            stalled.remove(node);
            running.insert(node);
            ChurnEventKind::Rejoin { node }
        } else {
            let k = rng.usize_below(members);
            let node = if k < running.len() {
                running.get(k)
            } else {
                stalled.get(k - running.len())
            };
            let scale = rng.range_f64(cfg.rate_factor_min, cfg.rate_factor_max);
            ChurnEventKind::SetRate { node, scale }
        };
        events.push(ChurnEvent { time: t, kind });
    }
    // Wind-down: once the budget is spent no further rejoins would fire,
    // so tasks queued on still-stalled nodes would be stranded and the
    // calendars could drain. Rejoin every straggler at the final time.
    let mut stragglers = stalled.items.clone();
    stragglers.sort_unstable();
    for node in stragglers {
        events.push(ChurnEvent {
            time: t,
            kind: ChurnEventKind::Rejoin { node },
        });
    }
    events
}

/// Per-engine (per-replication in the batch arena) churn runtime state.
pub(crate) struct ChurnRuntime {
    events: Vec<ChurnEvent>,
    cursor: usize,
    /// Member but not serving; queued tasks freeze in place.
    pub(crate) stalled: Vec<bool>,
    /// Not a member; never routed to, queue always empty.
    pub(crate) departed: Vec<bool>,
    /// Service-*duration* multiplier (1.0 = nominal) applied at schedule
    /// time; `x * 1.0` is IEEE-exact so the no-churn trace is unchanged.
    pub(crate) rate_scale: Vec<f64>,
    /// Seq of the node's valid in-calendar completion (0 = none). Stall,
    /// leave, and reschedule cancel lazily: calendar fronts whose seq no
    /// longer matches are discarded unprocessed.
    pub(crate) pending_seq: Vec<u64>,
    /// Queue-length deltas `(time, node, new_len)` applied outside the CS
    /// step path (leave drains / re-routes), in application order. The
    /// aggregator flushes these so time-averaged queue metrics stay exact.
    pub(crate) log: Vec<(f64, u32, u32)>,
}

impl ChurnRuntime {
    pub(crate) fn new(cfg: &ChurnConfig, seed: u64, n: usize) -> ChurnRuntime {
        let k0 = cfg.initial_active_count(n);
        ChurnRuntime {
            events: generate_schedule(cfg, seed, n),
            cursor: 0,
            stalled: vec![false; n],
            departed: (0..n).map(|i| i >= k0).collect(),
            rate_scale: vec![1.0; n],
            pending_seq: vec![0; n],
            log: Vec::new(),
        }
    }

    /// Time of the next unapplied lifecycle event (`inf` when exhausted).
    pub(crate) fn next_time(&self) -> f64 {
        self.events
            .get(self.cursor)
            .map_or(f64::INFINITY, |e| e.time)
    }

    pub(crate) fn pop(&mut self) -> Option<ChurnEvent> {
        let ev = self.events.get(self.cursor).copied();
        if ev.is_some() {
            self.cursor += 1;
        }
        ev
    }

    /// True when `seq` identifies the node's still-valid completion.
    pub(crate) fn is_live(&self, node: u32, seq: u64) -> bool {
        self.pending_seq[node as usize] == seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_cfg() -> ChurnConfig {
        ChurnConfig {
            arrival_rate: 0.8,
            mean_lifetime: 4.0,
            stall_rate: 0.3,
            mean_stall: 0.5,
            rate_change_rate: 0.6,
            rate_factor_min: 0.5,
            rate_factor_max: 2.0,
            initial_active: 0,
            max_events: 400,
        }
    }

    #[test]
    fn schedule_is_a_pure_function_of_seed_cfg_n() {
        let cfg = busy_cfg();
        let a = generate_schedule(&cfg, 42, 9);
        let b = generate_schedule(&cfg, 42, 9);
        assert_eq!(a, b);
        let c = generate_schedule(&cfg, 43, 9);
        assert_ne!(a, c, "different seeds must give different schedules");
        assert!(a.len() >= cfg.max_events, "busy config should hit the cap");
    }

    #[test]
    fn schedule_preserves_liveness_invariants() {
        let cfg = busy_cfg();
        let n = 7usize;
        let events = generate_schedule(&cfg, 1234, n);
        let mut departed = vec![false; n];
        let mut stalled = vec![false; n];
        let mut last_t = 0.0f64;
        for ev in &events {
            assert!(ev.time >= last_t, "event times must be non-decreasing");
            last_t = ev.time;
            let node = ev.kind.node() as usize;
            match ev.kind {
                ChurnEventKind::Join { .. } => {
                    assert!(departed[node], "join of a non-departed node");
                    // Free-list order: the lowest departed index joins first.
                    let min = (0..n).find(|&i| departed[i]).unwrap();
                    assert_eq!(node, min, "join must reclaim the lowest slot");
                    departed[node] = false;
                    stalled[node] = false;
                }
                ChurnEventKind::Leave { .. } => {
                    assert!(!departed[node], "leave of a departed node");
                    departed[node] = true;
                    stalled[node] = false;
                }
                ChurnEventKind::Stall { .. } => {
                    assert!(!departed[node] && !stalled[node]);
                    stalled[node] = true;
                }
                ChurnEventKind::Rejoin { .. } => {
                    assert!(!departed[node] && stalled[node]);
                    stalled[node] = false;
                }
                ChurnEventKind::SetRate { scale, .. } => {
                    assert!(!departed[node]);
                    assert!(
                        scale >= cfg.rate_factor_min && scale <= cfg.rate_factor_max,
                        "scale {scale} outside configured band"
                    );
                }
            }
            let members = departed.iter().filter(|&&d| !d).count();
            let running = (0..n).filter(|&i| !departed[i] && !stalled[i]).count();
            assert!(members >= 1, "membership must never empty");
            assert!(running >= 1, "at least one running node must remain");
        }
        // Wind-down: nobody may end the schedule stalled.
        assert!(
            (0..n).all(|i| !stalled[i]),
            "schedule must rejoin stragglers at wind-down"
        );
    }

    #[test]
    fn initial_active_nodes_join_from_the_departed_pool() {
        let cfg = ChurnConfig {
            arrival_rate: 2.0,
            initial_active: 2,
            max_events: 10,
            ..ChurnConfig::default()
        };
        let events = generate_schedule(&cfg, 7, 5);
        // Only joins are possible, and the departed pool is {2, 3, 4}.
        assert_eq!(events.len(), 3);
        let nodes: Vec<u32> = events.iter().map(|e| e.kind.node()).collect();
        assert_eq!(nodes, vec![2, 3, 4], "joins must fill slots in order");
    }

    #[test]
    fn quiet_config_generates_no_events() {
        let events = generate_schedule(&ChurnConfig::default(), 3, 4);
        assert!(events.is_empty());
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        let n = 4;
        let bad = [
            ChurnConfig {
                arrival_rate: -1.0,
                ..ChurnConfig::default()
            },
            ChurnConfig {
                mean_lifetime: f64::NAN,
                ..ChurnConfig::default()
            },
            ChurnConfig {
                stall_rate: 1.0,
                mean_stall: 0.0,
                ..ChurnConfig::default()
            },
            ChurnConfig {
                rate_factor_min: 0.0,
                ..ChurnConfig::default()
            },
            ChurnConfig {
                rate_factor_min: 2.0,
                rate_factor_max: 1.0,
                ..ChurnConfig::default()
            },
            ChurnConfig {
                initial_active: 9,
                ..ChurnConfig::default()
            },
        ];
        for cfg in &bad {
            assert!(cfg.validate(n).is_err(), "{cfg:?} should be rejected");
        }
        assert!(ChurnConfig::default().validate(n).is_ok());
    }

    #[test]
    fn toml_table_parses_and_rejects_unknown_keys() {
        let mut tbl = BTreeMap::new();
        tbl.insert("arrival_rate".to_string(), Value::Float(0.5));
        tbl.insert("mean_lifetime".to_string(), Value::Int(8));
        tbl.insert("initial_active".to_string(), Value::Int(3));
        let cfg = ChurnConfig::from_toml_table(&tbl).unwrap();
        assert_eq!(cfg.arrival_rate, 0.5);
        assert_eq!(cfg.mean_lifetime, 8.0);
        assert_eq!(cfg.initial_active, 3);

        tbl.insert("lifetime".to_string(), Value::Float(1.0));
        let err = ChurnConfig::from_toml_table(&tbl).unwrap_err();
        assert!(err.contains("unknown key 'lifetime'"), "{err}");
    }

    #[test]
    fn runtime_tracks_cursor_and_liveness() {
        let cfg = ChurnConfig {
            arrival_rate: 1.0,
            initial_active: 1,
            max_events: 2,
            ..ChurnConfig::default()
        };
        let mut rt = ChurnRuntime::new(&cfg, 11, 3);
        assert!(rt.departed[1] && rt.departed[2] && !rt.departed[0]);
        assert!(rt.next_time().is_finite());
        let first = rt.pop().unwrap();
        assert_eq!(first.kind, ChurnEventKind::Join { node: 1 });
        rt.pop().unwrap();
        assert!(rt.pop().is_none());
        assert_eq!(rt.next_time(), f64::INFINITY);
        rt.pending_seq[2] = 9;
        assert!(rt.is_live(2, 9));
        assert!(!rt.is_live(2, 8));
    }
}
