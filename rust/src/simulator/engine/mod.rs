//! Pluggable event engines for the closed-network simulator.
//!
//! Three engines realize the exact same dynamics:
//!
//! * [`EngineKind::Heap`] — the original monolithic [`Network`]: one global
//!   `BinaryHeap` of completion events and one `VecDeque<Task>` per node.
//!   Kept alive as the trace-equivalence **oracle** (the role
//!   `adaptive-exact` plays for the Fenwick sampler).
//! * [`EngineKind::Sharded`] — struct-of-arrays node state (flat queue
//!   lengths, an intrusive task pool instead of n separate `VecDeque`
//!   allocations) with nodes partitioned into S shards, each owning a local
//!   calendar of its completion events.  The central dispatcher merges only
//!   the S shard fronts per CS step, so calendar operations work on heaps
//!   of ~busy/S entries that stay cache-resident at n = 10^5–10^6.
//! * [`EngineKind::Batch`] — R **independent replications of the same
//!   cell** packed into one replication-major SoA arena (one task pool of
//!   capacity R·C, one flat queue-length array of R·n entries), stepped in
//!   an interleaved round loop with service durations drawn in vectorized
//!   blocks (`util::sampler::batch_exponential`).  The sweep scheduler's
//!   amortization engine for small-n × many-seed grids; see
//!   [`batch::run_batch`].
//!
//! # Determinism contract
//!
//! Both engines draw from the **same decomposed RNG streams**, so they are
//! bit-identical on a shared seed — for any shard count and any thread
//! count (`tests/engine_equivalence.rs`):
//!
//! * **Routing** consumes a dedicated sequential stream
//!   (`Rng::new(seed).derive(ROUTE_STREAM)`); routing decisions happen in
//!   CS-step order in every engine, so the stream decomposes identically.
//! * **Service durations** are *keyed*, not sequential: the duration of the
//!   c-th service started at node i is drawn from a fresh generator seeded
//!   with `stream_seed(service_seed(seed), [i, c])`.  A (node, count) pair
//!   fully determines the draw, so shard workers can sample their nodes'
//!   events with no cross-shard coordination and no dependence on shard
//!   membership or scheduling order.
//!
//! Policy observation (`observe`/`observe_node`/`observe_completion`)
//! stays on the central dispatcher in every engine: its call order is
//! part of the contract.  Incremental policies still receive exactly the
//! two queue-length changes per step; bulk policies get the flat SoA
//! `qlen` slice (a memcpy, not a per-node `VecDeque::len` walk); the
//! delay-feedback hook `observe_completion` fires once per CS step, right
//! after the completion and before the routing draw it may influence —
//! it consumes no RNG, so it cannot perturb the stream decomposition.

pub mod batch;
pub mod calendar;
pub mod churn;
pub mod sharded;
pub mod soa;

use super::network::{InitPlacement, Network, SimConfig, SimResult, StepOutcome};
use super::service::ServiceDist;
use crate::coordinator::policy::{SamplingPolicy, StaticPolicy};
use crate::util::rng::{stream_seed, Rng};
use crate::util::stats::Welford;
use crate::util::trace::TraceWriter;

/// Tag of the routing stream (the historical `Network` derivation, kept so
/// initial Routed placements reproduce the pre-engine RNG draws).
pub(crate) const ROUTE_STREAM: u64 = 0x51_3A_77;
/// Tag folding the config seed into the keyed service-duration stream.
const SERVICE_STREAM: u64 = 0x5EED_CA1E;

/// Root of the keyed service-duration stream for a config seed.
#[inline]
pub(crate) fn service_seed(seed: u64) -> u64 {
    stream_seed(seed, &[SERVICE_STREAM])
}

/// Duration of the `count`-th service started at `node` — a pure function
/// of (service stream root, node, count), independent of which engine,
/// shard, or thread asks.
#[inline]
pub(crate) fn service_duration(svc_seed: u64, dist: &ServiceDist, node: u32, count: u64) -> f64 {
    let mut rng = Rng::new(stream_seed(svc_seed, &[node as u64, count]));
    dist.sample(&mut rng)
}

/// Typed engine-layer failures — conditions a mis-sized or churning
/// scenario can legitimately hit, which therefore must surface as errors
/// through the sweep's early-abort path instead of aborting the process.
/// (The hot-path `TaskPool::push` keeps its panic: once construction
/// succeeds, the closed-network population invariant makes overflow a
/// logic bug, not an input error.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The task pool (capacity = `pool_capacity`, default C) ran out of
    /// slots while placing task `node`'s workload.
    PoolExhausted { node: usize, capacity: usize },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            EngineError::PoolExhausted { node, capacity } => write!(
                f,
                "task pool exhausted at node {node}: population exceeds capacity {capacity}"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

/// Which event engine executes a replication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// single global event heap + per-node `VecDeque`s (the oracle)
    Heap,
    /// SoA node state + per-shard calendars (+ optional worker threads)
    Sharded,
    /// replication-batched SoA arena with vectorized service sampling; a
    /// single `SimConfig` runs as a width-1 batch, the sweep scheduler
    /// packs R seeds of a cell through [`batch::run_batch`]
    Batch,
}

impl std::str::FromStr for EngineKind {
    type Err = String;

    fn from_str(s: &str) -> Result<EngineKind, String> {
        match s {
            "heap" => Ok(EngineKind::Heap),
            "sharded" => Ok(EngineKind::Sharded),
            "batch" => Ok(EngineKind::Batch),
            other => Err(format!("unknown engine '{other}' (heap|sharded|batch)")),
        }
    }
}

/// Engine selection carried by [`SimConfig`].  Changing it never changes
/// results — only where the per-step work happens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    pub kind: EngineKind,
    /// shard count for the sharded engine; 0 = auto (8 at n >= 10_000,
    /// else 1)
    pub shards: usize,
    /// worker threads for shard event generation; <= 1 = sequential (the
    /// dispatcher applies shard operations inline)
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { kind: EngineKind::Heap, shards: 0, threads: 1 }
    }
}

impl EngineConfig {
    pub fn heap() -> EngineConfig {
        EngineConfig::default()
    }

    pub fn sharded(shards: usize, threads: usize) -> EngineConfig {
        EngineConfig { kind: EngineKind::Sharded, shards, threads }
    }

    /// The batch arena.  Width is not carried here: a `SimConfig` describes
    /// ONE replication, so a standalone run is a width-1 batch; the sweep
    /// scheduler chooses R per cell (`[sweep] batch_width`) and calls
    /// [`batch::run_batch`] directly.
    pub fn batch() -> EngineConfig {
        EngineConfig { kind: EngineKind::Batch, shards: 0, threads: 1 }
    }

    /// Concrete shard count for a network of n nodes.
    pub fn resolve_shards(&self, n: usize) -> usize {
        let s = if self.shards == 0 {
            if n >= 10_000 {
                8
            } else {
                1
            }
        } else {
            self.shards
        };
        s.clamp(1, n.max(1))
    }
}

/// The engine interface the aggregation layers (`run_with_policy`,
/// `transient_mi`, the DL driver) consume.  One CS step per `advance`.
pub trait EventEngine {
    /// Advance one CS step: pop the next completion, route a replacement.
    fn advance(&mut self) -> Option<StepOutcome>;

    /// Current queue length of node i.
    fn queue_len(&self, i: usize) -> usize;

    /// Number of busy nodes right now (for τ_c).
    fn busy_nodes(&self) -> usize;

    /// Current virtual time.
    fn now(&self) -> f64;

    /// Total tasks in the network (must equal C always).
    fn population(&self) -> usize;

    /// Name of the routing policy in force.
    fn policy_name(&self) -> String;

    /// Queue-length deltas `(time, node, new_len)` applied *outside* the
    /// CS-step path by churn events during the latest `advance` (a leave
    /// drains and re-routes its queue), in application order. Aggregators
    /// flush these before folding the step so time-averaged occupancy
    /// stays exact under churn. Engines without churn return nothing.
    fn churn_deltas(&self) -> &[(f64, u32, u32)] {
        &[]
    }
}

/// Initial placement S_0 as (node, selection probability) pairs — shared
/// verbatim by every engine so the routing stream decomposes identically.
pub(crate) fn initial_placements(
    cfg: &SimConfig,
    policy: &mut dyn SamplingPolicy,
    rng: &mut Rng,
) -> Vec<(usize, f64)> {
    let n = cfg.p.len();
    // Under churn with a partial initial membership, placements go only to
    // the initially-active prefix [0, k); the caller has already masked
    // the policy via observe_leave, so Routed draws respect it too.
    let k = cfg
        .churn
        .as_ref()
        .map_or(n, |c| c.initial_active_count(n));
    match cfg.init {
        InitPlacement::OnePerNode => (0..n).map(|i| (i, policy.prob_of(i))).collect(),
        InitPlacement::RoundRobin => (0..cfg.concurrency)
            .map(|j| (j % k, policy.prob_of(j % k)))
            .collect(),
        InitPlacement::Routed => {
            let mut lens = vec![0u32; n];
            let incremental = policy.incremental();
            (0..cfg.concurrency)
                .map(|_| {
                    if !incremental {
                        policy.observe(&lens);
                    }
                    let node = policy.route(rng);
                    let prob = policy.prob_of(node);
                    lens[node] += 1;
                    if incremental {
                        policy.observe_node(node, lens[node]);
                    }
                    (node, prob)
                })
                .collect()
        }
    }
}

/// Build the engine selected by `cfg.engine` and hand it to `f`.
///
/// The parallel sharded engine owns a scoped worker pool, so it cannot
/// escape this function — every consumer (full runs, transient estimation)
/// threads its loop through here instead of holding an engine value.
pub fn with_engine<R>(
    cfg: SimConfig,
    policy: Box<dyn SamplingPolicy>,
    f: impl FnOnce(&mut dyn EventEngine) -> Result<R, String>,
) -> Result<R, String> {
    let eng = cfg.engine;
    match eng.kind {
        EngineKind::Heap => {
            let mut net = Network::with_policy(cfg, policy)?;
            f(&mut net)
        }
        EngineKind::Sharded => {
            let shards = eng.resolve_shards(cfg.p.len());
            let threads = eng.threads.max(1).min(shards);
            if threads <= 1 {
                let mut engine = sharded::ShardedEngine::sequential(cfg, policy, shards)?;
                f(&mut engine)
            } else {
                run_threaded(cfg, policy, shards, threads, f)
            }
        }
        EngineKind::Batch => {
            let mut engine = batch::SingleBatch::new(cfg, policy)?;
            f(&mut engine)
        }
    }
}

/// Threaded sharded dispatch (threads > 1).
#[cfg(not(loom))]
fn run_threaded<R>(
    cfg: SimConfig,
    policy: Box<dyn SamplingPolicy>,
    shards: usize,
    threads: usize,
    f: impl FnOnce(&mut dyn EventEngine) -> Result<R, String>,
) -> Result<R, String> {
    sharded::run_parallel(cfg, policy, shards, threads, f)
}

/// Under loom the worker pool is compiled out (loom models the mailbox
/// protocol directly in `sharded::loom_model`); fall back to the
/// bit-identical sequential sharded engine.
#[cfg(loom)]
fn run_threaded<R>(
    cfg: SimConfig,
    policy: Box<dyn SamplingPolicy>,
    shards: usize,
    _threads: usize,
    f: impl FnOnce(&mut dyn EventEngine) -> Result<R, String>,
) -> Result<R, String> {
    let mut engine = sharded::ShardedEngine::sequential(cfg, policy, shards)?;
    f(&mut engine)
}

/// Run a full simulation per the config (fixed-p static routing).
pub fn run(cfg: SimConfig) -> Result<SimResult, String> {
    let policy = Box::new(StaticPolicy::new(cfg.p.clone())?);
    run_with_policy(cfg, policy)
}

/// Run a full simulation under an arbitrary sampling policy — the sweep
/// engine's replication kernel, on whichever engine `cfg.engine` selects.
///
/// Per-step cost is O(log busy) calendar work (global heap or shard-local
/// calendars) plus the policy's per-dispatch cost — O(1) for alias-backed
/// static policies, O(log n) for the Fenwick adaptive policy.  Occupancy
/// time-averages are accumulated lazily per node, so replications with
/// n = 10^5–10^6 nodes never pay an O(n) scan per CS step.
pub fn run_with_policy(
    cfg: SimConfig,
    policy: Box<dyn SamplingPolicy>,
) -> Result<SimResult, String> {
    let n = cfg.p.len();
    let steps = cfg.steps;
    let record_tasks = cfg.record_tasks;
    let sample_every = cfg.queue_sample_every;
    let concurrency = cfg.concurrency;
    // disk-spilled trace: open before the engine runs so a bad path fails
    // fast, stream one record per CS step, patch the count on success
    let trace = match &cfg.trace_path {
        Some(p) => Some(TraceWriter::create(p)?),
        None => None,
    };
    with_engine(cfg, policy, move |net| {
        collect(net, n, steps, record_tasks, sample_every, concurrency, trace)
    })
}

/// Per-replication statistics accumulator — the engine-agnostic half of
/// the aggregation loop.  Floating-point accumulation order is fixed here,
/// so engines producing identical `StepOutcome` streams produce
/// bit-identical `SimResult`s; the batch arena drives one aggregator per
/// replication through the exact code path [`collect`] uses, which is what
/// keeps batched replications comparable to the heap oracle bit for bit.
pub(crate) struct StepAggregator {
    res: SimResult,
    busy_sum: u64,
    // lazy time-weighted queue integrals: each node's occupancy is
    // piecewise constant, so ∫X_i dt only needs flushing when X_i changes
    // (the completed node and the dispatch target) and once at the end
    area: Vec<f64>,
    last_change: Vec<f64>,
    q_len: Vec<u32>,
    steps: u64,
    record_tasks: bool,
    sample_every: u64,
    k: u64,
}

impl StepAggregator {
    pub fn new(
        n: usize,
        steps: u64,
        record_tasks: bool,
        sample_every: u64,
        mut init_qlen: impl FnMut(usize) -> u32,
    ) -> StepAggregator {
        let mut agg = StepAggregator {
            res: SimResult {
                delay_steps: vec![Welford::new(); n],
                delay_time: vec![Welford::new(); n],
                completions: vec![0; n],
                dispatches: vec![0; n],
                tau_max: 0,
                tau_c: 0.0,
                tau_sum: vec![0.0; n],
                total_time: 0.0,
                tasks: Vec::new(),
                queue_samples: Vec::new(),
                mean_queue: vec![0.0; n],
            },
            busy_sum: 0,
            area: vec![0.0; n],
            last_change: vec![0.0; n],
            q_len: (0..n).map(&mut init_qlen).collect(),
            steps,
            record_tasks,
            sample_every,
            k: 0,
        };
        // the k = 0 sample is the PRE-step initial state S_0.  Sampling
        // only inside push_step used to label the first POST-step state
        // k = 0, so occupancy plots silently missed t = 0.
        if agg.sample_every > 0 {
            agg.res.queue_samples.push((0, agg.q_len.clone()));
        }
        agg
    }

    #[inline]
    fn flush(&mut self, i: usize, t: f64, new_len: u32) {
        self.area[i] += self.q_len[i] as f64 * (t - self.last_change[i]);
        self.last_change[i] = t;
        self.q_len[i] = new_len;
    }

    /// Fold queue-length changes applied outside the CS-step path (churn
    /// leave drains), in the engines' shared application order — called
    /// before `push_step` so the lazy integrals close each piecewise-
    /// constant segment at the moment it actually ended.
    pub fn apply_churn_deltas(&mut self, deltas: &[(f64, u32, u32)]) {
        for &(t, node, new_len) in deltas {
            self.flush(node as usize, t, new_len);
        }
    }

    /// Fold one CS step: `qlen_completed`/`qlen_next` are the POST-step
    /// queue lengths of the completed node and the dispatch target, `busy`
    /// the post-step busy-node count.
    ///
    /// Self-routes (completed node == dispatch target) flush the same
    /// node twice at the same timestamp: the first flush sets
    /// `last_change[i] = t`, so the second accumulates `q·(t−t) = 0` area
    /// and merely refreshes the stored length — the time integrals stay
    /// exact (regression-tested in `simulator::network`).
    pub fn push_step(
        &mut self,
        out: &StepOutcome,
        qlen_completed: u32,
        qlen_next: u32,
        busy: usize,
    ) {
        let i = out.completed_node as usize;
        let j = out.next_node as usize;
        self.flush(i, out.time, qlen_completed);
        self.flush(j, out.time, qlen_next);
        let d = out.record.delay_steps();
        self.res.delay_steps[i].push(d as f64);
        self.res.delay_time[i].push(out.record.complete_time - out.record.dispatch_time);
        self.res.completions[i] += 1;
        self.res.dispatches[j] += 1;
        self.res.tau_sum[i] += d as f64;
        self.res.tau_max = self.res.tau_max.max(d);
        self.busy_sum += busy as u64;
        if self.record_tasks {
            self.res.tasks.push(out.record);
        }
        self.k += 1;
        // sample k is the state after k CS steps (k = 0, the initial
        // state, was emitted by the constructor)
        if self.sample_every > 0 && self.k % self.sample_every == 0 {
            self.res.queue_samples.push((self.k, self.q_len.clone()));
        }
    }

    /// Close the integrals at final virtual time `now` and emit the result.
    pub fn finish(mut self, now: f64) -> SimResult {
        self.res.tau_c = self.busy_sum as f64 / self.steps.max(1) as f64;
        self.res.total_time = now;
        let denom = now.max(f64::MIN_POSITIVE);
        for i in 0..self.res.mean_queue.len() {
            self.area[i] += self.q_len[i] as f64 * (now - self.last_change[i]);
            self.res.mean_queue[i] = self.area[i] / denom;
        }
        self.res
    }
}

/// The engine-agnostic aggregation loop: drive `net` for `steps` CS steps
/// through a [`StepAggregator`].
fn collect(
    net: &mut dyn EventEngine,
    n: usize,
    steps: u64,
    record_tasks: bool,
    sample_every: u64,
    concurrency: usize,
    mut trace: Option<TraceWriter>,
) -> Result<SimResult, String> {
    let mut agg =
        StepAggregator::new(n, steps, record_tasks, sample_every, |i| net.queue_len(i) as u32);
    for _ in 0..steps {
        let out = net.advance().ok_or("network drained")?;
        agg.apply_churn_deltas(net.churn_deltas());
        let i = out.completed_node as usize;
        let j = out.next_node as usize;
        agg.push_step(
            &out,
            net.queue_len(i) as u32,
            net.queue_len(j) as u32,
            net.busy_nodes(),
        );
        if let Some(w) = trace.as_mut() {
            w.push(&out.record)?;
        }
    }
    if let Some(w) = trace {
        w.finish()?;
    }
    debug_assert_eq!(net.population(), concurrency);
    Ok(agg.finish(net.now()))
}

/// Transient estimation of m_{i,k}^T (Fig 1): average, over `reps`
/// replications, of the delay of the task dispatched at step k *to node i*
/// (conditional on that routing; unconditional steps are skipped).
/// Returns (k, mean delay, count) for k in 0..steps.
pub fn transient_mi(
    base: &SimConfig,
    node: usize,
    reps: u64,
) -> Result<Vec<(u64, f64, u64)>, String> {
    let steps = base.steps;
    let mut sum = vec![0.0f64; steps as usize];
    let mut cnt = vec![0u64; steps as usize];
    for rep in 0..reps {
        let mut cfg = base.clone();
        cfg.seed = base.seed.wrapping_add(rep.wrapping_mul(0x9E3779B9));
        cfg.record_tasks = false;
        let policy = Box::new(StaticPolicy::new(cfg.p.clone())?);
        // tasks dispatched at step k: completion records carry dispatch_step
        with_engine(cfg, policy, |net| {
            for _ in 0..steps {
                let out = net.advance().ok_or("drained")?;
                if out.completed_node as usize == node {
                    let ds = out.record.dispatch_step;
                    if ds < steps {
                        // lint-allow(R5): figures-only per-step mean over one
                        // replication; never enters the cross-engine digest
                        sum[ds as usize] += out.record.delay_steps() as f64;
                        cnt[ds as usize] += 1;
                    }
                }
            }
            Ok(())
        })?;
    }
    Ok((0..steps)
        .map(|k| {
            let c = cnt[k as usize];
            (k, if c > 0 { sum[k as usize] / c as f64 } else { f64::NAN }, c)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parses() {
        assert_eq!("heap".parse::<EngineKind>().unwrap(), EngineKind::Heap);
        assert_eq!("sharded".parse::<EngineKind>().unwrap(), EngineKind::Sharded);
        assert_eq!("batch".parse::<EngineKind>().unwrap(), EngineKind::Batch);
        assert!("quantum".parse::<EngineKind>().is_err());
    }

    #[test]
    fn shard_count_resolution() {
        let auto = EngineConfig::sharded(0, 1);
        assert_eq!(auto.resolve_shards(100), 1, "small n stays single-shard");
        assert_eq!(auto.resolve_shards(10_000), 8);
        assert_eq!(auto.resolve_shards(1_000_000), 8);
        let fixed = EngineConfig::sharded(16, 1);
        assert_eq!(fixed.resolve_shards(1_000_000), 16);
        assert_eq!(fixed.resolve_shards(3), 3, "never more shards than nodes");
    }

    #[test]
    fn service_durations_are_keyed_not_sequential() {
        let root = service_seed(42);
        let d = ServiceDist::Exp { rate: 2.0 };
        let a = service_duration(root, &d, 7, 3);
        // same key -> same draw, independent of anything sampled in between
        let _ = service_duration(root, &d, 1, 0);
        let _ = service_duration(root, &d, 7, 4);
        assert_eq!(a.to_bits(), service_duration(root, &d, 7, 3).to_bits());
        // neighboring keys decorrelate
        assert_ne!(a.to_bits(), service_duration(root, &d, 7, 4).to_bits());
        assert_ne!(a.to_bits(), service_duration(root, &d, 8, 3).to_bits());
        assert_ne!(
            a.to_bits(),
            service_duration(service_seed(43), &d, 7, 3).to_bits()
        );
    }
}
