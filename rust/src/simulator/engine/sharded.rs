//! The sharded event engine: SoA node state + per-shard calendars +
//! optional deterministic worker threads.
//!
//! Nodes are partitioned round-robin across S shards (`shard = node % S`,
//! balancing the fast/slow clusters, which are laid out contiguously).
//! Each shard owns the calendar of its nodes' completion events plus the
//! per-node service counters that key the duration stream.  The central
//! dispatcher runs the CS-step loop:
//!
//! 1. merge the S shard fronts → the next completion (min time, then seq),
//! 2. apply the pool/queue bookkeeping and consult the sampling policy
//!    (observation order and the routing stream are central, sequential —
//!    they are part of the determinism contract),
//! 3. emit at most three shard commands (`PopFront`, up to two
//!    `Schedule`s) tagged with pre-assigned global sequence numbers.
//!
//! A [`ShardDriver`] decides *where* commands execute: [`LocalDriver`]
//! applies them inline (sequential mode); the threaded driver hands them
//! to persistent workers and barriers on completion at each dispatch
//! epoch.  Because durations are keyed by (node, service count) and
//! sequence numbers are assigned centrally, the resulting event trace is
//! bit-identical for every shard count and thread count — and to the heap
//! engine (`tests/engine_equivalence.rs`).
//!
//! Parallelism economics: the per-epoch barrier costs a few hundred ns, so
//! threads pay off only when shard work per epoch is substantial — the C
//! initial placements (one batched epoch), and large-C regimes where
//! calendar pushes dominate.  For small replications prefer `threads = 1`
//! and spend cores on seed-level parallelism (the sweep scheduler does
//! exactly this split).

use super::calendar::{Event, Front, ShardCalendar, EMPTY_FRONT, INF_BITS};
use super::churn::{ChurnEvent, ChurnEventKind, ChurnRuntime};
use super::soa::TaskPool;
use super::{initial_placements, service_duration, service_seed, EventEngine, ROUTE_STREAM};
use crate::coordinator::policy::SamplingPolicy;
use crate::simulator::network::{SimConfig, StepOutcome, TaskRecord};
use crate::simulator::service::ServiceDist;
use crate::util::rng::Rng;
// Atomics/mutexes come through the loom seam: std in normal builds,
// loom's model-checked doubles under `--cfg loom` (see util/sync.rs and
// the `loom_model` test module below).
use crate::util::sync::{AtomicBool, AtomicU64, Mutex, Ordering};

/// A shard-local operation, tagged with everything it needs so workers
/// never read central state.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Cmd {
    /// remove the shard's front event (the dispatcher consumed it)
    PopFront,
    /// start a service at `node` at virtual time `time`; the event carries
    /// the centrally assigned sequence number `seq` and the node's current
    /// churn rate scale (1.0 when churn is off — `dur * 1.0` is IEEE-exact)
    Schedule { node: u32, time: f64, seq: u64, scale: f64 },
}

/// One shard: calendar + keyed-duration state for its nodes.
pub(crate) struct Shard {
    /// total shard count (node -> local index is `node / stride`)
    stride: u32,
    svc_seed: u64,
    calendar: ShardCalendar,
    /// services started per owned node, by local index
    svc_count: Vec<u64>,
    /// owned nodes' service distributions, by local index
    service: Vec<ServiceDist>,
}

impl Shard {
    fn new(id: u32, stride: u32, svc_seed: u64, service_all: &[ServiceDist], cal_cap: usize) -> Shard {
        let service: Vec<ServiceDist> = service_all
            .iter()
            .skip(id as usize)
            .step_by(stride as usize)
            .copied()
            .collect();
        Shard {
            stride,
            svc_seed,
            // pre-sized to the shard's steady-state occupancy so hot-loop
            // pushes never regrow the heap (tests/hot_path_alloc.rs)
            calendar: ShardCalendar::with_capacity(cal_cap),
            svc_count: vec![0; service.len()],
            service,
        }
    }

    #[inline]
    fn apply(&mut self, cmd: Cmd) {
        match cmd {
            Cmd::PopFront => {
                self.calendar.pop();
            }
            Cmd::Schedule { node, time, seq, scale } => {
                let li = (node / self.stride) as usize;
                let count = self.svc_count[li];
                self.svc_count[li] = count + 1;
                let dur = service_duration(self.svc_seed, &self.service[li], node, count);
                self.calendar.push(Event { time: time + dur * scale, seq, node });
            }
        }
    }

    #[inline]
    fn front(&self) -> Front {
        self.calendar.front()
    }
}

/// Steady-state bound on one shard's calendar occupancy: at most one
/// in-flight completion per owned node (round-robin ownership → at most
/// ceil(n/S) owned nodes), never more than the whole population is busy.
fn shard_calendar_capacity(cfg: &SimConfig, n_shards: usize) -> usize {
    let n = cfg.p.len();
    n.div_ceil(n_shards).min(cfg.effective_pool_capacity()).min(n) + 1
}

/// Where shard commands execute.  `exec` applies a batch (each command
/// tagged with its shard id) and guarantees the affected shards' fronts
/// are observable through `front` afterwards.
pub(crate) trait ShardDriver {
    fn exec(&mut self, cmds: &[(u32, Cmd)]);
    fn front(&self, shard: u32) -> Front;
}

/// Sequential driver: the dispatcher applies shard operations inline.
pub(crate) struct LocalDriver {
    shards: Vec<Shard>,
}

impl ShardDriver for LocalDriver {
    fn exec(&mut self, cmds: &[(u32, Cmd)]) {
        for &(s, cmd) in cmds {
            self.shards[s as usize].apply(cmd);
        }
    }

    fn front(&self, shard: u32) -> Front {
        self.shards[shard as usize].front()
    }
}

// ---------------------------------------------------------------------------
// Central dispatcher
// ---------------------------------------------------------------------------

/// The sharded engine: central SoA state + a [`ShardDriver`].  The config
/// is consumed at build time (placements, pool sizing, shard service
/// tables); only live dispatch state is retained.
pub(crate) struct ShardedCore<D: ShardDriver> {
    policy: Box<dyn SamplingPolicy>,
    route_rng: Rng,
    pool: TaskPool,
    busy: usize,
    n_shards: u32,
    driver: D,
    seq: u64,
    now: f64,
    step: u64,
    /// reusable queue-length scratch for bulk policy observation
    lens_buf: Vec<u32>,
    /// reusable per-step command batch (≤ 3 entries after init)
    cmd_buf: Vec<(u32, Cmd)>,
    /// open-network lifecycle state (None = closed network)
    churn: Option<ChurnRuntime>,
}

/// The sequential sharded engine.
pub(crate) type ShardedEngine = ShardedCore<LocalDriver>;

impl ShardedCore<LocalDriver> {
    pub fn sequential(
        cfg: SimConfig,
        policy: Box<dyn SamplingPolicy>,
        n_shards: usize,
    ) -> Result<ShardedEngine, String> {
        let svc_seed = service_seed(cfg.seed);
        let cal_cap = shard_calendar_capacity(&cfg, n_shards);
        let shards = (0..n_shards)
            .map(|s| Shard::new(s as u32, n_shards as u32, svc_seed, &cfg.service, cal_cap))
            .collect();
        ShardedCore::build(cfg, policy, n_shards, LocalDriver { shards })
    }
}

impl<D: ShardDriver> ShardedCore<D> {
    fn build(
        cfg: SimConfig,
        mut policy: Box<dyn SamplingPolicy>,
        n_shards: usize,
        driver: D,
    ) -> Result<ShardedCore<D>, String> {
        cfg.validate()?;
        let n = cfg.p.len();
        if policy.n() != n {
            return Err(format!(
                "policy '{}' covers {} nodes but the network has {n}",
                policy.name(),
                policy.n()
            ));
        }
        let mut route_rng = Rng::new(cfg.seed).derive(ROUTE_STREAM);
        let churn = cfg.churn.as_ref().map(|c| ChurnRuntime::new(c, cfg.seed, n));
        // initially-departed nodes are masked out of the policy BEFORE the
        // initial placements are drawn — identical call sequence to the
        // heap oracle (part of the bit-identity contract)
        if let Some(rt) = &churn {
            #[cfg(debug_assertions)]
            let route_fp = route_rng.state_fingerprint();
            for i in 0..n {
                if rt.departed[i] {
                    policy.observe_leave(i);
                }
            }
            #[cfg(debug_assertions)]
            debug_assert_eq!(
                route_fp,
                route_rng.state_fingerprint(),
                "observe_leave moved the routing stream (policy '{}')",
                policy.name()
            );
        }
        let placements = initial_placements(&cfg, policy.as_mut(), &mut route_rng);
        let mut core = ShardedCore {
            pool: TaskPool::new(n, cfg.effective_pool_capacity()),
            busy: 0,
            n_shards: n_shards as u32,
            driver,
            seq: 0,
            now: 0.0,
            step: 0,
            lens_buf: Vec::with_capacity(n),
            cmd_buf: Vec::with_capacity(cfg.concurrency),
            policy,
            route_rng,
            churn,
        };
        // initial placement: pool pushes are central; the C initial service
        // starts go to the shards as ONE batched epoch (the only epoch with
        // more than three commands — workers absorb it in parallel).  The
        // fallible push surfaces a mis-sized pool as a typed error.
        for (node, prob) in placements {
            let len = core.pool.try_push(node, 0, 0.0, prob).map_err(|e| e.to_string())?;
            if len == 1 {
                core.busy += 1;
                core.seq += 1;
                core.set_pending(node as u32, core.seq);
                let scale = core.rate_scale(node as u32);
                core.cmd_buf.push((
                    node as u32 % core.n_shards,
                    Cmd::Schedule { node: node as u32, time: 0.0, seq: core.seq, scale },
                ));
            }
        }
        let init = std::mem::take(&mut core.cmd_buf);
        core.driver.exec(&init);
        core.cmd_buf = init;
        core.cmd_buf.clear();
        // incremental policies only ever hear about queues that change, so
        // sync them once with the realized initial state S_0 (idempotent
        // for the Routed path, which already observed each placement)
        if core.policy.incremental() {
            for i in 0..n {
                core.policy.observe_node(i, core.pool.qlen(i));
            }
        }
        Ok(core)
    }

    /// Merge the shard fronts: the globally earliest event.
    #[inline]
    fn merge_front(&self) -> Option<Front> {
        let mut best = EMPTY_FRONT;
        for s in 0..self.n_shards {
            let fr = self.driver.front(s);
            if (fr.0, fr.1) < (best.0, best.1) {
                best = fr;
            }
        }
        if best.1 == u64::MAX {
            None
        } else {
            Some(best)
        }
    }

    #[inline]
    fn rate_scale(&self, node: u32) -> f64 {
        self.churn.as_ref().map_or(1.0, |c| c.rate_scale[node as usize])
    }

    #[inline]
    fn set_pending(&mut self, node: u32, seq: u64) {
        if let Some(rt) = &mut self.churn {
            rt.pending_seq[node as usize] = seq;
        }
    }

    /// Merge to the next *valid* completion, applying every lifecycle
    /// event that precedes it (churn-first at timestamp ties, schedule
    /// order at equal times).  Shared prelude contract of all engines.
    fn next_completion(&mut self) -> Option<Front> {
        if self.churn.is_none() {
            return self.merge_front();
        }
        self.churn.as_mut().unwrap().log.clear();
        loop {
            // lazy cancellation: pop calendar fronts whose seq a stall /
            // leave invalidated (the pop command re-exposes the shard's
            // next event, so the merge loop converges)
            loop {
                let front = self.merge_front();
                let stale = match front {
                    Some((_, seq, node)) => !self.churn.as_ref().unwrap().is_live(node, seq),
                    None => false,
                };
                if !stale {
                    break;
                }
                let (_, _, node) = front.unwrap();
                self.cmd_buf.clear();
                self.cmd_buf.push((node % self.n_shards, Cmd::PopFront));
                self.driver.exec(&self.cmd_buf);
            }
            let tcomp = self.merge_front().map_or(f64::INFINITY, |f| f.0);
            let tchurn = self.churn.as_ref().unwrap().next_time();
            if tchurn <= tcomp && tchurn.is_finite() {
                let ev = self.churn.as_mut().unwrap().pop().unwrap();
                self.now = tchurn;
                self.apply_churn(ev);
                continue;
            }
            let front = self.merge_front()?;
            self.churn.as_mut().unwrap().pending_seq[front.2 as usize] = 0;
            return Some(front);
        }
    }

    /// Apply one lifecycle event at its timestamp (same semantics and
    /// policy call order as the heap oracle's `apply_churn`).
    fn apply_churn(&mut self, ev: ChurnEvent) {
        let t = ev.time;
        self.cmd_buf.clear();
        match ev.kind {
            ChurnEventKind::Join { node } => {
                let rt = self.churn.as_mut().unwrap();
                rt.departed[node as usize] = false;
                rt.stalled[node as usize] = false;
                rt.rate_scale[node as usize] = 1.0;
                // shard svc_count is NOT reset: duration keys stay unique
                #[cfg(debug_assertions)]
                let route_fp = self.route_rng.state_fingerprint();
                self.policy.observe_join(node as usize);
                #[cfg(debug_assertions)]
                debug_assert_eq!(
                    route_fp,
                    self.route_rng.state_fingerprint(),
                    "observe_join moved the routing stream (policy '{}')",
                    self.policy.name()
                );
            }
            ChurnEventKind::Leave { node } => self.apply_leave(node, t),
            ChurnEventKind::Stall { node } => {
                let rt = self.churn.as_mut().unwrap();
                rt.stalled[node as usize] = true;
                // cancel the in-flight completion; the queue freezes
                rt.pending_seq[node as usize] = 0;
                if self.pool.qlen(node as usize) > 0 {
                    self.busy -= 1;
                }
            }
            ChurnEventKind::Rejoin { node } => {
                self.churn.as_mut().unwrap().stalled[node as usize] = false;
                if self.pool.qlen(node as usize) > 0 {
                    self.busy += 1;
                    self.seq += 1;
                    self.set_pending(node, self.seq);
                    let scale = self.rate_scale(node);
                    self.cmd_buf.push((
                        node % self.n_shards,
                        Cmd::Schedule { node, time: t, seq: self.seq, scale },
                    ));
                }
            }
            ChurnEventKind::SetRate { node, scale } => {
                self.churn.as_mut().unwrap().rate_scale[node as usize] = scale;
            }
        }
        if !self.cmd_buf.is_empty() {
            self.driver.exec(&self.cmd_buf);
        }
    }

    /// A member departs: mask it from the policy, then re-route its queued
    /// tasks one at a time, each keeping its original dispatch identity.
    fn apply_leave(&mut self, node: u32, t: f64) {
        let ni = node as usize;
        {
            let rt = self.churn.as_mut().unwrap();
            rt.pending_seq[ni] = 0;
            if self.pool.qlen(ni) > 0 && !rt.stalled[ni] {
                self.busy -= 1;
            }
            rt.departed[ni] = true;
            rt.stalled[ni] = false;
        }
        #[cfg(debug_assertions)]
        let route_fp = self.route_rng.state_fingerprint();
        self.policy.observe_leave(ni);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            self.route_rng.state_fingerprint(),
            "observe_leave moved the routing stream (policy '{}')",
            self.policy.name()
        );
        let incremental = self.policy.incremental();
        while self.pool.qlen(ni) > 0 {
            let (d_step, d_time, d_prob, _rem) = self.pool.pop(ni);
            if !incremental {
                self.lens_buf.clear();
                self.lens_buf.extend_from_slice(self.pool.qlens());
                self.policy.observe(&self.lens_buf);
            }
            let dest = self.policy.route(&mut self.route_rng) as u32;
            let dlen = self.pool.push(dest as usize, d_step, d_time, d_prob);
            let dest_stalled = self.churn.as_ref().unwrap().stalled[dest as usize];
            if dlen == 1 && !dest_stalled {
                self.busy += 1;
                self.seq += 1;
                self.set_pending(dest, self.seq);
                let scale = self.rate_scale(dest);
                self.cmd_buf.push((
                    dest % self.n_shards,
                    Cmd::Schedule { node: dest, time: t, seq: self.seq, scale },
                ));
            }
            if incremental {
                self.policy.observe_node(dest as usize, dlen);
            }
            self.churn.as_mut().unwrap().log.push((t, dest, dlen));
        }
        self.churn.as_mut().unwrap().log.push((t, node, 0));
    }
}

impl<D: ShardDriver> EventEngine for ShardedCore<D> {
    fn advance(&mut self) -> Option<StepOutcome> {
        let (time, _seq, node32) = self.next_completion()?;
        self.now = time;
        let node = node32 as usize;
        let shard = node32 % self.n_shards;
        self.cmd_buf.clear();
        self.cmd_buf.push((shard, Cmd::PopFront));
        let (d_step, d_time, d_prob, new_len) = self.pool.pop(node);
        if new_len > 0 {
            self.seq += 1;
            self.set_pending(node32, self.seq);
            let scale = self.rate_scale(node32);
            self.cmd_buf
                .push((shard, Cmd::Schedule { node: node32, time, seq: self.seq, scale }));
        } else {
            self.busy -= 1;
        }
        let record = TaskRecord {
            node: node32,
            dispatch_step: d_step,
            complete_step: self.step,
            dispatch_time: d_time,
            complete_time: time,
            dispatch_prob: d_prob,
        };
        // delay-feedback channel — central, RNG-free, same call point as
        // the heap engine (part of the bit-identity contract); the debug
        // fingerprint is the runtime complement of lint rule R1
        #[cfg(debug_assertions)]
        let route_fp = self.route_rng.state_fingerprint();
        self.policy.observe_completion(
            node,
            record.delay_steps(),
            record.complete_time - record.dispatch_time,
        );
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            self.route_rng.state_fingerprint(),
            "observe_completion moved the routing stream (policy '{}')",
            self.policy.name()
        );
        // dispatcher: consult the sampling policy, select K_{k+1}, and send
        // the new model.  Same observation protocol as the heap engine —
        // incremental policies get only the two queue lengths that change.
        let incremental = self.policy.incremental();
        if incremental {
            self.policy.observe_node(node, new_len);
        } else {
            self.lens_buf.clear();
            self.lens_buf.extend_from_slice(self.pool.qlens());
            self.policy.observe(&self.lens_buf);
        }
        let next = self.policy.route(&mut self.route_rng) as u32;
        let next_prob = self.policy.prob_of(next as usize);
        let next_len = self.pool.push(next as usize, self.step + 1, time, next_prob);
        let next_stalled = self.churn.as_ref().is_some_and(|c| c.stalled[next as usize]);
        if next_len == 1 && !next_stalled {
            self.busy += 1;
            self.seq += 1;
            self.set_pending(next, self.seq);
            let scale = self.rate_scale(next);
            self.cmd_buf.push((
                next % self.n_shards,
                Cmd::Schedule { node: next, time, seq: self.seq, scale },
            ));
        }
        if incremental {
            self.policy.observe_node(next as usize, next_len);
        }
        self.driver.exec(&self.cmd_buf);
        let outcome = StepOutcome {
            completed_node: node32,
            dispatch_step: d_step,
            next_node: next,
            time,
            record,
        };
        self.step += 1;
        Some(outcome)
    }

    fn queue_len(&self, i: usize) -> usize {
        self.pool.qlen(i) as usize
    }

    fn busy_nodes(&self) -> usize {
        self.busy
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn population(&self) -> usize {
        self.pool.population()
    }

    fn policy_name(&self) -> String {
        self.policy.name()
    }

    fn churn_deltas(&self) -> &[(f64, u32, u32)] {
        match &self.churn {
            Some(rt) => &rt.log,
            None => &[],
        }
    }
}

// ---------------------------------------------------------------------------
// Deterministic parallel mode
// ---------------------------------------------------------------------------

/// One shard's published front: three atomics written by its worker before
/// the Release store on `done`, read by the dispatcher after the Acquire
/// load — release/acquire on `done` orders them without tearing.
struct FrontCell {
    time_bits: AtomicU64,
    seq: AtomicU64,
    node: AtomicU64,
}

impl FrontCell {
    fn new() -> FrontCell {
        FrontCell {
            time_bits: AtomicU64::new(INF_BITS),
            seq: AtomicU64::new(u64::MAX),
            node: AtomicU64::new(u64::MAX),
        }
    }

    fn publish(&self, fr: Front) {
        self.time_bits.store(fr.0.to_bits(), Ordering::Relaxed);
        self.seq.store(fr.1, Ordering::Relaxed);
        self.node.store(fr.2 as u64, Ordering::Relaxed);
    }

    fn load(&self) -> Front {
        (
            f64::from_bits(self.time_bits.load(Ordering::Relaxed)),
            self.seq.load(Ordering::Relaxed),
            self.node.load(Ordering::Relaxed) as u32,
        )
    }
}

/// Mailbox between the dispatcher and one worker: the dispatcher fills
/// `cmds` under the mutex, then bumps `epoch` (Release); the worker drains,
/// applies, publishes fronts, and acknowledges via `done` (Release).
struct WorkerSlot {
    epoch: AtomicU64,
    done: AtomicU64,
    cmds: Mutex<Vec<(u32, Cmd)>>,
}

struct ParallelShared {
    slots: Vec<WorkerSlot>,
    fronts: Vec<FrontCell>,
    shutdown: AtomicBool,
}

/// Driver that ships commands to persistent shard workers and barriers at
/// each dispatch epoch.  The dispatcher keeps a local front cache so only
/// shards it commanded this epoch are re-read.
///
/// Not compiled under loom: loom models the mailbox protocol directly in
/// `loom_model` below, and provides neither scoped threads nor spin hints.
#[cfg(not(loom))]
pub(crate) struct ThreadedDriver<'a> {
    shared: &'a ParallelShared,
    n_workers: usize,
    fronts: Vec<Front>,
    /// per-worker staging buffers (reused across epochs)
    staged: Vec<Vec<(u32, Cmd)>>,
}

#[cfg(not(loom))]
impl ShardDriver for ThreadedDriver<'_> {
    fn exec(&mut self, cmds: &[(u32, Cmd)]) {
        if cmds.is_empty() {
            return;
        }
        for &(s, cmd) in cmds {
            self.staged[s as usize % self.n_workers].push((s, cmd));
        }
        let mut waits: [(usize, u64); 8] = [(usize::MAX, 0); 8];
        let mut n_waits = 0usize;
        for w in 0..self.n_workers {
            if self.staged[w].is_empty() {
                continue;
            }
            let slot = &self.shared.slots[w];
            {
                let mut q = slot.cmds.lock().unwrap();
                q.append(&mut self.staged[w]);
            }
            let e = slot.epoch.load(Ordering::Relaxed) + 1;
            slot.epoch.store(e, Ordering::Release);
            if n_waits < waits.len() {
                waits[n_waits] = (w, e);
                n_waits += 1;
            } else {
                // > 8 workers involved only in the batched init epoch;
                // wait for the overflow immediately (still one barrier)
                while slot.done.load(Ordering::Acquire) < e {
                    std::hint::spin_loop();
                }
            }
        }
        for &(w, e) in &waits[..n_waits] {
            let slot = &self.shared.slots[w];
            let mut spins = 0u32;
            while slot.done.load(Ordering::Acquire) < e {
                spins += 1;
                if spins > 10_000 {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
        }
        for &(s, _) in cmds {
            self.fronts[s as usize] = self.shared.fronts[s as usize].load();
        }
    }

    fn front(&self, shard: u32) -> Front {
        self.fronts[shard as usize]
    }
}

#[cfg(not(loom))]
fn worker_loop(mut shards: Vec<(u32, Shard)>, w: usize, shared: &ParallelShared) {
    let slot = &shared.slots[w];
    let n_workers = shared.slots.len();
    let mut last = 0u64;
    let mut spins = 0u32;
    // swap buffer for draining the mailbox: the worker and the dispatcher
    // alternate two Vecs, so the per-epoch hot path never allocates
    let mut work: Vec<(u32, Cmd)> = Vec::new();
    loop {
        let e = slot.epoch.load(Ordering::Acquire);
        if e == last {
            if shared.shutdown.load(Ordering::Acquire) {
                break;
            }
            spins += 1;
            if spins > 10_000 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
            continue;
        }
        spins = 0;
        {
            let mut q = slot.cmds.lock().unwrap();
            std::mem::swap(&mut *q, &mut work);
        }
        for &(s, cmd) in &work {
            // worker w owns shards {s : s % n_workers == w}, densely packed
            let (id, shard) = &mut shards[(s as usize) / n_workers];
            debug_assert_eq!(*id, s);
            shard.apply(cmd);
            shared.fronts[s as usize].publish(shard.front());
        }
        work.clear();
        last = e;
        slot.done.store(e, Ordering::Release);
    }
}

/// Run `f` over a sharded engine whose shard operations execute on
/// `threads` persistent workers.  Bit-identical to the sequential engine:
/// the workers only ever apply centrally ordered, keyed operations.
#[cfg(not(loom))]
pub(crate) fn run_parallel<R>(
    cfg: SimConfig,
    policy: Box<dyn SamplingPolicy>,
    n_shards: usize,
    threads: usize,
    f: impl FnOnce(&mut dyn EventEngine) -> Result<R, String>,
) -> Result<R, String> {
    let n_workers = threads.min(n_shards).max(1);
    let svc_seed = service_seed(cfg.seed);
    let cal_cap = shard_calendar_capacity(&cfg, n_shards);
    let mut per_worker: Vec<Vec<(u32, Shard)>> = (0..n_workers)
        .map(|w| {
            (0..n_shards)
                .filter(|s| s % n_workers == w)
                .map(|s| {
                    (
                        s as u32,
                        Shard::new(s as u32, n_shards as u32, svc_seed, &cfg.service, cal_cap),
                    )
                })
                .collect()
        })
        .collect();
    let shared = ParallelShared {
        slots: (0..n_workers)
            .map(|_| WorkerSlot {
                epoch: AtomicU64::new(0),
                done: AtomicU64::new(0),
                cmds: Mutex::new(Vec::new()),
            })
            .collect(),
        fronts: (0..n_shards).map(|_| FrontCell::new()).collect(),
        shutdown: AtomicBool::new(false),
    };
    // workers spin until `shutdown`; raise it on every exit path —
    // including a dispatcher panic — or the scope's implicit join would
    // deadlock on the spinning workers
    struct Shutdown<'a>(&'a AtomicBool);
    impl Drop for Shutdown<'_> {
        fn drop(&mut self) {
            self.0.store(true, Ordering::Release);
        }
    }
    std::thread::scope(|scope| {
        let _guard = Shutdown(&shared.shutdown);
        for (w, shards) in per_worker.drain(..).enumerate() {
            let shared = &shared;
            scope.spawn(move || worker_loop(shards, w, shared));
        }
        let driver = ThreadedDriver {
            shared: &shared,
            n_workers,
            fronts: vec![EMPTY_FRONT; n_shards],
            staged: vec![Vec::new(); n_workers],
        };
        let result = ShardedCore::build(cfg, policy, n_shards, driver)
            .and_then(|mut core| f(&mut core));
        drop(_guard);
        result
    })
}

/// Loom model checks for the two lock-free seams of the parallel driver:
/// the `WorkerSlot` epoch/`done` mailbox handshake and the `FrontCell`
/// publication protocol.  Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_`.
///
/// The real `worker_loop`/`ThreadedDriver` pair cannot run under loom
/// (scoped threads, bounded spin hints), so these tests drive the same
/// shared types through the same ordering discipline: stage under the
/// mutex → `epoch` Release bump → worker Acquire drain → Relaxed front
/// stores → `done` Release ack → dispatcher Acquire read.  Loom explores
/// every interleaving, so a weakened ordering anywhere in the chain fails
/// here instead of as a digest mismatch.
#[cfg(all(loom, test))]
mod loom_model {
    use super::*;
    use loom::sync::Arc;
    use loom::thread;

    fn one_slot_shared() -> ParallelShared {
        ParallelShared {
            slots: vec![WorkerSlot {
                epoch: AtomicU64::new(0),
                done: AtomicU64::new(0),
                cmds: Mutex::new(Vec::new()),
            }],
            fronts: vec![FrontCell::new()],
            shutdown: AtomicBool::new(false),
        }
    }

    /// Two full epochs of the mailbox protocol: every command staged
    /// before the epoch bump is drained exactly once, and the front
    /// published for epoch e is visible after the dispatcher's Acquire
    /// load of `done >= e`.
    #[test]
    fn loom_mailbox_epoch_done_handshake() {
        loom::model(|| {
            let shared = Arc::new(one_slot_shared());
            let worker = {
                let shared = Arc::clone(&shared);
                thread::spawn(move || {
                    let slot = &shared.slots[0];
                    let mut last = 0u64;
                    let mut applied = 0u64;
                    while last < 2 {
                        let e = slot.epoch.load(Ordering::Acquire);
                        if e == last {
                            thread::yield_now();
                            continue;
                        }
                        let drained: Vec<(u32, Cmd)> = {
                            let mut q = slot.cmds.lock().unwrap();
                            std::mem::take(&mut *q)
                        };
                        assert!(
                            !drained.is_empty(),
                            "epoch bump must make the staged batch visible"
                        );
                        for &(s, cmd) in &drained {
                            if let Cmd::Schedule { node, time, seq, .. } = cmd {
                                shared.fronts[s as usize].publish((time, seq, node));
                            }
                            applied += 1;
                        }
                        last = e;
                        slot.done.store(e, Ordering::Release);
                    }
                    applied
                })
            };
            let slot = &shared.slots[0];
            for e in 1..=2u64 {
                {
                    let mut q = slot.cmds.lock().unwrap();
                    q.push((0, Cmd::Schedule { node: 9, time: e as f64, seq: e, scale: 1.0 }));
                }
                slot.epoch.store(e, Ordering::Release);
                while slot.done.load(Ordering::Acquire) < e {
                    thread::yield_now();
                }
                // Acquire on `done` orders the worker's Relaxed front
                // stores: the read must see exactly this epoch's front.
                assert_eq!(shared.fronts[0].load(), (e as f64, e, 9));
            }
            assert_eq!(worker.join().unwrap(), 2);
        });
    }

    /// FrontCell's three Relaxed atomics are a consistent snapshot once
    /// the Release store on `done` has been Acquire-observed.
    #[test]
    fn loom_front_publication_ordered_by_done() {
        loom::model(|| {
            let shared = Arc::new((FrontCell::new(), AtomicU64::new(0)));
            let publisher = {
                let shared = Arc::clone(&shared);
                thread::spawn(move || {
                    shared.0.publish((0.5, 7, 3));
                    shared.1.store(1, Ordering::Release);
                })
            };
            let (cell, done) = &*shared;
            if done.load(Ordering::Acquire) == 1 {
                assert_eq!(cell.load(), (0.5, 7, 3));
            }
            publisher.join().unwrap();
            assert_eq!(cell.load(), (0.5, 7, 3));
        });
    }

    /// An idle worker parked on an unchanged epoch observes `shutdown`
    /// and exits — the wind-down path `run_parallel` relies on for its
    /// panic-safe Drop guard.
    #[test]
    fn loom_shutdown_reaches_idle_worker() {
        loom::model(|| {
            let shared = Arc::new(one_slot_shared());
            let worker = {
                let shared = Arc::clone(&shared);
                thread::spawn(move || {
                    let slot = &shared.slots[0];
                    let last = 0u64;
                    loop {
                        let e = slot.epoch.load(Ordering::Acquire);
                        if e == last {
                            if shared.shutdown.load(Ordering::Acquire) {
                                break;
                            }
                            thread::yield_now();
                            continue;
                        }
                    }
                })
            };
            shared.shutdown.store(true, Ordering::Release);
            worker.join().unwrap();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::StaticPolicy;
    use crate::simulator::service::{ServiceDist, ServiceFamily};

    fn cfg(n: usize, c: usize, seed: u64) -> SimConfig {
        let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 3.0 } else { 1.0 }).collect();
        SimConfig {
            seed,
            ..SimConfig::new(
                vec![1.0 / n as f64; n],
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                c,
                0,
            )
        }
    }

    fn policy(n: usize) -> Box<dyn SamplingPolicy> {
        Box::new(StaticPolicy::new(vec![1.0 / n as f64; n]).unwrap())
    }

    #[test]
    fn population_is_conserved_across_shard_counts() {
        for shards in [1usize, 3, 5] {
            let mut eng = ShardedEngine::sequential(cfg(10, 7, 3), policy(10), shards).unwrap();
            assert_eq!(eng.population(), 7);
            for _ in 0..400 {
                eng.advance().unwrap();
                assert_eq!(eng.population(), 7);
            }
            assert!(eng.busy_nodes() >= 1 && eng.busy_nodes() <= 7);
        }
    }

    #[test]
    fn shard_count_does_not_change_the_trace() {
        let trace = |shards: usize| -> Vec<(u32, u64, u64)> {
            let mut eng =
                ShardedEngine::sequential(cfg(9, 5, 11), policy(9), shards).unwrap();
            (0..600)
                .map(|_| {
                    let o = eng.advance().unwrap();
                    (o.completed_node, o.record.dispatch_step, o.time.to_bits())
                })
                .collect()
        };
        let one = trace(1);
        assert_eq!(one, trace(4));
        assert_eq!(one, trace(9));
    }

    #[test]
    fn parallel_workers_match_sequential() {
        let seq_trace = {
            let mut eng = ShardedEngine::sequential(cfg(12, 8, 5), policy(12), 4).unwrap();
            (0..500)
                .map(|_| {
                    let o = eng.advance().unwrap();
                    (o.completed_node, o.next_node, o.time.to_bits())
                })
                .collect::<Vec<_>>()
        };
        for threads in [2usize, 4] {
            let par_trace = run_parallel(cfg(12, 8, 5), policy(12), 4, threads, |eng| {
                Ok((0..500)
                    .map(|_| {
                        let o = eng.advance().unwrap();
                        (o.completed_node, o.next_node, o.time.to_bits())
                    })
                    .collect::<Vec<_>>())
            })
            .unwrap();
            assert_eq!(seq_trace, par_trace, "threads={threads}");
        }
    }

    #[test]
    fn churn_trace_is_shard_count_invariant() {
        use super::super::churn::ChurnConfig;
        let churn = ChurnConfig {
            arrival_rate: 0.7,
            mean_lifetime: 2.5,
            stall_rate: 0.5,
            mean_stall: 0.4,
            rate_change_rate: 0.6,
            rate_factor_min: 0.5,
            rate_factor_max: 2.0,
            initial_active: 6,
            max_events: 250,
        };
        let trace = |shards: usize| -> Vec<(u32, u32, u64)> {
            let mut c = cfg(9, 5, 11);
            c.churn = Some(churn.clone());
            let mut eng = ShardedEngine::sequential(c, policy(9), shards).unwrap();
            (0..800)
                .map(|_| {
                    let o = eng.advance().unwrap();
                    assert_eq!(eng.population(), 5, "churn must conserve the C tasks");
                    (o.completed_node, o.next_node, o.time.to_bits())
                })
                .collect()
        };
        let one = trace(1);
        assert_eq!(one, trace(4));
        assert_eq!(one, trace(9));
    }

    #[test]
    fn undersized_pool_is_a_typed_error_not_a_panic() {
        let mut c = cfg(6, 5, 3);
        c.pool_capacity = 2;
        let err = ShardedEngine::sequential(c, policy(6), 2).unwrap_err();
        assert!(err.contains("task pool exhausted"), "{err}");
        assert!(err.contains("capacity 2"), "{err}");
    }

    #[test]
    fn build_errors_shut_workers_down() {
        // invalid config: the scoped pool must still wind down cleanly
        let mut bad = cfg(4, 0, 1);
        bad.concurrency = 0;
        let err = run_parallel(bad, policy(4), 2, 2, |_| Ok(())).unwrap_err();
        assert!(err.contains("concurrency"), "{err}");
    }
}
