//! Completion-event calendars.
//!
//! [`Event`] is the (virtual time, global sequence, node) triple both
//! engines order on: min time first, ties broken by the globally unique
//! sequence number the dispatcher assigned at schedule time.  Because the
//! order is total, a `BinaryHeap` pops the same event regardless of
//! insertion order — which is what lets shard workers apply their schedule
//! operations concurrently without perturbing the trace.
//!
//! [`ShardCalendar`] is one shard's local min-heap.  The central server
//! never walks a calendar; it only merges the S shard *fronts* per CS
//! step, so every heap operation runs on ~busy/S entries.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Raw IEEE-754 bits of +inf — the "empty front" time sentinel shared with
/// the parallel driver's atomic front cells.
pub(crate) const INF_BITS: u64 = 0x7FF0_0000_0000_0000;

/// A shard front: (completion time, schedule sequence, node).  An empty
/// calendar reports `(inf, u64::MAX, u32::MAX)`.
pub(crate) type Front = (f64, u64, u32);

pub(crate) const EMPTY_FRONT: Front = (f64::INFINITY, u64::MAX, u32::MAX);

/// Completion event in the virtual-time calendar.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub node: u32,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for min-heap; ties broken by seq for determinism
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One shard's event calendar.
#[derive(Debug, Default)]
pub(crate) struct ShardCalendar {
    heap: BinaryHeap<Event>,
}

impl ShardCalendar {
    pub fn new() -> ShardCalendar {
        ShardCalendar { heap: BinaryHeap::new() }
    }

    /// A calendar pre-sized for its steady-state occupancy, so the hot
    /// loop's push/pop never regrows the heap's backing storage.
    pub fn with_capacity(cap: usize) -> ShardCalendar {
        ShardCalendar { heap: BinaryHeap::with_capacity(cap) }
    }

    #[inline]
    pub fn push(&mut self, ev: Event) {
        self.heap.push(ev);
    }

    /// Remove and return the shard's earliest event.
    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// The shard's earliest event as a [`Front`] triple.
    #[inline]
    pub fn front(&self) -> Front {
        match self.heap.peek() {
            Some(e) => (e.time, e.seq, e.node),
            None => EMPTY_FRONT,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_order_is_time_then_seq_regardless_of_insertion() {
        let evs = [
            Event { time: 2.0, seq: 5, node: 0 },
            Event { time: 1.0, seq: 9, node: 1 },
            Event { time: 1.0, seq: 3, node: 2 },
            Event { time: 0.5, seq: 7, node: 3 },
        ];
        // every insertion order yields the same pop order (total order)
        let orders: [[usize; 4]; 3] = [[0, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]];
        for ord in orders {
            let mut cal = ShardCalendar::new();
            for &i in &ord {
                cal.push(evs[i]);
            }
            let popped: Vec<u32> = (0..4).map(|_| cal.pop().unwrap().node).collect();
            assert_eq!(popped, vec![3, 2, 1, 0]);
        }
    }

    #[test]
    fn front_reports_min_and_empty_sentinel() {
        let mut cal = ShardCalendar::new();
        assert_eq!(cal.front(), EMPTY_FRONT);
        cal.push(Event { time: 3.0, seq: 1, node: 4 });
        cal.push(Event { time: 2.0, seq: 2, node: 5 });
        assert_eq!(cal.front(), (2.0, 2, 5));
        cal.pop();
        assert_eq!(cal.front(), (3.0, 1, 4));
    }

    #[test]
    fn inf_bits_matches_ieee() {
        assert_eq!(f64::INFINITY.to_bits(), INF_BITS);
    }

    #[test]
    fn with_capacity_preallocates_backing_storage() {
        let cal = ShardCalendar::with_capacity(17);
        assert!(cal.heap.capacity() >= 17);
        assert_eq!(cal.front(), EMPTY_FRONT);
    }
}
