//! The batch replication engine: R independent replications of the same
//! cell in one replication-major SoA arena, with vectorized service
//! sampling.
//!
//! The sweep layer's unit of work is an *ensemble*: every reported number
//! is a mean ± CI over many replications of one cell.  Before this engine,
//! each replication built its own arena (its own task pool, queue arrays,
//! calendar) and drew service durations one at a time — at small n the
//! per-replication constant costs rival the stepping itself.  The batch
//! arena amortizes them:
//!
//! * **One allocation for all R task pools.**  Replications share the node
//!   count and layout, so the [`TaskPool`] is built once with R·n virtual
//!   nodes (`global index = rep·n + node`, replication-major) and capacity
//!   R·C.  Queue lengths for all replications live in one flat `u32`
//!   array; replication r's slice is `qlens[r·n .. (r+1)·n]`.
//! * **Interleaved stepping.**  A *round* advances every replication by
//!   one CS step.  All replications run the same `steps` budget, so rounds
//!   keep them in lockstep with no liveness tracking, and the pool/queue
//!   touches of consecutive replications stay within one working set
//!   instead of R cold ones built and torn down in sequence.
//! * **Vectorized service sampling.**  A step *defers* its (up to two)
//!   service draws into a pending block; the end of each round resolves
//!   the whole block at once.  Durations are keyed by (replication's
//!   service root, node, service count) — pure functions of the key — so
//!   deferral and batch order cannot change any value.  Every
//!   single-family cell (all-exponential, all-deterministic,
//!   all-lognormal) goes through its chunked-lane kernel in
//!   `util::sampler` — bit-identical to the scalar keyed draw by
//!   construction; only mixed-family cells fall back to scalar keyed
//!   draws, flagged once on stderr.
//! * **Prefetched routing draws.**  Round boundaries also block-resolve
//!   each replication's next raw routing u64, so the steady-state step
//!   never constructs or seeds a scalar generator.  The step's dispatch
//!   (or the first churn re-route) drains the slot through the policy's
//!   `route_prefetched` continuation, which is draw-for-draw identical to
//!   the scalar `route` path — the slot always holds the stream's next
//!   raw value, whoever consumes it.
//!
//! # Determinism contract
//!
//! Each replication r keeps exactly the per-replication streams of the
//! heap oracle: routing from `Rng::new(seed_r).derive(ROUTE_STREAM)`
//! consumed in that replication's CS-step order, service durations keyed
//! via `stream_seed(service_seed(seed_r), [node, count])`.  Replications
//! never share RNG state, policies, or calendars — only storage — so every
//! replication in a batch is bit-identical to the same seed run alone on
//! the heap engine, for any batch width (`tests/engine_equivalence.rs`
//! checks R ∈ {1, 4, 32} across all builtin policies).

use super::calendar::{Event, ShardCalendar};
use super::churn::{ChurnEvent, ChurnEventKind, ChurnRuntime};
use super::soa::TaskPool;
use super::{
    initial_placements, service_duration, service_seed, EngineError, EventEngine, StepAggregator,
    ROUTE_STREAM,
};
use crate::coordinator::policy::SamplingPolicy;
use crate::simulator::network::{SimConfig, SimResult, StepOutcome, TaskRecord};
use crate::simulator::service::ServiceDist;
use crate::util::rng::Rng;
use crate::util::sampler::{batch_deterministic, batch_exponential, batch_lognormal};
use crate::util::trace::TraceWriter;
use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide once-flag for the scalar-fallback notice: a heterogeneous
/// service cell silently de-vectorizing the whole sweep is exactly the
/// regression the raw-speed work guards against, so the first fallback
/// block says so on stderr (once — sweeps run thousands of blocks).
static SCALAR_FALLBACK_LOGGED: AtomicBool = AtomicBool::new(false);

/// The vectorized sampling kernel a cell's service-family mix admits.
/// One family across all nodes → that family's chunked-lane batch kernel
/// (`util::sampler::batch_*`, each bit-identical to the scalar keyed
/// draw); mixed families → the scalar keyed fallback.
enum BatchSampling {
    /// every node exponential — per-node rates
    Exp { rates: Vec<f64> },
    /// every node deterministic — per-node means (no RNG consumed)
    Det { means: Vec<f64> },
    /// every node log-normal — per-node (mean, cv)
    LogNormal { means: Vec<f64>, cvs: Vec<f64> },
    /// heterogeneous families: scalar keyed draws, flagged loudly
    Mixed,
}

impl BatchSampling {
    fn of(service: &[ServiceDist]) -> BatchSampling {
        let exp: Option<Vec<f64>> = service
            .iter()
            .map(|d| match d {
                ServiceDist::Exp { rate } => Some(*rate),
                _ => None,
            })
            .collect();
        if let Some(rates) = exp {
            return BatchSampling::Exp { rates };
        }
        let det: Option<Vec<f64>> = service
            .iter()
            .map(|d| match d {
                ServiceDist::Det { mean } => Some(*mean),
                _ => None,
            })
            .collect();
        if let Some(means) = det {
            return BatchSampling::Det { means };
        }
        let log: Option<Vec<(f64, f64)>> = service
            .iter()
            .map(|d| match d {
                ServiceDist::LogNormal { mean, cv } => Some((*mean, *cv)),
                _ => None,
            })
            .collect();
        if let Some(mc) = log {
            let (means, cvs) = mc.into_iter().unzip();
            return BatchSampling::LogNormal { means, cvs };
        }
        BatchSampling::Mixed
    }

    fn vectorized(&self) -> bool {
        !matches!(self, BatchSampling::Mixed)
    }
}

/// Whether a cell with these per-node service distributions takes the
/// vectorized batched sampling path (one family across all nodes) or the
/// scalar keyed fallback.  The sweep layer reports this per cell in its
/// perf block so a de-vectorization regression is visible in the JSON.
pub fn batch_vectorizes(service: &[ServiceDist]) -> bool {
    BatchSampling::of(service).vectorized()
}

/// A deferred service draw: everything needed to materialize the
/// completion event once the round's block is sampled.
#[derive(Clone, Copy, Debug)]
struct PendingDraw {
    rep: u32,
    node: u32,
    /// the node's service count at schedule time (the duration key)
    count: u64,
    /// virtual start time of the service in its replication
    start: f64,
    /// the replication-local sequence number assigned at schedule time
    seq: u64,
    /// the node's churn rate scale captured at schedule time (1.0 when
    /// churn is off — `dur * 1.0` is IEEE-exact)
    scale: f64,
}

/// R same-cell replications sharing one SoA arena.
pub(crate) struct BatchArena {
    /// nodes per replication
    n: usize,
    /// shared per-node service distributions (identical across reps)
    service: Vec<ServiceDist>,
    /// the vectorized kernel this cell's family mix admits
    sampling: BatchSampling,
    /// one pool for all replications: R·n virtual nodes, capacity R·C
    pool: TaskPool,
    /// per-(rep, node) services started, replication-major like the pool
    svc_count: Vec<u64>,
    // per-replication state
    calendars: Vec<ShardCalendar>,
    policies: Vec<Box<dyn SamplingPolicy>>,
    route_rng: Vec<Rng>,
    /// one-deep prefetched raw routing draw per replication, block-
    /// resolved at round boundaries for policies that opt in
    /// (`SamplingPolicy::prefetch_routes`).  The slot always holds the
    /// stream's NEXT raw u64, so draining it first keeps any interleaving
    /// of prefetched and scalar consumption draw-for-draw identical to
    /// the heap oracle.
    route_prefetch: Vec<Option<u64>>,
    /// per-replication keyed service-stream roots
    svc_base: Vec<u64>,
    seq: Vec<u64>,
    now: Vec<f64>,
    step: Vec<u64>,
    busy: Vec<usize>,
    /// deferred draws of the current round
    pending: Vec<PendingDraw>,
    /// per-replication open-network lifecycle state (None = closed)
    churn: Option<Vec<ChurnRuntime>>,
    // reusable scratch for the vectorized sampler and bulk observation
    seed_buf: Vec<u64>,
    rate_buf: Vec<f64>,
    cv_buf: Vec<f64>,
    dur_buf: Vec<f64>,
    lens_buf: Vec<u32>,
}

impl BatchArena {
    /// Build the arena: `base` supplies the shared cell shape (p, service,
    /// C, steps, init); `seeds[r]` and `policies[r]` are replication r's
    /// RNG root and fresh policy instance.
    pub fn new(
        base: &SimConfig,
        seeds: &[u64],
        mut policies: Vec<Box<dyn SamplingPolicy>>,
    ) -> Result<BatchArena, String> {
        base.validate()?;
        if seeds.is_empty() {
            return Err("batch arena needs at least one replication".into());
        }
        if policies.len() != seeds.len() {
            return Err(format!(
                "batch arena: {} seeds but {} policies",
                seeds.len(),
                policies.len()
            ));
        }
        let n = base.p.len();
        for p in &policies {
            if p.n() != n {
                return Err(format!(
                    "policy '{}' covers {} nodes but the network has {n}",
                    p.name(),
                    p.n()
                ));
            }
        }
        let reps = seeds.len();
        let cap = base.effective_pool_capacity();
        // churn-off steady state holds at most min(n, C) completions per
        // replication calendar (one per busy node; round-deferred draws
        // sit in `pending`, not the calendar), so the heaps never regrow
        let cal_cap = n.min(cap) + 1;
        let mut arena = BatchArena {
            n,
            service: base.service.clone(),
            sampling: BatchSampling::of(&base.service),
            pool: TaskPool::new(reps * n, reps * cap),
            svc_count: vec![0; reps * n],
            calendars: (0..reps)
                .map(|_| ShardCalendar::with_capacity(cal_cap))
                .collect(),
            policies: Vec::new(),
            route_rng: seeds
                .iter()
                .map(|&s| Rng::new(s).derive(ROUTE_STREAM))
                .collect(),
            route_prefetch: vec![None; reps],
            svc_base: seeds.iter().map(|&s| service_seed(s)).collect(),
            seq: vec![0; reps],
            now: vec![0.0; reps],
            step: vec![0; reps],
            busy: vec![0; reps],
            pending: Vec::with_capacity(2 * reps),
            churn: base
                .churn
                .as_ref()
                .map(|c| seeds.iter().map(|&s| ChurnRuntime::new(c, s, n)).collect()),
            seed_buf: Vec::new(),
            rate_buf: Vec::new(),
            cv_buf: Vec::new(),
            dur_buf: Vec::new(),
            lens_buf: Vec::with_capacity(n),
        };
        // initial placement S_0, one replication at a time: placements
        // consume replication r's routing stream exactly as the heap
        // engine's constructor would
        for (r, policy) in policies.iter_mut().enumerate() {
            // initially-departed nodes are masked out of replication r's
            // policy BEFORE its placements are drawn — identical call
            // sequence to the heap oracle on seed_r
            if let Some(ch) = &arena.churn {
                #[cfg(debug_assertions)]
                let route_fp = arena.route_rng[r].state_fingerprint();
                for i in 0..n {
                    if ch[r].departed[i] {
                        policy.observe_leave(i);
                    }
                }
                #[cfg(debug_assertions)]
                debug_assert_eq!(
                    route_fp,
                    arena.route_rng[r].state_fingerprint(),
                    "observe_leave moved the routing stream (policy '{}')",
                    policy.name()
                );
            }
            let placements = initial_placements(base, policy.as_mut(), &mut arena.route_rng[r]);
            for (placed, (node, prob)) in placements.into_iter().enumerate() {
                // mirror the heap oracle's per-replication capacity check
                if placed >= cap {
                    return Err(EngineError::PoolExhausted { node, capacity: cap }.to_string());
                }
                let len = arena
                    .pool
                    .try_push(r * n + node, 0, 0.0, prob)
                    .map_err(|e| e.to_string())?;
                if len == 1 {
                    arena.busy[r] += 1;
                    arena.schedule(r, node, 0.0);
                }
            }
            // incremental policies only ever hear about queues that
            // change, so sync them once with the realized initial state
            // (idempotent for the Routed path)
            if policy.incremental() {
                for i in 0..n {
                    policy.observe_node(i, arena.pool.qlen(r * n + i));
                }
            }
        }
        arena.policies = policies;
        // the C·R initial services are the first (and largest) sampled
        // block; the first routing draws prefetch right behind them
        arena.end_round();
        Ok(arena)
    }

    /// Round boundary: resolve the round's deferred service block, then
    /// block-resolve the next raw routing draw of every replication whose
    /// policy opts into the prefetched path.
    pub(crate) fn end_round(&mut self) {
        self.flush_pending();
        for r in 0..self.route_prefetch.len() {
            if self.route_prefetch[r].is_none() && self.policies[r].prefetch_routes() {
                self.route_prefetch[r] = Some(self.route_rng[r].next_u64());
            }
        }
    }

    /// Draw replication `r`'s next routing destination, draining the
    /// prefetched raw draw first (it is always the stream's next value;
    /// extra consumers within a round — churn leave re-routes — continue
    /// on the scalar path, so the stream order never changes).
    #[inline]
    fn draw_route(&mut self, r: usize) -> usize {
        match self.route_prefetch[r].take() {
            Some(first) => self.policies[r].route_prefetched(first, &mut self.route_rng[r]),
            None => self.policies[r].route(&mut self.route_rng[r]),
        }
    }

    /// Record a deferred service start for replication `r` at `node`.
    #[inline]
    fn schedule(&mut self, r: usize, node: usize, start: f64) {
        let gi = r * self.n + node;
        let count = self.svc_count[gi];
        self.svc_count[gi] = count + 1;
        self.seq[r] += 1;
        let mut scale = 1.0;
        if let Some(ch) = &mut self.churn {
            let rt = &mut ch[r];
            rt.pending_seq[node] = self.seq[r];
            scale = rt.rate_scale[node];
        }
        self.pending.push(PendingDraw {
            rep: r as u32,
            node: node as u32,
            count,
            start,
            seq: self.seq[r],
            scale,
        });
    }

    /// Schedule a churn-triggered service start *immediately* (scalar
    /// keyed draw straight into the calendar).  Lifecycle events need the
    /// completion in place before the prelude's next front comparison, so
    /// they bypass the round's deferred block; the key fully determines
    /// the duration, so the value is bit-identical either way.
    fn schedule_now(&mut self, r: usize, node: usize, start: f64) {
        let gi = r * self.n + node;
        let count = self.svc_count[gi];
        self.svc_count[gi] = count + 1;
        self.seq[r] += 1;
        let seq = self.seq[r];
        let mut scale = 1.0;
        if let Some(ch) = &mut self.churn {
            let rt = &mut ch[r];
            rt.pending_seq[node] = seq;
            scale = rt.rate_scale[node];
        }
        let dur = service_duration(self.svc_base[r], &self.service[node], node as u32, count);
        self.calendars[r].push(Event { time: start + dur * scale, seq, node: node as u32 });
    }

    /// Resolve every deferred draw of the round and push the completion
    /// events.  Vectorized for single-family cells, scalar keyed for mixed
    /// cells — identical values either way (the key fully determines the
    /// draw).
    pub(crate) fn flush_pending(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.dur_buf.clear();
        match &self.sampling {
            BatchSampling::Exp { rates } => {
                self.seed_buf.clear();
                self.rate_buf.clear();
                for p in &self.pending {
                    self.seed_buf.push(crate::util::rng::stream_seed(
                        self.svc_base[p.rep as usize],
                        &[p.node as u64, p.count],
                    ));
                    self.rate_buf.push(rates[p.node as usize]);
                }
                self.dur_buf.resize(self.pending.len(), 0.0);
                batch_exponential(&self.seed_buf, &self.rate_buf, &mut self.dur_buf);
            }
            BatchSampling::Det { means } => {
                // no RNG consumed — the "batch" is a mean lookup per draw
                self.rate_buf.clear();
                for p in &self.pending {
                    self.rate_buf.push(means[p.node as usize]);
                }
                self.dur_buf.resize(self.pending.len(), 0.0);
                batch_deterministic(&self.rate_buf, &mut self.dur_buf);
            }
            BatchSampling::LogNormal { means, cvs } => {
                self.seed_buf.clear();
                self.rate_buf.clear();
                self.cv_buf.clear();
                for p in &self.pending {
                    self.seed_buf.push(crate::util::rng::stream_seed(
                        self.svc_base[p.rep as usize],
                        &[p.node as u64, p.count],
                    ));
                    self.rate_buf.push(means[p.node as usize]);
                    self.cv_buf.push(cvs[p.node as usize]);
                }
                self.dur_buf.resize(self.pending.len(), 0.0);
                batch_lognormal(&self.seed_buf, &self.rate_buf, &self.cv_buf, &mut self.dur_buf);
            }
            BatchSampling::Mixed => {
                // every single-family cell has a vectorized kernel above,
                // so landing here means the cell genuinely mixes families
                debug_assert!(
                    !batch_vectorizes(&self.service),
                    "scalar fallback taken for a single-family cell"
                );
                if !SCALAR_FALLBACK_LOGGED.swap(true, Ordering::Relaxed) {
                    eprintln!(
                        "note: mixed service families in cell — batch engine \
                         falling back to scalar keyed service draws"
                    );
                }
                for p in &self.pending {
                    self.dur_buf.push(service_duration(
                        self.svc_base[p.rep as usize],
                        &self.service[p.node as usize],
                        p.node,
                        p.count,
                    ));
                }
            }
        }
        for (p, &dur) in self.pending.iter().zip(&self.dur_buf) {
            self.calendars[p.rep as usize].push(Event {
                time: p.start + dur * p.scale,
                seq: p.seq,
                node: p.node,
            });
        }
        self.pending.clear();
    }

    /// Merge to replication `r`'s next *valid* completion, applying every
    /// lifecycle event that precedes it (churn-first at timestamp ties,
    /// schedule order at equal times).  Shared prelude contract of all
    /// engines.
    fn next_completion(&mut self, r: usize) -> Option<Event> {
        if self.churn.is_none() {
            return self.calendars[r].pop();
        }
        self.churn.as_mut().unwrap()[r].log.clear();
        loop {
            // lazy cancellation: drop calendar fronts whose seq a stall /
            // leave invalidated
            loop {
                let (_, seq, node) = self.calendars[r].front();
                if seq == u64::MAX || self.churn.as_ref().unwrap()[r].is_live(node, seq) {
                    break;
                }
                self.calendars[r].pop();
            }
            let front = self.calendars[r].front();
            let tcomp = if front.1 == u64::MAX { f64::INFINITY } else { front.0 };
            let tchurn = self.churn.as_ref().unwrap()[r].next_time();
            if tchurn <= tcomp && tchurn.is_finite() {
                let ev = self.churn.as_mut().unwrap()[r].pop().unwrap();
                self.now[r] = ev.time;
                self.apply_churn(r, ev);
                continue;
            }
            let ev = self.calendars[r].pop()?;
            self.churn.as_mut().unwrap()[r].pending_seq[ev.node as usize] = 0;
            return Some(ev);
        }
    }

    /// Apply one lifecycle event to replication `r` (same semantics and
    /// policy call order as the heap oracle's `apply_churn`).
    fn apply_churn(&mut self, r: usize, ev: ChurnEvent) {
        let t = ev.time;
        match ev.kind {
            ChurnEventKind::Join { node } => {
                {
                    let rt = &mut self.churn.as_mut().unwrap()[r];
                    rt.departed[node as usize] = false;
                    rt.stalled[node as usize] = false;
                    rt.rate_scale[node as usize] = 1.0;
                    // svc_count is NOT reset: duration keys stay unique
                }
                #[cfg(debug_assertions)]
                let route_fp = self.route_rng[r].state_fingerprint();
                self.policies[r].observe_join(node as usize);
                #[cfg(debug_assertions)]
                debug_assert_eq!(
                    route_fp,
                    self.route_rng[r].state_fingerprint(),
                    "observe_join moved the routing stream (policy '{}')",
                    self.policies[r].name()
                );
            }
            ChurnEventKind::Leave { node } => self.apply_leave(r, node, t),
            ChurnEventKind::Stall { node } => {
                let gi = r * self.n + node as usize;
                let rt = &mut self.churn.as_mut().unwrap()[r];
                rt.stalled[node as usize] = true;
                // cancel the in-flight completion; the queue freezes
                rt.pending_seq[node as usize] = 0;
                if self.pool.qlen(gi) > 0 {
                    self.busy[r] -= 1;
                }
            }
            ChurnEventKind::Rejoin { node } => {
                self.churn.as_mut().unwrap()[r].stalled[node as usize] = false;
                if self.pool.qlen(r * self.n + node as usize) > 0 {
                    self.busy[r] += 1;
                    self.schedule_now(r, node as usize, t);
                }
            }
            ChurnEventKind::SetRate { node, scale } => {
                self.churn.as_mut().unwrap()[r].rate_scale[node as usize] = scale;
            }
        }
    }

    /// A member departs from replication `r`: mask it from the policy,
    /// then re-route its queued tasks one at a time, each keeping its
    /// original dispatch identity (a hand-off, not a new dispatch).
    fn apply_leave(&mut self, r: usize, node: u32, t: f64) {
        let ni = node as usize;
        let gi = r * self.n + ni;
        {
            let qlen = self.pool.qlen(gi);
            let rt = &mut self.churn.as_mut().unwrap()[r];
            rt.pending_seq[ni] = 0;
            if qlen > 0 && !rt.stalled[ni] {
                self.busy[r] -= 1;
            }
            rt.departed[ni] = true;
            rt.stalled[ni] = false;
        }
        #[cfg(debug_assertions)]
        let route_fp = self.route_rng[r].state_fingerprint();
        self.policies[r].observe_leave(ni);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            self.route_rng[r].state_fingerprint(),
            "observe_leave moved the routing stream (policy '{}')",
            self.policies[r].name()
        );
        let incremental = self.policies[r].incremental();
        while self.pool.qlen(gi) > 0 {
            let (d_step, d_time, d_prob, _rem) = self.pool.pop(gi);
            if !incremental {
                self.lens_buf.clear();
                self.lens_buf
                    .extend_from_slice(self.pool.qlens_of(r * self.n, self.n));
                self.policies[r].observe(&self.lens_buf);
            }
            let dest = self.draw_route(r);
            let dlen = self.pool.push(r * self.n + dest, d_step, d_time, d_prob);
            let dest_stalled = self.churn.as_ref().unwrap()[r].stalled[dest];
            if dlen == 1 && !dest_stalled {
                self.busy[r] += 1;
                self.schedule_now(r, dest, t);
            }
            if incremental {
                self.policies[r].observe_node(dest, dlen);
            }
            self.churn.as_mut().unwrap()[r].log.push((t, dest as u32, dlen));
        }
        self.churn.as_mut().unwrap()[r].log.push((t, node, 0));
    }

    /// Replication `r`'s queue-delta log from its latest `step_rep`.
    pub(crate) fn churn_deltas_of(&self, r: usize) -> &[(f64, u32, u32)] {
        match &self.churn {
            Some(ch) => &ch[r].log,
            None => &[],
        }
    }

    /// Advance replication `r` one CS step.  Scheduled services are only
    /// *deferred*, not yet in the calendar — callers must `flush_pending`
    /// before stepping any replication again.
    pub(crate) fn step_rep(&mut self, r: usize) -> Option<StepOutcome> {
        let ev = self.next_completion(r)?;
        self.now[r] = ev.time;
        let node = ev.node as usize;
        let (d_step, d_time, d_prob, new_len) = self.pool.pop(r * self.n + node);
        if new_len > 0 {
            self.schedule(r, node, ev.time);
        } else {
            self.busy[r] -= 1;
        }
        let record = TaskRecord {
            node: ev.node,
            dispatch_step: d_step,
            complete_step: self.step[r],
            dispatch_time: d_time,
            complete_time: ev.time,
            dispatch_prob: d_prob,
        };
        // delay-feedback channel — per-replication policy, RNG-free, same
        // call point as the heap engine (part of the bit-identity
        // contract); debug builds assert the no-RNG half at runtime
        // (complement of lint rule R1)
        #[cfg(debug_assertions)]
        let route_fp = self.route_rng[r].state_fingerprint();
        self.policies[r].observe_completion(
            node,
            record.delay_steps(),
            record.complete_time - record.dispatch_time,
        );
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            self.route_rng[r].state_fingerprint(),
            "observe_completion moved the routing stream (policy '{}')",
            self.policies[r].name()
        );
        // dispatcher: same observation protocol as the heap and sharded
        // engines — incremental policies get only the two changed queues
        let incremental = self.policies[r].incremental();
        if incremental {
            self.policies[r].observe_node(node, new_len);
        } else {
            self.lens_buf.clear();
            self.lens_buf
                .extend_from_slice(self.pool.qlens_of(r * self.n, self.n));
            self.policies[r].observe(&self.lens_buf);
        }
        let next = self.draw_route(r);
        let next_prob = self.policies[r].prob_of(next);
        let next_len = self
            .pool
            .push(r * self.n + next, self.step[r] + 1, ev.time, next_prob);
        let next_stalled = self
            .churn
            .as_ref()
            .is_some_and(|ch| ch[r].stalled[next]);
        if next_len == 1 && !next_stalled {
            self.busy[r] += 1;
            self.schedule(r, next, ev.time);
        }
        if incremental {
            self.policies[r].observe_node(next, next_len);
        }
        let outcome = StepOutcome {
            completed_node: ev.node,
            dispatch_step: d_step,
            next_node: next as u32,
            time: ev.time,
            record,
        };
        self.step[r] += 1;
        Some(outcome)
    }

    /// Tasks currently held by replication `r` (must equal C always).
    pub(crate) fn population_of(&self, r: usize) -> usize {
        self.pool.population_of(r * self.n, self.n)
    }
}

/// Run R replications of the same cell to completion through one batch
/// arena, returning one `SimResult` per seed, in seed order.  Every result
/// is bit-identical to running that seed alone on the heap oracle.
///
/// `mk_policy(r)` must build a FRESH policy instance for replication r —
/// adaptive policies carry per-replication state.  All replications share
/// `base`'s shape (p, service, concurrency, steps, init, record flags);
/// `base.seed` is ignored in favor of `seeds[r]`.
pub fn run_batch(
    base: &SimConfig,
    seeds: &[u64],
    mut mk_policy: impl FnMut(usize) -> Result<Box<dyn SamplingPolicy>, String>,
) -> Result<Vec<SimResult>, String> {
    let policies = (0..seeds.len())
        .map(&mut mk_policy)
        .collect::<Result<Vec<_>, String>>()?;
    let mut arena = BatchArena::new(base, seeds, policies)?;
    let n = base.p.len();
    let reps = seeds.len();
    let mut aggs: Vec<StepAggregator> = (0..reps)
        .map(|r| {
            StepAggregator::new(n, base.steps, base.record_tasks, base.queue_sample_every, |i| {
                arena.pool.qlen(r * n + i)
            })
        })
        .collect();
    // disk-spilled traces: one file per replication, `.rep<r>`-suffixed
    let mut traces: Vec<Option<TraceWriter>> = match &base.trace_path {
        Some(p) => (0..reps)
            .map(|r| TraceWriter::create(&format!("{p}.rep{r}")).map(Some))
            .collect::<Result<_, String>>()?,
        None => (0..reps).map(|_| None).collect(),
    };
    for _ in 0..base.steps {
        // one interleaved round: every replication advances one CS step,
        // then the round's service draws resolve as one sampled block
        for (r, agg) in aggs.iter_mut().enumerate() {
            let out = arena.step_rep(r).ok_or("network drained")?;
            // lifecycle queue deltas (leave drains) precede the step's own
            // flushes — same feed order as the single-run collect loop
            agg.apply_churn_deltas(arena.churn_deltas_of(r));
            let i = out.completed_node as usize;
            let j = out.next_node as usize;
            agg.push_step(
                &out,
                arena.pool.qlen(r * n + i),
                arena.pool.qlen(r * n + j),
                arena.busy[r],
            );
            if let Some(w) = traces[r].as_mut() {
                w.push(&out.record)?;
            }
        }
        arena.end_round();
    }
    for w in traces.into_iter().flatten() {
        w.finish()?;
    }
    Ok(aggs
        .into_iter()
        .enumerate()
        .map(|(r, agg)| {
            debug_assert_eq!(arena.population_of(r), base.concurrency);
            agg.finish(arena.now[r])
        })
        .collect())
}

/// A width-1 batch arena behind the [`EventEngine`] interface — what
/// `engine = "batch"` resolves to for a standalone `SimConfig` (CLI
/// `--engine batch`, equivalence tests, `transient_mi`).
pub(crate) struct SingleBatch {
    arena: BatchArena,
}

impl SingleBatch {
    pub fn new(cfg: SimConfig, policy: Box<dyn SamplingPolicy>) -> Result<SingleBatch, String> {
        let seeds = [cfg.seed];
        Ok(SingleBatch { arena: BatchArena::new(&cfg, &seeds, vec![policy])? })
    }
}

impl EventEngine for SingleBatch {
    fn advance(&mut self) -> Option<StepOutcome> {
        let out = self.arena.step_rep(0);
        self.arena.end_round();
        out
    }

    fn queue_len(&self, i: usize) -> usize {
        self.arena.pool.qlen(i) as usize
    }

    fn busy_nodes(&self) -> usize {
        self.arena.busy[0]
    }

    fn now(&self) -> f64 {
        self.arena.now[0]
    }

    fn population(&self) -> usize {
        self.arena.population_of(0)
    }

    fn policy_name(&self) -> String {
        self.arena.policies[0].name()
    }

    fn churn_deltas(&self) -> &[(f64, u32, u32)] {
        self.arena.churn_deltas_of(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{FenwickAdaptivePolicy, StaticPolicy};
    use crate::simulator::engine::run_with_policy;
    use crate::simulator::network::SimConfig;
    use crate::simulator::service::{ServiceDist, ServiceFamily};
    use crate::simulator::EngineConfig;
    use crate::util::rng::stream_seed;

    fn cfg(n: usize, c: usize, steps: u64, family: ServiceFamily) -> SimConfig {
        let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
        SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, family),
            c,
            steps,
        )
    }

    fn static_policy(n: usize) -> Box<dyn SamplingPolicy> {
        Box::new(StaticPolicy::new(vec![1.0 / n as f64; n]).unwrap())
    }

    fn heap_oracle(base: &SimConfig, seed: u64) -> SimResult {
        let mut c = base.clone();
        c.seed = seed;
        c.engine = EngineConfig::heap();
        run_with_policy(c, static_policy(base.p.len())).unwrap()
    }

    #[test]
    fn every_batched_replication_matches_its_heap_oracle() {
        let base = cfg(8, 5, 600, ServiceFamily::Exponential);
        let seeds: Vec<u64> = (0..6).map(|i| stream_seed(3, &[0, i])).collect();
        let results = run_batch(&base, &seeds, |_| Ok(static_policy(8))).unwrap();
        assert_eq!(results.len(), 6);
        for (r, got) in results.iter().enumerate() {
            let want = heap_oracle(&base, seeds[r]);
            assert_eq!(got.total_time.to_bits(), want.total_time.to_bits(), "rep {r}");
            assert_eq!(got.completions, want.completions, "rep {r}");
            assert_eq!(got.tau_max, want.tau_max, "rep {r}");
            for i in 0..8 {
                assert_eq!(
                    got.mean_queue[i].to_bits(),
                    want.mean_queue[i].to_bits(),
                    "rep {r} node {i}"
                );
            }
        }
    }

    #[test]
    fn scalar_fallback_families_match_heap_too() {
        // deterministic + lognormal cells now take their own vectorized
        // kernels — they must stay bit-identical to the heap oracle
        for family in [
            ServiceFamily::Deterministic,
            ServiceFamily::LogNormal(0.5),
            ServiceFamily::LogNormal(1.2),
        ] {
            let base = cfg(6, 4, 400, family);
            assert!(batch_vectorizes(&base.service), "{family:?}");
            let seeds = [11u64, 12, 13];
            let results = run_batch(&base, &seeds, |_| Ok(static_policy(6))).unwrap();
            for (r, got) in results.iter().enumerate() {
                let want = heap_oracle(&base, seeds[r]);
                assert_eq!(
                    got.total_time.to_bits(),
                    want.total_time.to_bits(),
                    "{family:?} rep {r}"
                );
                assert_eq!(got.dispatches, want.dispatches, "{family:?} rep {r}");
            }
        }
    }

    #[test]
    fn mixed_family_cells_take_the_scalar_path_and_still_match() {
        // the only remaining scalar-fallback route: a cell that genuinely
        // mixes service families
        let mut base = cfg(6, 4, 400, ServiceFamily::Exponential);
        base.service[1] = ServiceDist::Det { mean: 0.25 };
        base.service[4] = ServiceDist::LogNormal { mean: 1.0, cv: 1.2 };
        assert!(!batch_vectorizes(&base.service));
        let seeds = [41u64, 42, 43];
        let results = run_batch(&base, &seeds, |_| Ok(static_policy(6))).unwrap();
        for (r, got) in results.iter().enumerate() {
            let want = heap_oracle(&base, seeds[r]);
            assert_eq!(got.total_time.to_bits(), want.total_time.to_bits(), "rep {r}");
            assert_eq!(got.completions, want.completions, "rep {r}");
        }
    }

    #[test]
    fn batched_adaptive_policies_stay_per_replication() {
        // adaptive state must not leak between replications: each batched
        // replication equals the same seed run alone
        let base = cfg(10, 7, 500, ServiceFamily::Exponential);
        let mk = || -> Box<dyn SamplingPolicy> {
            Box::new(FenwickAdaptivePolicy::new(vec![0.1; 10], 0.8).unwrap())
        };
        let seeds = [5u64, 6, 7, 8];
        let batched = run_batch(&base, &seeds, |_| Ok(mk())).unwrap();
        for (r, got) in batched.iter().enumerate() {
            let mut c = base.clone();
            c.seed = seeds[r];
            let want = run_with_policy(c, mk()).unwrap();
            assert_eq!(got.total_time.to_bits(), want.total_time.to_bits(), "rep {r}");
            assert_eq!(got.completions, want.completions, "rep {r}");
        }
    }

    #[test]
    fn churny_batched_replications_match_the_heap_oracle() {
        use crate::simulator::engine::churn::ChurnConfig;
        let mut base = cfg(8, 5, 500, ServiceFamily::Exponential);
        base.churn = Some(ChurnConfig {
            arrival_rate: 0.7,
            mean_lifetime: 2.0,
            stall_rate: 0.5,
            mean_stall: 0.4,
            rate_change_rate: 0.5,
            rate_factor_min: 0.5,
            rate_factor_max: 2.0,
            initial_active: 6,
            max_events: 200,
        });
        let seeds = [31u64, 32, 33, 34];
        let results = run_batch(&base, &seeds, |_| Ok(static_policy(8))).unwrap();
        for (r, got) in results.iter().enumerate() {
            let want = heap_oracle(&base, seeds[r]);
            assert_eq!(got.total_time.to_bits(), want.total_time.to_bits(), "rep {r}");
            assert_eq!(got.completions, want.completions, "rep {r}");
            for i in 0..8 {
                // bit-equal time-weighted queue averages also pin the
                // aggregator's churn-delta feed on both engines
                assert_eq!(
                    got.mean_queue[i].to_bits(),
                    want.mean_queue[i].to_bits(),
                    "rep {r} node {i}"
                );
            }
        }
    }

    #[test]
    fn undersized_pool_is_a_typed_error_not_a_panic() {
        let mut base = cfg(4, 3, 10, ServiceFamily::Exponential);
        base.pool_capacity = 2;
        let err = run_batch(&base, &[1, 2], |_| Ok(static_policy(4))).unwrap_err();
        assert!(err.contains("task pool exhausted"), "{err}");
        assert!(err.contains("capacity 2"), "{err}");
    }

    #[test]
    fn population_is_conserved_per_replication() {
        let base = cfg(7, 4, 0, ServiceFamily::Exponential);
        let seeds = [1u64, 2, 3];
        let mut arena =
            BatchArena::new(&base, &seeds, seeds.iter().map(|_| static_policy(7)).collect())
                .unwrap();
        for _ in 0..200 {
            for r in 0..3 {
                arena.step_rep(r).unwrap();
                assert_eq!(arena.population_of(r), 4);
            }
            arena.flush_pending();
        }
        for r in 0..3 {
            assert!(arena.busy[r] >= 1 && arena.busy[r] <= 4);
        }
    }

    #[test]
    fn single_batch_engine_is_selectable_via_config() {
        let mut a = cfg(9, 5, 300, ServiceFamily::Exponential);
        a.seed = 21;
        let mut b = a.clone();
        b.engine = EngineConfig::batch();
        let heap = run_with_policy(a, static_policy(9)).unwrap();
        let batch = run_with_policy(b, static_policy(9)).unwrap();
        assert_eq!(heap.total_time.to_bits(), batch.total_time.to_bits());
        assert_eq!(heap.completions, batch.completions);
    }

    #[test]
    fn arena_rejects_mismatched_inputs() {
        let base = cfg(4, 2, 10, ServiceFamily::Exponential);
        assert!(BatchArena::new(&base, &[], Vec::new()).is_err());
        let err = BatchArena::new(&base, &[1, 2], vec![static_policy(4)]).unwrap_err();
        assert!(err.contains("2 seeds"), "{err}");
        let err = BatchArena::new(&base, &[1], vec![static_policy(5)]).unwrap_err();
        assert!(err.contains("covers 5 nodes"), "{err}");
    }
}
