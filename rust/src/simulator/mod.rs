//! Event-driven closed-network simulator — the dynamics substrate under the
//! paper's figures (1, 5, 10–12) and the DL experiment driver.

pub mod network;
pub mod service;

pub use network::{
    run, run_with_policy, transient_mi, InitPlacement, Network, SimConfig, SimResult,
    StepOutcome, TaskRecord,
};
pub use service::{ServiceDist, ServiceFamily};
