//! Event-driven closed-network simulator — the dynamics substrate under the
//! paper's figures (1, 5, 10–12) and the DL experiment driver.
//!
//! Two interchangeable engines (`engine`): the monolithic heap oracle
//! (`Network`) and the sharded SoA engine that scales replications to
//! n = 10^6 nodes.  They are bit-identical on a shared seed.

pub mod engine;
pub mod network;
pub mod service;

pub use engine::{
    run, run_with_policy, transient_mi, with_engine, EngineConfig, EngineKind, EventEngine,
};
pub use network::{
    InitPlacement, Network, SimConfig, SimResult, StepOutcome, TaskRecord,
};
pub use service::{ServiceDist, ServiceFamily};
