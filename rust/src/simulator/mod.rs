//! Event-driven closed-network simulator — the dynamics substrate under the
//! paper's figures (1, 5, 10–12) and the DL experiment driver.
//!
//! Three interchangeable engines (`engine`): the monolithic heap oracle
//! (`Network`), the sharded SoA engine that scales replications to
//! n = 10^6 nodes, and the batch arena that packs R replications of one
//! cell into a single SoA allocation with vectorized service sampling.
//! All are bit-identical on a shared seed.

// Item-level docs are still being backfilled module by module (see the
// crate-root docs ratchet note).
#[allow(missing_docs)]
pub mod engine;
#[allow(missing_docs)]
pub mod network;
#[allow(missing_docs)]
pub mod service;

pub use engine::batch::{batch_vectorizes, run_batch};
pub use engine::churn::{
    generate_schedule, ChurnConfig, ChurnEvent, ChurnEventKind, CHURN_KEYS,
};
pub use engine::{
    run, run_with_policy, transient_mi, with_engine, EngineConfig, EngineError, EngineKind,
    EventEngine,
};
pub use network::{
    InitPlacement, Network, SimConfig, SimResult, StepOutcome, TaskRecord,
};
pub use service::{ServiceDist, ServiceFamily};
