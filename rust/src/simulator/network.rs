//! Event-driven simulation of the paper's closed queueing network.
//!
//! Exactly Algorithm 1's task-flow skeleton without the learning: `C` tasks
//! circulate over `n` single-server FIFO nodes; a task completion is one
//! **CS step** `k`; the dispatcher immediately routes a fresh task to
//! `K_{k+1} ~ p`.  The simulator tracks, per task, the dispatch step and
//! completion step — their difference is the paper's delay `M_{i,k}^T` in
//! server steps — plus queue-length and activity statistics used by both
//! the figures (1, 5, 10–12) and the AsyncSGD/FedBuff comparators
//! (τ_max, τ_c, τ_sum of Table 1).
//!
//! The same engine drives the DL experiments: `coordinator::driver` replays
//! the event stream and attaches real gradient computations to completions.
//!
//! Routing is delegated to a [`SamplingPolicy`]: the policy observes the
//! queue lengths before every dispatch and the engine records, on each
//! task, the probability with which its node was selected — the
//! inverse-probability weight Generalized AsyncSGD needs to stay unbiased
//! under time-varying policies.  `Network::new` wraps the config's `p` in
//! a static policy, reproducing the original fixed-p dynamics exactly.
//!
//! `Network` is the **heap engine** (`engine = "heap"`): one global event
//! heap, one `VecDeque` per node.  It doubles as the trace-equivalence
//! oracle for the sharded engine (`simulator::engine`): both draw routing
//! from the same sequential stream and service durations from the same
//! keyed (node, service count) stream, so their event traces are
//! bit-identical on a shared seed.

use super::engine::calendar::Event;
use super::engine::churn::{ChurnConfig, ChurnEvent, ChurnEventKind, ChurnRuntime};
use super::engine::{
    initial_placements, service_duration, service_seed, EngineConfig, EngineError, EventEngine,
    ROUTE_STREAM,
};
use super::service::ServiceDist;
use crate::coordinator::policy::{SamplingPolicy, StaticPolicy};
use crate::util::rng::Rng;
use crate::util::stats::Welford;
use std::collections::{BinaryHeap, VecDeque};

/// Initial placement of the C tasks (the paper's `S_0`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitPlacement {
    /// one task on each node; requires C == n ("full concurrency")
    OnePerNode,
    /// route each initial task independently via p
    Routed,
    /// node (j mod n) gets task j — deterministic, spreads evenly
    RoundRobin,
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub p: Vec<f64>,
    pub service: Vec<ServiceDist>,
    pub concurrency: usize,
    pub steps: u64,
    pub seed: u64,
    pub init: InitPlacement,
    /// keep every (node, dispatch_step, complete_step) record
    pub record_tasks: bool,
    /// sample queue lengths every `queue_sample_every` steps (0 = never)
    pub queue_sample_every: u64,
    /// which event engine executes the run (never changes results — the
    /// engines are bit-identical on a shared seed; see `simulator::engine`)
    pub engine: EngineConfig,
    /// open-network lifecycle process (None = the paper's closed network).
    /// The schedule is a pure function of `(churn, seed, n)` on a stream
    /// of its own, so enabling it never perturbs route/service draws.
    pub churn: Option<ChurnConfig>,
    /// task-pool capacity of the flat-pool engines (0 = exactly C).  A
    /// pool too small for the initial population surfaces a typed
    /// [`EngineError::PoolExhausted`] instead of a hot-path panic.
    pub pool_capacity: usize,
    /// stream every completed-task record to this file (`util::trace`
    /// layout) instead of holding O(steps) records resident — the
    /// disk-spilled form of `record_tasks` for 10^6+-step horizons.
    /// Batched replications write one file each, suffixed `.rep<r>`.
    /// Independent of `record_tasks`: set that false when spilling unless
    /// the resident copy is also wanted.
    pub trace_path: Option<String>,
}

impl SimConfig {
    pub fn new(p: Vec<f64>, service: Vec<ServiceDist>, concurrency: usize, steps: u64) -> Self {
        SimConfig {
            p,
            service,
            concurrency,
            steps,
            seed: 0,
            init: InitPlacement::Routed,
            record_tasks: false,
            queue_sample_every: 0,
            engine: EngineConfig::default(),
            churn: None,
            pool_capacity: 0,
            trace_path: None,
        }
    }

    /// Effective task-pool capacity (the `0` default means "exactly C").
    pub fn effective_pool_capacity(&self) -> usize {
        if self.pool_capacity == 0 {
            self.concurrency
        } else {
            self.pool_capacity
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.p.len() != self.service.len() || self.p.is_empty() {
            return Err("p/service length mismatch".into());
        }
        if self.concurrency == 0 {
            return Err("concurrency C must be >= 1".into());
        }
        if self.init == InitPlacement::OnePerNode && self.concurrency != self.p.len() {
            return Err(format!(
                "OnePerNode needs C == n (got C={} n={})",
                self.concurrency,
                self.p.len()
            ));
        }
        // lint-allow(R8): input validation over the config's p vector in its
        // given order — rejects bad configs, never feeds the digest
        let sum: f64 = self.p.iter().sum();
        if (sum - 1.0).abs() > 1e-9 {
            return Err(format!("p sums to {sum}"));
        }
        for (i, (pi, sd)) in self.p.iter().zip(&self.service).enumerate() {
            if !pi.is_finite() || *pi < 0.0 {
                return Err(format!("p[{i}] = {pi} is not a probability"));
            }
            if *pi == 0.0 && sd.rate() > 0.0 {
                return Err(format!(
                    "p[{i}] = 0 on a node with positive service rate mu={}: \
                     GenAsync's eta/(n*p_i) scaling would divide by zero; \
                     drop the node instead of zeroing its probability",
                    sd.rate()
                ));
            }
        }
        if let Some(churn) = &self.churn {
            let n = self.p.len();
            churn.validate(n)?;
            if self.init == InitPlacement::OnePerNode && churn.initial_active_count(n) < n {
                return Err(format!(
                    "OnePerNode requires all nodes active at t = 0, \
                     but [churn] initial_active = {} < n = {n}",
                    churn.initial_active_count(n)
                ));
            }
        }
        Ok(())
    }
}

/// One completed-task record.
#[derive(Clone, Copy, Debug)]
pub struct TaskRecord {
    pub node: u32,
    pub dispatch_step: u64,
    pub complete_step: u64,
    pub dispatch_time: f64,
    pub complete_time: f64,
    /// probability with which `node` was selected at dispatch time (the
    /// IPW weight for unbiased non-uniform-sampling updates)
    pub dispatch_prob: f64,
}

impl TaskRecord {
    /// Delay in CS steps (the paper's M).
    pub fn delay_steps(&self) -> u64 {
        self.complete_step - self.dispatch_step
    }
}

#[derive(Clone, Copy, Debug)]
struct Task {
    dispatch_step: u64,
    dispatch_time: f64,
    dispatch_prob: f64,
}

/// Aggregated results of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// per-node delay statistics (CS steps)
    pub delay_steps: Vec<Welford>,
    /// per-node delay statistics (virtual time)
    pub delay_time: Vec<Welford>,
    /// per-node completion counts (= J_k frequencies)
    pub completions: Vec<u64>,
    /// per-node dispatch counts (= K_{k+1} frequencies)
    pub dispatches: Vec<u64>,
    /// τ_max: maximum observed delay in steps
    pub tau_max: u64,
    /// τ_c: average number of busy nodes at step times
    pub tau_c: f64,
    /// τ_sum per node: total delay-in-steps of its completed tasks
    pub tau_sum: Vec<f64>,
    /// total virtual time elapsed over `steps` CS steps
    pub total_time: f64,
    /// optional full task records
    pub tasks: Vec<TaskRecord>,
    /// optional queue-length samples: (steps completed, X_1..X_n).  The
    /// first entry is the PRE-step initial state (k = 0, the realized
    /// S_0); entry k is the state after k CS steps.
    pub queue_samples: Vec<(u64, Vec<u32>)>,
    /// time-WEIGHTED average queue length per node (matches the stationary
    /// product form; event-time sampling would be biased — departures do
    /// not see time averages in a closed network)
    pub mean_queue: Vec<f64>,
}

impl SimResult {
    /// Average delay (steps) over a node index range — cluster summary.
    pub fn cluster_delay(&self, range: std::ops::Range<usize>) -> f64 {
        let mut w = Welford::new();
        for i in range {
            if self.delay_steps[i].count() > 0 {
                // weight clusters by tasks, merging Welfords
                w.merge(&self.delay_steps[i]);
            }
        }
        // a horizon shorter than the first completion merges zero tasks:
        // report a defined 0, not the 0/0 NaN of an empty Welford mean
        if w.count() == 0 {
            return 0.0;
        }
        w.mean()
    }

    /// Empirical m_i: mean delay in steps per node.
    pub fn m_empirical(&self) -> Vec<f64> {
        self.delay_steps.iter().map(|w| w.mean()).collect()
    }

    /// CS step *rate* (steps per unit virtual time).  Zero-step runs have
    /// zero elapsed time; the rate is a defined 0 rather than 0/0.
    pub fn step_rate(&self, steps: u64) -> f64 {
        if self.total_time <= 0.0 {
            return 0.0;
        }
        steps as f64 / self.total_time
    }
}

/// The simulator engine.  Reusable: `run` consumes a config and returns the
/// aggregate; `Network::new` + `step_until` give fine-grained control (used
/// by the coordinator driver).
pub struct Network {
    pub cfg: SimConfig,
    /// sequential routing stream (dedicated — service draws never touch it)
    route_rng: Rng,
    /// root of the keyed (node, service count) duration stream
    svc_seed: u64,
    /// services started per node — the key of the duration stream
    svc_count: Vec<u64>,
    policy: Box<dyn SamplingPolicy>,
    queues: Vec<VecDeque<Task>>,
    heap: BinaryHeap<Event>,
    seq: u64,
    pub now: f64,
    pub step: u64,
    busy_count: usize,
    /// reusable queue-length scratch for policy observation
    lens_buf: Vec<u32>,
    /// open-network lifecycle state (None = closed network)
    churn: Option<ChurnRuntime>,
}

/// What happened at one CS step (completion + routing of a fresh task).
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// node J_k that completed
    pub completed_node: u32,
    /// completed task's dispatch step (the paper's I_k)
    pub dispatch_step: u64,
    /// node K_{k+1} that received the new task
    pub next_node: u32,
    /// virtual time of this step
    pub time: f64,
    /// full record for the completed task
    pub record: TaskRecord,
}

impl Network {
    /// Fixed-p engine: wraps `cfg.p` in a [`StaticPolicy`] — the same
    /// dynamics as an explicit static policy (same alias table, same
    /// streams).  Note: the engine refactor re-keyed service durations by
    /// (node, service count), so same-seed traces differ from pre-engine
    /// releases; what is guaranteed is bit-identity across engines, shard
    /// counts, and thread counts on a shared seed.
    pub fn new(cfg: SimConfig) -> Result<Network, String> {
        let policy = Box::new(StaticPolicy::new(cfg.p.clone())?);
        Network::with_policy(cfg, policy)
    }

    /// Engine with an arbitrary (possibly adaptive) sampling policy.  The
    /// policy is consulted at every routing step; `cfg.p` remains the
    /// reference distribution used for validation.
    pub fn with_policy(
        cfg: SimConfig,
        mut policy: Box<dyn SamplingPolicy>,
    ) -> Result<Network, String> {
        cfg.validate()?;
        let n = cfg.p.len();
        if policy.n() != n {
            return Err(format!(
                "policy '{}' covers {} nodes but the network has {n}",
                policy.name(),
                policy.n()
            ));
        }
        let mut route_rng = Rng::new(cfg.seed).derive(ROUTE_STREAM);
        let churn = cfg.churn.as_ref().map(|c| ChurnRuntime::new(c, cfg.seed, n));
        // Initially-departed nodes are masked out of the policy BEFORE the
        // initial placements are drawn, so S_0 routes only over the live
        // membership — every engine performs this identical call sequence.
        if let Some(rt) = &churn {
            #[cfg(debug_assertions)]
            let route_fp = route_rng.state_fingerprint();
            for i in 0..n {
                if rt.departed[i] {
                    policy.observe_leave(i);
                }
            }
            #[cfg(debug_assertions)]
            debug_assert_eq!(
                route_fp,
                route_rng.state_fingerprint(),
                "observe_leave moved the routing stream (policy '{}')",
                policy.name()
            );
        }
        // initial placement S_0 — (node, selection probability) pairs,
        // shared with the sharded engine so routing streams decompose
        // identically
        let placements = initial_placements(&cfg, policy.as_mut(), &mut route_rng);
        let svc_seed = service_seed(cfg.seed);
        let cap = cfg.effective_pool_capacity();
        // Pre-size the hot-loop containers: the heap holds at most one
        // completion per busy node (advance pops before it pushes, so
        // occupancy never exceeds min(n, C) + 1), and a queue at most the
        // full population.  The per-queue reserve is gated so huge n·C
        // cells don't pay O(n·C) resident memory for a bound a run never
        // approaches; within the gate the steady-state step allocates
        // nothing (tests/hot_path_alloc.rs).
        let mut queues = vec![VecDeque::new(); n];
        if n.saturating_mul(cap) <= (1 << 22) {
            for q in &mut queues {
                q.reserve(cap);
            }
        }
        let mut net = Network {
            queues,
            heap: BinaryHeap::with_capacity(n.min(cap) + 1),
            seq: 0,
            now: 0.0,
            step: 0,
            busy_count: 0,
            svc_seed,
            svc_count: vec![0; n],
            policy,
            cfg,
            route_rng,
            lens_buf: Vec::with_capacity(n),
            churn,
        };
        for (placed, (node, prob)) in placements.into_iter().enumerate() {
            // mirror the flat-pool engines' capacity check so a mis-sized
            // scenario errors identically no matter which engine runs it
            if placed >= cap {
                return Err(EngineError::PoolExhausted { node, capacity: cap }.to_string());
            }
            net.arrive(node as u32, 0, 0.0, prob);
        }
        // incremental policies only ever hear about queues that change, so
        // sync them once with the realized initial state S_0 (idempotent
        // for the Routed path, which already observed each placement)
        if net.policy.incremental() {
            for i in 0..n {
                net.policy.observe_node(i, net.queues[i].len() as u32);
            }
        }
        Ok(net)
    }

    fn arrive(&mut self, node: u32, dispatch_step: u64, t: f64, dispatch_prob: f64) {
        self.queues[node as usize].push_back(Task {
            dispatch_step,
            dispatch_time: t,
            dispatch_prob,
        });
        // a stalled node accepts tasks but does not serve them; its
        // service is (re)scheduled by the Rejoin event
        let stalled = self.churn.as_ref().is_some_and(|c| c.stalled[node as usize]);
        if self.queues[node as usize].len() == 1 && !stalled {
            self.busy_count += 1;
            self.schedule_service(node, t);
        }
    }

    fn schedule_service(&mut self, node: u32, t: f64) {
        let count = self.svc_count[node as usize];
        self.svc_count[node as usize] = count + 1;
        let dur = service_duration(self.svc_seed, &self.cfg.service[node as usize], node, count);
        // markov-modulated rate: the scale multiplies the *duration*;
        // `x * 1.0` is IEEE-exact, so the no-churn trace is unchanged
        let scale = self.churn.as_ref().map_or(1.0, |c| c.rate_scale[node as usize]);
        self.seq += 1;
        if let Some(rt) = &mut self.churn {
            rt.pending_seq[node as usize] = self.seq;
        }
        self.heap.push(Event { time: t + dur * scale, seq: self.seq, node });
    }

    /// Number of busy nodes right now (for τ_c).
    pub fn busy_nodes(&self) -> usize {
        self.busy_count
    }

    pub fn queue_len(&self, i: usize) -> usize {
        self.queues[i].len()
    }

    /// Name of the routing policy in force.
    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    /// The routing distribution currently in force (time-varying for
    /// adaptive policies).  O(n) — diagnostics only.
    pub fn current_probs(&self) -> Vec<f64> {
        self.policy.probs()
    }

    /// Pop the next *valid* completion, applying every lifecycle event
    /// that precedes it (churn-first at timestamp ties, schedule order at
    /// equal times).  Shared prelude contract of all three engines.
    fn next_completion(&mut self) -> Option<Event> {
        if self.churn.is_none() {
            return self.heap.pop();
        }
        if let Some(rt) = &mut self.churn {
            rt.log.clear();
        }
        loop {
            // lazy cancellation: drop calendar fronts whose seq a stall /
            // leave / reschedule invalidated
            loop {
                let stale = match self.heap.peek() {
                    Some(front) => {
                        let rt = self.churn.as_ref().unwrap();
                        !rt.is_live(front.node, front.seq)
                    }
                    None => false,
                };
                if !stale {
                    break;
                }
                self.heap.pop();
            }
            let tcomp = self.heap.peek().map_or(f64::INFINITY, |e| e.time);
            let tchurn = self.churn.as_ref().unwrap().next_time();
            if tchurn <= tcomp && tchurn.is_finite() {
                let ev = self.churn.as_mut().unwrap().pop().unwrap();
                self.now = tchurn;
                self.apply_churn(ev);
                continue;
            }
            let ev = self.heap.pop()?;
            self.churn.as_mut().unwrap().pending_seq[ev.node as usize] = 0;
            return Some(ev);
        }
    }

    /// Apply one lifecycle event at its timestamp.
    fn apply_churn(&mut self, ev: ChurnEvent) {
        let t = ev.time;
        match ev.kind {
            ChurnEventKind::Join { node } => {
                let rt = self.churn.as_mut().unwrap();
                rt.departed[node as usize] = false;
                rt.stalled[node as usize] = false;
                rt.rate_scale[node as usize] = 1.0;
                // svc_count is NOT reset: service-duration keys must stay
                // unique across a slot's successive tenancies
                #[cfg(debug_assertions)]
                let route_fp = self.route_rng.state_fingerprint();
                self.policy.observe_join(node as usize);
                #[cfg(debug_assertions)]
                debug_assert_eq!(
                    route_fp,
                    self.route_rng.state_fingerprint(),
                    "observe_join moved the routing stream (policy '{}')",
                    self.policy.name()
                );
            }
            ChurnEventKind::Leave { node } => self.apply_leave(node, t),
            ChurnEventKind::Stall { node } => {
                let rt = self.churn.as_mut().unwrap();
                rt.stalled[node as usize] = true;
                // cancel the in-flight completion; the queue freezes
                rt.pending_seq[node as usize] = 0;
                if !self.queues[node as usize].is_empty() {
                    self.busy_count -= 1;
                }
            }
            ChurnEventKind::Rejoin { node } => {
                self.churn.as_mut().unwrap().stalled[node as usize] = false;
                if !self.queues[node as usize].is_empty() {
                    self.busy_count += 1;
                    self.schedule_service(node, t);
                }
            }
            ChurnEventKind::SetRate { node, scale } => {
                self.churn.as_mut().unwrap().rate_scale[node as usize] = scale;
            }
        }
    }

    /// A member departs: mask it from the policy, then re-route its queued
    /// tasks one at a time, each keeping its original dispatch identity
    /// (step, time, prob) — a hand-off, not a new dispatch.
    fn apply_leave(&mut self, node: u32, t: f64) {
        let ni = node as usize;
        {
            let rt = self.churn.as_mut().unwrap();
            rt.pending_seq[ni] = 0;
            if !self.queues[ni].is_empty() && !rt.stalled[ni] {
                self.busy_count -= 1;
            }
            rt.departed[ni] = true;
            rt.stalled[ni] = false;
        }
        #[cfg(debug_assertions)]
        let route_fp = self.route_rng.state_fingerprint();
        self.policy.observe_leave(ni);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            self.route_rng.state_fingerprint(),
            "observe_leave moved the routing stream (policy '{}')",
            self.policy.name()
        );
        let incremental = self.policy.incremental();
        while let Some(task) = self.queues[ni].pop_front() {
            if !incremental {
                self.lens_buf.clear();
                self.lens_buf.extend(self.queues.iter().map(|q| q.len() as u32));
                self.policy.observe(&self.lens_buf);
            }
            let dest = self.policy.route(&mut self.route_rng) as u32;
            self.queues[dest as usize].push_back(task);
            let dlen = self.queues[dest as usize].len() as u32;
            let dest_stalled = self.churn.as_ref().unwrap().stalled[dest as usize];
            if dlen == 1 && !dest_stalled {
                self.busy_count += 1;
                self.schedule_service(dest, t);
            }
            if incremental {
                self.policy.observe_node(dest as usize, dlen);
            }
            self.churn.as_mut().unwrap().log.push((t, dest, dlen));
        }
        self.churn.as_mut().unwrap().log.push((t, node, 0));
    }

    /// Advance one CS step: pop the next valid completion — applying any
    /// lifecycle events that precede it — and route a replacement.
    /// Returns None only when the calendar and the churn schedule are both
    /// exhausted (cannot happen with C >= 1: some live node always serves).
    pub fn advance(&mut self) -> Option<StepOutcome> {
        let ev = self.next_completion()?;
        self.now = ev.time;
        let node = ev.node;
        let task = self.queues[node as usize]
            .pop_front()
            .expect("completion event for empty queue");
        if self.queues[node as usize].is_empty() {
            self.busy_count -= 1;
        } else {
            self.schedule_service(node, self.now);
        }
        let record = TaskRecord {
            node,
            dispatch_step: task.dispatch_step,
            complete_step: self.step,
            dispatch_time: task.dispatch_time,
            complete_time: self.now,
            dispatch_prob: task.dispatch_prob,
        };
        // delay-feedback channel: report the completed task's observed
        // delay BEFORE the routing decision it may influence.  The hook
        // consumes no RNG, so the engines' bit-identity contract is
        // untouched; call order is part of that contract (every engine
        // observes the identical completion right here).  Debug builds
        // assert the no-RNG half at runtime (complement of lint rule R1).
        #[cfg(debug_assertions)]
        let route_fp = self.route_rng.state_fingerprint();
        self.policy.observe_completion(
            node as usize,
            record.delay_steps(),
            record.complete_time - record.dispatch_time,
        );
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            self.route_rng.state_fingerprint(),
            "observe_completion moved the routing stream (policy '{}')",
            self.policy.name()
        );
        // dispatcher: consult the sampling policy, select K_{k+1}, and send
        // the new model.  Incremental policies get only the two queue
        // lengths that changed (the pop above and the arrival below), so a
        // dispatch costs O(log n) instead of O(n).
        let incremental = self.policy.incremental();
        if incremental {
            self.policy
                .observe_node(node as usize, self.queues[node as usize].len() as u32);
        } else {
            self.lens_buf.clear();
            self.lens_buf.extend(self.queues.iter().map(|q| q.len() as u32));
            self.policy.observe(&self.lens_buf);
        }
        let next = self.policy.route(&mut self.route_rng) as u32;
        let next_prob = self.policy.prob_of(next as usize);
        let next_dispatch_step = self.step + 1;
        self.arrive(next, next_dispatch_step, self.now, next_prob);
        if incremental {
            self.policy
                .observe_node(next as usize, self.queues[next as usize].len() as u32);
        }
        let outcome = StepOutcome {
            completed_node: node,
            dispatch_step: task.dispatch_step,
            next_node: next,
            time: self.now,
            record,
        };
        self.step += 1;
        Some(outcome)
    }

    /// Total tasks currently in the network (must equal C always).
    pub fn population(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }
}

impl EventEngine for Network {
    fn advance(&mut self) -> Option<StepOutcome> {
        Network::advance(self)
    }

    fn queue_len(&self, i: usize) -> usize {
        Network::queue_len(self, i)
    }

    fn busy_nodes(&self) -> usize {
        Network::busy_nodes(self)
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn population(&self) -> usize {
        Network::population(self)
    }

    fn policy_name(&self) -> String {
        Network::policy_name(self)
    }

    fn churn_deltas(&self) -> &[(f64, u32, u32)] {
        match &self.churn {
            Some(rt) => &rt.log,
            None => &[],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::engine::{run, transient_mi};
    use crate::simulator::service::ServiceFamily;

    fn two_cluster_cfg(
        n: usize,
        n_fast: usize,
        mu_f: f64,
        mu_s: f64,
        c: usize,
        steps: u64,
    ) -> SimConfig {
        let rates: Vec<f64> = (0..n).map(|i| if i < n_fast { mu_f } else { mu_s }).collect();
        SimConfig::new(
            vec![1.0 / n as f64; n],
            ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
            c,
            steps,
        )
    }

    #[test]
    fn validates_config() {
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        assert!(cfg.validate().is_ok());
        cfg.concurrency = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 5, 10);
        cfg.init = InitPlacement::OnePerNode;
        assert!(cfg.validate().is_err());
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        cfg.p[0] = 0.9;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn zero_p_on_active_node_rejected() {
        // GenAsync divides by n·p_i — a zero-probability node with positive
        // service rate must be a config error, not a NaN factory
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        cfg.p = vec![0.0, 0.4, 0.3, 0.3];
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("p[0]"), "{err}");
        assert!(err.contains("service rate"), "{err}");
        // negative / non-finite entries are rejected too
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        cfg.p = vec![-0.1, 0.5, 0.3, 0.3];
        assert!(cfg.validate().is_err());
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        cfg.p = vec![f64::NAN, 0.4, 0.3, 0.3];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn adaptive_policy_conserves_population_and_records_probs() {
        use crate::coordinator::policy::AdaptiveQueuePolicy;
        let mut cfg = two_cluster_cfg(6, 3, 2.0, 1.0, 8, 0);
        cfg.seed = 17;
        let policy = AdaptiveQueuePolicy::new(cfg.p.clone(), 0.7).unwrap();
        let mut net = Network::with_policy(cfg, Box::new(policy)).unwrap();
        for _ in 0..2000 {
            let out = net.advance().unwrap();
            assert_eq!(net.population(), 8);
            let dp = out.record.dispatch_prob;
            assert!(dp > 0.0 && dp <= 1.0, "dispatch prob {dp}");
        }
        let sum: f64 = net.current_probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn static_policy_matches_plain_network() {
        // Network::new and an explicit StaticPolicy must generate the
        // identical event stream (same RNG consumption)
        use crate::coordinator::policy::StaticPolicy;
        let mut cfg = two_cluster_cfg(6, 3, 2.0, 1.0, 6, 300);
        cfg.seed = 23;
        cfg.record_tasks = true;
        let a = run(cfg.clone()).unwrap();
        let policy = StaticPolicy::new(cfg.p.clone()).unwrap();
        let mut net = Network::with_policy(cfg, Box::new(policy)).unwrap();
        for rec in &a.tasks {
            let out = net.advance().unwrap();
            assert_eq!(out.record.node, rec.node);
            assert_eq!(out.record.dispatch_step, rec.dispatch_step);
            assert_eq!(out.record.complete_time.to_bits(), rec.complete_time.to_bits());
        }
    }

    #[test]
    fn population_is_conserved() {
        let cfg = two_cluster_cfg(5, 2, 3.0, 1.0, 7, 0);
        let mut net = Network::new(cfg).unwrap();
        assert_eq!(net.population(), 7);
        for _ in 0..500 {
            net.advance().unwrap();
            assert_eq!(net.population(), 7);
        }
    }

    #[test]
    fn determinism_same_seed() {
        let mut cfg = two_cluster_cfg(6, 3, 2.0, 1.0, 6, 200);
        cfg.seed = 99;
        cfg.record_tasks = true;
        let a = run(cfg.clone()).unwrap();
        let b = run(cfg).unwrap();
        assert_eq!(a.tau_max, b.tau_max);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.delay_steps(), y.delay_steps());
            assert_eq!(x.node, y.node);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg = two_cluster_cfg(6, 3, 2.0, 1.0, 6, 500);
        cfg.seed = 1;
        let a = run(cfg.clone()).unwrap();
        cfg.seed = 2;
        let b = run(cfg).unwrap();
        assert_ne!(a.total_time.to_bits(), b.total_time.to_bits());
    }

    #[test]
    fn dispatch_frequencies_match_p() {
        let n = 4;
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let rates = vec![5.0; n];
        let cfg = SimConfig {
            seed: 3,
            ..SimConfig::new(
                p.clone(),
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                8,
                100_000,
            )
        };
        let res = run(cfg).unwrap();
        let total: u64 = res.dispatches.iter().sum();
        for i in 0..n {
            let f = res.dispatches[i] as f64 / total as f64;
            assert!((f - p[i]).abs() < 0.01, "node {i}: freq {f} vs p {}", p[i]);
        }
    }

    #[test]
    fn completion_rates_match_visit_ratios_long_run() {
        // flow balance: completions per node ∝ p_i (each dispatched task
        // eventually completes exactly once)
        let p = vec![0.5, 0.5];
        let cfg = SimConfig {
            seed: 4,
            ..SimConfig::new(
                vec![0.5, 0.5],
                ServiceDist::from_rates(&[4.0, 1.0], ServiceFamily::Exponential),
                6,
                200_000,
            )
        };
        let res = run(cfg).unwrap();
        let total: u64 = res.completions.iter().sum();
        for i in 0..2 {
            let f = res.completions[i] as f64 / total as f64;
            assert!((f - p[i]).abs() < 0.01, "node {i} completion share {f}");
        }
    }

    #[test]
    fn mean_queue_matches_jackson_theory() {
        use crate::queueing::ClosedNetwork;
        let n = 4;
        let p = vec![0.25; 4];
        let rates = vec![1.5, 1.5, 0.75, 0.75];
        let cfg = SimConfig {
            seed: 5,
            ..SimConfig::new(
                p.clone(),
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                10,
                300_000,
            )
        };
        let res = run(cfg).unwrap();
        let net = ClosedNetwork::new(p, rates).unwrap();
        let b = net.buzen(10);
        for i in 0..n {
            let theory = b.mean_queue(i, 10);
            let sim = res.mean_queue[i];
            assert!(
                (sim - theory).abs() < 0.15,
                "node {i}: sim {sim} vs theory {theory}"
            );
        }
    }

    #[test]
    fn delays_scale_with_cluster_speed() {
        let cfg = SimConfig {
            seed: 6,
            ..two_cluster_cfg(10, 5, 1.2, 1.0, 200, 100_000)
        };
        let res = run(cfg).unwrap();
        let fast = res.cluster_delay(0..5);
        let slow = res.cluster_delay(5..10);
        assert!(slow > 3.0 * fast, "slow {slow} vs fast {fast}");
        // average delays well below τ_max (the paper's headline point)
        assert!((res.tau_max as f64) > 2.0 * slow);
    }

    #[test]
    fn deterministic_service_works() {
        let rates = vec![2.0, 1.0];
        let cfg = SimConfig {
            seed: 7,
            ..SimConfig::new(
                vec![0.5, 0.5],
                ServiceDist::from_rates(&rates, ServiceFamily::Deterministic),
                4,
                10_000,
            )
        };
        let res = run(cfg).unwrap();
        assert!(res.total_time > 0.0);
        assert_eq!(res.completions.iter().sum::<u64>(), 10_000);
    }

    #[test]
    fn tau_c_bounded_by_min_n_c() {
        let cfg = SimConfig {
            seed: 8,
            ..two_cluster_cfg(10, 5, 1.0, 1.0, 3, 20_000)
        };
        let res = run(cfg).unwrap();
        assert!(res.tau_c > 0.0 && res.tau_c <= 3.0, "tau_c={}", res.tau_c);
    }

    #[test]
    fn single_node_single_task_delay_zero() {
        // C=1, n=1: every task completes before the next is dispatched:
        // delay = complete_step - dispatch_step = 0 each time
        let cfg = SimConfig::new(
            vec![1.0],
            vec![ServiceDist::Exp { rate: 1.0 }],
            1,
            1000,
        );
        let res = run(cfg).unwrap();
        assert_eq!(res.tau_max, 0);
        assert_eq!(res.delay_steps[0].mean(), 0.0);
    }

    #[test]
    fn transient_mi_stabilizes() {
        // Fig 1: m_{1,k} becomes stationary after a burn-in (~k > 50 for
        // n=10).  Check the two halves of the late window agree.
        let mut cfg = two_cluster_cfg(10, 5, 10.0, 1.0, 10, 300);
        cfg.init = InitPlacement::OnePerNode;
        let series = transient_mi(&cfg, 1, 400).unwrap();
        let window_mean = |lo: usize, hi: usize| -> f64 {
            let vals: Vec<f64> = series[lo..hi]
                .iter()
                .filter(|s| s.2 > 0)
                .map(|s| s.1)
                .collect();
            vals.iter().sum::<f64>() / vals.len().max(1) as f64
        };
        let a = window_mean(150, 215);
        let b = window_mean(215, 280);
        assert!(a.is_finite() && b.is_finite());
        assert!(
            (a - b).abs() < 0.35 * a.max(b),
            "late windows disagree: {a} vs {b}"
        );
    }

    #[test]
    fn queue_sampling_records() {
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 1000);
        cfg.queue_sample_every = 100;
        let res = run(cfg).unwrap();
        // k = 0 (the pre-step initial state) plus one sample per 100 steps
        assert_eq!(res.queue_samples.len(), 11);
        assert_eq!(res.queue_samples[0].0, 0, "first sample is the t = 0 state");
        assert_eq!(res.queue_samples.last().unwrap().0, 1000);
        for (k, (step, qs)) in res.queue_samples.iter().enumerate() {
            assert_eq!(*step, 100 * k as u64);
            assert_eq!(qs.iter().map(|&x| x as usize).sum::<usize>(), 4);
        }
    }

    #[test]
    fn zero_step_horizon_yields_defined_zeros() {
        // horizon shorter than the first completion: steps = 0 must give
        // well-defined zeros, never a 0/0 NaN (satellite of the churn PR)
        let cfg = SimConfig::new(vec![1.0], vec![ServiceDist::Exp { rate: 1.0 }], 1, 0);
        let res = run(cfg).unwrap();
        assert_eq!(res.completions.iter().sum::<u64>(), 0);
        assert_eq!(res.total_time, 0.0);
        assert_eq!(res.step_rate(0), 0.0, "0 steps / 0 time must be 0, not NaN");
        assert_eq!(res.cluster_delay(0..1), 0.0, "empty delay merge must be 0, not NaN");
        assert!(res.tau_c.is_finite());
        assert!(res.mean_queue[0].is_finite());
    }

    #[test]
    fn undersized_pool_capacity_is_a_typed_error() {
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        cfg.pool_capacity = 3;
        let err = Network::new(cfg).unwrap_err();
        assert!(err.contains("task pool exhausted"), "{err}");
        assert!(err.contains("capacity 3"), "{err}");
    }

    fn churny(initial_active: usize) -> ChurnConfig {
        ChurnConfig {
            arrival_rate: 0.6,
            mean_lifetime: 3.0,
            stall_rate: 0.4,
            mean_stall: 0.5,
            rate_change_rate: 0.5,
            rate_factor_min: 0.5,
            rate_factor_max: 2.0,
            initial_active,
            max_events: 300,
        }
    }

    #[test]
    fn churn_conserves_population_and_empties_departed_queues() {
        let mut cfg = two_cluster_cfg(6, 3, 2.0, 1.0, 8, 0);
        cfg.seed = 21;
        cfg.churn = Some(churny(4));
        let mut net = Network::new(cfg).unwrap();
        for _ in 0..4000 {
            let out = net.advance().unwrap();
            assert_eq!(net.population(), 8, "churn must conserve the C tasks");
            let rt = net.churn.as_ref().unwrap();
            assert!(
                !rt.departed[out.next_node as usize],
                "dispatched to departed node {}",
                out.next_node
            );
            for i in 0..6 {
                if rt.departed[i] {
                    assert_eq!(net.queue_len(i), 0, "departed node {i} still holds tasks");
                }
            }
        }
    }

    #[test]
    fn quiet_churn_leaves_the_trace_bit_identical() {
        // an enabled-but-eventless [churn] block must not perturb a single
        // draw: rate scale 1.0 multiplies exactly, pending-seq bookkeeping
        // consumes nothing
        let mut cfg = two_cluster_cfg(6, 3, 2.0, 1.0, 6, 300);
        cfg.seed = 23;
        cfg.record_tasks = true;
        let base = run(cfg.clone()).unwrap();
        cfg.churn = Some(ChurnConfig::default());
        let churned = run(cfg).unwrap();
        assert_eq!(base.tasks.len(), churned.tasks.len());
        for (a, b) in base.tasks.iter().zip(&churned.tasks) {
            assert_eq!(a.node, b.node);
            assert_eq!(a.complete_time.to_bits(), b.complete_time.to_bits());
        }
    }

    #[test]
    fn one_per_node_with_partial_membership_is_rejected() {
        let mut cfg = two_cluster_cfg(4, 2, 1.0, 1.0, 4, 10);
        cfg.init = InitPlacement::OnePerNode;
        cfg.churn = Some(ChurnConfig {
            initial_active: 3,
            ..ChurnConfig::default()
        });
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("OnePerNode"), "{err}");
    }

    #[test]
    fn self_route_double_flush_keeps_time_averages_exact() {
        // n = 1 forces completed == dispatch target on EVERY step, so the
        // aggregator flushes the same node twice per step at the same
        // timestamp.  The second flush must contribute zero area: the
        // time-weighted mean queue stays exactly C, and every sample —
        // including the pre-step k = 0 snapshot — shows all C tasks.
        let mut cfg = SimConfig::new(vec![1.0], vec![ServiceDist::Exp { rate: 2.0 }], 3, 500);
        cfg.queue_sample_every = 50;
        let res = run(cfg).unwrap();
        assert!(
            (res.mean_queue[0] - 3.0).abs() < 1e-9,
            "mean queue {} must equal C = 3",
            res.mean_queue[0]
        );
        assert_eq!(res.queue_samples.len(), 11);
        assert_eq!(res.queue_samples[0], (0, vec![3u32]));
        for (_, qs) in &res.queue_samples {
            assert_eq!(qs[0], 3);
        }
        assert_eq!(res.completions[0], 500);
        assert_eq!(res.dispatches[0], 500);
    }
}
