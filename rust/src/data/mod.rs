//! Data pipeline: synthetic dataset generation (`synth`), non-iid client
//! partitioning (`partition`), and batch loading with augmentation
//! (`loader`).

pub mod loader;
pub mod partition;
pub mod synth;

pub use loader::{Batch, ClientLoader, EvalBatches};
pub use partition::{Partition, PartitionScheme};
pub use synth::{generate, Dataset, SynthSpec};
