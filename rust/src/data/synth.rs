//! Synthetic image-classification datasets (CIFAR-10 / TinyImageNet
//! substitutes — no dataset downloads in this offline environment; see
//! DESIGN.md §Substitutions for why this preserves the experiments).
//!
//! Construction: each class gets a deterministic template built from a
//! class-specific mixture of 2-D sinusoidal gratings (frequency,
//! orientation, phase, per-channel gain all derived from the class index)
//! — loosely "textures".  A sample is its class template, randomly
//! translated (toroidally), scaled by a random contrast, plus white noise.
//! The task is learnable by an MLP (templates are linearly separable at
//! high SNR; noise + shifts make it non-trivial) and completely
//! reproducible from the seed.

use crate::util::rng::Rng;

/// Stream id for dataset generation draws (R6: named so collisions with
/// other streams are auditable crate-wide).
const SYNTH_STREAM: u64 = 0xDA7A;

/// A dense dataset of flattened images.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// flattened row-major samples, len = n * dim
    pub x: Vec<f32>,
    /// labels in [0, classes)
    pub y: Vec<u16>,
    pub dim: usize,
    pub classes: usize,
    /// image geometry (height, width, channels); dim = h*w*ch
    pub height: usize,
    pub width: usize,
    pub channels: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }
}

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    /// number of gratings per class template
    pub gratings: usize,
    /// additive noise std (signal is ~unit RMS)
    pub noise: f64,
}

impl SynthSpec {
    /// CIFAR-10-like: 32x32x3, 10 classes.  The noise level is calibrated
    /// so that the paper's 200-CS-step protocol lands mid-training (the
    /// regime where the async algorithms separate, as in Fig 6) instead of
    /// saturating — the class templates stay asymptotically separable.
    pub fn cifar_like() -> Self {
        SynthSpec { height: 32, width: 32, channels: 3, classes: 10, gratings: 3, noise: 1.5 }
    }

    /// TinyImageNet-like: 64x64x3, 200 classes — the class count alone
    /// makes this hard at the Fig-7 step budget; keep noise moderate.
    pub fn tiny_imagenet_like() -> Self {
        SynthSpec { height: 64, width: 64, channels: 3, classes: 200, gratings: 4, noise: 1.0 }
    }

    /// Minimal 4x4x3 / 10-class variant for fast tests.
    pub fn tiny_test() -> Self {
        SynthSpec { height: 4, width: 4, channels: 3, classes: 10, gratings: 2, noise: 0.3 }
    }

    pub fn dim(&self) -> usize {
        self.height * self.width * self.channels
    }
}

/// One class's template generator (deterministic in (spec, class)).
fn class_template(spec: &SynthSpec, class: usize) -> Vec<f32> {
    let mut rng = Rng::new(0xC1A5_5000 + class as u64);
    let (h, w, ch) = (spec.height, spec.width, spec.channels);
    let mut tpl = vec![0.0f32; spec.dim()];
    for _ in 0..spec.gratings {
        let fx = rng.range_f64(0.5, 3.5); // cycles across the image
        let fy = rng.range_f64(0.5, 3.5);
        let phase = rng.range_f64(0.0, std::f64::consts::TAU);
        let gains: Vec<f64> = (0..ch).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        for yy in 0..h {
            for xx in 0..w {
                let v = (std::f64::consts::TAU
                    * (fx * xx as f64 / w as f64 + fy * yy as f64 / h as f64)
                    + phase)
                    .sin();
                for (cc, g) in gains.iter().enumerate() {
                    tpl[(yy * w + xx) * ch + cc] += (g * v) as f32;
                }
            }
        }
    }
    // normalize template to unit RMS
    let rms = (tpl.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
        / tpl.len() as f64)
        .sqrt()
        .max(1e-9);
    for v in tpl.iter_mut() {
        *v = (*v as f64 / rms) as f32;
    }
    tpl
}

/// Generate a dataset of `n` samples with balanced random classes.
pub fn generate(spec: &SynthSpec, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).derive(SYNTH_STREAM);
    let dim = spec.dim();
    let templates: Vec<Vec<f32>> = (0..spec.classes).map(|c| class_template(spec, c)).collect();
    let (h, w, ch) = (spec.height, spec.width, spec.channels);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.usize_below(spec.classes) as u16;
        let tpl = &templates[class as usize];
        let dy = rng.usize_below(h);
        let dx = rng.usize_below(w);
        let contrast = rng.range_f64(0.7, 1.3);
        for yy in 0..h {
            let sy = (yy + dy) % h;
            for xx in 0..w {
                let sx = (xx + dx) % w;
                for cc in 0..ch {
                    let sig = tpl[(sy * w + sx) * ch + cc] as f64 * contrast;
                    let noise = rng.normal() * spec.noise;
                    x.push((sig + noise) as f32);
                }
            }
        }
        y.push(class);
    }
    Dataset { x, y, dim, classes: spec.classes, height: h, width: w, channels: ch }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = SynthSpec::tiny_test();
        let a = generate(&spec, 50, 7);
        let b = generate(&spec, 50, 7);
        assert_eq!(a.len(), 50);
        assert_eq!(a.dim, 48);
        assert_eq!(a.x.len(), 50 * 48);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&spec, 50, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn labels_in_range_and_diverse() {
        let spec = SynthSpec::tiny_test();
        let d = generate(&spec, 500, 1);
        assert!(d.y.iter().all(|&l| (l as usize) < spec.classes));
        let mut seen = vec![false; spec.classes];
        for &l in &d.y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all classes should appear in 500 draws");
    }

    #[test]
    fn templates_are_class_distinct() {
        let spec = SynthSpec::cifar_like();
        let t0 = class_template(&spec, 0);
        let t1 = class_template(&spec, 1);
        let dot: f64 = t0
            .iter()
            .zip(&t1)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum::<f64>()
            / t0.len() as f64;
        // near-orthogonal random gratings
        assert!(dot.abs() < 0.4, "templates too correlated: {dot}");
    }

    #[test]
    fn signal_to_noise_reasonable() {
        let spec = SynthSpec::cifar_like();
        let d = generate(&spec, 20, 3);
        // per-pixel variance ≈ signal (≈1·contrast²) + noise² (6.25)
        let var: f64 = d.x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
            / d.x.len() as f64;
        let expect = 1.0 + spec.noise * spec.noise;
        assert!(
            var > 0.6 * expect && var < 1.6 * expect,
            "var={var}, expect≈{expect}"
        );
    }

    #[test]
    fn nearest_template_classifies_most_samples() {
        // sanity: the task must be learnable — a correlation classifier
        // against the (untranslated) templates should beat chance by a lot
        let spec = SynthSpec::tiny_test();
        let d = generate(&spec, 300, 5);
        let templates: Vec<Vec<f32>> =
            (0..spec.classes).map(|c| class_template(&spec, c)).collect();
        let mut correct = 0;
        for i in 0..d.len() {
            let s = d.sample(i);
            // max correlation over all toroidal shifts of the template is
            // expensive; use magnitude-spectrum-free proxy: best of a few
            // shifts — enough to beat chance
            let mut best = (f64::MIN, 0usize);
            for (c, t) in templates.iter().enumerate() {
                for dy in 0..spec.height {
                    for dx in 0..spec.width {
                        let mut dot = 0.0f64;
                        for yy in 0..spec.height {
                            for xx in 0..spec.width {
                                let sy = (yy + dy) % spec.height;
                                let sx = (xx + dx) % spec.width;
                                for cc in 0..spec.channels {
                                    dot += s[(yy * spec.width + xx) * spec.channels + cc] as f64
                                        * t[(sy * spec.width + sx) * spec.channels + cc] as f64;
                                }
                            }
                        }
                        if dot > best.0 {
                            best = (dot, c);
                        }
                    }
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.5, "template-matching accuracy {acc} should be >> 0.1 chance");
    }
}
