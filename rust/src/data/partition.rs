//! Client data partitioning — the paper's statistical-heterogeneity setup.
//!
//! CIFAR experiment (§5): "each client takes seven classes (out of the ten
//! possible) without replacement" — every client holds a class subset of
//! size `classes_per_client`; each training sample is assigned to a client
//! that holds its class (uniformly among them).  TinyImageNet uses IID.

use super::synth::Dataset;
use crate::util::rng::Rng;

/// Stream id for shard assignment draws (R6: named so collisions with
/// other streams are auditable crate-wide).
const PARTITION_STREAM: u64 = 0x9A47;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PartitionScheme {
    Iid,
    /// each client draws `classes_per_client` distinct classes
    ClassSubset { classes_per_client: usize },
}

/// Per-client view: indices into the shared dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Vec<u32>>,
    pub scheme: PartitionScheme,
}

impl Partition {
    pub fn build(
        data: &Dataset,
        n_clients: usize,
        scheme: PartitionScheme,
        seed: u64,
    ) -> Result<Partition, String> {
        if n_clients == 0 {
            return Err("need at least one client".into());
        }
        let mut rng = Rng::new(seed).derive(PARTITION_STREAM);
        let mut shards = vec![Vec::new(); n_clients];
        match scheme {
            PartitionScheme::Iid => {
                for i in 0..data.len() {
                    shards[rng.usize_below(n_clients)].push(i as u32);
                }
            }
            PartitionScheme::ClassSubset { classes_per_client } => {
                if classes_per_client == 0 || classes_per_client > data.classes {
                    return Err(format!(
                        "classes_per_client {classes_per_client} out of range 1..={}",
                        data.classes
                    ));
                }
                // each client picks its class subset without replacement
                let client_classes: Vec<Vec<usize>> = (0..n_clients)
                    .map(|_| rng.sample_distinct(data.classes, classes_per_client))
                    .collect();
                // invert: class -> clients holding it
                let mut holders: Vec<Vec<u32>> = vec![Vec::new(); data.classes];
                for (ci, classes) in client_classes.iter().enumerate() {
                    for &c in classes {
                        holders[c].push(ci as u32);
                    }
                }
                // a class nobody holds (possible for tiny n_clients): assign
                // round-robin fallback holders so no data is dropped
                for (c, h) in holders.iter_mut().enumerate() {
                    if h.is_empty() {
                        h.push((c % n_clients) as u32);
                    }
                }
                for i in 0..data.len() {
                    let class = data.y[i] as usize;
                    let h = &holders[class];
                    let client = h[rng.usize_below(h.len())];
                    shards[client as usize].push(i as u32);
                }
            }
        }
        Ok(Partition { shards, scheme })
    }

    pub fn n_clients(&self) -> usize {
        self.shards.len()
    }

    pub fn total_samples(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// Number of distinct classes present on a client.
    pub fn client_classes(&self, data: &Dataset, client: usize) -> usize {
        let mut seen = vec![false; data.classes];
        for &i in &self.shards[client] {
            seen[data.y[i as usize] as usize] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn data() -> Dataset {
        generate(&SynthSpec::tiny_test(), 2000, 42)
    }

    #[test]
    fn iid_partition_covers_everything() {
        let d = data();
        let p = Partition::build(&d, 10, PartitionScheme::Iid, 1).unwrap();
        assert_eq!(p.total_samples(), 2000);
        let mut seen = vec![false; 2000];
        for s in &p.shards {
            for &i in s {
                assert!(!seen[i as usize], "sample assigned twice");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        // balanced within 4 sigma
        for s in &p.shards {
            assert!((s.len() as f64 - 200.0).abs() < 4.0 * (200.0f64 * 0.9).sqrt());
        }
    }

    #[test]
    fn class_subset_respects_subsets() {
        let d = data();
        let p = Partition::build(
            &d,
            20,
            PartitionScheme::ClassSubset { classes_per_client: 7 },
            3,
        )
        .unwrap();
        assert_eq!(p.total_samples(), 2000);
        for c in 0..20 {
            let k = p.client_classes(&d, c);
            assert!(k <= 7, "client {c} has {k} classes (> 7)");
        }
        // heterogeneity: clients differ in their class sets
        let distinct: std::collections::BTreeSet<Vec<u16>> = (0..20)
            .map(|c| {
                let mut classes: Vec<u16> =
                    p.shards[c].iter().map(|&i| d.y[i as usize]).collect();
                classes.sort_unstable();
                classes.dedup();
                classes
            })
            .collect();
        assert!(distinct.len() > 5, "class subsets suspiciously uniform");
    }

    #[test]
    fn deterministic_by_seed() {
        let d = data();
        let a = Partition::build(&d, 10, PartitionScheme::ClassSubset { classes_per_client: 7 }, 5)
            .unwrap();
        let b = Partition::build(&d, 10, PartitionScheme::ClassSubset { classes_per_client: 7 }, 5)
            .unwrap();
        assert_eq!(a.shards, b.shards);
        let c = Partition::build(&d, 10, PartitionScheme::ClassSubset { classes_per_client: 7 }, 6)
            .unwrap();
        assert_ne!(a.shards, c.shards);
    }

    #[test]
    fn rejects_bad_args() {
        let d = data();
        assert!(Partition::build(&d, 0, PartitionScheme::Iid, 1).is_err());
        assert!(Partition::build(
            &d,
            4,
            PartitionScheme::ClassSubset { classes_per_client: 0 },
            1
        )
        .is_err());
        assert!(Partition::build(
            &d,
            4,
            PartitionScheme::ClassSubset { classes_per_client: 11 },
            1
        )
        .is_err());
    }

    #[test]
    fn single_client_gets_all() {
        let d = data();
        let p = Partition::build(&d, 1, PartitionScheme::ClassSubset { classes_per_client: 7 }, 1)
            .unwrap();
        // fallback holders guarantee nothing is dropped even though the
        // single client only "holds" 7 of 10 classes
        assert_eq!(p.total_samples(), 2000);
    }
}
