//! Per-client batch loading: shuffled epochs over the client's shard,
//! horizontal-flip augmentation (the paper's "standard augmentation"),
//! one-hot label encoding — produces exactly the (x, onehot) tensors the
//! AOT-compiled train/eval steps expect.

use super::synth::Dataset;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Stream id for per-client batch shuffling (R6: named so collisions with
/// other streams are auditable crate-wide).
const LOADER_STREAM: u64 = 0x10AD;

/// A batch ready for the backend: flattened f32 tensors.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (batch, dim) row-major
    pub x: Vec<f32>,
    /// (batch, classes) one-hot
    pub onehot: Vec<f32>,
    pub batch: usize,
}

/// Cyclic shuffled sampler over one client's shard.
pub struct ClientLoader {
    data: Arc<Dataset>,
    indices: Vec<u32>,
    cursor: usize,
    rng: Rng,
    pub batch_size: usize,
    pub augment: bool,
}

impl ClientLoader {
    pub fn new(
        data: Arc<Dataset>,
        shard: Vec<u32>,
        batch_size: usize,
        augment: bool,
        seed: u64,
    ) -> Result<ClientLoader, String> {
        if batch_size == 0 {
            return Err("batch_size must be > 0".into());
        }
        if shard.is_empty() {
            return Err("client shard is empty".into());
        }
        let mut rng = Rng::new(seed).derive(LOADER_STREAM);
        let mut indices = shard;
        rng.shuffle(&mut indices);
        Ok(ClientLoader { data, indices, cursor: 0, rng, batch_size, augment })
    }

    /// Next batch; wraps with a reshuffle at epoch boundaries (samples may
    /// repeat within a batch if the shard is smaller than the batch).
    pub fn next_batch(&mut self) -> Batch {
        let d = &self.data;
        let mut x = Vec::with_capacity(self.batch_size * d.dim);
        let mut onehot = vec![0.0f32; self.batch_size * d.classes];
        for b in 0..self.batch_size {
            if self.cursor >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
            }
            let idx = self.indices[self.cursor] as usize;
            self.cursor += 1;
            let flip = self.augment && self.rng.uniform() < 0.5;
            push_sample(d, idx, flip, &mut x);
            onehot[b * d.classes + d.y[idx] as usize] = 1.0;
        }
        Batch { x, onehot, batch: self.batch_size }
    }
}

/// Append sample `idx` (optionally horizontally flipped) to `out`.
fn push_sample(d: &Dataset, idx: usize, flip: bool, out: &mut Vec<f32>) {
    let s = d.sample(idx);
    if !flip {
        out.extend_from_slice(s);
        return;
    }
    let (h, w, ch) = (d.height, d.width, d.channels);
    for yy in 0..h {
        for xx in 0..w {
            let sx = w - 1 - xx;
            let base = (yy * w + sx) * ch;
            out.extend_from_slice(&s[base..base + ch]);
        }
    }
}

/// Whole-set evaluation batches (no shuffle, no augmentation, padded by
/// repeating the last sample; `valid` counts real samples per batch).
pub struct EvalBatches {
    pub batches: Vec<(Batch, usize)>,
}

impl EvalBatches {
    pub fn new(data: &Dataset, batch_size: usize) -> EvalBatches {
        let mut batches = Vec::new();
        let mut i = 0;
        while i < data.len() {
            let valid = batch_size.min(data.len() - i);
            let mut x = Vec::with_capacity(batch_size * data.dim);
            let mut onehot = vec![0.0f32; batch_size * data.classes];
            for b in 0..batch_size {
                let idx = (i + b).min(data.len() - 1);
                push_sample(data, idx, false, &mut x);
                onehot[b * data.classes + data.y[idx] as usize] = 1.0;
            }
            batches.push((Batch { x, onehot, batch: batch_size }, valid));
            i += valid;
        }
        EvalBatches { batches }
    }

    pub fn total_valid(&self) -> usize {
        self.batches.iter().map(|(_, v)| v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn setup() -> (Arc<Dataset>, Vec<u32>) {
        let d = Arc::new(generate(&SynthSpec::tiny_test(), 100, 1));
        let shard: Vec<u32> = (0..50).collect();
        (d, shard)
    }

    #[test]
    fn batch_shapes() {
        let (d, shard) = setup();
        let mut l = ClientLoader::new(d.clone(), shard, 8, false, 7).unwrap();
        let b = l.next_batch();
        assert_eq!(b.x.len(), 8 * d.dim);
        assert_eq!(b.onehot.len(), 8 * d.classes);
        for r in 0..8 {
            let row = &b.onehot[r * d.classes..(r + 1) * d.classes];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn epoch_covers_shard() {
        let (d, shard) = setup();
        let mut l = ClientLoader::new(d, shard, 10, false, 7).unwrap();
        // 5 batches of 10 = one epoch over 50 distinct samples: every
        // sample appears exactly once — verified via x-row uniqueness
        let mut rows = std::collections::BTreeSet::new();
        for _ in 0..5 {
            let b = l.next_batch();
            for r in 0..10 {
                let row: Vec<u32> = b.x[r * 48..(r + 1) * 48]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                rows.insert(row);
            }
        }
        assert_eq!(rows.len(), 50);
    }

    #[test]
    fn flip_is_involutive_geometry() {
        let (d, _) = setup();
        let mut plain = Vec::new();
        push_sample(&d, 0, false, &mut plain);
        let mut flipped = Vec::new();
        push_sample(&d, 0, true, &mut flipped);
        assert_ne!(plain, flipped);
        // flipping the flipped reconstructs the original
        let tmp = Dataset {
            x: flipped.clone(),
            y: vec![0],
            dim: d.dim,
            classes: d.classes,
            height: d.height,
            width: d.width,
            channels: d.channels,
        };
        let mut back = Vec::new();
        push_sample(&tmp, 0, true, &mut back);
        assert_eq!(plain, back);
    }

    #[test]
    fn rejects_empty_shard_and_zero_batch() {
        let (d, shard) = setup();
        assert!(ClientLoader::new(d.clone(), vec![], 8, false, 1).is_err());
        assert!(ClientLoader::new(d, shard, 0, false, 1).is_err());
    }

    #[test]
    fn eval_batches_cover_exactly() {
        let (d, _) = setup();
        let ev = EvalBatches::new(&d, 32);
        assert_eq!(ev.total_valid(), 100);
        assert_eq!(ev.batches.len(), 4); // 32+32+32+4
        assert_eq!(ev.batches[3].1, 4);
        assert_eq!(ev.batches[3].0.x.len(), 32 * d.dim);
    }

    #[test]
    fn loader_deterministic_per_seed() {
        let (d, shard) = setup();
        let mut a = ClientLoader::new(d.clone(), shard.clone(), 8, true, 9).unwrap();
        let mut b = ClientLoader::new(d, shard, 8, true, 9).unwrap();
        assert_eq!(a.next_batch().x, b.next_batch().x);
    }
}
