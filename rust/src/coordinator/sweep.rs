//! Parallel multi-seed sweep engine — the ensemble layer over the
//! simulator and the experiment runner.
//!
//! The paper's claims are distributional: the closed Jackson network's
//! stationary queue lengths and the delay/complexity trade-off of
//! Theorem 1's non-uniform sampling only show up across many
//! replications.  A [`SweepSpec`] declares a grid of scenarios × policies
//! (× algorithms in train mode) × seeds in TOML
//! (`scenarios/sweep_fig6.toml` is the worked example); [`run_sweep`]
//! executes every replication across OS worker threads and reduces them
//! into per-cell Welford aggregates with 95% confidence intervals,
//! rendered as JSON for the figures layer
//! ([`crate::figures::sweep_figs`]).
//!
//! Determinism contract (tested in `tests/sweep_determinism.rs`): every
//! replication runs on its own RNG stream derived from
//! `stream_seed(base_seed, [cell_id, seed_index])`, workers write results
//! into a slot indexed by replication id, and the reduction walks slots
//! in (cell, seed) order — so the aggregated JSON (its deterministic
//! core; see [`SweepReport::to_json_deterministic`]) is bit-identical
//! regardless of thread count or scheduling order.  Engine choice never
//! perturbs results either: the heap and sharded simulator engines are
//! bit-identical on a shared seed, so the per-cell scheduler is free to
//! pick whichever runs fastest.
//!
//! Per-cell scheduling: the scheduler splits the worker budget between
//! *replication-level* and *shard-level* parallelism.  Cells with
//! `clients >= big_n` (default 100 000) are memory-bound — they run one
//! replication at a time on the sharded engine with the whole thread
//! budget inside the replication.  Smaller cells are construction-bound —
//! their seeds are packed into **batch arenas**
//! (`simulator::engine::batch`): chunks of R replications share one SoA
//! allocation and draw service durations in vectorized blocks, and the
//! chunks fan out across the worker pool.  `batch_width` fixes R; 0 (the
//! default) sizes chunks so every worker gets one while amortizing as much
//! construction as possible.  `engine = "heap"`, `"sharded"`, or
//! `"batch"` overrides the auto split.  None of this can move a number:
//! all three engines are bit-identical per replication on a shared seed.
//!
//! Each simulate replication also reports **perf metrics** (events/sec,
//! peak RSS) so BENCH trajectories capture scale, not just wall time.
//! They are timing-derived and live outside the deterministic JSON core.
//!
//! Grid TOML schema:
//!
//! ```toml
//! [sweep]
//! name = "fig6_sweep"        # report id
//! mode = "simulate"          # simulate | train
//! seeds = 8                  # replications per cell
//! base_seed = 42             # root of every replication stream
//! threads = 4                # worker threads (0 = one per core)
//! out = "results/sweep.json" # default output (CLI --out overrides)
//! engine = "auto"            # auto | heap | sharded | batch (per-cell scheduler)
//! shards = 0                 # sharded-engine shard count (0 = auto)
//! big_n = 100000             # clients >= big_n -> shard-level threads
//! batch_width = 0            # replications per batch arena (0 = auto)
//! pool_capacity = 0          # task-pool slots per replication (0 = concurrency)
//!
//! [churn]                    # optional open-network lifecycle (omit = closed)
//! arrival_rate = 0.6         # join hazard while any node is departed
//! mean_lifetime = 3.0        # mean membership duration before a leave
//! stall_rate = 0.4           # stall hazard per running node
//! mean_stall = 0.5           # mean stall duration
//! rate_change_rate = 0.5     # markov-modulated service-rate switch hazard
//! rate_factor_min = 0.5      # service-duration scale ~ U[min, max]
//! rate_factor_max = 2.0
//! initial_active = 0         # nodes live at t = 0 (0 = all)
//! max_events = 10000         # schedule truncation cap
//!
//! [grid]                     # every axis is a list; cells = cartesian
//! clients = [100, 1000]      # product x policies (x algos in train mode)
//! concurrency = [10]
//! steps = [20000]
//! mu_fast = [4.0]
//! slow_fraction = [0.5]
//! gamma = [0.5]              # adaptive / delay-adaptive pressure
//! beta = [0.9]               # delay-adaptive EWMA momentum
//! service = ["exp"]          # exp | det | lognormal | lognormal:<cv>
//! policies = ["uniform", "optimal", "adaptive"]
//! # p_fast = [0.004]         # optional static-tilt axis
//! # algos = ["gasync"]       # train mode only
//!
//! [train]                    # train-mode knobs (ignored in simulate)
//! variant = "tiny"
//! eta = 0.05
//! n_train = 2000
//! n_val = 400
//! classes_per_client = 7
//! eval_every = 20
//! kappa = 0.5                # genasync-damped staleness damping
//! ```

use super::experiment::{two_cluster_n_fast, two_cluster_p, two_cluster_rates};
use super::policy::{optimal_two_cluster, PolicyCtx, PolicyRegistry, SamplingPolicy, StaticPolicy};
use super::serve::{ServeConfig, ServeSetup};
use crate::coordinator::Experiment;
use crate::runtime::BackendKind;
use crate::simulator::{
    batch_vectorizes, run_batch, run_with_policy, ChurnConfig, EngineConfig, EngineKind,
    ServiceDist, ServiceFamily, SimConfig, SimResult,
};
use crate::util::json::Json;
use crate::util::mem::peak_rss_mib;
use crate::util::rng::stream_seed;
use crate::util::stats::Welford;
use crate::util::toml::Doc;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepMode {
    /// Pure queueing replications (`simulator::run_with_policy`) — scales
    /// to 10^5–10^6 nodes per replication.
    Simulate,
    /// Full DL experiments through [`Experiment::run`] on the native
    /// backend — scales in seeds, not nodes.
    Train,
    /// Event-driven coordinator sessions ([`ServeSetup::run`]) — live
    /// admission control over the same policy/strategy registries.
    Serve,
}

impl std::str::FromStr for SweepMode {
    type Err = String;

    fn from_str(s: &str) -> Result<SweepMode, String> {
        match s {
            "simulate" => Ok(SweepMode::Simulate),
            "train" => Ok(SweepMode::Train),
            "serve" => Ok(SweepMode::Serve),
            other => Err(format!("unknown sweep mode '{other}' (simulate|train|serve)")),
        }
    }
}

/// Validate a sweep-level engine selector: "auto" (per-cell scheduler
/// decides) or any concrete [`EngineKind`] name.  The single authority
/// shared by the TOML parser and the `--engine` CLI override, so the two
/// surfaces cannot drift.
pub fn validate_engine_choice(name: &str) -> Result<(), String> {
    if name == "auto" || name.parse::<EngineKind>().is_ok() {
        Ok(())
    } else {
        Err(format!("engine = '{name}' must be auto, heap, sharded, or batch"))
    }
}

/// One point of the structural grid (everything except policy/algo/seed).
#[derive(Clone, Debug)]
pub struct ScenarioPoint {
    pub clients: usize,
    pub concurrency: usize,
    pub steps: u64,
    pub mu_fast: f64,
    pub slow_fraction: f64,
    pub gamma: f64,
    /// delay-adaptive EWMA momentum
    pub beta: f64,
    pub p_fast: Option<f64>,
    pub service: ServiceFamily,
}

impl ScenarioPoint {
    pub fn n_fast(&self) -> usize {
        two_cluster_n_fast(self.clients, self.slow_fraction)
    }

    /// Base/static routing distribution (uniform unless p_fast tilts it).
    pub fn base_p(&self) -> Result<Vec<f64>, String> {
        if let Some(pf) = self.p_fast {
            let nf = self.n_fast();
            if nf == 0 || nf >= self.clients {
                return Err("p_fast needs a two-cluster population".into());
            }
            let q = (1.0 - nf as f64 * pf) / (self.clients - nf) as f64;
            if !(pf > 0.0) || q <= 0.0 {
                return Err(format!(
                    "p_fast {pf} leaves no probability mass for slow nodes (q = {q})"
                ));
            }
        }
        Ok(two_cluster_p(self.clients, self.slow_fraction, self.p_fast))
    }

    pub fn rates(&self) -> Vec<f64> {
        two_cluster_rates(self.clients, self.slow_fraction, self.mu_fast)
    }

    pub fn policy_ctx(&self) -> Result<PolicyCtx, String> {
        Ok(PolicyCtx {
            n: self.clients,
            base_p: self.base_p()?,
            gamma: self.gamma,
            beta: self.beta,
            n_fast: self.n_fast(),
            mu_fast: self.mu_fast,
            mu_slow: 1.0,
            concurrency: self.concurrency,
            steps: self.steps,
        })
    }

    fn service_name(&self) -> String {
        match self.service {
            ServiceFamily::Exponential => "exp".into(),
            ServiceFamily::Deterministic => "det".into(),
            // the bare spelling stays the label of the historical default
            // cv so existing reports diff cleanly; any other cv is spelled
            // out, keeping grid legs like lognormal:1.2 distinguishable
            ServiceFamily::LogNormal(cv) if cv == 0.5 => "lognormal".into(),
            ServiceFamily::LogNormal(cv) => format!("lognormal:{cv}"),
        }
    }

    pub fn label(&self) -> String {
        let mut s = format!(
            "n{}_C{}_T{}_mu{}_sf{}_g{}_b{}_{}",
            self.clients,
            self.concurrency,
            self.steps,
            self.mu_fast,
            self.slow_fraction,
            self.gamma,
            self.beta,
            self.service_name()
        );
        if let Some(pf) = self.p_fast {
            s.push_str(&format!("_pf{pf}"));
        }
        s
    }
}

/// Train-mode knobs shared by every cell.
#[derive(Clone, Debug)]
pub struct TrainKnobs {
    pub variant: String,
    pub eta: f64,
    pub n_train: usize,
    pub n_val: usize,
    pub classes_per_client: usize,
    pub eval_every: u64,
    /// genasync-damped staleness-damping strength κ
    pub kappa: f64,
}

impl Default for TrainKnobs {
    fn default() -> TrainKnobs {
        TrainKnobs {
            variant: "tiny".into(),
            eta: 0.05,
            n_train: 2_000,
            n_val: 400,
            classes_per_client: 7,
            eval_every: 20,
            kappa: 0.5,
        }
    }
}

/// One aggregation cell: a scenario × policy (× algo) combination whose
/// seeds are reduced together.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub id: usize,
    pub scenario: ScenarioPoint,
    pub policy: String,
    /// registry algorithm name in train mode, "-" in simulate mode
    pub algo: String,
}

impl SweepCell {
    pub fn label(&self) -> String {
        if self.algo == "-" {
            format!("{}/{}", self.scenario.label(), self.policy)
        } else {
            format!("{}/{}/{}", self.scenario.label(), self.policy, self.algo)
        }
    }
}

/// The parsed, validated sweep declaration.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    pub mode: SweepMode,
    pub seeds: u64,
    pub base_seed: u64,
    pub threads: usize,
    pub out: String,
    /// engine selection: "auto" (scheduler decides per cell by `big_n`),
    /// "heap", or "sharded"
    pub engine: String,
    /// sharded-engine shard count (0 = auto)
    pub shards: usize,
    /// cells with `clients >= big_n` get shard-level threads instead of
    /// seed-level fan-out
    pub big_n: u64,
    /// replications packed per batch arena on batch cells; 0 = auto (see
    /// [`SweepSpec::resolve_batch_width`])
    pub batch_width: usize,
    /// task-pool slots per replication (0 = exactly `concurrency`); an
    /// undersized pool surfaces as a typed cell error, never a panic
    pub pool_capacity: usize,
    /// optional open-network node lifecycle applied to every cell
    pub churn: Option<ChurnConfig>,
    /// admission-control knobs applied to every serve-mode cell (None =
    /// serve defaults)
    pub serve: Option<ServeConfig>,
    pub cells: Vec<SweepCell>,
    pub train: TrainKnobs,
}

/// Keys the `[sweep]` table accepts — the single list shared by the
/// parser below and the `docs/SCENARIOS.md` cross-check in
/// `tests/scenario_lint.rs`.
pub const SWEEP_KEYS: &[&str] = &[
    "name", "mode", "seeds", "base_seed", "threads", "out", "engine", "shards", "big_n",
    "batch_width", "pool_capacity",
];

/// Keys the `[grid]` table accepts (same contract as [`SWEEP_KEYS`]).
pub const GRID_KEYS: &[&str] = &[
    "clients",
    "concurrency",
    "steps",
    "mu_fast",
    "slow_fraction",
    "gamma",
    "beta",
    "p_fast",
    "service",
    "policies",
    "algos",
];

/// Keys the `[train]` table accepts (same contract as [`SWEEP_KEYS`]).
pub const TRAIN_KEYS: &[&str] = &[
    "variant",
    "eta",
    "n_train",
    "n_val",
    "classes_per_client",
    "eval_every",
    "kappa",
];

impl SweepSpec {
    pub fn from_path(path: &Path) -> Result<SweepSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("sweep grid {}: {e}", path.display()))?;
        SweepSpec::from_toml(&text).map_err(|e| format!("sweep grid {}: {e}", path.display()))
    }

    pub fn from_toml(text: &str) -> Result<SweepSpec, String> {
        let doc = Doc::parse(text)?;
        for (table, keys) in &doc.tables {
            let known: &[&str] = match table.as_str() {
                "" => &[],
                "sweep" => SWEEP_KEYS,
                // [churn]/[serve] keys are validated (strictly) by
                // ChurnConfig::from_toml_table / ServeConfig::
                // from_toml_table — one authority each, no drift
                "churn" | "serve" => continue,
                "grid" => GRID_KEYS,
                "train" => TRAIN_KEYS,
                other => {
                    return Err(format!(
                        "unknown table [{other}] (sweep|grid|churn|serve|train)"
                    ))
                }
            };
            for k in keys.keys() {
                if !known.contains(&k.as_str()) {
                    return Err(format!(
                        "unknown key '{k}' in [{table}] (known: {})",
                        known.join(", ")
                    ));
                }
            }
        }
        let mode: SweepMode = doc.str_or("sweep", "mode", "simulate").parse()?;
        let seeds = doc.i64_or("sweep", "seeds", 8);
        if seeds < 1 {
            return Err(format!("[sweep] seeds = {seeds} must be >= 1"));
        }
        let threads = doc.i64_or("sweep", "threads", 0);
        if threads < 0 {
            return Err(format!("[sweep] threads = {threads} must be >= 0"));
        }
        let engine = doc.str_or("sweep", "engine", "auto");
        validate_engine_choice(&engine).map_err(|e| format!("[sweep] {e}"))?;
        let shards = doc.i64_or("sweep", "shards", 0);
        if shards < 0 {
            return Err(format!("[sweep] shards = {shards} must be >= 0"));
        }
        let big_n = doc.i64_or("sweep", "big_n", 100_000);
        if big_n < 0 {
            return Err(format!("[sweep] big_n = {big_n} must be >= 0"));
        }
        let batch_width = doc.i64_or("sweep", "batch_width", 0);
        if batch_width < 0 {
            return Err(format!("[sweep] batch_width = {batch_width} must be >= 0"));
        }
        let pool_capacity = doc.i64_or("sweep", "pool_capacity", 0);
        if pool_capacity < 0 {
            return Err(format!("[sweep] pool_capacity = {pool_capacity} must be >= 0"));
        }
        // errors out of the churn parser/validator already carry their
        // own "[churn]" context
        let churn = match doc.tables.get("churn") {
            Some(tbl) => Some(ChurnConfig::from_toml_table(tbl)?),
            None => None,
        };
        let serve = match doc.tables.get("serve") {
            Some(tbl) => Some(ServeConfig::from_toml_table(tbl)?),
            None => None,
        };

        // grid axes: every key is a homogeneous list; absent = one default
        let ints = |key: &str, default: i64| -> Result<Vec<i64>, String> {
            match doc.get("grid", key) {
                None => Ok(vec![default]),
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| format!("[grid] {key} must be an array"))?;
                    if arr.is_empty() {
                        return Err(format!("[grid] {key} must not be empty"));
                    }
                    arr.iter()
                        .map(|x| {
                            x.as_i64().filter(|i| *i >= 0).ok_or_else(|| {
                                format!("[grid] {key} must hold non-negative integers")
                            })
                        })
                        .collect()
                }
            }
        };
        let floats = |key: &str, default: f64| -> Result<Vec<f64>, String> {
            match doc.get("grid", key) {
                None => Ok(vec![default]),
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| format!("[grid] {key} must be an array"))?;
                    if arr.is_empty() {
                        return Err(format!("[grid] {key} must not be empty"));
                    }
                    arr.iter()
                        .map(|x| {
                            x.as_f64()
                                .ok_or_else(|| format!("[grid] {key} must hold numbers"))
                        })
                        .collect()
                }
            }
        };
        let strings = |key: &str, default: &str| -> Result<Vec<String>, String> {
            match doc.get("grid", key) {
                None => Ok(vec![default.to_string()]),
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| format!("[grid] {key} must be an array"))?;
                    if arr.is_empty() {
                        return Err(format!("[grid] {key} must not be empty"));
                    }
                    arr.iter()
                        .map(|x| {
                            x.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| format!("[grid] {key} must hold strings"))
                        })
                        .collect()
                }
            }
        };

        let clients = ints("clients", 100)?;
        let concurrency = ints("concurrency", 10)?;
        let steps = ints("steps", 20_000)?;
        let mu_fast = floats("mu_fast", 4.0)?;
        let slow_fraction = floats("slow_fraction", 0.5)?;
        let gamma = floats("gamma", 0.5)?;
        let beta = floats("beta", 0.9)?;
        let p_fast: Vec<Option<f64>> = match doc.get("grid", "p_fast") {
            None => vec![None],
            Some(_) => floats("p_fast", 0.0)?.into_iter().map(Some).collect(),
        };
        let services: Vec<ServiceFamily> = strings("service", "exp")?
            .iter()
            .map(|s| s.parse())
            .collect::<Result<_, _>>()?;
        let policies = strings("policies", "uniform")?;
        let algos = match mode {
            SweepMode::Simulate => vec!["-".to_string()],
            SweepMode::Train | SweepMode::Serve => strings("algos", "gasync")?,
        };
        let registry = PolicyRegistry::builtin();
        for p in &policies {
            if !registry.contains(p) {
                return Err(format!(
                    "[grid] unknown policy '{p}' (available: {})",
                    registry.names().join("|")
                ));
            }
        }
        if mode != SweepMode::Simulate {
            let strategies = crate::fl::StrategyRegistry::builtin();
            for a in &algos {
                if !strategies.contains(a) {
                    return Err(format!(
                        "[grid] unknown algorithm '{a}' (available: {})",
                        strategies.names().join("|")
                    ));
                }
            }
        }

        // cells: scenario-major cartesian product, fixed axis order, so
        // cell ids (and thus RNG streams) depend only on the grid itself
        let mut cells = Vec::new();
        for &n in &clients {
            for &c in &concurrency {
                for &t in &steps {
                    for &mu in &mu_fast {
                        for &sf in &slow_fraction {
                            for &g in &gamma {
                                for &b in &beta {
                                    for &pf in &p_fast {
                                        for &svc in &services {
                                            for pol in &policies {
                                                for algo in &algos {
                                                    let scenario = ScenarioPoint {
                                                        clients: n as usize,
                                                        concurrency: c as usize,
                                                        steps: t as u64,
                                                        mu_fast: mu,
                                                        slow_fraction: sf,
                                                        gamma: g,
                                                        beta: b,
                                                        p_fast: pf,
                                                        service: svc,
                                                    };
                                                    scenario.validate()?;
                                                    // fail at parse time,
                                                    // not after hours of
                                                    // other cells ran
                                                    if let Some(c) = &churn {
                                                        c.validate(scenario.clients)?;
                                                    }
                                                    if pol == "optimal" {
                                                        let nf = scenario.n_fast();
                                                        if nf == 0 || nf >= scenario.clients {
                                                            return Err(format!(
                                                                "grid: policy 'optimal' needs a \
                                                                 two-cluster population \
                                                                 (n_fast {nf} of {})",
                                                                scenario.clients
                                                            ));
                                                        }
                                                    }
                                                    cells.push(SweepCell {
                                                        id: cells.len(),
                                                        scenario,
                                                        policy: pol.clone(),
                                                        algo: algo.clone(),
                                                    });
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        if cells.is_empty() {
            return Err("sweep grid resolves to zero cells".into());
        }

        let train = TrainKnobs {
            variant: doc.str_or("train", "variant", "tiny"),
            eta: doc.f64_or("train", "eta", 0.05),
            n_train: doc.i64_or("train", "n_train", 2_000).max(0) as usize,
            n_val: doc.i64_or("train", "n_val", 400).max(0) as usize,
            classes_per_client: doc.i64_or("train", "classes_per_client", 7).max(0) as usize,
            eval_every: doc.i64_or("train", "eval_every", 20).max(0) as u64,
            kappa: doc.f64_or("train", "kappa", 0.5),
        };
        if !(train.kappa >= 0.0) || !train.kappa.is_finite() {
            return Err(format!(
                "[train] kappa = {} must be finite and >= 0",
                train.kappa
            ));
        }

        Ok(SweepSpec {
            name: doc.str_or("sweep", "name", "sweep"),
            mode,
            seeds: seeds as u64,
            base_seed: doc.i64_or("sweep", "base_seed", 0) as u64,
            threads: threads as usize,
            out: doc.str_or("sweep", "out", "results/sweep.json"),
            engine,
            shards: shards as usize,
            big_n: big_n as u64,
            batch_width: batch_width as usize,
            pool_capacity: pool_capacity as usize,
            churn,
            serve,
            cells,
            train,
        })
    }

    /// The engine a cell's replications run on — a pure function of the
    /// spec and the cell (NOT of the worker-thread count), so the choice
    /// never perturbs the deterministic report.  `worker_threads` only
    /// sizes the shard-level pool of big-n cells.
    pub fn engine_for_cell(&self, cell: &SweepCell, worker_threads: usize) -> EngineConfig {
        if self.mode != SweepMode::Simulate {
            // train: the DL driver holds the heap engine directly;
            // serve: replications run on the single-threaded executor
            return EngineConfig::heap();
        }
        let n = cell.scenario.clients as u64;
        let kind = match self.engine.as_str() {
            "heap" => EngineKind::Heap,
            "sharded" => EngineKind::Sharded,
            "batch" => EngineKind::Batch,
            // auto: big-n cells are memory-bound -> sharded SoA engine
            // with shard-level threads; everything else is construction-
            // bound -> batch arenas amortize it across the cell's seeds
            _ => {
                if n >= self.big_n {
                    EngineKind::Sharded
                } else {
                    EngineKind::Batch
                }
            }
        };
        match kind {
            EngineKind::Heap => EngineConfig::heap(),
            EngineKind::Batch => EngineConfig::batch(),
            EngineKind::Sharded => {
                // big-n cells get the whole worker budget as shard threads
                // (their replications run one at a time); small sharded
                // cells stay sequential and parallelize over seeds.  Cap
                // at the RESOLVED shard count up front: the engine clamps
                // threads to shards anyway, and classifying a shards=1
                // cell as "wide" would serialize its seeds for nothing.
                let shard_cap =
                    EngineConfig::sharded(self.shards, 1).resolve_shards(cell.scenario.clients);
                let threads = if n >= self.big_n {
                    worker_threads.max(1).min(shard_cap)
                } else {
                    1
                };
                EngineConfig::sharded(self.shards, threads)
            }
        }
    }

    /// Replications per batch arena for this sweep's batch cells.
    ///
    /// `batch_width > 0` pins R (clamped to the per-cell seed count — a
    /// batch never spans cells, since replications of different cells
    /// share neither layout nor policy).  Auto (0) balances two pulls:
    /// wider arenas amortize more construction and feed the vectorized
    /// sampler longer blocks, but chunks are the unit the worker pool
    /// schedules, so R is sized to leave at least one chunk per worker —
    /// `ceil(total batch replications / workers)` — and capped at 32,
    /// past which the arena's working set outgrows the amortization win
    /// (and holds R·C tasks in memory for nothing).
    pub fn resolve_batch_width(&self, worker_threads: usize) -> u64 {
        let seeds = self.seeds.max(1);
        if self.batch_width > 0 {
            return (self.batch_width as u64).min(seeds);
        }
        let batch_cells = self
            .cells
            .iter()
            .filter(|c| self.engine_for_cell(c, worker_threads).kind == EngineKind::Batch)
            .count() as u64;
        let total = batch_cells * seeds;
        let per_worker = total.div_ceil(worker_threads.max(1) as u64);
        per_worker.clamp(1, 32).min(seeds)
    }
}

impl ScenarioPoint {
    fn validate(&self) -> Result<(), String> {
        if self.clients < 2 {
            return Err(format!("grid: clients {} must be >= 2", self.clients));
        }
        if self.concurrency == 0 {
            return Err("grid: concurrency must be >= 1".into());
        }
        if self.steps == 0 {
            return Err("grid: steps must be >= 1".into());
        }
        if !(self.mu_fast > 0.0) {
            return Err(format!("grid: mu_fast {} must be positive", self.mu_fast));
        }
        if !(0.0..=1.0).contains(&self.slow_fraction) {
            return Err(format!(
                "grid: slow_fraction {} must be in [0,1]",
                self.slow_fraction
            ));
        }
        if !(self.gamma >= 0.0) || !self.gamma.is_finite() {
            return Err(format!("grid: gamma {} must be finite and >= 0", self.gamma));
        }
        if !(0.0..1.0).contains(&self.beta) {
            return Err(format!("grid: beta {} must be in [0, 1)", self.beta));
        }
        self.base_p().map(|_| ())
    }
}

/// One replication's scalar metrics (+ training curve in train mode).
#[derive(Clone, Debug, Default)]
pub struct RepResult {
    pub metrics: BTreeMap<String, f64>,
    /// timing/host-dependent scale metrics (events/sec, peak RSS) — kept
    /// apart from `metrics` so the deterministic JSON core stays
    /// bit-identical across thread counts and hosts
    pub perf: BTreeMap<String, f64>,
    /// (step, virtual_time, train_loss, val_loss, val_acc)
    pub curve: Vec<(u64, f64, f64, f64, f64)>,
}

/// A cell's seeds reduced into Welford accumulators.
#[derive(Clone, Debug)]
pub struct CellReport {
    pub cell: SweepCell,
    /// engine label the scheduler picked ("heap" / "sharded(S=8)")
    pub engine: String,
    pub metrics: BTreeMap<String, Welford>,
    /// perf aggregates (events/sec, peak RSS MiB) — excluded from the
    /// deterministic JSON core
    pub perf: BTreeMap<String, Welford>,
    /// per eval point: (step, metric name -> accumulator)
    pub curve: Vec<(u64, BTreeMap<String, Welford>)>,
}

/// The full aggregated sweep.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    pub mode: SweepMode,
    pub seeds: u64,
    pub base_seed: u64,
    pub cells: Vec<CellReport>,
}

/// Build one replication's sampling policy: the per-cell precomputed
/// distribution when available (the Theorem-1 optimizer runs once per
/// cell, not once per seed), otherwise a fresh registry build.
fn cell_policy(
    cell: &SweepCell,
    cached_p: Option<&[f64]>,
) -> Result<Box<dyn SamplingPolicy>, String> {
    match cached_p {
        Some(p) => Ok(Box::new(StaticPolicy::labeled(&cell.policy, p.to_vec())?)),
        None => PolicyRegistry::builtin().build(&cell.policy, &cell.scenario.policy_ctx()?),
    }
}

/// The deterministic scalar metrics of one simulate replication.
fn sim_metrics(s: &ScenarioPoint, res: &SimResult) -> BTreeMap<String, f64> {
    let nf = s.n_fast();
    let n = s.clients;
    let cluster_queue =
        |range: std::ops::Range<usize>| -> f64 { crate::util::stats::mean(&res.mean_queue[range]) };
    let mut m = BTreeMap::new();
    m.insert("delay_all".into(), res.cluster_delay(0..n));
    m.insert("delay_fast".into(), res.cluster_delay(0..nf));
    m.insert("delay_slow".into(), res.cluster_delay(nf..n));
    m.insert("queue_fast".into(), cluster_queue(0..nf));
    m.insert("queue_slow".into(), cluster_queue(nf..n));
    m.insert("step_rate".into(), res.step_rate(s.steps));
    // completed-steps marker: tiny horizons can finish 0 steps, and 0 here
    // is the defined signal that the delay/rate metrics averaged nothing
    m.insert("steps".into(), res.completions.iter().sum::<u64>() as f64);
    m.insert("tau_c".into(), res.tau_c);
    m.insert("tau_max".into(), res.tau_max as f64);
    m.insert("total_time".into(), res.total_time);
    m
}

/// Scale trajectory: wall-clock throughput + memory high-water mark
/// (timing-derived -> perf, never the deterministic metrics map).
/// peak_rss_mib is the PROCESS-wide monotone watermark — an upper bound
/// that absorbs earlier/concurrent cells — and is omitted entirely on
/// platforms without a probe (see util::mem).  Batched replications
/// report their arena's per-replication share of the wall clock plus the
/// arena width.
fn sim_perf(
    steps: u64,
    wall: f64,
    batch_width: Option<u64>,
    vectorized: bool,
) -> BTreeMap<String, f64> {
    let mut perf = BTreeMap::new();
    perf.insert("wall_secs".into(), wall);
    perf.insert(
        "events_per_sec".into(),
        steps as f64 / wall.max(f64::MIN_POSITIVE),
    );
    // 1.0 when the cell's service vector is single-family, i.e. the batch
    // arena draws its durations through a vectorized block kernel; 0.0
    // flags cells paying the scalar mixed-family fallback
    perf.insert("service_vectorized".into(), f64::from(u8::from(vectorized)));
    if let Some(rss) = peak_rss_mib() {
        perf.insert("peak_rss_mib".into(), rss);
    }
    if let Some(r) = batch_width {
        perf.insert("batch_width".into(), r as f64);
    }
    perf
}

fn simulate_replication(
    spec: &SweepSpec,
    cell: &SweepCell,
    cached_p: Option<&[f64]>,
    engine: EngineConfig,
    seed: u64,
) -> Result<RepResult, String> {
    let s = &cell.scenario;
    let policy = cell_policy(cell, cached_p)?;
    let service = ServiceDist::from_rates(&s.rates(), s.service);
    let vectorized = batch_vectorizes(&service);
    let cfg = SimConfig {
        seed,
        engine,
        churn: spec.churn.clone(),
        pool_capacity: spec.pool_capacity,
        ..SimConfig::new(policy.probs(), service, s.concurrency, s.steps)
    };
    // lint-allow(R3): wall-clock feeds only the `perf` JSON block, which
    // to_json_deterministic() excludes from the comparison payload
    let t0 = std::time::Instant::now();
    let res = run_with_policy(cfg, policy)?;
    let wall = t0.elapsed().as_secs_f64();
    Ok(RepResult {
        metrics: sim_metrics(s, &res),
        perf: sim_perf(s.steps, wall, None, vectorized),
        curve: Vec::new(),
    })
}

/// Run seed indices `seed_lo..seed_hi` of a batch cell through ONE batch
/// arena (`simulator::engine::batch::run_batch`), returning their
/// RepResults in seed order.  Each replication keeps its own
/// `stream_seed(base_seed, [cell, seed])` stream and is bit-identical to
/// the heap oracle, so chunking is invisible in the deterministic report.
fn simulate_cell_batch(
    spec: &SweepSpec,
    cell: &SweepCell,
    cached_p: Option<&[f64]>,
    seed_lo: u64,
    seed_hi: u64,
) -> Result<Vec<RepResult>, String> {
    let s = &cell.scenario;
    let first = cell_policy(cell, cached_p)?;
    let service = ServiceDist::from_rates(&s.rates(), s.service);
    let vectorized = batch_vectorizes(&service);
    let base = SimConfig {
        engine: EngineConfig::batch(),
        churn: spec.churn.clone(),
        pool_capacity: spec.pool_capacity,
        ..SimConfig::new(first.probs(), service, s.concurrency, s.steps)
    };
    let seeds: Vec<u64> = (seed_lo..seed_hi)
        .map(|idx| stream_seed(spec.base_seed, &[cell.id as u64, idx]))
        .collect();
    let width = seeds.len() as u64;
    // lint-allow(R3): wall-clock feeds only the `perf` JSON block, which
    // to_json_deterministic() excludes from the comparison payload
    let t0 = std::time::Instant::now();
    // `first` (read above for the shared cfg.p) serves as replication 0's
    // policy; later replications build fresh instances as usual
    let mut first = Some(first);
    let results = run_batch(&base, &seeds, |_| match first.take() {
        Some(p) => Ok(p),
        None => cell_policy(cell, cached_p),
    })?;
    // the arena interleaves its replications, so each one's share of the
    // wall clock is the chunk total over the width
    let wall = t0.elapsed().as_secs_f64() / width.max(1) as f64;
    Ok(results
        .iter()
        .map(|res| RepResult {
            metrics: sim_metrics(s, res),
            perf: sim_perf(s.steps, wall, Some(width), vectorized),
            curve: Vec::new(),
        })
        .collect())
}

fn train_replication(cell: &SweepCell, knobs: &TrainKnobs, seed: u64) -> Result<RepResult, String> {
    let s = &cell.scenario;
    let mut b = Experiment::builder()
        .variant(&knobs.variant)
        .backend(BackendKind::Native)
        .algo(&cell.algo)
        .policy(&cell.policy)
        .clients(s.clients)
        .concurrency(s.concurrency)
        .steps(s.steps)
        .eta(knobs.eta)
        .slow_fraction(s.slow_fraction)
        .mu_fast(s.mu_fast)
        .adaptive_gamma(s.gamma)
        .delay_beta(s.beta)
        .damping_kappa(knobs.kappa)
        .n_train(knobs.n_train)
        .n_val(knobs.n_val)
        .classes_per_client(knobs.classes_per_client)
        .eval_every(knobs.eval_every)
        .seed(seed);
    if let Some(pf) = s.p_fast {
        b = b.p_fast(pf);
    }
    let exp = b.build()?;
    let res = exp.run()?;
    let mut m = BTreeMap::new();
    m.insert("final_accuracy".into(), res.final_accuracy);
    m.insert("final_val_loss".into(), res.final_val_loss);
    m.insert("tau_max".into(), res.tau_max as f64);
    m.insert("virtual_time".into(), res.total_virtual_time);
    let curve = res
        .curve
        .iter()
        .map(|c| (c.step, c.virtual_time, c.train_loss, c.val_loss, c.val_accuracy))
        .collect();
    Ok(RepResult { metrics: m, perf: BTreeMap::new(), curve })
}

/// One serve session as a sweep replication: same admission knobs for
/// every cell, the cell's scenario/policy/algo for everything else, the
/// shared `[train]` eta/kappa for the strategies.
fn serve_replication(spec: &SweepSpec, cell: &SweepCell, seed: u64) -> Result<RepResult, String> {
    let s = &cell.scenario;
    let setup = ServeSetup {
        clients: s.clients,
        concurrency: s.concurrency,
        dispatches: s.steps,
        slow_fraction: s.slow_fraction,
        mu_fast: s.mu_fast,
        p_fast: s.p_fast,
        gamma: s.gamma,
        beta: s.beta,
        eta: spec.train.eta,
        kappa: spec.train.kappa,
        policy: cell.policy.clone(),
        algo: cell.algo.clone(),
        seed,
        cfg: spec.serve.clone().unwrap_or_default(),
    };
    let rep = setup.run()?;
    let mut m = BTreeMap::new();
    m.insert("dispatched".into(), rep.dispatched as f64);
    m.insert("completed".into(), rep.completed as f64);
    m.insert("mean_delay".into(), rep.delay.mean());
    m.insert("mean_queue_time".into(), rep.queue_time.mean());
    m.insert("mean_compute_time".into(), rep.compute_time.mean());
    m.insert("virtual_time".into(), rep.virtual_time);
    m.insert("windows".into(), rep.windows as f64);
    let denom = (rep.completed as f64).max(1.0);
    m.insert("deadline_miss_rate".into(), rep.deadline_misses as f64 / denom);
    m.insert("deferred_rate".into(), rep.deferred as f64 / denom);
    let mut perf = BTreeMap::new();
    perf.insert("wall_secs".into(), rep.wall_secs);
    perf.insert("dispatches_per_sec".into(), rep.dispatches_per_sec());
    Ok(RepResult { metrics: m, perf, curve: Vec::new() })
}

fn run_replication(
    spec: &SweepSpec,
    cell: &SweepCell,
    cached_p: Option<&[f64]>,
    engine: EngineConfig,
    seed_idx: u64,
) -> Result<RepResult, String> {
    // one independent stream per (cell, seed index): deterministic and
    // scheduling-free by construction
    let seed = stream_seed(spec.base_seed, &[cell.id as u64, seed_idx]);
    match spec.mode {
        SweepMode::Simulate => simulate_replication(spec, cell, cached_p, engine, seed),
        SweepMode::Train => train_replication(cell, &spec.train, seed),
        SweepMode::Serve => serve_replication(spec, cell, seed),
    }
}

/// Distributions that are expensive to construct but depend only on the
/// cell, not the seed — today the Theorem-1 `optimal` sweep.  Computing
/// them up front also fails fast, before any replication has run.
fn precompute_cell_distributions(spec: &SweepSpec) -> Result<Vec<Option<Vec<f64>>>, String> {
    let mut out = vec![None; spec.cells.len()];
    if spec.mode == SweepMode::Simulate {
        for cell in &spec.cells {
            if cell.policy == "optimal" {
                let pol = optimal_two_cluster(&cell.scenario.policy_ctx()?)
                    .map_err(|e| format!("cell {}: {e}", cell.label()))?;
                out[cell.id] = Some(pol.probs());
            }
        }
    }
    Ok(out)
}

/// One unit of worker-pool work: a single replication, or a contiguous
/// chunk of one batch cell's seeds sharing a batch arena.
#[derive(Clone, Copy, Debug)]
enum WorkItem {
    /// replication id (cell · seeds + seed index)
    Rep(usize),
    /// seed indices `lo..hi` of `cell`, one arena
    Chunk { cell: usize, lo: u64, hi: u64 },
}

/// Execute every replication of the grid and reduce in (cell, seed) order.
///
/// The per-cell scheduler splits the `spec.threads` worker budget (0 = one
/// per available core): replications whose engine runs sequentially
/// ("narrow" cells) fan out across the worker pool — batch cells as
/// arena-sized seed chunks, heap/sequential-sharded cells one replication
/// per item; replications whose sharded engine owns its own thread pool
/// ("wide" big-n cells) run one at a time so the machine is never
/// oversubscribed.  Results land in slots indexed by replication id either
/// way, so the reduction — and the deterministic report — is identical
/// under every split.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepReport, String> {
    let threads = if spec.threads == 0 {
        // lint-allow(R3): worker-count probe only; slot-indexed reduction makes
        // the report identical under every split, so parallelism never reaches
        // the digest
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        spec.threads
    };
    let total = spec.cells.len() * spec.seeds as usize;
    let cell_p = precompute_cell_distributions(spec)?;
    let engines: Vec<EngineConfig> = spec
        .cells
        .iter()
        .map(|c| spec.engine_for_cell(c, threads))
        .collect();
    let batch_width = spec.resolve_batch_width(threads);
    let failed = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<Result<RepResult, String>>>> =
        Mutex::new(vec![None; total]);
    // phase 1: narrow work across the worker pool
    let mut narrow: Vec<WorkItem> = Vec::new();
    for (c, eng) in engines.iter().enumerate() {
        match eng.kind {
            EngineKind::Batch => {
                let mut lo = 0;
                while lo < spec.seeds {
                    let hi = (lo + batch_width).min(spec.seeds);
                    narrow.push(WorkItem::Chunk { cell: c, lo, hi });
                    lo = hi;
                }
            }
            _ if eng.threads <= 1 => {
                for s in 0..spec.seeds as usize {
                    narrow.push(WorkItem::Rep(c * spec.seeds as usize + s));
                }
            }
            _ => {} // wide sharded cells run in phase 2
        }
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                // early abort: once any replication has failed the sweep
                // is doomed, so don't burn hours on the remaining cells
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= narrow.len() {
                    break;
                }
                match narrow[k] {
                    WorkItem::Rep(r) => {
                        let cell = &spec.cells[r / spec.seeds as usize];
                        let seed_idx = (r % spec.seeds as usize) as u64;
                        let out = run_replication(
                            spec,
                            cell,
                            cell_p[cell.id].as_deref(),
                            engines[cell.id],
                            seed_idx,
                        );
                        if out.is_err() {
                            failed.store(true, Ordering::Relaxed);
                        }
                        slots.lock().unwrap()[r] = Some(out);
                    }
                    WorkItem::Chunk { cell, lo, hi } => {
                        let c = &spec.cells[cell];
                        let out = simulate_cell_batch(spec, c, cell_p[cell].as_deref(), lo, hi);
                        let mut slots = slots.lock().unwrap();
                        match out {
                            Ok(reps) => {
                                for (j, rep) in reps.into_iter().enumerate() {
                                    slots[cell * spec.seeds as usize + lo as usize + j] =
                                        Some(Ok(rep));
                                }
                            }
                            Err(e) => {
                                // an arena failure takes its whole chunk
                                // down; every member must report it so the
                                // reduction never sees a silent hole
                                failed.store(true, Ordering::Relaxed);
                                for s in lo..hi {
                                    slots[cell * spec.seeds as usize + s as usize] =
                                        Some(Err(e.clone()));
                                }
                            }
                        }
                    }
                }
            });
        }
    });
    // phase 2: wide (big-n sharded) replications sequentially — each one
    // spends the whole thread budget inside its engine
    for r in (0..total).filter(|r| engines[r / spec.seeds as usize].threads > 1) {
        if failed.load(Ordering::Relaxed) {
            break;
        }
        let cell = &spec.cells[r / spec.seeds as usize];
        let seed_idx = (r % spec.seeds as usize) as u64;
        let out = run_replication(
            spec,
            cell,
            cell_p[cell.id].as_deref(),
            engines[cell.id],
            seed_idx,
        );
        if out.is_err() {
            failed.store(true, Ordering::Relaxed);
        }
        slots.lock().unwrap()[r] = Some(out);
    }
    let slots = slots.into_inner().map_err(|e| e.to_string())?;
    // surface the earliest recorded failure first — after an early abort
    // the later slots are legitimately empty
    for (r, slot) in slots.iter().enumerate() {
        if let Some(Err(e)) = slot {
            let cell = &spec.cells[r / spec.seeds as usize];
            return Err(format!(
                "cell {} seed {}: {e}",
                cell.label(),
                r % spec.seeds as usize
            ));
        }
    }
    // ordered reduction: walk replications in (cell, seed) order so the
    // aggregate is independent of which worker ran what when
    let mut cells = Vec::with_capacity(spec.cells.len());
    for cell in &spec.cells {
        let mut metrics: BTreeMap<String, Welford> = BTreeMap::new();
        let mut perf: BTreeMap<String, Welford> = BTreeMap::new();
        let mut curve: Vec<(u64, BTreeMap<String, Welford>)> = Vec::new();
        let mut curve_len = usize::MAX;
        let mut reps: Vec<&RepResult> = Vec::with_capacity(spec.seeds as usize);
        for s in 0..spec.seeds as usize {
            let r = cell.id * spec.seeds as usize + s;
            let rep = slots[r]
                .as_ref()
                .ok_or_else(|| format!("replication {r} never ran"))?
                .as_ref()
                .map_err(|e| format!("cell {} seed {s}: {e}", cell.label()))?;
            curve_len = curve_len.min(rep.curve.len());
            reps.push(rep);
        }
        for rep in &reps {
            for (k, &v) in &rep.metrics {
                let w = metrics.entry(k.clone()).or_default();
                if v.is_finite() {
                    w.push(v);
                }
            }
            for (k, &v) in &rep.perf {
                let w = perf.entry(k.clone()).or_default();
                if v.is_finite() {
                    w.push(v);
                }
            }
        }
        if curve_len != usize::MAX && curve_len > 0 {
            for i in 0..curve_len {
                let step = reps[0].curve[i].0;
                // aggregate only while every seed is at the SAME eval
                // step: round-based strategies emit seed-dependent final
                // points, and averaging mismatched steps would plot mixed
                // values at a wrong x-coordinate
                if reps.iter().any(|rep| rep.curve[i].0 != step) {
                    break;
                }
                let mut point: BTreeMap<String, Welford> = BTreeMap::new();
                for rep in &reps {
                    let (_, vt, tl, vl, va) = rep.curve[i];
                    point.entry("virtual_time".into()).or_default().push(vt);
                    point.entry("train_loss".into()).or_default().push(tl);
                    point.entry("val_loss".into()).or_default().push(vl);
                    point.entry("val_acc".into()).or_default().push(va);
                }
                curve.push((step, point));
            }
        }
        let e = engines[cell.id];
        let engine = if spec.mode == SweepMode::Serve {
            "serve".to_string()
        } else {
            match e.kind {
                EngineKind::Heap => "heap".to_string(),
                EngineKind::Sharded => {
                    format!("sharded(S={})", e.resolve_shards(cell.scenario.clients))
                }
                // the chunk target width; a cell's tail chunk may be narrower
                EngineKind::Batch => format!("batch(R={})", batch_width.min(spec.seeds)),
            }
        };
        cells.push(CellReport { cell: cell.clone(), engine, metrics, perf, curve });
    }
    Ok(SweepReport {
        name: spec.name.clone(),
        mode: spec.mode,
        seeds: spec.seeds,
        base_seed: spec.base_seed,
        cells,
    })
}

fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn welford_json(w: &Welford) -> Json {
    let mut m = BTreeMap::new();
    m.insert("count".to_string(), Json::Num(w.count() as f64));
    m.insert("mean".to_string(), num(w.mean()));
    m.insert("std".to_string(), num(w.std()));
    m.insert("sem".to_string(), num(w.sem()));
    m.insert("ci95".to_string(), num(w.ci95()));
    m.insert("min".to_string(), num(w.min()));
    m.insert("max".to_string(), num(w.max()));
    Json::Obj(m)
}

impl SweepReport {
    /// Render the full aggregate as JSON, including the per-cell `perf`
    /// block (events/sec, peak RSS) for BENCH trajectories.  Perf values
    /// are timing-derived and host-dependent; use
    /// [`Self::to_json_deterministic`] for bit-stable comparisons.
    pub fn to_json(&self) -> Json {
        self.render_json(true)
    }

    /// Render the deterministic core only.  Key order (BTreeMap) and f64
    /// formatting are both deterministic, and nothing scheduling- or
    /// host-dependent (thread count, timestamps, perf) is included — this
    /// is the determinism test's comparison unit.
    pub fn to_json_deterministic(&self) -> Json {
        self.render_json(false)
    }

    fn render_json(&self, include_perf: bool) -> Json {
        let mut root = BTreeMap::new();
        root.insert("name".to_string(), Json::Str(self.name.clone()));
        root.insert(
            "mode".to_string(),
            Json::Str(
                match self.mode {
                    SweepMode::Simulate => "simulate",
                    SweepMode::Train => "train",
                    SweepMode::Serve => "serve",
                }
                .to_string(),
            ),
        );
        root.insert("seeds".to_string(), Json::Num(self.seeds as f64));
        root.insert("base_seed".to_string(), Json::Num(self.base_seed as f64));
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let s = &c.cell.scenario;
                let mut sc = BTreeMap::new();
                sc.insert("clients".to_string(), Json::Num(s.clients as f64));
                sc.insert("concurrency".to_string(), Json::Num(s.concurrency as f64));
                sc.insert("steps".to_string(), Json::Num(s.steps as f64));
                sc.insert("mu_fast".to_string(), Json::Num(s.mu_fast));
                sc.insert("slow_fraction".to_string(), Json::Num(s.slow_fraction));
                sc.insert("gamma".to_string(), Json::Num(s.gamma));
                sc.insert("beta".to_string(), Json::Num(s.beta));
                sc.insert("n_fast".to_string(), Json::Num(s.n_fast() as f64));
                sc.insert(
                    "p_fast".to_string(),
                    s.p_fast.map(Json::Num).unwrap_or(Json::Null),
                );
                sc.insert("service".to_string(), Json::Str(s.service_name()));
                let mut obj = BTreeMap::new();
                obj.insert("id".to_string(), Json::Num(c.cell.id as f64));
                obj.insert("label".to_string(), Json::Str(c.cell.label()));
                obj.insert("policy".to_string(), Json::Str(c.cell.policy.clone()));
                obj.insert("algo".to_string(), Json::Str(c.cell.algo.clone()));
                if include_perf {
                    // provenance, not result: the engines are bit-identical,
                    // so the label lives outside the deterministic core
                    obj.insert("engine".to_string(), Json::Str(c.engine.clone()));
                }
                obj.insert("scenario".to_string(), Json::Obj(sc));
                obj.insert(
                    "metrics".to_string(),
                    Json::Obj(
                        c.metrics
                            .iter()
                            .map(|(k, w)| (k.clone(), welford_json(w)))
                            .collect(),
                    ),
                );
                if include_perf && !c.perf.is_empty() {
                    obj.insert(
                        "perf".to_string(),
                        Json::Obj(
                            c.perf
                                .iter()
                                .map(|(k, w)| (k.clone(), welford_json(w)))
                                .collect(),
                        ),
                    );
                }
                if !c.curve.is_empty() {
                    obj.insert(
                        "curve".to_string(),
                        Json::Arr(
                            c.curve
                                .iter()
                                .map(|(step, point)| {
                                    let mut p = BTreeMap::new();
                                    p.insert("step".to_string(), Json::Num(*step as f64));
                                    for (k, w) in point {
                                        p.insert(k.clone(), welford_json(w));
                                    }
                                    Json::Obj(p)
                                })
                                .collect(),
                        ),
                    );
                }
                Json::Obj(obj)
            })
            .collect();
        root.insert("cells".to_string(), Json::Arr(cells));
        Json::Obj(root)
    }

    /// One-line terminal summary per cell (mean ± 95% CI of the headline
    /// metrics).
    pub fn summary(&self) -> String {
        let fmt = |w: Option<&Welford>| -> String {
            match w {
                Some(w) if w.count() > 0 => {
                    let ci = w.ci95();
                    if ci.is_finite() {
                        format!("{:.3} ±{:.3}", w.mean(), ci)
                    } else {
                        format!("{:.3}", w.mean())
                    }
                }
                _ => "-".to_string(),
            }
        };
        let mut out = String::new();
        for c in &self.cells {
            let line = match self.mode {
                SweepMode::Simulate => format!(
                    "{:<48} delay fast {} / slow {} | step rate {} | tau_c {}",
                    c.cell.label(),
                    fmt(c.metrics.get("delay_fast")),
                    fmt(c.metrics.get("delay_slow")),
                    fmt(c.metrics.get("step_rate")),
                    fmt(c.metrics.get("tau_c")),
                ),
                SweepMode::Train => format!(
                    "{:<48} acc {} | val loss {} | tau_max {}",
                    c.cell.label(),
                    fmt(c.metrics.get("final_accuracy")),
                    fmt(c.metrics.get("final_val_loss")),
                    fmt(c.metrics.get("tau_max")),
                ),
                SweepMode::Serve => format!(
                    "{:<48} delay {} | miss rate {} | deferred {}",
                    c.cell.label(),
                    fmt(c.metrics.get("mean_delay")),
                    fmt(c.metrics.get("deadline_miss_rate")),
                    fmt(c.metrics.get("deferred_rate")),
                ),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GRID: &str = r#"
[sweep]
name = "smoke"
mode = "simulate"
seeds = 3
base_seed = 7
threads = 2

[grid]
clients = [8, 12]
concurrency = [4]
steps = [400]
mu_fast = [4.0]
slow_fraction = [0.5]
policies = ["uniform", "adaptive"]
"#;

    const CHURN_GRID: &str = r#"
[sweep]
name = "churn_smoke"
mode = "simulate"
seeds = 2
base_seed = 11
threads = 2

[churn]
arrival_rate = 0.6
mean_lifetime = 3.0
stall_rate = 0.4
mean_stall = 0.5
rate_change_rate = 0.5
rate_factor_min = 0.5
rate_factor_max = 2.0
initial_active = 6
max_events = 200

[grid]
clients = [8]
concurrency = [4]
steps = [300]
mu_fast = [4.0]
slow_fraction = [0.5]
policies = ["uniform", "adaptive"]
"#;

    #[test]
    fn parses_grid_and_builds_cells() {
        let spec = SweepSpec::from_toml(GRID).unwrap();
        assert_eq!(spec.name, "smoke");
        assert_eq!(spec.mode, SweepMode::Simulate);
        assert_eq!(spec.seeds, 3);
        assert_eq!(spec.threads, 2);
        // 2 clients x 2 policies = 4 cells, scenario-major order
        assert_eq!(spec.cells.len(), 4);
        assert_eq!(spec.cells[0].scenario.clients, 8);
        assert_eq!(spec.cells[0].policy, "uniform");
        assert_eq!(spec.cells[1].policy, "adaptive");
        assert_eq!(spec.cells[2].scenario.clients, 12);
        for (i, c) in spec.cells.iter().enumerate() {
            assert_eq!(c.id, i);
        }
    }

    #[test]
    fn rejects_unknown_tables_keys_policies_and_modes() {
        let err = SweepSpec::from_toml("[sweeep]\nseeds = 2").unwrap_err();
        assert!(err.contains("sweeep"), "{err}");
        let err = SweepSpec::from_toml("[grid]\nclinets = [10]").unwrap_err();
        assert!(err.contains("clinets"), "{err}");
        let err = SweepSpec::from_toml("[grid]\npolicies = [\"zipf\"]").unwrap_err();
        assert!(err.contains("zipf"), "{err}");
        let err = SweepSpec::from_toml("[sweep]\nmode = \"quantum\"").unwrap_err();
        assert!(err.contains("quantum"), "{err}");
        let err = SweepSpec::from_toml("[sweep]\nseeds = 0").unwrap_err();
        assert!(err.contains("seeds"), "{err}");
        let err = SweepSpec::from_toml("[grid]\nclients = []").unwrap_err();
        assert!(err.contains("clients"), "{err}");
        let err = SweepSpec::from_toml("[grid]\nclients = 10").unwrap_err();
        assert!(err.contains("array"), "{err}");
        // misconfigurations that would otherwise fail mid-sweep are
        // rejected at parse time
        let err = SweepSpec::from_toml("[grid]\ngamma = [-0.5]").unwrap_err();
        assert!(err.contains("gamma"), "{err}");
        let err = SweepSpec::from_toml("[grid]\nbeta = [1.5]").unwrap_err();
        assert!(err.contains("beta"), "{err}");
        let err = SweepSpec::from_toml("[train]\nkappa = -0.5").unwrap_err();
        assert!(err.contains("kappa"), "{err}");
        let err = SweepSpec::from_toml("[sweep]\nmode = \"train\"\n[grid]\nalgos = [\"fedavgg\"]")
            .unwrap_err();
        assert!(err.contains("fedavgg"), "{err}");
        let err =
            SweepSpec::from_toml("[grid]\nslow_fraction = [1.0]\npolicies = [\"optimal\"]")
                .unwrap_err();
        assert!(err.contains("optimal"), "{err}");
    }

    #[test]
    fn parses_churn_block_and_rejects_bad_knobs() {
        let spec = SweepSpec::from_toml(CHURN_GRID).unwrap();
        let churn = spec.churn.as_ref().expect("[churn] table parsed");
        assert_eq!(churn.arrival_rate, 0.6);
        assert_eq!(churn.initial_active, 6);
        assert_eq!(spec.pool_capacity, 0, "defaults to concurrency");
        // no [churn] table -> closed network
        assert!(SweepSpec::from_toml(GRID).unwrap().churn.is_none());
        // strict keys inside [churn], strict tables outside
        let err = SweepSpec::from_toml("[churn]\nbogus = 1.0").unwrap_err();
        assert!(err.contains("bogus"), "{err}");
        let err = SweepSpec::from_toml("[chrun]\narrival_rate = 1.0").unwrap_err();
        assert!(err.contains("chrun"), "{err}");
        let err = SweepSpec::from_toml("[sweep]\npool_capacity = -1").unwrap_err();
        assert!(err.contains("pool_capacity"), "{err}");
        // churn knobs that can't serve the grid fail at parse time: 9
        // initially-active nodes do not fit an 8-client scenario
        let bad = CHURN_GRID.replace("initial_active = 6", "initial_active = 9");
        let err = SweepSpec::from_toml(&bad).unwrap_err();
        assert!(err.contains("initial_active"), "{err}");
    }

    #[test]
    fn churn_sweep_is_engine_invariant() {
        // the engine-equivalence contract must survive an open network:
        // heap, sharded, and batch arenas aggregate to the identical
        // deterministic JSON under nonzero churn
        let render = |engine: &str, batch_width: usize| -> String {
            let mut spec = SweepSpec::from_toml(CHURN_GRID).unwrap();
            spec.engine = engine.to_string();
            spec.shards = 3;
            spec.batch_width = batch_width;
            run_sweep(&spec).unwrap().to_json_deterministic().render()
        };
        let heap = render("heap", 0);
        assert_eq!(heap, render("sharded", 0), "sharded vs heap under churn");
        assert_eq!(heap, render("batch", 1), "width-1 batch arenas under churn");
        assert_eq!(heap, render("batch", 2), "width-2 batch arenas under churn");
    }

    #[test]
    fn pool_exhaustion_is_a_typed_sweep_error_not_a_panic() {
        // a pool sized below the task population must abort the sweep with
        // the typed EngineError surfaced through the cell-error path — on
        // every engine the scheduler can pick
        for engine in ["heap", "sharded", "batch"] {
            let mut spec = SweepSpec::from_toml(GRID).unwrap();
            spec.engine = engine.to_string();
            spec.pool_capacity = 1; // < concurrency = 4
            let err = run_sweep(&spec).unwrap_err();
            assert!(err.contains("task pool exhausted"), "{engine}: {err}");
            assert!(err.contains("capacity 1"), "{engine}: {err}");
            assert!(err.contains("cell"), "{engine}: {err}");
        }
    }

    #[test]
    fn sweep_aggregates_all_cells_and_seeds() {
        let spec = SweepSpec::from_toml(GRID).unwrap();
        let report = run_sweep(&spec).unwrap();
        assert_eq!(report.cells.len(), 4);
        for c in &report.cells {
            let d = &c.metrics["delay_all"];
            assert_eq!(d.count(), 3, "{}", c.cell.label());
            assert!(d.mean().is_finite());
            assert!(d.ci95().is_finite(), "3 seeds give a CI");
            assert!(c.metrics["step_rate"].mean() > 0.0);
        }
        // JSON renders and parses back
        let rendered = report.to_json().render();
        let parsed = Json::parse(&rendered).unwrap();
        assert_eq!(
            parsed.get("cells").unwrap().as_arr().unwrap().len(),
            4
        );
        assert!(!report.summary().is_empty());
    }

    #[test]
    fn replication_streams_are_independent() {
        let spec = SweepSpec::from_toml(GRID).unwrap();
        let eng = EngineConfig::heap();
        let a = run_replication(&spec, &spec.cells[0], None, eng, 0).unwrap();
        let b = run_replication(&spec, &spec.cells[0], None, eng, 1).unwrap();
        let c = run_replication(&spec, &spec.cells[0], None, eng, 0).unwrap();
        assert_ne!(
            a.metrics["total_time"].to_bits(),
            b.metrics["total_time"].to_bits(),
            "different seed indices must differ"
        );
        assert_eq!(
            a.metrics["total_time"].to_bits(),
            c.metrics["total_time"].to_bits(),
            "same replication must be reproducible"
        );
    }

    #[test]
    fn scheduler_splits_threads_between_seeds_and_shards() {
        let mut spec = SweepSpec::from_toml(GRID).unwrap();
        assert_eq!(spec.engine, "auto");
        assert_eq!(spec.big_n, 100_000);
        assert_eq!(spec.batch_width, 0, "batch width defaults to auto");
        // auto: small cells go to the batch arena
        let e = spec.engine_for_cell(&spec.cells[0], 4);
        assert_eq!(e.kind, EngineKind::Batch);
        // lowering big_n flips them to wide sharded cells owning the
        // budget (capped by the resolved shard count)
        spec.big_n = 1;
        spec.shards = 8;
        let e = spec.engine_for_cell(&spec.cells[0], 4);
        assert_eq!(e.kind, EngineKind::Sharded);
        assert_eq!(e.threads, 4);
        // a single-shard cell can't use shard threads — it must stay
        // narrow so its seeds fan out across the worker pool instead
        spec.shards = 1;
        let e = spec.engine_for_cell(&spec.cells[0], 4);
        assert_eq!(e.kind, EngineKind::Sharded);
        assert_eq!(e.threads, 1, "shard clamp must keep shards=1 cells narrow");
        spec.shards = 0;
        // explicit heap/sharded overrides win over auto
        spec.engine = "heap".into();
        assert_eq!(spec.engine_for_cell(&spec.cells[0], 4).kind, EngineKind::Heap);
        spec.engine = "sharded".into();
        spec.big_n = 100_000;
        let e = spec.engine_for_cell(&spec.cells[0], 4);
        assert_eq!(e.kind, EngineKind::Sharded);
        assert_eq!(e.threads, 1, "small sharded cells parallelize over seeds");
        // engine strings are validated at parse time
        let err = SweepSpec::from_toml("[sweep]\nengine = \"gpu\"").unwrap_err();
        assert!(err.contains("engine"), "{err}");
        let err = SweepSpec::from_toml("[sweep]\nbatch_width = -2").unwrap_err();
        assert!(err.contains("batch_width"), "{err}");
    }

    #[test]
    fn batch_width_resolution_balances_pool_and_amortization() {
        let mut spec = SweepSpec::from_toml(GRID).unwrap();
        spec.engine = "batch".into();
        // explicit width wins, clamped to the per-cell seed count
        spec.batch_width = 2;
        assert_eq!(spec.resolve_batch_width(4), 2);
        spec.batch_width = 100;
        assert_eq!(spec.resolve_batch_width(4), 3, "never wider than seeds");
        // auto: 4 batch cells x 3 seeds = 12 replications
        spec.batch_width = 0;
        assert_eq!(spec.resolve_batch_width(4), 3, "12 reps / 4 workers");
        assert_eq!(spec.resolve_batch_width(12), 1, "plenty of workers -> R=1");
        spec.seeds = 64;
        assert_eq!(
            spec.resolve_batch_width(4),
            32,
            "auto width caps at 32 even when fewer, wider chunks would fit"
        );
        // heap-only sweeps have no batch cells; the width is moot but sane
        spec.engine = "heap".into();
        spec.seeds = 3;
        assert_eq!(spec.resolve_batch_width(4), 1);
    }

    #[test]
    fn batch_chunks_fill_every_slot_once() {
        // seeds = 3 with batch_width = 2 -> chunks [0,2) and [2,3): every
        // replication must land exactly one result, including tail chunks
        let mut spec = SweepSpec::from_toml(GRID).unwrap();
        spec.engine = "batch".into();
        spec.batch_width = 2;
        let report = run_sweep(&spec).unwrap();
        assert_eq!(report.cells.len(), 4);
        for c in &report.cells {
            assert_eq!(c.metrics["delay_all"].count(), 3, "{}", c.cell.label());
            assert!(c.engine.starts_with("batch(R="), "{}", c.engine);
            // every replication reports the arena width it actually ran in
            let bw = &c.perf["batch_width"];
            assert_eq!(bw.count(), 3);
            assert_eq!(bw.min(), 1.0, "tail chunk is width 1");
            assert_eq!(bw.max(), 2.0);
        }
    }

    #[test]
    fn engine_choice_never_changes_the_deterministic_report() {
        // the same grid on heap, sequential sharded, wide (threaded)
        // sharded, and batch arenas of several widths must aggregate to
        // the identical deterministic JSON — the sweep-level face of the
        // engine equivalence contract
        let render = |engine: &str, big_n: u64, batch_width: usize| -> String {
            let mut spec = SweepSpec::from_toml(GRID).unwrap();
            spec.engine = engine.to_string();
            spec.big_n = big_n;
            spec.shards = 3;
            spec.batch_width = batch_width;
            run_sweep(&spec).unwrap().to_json_deterministic().render()
        };
        let heap = render("heap", 100_000, 0);
        assert_eq!(heap, render("sharded", 100_000, 0), "sequential sharded");
        assert_eq!(heap, render("sharded", 1, 0), "wide sharded (shard threads)");
        assert_eq!(heap, render("batch", 100_000, 1), "width-1 batch arenas");
        assert_eq!(heap, render("batch", 100_000, 2), "chunked batch arenas");
        assert_eq!(heap, render("batch", 100_000, 0), "auto-width batch arenas");
    }

    #[test]
    fn perf_metrics_reported_but_not_in_deterministic_core() {
        let spec = SweepSpec::from_toml(GRID).unwrap();
        let report = run_sweep(&spec).unwrap();
        for c in &report.cells {
            // auto scheduling: small cells run in batch arenas
            assert!(c.engine.starts_with("batch(R="), "{}", c.engine);
            let eps = &c.perf["events_per_sec"];
            assert_eq!(eps.count(), 3, "{}", c.cell.label());
            assert!(eps.mean() > 0.0);
            assert!(c.perf.contains_key("wall_secs"));
            // peak RSS is present iff the platform probe is (never a fake
            // 0: the key is omitted, not zeroed, on macOS runners)
            match crate::util::mem::peak_rss_mib() {
                Some(_) => {
                    assert!(c.perf["peak_rss_mib"].mean() > 0.0, "{}", c.cell.label())
                }
                None => assert!(!c.perf.contains_key("peak_rss_mib")),
            }
        }
        let full = report.to_json().render();
        assert!(full.contains("events_per_sec"));
        let core = report.to_json_deterministic().render();
        assert!(!core.contains("events_per_sec"));
        assert!(!core.contains("wall_secs"));
        assert!(full.contains("\"engine\""), "full JSON carries provenance");
        assert!(
            !core.contains("\"engine\""),
            "engine label is provenance, not a result — the core must be \
             invariant across engine choices"
        );
    }
}
