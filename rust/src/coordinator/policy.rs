//! Sampling policies — the server's choice of the routing distribution p,
//! the paper's central design variable.
//!
//! A [`SamplingPolicy`] is consulted by the closed-network simulator at
//! *every* routing step: `observe` sees the current queue lengths, `route`
//! draws the next node K_{k+1}, and `probs` exposes the distribution in
//! force so the dispatcher can record the selection probability on the
//! task.  Generalized AsyncSGD reads that dispatch-time probability back
//! for its unbiased `η/(n p_i)` scaling, which keeps the aggregate update
//! direction unbiased even under time-varying p (see
//! `fl::strategy::GenAsync`).
//!
//! Built-ins, all reachable from `fedqueue train --policy <name>` through
//! the [`PolicyRegistry`]:
//!
//! * `static`  — the experiment's fixed p (two-cluster tilt or explicit
//!   vector); exactly the pre-refactor behavior.
//! * `uniform` — p_i = 1/n regardless of the configured tilt.
//! * `optimal` — the Theorem-1 bound-optimal two-cluster p, wired to
//!   [`crate::bound::optimizer`] (the old `--optimal-p` path).
//! * `adaptive` — queue-length-aware: p_i ∝ base_i · exp(−γ·X_i),
//!   renormalized before each dispatch.  Nodes with long queues are
//!   sampled less, which caps staleness without starving anyone (γ = 0
//!   degenerates to `static`); motivated by the delay-aware policies of
//!   arXiv:2502.08206 / arXiv:2402.11198.

use crate::bound::{BoundParams, MiSource, TwoClusterStudy};
use crate::util::rng::{AliasTable, Rng};

/// The routing-distribution interface consulted by the simulator.
pub trait SamplingPolicy {
    /// Display name (curve labels, diagnostics).
    fn name(&self) -> String;

    /// The distribution currently in force over the n nodes.
    fn probs(&self) -> &[f64];

    /// Observe the queue lengths right before a routing decision.
    /// Static policies ignore this; adaptive ones recompute `probs`.
    fn observe(&mut self, _queue_lens: &[u32]) {}

    /// Sample the next node K_{k+1} from the distribution in force.
    fn route(&mut self, rng: &mut Rng) -> usize;
}

// ---------------------------------------------------------------------------
// Static (fixed p) — alias-table sampling, identical to the original engine
// ---------------------------------------------------------------------------

pub struct StaticPolicy {
    label: String,
    p: Vec<f64>,
    alias: AliasTable,
}

impl StaticPolicy {
    pub fn new(p: Vec<f64>) -> Result<StaticPolicy, String> {
        StaticPolicy::labeled("static", p)
    }

    pub fn labeled(label: &str, p: Vec<f64>) -> Result<StaticPolicy, String> {
        let alias = AliasTable::new(&p)?;
        Ok(StaticPolicy { label: label.to_string(), p, alias })
    }

    pub fn uniform(n: usize) -> Result<StaticPolicy, String> {
        if n == 0 {
            return Err("uniform policy needs n >= 1".into());
        }
        StaticPolicy::labeled("uniform", vec![1.0 / n as f64; n])
    }
}

impl SamplingPolicy for StaticPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn probs(&self) -> &[f64] {
        &self.p
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        self.alias.sample(rng)
    }
}

// ---------------------------------------------------------------------------
// Adaptive queue-length-aware policy
// ---------------------------------------------------------------------------

pub struct AdaptiveQueuePolicy {
    base: Vec<f64>,
    gamma: f64,
    probs: Vec<f64>,
}

impl AdaptiveQueuePolicy {
    pub fn new(base: Vec<f64>, gamma: f64) -> Result<AdaptiveQueuePolicy, String> {
        if base.is_empty() {
            return Err("adaptive policy needs a non-empty base distribution".into());
        }
        if !(gamma >= 0.0) || !gamma.is_finite() {
            return Err(format!("adaptive policy: gamma {gamma} must be finite and >= 0"));
        }
        let sum: f64 = base.iter().sum();
        if (sum - 1.0).abs() > 1e-6 || base.iter().any(|&b| b < 0.0 || !b.is_finite()) {
            return Err(format!("adaptive policy: base p must be a distribution (sum {sum})"));
        }
        Ok(AdaptiveQueuePolicy { probs: base.clone(), base, gamma })
    }
}

impl SamplingPolicy for AdaptiveQueuePolicy {
    fn name(&self) -> String {
        format!("adaptive(gamma={})", self.gamma)
    }

    fn probs(&self) -> &[f64] {
        &self.probs
    }

    fn observe(&mut self, queue_lens: &[u32]) {
        let mut total = 0.0f64;
        for (pi, (&b, &q)) in self
            .probs
            .iter_mut()
            .zip(self.base.iter().zip(queue_lens.iter()))
        {
            *pi = b * (-self.gamma * q as f64).exp();
            total += *pi;
        }
        if !(total > 0.0) || !total.is_finite() {
            // all mass underflowed (enormous γ·X): fall back to the base
            self.probs.copy_from_slice(&self.base);
            total = self.probs.iter().sum();
        }
        for pi in self.probs.iter_mut() {
            *pi /= total;
        }
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        let mut acc = 0.0f64;
        for (i, &pi) in self.probs.iter().enumerate() {
            acc += pi;
            if u < acc {
                return i;
            }
        }
        self.probs.len() - 1
    }
}

// ---------------------------------------------------------------------------
// Theorem-1 optimal two-cluster policy
// ---------------------------------------------------------------------------

/// Shape of the experiment a policy is built for.  Constructors read what
/// they need and ignore the rest.
#[derive(Clone, Debug)]
pub struct PolicyCtx {
    /// number of clients n
    pub n: usize,
    /// the experiment's base/static distribution (two-cluster tilt etc.)
    pub base_p: Vec<f64>,
    /// queue-pressure strength for the adaptive policy
    pub gamma: f64,
    /// two-cluster shape for the Theorem-1 optimizer
    pub n_fast: usize,
    pub mu_fast: f64,
    pub mu_slow: f64,
    pub concurrency: usize,
    pub steps: u64,
}

/// Build the bound-optimal static two-cluster policy by sweeping the
/// Theorem-1 optimizer — the exact computation behind the historical
/// `--optimal-p` flag (worked-example constants A=100, B=20, L=1, 50-point
/// log grid), packaged as a [`StaticPolicy`] labeled "optimal".
pub fn optimal_two_cluster(ctx: &PolicyCtx) -> Result<StaticPolicy, String> {
    if ctx.n_fast == 0 || ctx.n_fast >= ctx.n {
        return Err(format!(
            "optimal policy needs a two-cluster population (n_fast {} of n {})",
            ctx.n_fast, ctx.n
        ));
    }
    let study = TwoClusterStudy {
        params: BoundParams {
            a: 100.0,
            b: 20.0,
            l: 1.0,
            c: ctx.concurrency,
            t: ctx.steps,
            n: ctx.n,
        },
        n_fast: ctx.n_fast,
        mu_fast: ctx.mu_fast,
        mu_slow: ctx.mu_slow,
        source: MiSource::default(),
    };
    let (best, _) = study.optimize_p(50)?;
    let pf = best.p_fast;
    let q = (1.0 - ctx.n_fast as f64 * pf) / (ctx.n - ctx.n_fast) as f64;
    let p: Vec<f64> = (0..ctx.n)
        .map(|i| if i < ctx.n_fast { pf } else { q })
        .collect();
    StaticPolicy::labeled("optimal", p)
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

type PolicyCtor = Box<dyn Fn(&PolicyCtx) -> Result<Box<dyn SamplingPolicy>, String>>;

pub struct PolicyEntry {
    pub name: String,
    pub summary: String,
    ctor: PolicyCtor,
}

/// String → constructor mapping for sampling policies.  `builtin()`
/// carries the four paper-relevant policies; downstream code may
/// `register` more without touching the simulator or the CLI.
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    pub fn empty() -> PolicyRegistry {
        PolicyRegistry { entries: Vec::new() }
    }

    pub fn builtin() -> PolicyRegistry {
        let mut r = PolicyRegistry::empty();
        r.register(
            "static",
            "fixed p from the experiment config (two-cluster tilt or explicit vector)",
            |ctx| Ok(Box::new(StaticPolicy::new(ctx.base_p.clone())?) as Box<dyn SamplingPolicy>),
        );
        r.register("uniform", "p_i = 1/n", |ctx| {
            Ok(Box::new(StaticPolicy::uniform(ctx.n)?) as Box<dyn SamplingPolicy>)
        });
        r.register(
            "optimal",
            "Theorem-1 bound-optimal two-cluster p (the old --optimal-p)",
            |ctx| Ok(Box::new(optimal_two_cluster(ctx)?) as Box<dyn SamplingPolicy>),
        );
        r.register(
            "adaptive",
            "queue-length-aware: p_i proportional to base_i*exp(-gamma*X_i)",
            |ctx| {
                Ok(Box::new(AdaptiveQueuePolicy::new(ctx.base_p.clone(), ctx.gamma)?)
                    as Box<dyn SamplingPolicy>)
            },
        );
        r
    }

    /// Register (or replace) a policy constructor.
    pub fn register<F>(&mut self, name: &str, summary: &str, ctor: F)
    where
        F: Fn(&PolicyCtx) -> Result<Box<dyn SamplingPolicy>, String> + 'static,
    {
        self.entries.retain(|e| e.name != name);
        self.entries.push(PolicyEntry {
            name: name.to_string(),
            summary: summary.to_string(),
            ctor: Box::new(ctor),
        });
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    pub fn build(&self, name: &str, ctx: &PolicyCtx) -> Result<Box<dyn SamplingPolicy>, String> {
        for e in &self.entries {
            if e.name == name {
                return (e.ctor)(ctx);
            }
        }
        Err(format!(
            "unknown sampling policy '{name}' (available: {})",
            self.names().join("|")
        ))
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    pub fn summaries(&self) -> Vec<(String, String)> {
        self.entries
            .iter()
            .map(|e| (e.name.clone(), e.summary.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(n: usize) -> PolicyCtx {
        PolicyCtx {
            n,
            base_p: vec![1.0 / n as f64; n],
            gamma: 0.5,
            n_fast: n / 2,
            mu_fast: 4.0,
            mu_slow: 1.0,
            concurrency: 4,
            steps: 200,
        }
    }

    #[test]
    fn static_policy_samples_p() {
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let mut pol = StaticPolicy::new(p.clone()).unwrap();
        assert_eq!(pol.probs(), &p[..]);
        let mut rng = Rng::new(1);
        let mut counts = vec![0u64; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[pol.route(&mut rng)] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - p[i]).abs() < 0.01, "node {i}: freq {f} vs p {}", p[i]);
        }
    }

    #[test]
    fn adaptive_tilts_away_from_long_queues() {
        let mut pol = AdaptiveQueuePolicy::new(vec![0.25; 4], 1.0).unwrap();
        pol.observe(&[0, 0, 5, 0]);
        let p = pol.probs();
        assert!(p[2] < p[0], "loaded node must be sampled less: {p:?}");
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "probs sum {sum}");
        // γ=0 degenerates to the base
        let mut flat = AdaptiveQueuePolicy::new(vec![0.25; 4], 0.0).unwrap();
        flat.observe(&[9, 0, 3, 1]);
        for &pi in flat.probs() {
            assert!((pi - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn adaptive_route_matches_probs() {
        let mut pol = AdaptiveQueuePolicy::new(vec![0.25; 4], 1.0).unwrap();
        pol.observe(&[3, 0, 0, 3]);
        let want = pol.probs().to_vec();
        let mut rng = Rng::new(7);
        let mut counts = vec![0u64; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[pol.route(&mut rng)] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - want[i]).abs() < 0.01, "node {i}: {f} vs {}", want[i]);
        }
    }

    #[test]
    fn adaptive_survives_underflow() {
        let mut pol = AdaptiveQueuePolicy::new(vec![0.5, 0.5], 1e6).unwrap();
        pol.observe(&[1000, 1000]);
        let sum: f64 = pol.probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fallback must renormalize: {sum}");
    }

    #[test]
    fn optimal_policy_tilts_below_uniform() {
        // the paper's headline: fast clients sampled LESS than uniformly
        let c = ctx(20);
        let pol = optimal_two_cluster(&c).unwrap();
        assert_eq!(pol.name(), "optimal");
        let p = pol.probs();
        assert_eq!(p.len(), 20);
        assert!(p[0] < 1.0 / 20.0, "fast p {} should be below uniform", p[0]);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        // one-cluster population is rejected
        let mut bad = ctx(20);
        bad.n_fast = 0;
        assert!(optimal_two_cluster(&bad).is_err());
    }

    #[test]
    fn registry_builds_every_builtin() {
        let reg = PolicyRegistry::builtin();
        assert_eq!(reg.names(), vec!["static", "uniform", "optimal", "adaptive"]);
        let c = ctx(10);
        for name in reg.names() {
            let pol = reg.build(&name, &c).unwrap();
            let sum: f64 = pol.probs().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{name}: probs sum {sum}");
        }
        let err = reg.build("zipf", &c).unwrap_err();
        assert!(err.contains("unknown sampling policy"), "{err}");
        assert!(err.contains("adaptive"), "error must list names: {err}");
    }

    #[test]
    fn registry_accepts_third_party_policies() {
        let mut reg = PolicyRegistry::builtin();
        reg.register("slowest-first", "always node n-1 (test double)", |c| {
            struct SlowestFirst {
                p: Vec<f64>,
            }
            impl SamplingPolicy for SlowestFirst {
                fn name(&self) -> String {
                    "slowest-first".into()
                }
                fn probs(&self) -> &[f64] {
                    &self.p
                }
                fn route(&mut self, _rng: &mut Rng) -> usize {
                    self.p.len() - 1
                }
            }
            let mut p = vec![0.0; c.n];
            p[c.n - 1] = 1.0;
            Ok(Box::new(SlowestFirst { p }) as Box<dyn SamplingPolicy>)
        });
        let mut pol = reg.build("slowest-first", &ctx(6)).unwrap();
        let mut rng = Rng::new(3);
        assert_eq!(pol.route(&mut rng), 5);
    }
}
