//! Sampling policies — the server's choice of the routing distribution p,
//! the paper's central design variable.
//!
//! A [`SamplingPolicy`] is consulted by the closed-network simulator at
//! *every* routing step, so its per-step surface is deliberately cheap:
//! `observe_node` ingests one queue-length change (only two queues change
//! per CS step), `route` draws the next node K_{k+1}, and `prob_of`
//! exposes the selection probability in force so the dispatcher can record
//! it on the task.  Generalized AsyncSGD reads that dispatch-time
//! probability back for its unbiased `η/(n p_i)` scaling, which keeps the
//! aggregate update direction unbiased even under time-varying p (see
//! `fl::strategy::GenAsync`).
//!
//! Sampler complexity per dispatch:
//!
//! * static policies — Walker alias table: O(1) draw
//! * `adaptive` — Fenwick-tree sampler: O(log n) observe + O(log n) draw
//! * `adaptive-exact` — O(n) renormalize + CDF scan; the exact reference
//!   the fast samplers are validated against (`tests/statistical_samplers`)
//!
//! Built-ins, all reachable from `fedqueue train --policy <name>` and the
//! sweep grids through the [`PolicyRegistry`]:
//!
//! * `static`  — the experiment's fixed p (two-cluster tilt or explicit
//!   vector); exactly the pre-refactor behavior.
//! * `uniform` — p_i = 1/n regardless of the configured tilt.
//! * `optimal` — the Theorem-1 bound-optimal two-cluster p, wired to
//!   [`crate::bound::optimizer`] (the old `--optimal-p` path).
//! * `adaptive` — queue-length-aware: p_i ∝ base_i · exp(−γ·X_i), kept in
//!   a Fenwick tree so each routing step costs O(log n) instead of O(n).
//!   Nodes with long queues are sampled less, which caps staleness without
//!   starving anyone (γ = 0 degenerates to `static`); motivated by the
//!   delay-aware policies of arXiv:2502.08206 / arXiv:2402.11198.
//! * `adaptive-exact` — same distribution via full renormalization, O(n)
//!   per step; the oracle for tests and small-n debugging.
//! * `delay-adaptive` — delay-feedback (arXiv:2402.11198-style):
//!   p_i ∝ base_i · exp(−γ·D̂_i), where D̂_i is a per-node EWMA of the
//!   *observed* completion delay in CS steps (the paper's M) with momentum
//!   β, fed through the [`SamplingPolicy::observe_completion`] channel.
//!   Unlike `adaptive`, which tilts on the queue-length *proxy*, this
//!   closes the loop on the quantity the paper's bound actually controls.
//!   Fenwick-backed: O(log n) per completion, O(log n) per draw.
//! * `delay-adaptive-exact` — same distribution via full renormalization,
//!   O(n) per completion; the oracle `delay-adaptive` is validated
//!   against (`tests/statistical_samplers.rs`).

use crate::bound::{BoundParams, MiSource, TwoClusterStudy};
use crate::util::rng::{u64_to_uniform, AliasTable, Rng};
use crate::util::sampler::{linear_route, masked_linear_route, FenwickSampler};

/// The routing-distribution interface consulted by the simulator.
///
/// Implementors keep `prob_of`/`observe_node`/`route` sublinear in n —
/// they sit on the per-dispatch hot path.  `probs` materializes the full
/// distribution and is for setup and diagnostics only.
pub trait SamplingPolicy {
    /// Display name (curve labels, diagnostics).
    fn name(&self) -> String;

    /// Number of nodes the distribution covers.
    fn n(&self) -> usize;

    /// Normalized selection probability of node i under the distribution
    /// currently in force.  Hot path: O(1) or O(log n).
    fn prob_of(&self, i: usize) -> f64;

    /// Materialize the full distribution in force — O(n), setup and
    /// diagnostics only, never called per dispatch.
    fn probs(&self) -> Vec<f64> {
        (0..self.n()).map(|i| self.prob_of(i)).collect()
    }

    /// Observe all queue lengths right before a routing decision (bulk
    /// path).  Static policies ignore this; adaptive ones recompute their
    /// weights.
    fn observe(&mut self, _queue_lens: &[u32]) {}

    /// Observe that node i's queue length changed to `len` (incremental
    /// path).  Policies that return `true` from [`Self::incremental`]
    /// receive only these point updates — exactly the two queues that
    /// change per CS step — instead of the O(n) bulk `observe`.
    fn observe_node(&mut self, _node: usize, _len: u32) {}

    /// Whether `observe_node` fully covers `observe` for this policy.
    /// When true the simulator skips building the O(n) queue-length
    /// vector on every dispatch.
    fn incremental(&self) -> bool {
        false
    }

    /// Observe one completed task: node `i` finished a task whose delay
    /// was `delay_steps` CS steps (the paper's M) / `delay_time` units of
    /// virtual time.  The delay-feedback channel for delay-adaptive
    /// policies; default no-op.
    ///
    /// Every engine calls this on the central dispatcher path, right
    /// after the completion and before the routing decision it may
    /// influence.  Implementations MUST NOT consume RNG: the hook sits
    /// inside the heap/sharded/batch step loops, whose bit-identity
    /// contract relies on the routing stream decomposing identically
    /// (see `simulator::engine`).
    fn observe_completion(&mut self, _node: usize, _delay_steps: u64, _delay_time: f64) {}

    /// Membership channel, join side: node `node` (re)entered the network
    /// and must return to the routing support, with any per-node adaptive
    /// state (delay EWMA, queue tilt) reset to its fresh-node value.
    ///
    /// Like [`Self::observe_completion`], this fires on the central
    /// dispatcher path of every engine — implementations MUST NOT consume
    /// RNG (enforced statically by `cargo xtask lint` rule R1 and by
    /// debug-build routing-stream fingerprint guards in all three
    /// engines); default no-op for membership-oblivious policies.
    fn observe_join(&mut self, _node: usize) {}

    /// Membership channel, leave side: node `node` departed and must be
    /// removed from the routing support — `route` may never select it and
    /// `prob_of` must report 0 until a matching `observe_join`. Same
    /// RNG-free contract as [`Self::observe_join`].
    fn observe_leave(&mut self, _node: usize) {}

    /// Sample the next node K_{k+1} from the distribution in force.
    fn route(&mut self, rng: &mut Rng) -> usize;

    /// Whether this policy supports the block-resolved routing-draw path:
    /// [`Self::route_prefetched`] fed the routing stream's next raw u64 is
    /// bit-identical (index AND draws consumed) to [`Self::route`].  The
    /// batch arena only prefetches raw draws for policies that opt in;
    /// everything else keeps the scalar path.  Default false so
    /// third-party policies are unaffected.
    fn prefetch_routes(&self) -> bool {
        false
    }

    /// [`Self::route`] with the routing stream's FIRST raw u64 already
    /// drawn (`first` must be the value `rng` would have produced next);
    /// any further draws the sampler needs — alias accept uniforms, rare
    /// Lemire rejections — continue on `rng`.  Only called when
    /// [`Self::prefetch_routes`] returns true; the default is a loud
    /// debug-build assertion with a release-mode fallback that re-routes
    /// scalar-ly (which would skip a stream value — hence the assertion).
    fn route_prefetched(&mut self, _first: u64, rng: &mut Rng) -> usize {
        debug_assert!(
            false,
            "route_prefetched on a policy that does not opt in (prefetch_routes() == false)"
        );
        self.route(rng)
    }
}

// ---------------------------------------------------------------------------
// Static (fixed p) — alias-table sampling, identical to the original engine
// ---------------------------------------------------------------------------

pub struct StaticPolicy {
    label: String,
    p: Vec<f64>,
    alias: AliasTable,
    /// membership mask under churn: `route` restricts to active nodes
    active: Vec<bool>,
    inactive: usize,
    /// Σ p_i over active nodes, maintained incrementally on join/leave —
    /// every engine applies the identical +=/-= sequence, so the drift is
    /// bit-identical and the masked draws stay in lockstep.
    active_mass: f64,
}

impl StaticPolicy {
    pub fn new(p: Vec<f64>) -> Result<StaticPolicy, String> {
        StaticPolicy::labeled("static", p)
    }

    pub fn labeled(label: &str, p: Vec<f64>) -> Result<StaticPolicy, String> {
        let alias = AliasTable::new(&p)?;
        let n = p.len();
        let active_mass = p.iter().sum();
        Ok(StaticPolicy {
            label: label.to_string(),
            p,
            alias,
            active: vec![true; n],
            inactive: 0,
            active_mass,
        })
    }

    pub fn uniform(n: usize) -> Result<StaticPolicy, String> {
        if n == 0 {
            return Err("uniform policy needs n >= 1".into());
        }
        StaticPolicy::labeled("uniform", vec![1.0 / n as f64; n])
    }
}

impl SamplingPolicy for StaticPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn n(&self) -> usize {
        self.p.len()
    }

    fn prob_of(&self, i: usize) -> f64 {
        if self.inactive == 0 {
            self.p[i]
        } else if self.active[i] {
            self.p[i] / self.active_mass
        } else {
            0.0
        }
    }

    fn probs(&self) -> Vec<f64> {
        if self.inactive == 0 {
            self.p.clone()
        } else {
            (0..self.p.len()).map(|i| self.prob_of(i)).collect()
        }
    }

    fn incremental(&self) -> bool {
        // queue lengths never move a static distribution
        true
    }

    fn observe_join(&mut self, node: usize) {
        if self.active[node] {
            return;
        }
        self.active[node] = true;
        self.inactive -= 1;
        self.active_mass += self.p[node];
    }

    fn observe_leave(&mut self, node: usize) {
        if !self.active[node] {
            return;
        }
        self.active[node] = false;
        self.inactive += 1;
        self.active_mass -= self.p[node];
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        if self.inactive == 0 {
            // full membership: the historical O(1) alias path, untouched
            // draw-for-draw (two uniforms per sample)
            self.alias.sample(rng)
        } else {
            // membership-restricted: one-uniform masked CDF scan over the
            // conditioned distribution p_i / active_mass
            masked_linear_route(&self.p, &self.active, self.active_mass, rng.uniform())
        }
    }

    fn prefetch_routes(&self) -> bool {
        true
    }

    fn route_prefetched(&mut self, first: u64, rng: &mut Rng) -> usize {
        if self.inactive == 0 {
            self.alias.sample_prefetched(first, rng)
        } else {
            masked_linear_route(&self.p, &self.active, self.active_mass, u64_to_uniform(first))
        }
    }
}

// ---------------------------------------------------------------------------
// Adaptive queue-length-aware policies: Fenwick-backed (hot path) and the
// exact renormalizing reference
// ---------------------------------------------------------------------------

fn validate_adaptive(base: &[f64], gamma: f64) -> Result<(), String> {
    if base.is_empty() {
        return Err("adaptive policy needs a non-empty base distribution".into());
    }
    if !(gamma >= 0.0) || !gamma.is_finite() {
        return Err(format!("adaptive policy: gamma {gamma} must be finite and >= 0"));
    }
    let sum: f64 = base.iter().sum();
    if (sum - 1.0).abs() > 1e-6 || base.iter().any(|&b| b < 0.0 || !b.is_finite()) {
        return Err(format!("adaptive policy: base p must be a distribution (sum {sum})"));
    }
    Ok(())
}

/// Queue-length-aware sampling with O(log n) per-dispatch cost: the raw
/// weights w_i = base_i · exp(−γ·X_i) live in a [`FenwickSampler`], so a
/// single queue change updates one leaf and a draw is one tree descent —
/// no renormalization ever happens (probabilities are w_i / Σw on read).
///
/// Underflow semantics mirror [`AdaptiveQueuePolicy`] exactly: while
/// *every* tilted weight has underflowed to zero (enormous γ·X on every
/// node), the distribution in force is the base distribution; the moment
/// any node's weight turns positive again the tilted law resumes.  A
/// `positive`-leaf counter makes the check O(1) without mutating the tree.
pub struct FenwickAdaptivePolicy {
    base: Vec<f64>,
    gamma: f64,
    sampler: FenwickSampler,
    /// alias table over the base distribution — the all-underflowed
    /// escape hatch, sampled without touching the tilted weights
    base_alias: AliasTable,
    /// number of leaves with a strictly positive tilted weight
    positive: usize,
    /// membership mask under churn; departed leaves hold weight 0
    active: Vec<bool>,
    inactive: usize,
    /// Σ base_i over active nodes — the mass behind the masked
    /// all-underflowed fallback (the base alias covers departed nodes,
    /// so it is only safe at full membership)
    active_base_mass: f64,
}

impl FenwickAdaptivePolicy {
    pub fn new(base: Vec<f64>, gamma: f64) -> Result<FenwickAdaptivePolicy, String> {
        validate_adaptive(&base, gamma)?;
        let sampler = FenwickSampler::new(&base)?;
        let base_alias = AliasTable::new(&base)?;
        let positive = base.iter().filter(|&&b| b > 0.0).count();
        let n = base.len();
        let active_base_mass = base.iter().sum();
        Ok(FenwickAdaptivePolicy {
            base,
            gamma,
            sampler,
            base_alias,
            positive,
            active: vec![true; n],
            inactive: 0,
            active_base_mass,
        })
    }

    fn tilt(&self, node: usize, len: u32) -> f64 {
        let w = self.base[node] * (-self.gamma * len as f64).exp();
        if w.is_finite() {
            w
        } else {
            0.0
        }
    }

    /// Write `w` into the node's leaf, maintaining the positive-leaf count.
    fn set_weight(&mut self, node: usize, w: f64) {
        let was = self.sampler.weight(node) > 0.0;
        self.sampler.set(node, w);
        match (was, w > 0.0) {
            (true, false) => self.positive -= 1,
            (false, true) => self.positive += 1,
            _ => {}
        }
    }
}

impl SamplingPolicy for FenwickAdaptivePolicy {
    fn name(&self) -> String {
        format!("adaptive(gamma={})", self.gamma)
    }

    fn n(&self) -> usize {
        self.base.len()
    }

    fn prob_of(&self, i: usize) -> f64 {
        if self.positive == 0 {
            // all-underflowed fallback: the (membership-conditioned) base
            if self.inactive == 0 {
                return self.base[i];
            }
            return if self.active[i] {
                self.base[i] / self.active_base_mass
            } else {
                0.0
            };
        }
        self.sampler.weight(i) / self.sampler.total()
    }

    fn observe(&mut self, queue_lens: &[u32]) {
        for (i, &q) in queue_lens.iter().enumerate() {
            self.observe_node(i, q);
        }
    }

    fn observe_node(&mut self, node: usize, len: u32) {
        if !self.active[node] {
            // departed leaves stay pinned at weight 0
            return;
        }
        let w = self.tilt(node, len);
        self.set_weight(node, w);
    }

    fn incremental(&self) -> bool {
        true
    }

    fn observe_join(&mut self, node: usize) {
        if self.active[node] {
            return;
        }
        self.active[node] = true;
        self.inactive -= 1;
        self.active_base_mass += self.base[node];
        // a (re)joined node starts with an empty queue: fresh tilt at X=0
        let w = self.tilt(node, 0);
        self.set_weight(node, w);
    }

    fn observe_leave(&mut self, node: usize) {
        if !self.active[node] {
            return;
        }
        self.active[node] = false;
        self.inactive += 1;
        self.active_base_mass -= self.base[node];
        self.set_weight(node, 0.0);
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        if self.positive == 0 {
            // All-underflowed fallback. At full membership the pre-built
            // base alias is exact; under churn it would put mass on
            // departed nodes (stale support — the mass-collapse bug), so
            // the masked one-uniform scan conditions the base on the
            // active set instead.
            if self.inactive == 0 {
                return self.base_alias.sample(rng);
            }
            return masked_linear_route(
                &self.base,
                &self.active,
                self.active_base_mass,
                rng.uniform(),
            );
        }
        self.sampler.sample(rng)
    }

    fn prefetch_routes(&self) -> bool {
        true
    }

    fn route_prefetched(&mut self, first: u64, rng: &mut Rng) -> usize {
        if self.positive == 0 {
            if self.inactive == 0 {
                return self.base_alias.sample_prefetched(first, rng);
            }
            return masked_linear_route(
                &self.base,
                &self.active,
                self.active_base_mass,
                u64_to_uniform(first),
            );
        }
        self.sampler.sample_prefetched(first)
    }
}

/// The exact adaptive policy: recomputes and renormalizes all n
/// probabilities on every observation and routes by CDF scan — O(n) per
/// dispatch.  Kept as the oracle `adaptive` is validated against and for
/// debugging at small n; registered as `adaptive-exact`.
pub struct AdaptiveQueuePolicy {
    base: Vec<f64>,
    gamma: f64,
    probs: Vec<f64>,
    /// membership mask under churn; departed nodes carry zero probability
    active: Vec<bool>,
}

impl AdaptiveQueuePolicy {
    pub fn new(base: Vec<f64>, gamma: f64) -> Result<AdaptiveQueuePolicy, String> {
        validate_adaptive(&base, gamma)?;
        let n = base.len();
        Ok(AdaptiveQueuePolicy {
            probs: base.clone(),
            active: vec![true; n],
            base,
            gamma,
        })
    }

    /// Zero masked entries and renormalize — keeps `prob_of` coherent
    /// between bulk observations when membership changes.
    fn renormalize_masked(&mut self) {
        let mut total = 0.0f64;
        for (pi, &a) in self.probs.iter_mut().zip(self.active.iter()) {
            if !a {
                *pi = 0.0;
            }
            total += *pi;
        }
        if !(total > 0.0) || !total.is_finite() {
            // masked-base fallback: the active slice of the base
            total = 0.0;
            for (i, pi) in self.probs.iter_mut().enumerate() {
                *pi = if self.active[i] { self.base[i] } else { 0.0 };
                total += *pi;
            }
        }
        for pi in self.probs.iter_mut() {
            *pi /= total;
        }
    }
}

impl SamplingPolicy for AdaptiveQueuePolicy {
    fn name(&self) -> String {
        format!("adaptive-exact(gamma={})", self.gamma)
    }

    fn n(&self) -> usize {
        self.probs.len()
    }

    fn prob_of(&self, i: usize) -> f64 {
        self.probs[i]
    }

    fn probs(&self) -> Vec<f64> {
        self.probs.clone()
    }

    fn observe(&mut self, queue_lens: &[u32]) {
        let mut total = 0.0f64;
        for (i, (pi, (&b, &q))) in self
            .probs
            .iter_mut()
            .zip(self.base.iter().zip(queue_lens.iter()))
            .enumerate()
        {
            *pi = if self.active[i] {
                b * (-self.gamma * q as f64).exp()
            } else {
                0.0
            };
            total += *pi;
        }
        if !(total > 0.0) || !total.is_finite() {
            // all active mass underflowed (enormous γ·X): fall back to
            // the membership-masked base
            total = 0.0;
            for (i, pi) in self.probs.iter_mut().enumerate() {
                *pi = if self.active[i] { self.base[i] } else { 0.0 };
                total += *pi;
            }
        }
        for pi in self.probs.iter_mut() {
            *pi /= total;
        }
    }

    fn observe_join(&mut self, node: usize) {
        if self.active[node] {
            return;
        }
        self.active[node] = true;
        // fresh member, empty queue: tilt at X = 0 is the raw base mass
        self.probs[node] = self.base[node];
        self.renormalize_masked();
    }

    fn observe_leave(&mut self, node: usize) {
        if !self.active[node] {
            return;
        }
        self.active[node] = false;
        self.renormalize_masked();
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        // reference CDF scan (fixed fall-through: never lands on a
        // trailing zero-mass node, see util::sampler::linear_route)
        linear_route(&self.probs, rng.uniform())
    }

    fn prefetch_routes(&self) -> bool {
        true
    }

    fn route_prefetched(&mut self, first: u64, _rng: &mut Rng) -> usize {
        linear_route(&self.probs, u64_to_uniform(first))
    }
}

// ---------------------------------------------------------------------------
// Delay-feedback adaptive policies: Fenwick-backed (hot path) and the exact
// renormalizing reference.  Tilt on the OBSERVED completion delay (EWMA)
// instead of the instantaneous queue length.
// ---------------------------------------------------------------------------

fn validate_delay_adaptive(base: &[f64], gamma: f64, beta: f64) -> Result<(), String> {
    validate_adaptive(base, gamma)?;
    if !(0.0..1.0).contains(&beta) {
        return Err(format!(
            "delay-adaptive policy: EWMA momentum beta {beta} must be in [0, 1)"
        ));
    }
    Ok(())
}

/// Delay-feedback sampling with O(log n) per-event cost
/// (arXiv:2402.11198-style): each completion updates the completed node's
/// delay estimate D̂_i ← β·D̂_i + (1−β)·M and its tilted weight
/// w_i = base_i · exp(−γ·D̂_i) in a [`FenwickSampler`]; a draw is one tree
/// descent.  Queue-length observations are no-ops (`incremental` is true
/// so the engines skip the O(n) bulk vector entirely).
///
/// Underflow semantics mirror the `adaptive` pair: while *every* tilted
/// weight has underflowed to zero, the base distribution is in force via
/// a pre-built alias table; the tilted law resumes the moment any node's
/// weight turns positive again.
pub struct FenwickDelayAdaptivePolicy {
    base: Vec<f64>,
    gamma: f64,
    beta: f64,
    /// per-node EWMA of observed completion delay in CS steps
    ewma: Vec<f64>,
    sampler: FenwickSampler,
    base_alias: AliasTable,
    /// number of leaves with a strictly positive tilted weight
    positive: usize,
    /// membership mask under churn; departed leaves hold weight 0
    active: Vec<bool>,
    inactive: usize,
    /// Σ base_i over active nodes — backs the masked underflow fallback
    active_base_mass: f64,
}

impl FenwickDelayAdaptivePolicy {
    pub fn new(
        base: Vec<f64>,
        gamma: f64,
        beta: f64,
    ) -> Result<FenwickDelayAdaptivePolicy, String> {
        validate_delay_adaptive(&base, gamma, beta)?;
        let sampler = FenwickSampler::new(&base)?;
        let base_alias = AliasTable::new(&base)?;
        let positive = base.iter().filter(|&&b| b > 0.0).count();
        let n = base.len();
        let active_base_mass = base.iter().sum();
        Ok(FenwickDelayAdaptivePolicy {
            base,
            gamma,
            beta,
            ewma: vec![0.0; n],
            sampler,
            base_alias,
            positive,
            active: vec![true; n],
            inactive: 0,
            active_base_mass,
        })
    }

    /// Current per-node delay estimates D̂ (diagnostics and tests).
    pub fn delay_estimates(&self) -> &[f64] {
        &self.ewma
    }

    fn tilt(&self, node: usize) -> f64 {
        let w = self.base[node] * (-self.gamma * self.ewma[node]).exp();
        if w.is_finite() {
            w
        } else {
            0.0
        }
    }

    /// Write `w` into the node's leaf, maintaining the positive-leaf count.
    fn set_weight(&mut self, node: usize, w: f64) {
        let was = self.sampler.weight(node) > 0.0;
        self.sampler.set(node, w);
        match (was, w > 0.0) {
            (true, false) => self.positive -= 1,
            (false, true) => self.positive += 1,
            _ => {}
        }
    }
}

impl SamplingPolicy for FenwickDelayAdaptivePolicy {
    fn name(&self) -> String {
        format!("delay-adaptive(gamma={},beta={})", self.gamma, self.beta)
    }

    fn n(&self) -> usize {
        self.base.len()
    }

    fn prob_of(&self, i: usize) -> f64 {
        if self.positive == 0 {
            // all-underflowed fallback: the (membership-conditioned) base
            if self.inactive == 0 {
                return self.base[i];
            }
            return if self.active[i] {
                self.base[i] / self.active_base_mass
            } else {
                0.0
            };
        }
        self.sampler.weight(i) / self.sampler.total()
    }

    fn incremental(&self) -> bool {
        // queue lengths never move this distribution — only completions do
        true
    }

    fn observe_completion(&mut self, node: usize, delay_steps: u64, _delay_time: f64) {
        if !self.active[node] {
            // departed leaves stay pinned at weight 0
            return;
        }
        self.ewma[node] = self.beta * self.ewma[node] + (1.0 - self.beta) * delay_steps as f64;
        let w = self.tilt(node);
        self.set_weight(node, w);
    }

    fn observe_join(&mut self, node: usize) {
        if self.active[node] {
            return;
        }
        self.active[node] = true;
        self.inactive -= 1;
        self.active_base_mass += self.base[node];
        // a (re)joined node starts with a fresh delay estimate
        self.ewma[node] = 0.0;
        let w = self.tilt(node);
        self.set_weight(node, w);
    }

    fn observe_leave(&mut self, node: usize) {
        if !self.active[node] {
            return;
        }
        self.active[node] = false;
        self.inactive += 1;
        self.active_base_mass -= self.base[node];
        self.set_weight(node, 0.0);
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        if self.positive == 0 {
            // All-underflowed fallback (the delay-adaptive mass-collapse
            // path): exact at full membership via the base alias, but the
            // alias covers departed nodes, so under churn the masked
            // one-uniform scan conditions the base on the active set.
            if self.inactive == 0 {
                return self.base_alias.sample(rng);
            }
            return masked_linear_route(
                &self.base,
                &self.active,
                self.active_base_mass,
                rng.uniform(),
            );
        }
        self.sampler.sample(rng)
    }

    fn prefetch_routes(&self) -> bool {
        true
    }

    fn route_prefetched(&mut self, first: u64, rng: &mut Rng) -> usize {
        if self.positive == 0 {
            if self.inactive == 0 {
                return self.base_alias.sample_prefetched(first, rng);
            }
            return masked_linear_route(
                &self.base,
                &self.active,
                self.active_base_mass,
                u64_to_uniform(first),
            );
        }
        self.sampler.sample_prefetched(first)
    }
}

/// The exact delay-feedback policy: updates the completed node's delay
/// EWMA, then recomputes and renormalizes all n probabilities — O(n) per
/// completion, CDF-scan routing.  The oracle `delay-adaptive` is
/// validated against; registered as `delay-adaptive-exact`.
pub struct DelayAdaptivePolicy {
    base: Vec<f64>,
    gamma: f64,
    beta: f64,
    ewma: Vec<f64>,
    probs: Vec<f64>,
    /// membership mask under churn; departed nodes carry zero probability
    active: Vec<bool>,
}

impl DelayAdaptivePolicy {
    pub fn new(base: Vec<f64>, gamma: f64, beta: f64) -> Result<DelayAdaptivePolicy, String> {
        validate_delay_adaptive(&base, gamma, beta)?;
        let n = base.len();
        Ok(DelayAdaptivePolicy {
            probs: base.clone(),
            ewma: vec![0.0; n],
            active: vec![true; n],
            base,
            gamma,
            beta,
        })
    }

    /// Current per-node delay estimates D̂ (diagnostics and tests).
    pub fn delay_estimates(&self) -> &[f64] {
        &self.ewma
    }

    /// Recompute the full distribution from (base, EWMA, membership) —
    /// shared by the completion and membership channels, RNG-free.
    fn recompute(&mut self) {
        let mut total = 0.0f64;
        for (i, (pi, (&b, &d))) in self
            .probs
            .iter_mut()
            .zip(self.base.iter().zip(self.ewma.iter()))
            .enumerate()
        {
            *pi = if self.active[i] {
                b * (-self.gamma * d).exp()
            } else {
                0.0
            };
            total += *pi;
        }
        if !(total > 0.0) || !total.is_finite() {
            // all active mass underflowed (enormous γ·D̂): fall back to
            // the membership-masked base
            total = 0.0;
            for (i, pi) in self.probs.iter_mut().enumerate() {
                *pi = if self.active[i] { self.base[i] } else { 0.0 };
                total += *pi;
            }
        }
        for pi in self.probs.iter_mut() {
            *pi /= total;
        }
    }
}

impl SamplingPolicy for DelayAdaptivePolicy {
    fn name(&self) -> String {
        format!("delay-adaptive-exact(gamma={},beta={})", self.gamma, self.beta)
    }

    fn n(&self) -> usize {
        self.probs.len()
    }

    fn prob_of(&self, i: usize) -> f64 {
        self.probs[i]
    }

    fn probs(&self) -> Vec<f64> {
        self.probs.clone()
    }

    fn incremental(&self) -> bool {
        true
    }

    fn observe_completion(&mut self, node: usize, delay_steps: u64, _delay_time: f64) {
        if !self.active[node] {
            return;
        }
        self.ewma[node] = self.beta * self.ewma[node] + (1.0 - self.beta) * delay_steps as f64;
        self.recompute();
    }

    fn observe_join(&mut self, node: usize) {
        if self.active[node] {
            return;
        }
        self.active[node] = true;
        // a (re)joined node starts with a fresh delay estimate
        self.ewma[node] = 0.0;
        self.recompute();
    }

    fn observe_leave(&mut self, node: usize) {
        if !self.active[node] {
            return;
        }
        self.active[node] = false;
        self.recompute();
    }

    fn route(&mut self, rng: &mut Rng) -> usize {
        linear_route(&self.probs, rng.uniform())
    }

    fn prefetch_routes(&self) -> bool {
        true
    }

    fn route_prefetched(&mut self, first: u64, _rng: &mut Rng) -> usize {
        linear_route(&self.probs, u64_to_uniform(first))
    }
}

// ---------------------------------------------------------------------------
// Theorem-1 optimal two-cluster policy
// ---------------------------------------------------------------------------

/// Shape of the experiment a policy is built for.  Constructors read what
/// they need and ignore the rest.
#[derive(Clone, Debug)]
pub struct PolicyCtx {
    /// number of clients n
    pub n: usize,
    /// the experiment's base/static distribution (two-cluster tilt etc.)
    pub base_p: Vec<f64>,
    /// queue-pressure / delay-pressure strength for the adaptive and
    /// delay-adaptive policies
    pub gamma: f64,
    /// EWMA momentum for the delay-adaptive policy's delay estimates
    pub beta: f64,
    /// two-cluster shape for the Theorem-1 optimizer
    pub n_fast: usize,
    pub mu_fast: f64,
    pub mu_slow: f64,
    pub concurrency: usize,
    pub steps: u64,
}

/// Package a two-cluster tilt — `p_fast` on each of the first `n_fast`
/// nodes, the leftover mass spread evenly over the slow cluster — as a
/// labeled [`StaticPolicy`], validating the tilt actually leaves a
/// distribution.  `n_fast · p_fast > 1` drives the slow-node mass q
/// negative, which previously surfaced as an opaque `AliasTable`
/// construction error (or, worse, a silently invalid distribution); now
/// it is a clear error naming `p_fast` and `n_fast`.
pub fn two_cluster_static(
    label: &str,
    n: usize,
    n_fast: usize,
    p_fast: f64,
) -> Result<StaticPolicy, String> {
    if n_fast == 0 || n_fast >= n {
        return Err(format!(
            "{label} policy needs a two-cluster population (n_fast {n_fast} of n {n})"
        ));
    }
    if !p_fast.is_finite() || p_fast <= 0.0 {
        return Err(format!(
            "{label} policy: p_fast = {p_fast} must be a positive, finite probability"
        ));
    }
    let q = (1.0 - n_fast as f64 * p_fast) / (n - n_fast) as f64;
    if !(q > 0.0) {
        return Err(format!(
            "{label} policy: p_fast = {p_fast} with n_fast = {n_fast} puts mass \
             n_fast·p_fast = {} on the fast cluster, leaving none for the {} slow \
             nodes (q = {q}); a valid tilt needs n_fast·p_fast < 1",
            n_fast as f64 * p_fast,
            n - n_fast
        ));
    }
    let p: Vec<f64> = (0..n).map(|i| if i < n_fast { p_fast } else { q }).collect();
    StaticPolicy::labeled(label, p)
}

/// Build the bound-optimal static two-cluster policy by sweeping the
/// Theorem-1 optimizer — the exact computation behind the historical
/// `--optimal-p` flag (worked-example constants A=100, B=20, L=1, 50-point
/// log grid), packaged as a [`StaticPolicy`] labeled "optimal".
pub fn optimal_two_cluster(ctx: &PolicyCtx) -> Result<StaticPolicy, String> {
    if ctx.n_fast == 0 || ctx.n_fast >= ctx.n {
        return Err(format!(
            "optimal policy needs a two-cluster population (n_fast {} of n {})",
            ctx.n_fast, ctx.n
        ));
    }
    let study = TwoClusterStudy {
        params: BoundParams {
            a: 100.0,
            b: 20.0,
            l: 1.0,
            c: ctx.concurrency,
            t: ctx.steps,
            n: ctx.n,
        },
        n_fast: ctx.n_fast,
        mu_fast: ctx.mu_fast,
        mu_slow: ctx.mu_slow,
        source: MiSource::default(),
    };
    let (best, _) = study.optimize_p(50)?;
    // validate the optimizer's result instead of trusting it: a p_fast
    // with n_fast·p_fast >= 1 must fail loudly, naming the culprits
    two_cluster_static("optimal", ctx.n, ctx.n_fast, best.p_fast)
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

type PolicyCtor = Box<dyn Fn(&PolicyCtx) -> Result<Box<dyn SamplingPolicy>, String>>;

pub struct PolicyEntry {
    pub name: String,
    pub summary: String,
    ctor: PolicyCtor,
}

/// String → constructor mapping for sampling policies.  `builtin()`
/// carries the paper-relevant policies; downstream code may `register`
/// more without touching the simulator or the CLI.
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    pub fn empty() -> PolicyRegistry {
        PolicyRegistry { entries: Vec::new() }
    }

    pub fn builtin() -> PolicyRegistry {
        let mut r = PolicyRegistry::empty();
        r.register(
            "static",
            "fixed p from the experiment config (two-cluster tilt or explicit vector)",
            |ctx| Ok(Box::new(StaticPolicy::new(ctx.base_p.clone())?) as Box<dyn SamplingPolicy>),
        );
        r.register("uniform", "p_i = 1/n", |ctx| {
            Ok(Box::new(StaticPolicy::uniform(ctx.n)?) as Box<dyn SamplingPolicy>)
        });
        r.register(
            "optimal",
            "Theorem-1 bound-optimal two-cluster p (the old --optimal-p)",
            |ctx| Ok(Box::new(optimal_two_cluster(ctx)?) as Box<dyn SamplingPolicy>),
        );
        r.register(
            "adaptive",
            "queue-length-aware p_i ~ base_i*exp(-gamma*X_i), Fenwick-backed O(log n)",
            |ctx| {
                Ok(Box::new(FenwickAdaptivePolicy::new(ctx.base_p.clone(), ctx.gamma)?)
                    as Box<dyn SamplingPolicy>)
            },
        );
        r.register(
            "adaptive-exact",
            "same distribution as adaptive via O(n) renormalization (test oracle)",
            |ctx| {
                Ok(Box::new(AdaptiveQueuePolicy::new(ctx.base_p.clone(), ctx.gamma)?)
                    as Box<dyn SamplingPolicy>)
            },
        );
        r.register(
            "delay-adaptive",
            "delay-feedback p_i ~ base_i*exp(-gamma*D_i), EWMA(beta) of observed delay, O(log n)",
            |ctx| {
                Ok(Box::new(FenwickDelayAdaptivePolicy::new(
                    ctx.base_p.clone(),
                    ctx.gamma,
                    ctx.beta,
                )?) as Box<dyn SamplingPolicy>)
            },
        );
        r.register(
            "delay-adaptive-exact",
            "same distribution as delay-adaptive via O(n) renormalization (test oracle)",
            |ctx| {
                Ok(Box::new(DelayAdaptivePolicy::new(ctx.base_p.clone(), ctx.gamma, ctx.beta)?)
                    as Box<dyn SamplingPolicy>)
            },
        );
        r
    }

    /// Register (or replace) a policy constructor.
    pub fn register<F>(&mut self, name: &str, summary: &str, ctor: F)
    where
        F: Fn(&PolicyCtx) -> Result<Box<dyn SamplingPolicy>, String> + 'static,
    {
        self.entries.retain(|e| e.name != name);
        self.entries.push(PolicyEntry {
            name: name.to_string(),
            summary: summary.to_string(),
            ctor: Box::new(ctor),
        });
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    pub fn build(&self, name: &str, ctx: &PolicyCtx) -> Result<Box<dyn SamplingPolicy>, String> {
        for e in &self.entries {
            if e.name == name {
                return (e.ctor)(ctx);
            }
        }
        Err(format!(
            "unknown sampling policy '{name}' (available: {})",
            self.names().join("|")
        ))
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    pub fn summaries(&self) -> Vec<(String, String)> {
        self.entries
            .iter()
            .map(|e| (e.name.clone(), e.summary.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(n: usize) -> PolicyCtx {
        PolicyCtx {
            n,
            base_p: vec![1.0 / n as f64; n],
            gamma: 0.5,
            beta: 0.9,
            n_fast: n / 2,
            mu_fast: 4.0,
            mu_slow: 1.0,
            concurrency: 4,
            steps: 200,
        }
    }

    #[test]
    fn static_policy_samples_p() {
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let mut pol = StaticPolicy::new(p.clone()).unwrap();
        assert_eq!(pol.probs(), p);
        assert_eq!(pol.n(), 4);
        assert_eq!(pol.prob_of(2), 0.3);
        assert!(pol.incremental());
        let mut rng = Rng::new(1);
        let mut counts = vec![0u64; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[pol.route(&mut rng)] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - p[i]).abs() < 0.01, "node {i}: freq {f} vs p {}", p[i]);
        }
    }

    #[test]
    fn adaptive_tilts_away_from_long_queues() {
        let mut pol = AdaptiveQueuePolicy::new(vec![0.25; 4], 1.0).unwrap();
        pol.observe(&[0, 0, 5, 0]);
        let p = pol.probs();
        assert!(p[2] < p[0], "loaded node must be sampled less: {p:?}");
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "probs sum {sum}");
        // γ=0 degenerates to the base
        let mut flat = AdaptiveQueuePolicy::new(vec![0.25; 4], 0.0).unwrap();
        flat.observe(&[9, 0, 3, 1]);
        for pi in flat.probs() {
            assert!((pi - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn fenwick_adaptive_matches_exact_distribution() {
        // both implementations realize p_i ∝ base_i·exp(−γX_i); their
        // normalized probabilities must agree to fp precision
        let base = vec![0.1, 0.4, 0.2, 0.3];
        let lens = [3u32, 0, 7, 2];
        let mut exact = AdaptiveQueuePolicy::new(base.clone(), 0.9).unwrap();
        let mut fast = FenwickAdaptivePolicy::new(base, 0.9).unwrap();
        exact.observe(&lens);
        for (i, &l) in lens.iter().enumerate() {
            fast.observe_node(i, l);
        }
        assert!(fast.incremental());
        for i in 0..4 {
            assert!(
                (fast.prob_of(i) - exact.prob_of(i)).abs() < 1e-12,
                "node {i}: {} vs {}",
                fast.prob_of(i),
                exact.prob_of(i)
            );
        }
        let sum: f64 = fast.probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fenwick_adaptive_route_matches_probs() {
        let mut pol = FenwickAdaptivePolicy::new(vec![0.25; 4], 1.0).unwrap();
        pol.observe(&[3, 0, 0, 3]);
        let want = pol.probs();
        let mut rng = Rng::new(7);
        let mut counts = vec![0u64; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[pol.route(&mut rng)] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - want[i]).abs() < 0.01, "node {i}: {f} vs {}", want[i]);
        }
    }

    #[test]
    fn adaptive_route_matches_probs() {
        let mut pol = AdaptiveQueuePolicy::new(vec![0.25; 4], 1.0).unwrap();
        pol.observe(&[3, 0, 0, 3]);
        let want = pol.probs();
        let mut rng = Rng::new(7);
        let mut counts = vec![0u64; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[pol.route(&mut rng)] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - want[i]).abs() < 0.01, "node {i}: {f} vs {}", want[i]);
        }
    }

    #[test]
    fn adaptive_survives_underflow() {
        let mut pol = AdaptiveQueuePolicy::new(vec![0.5, 0.5], 1e6).unwrap();
        pol.observe(&[1000, 1000]);
        let sum: f64 = pol.probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fallback must renormalize: {sum}");
        // Fenwick variant mirrors the exact fallback: while EVERY tilted
        // weight is underflowed the base distribution is in force...
        let mut fast = FenwickAdaptivePolicy::new(vec![0.5, 0.5], 1e6).unwrap();
        fast.observe(&[1000, 1000]);
        let mut rng = Rng::new(3);
        let i = fast.route(&mut rng);
        assert!(i < 2);
        assert!((fast.prob_of(0) - 0.5).abs() < 1e-12, "base in force");
        let sum: f64 = fast.probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fallback must renormalize: {sum}");
        // ...and the tilted law resumes the moment any weight recovers
        fast.observe_node(1, 0);
        assert!((fast.prob_of(1) - 1.0).abs() < 1e-12, "node 1 holds all mass");
        assert_eq!(fast.route(&mut rng), 1);
        // the exact oracle agrees on the recovered state
        pol.observe(&[1000, 0]);
        assert!((pol.prob_of(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn delay_adaptive_tilts_away_from_slow_completions() {
        // feed node 2 a stream of large observed delays: its EWMA grows
        // and its sampling mass shrinks, on BOTH implementations alike
        let base = vec![0.25; 4];
        let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), 0.5, 0.5).unwrap();
        let mut exact = DelayAdaptivePolicy::new(base, 0.5, 0.5).unwrap();
        assert!(fast.incremental() && exact.incremental());
        for _ in 0..5 {
            fast.observe_completion(2, 8, 8.0);
            exact.observe_completion(2, 8, 8.0);
            fast.observe_completion(0, 1, 1.0);
            exact.observe_completion(0, 1, 1.0);
        }
        // closed-form EWMA after five (8, then 1) rounds with beta = 0.5
        let mut d2 = 0.0;
        let mut d0 = 0.0;
        for _ in 0..5 {
            d2 = 0.5 * d2 + 0.5 * 8.0;
            d0 = 0.5 * d0 + 0.5 * 1.0;
        }
        assert!((fast.delay_estimates()[2] - d2).abs() < 1e-12);
        assert!((exact.delay_estimates()[0] - d0).abs() < 1e-12);
        for i in 0..4 {
            assert!(
                (fast.prob_of(i) - exact.prob_of(i)).abs() < 1e-12,
                "node {i}: {} vs {}",
                fast.prob_of(i),
                exact.prob_of(i)
            );
        }
        let p = fast.probs();
        assert!(p[2] < p[1], "delayed node must be sampled less: {p:?}");
        assert!(p[0] < p[1], "mildly delayed node tilts below untouched ones");
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "probs sum {sum}");
        // queue-length observations are no-ops for delay policies
        fast.observe_node(1, 50);
        exact.observe(&[9, 9, 9, 9]);
        for i in 0..4 {
            assert!((fast.prob_of(i) - p[i]).abs() < 1e-15);
        }
    }

    #[test]
    fn delay_adaptive_routes_match_probs() {
        let mut pol = FenwickDelayAdaptivePolicy::new(vec![0.25; 4], 0.3, 0.8).unwrap();
        for _ in 0..10 {
            pol.observe_completion(3, 12, 12.0);
            pol.observe_completion(1, 2, 2.0);
        }
        let want = pol.probs();
        let mut rng = Rng::new(19);
        let mut counts = vec![0u64; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[pol.route(&mut rng)] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - want[i]).abs() < 0.01, "node {i}: {f} vs {}", want[i]);
        }
    }

    #[test]
    fn delay_adaptive_survives_underflow() {
        // enormous γ·D̂ on every node underflows every tilted weight: the
        // base distribution must take over, and the tilted law must resume
        // the moment one node's estimate recovers (beta = 0 tracks the
        // last observation exactly, which makes recovery immediate)
        let mut fast = FenwickDelayAdaptivePolicy::new(vec![0.5, 0.5], 1e6, 0.0).unwrap();
        let mut exact = DelayAdaptivePolicy::new(vec![0.5, 0.5], 1e6, 0.0).unwrap();
        for pol in [&mut fast as &mut dyn SamplingPolicy, &mut exact] {
            pol.observe_completion(0, 1000, 1000.0);
            pol.observe_completion(1, 1000, 1000.0);
            let sum: f64 = pol.probs().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "fallback must renormalize: {sum}");
            assert!((pol.prob_of(0) - 0.5).abs() < 1e-12, "base in force");
            pol.observe_completion(1, 0, 0.0);
            assert!((pol.prob_of(1) - 1.0).abs() < 1e-12, "node 1 holds all mass");
        }
        let mut rng = Rng::new(3);
        assert_eq!(fast.route(&mut rng), 1);
        assert_eq!(exact.route(&mut rng), 1);
    }

    #[test]
    fn delay_adaptive_validates() {
        assert!(FenwickDelayAdaptivePolicy::new(vec![0.5, 0.5], 0.5, 1.0).is_err());
        assert!(FenwickDelayAdaptivePolicy::new(vec![0.5, 0.5], 0.5, -0.1).is_err());
        assert!(FenwickDelayAdaptivePolicy::new(vec![0.5, 0.5], -1.0, 0.5).is_err());
        assert!(DelayAdaptivePolicy::new(vec![0.5, 0.5], 0.5, f64::NAN).is_err());
        assert!(DelayAdaptivePolicy::new(vec![0.9, 0.4], 0.5, 0.5).is_err());
        assert!(FenwickDelayAdaptivePolicy::new(vec![0.5, 0.5], 0.0, 0.0).is_ok());
    }

    #[test]
    fn two_cluster_static_rejects_overweight_fast_cluster() {
        // the historical failure: n_fast·p_fast > 1 drove the slow-node
        // mass negative and died inside AliasTable with an opaque message
        let err = two_cluster_static("optimal", 10, 4, 0.3).unwrap_err();
        assert!(err.contains("p_fast = 0.3"), "{err}");
        assert!(err.contains("n_fast = 4"), "{err}");
        assert!(err.contains("slow"), "{err}");
        // boundary: n_fast·p_fast == 1 leaves exactly zero slow mass
        assert!(two_cluster_static("optimal", 10, 4, 0.25).is_err());
        // malformed optimizer outputs are named, not propagated as NaN
        assert!(two_cluster_static("optimal", 10, 4, f64::NAN).is_err());
        assert!(two_cluster_static("optimal", 10, 4, -0.1).is_err());
        assert!(two_cluster_static("optimal", 10, 0, 0.1).is_err());
        // a valid tilt still builds
        let pol = two_cluster_static("optimal", 10, 4, 0.05).unwrap();
        let p = pol.probs();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((p[9] - (1.0 - 4.0 * 0.05) / 6.0).abs() < 1e-12);
    }

    #[test]
    fn optimal_policy_tilts_below_uniform() {
        // the paper's headline: fast clients sampled LESS than uniformly
        let c = ctx(20);
        let pol = optimal_two_cluster(&c).unwrap();
        assert_eq!(pol.name(), "optimal");
        let p = pol.probs();
        assert_eq!(p.len(), 20);
        assert!(p[0] < 1.0 / 20.0, "fast p {} should be below uniform", p[0]);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        // one-cluster population is rejected
        let mut bad = ctx(20);
        bad.n_fast = 0;
        assert!(optimal_two_cluster(&bad).is_err());
    }

    #[test]
    fn registry_builds_every_builtin() {
        let reg = PolicyRegistry::builtin();
        assert_eq!(
            reg.names(),
            vec![
                "static",
                "uniform",
                "optimal",
                "adaptive",
                "adaptive-exact",
                "delay-adaptive",
                "delay-adaptive-exact"
            ]
        );
        let c = ctx(10);
        for name in reg.names() {
            let pol = reg.build(&name, &c).unwrap();
            let sum: f64 = pol.probs().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{name}: probs sum {sum}");
            assert_eq!(pol.n(), 10, "{name}");
        }
        let err = reg.build("zipf", &c).unwrap_err();
        assert!(err.contains("unknown sampling policy"), "{err}");
        assert!(err.contains("adaptive"), "error must list names: {err}");
    }

    #[test]
    fn static_policy_masks_departed_nodes() {
        let p = vec![0.1, 0.2, 0.3, 0.4];
        let mut pol = StaticPolicy::new(p.clone()).unwrap();
        pol.observe_leave(3);
        assert_eq!(pol.prob_of(3), 0.0);
        let mass: f64 = 0.1 + 0.2 + 0.3;
        assert!((pol.prob_of(1) - 0.2 / mass).abs() < 1e-12);
        assert!((pol.probs().iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let mut rng = Rng::new(5);
        for _ in 0..20_000 {
            assert_ne!(pol.route(&mut rng), 3, "departed node routed");
        }
        // idempotent double-leave, then a join restores the exact p
        pol.observe_leave(3);
        pol.observe_join(3);
        pol.observe_join(3);
        assert_eq!(pol.probs(), p);
    }

    #[test]
    fn adaptive_policies_mask_departed_nodes() {
        let base = vec![0.25; 4];
        let mut fast = FenwickAdaptivePolicy::new(base.clone(), 0.5).unwrap();
        let mut exact = AdaptiveQueuePolicy::new(base, 0.5).unwrap();
        fast.observe_leave(1);
        exact.observe_leave(1);
        exact.observe(&[2, 0, 1, 0]);
        fast.observe(&[2, 0, 1, 0]);
        assert_eq!(fast.prob_of(1), 0.0);
        assert_eq!(exact.prob_of(1), 0.0);
        // observing the departed node's queue must not resurrect it
        fast.observe_node(1, 0);
        assert_eq!(fast.prob_of(1), 0.0);
        let mut rng = Rng::new(9);
        for _ in 0..20_000 {
            assert_ne!(fast.route(&mut rng), 1);
            assert_ne!(exact.route(&mut rng), 1);
        }
        // a join brings the node back with a fresh (empty-queue) tilt
        fast.observe_join(1);
        assert!(fast.prob_of(1) > 0.0);
    }

    #[test]
    fn underflow_fallback_respects_membership() {
        // the satellite bug: with every tilted weight underflowed AND a
        // departed node, the fallback used to sample the FULL base alias,
        // routing to the departed node
        let base = vec![0.25; 4];
        for leave_first in [true, false] {
            let mut pol = FenwickAdaptivePolicy::new(base.clone(), 1e6).unwrap();
            if leave_first {
                pol.observe_leave(2);
                pol.observe(&[1000, 1000, 0, 1000]);
            } else {
                pol.observe(&[1000, 1000, 1000, 1000]);
                pol.observe_leave(2);
            }
            assert_eq!(pol.prob_of(2), 0.0);
            assert!((pol.prob_of(0) - 1.0 / 3.0).abs() < 1e-12, "masked base");
            let mut rng = Rng::new(11);
            for _ in 0..20_000 {
                let dest = pol.route(&mut rng);
                assert_ne!(dest, 2, "mass-collapse fallback routed to a departed node");
            }
        }
        // same collapse on the delay-feedback pair
        let mut fast = FenwickDelayAdaptivePolicy::new(base.clone(), 1e6, 0.0).unwrap();
        let mut exact = DelayAdaptivePolicy::new(base, 1e6, 0.0).unwrap();
        for pol in [&mut fast as &mut dyn SamplingPolicy, &mut exact] {
            pol.observe_leave(0);
            for i in 1..4 {
                pol.observe_completion(i, 1000, 1000.0);
            }
            assert_eq!(pol.prob_of(0), 0.0);
            let mut rng = Rng::new(13);
            for _ in 0..20_000 {
                assert_ne!(pol.route(&mut rng), 0);
            }
            // completions reported for a departed node are ignored
            pol.observe_completion(0, 1, 1.0);
            assert_eq!(pol.prob_of(0), 0.0);
            // rejoining resets the delay estimate: fresh node, full tilt
            pol.observe_join(0);
            assert!((pol.prob_of(0) - 1.0).abs() < 1e-12, "rejoined node holds the only live mass");
        }
        assert_eq!(fast.delay_estimates()[0], 0.0);
        assert_eq!(exact.delay_estimates()[0], 0.0);
    }

    #[test]
    fn route_prefetched_is_bit_identical_to_route() {
        // every built-in opts into the block-resolved routing path; feeding
        // route_prefetched the raw u64 the scalar stream would have drawn
        // must reproduce the same index AND leave the generator at the
        // same position, in every reachable sampler state: full
        // membership, masked membership, and the all-underflowed fallback
        fn mk(name: &str, base: &[f64], gamma: f64) -> Box<dyn SamplingPolicy> {
            let b = base.to_vec();
            match name {
                "static" => Box::new(StaticPolicy::new(b).unwrap()),
                "adaptive" => Box::new(FenwickAdaptivePolicy::new(b, gamma).unwrap()),
                "adaptive-exact" => Box::new(AdaptiveQueuePolicy::new(b, gamma).unwrap()),
                "delay-adaptive" => {
                    Box::new(FenwickDelayAdaptivePolicy::new(b, gamma, 0.0).unwrap())
                }
                _ => Box::new(DelayAdaptivePolicy::new(b, gamma, 0.0).unwrap()),
            }
        }
        fn check(name: &str, state: &str, pol: &mut dyn SamplingPolicy, seed: u64) {
            assert!(pol.prefetch_routes(), "{name} must opt in");
            let mut scalar = Rng::new(seed);
            let mut pre = scalar.clone();
            for k in 0..5_000 {
                let want = pol.route(&mut scalar);
                let first = pre.next_u64();
                let got = pol.route_prefetched(first, &mut pre);
                assert_eq!(got, want, "{name} {state} draw {k}");
                assert_eq!(
                    pre.state_fingerprint(),
                    scalar.state_fingerprint(),
                    "{name} {state} draw {k}: stream position diverged"
                );
            }
        }
        let base = [0.1, 0.2, 0.3, 0.4];
        let names = [
            "static",
            "adaptive",
            "adaptive-exact",
            "delay-adaptive",
            "delay-adaptive-exact",
        ];
        for name in names {
            // fresh distribution
            let mut pol = mk(name, &base, 0.7);
            check(name, "fresh", pol.as_mut(), 0xCAFE);
            // tilted + membership-masked
            let mut pol = mk(name, &base, 0.7);
            pol.observe(&[2, 0, 5, 1]);
            pol.observe_completion(2, 7, 7.0);
            pol.observe_leave(3);
            check(name, "masked", pol.as_mut(), 0xCAFF);
            // all-underflowed fallback (static has no such state)
            if name != "static" {
                let mut pol = mk(name, &base, 1e6);
                pol.observe(&[1000; 4]);
                for i in 0..4 {
                    pol.observe_completion(i, 1000, 1000.0);
                }
                check(name, "underflow", pol.as_mut(), 0xCB00);
            }
        }
    }

    #[test]
    fn registry_accepts_third_party_policies() {
        let mut reg = PolicyRegistry::builtin();
        reg.register("slowest-first", "always node n-1 (test double)", |c| {
            struct SlowestFirst {
                p: Vec<f64>,
            }
            impl SamplingPolicy for SlowestFirst {
                fn name(&self) -> String {
                    "slowest-first".into()
                }
                fn n(&self) -> usize {
                    self.p.len()
                }
                fn prob_of(&self, i: usize) -> f64 {
                    self.p[i]
                }
                fn route(&mut self, _rng: &mut Rng) -> usize {
                    self.p.len() - 1
                }
            }
            let mut p = vec![0.0; c.n];
            p[c.n - 1] = 1.0;
            Ok(Box::new(SlowestFirst { p }) as Box<dyn SamplingPolicy>)
        });
        let mut pol = reg.build("slowest-first", &ctx(6)).unwrap();
        let mut rng = Rng::new(3);
        assert_eq!(pol.route(&mut rng), 5);
    }
}
