//! `fedqueue serve` — the event-driven coordinator service mode.
//!
//! Every other mode in this repo *replays* a precomputed schedule; this
//! one *reacts*.  Simulated clients run as spawned futures on the
//! deterministic single-threaded executor (`runtime::executor`), and the
//! coordinator makes a live decision per dispatch:
//!
//! 1. **Estimate** — per client, two EWMA estimators track observed
//!    queue time (everything between dispatch and the gradient landing
//!    that is not compute) and compute time.
//! 2. **Admit** — time is divided into synchronization windows of
//!    length `t_sync`.  A dispatch whose estimated round trip fits in
//!    the current window (plus an `admission_tolerance` slack, plus a
//!    `safety_buffer` margin) goes out immediately; otherwise it is
//!    deferred to the next window boundary — never further, so progress
//!    is guaranteed even when every estimate blows the window.  During
//!    a client's `warm_up` first completions there is no trusted
//!    estimate and dispatches are unconditional.  The shape follows
//!    APPFL's `QueueScheduler` (t_sync windows, warm-up, safety
//!    buffer).
//! 3. **Aggregate** — completions feed the unchanged
//!    [`ServerStrategy`]/[`SamplingPolicy`] registries: the strategy's
//!    `on_gradient` sees real dispatch-time probabilities and staleness,
//!    and the policy's `observe_completion` channel (RNG-free, lint
//!    rule R1) drives `delay-adaptive` sampling exactly as in the
//!    offline engines.
//!
//! Determinism: the executor's virtual clock orders all events by
//! `(time, registration sequence)`; compute draws are keyed per
//! `(client, per-client dispatch index)` on a serve-private stream, and
//! the routing RNG is consumed in completion order — so the
//! [`ServeReport`]'s deterministic core (`to_json_deterministic`) is
//! bit-identical across runs on a shared seed.  Wall-clock throughput
//! (dispatches/sec) lives only in the full report's `perf` block.
//!
//! [`ServerStrategy`]: crate::fl::ServerStrategy
//! [`SamplingPolicy`]: crate::coordinator::policy::SamplingPolicy

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

use crate::coordinator::experiment::{
    two_cluster_n_fast, two_cluster_p, two_cluster_rates, Experiment,
};
use crate::coordinator::policy::{PolicyCtx, PolicyRegistry, SamplingPolicy};
use crate::fl::{GradientCtx, ModelState, ServerStrategy, StrategyParams, StrategyRegistry};
use crate::runtime::executor::{Executor, Handle};
use crate::util::json::Json;
use crate::util::rng::{stream_seed, Rng};
use crate::util::stats::{Ewma, Welford};
use crate::util::toml::Value;

/// Serve-private RNG stream tags (fully separate from the offline
/// engines' routing/service/churn streams).
const SERVE_ROUTE_STREAM: u64 = 0x5E_47_E0;
const SERVE_SERVICE_STREAM: u64 = 0x5E_47_E1;
const SERVE_JOIN_STREAM: u64 = 0x5E_47_E2;

/// Width of the stand-in model the strategies aggregate into.  Serve
/// mode exercises version counting, staleness damping, and IPW scaling
/// — not learning — so the tensor is tiny and the gradients are zero.
const SERVE_MODEL_DIM: usize = 8;

/// Every key the `[serve]` TOML table accepts, in documentation order.
/// `docs/SCENARIOS.md` must list each of these (pinned by
/// `tests/scenario_lint.rs`).
pub const SERVE_KEYS: &[&str] = &[
    "t_sync",
    "warm_up",
    "alpha_queue",
    "alpha_compute",
    "safety_buffer",
    "admission_tolerance",
    "server_time",
    "ramp_time",
];

/// Admission-control knobs for serve mode (the `[serve]` TOML table).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Synchronization-window length in virtual time.
    pub t_sync: f64,
    /// Completions a client must report before its estimates are
    /// trusted; until then dispatches to it are unconditional.
    pub warm_up: u64,
    /// EWMA weight for queue-time observations, in (0, 1].
    pub alpha_queue: f64,
    /// EWMA weight for compute-time observations, in (0, 1].
    pub alpha_compute: f64,
    /// Fixed margin added to the round-trip estimate before the window
    /// check.
    pub safety_buffer: f64,
    /// Fraction of `t_sync` a round trip may overshoot the window
    /// boundary and still be admitted; also sets each task's deadline.
    pub admission_tolerance: f64,
    /// Server-side processing time per gradient (sequential, FIFO) —
    /// the source of observable queue time at high concurrency.
    pub server_time: f64,
    /// When > 0, every odd-indexed client starts outside the network
    /// (`observe_leave`) and joins at a seeded uniform time in
    /// `[0, ramp_time)` — the mid-window-join path.
    pub ramp_time: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            t_sync: 50.0,
            warm_up: 3,
            alpha_queue: 0.5,
            alpha_compute: 0.5,
            safety_buffer: 0.0,
            admission_tolerance: 0.15,
            server_time: 0.01,
            ramp_time: 0.0,
        }
    }
}

impl ServeConfig {
    /// Parse a `[serve]` table.  This function is the single authority
    /// on the table's keys (mirroring `ChurnConfig::from_toml_table`):
    /// `Experiment::from_toml` and `SweepSpec::from_toml` both delegate
    /// here.
    pub fn from_toml_table(tbl: &BTreeMap<String, Value>) -> Result<ServeConfig, String> {
        let mut cfg = ServeConfig::default();
        let num = |k: &str, v: &Value| {
            v.as_f64().ok_or_else(|| format!("[serve] {k} must be a number"))
        };
        let count = |k: &str, v: &Value| -> Result<u64, String> {
            match v.as_i64() {
                Some(i) if i >= 0 => Ok(i as u64),
                _ => Err(format!("[serve] {k} must be a non-negative integer")),
            }
        };
        for (k, v) in tbl {
            match k.as_str() {
                "t_sync" => cfg.t_sync = num(k, v)?,
                "warm_up" => cfg.warm_up = count(k, v)?,
                "alpha_queue" => cfg.alpha_queue = num(k, v)?,
                "alpha_compute" => cfg.alpha_compute = num(k, v)?,
                "safety_buffer" => cfg.safety_buffer = num(k, v)?,
                "admission_tolerance" => cfg.admission_tolerance = num(k, v)?,
                "server_time" => cfg.server_time = num(k, v)?,
                "ramp_time" => cfg.ramp_time = num(k, v)?,
                other => {
                    return Err(format!(
                        "unknown key '{other}' in [serve] ({})",
                        SERVE_KEYS.join("|")
                    ))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation (positivity/finiteness of every knob).
    pub fn validate(&self) -> Result<(), String> {
        if !(self.t_sync > 0.0) || !self.t_sync.is_finite() {
            return Err(format!("[serve] t_sync {} must be finite and > 0", self.t_sync));
        }
        for (name, a) in [("alpha_queue", self.alpha_queue), ("alpha_compute", self.alpha_compute)]
        {
            if !(a > 0.0 && a <= 1.0) {
                return Err(format!("[serve] {name} {a} must be in (0, 1]"));
            }
        }
        for (name, x) in [
            ("safety_buffer", self.safety_buffer),
            ("admission_tolerance", self.admission_tolerance),
            ("server_time", self.server_time),
            ("ramp_time", self.ramp_time),
        ] {
            if !(x >= 0.0) || !x.is_finite() {
                return Err(format!("[serve] {name} {x} must be finite and >= 0"));
            }
        }
        Ok(())
    }
}

/// Outcome of one admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// No trusted estimate yet — dispatched unconditionally.
    Warm,
    /// Estimated round trip fits the current window — dispatched now.
    Admitted,
    /// Estimate blows the window — delivery deferred to the next
    /// window boundary (never further, so progress is guaranteed).
    Deferred,
}

/// End of the synchronization window containing time `t`.
fn window_end_of(t: f64, t_sync: f64) -> f64 {
    (t / t_sync).floor() * t_sync + t_sync
}

/// The pure admission decision: given the current virtual time and the
/// coordinator's round-trip estimate for the target client (`None`
/// while the client is warming up), decide when the task is delivered.
/// Returns the classification and the delivery time (`now` for
/// `Warm`/`Admitted`, the next window boundary for `Deferred`).
pub fn decide_dispatch(cfg: &ServeConfig, now: f64, estimate: Option<f64>) -> (Admission, f64) {
    match estimate {
        None => (Admission::Warm, now),
        Some(est) => {
            let window_end = window_end_of(now, cfg.t_sync);
            let slack = cfg.admission_tolerance * cfg.t_sync;
            if now + est + cfg.safety_buffer <= window_end + slack {
                (Admission::Admitted, now)
            } else {
                (Admission::Deferred, window_end)
            }
        }
    }
}

/// Everything needed to run one serve session.  Built from an
/// [`Experiment`] (CLI path) or assembled directly (sweep path, tests).
#[derive(Clone, Debug)]
pub struct ServeSetup {
    /// Number of simulated clients n.
    pub clients: usize,
    /// Tasks kept in flight (initial dispatch fan-out C).
    pub concurrency: usize,
    /// Total dispatch budget — the serve analogue of `steps`.
    pub dispatches: u64,
    /// Fraction of clients in the slow cluster (rate 1).
    pub slow_fraction: f64,
    /// Compute rate of the fast cluster.
    pub mu_fast: f64,
    /// Optional per-fast-node sampling tilt (None = uniform).
    pub p_fast: Option<f64>,
    /// Queue/delay-pressure strength for the adaptive policies.
    pub gamma: f64,
    /// EWMA momentum for the delay-adaptive policy.
    pub beta: f64,
    /// Server learning rate (strategies).
    pub eta: f64,
    /// Staleness-damping strength for `genasync-damped`.
    pub kappa: f64,
    /// Sampling-policy registry name.
    pub policy: String,
    /// Server-strategy registry name.
    pub algo: String,
    /// Root seed for the serve-private RNG streams.
    pub seed: u64,
    /// Admission-control knobs.
    pub cfg: ServeConfig,
}

impl ServeSetup {
    /// Build from a parsed scenario (the `fedqueue serve` CLI path).
    /// `steps` becomes the dispatch budget; a missing `[serve]` table
    /// means default admission knobs.
    pub fn from_experiment(exp: &Experiment) -> ServeSetup {
        ServeSetup {
            clients: exp.n_clients,
            concurrency: exp.concurrency,
            dispatches: exp.steps,
            slow_fraction: exp.slow_fraction,
            mu_fast: exp.mu_fast,
            p_fast: exp.p_fast,
            gamma: exp.gamma,
            beta: exp.beta,
            eta: exp.eta,
            kappa: exp.kappa,
            policy: exp.policy.clone(),
            algo: exp.algo.clone(),
            seed: exp.seed,
            cfg: exp.serve.clone().unwrap_or_default(),
        }
    }

    /// Structural validation; policy/algo names are checked against the
    /// registries when [`ServeSetup::run`] builds them.
    pub fn validate(&self) -> Result<(), String> {
        if self.clients == 0 {
            return Err("serve: clients must be >= 1".into());
        }
        if self.concurrency == 0 {
            return Err("serve: concurrency must be >= 1".into());
        }
        if self.dispatches == 0 {
            return Err("serve: dispatch budget (steps) must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.slow_fraction) {
            return Err(format!("serve: slow_fraction {} not in [0,1]", self.slow_fraction));
        }
        if !(self.mu_fast > 0.0) || !self.mu_fast.is_finite() {
            return Err(format!("serve: mu_fast {} must be finite and > 0", self.mu_fast));
        }
        self.cfg.validate()
    }

    fn policy_ctx(&self) -> Result<PolicyCtx, String> {
        Ok(PolicyCtx {
            n: self.clients,
            base_p: two_cluster_p(self.clients, self.slow_fraction, self.p_fast),
            gamma: self.gamma,
            beta: self.beta,
            n_fast: two_cluster_n_fast(self.clients, self.slow_fraction),
            mu_fast: self.mu_fast,
            mu_slow: 1.0,
            concurrency: self.concurrency,
            steps: self.dispatches,
        })
    }

    /// Run the serve session to quiescence and return its report.
    pub fn run(&self) -> Result<ServeReport, String> {
        self.validate()?;
        let ctx = self.policy_ctx()?;
        let policy = PolicyRegistry::builtin().build(&self.policy, &ctx)?;
        let mut params = StrategyParams::new(self.eta, policy.probs());
        params.kappa = self.kappa;
        let strategy = StrategyRegistry::builtin().build(&self.algo, &params)?;
        let policy_name = policy.name();
        let algo_name = strategy.name().to_string();

        let exec = Executor::new();
        let h = exec.handle();
        let n = self.clients;
        let cfg = self.cfg.clone();

        let mut st = ServeState {
            cfg: cfg.clone(),
            policy,
            strategy,
            model: ModelState {
                tensors: vec![vec![0.0f32; SERVE_MODEL_DIM]],
                shapes: vec![vec![SERVE_MODEL_DIM]],
            },
            grads: vec![vec![0.0f32; SERVE_MODEL_DIM]],
            route_rng: Rng::new(stream_seed(self.seed, &[SERVE_ROUTE_STREAM])),
            service_root: stream_seed(self.seed, &[SERVE_SERVICE_STREAM]),
            rates: two_cluster_rates(self.clients, self.slow_fraction, self.mu_fast),
            clients: (0..n)
                .map(|_| ClientState {
                    inbox: VecDeque::new(),
                    waker: None,
                    ewma_queue: Ewma::new(cfg.alpha_queue),
                    ewma_compute: Ewma::new(cfg.alpha_compute),
                    completions: 0,
                    dispatches: 0,
                })
                .collect(),
            budget: self.dispatches,
            dispatched: 0,
            completed: 0,
            server_free: 0.0,
            warm: 0,
            admitted: 0,
            deferred: 0,
            deadline_misses: 0,
            joins: 0,
            delay_w: Welford::new(),
            queue_w: Welford::new(),
            compute_w: Welford::new(),
            est_err_w: Welford::new(),
        };

        // Ramp: odd-indexed clients start outside the network and join
        // at seeded times; even-indexed clients anchor both clusters so
        // the initial routing distribution always has support.
        let join_root = stream_seed(self.seed, &[SERVE_JOIN_STREAM]);
        let join_at: Vec<f64> = (0..n)
            .map(|i| {
                if cfg.ramp_time > 0.0 && i % 2 == 1 {
                    cfg.ramp_time * Rng::new(stream_seed(join_root, &[i as u64])).uniform()
                } else {
                    -1.0
                }
            })
            .collect();
        #[cfg(debug_assertions)]
        let route_fp = st.route_rng.state_fingerprint();
        for (i, at) in join_at.iter().enumerate() {
            if *at >= 0.0 {
                st.policy.observe_leave(i);
            }
        }
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            st.route_rng.state_fingerprint(),
            "observe_leave moved the routing stream (policy '{}')",
            st.policy.name()
        );

        let st = Rc::new(RefCell::new(st));
        for (i, at) in join_at.into_iter().enumerate() {
            exec.spawn(client_loop(h.clone(), Rc::clone(&st), i, at));
        }
        // Initial fan-out: C tasks routed at t = 0, all through the
        // same admission path completions use later.
        let fan_out = (self.concurrency as u64).min(self.dispatches);
        for _ in 0..fan_out {
            route_and_dispatch(&st, &h, 0.0);
        }

        let wall_start = std::time::Instant::now(); // lint-allow(R3): wall clock feeds only the perf block, which to_json_deterministic() excludes from the comparison payload
        exec.run();
        let wall_secs = wall_start.elapsed().as_secs_f64();

        let g = st.borrow();
        debug_assert_eq!(g.completed, g.dispatched, "serve run did not drain");
        let virtual_time = exec.now();
        Ok(ServeReport {
            setup: self.clone(),
            policy_name,
            algo_name,
            dispatched: g.dispatched,
            completed: g.completed,
            versions: g.strategy.version(),
            received: g.strategy.received(),
            warm: g.warm,
            admitted: g.admitted,
            deferred: g.deferred,
            deadline_misses: g.deadline_misses,
            joins: g.joins,
            virtual_time,
            windows: (virtual_time / self.cfg.t_sync).floor() as u64 + 1,
            delay: g.delay_w.clone(),
            queue_time: g.queue_w.clone(),
            compute_time: g.compute_w.clone(),
            estimate_abs_err: g.est_err_w.clone(),
            wall_secs,
        })
    }
}

/// One in-flight task, created at routing time and consumed at
/// completion time.
#[derive(Clone, Copy, Debug)]
struct TaskMsg {
    /// Virtual time the admission decision scheduled delivery for.
    dispatch_time: f64,
    /// Policy probability of the target at routing time (IPW channel).
    dispatch_prob: f64,
    /// Strategy version at routing time (staleness channel).
    version_at_dispatch: u64,
    /// Deadline: end of the delivery window plus the tolerance slack.
    deadline: f64,
}

/// Per-client coordinator state: the inbox models the client's task
/// queue, the waker parks its future between tasks.
struct ClientState {
    inbox: VecDeque<TaskMsg>,
    waker: Option<Waker>,
    ewma_queue: Ewma,
    ewma_compute: Ewma,
    completions: u64,
    /// Per-client dispatch counter k — the second tag of the keyed
    /// compute draw, so draws are independent of scheduling order.
    dispatches: u64,
}

/// Shared coordinator state, behind `Rc<RefCell<…>>` so every client
/// future reaches it.
struct ServeState {
    cfg: ServeConfig,
    policy: Box<dyn SamplingPolicy>,
    strategy: Box<dyn ServerStrategy>,
    model: ModelState,
    grads: Vec<Vec<f32>>,
    route_rng: Rng,
    service_root: u64,
    rates: Vec<f64>,
    clients: Vec<ClientState>,
    budget: u64,
    dispatched: u64,
    completed: u64,
    /// Virtual time until which the (sequential) server is busy — the
    /// FIFO bookkeeping that turns concurrency into queue time.
    server_free: f64,
    warm: u64,
    admitted: u64,
    deferred: u64,
    deadline_misses: u64,
    joins: u64,
    delay_w: Welford,
    queue_w: Welford,
    compute_w: Welford,
    est_err_w: Welford,
}

/// Future resolving to the client's next task: pops the inbox or parks
/// the client's waker.
struct NextTask {
    st: Rc<RefCell<ServeState>>,
    client: usize,
}

impl Future for NextTask {
    type Output = TaskMsg;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<TaskMsg> {
        let this = self.get_mut();
        let mut g = this.st.borrow_mut();
        let c = &mut g.clients[this.client];
        match c.inbox.pop_front() {
            Some(msg) => Poll::Ready(msg),
            None => {
                c.waker = Some(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

/// Push a task into client `j`'s inbox and wake its future.
fn deliver(st: &Rc<RefCell<ServeState>>, j: usize, msg: TaskMsg) {
    let waker = {
        let mut g = st.borrow_mut();
        let c = &mut g.clients[j];
        c.inbox.push_back(msg);
        c.waker.take()
    };
    if let Some(w) = waker {
        w.wake();
    }
}

/// Route the next dispatch (if budget remains) and deliver it now or,
/// when the admission controller defers, at the next window boundary.
fn route_and_dispatch(st: &Rc<RefCell<ServeState>>, h: &Handle, now: f64) {
    let decision = {
        let mut g = st.borrow_mut();
        let s = &mut *g;
        if s.budget == 0 {
            return;
        }
        s.budget -= 1;
        // Contract order (matches the offline engines): the completion
        // callback has already fired, so routing sees updated weights.
        let j = s.policy.route(&mut s.route_rng);
        let estimate = {
            let c = &s.clients[j];
            if c.completions >= s.cfg.warm_up {
                match (c.ewma_queue.estimate(), c.ewma_compute.estimate()) {
                    (Some(q), Some(cp)) => Some(q + cp),
                    _ => None,
                }
            } else {
                None
            }
        };
        let (adm, at) = decide_dispatch(&s.cfg, now, estimate);
        match adm {
            Admission::Warm => s.warm += 1,
            Admission::Admitted => s.admitted += 1,
            Admission::Deferred => s.deferred += 1,
        }
        s.dispatched += 1;
        let msg = TaskMsg {
            dispatch_time: at,
            dispatch_prob: s.policy.prob_of(j),
            version_at_dispatch: s.strategy.version(),
            deadline: window_end_of(at, s.cfg.t_sync)
                + s.cfg.admission_tolerance * s.cfg.t_sync,
        };
        s.strategy.on_dispatch(j, s.dispatched, at);
        (j, msg, at)
    };
    let (j, msg, at) = decision;
    if at <= now {
        deliver(st, j, msg);
    } else {
        let st2 = Rc::clone(st);
        let h2 = h.clone();
        h.spawn(async move {
            h2.sleep_until(at).await;
            deliver(&st2, j, msg);
        });
    }
}

/// Fold a finished round trip into the model, the policy's delay
/// channel, the EWMAs, and the report aggregates — then route the next
/// dispatch at the freed capacity.
fn complete(st: &Rc<RefCell<ServeState>>, h: &Handle, i: usize, msg: TaskMsg, compute: f64, now: f64) {
    {
        let mut g = st.borrow_mut();
        let s = &mut *g;
        s.completed += 1;
        let delay_time = now - msg.dispatch_time;
        let delay_steps = s.strategy.version().saturating_sub(msg.version_at_dispatch);
        let ctx = GradientCtx {
            node: i,
            step: s.completed,
            time: now,
            delay_steps,
            dispatch_prob: msg.dispatch_prob,
            grads: &s.grads,
        };
        s.strategy.on_gradient(&mut s.model, &ctx);
        #[cfg(debug_assertions)]
        let route_fp = s.route_rng.state_fingerprint();
        s.policy.observe_completion(i, delay_steps, delay_time);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            s.route_rng.state_fingerprint(),
            "observe_completion moved the routing stream (policy '{}')",
            s.policy.name()
        );
        let queue_time = (delay_time - compute).max(0.0);
        if now > msg.deadline {
            s.deadline_misses += 1;
        }
        // Score the pre-update estimate against the realized round trip
        // (only once warm — the quantity the admission check used).
        let c = &s.clients[i];
        if c.completions >= s.cfg.warm_up {
            if let (Some(q), Some(cp)) = (c.ewma_queue.estimate(), c.ewma_compute.estimate()) {
                s.est_err_w.push((q + cp - delay_time).abs());
            }
        }
        let c = &mut s.clients[i];
        c.ewma_queue.push(queue_time);
        c.ewma_compute.push(compute);
        c.completions += 1;
        s.delay_w.push(delay_time);
        s.queue_w.push(queue_time);
        s.compute_w.push(compute);
    }
    route_and_dispatch(st, h, now);
}

/// One simulated client: optionally join mid-ramp, then loop — await a
/// task, compute for a keyed-exponential duration, wait for the
/// (sequential) server to fold the gradient in, report completion.
async fn client_loop(h: Handle, st: Rc<RefCell<ServeState>>, i: usize, join_at: f64) {
    if join_at >= 0.0 {
        h.sleep_until(join_at).await;
        let mut g = st.borrow_mut();
        #[cfg(debug_assertions)]
        let route_fp = g.route_rng.state_fingerprint();
        g.policy.observe_join(i);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            route_fp,
            g.route_rng.state_fingerprint(),
            "observe_join moved the routing stream (policy '{}')",
            g.policy.name()
        );
        g.joins += 1;
        drop(g);
    }
    loop {
        let msg = NextTask { st: Rc::clone(&st), client: i }.await;
        let compute = {
            let mut g = st.borrow_mut();
            let s = &mut *g;
            let k = s.clients[i].dispatches;
            s.clients[i].dispatches += 1;
            let seed = stream_seed(s.service_root, &[i as u64, k]);
            Rng::new(seed).exponential(s.rates[i])
        };
        h.sleep_until(h.now() + compute).await;
        let finish = {
            let mut g = st.borrow_mut();
            let arrival = h.now();
            let begin = if g.server_free > arrival { g.server_free } else { arrival };
            let fin = begin + g.cfg.server_time;
            g.server_free = fin;
            fin
        };
        h.sleep_until(finish).await;
        complete(&st, &h, i, msg, compute, finish);
    }
}

/// Result of one serve session.  The deterministic core
/// ([`ServeReport::to_json_deterministic`]) is bit-identical across
/// runs on a shared seed; wall-clock throughput lives only in the full
/// report's `perf` block.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Echo of the setup that produced this report.
    pub setup: ServeSetup,
    /// Resolved policy name (aliases normalized).
    pub policy_name: String,
    /// Resolved strategy name (aliases normalized).
    pub algo_name: String,
    /// Tasks routed (== completions at quiescence).
    pub dispatched: u64,
    /// Gradients folded in.
    pub completed: u64,
    /// Final strategy version counter.
    pub versions: u64,
    /// Final strategy received counter.
    pub received: u64,
    /// Dispatches sent during a client's warm-up (no estimate).
    pub warm: u64,
    /// Dispatches whose estimate fit the window.
    pub admitted: u64,
    /// Dispatches deferred to the next window boundary.
    pub deferred: u64,
    /// Completions that landed after their deadline.
    pub deadline_misses: u64,
    /// Ramped clients that joined mid-session.
    pub joins: u64,
    /// Virtual time at quiescence.
    pub virtual_time: f64,
    /// Synchronization windows the session spanned.
    pub windows: u64,
    /// Round-trip delay (dispatch → gradient applied).
    pub delay: Welford,
    /// Non-compute share of the round trip.
    pub queue_time: Welford,
    /// Keyed-exponential compute share.
    pub compute_time: Welford,
    /// |estimate − realized round trip| for warm dispatches.
    pub estimate_abs_err: Welford,
    /// Wall-clock seconds of the executor run (perf block only).
    pub wall_secs: f64,
}

fn num(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

fn welford_json(w: &Welford) -> Json {
    let mut m = BTreeMap::new();
    m.insert("count".to_string(), Json::Num(w.count() as f64));
    m.insert("mean".to_string(), num(w.mean()));
    m.insert("std".to_string(), num(w.std()));
    m.insert("min".to_string(), num(w.min()));
    m.insert("max".to_string(), num(w.max()));
    Json::Obj(m)
}

impl ServeReport {
    /// Dispatch throughput against the wall clock (perf metric).
    pub fn dispatches_per_sec(&self) -> f64 {
        self.dispatched as f64 / self.wall_secs.max(1e-12)
    }

    fn render_json(&self, include_perf: bool) -> Json {
        let s = &self.setup;
        let mut config = BTreeMap::new();
        config.insert("clients".into(), Json::Num(s.clients as f64));
        config.insert("concurrency".into(), Json::Num(s.concurrency as f64));
        config.insert("dispatch_budget".into(), Json::Num(s.dispatches as f64));
        config.insert("seed".into(), Json::Num(s.seed as f64));
        config.insert("policy".into(), Json::Str(self.policy_name.clone()));
        config.insert("algo".into(), Json::Str(self.algo_name.clone()));
        config.insert("eta".into(), num(s.eta));
        config.insert("kappa".into(), num(s.kappa));
        config.insert("mu_fast".into(), num(s.mu_fast));
        config.insert("slow_fraction".into(), num(s.slow_fraction));
        config.insert("gamma".into(), num(s.gamma));
        config.insert("beta".into(), num(s.beta));
        config.insert("p_fast".into(), s.p_fast.map_or(Json::Null, num));
        config.insert("t_sync".into(), num(s.cfg.t_sync));
        config.insert("warm_up".into(), Json::Num(s.cfg.warm_up as f64));
        config.insert("alpha_queue".into(), num(s.cfg.alpha_queue));
        config.insert("alpha_compute".into(), num(s.cfg.alpha_compute));
        config.insert("safety_buffer".into(), num(s.cfg.safety_buffer));
        config.insert("admission_tolerance".into(), num(s.cfg.admission_tolerance));
        config.insert("server_time".into(), num(s.cfg.server_time));
        config.insert("ramp_time".into(), num(s.cfg.ramp_time));

        let mut totals = BTreeMap::new();
        totals.insert("dispatched".into(), Json::Num(self.dispatched as f64));
        totals.insert("completed".into(), Json::Num(self.completed as f64));
        totals.insert("versions".into(), Json::Num(self.versions as f64));
        totals.insert("received".into(), Json::Num(self.received as f64));
        totals.insert("virtual_time".into(), num(self.virtual_time));
        totals.insert("windows".into(), Json::Num(self.windows as f64));

        let mut admission = BTreeMap::new();
        admission.insert("warm".into(), Json::Num(self.warm as f64));
        admission.insert("admitted".into(), Json::Num(self.admitted as f64));
        admission.insert("deferred".into(), Json::Num(self.deferred as f64));
        admission.insert("deadline_misses".into(), Json::Num(self.deadline_misses as f64));
        admission.insert("joins".into(), Json::Num(self.joins as f64));

        let mut root = BTreeMap::new();
        root.insert("mode".into(), Json::Str("serve".into()));
        root.insert("config".into(), Json::Obj(config));
        root.insert("totals".into(), Json::Obj(totals));
        root.insert("admission".into(), Json::Obj(admission));
        root.insert("delay".into(), welford_json(&self.delay));
        root.insert("queue_time".into(), welford_json(&self.queue_time));
        root.insert("compute_time".into(), welford_json(&self.compute_time));
        root.insert("estimate_abs_err".into(), welford_json(&self.estimate_abs_err));
        if include_perf {
            let mut perf = BTreeMap::new();
            perf.insert("wall_secs".into(), num(self.wall_secs));
            perf.insert("dispatches_per_sec".into(), num(self.dispatches_per_sec()));
            root.insert("perf".into(), Json::Obj(perf));
        }
        Json::Obj(root)
    }

    /// Full report, including the wall-clock `perf` block.
    pub fn to_json(&self) -> Json {
        self.render_json(true)
    }

    /// Deterministic core only: everything except wall-clock perf.
    /// This rendering is byte-identical across runs on a shared seed.
    pub fn to_json_deterministic(&self) -> Json {
        self.render_json(false)
    }

    /// Human-readable multi-line summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "serve {}/{}: {} dispatched, {} completed over {} windows \
             (virtual time {:.2})\n\
             admission: warm {} | admitted {} | deferred {} | \
             deadline misses {} | joins {}\n\
             delay mean {:.4} | queue mean {:.4} | compute mean {:.4} | \
             est |err| mean {:.4}\n",
            self.policy_name,
            self.algo_name,
            self.dispatched,
            self.completed,
            self.windows,
            self.virtual_time,
            self.warm,
            self.admitted,
            self.deferred,
            self.deadline_misses,
            self.joins,
            self.delay.mean(),
            self.queue_time.mean(),
            self.compute_time.mean(),
            self.estimate_abs_err.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServeSetup {
        ServeSetup {
            clients: 16,
            concurrency: 4,
            dispatches: 200,
            slow_fraction: 0.5,
            mu_fast: 4.0,
            p_fast: None,
            gamma: 0.5,
            beta: 0.9,
            eta: 0.05,
            kappa: 0.5,
            policy: "delay-adaptive".into(),
            algo: "genasync-damped".into(),
            seed: 11,
            cfg: ServeConfig { t_sync: 10.0, server_time: 0.05, ..ServeConfig::default() },
        }
    }

    #[test]
    fn decision_is_warm_without_estimate() {
        let cfg = ServeConfig::default();
        assert_eq!(decide_dispatch(&cfg, 123.0, None), (Admission::Warm, 123.0));
    }

    #[test]
    fn decision_boundary_with_zero_safety_buffer() {
        let cfg = ServeConfig {
            t_sync: 10.0,
            safety_buffer: 0.0,
            admission_tolerance: 0.0,
            ..ServeConfig::default()
        };
        // 4 + 6 lands exactly on the boundary: admitted.
        assert_eq!(decide_dispatch(&cfg, 4.0, Some(6.0)), (Admission::Admitted, 4.0));
        // One epsilon over: deferred to the boundary.
        assert_eq!(decide_dispatch(&cfg, 4.0, Some(6.1)), (Admission::Deferred, 10.0));
        // The safety buffer alone can push a fitting estimate over.
        let buffered = ServeConfig { safety_buffer: 1.0, ..cfg };
        assert_eq!(decide_dispatch(&buffered, 4.0, Some(5.5)), (Admission::Deferred, 10.0));
    }

    #[test]
    fn deferral_never_skips_a_window() {
        let cfg = ServeConfig { t_sync: 10.0, ..ServeConfig::default() };
        let (adm, at) = decide_dispatch(&cfg, 17.0, Some(1e9));
        assert_eq!(adm, Admission::Deferred);
        assert_eq!(at, 20.0, "deferred exactly one boundary, however bad the estimate");
    }

    #[test]
    fn serve_drains_its_budget() {
        let report = tiny().run().unwrap();
        assert_eq!(report.dispatched, 200);
        assert_eq!(report.completed, 200);
        assert_eq!(report.warm + report.admitted + report.deferred, 200);
        assert!(report.virtual_time > 0.0);
        assert_eq!(report.received, 200);
    }

    #[test]
    fn serve_toml_table_roundtrip_and_unknown_key() {
        let mut tbl = BTreeMap::new();
        tbl.insert("t_sync".to_string(), Value::Float(25.0));
        tbl.insert("warm_up".to_string(), Value::Int(5));
        tbl.insert("safety_buffer".to_string(), Value::Float(1.5));
        let cfg = ServeConfig::from_toml_table(&tbl).unwrap();
        assert_eq!(cfg.t_sync, 25.0);
        assert_eq!(cfg.warm_up, 5);
        assert_eq!(cfg.safety_buffer, 1.5);
        tbl.insert("tsync".to_string(), Value::Float(1.0));
        let err = ServeConfig::from_toml_table(&tbl).unwrap_err();
        assert!(err.contains("unknown key 'tsync'"), "{err}");
    }

    #[test]
    fn serve_config_rejects_degenerate_knobs() {
        for (patch, needle) in [
            (ServeConfig { t_sync: 0.0, ..ServeConfig::default() }, "t_sync"),
            (ServeConfig { alpha_queue: 0.0, ..ServeConfig::default() }, "alpha_queue"),
            (ServeConfig { alpha_compute: 1.5, ..ServeConfig::default() }, "alpha_compute"),
            (ServeConfig { safety_buffer: -1.0, ..ServeConfig::default() }, "safety_buffer"),
            (ServeConfig { server_time: f64::NAN, ..ServeConfig::default() }, "server_time"),
        ] {
            let err = patch.validate().unwrap_err();
            assert!(err.contains(needle), "{err}");
        }
    }

    #[test]
    fn deterministic_core_is_identical_across_runs() {
        let a = tiny().run().unwrap();
        let b = tiny().run().unwrap();
        assert_eq!(
            a.to_json_deterministic().render(),
            b.to_json_deterministic().render()
        );
        // and a different seed moves the aggregate
        let mut other = tiny();
        other.seed = 12;
        let c = other.run().unwrap();
        assert_ne!(
            a.to_json_deterministic().render(),
            c.to_json_deterministic().render()
        );
    }
}
