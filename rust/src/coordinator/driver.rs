//! The asynchronous central server — Algorithm 1's event loop (L3).
//!
//! Binds together:
//!   * the **closed-network simulator** (virtual time, FIFO client queues,
//!     routing `K_{k+1}` drawn from a pluggable [`SamplingPolicy`]),
//!   * the **gradient backend** (PJRT-executed AOT JAX/Pallas model, or the
//!     native cross-check backend),
//!   * the **server strategy** (any [`ServerStrategy`] from the registry:
//!     Generalized AsyncSGD / AsyncSGD / FedBuff / FedAvg / FAVANO / ...),
//!   * per-client **data loaders** (non-iid shards).
//!
//! Faithful to the paper's semantics: the gradient completed at CS step `k`
//! was computed on the model version dispatched at step `I_k` — the driver
//! snapshots the model at dispatch time and keeps `C` snapshots alive (one
//! per in-flight task; Lemma 9's constant-cardinality invariant is asserted
//! in tests).

use super::policy::{SamplingPolicy, StaticPolicy};
use crate::data::{ClientLoader, EvalBatches};
use crate::fl::{GradientCtx, ModelState, ServerStrategy};
use crate::runtime::Backend;
use crate::simulator::{Network, SimConfig};
use std::collections::BTreeMap;
use std::rc::Rc;

/// One point of the training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub step: u64,
    pub virtual_time: f64,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_accuracy: f64,
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub curve: Vec<CurvePoint>,
    pub final_accuracy: f64,
    pub final_val_loss: f64,
    /// per-node mean delay in CS steps (empirical m_i)
    pub mean_delay: Vec<f64>,
    pub tau_max: u64,
    pub total_virtual_time: f64,
    /// wall-clock seconds spent in gradient computation (backend)
    pub backend_secs: f64,
    /// wall-clock seconds total
    pub wall_secs: f64,
    pub steps: u64,
    /// strategy name (registry key) the run used
    pub strategy: String,
    /// sampling-policy name the run used
    pub policy: String,
    /// server model versions applied (≤ steps for buffered strategies)
    pub versions: u64,
}

pub struct DriverConfig {
    /// closed-network dynamics (reference p, service rates, C, seed)
    pub sim: SimConfig,
    /// server update strategy
    pub strategy: Box<dyn ServerStrategy>,
    /// routing policy consulted at every dispatch
    pub policy: Box<dyn SamplingPolicy>,
    /// evaluate every this many CS steps (0 = only at end)
    pub eval_every: u64,
    /// moving-average window for train loss reporting
    pub loss_window: usize,
}

impl DriverConfig {
    /// Convenience: static-p routing taken from `sim.p`.
    pub fn with_strategy(
        sim: SimConfig,
        strategy: Box<dyn ServerStrategy>,
    ) -> Result<DriverConfig, String> {
        let policy = Box::new(StaticPolicy::new(sim.p.clone())?);
        Ok(DriverConfig { sim, strategy, policy, eval_every: 0, loss_window: 20 })
    }
}

pub struct Driver<'a> {
    pub backend: &'a mut dyn Backend,
    pub loaders: Vec<ClientLoader>,
    pub val: EvalBatches,
}

impl<'a> Driver<'a> {
    pub fn new(
        backend: &'a mut dyn Backend,
        loaders: Vec<ClientLoader>,
        val: EvalBatches,
    ) -> Driver<'a> {
        Driver { backend, loaders, val }
    }

    /// Run `cfg.sim.steps` CS steps of the asynchronous algorithm.
    pub fn run(&mut self, cfg: DriverConfig, model: &mut ModelState) -> Result<TrainResult, String> {
        let DriverConfig { sim, strategy, policy, eval_every, loss_window } = cfg;
        let mut strategy = strategy;
        let n = sim.p.len();
        if self.loaders.len() != n {
            return Err(format!("{} loaders for n={n} clients", self.loaders.len()));
        }
        let steps = sim.steps;
        // lint-allow(R3): wall clock wraps the whole run for the perf block
        // only; to_json_deterministic() excludes it from the digest payload
        let wall0 = std::time::Instant::now();
        let mut backend_secs = 0.0f64;
        let policy_name = policy.name();
        let mut net = Network::with_policy(sim, policy)?;
        // announce the C initial placements (all dispatched at step 0) so
        // strategies that track in-flight tasks see every dispatch
        for i in 0..n {
            for _ in 0..net.queue_len(i) {
                strategy.on_dispatch(i, 0, 0.0);
            }
        }
        // model snapshots per dispatch step; step 0 counts all initial
        // tasks.  Rc so handing a snapshot to the backend costs a pointer
        // copy, not a full parameter copy (§Perf: halves per-step memcpy).
        // BTreeMap, not HashMap: the map stays tiny (≤ C+1 live entries,
        // key-addressed), and an ordered map keeps any future traversal —
        // like the Lemma-9 audit below — deterministic by construction.
        let mut snapshots: BTreeMap<u64, (Rc<ModelState>, u32)> = BTreeMap::new();
        snapshots.insert(0, (Rc::new(model.clone()), net.population() as u32));
        let mut curve = Vec::new();
        let mut delay_sum = vec![0.0f64; n];
        let mut delay_cnt = vec![0u64; n];
        let mut tau_max = 0u64;
        let mut recent_losses: Vec<f64> = Vec::new();
        for k in 0..steps {
            let out = net.advance().ok_or("network drained")?;
            let node = out.completed_node as usize;
            // model version this client computed on (dispatched at I_k)
            let dispatched: Rc<ModelState> = {
                let entry = snapshots
                    .get_mut(&out.record.dispatch_step)
                    .ok_or_else(|| format!("missing snapshot for step {}", out.record.dispatch_step))?;
                entry.1 -= 1;
                let m = Rc::clone(&entry.0);
                if entry.1 == 0 {
                    snapshots.remove(&out.record.dispatch_step);
                }
                m
            };
            let batch = self.loaders[node].next_batch();
            // lint-allow(R3): times the backend train_step for perf metadata;
            // backend_secs never enters the deterministic digest
            let t0 = std::time::Instant::now();
            let (loss, grads) = self.backend.train_step(&dispatched, &batch)?;
            backend_secs += t0.elapsed().as_secs_f64();
            let d = out.record.delay_steps();
            strategy.on_gradient(
                model,
                &GradientCtx {
                    node,
                    step: k,
                    time: out.time,
                    delay_steps: d,
                    dispatch_prob: out.record.dispatch_prob,
                    grads: &grads,
                },
            );
            // bookkeeping
            delay_sum[node] += d as f64;
            delay_cnt[node] += 1;
            tau_max = tau_max.max(d);
            recent_losses.push(loss);
            if recent_losses.len() > loss_window.max(1) {
                recent_losses.remove(0);
            }
            // dispatch of the fresh task (already performed inside advance):
            // snapshot the CURRENT server model for it
            snapshots.insert(k + 1, (Rc::new(model.clone()), 1));
            strategy.on_dispatch(out.next_node as usize, k + 1, out.time);
            debug_assert_eq!(
                snapshots.values().map(|(_, c)| *c as usize).sum::<usize>(),
                net.population(),
                "in-flight snapshot count must equal C (Lemma 9)"
            );
            let do_eval = eval_every > 0 && (k + 1) % eval_every == 0;
            if do_eval || k + 1 == steps {
                // lint-allow(R3): times the backend evaluate for perf metadata;
                // backend_secs never enters the deterministic digest
                let t0 = std::time::Instant::now();
                let ev = self.backend.evaluate(model, &self.val)?;
                backend_secs += t0.elapsed().as_secs_f64();
                curve.push(CurvePoint {
                    step: k + 1,
                    virtual_time: out.time,
                    train_loss: recent_losses.iter().sum::<f64>() / recent_losses.len() as f64,
                    val_loss: ev.mean_loss,
                    val_accuracy: ev.accuracy,
                });
            }
        }
        let last = curve.last().copied().ok_or("no evaluation points")?;
        Ok(TrainResult {
            final_accuracy: last.val_accuracy,
            final_val_loss: last.val_loss,
            curve,
            mean_delay: delay_sum
                .iter()
                .zip(&delay_cnt)
                .map(|(s, c)| if *c > 0 { s / *c as f64 } else { f64::NAN })
                .collect(),
            tau_max,
            total_virtual_time: net.now,
            backend_secs,
            wall_secs: wall0.elapsed().as_secs_f64(),
            steps,
            strategy: strategy.name().to_string(),
            policy: policy_name,
            versions: strategy.version(),
        })
    }
}

/// Convenience: build the per-client loaders + validation batches for a
/// dataset/partition/backend combination.
pub fn build_loaders(
    data: std::sync::Arc<crate::data::Dataset>,
    partition: &crate::data::Partition,
    train_batch: usize,
    augment: bool,
    seed: u64,
) -> Result<Vec<ClientLoader>, String> {
    let mut out = Vec::with_capacity(partition.n_clients());
    for (ci, shard) in partition.shards.iter().enumerate() {
        // empty shards get a fallback singleton so the loader is valid;
        // their gradients are still real (one repeated sample).
        let shard = if shard.is_empty() { vec![0u32] } else { shard.clone() };
        out.push(ClientLoader::new(
            data.clone(),
            shard,
            train_batch,
            augment,
            seed.wrapping_add(ci as u64).wrapping_mul(0x2545F4914F6CDD1D),
        )?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, Partition, PartitionScheme, SynthSpec};
    use crate::fl::{GenAsync, StrategyParams, StrategyRegistry};
    use crate::runtime::{Backend, NativeBackend};
    use crate::simulator::{ServiceDist, ServiceFamily};
    use std::sync::Arc;

    fn setup(
        n: usize,
        steps: u64,
    ) -> (NativeBackend, Vec<ClientLoader>, EvalBatches, SimConfig, ModelState) {
        let spec = SynthSpec::tiny_test();
        let train = Arc::new(generate(&spec, 800, 21));
        let val = generate(&spec, 200, 22);
        let part = Partition::build(
            &train,
            n,
            PartitionScheme::ClassSubset { classes_per_client: 7 },
            23,
        )
        .unwrap();
        let backend = NativeBackend::tiny();
        let loaders =
            build_loaders(train, &part, backend.spec().train_batch, true, 24).unwrap();
        let val_batches = EvalBatches::new(&val, backend.spec().eval_batch);
        let rates: Vec<f64> = (0..n).map(|i| if i < n / 2 { 2.0 } else { 1.0 }).collect();
        let sim = SimConfig {
            seed: 25,
            ..SimConfig::new(
                vec![1.0 / n as f64; n],
                ServiceDist::from_rates(&rates, ServiceFamily::Exponential),
                4,
                steps,
            )
        };
        let model = backend.spec().init_model(26);
        (backend, loaders, val_batches, sim, model)
    }

    fn gasync_cfg(sim: SimConfig, eta: f64, eval_every: u64) -> DriverConfig {
        let p = sim.p.clone();
        let mut cfg =
            DriverConfig::with_strategy(sim, Box::new(GenAsync::new(eta, p))).unwrap();
        cfg.eval_every = eval_every;
        cfg.loss_window = 20;
        cfg
    }

    #[test]
    fn gasync_training_improves_accuracy() {
        let (mut be, loaders, val, sim, mut model) = setup(8, 150);
        let mut driver = Driver::new(&mut be, loaders, val);
        let res = driver.run(gasync_cfg(sim, 0.05, 50), &mut model).unwrap();
        assert_eq!(res.steps, 150);
        assert_eq!(res.curve.len(), 3);
        assert_eq!(res.strategy, "gasync");
        assert_eq!(res.policy, "static");
        assert_eq!(res.versions, 150);
        assert!(
            res.final_accuracy > 0.3,
            "accuracy {} should beat 0.1 chance",
            res.final_accuracy
        );
        // loss should broadly decrease
        assert!(res.curve.last().unwrap().val_loss < res.curve[0].val_loss * 1.2);
        assert!(res.tau_max >= 1);
        assert!(res.total_virtual_time > 0.0);
    }

    #[test]
    fn all_registered_strategies_run() {
        let reg = StrategyRegistry::builtin();
        for algo in reg.names() {
            let (mut be, loaders, val, sim, mut model) = setup(6, 60);
            let prm = StrategyParams::new(0.05, sim.p.clone());
            let strategy = reg.build(&algo, &prm).unwrap();
            let cfg = DriverConfig::with_strategy(sim, strategy).unwrap();
            let mut driver = Driver::new(&mut be, loaders, val);
            let res = driver.run(cfg, &mut model).unwrap();
            assert_eq!(res.curve.len(), 1, "{algo}: final eval only");
            assert_eq!(res.strategy, algo);
            assert!(res.final_accuracy > 0.05, "{algo}: {}", res.final_accuracy);
        }
    }

    #[test]
    fn deterministic_given_seeds() {
        let run_once = || {
            let (mut be, loaders, val, sim, mut model) = setup(6, 40);
            let mut driver = Driver::new(&mut be, loaders, val);
            driver.run(gasync_cfg(sim, 0.05, 0), &mut model).unwrap();
            (model.l2_norm(), model.tensors[0][0])
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.1.to_bits(), b.1.to_bits());
        assert_eq!(a.0.to_bits(), b.0.to_bits());
    }

    #[test]
    fn stale_gradients_are_used() {
        // with C=4 tasks over 6 nodes some gradients must be delayed ≥1 step
        let (mut be, loaders, val, sim, mut model) = setup(6, 80);
        let mut driver = Driver::new(&mut be, loaders, val);
        let res = driver.run(gasync_cfg(sim, 0.02, 0), &mut model).unwrap();
        assert!(res.tau_max >= 2, "tau_max {} suspiciously small", res.tau_max);
        let mean_delay: f64 = res.mean_delay.iter().filter(|d| d.is_finite()).sum::<f64>();
        assert!(mean_delay > 0.0);
    }

    #[test]
    fn loader_count_validated() {
        let (mut be, loaders, val, sim, mut model) = setup(6, 10);
        let mut short = loaders;
        short.pop();
        let mut driver = Driver::new(&mut be, short, val);
        let err = driver.run(gasync_cfg(sim, 0.05, 0), &mut model).unwrap_err();
        assert!(err.contains("loaders"));
    }

    #[test]
    fn nonuniform_sampling_runs_and_converges() {
        let (mut be, loaders, val, mut sim, mut model) = setup(8, 150);
        // tilt: fast nodes (0..4) sampled less — the paper's optimal shape
        let mut p = vec![0.08; 4];
        p.extend(vec![0.17; 4]);
        sim.p = p;
        let mut driver = Driver::new(&mut be, loaders, val);
        let res = driver.run(gasync_cfg(sim, 0.05, 0), &mut model).unwrap();
        assert!(res.final_accuracy > 0.3, "accuracy {}", res.final_accuracy);
    }

    #[test]
    fn adaptive_policy_trains_end_to_end() {
        use crate::coordinator::policy::AdaptiveQueuePolicy;
        let (mut be, loaders, val, sim, mut model) = setup(8, 150);
        let p = sim.p.clone();
        let policy = AdaptiveQueuePolicy::new(p.clone(), 0.5).unwrap();
        let cfg = DriverConfig {
            sim,
            strategy: Box::new(GenAsync::new(0.05, p)),
            policy: Box::new(policy),
            eval_every: 0,
            loss_window: 20,
        };
        let mut driver = Driver::new(&mut be, loaders, val);
        let res = driver.run(cfg, &mut model).unwrap();
        assert!(res.policy.starts_with("adaptive"), "{}", res.policy);
        assert!(res.final_accuracy > 0.25, "accuracy {}", res.final_accuracy);
    }
}
