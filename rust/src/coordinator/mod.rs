//! L3 coordinator — the paper's system contribution: the asynchronous
//! central server (`driver`), the open sampling-policy surface (`policy`),
//! synchronous round engines (`sync`), the builder/scenario-based
//! experiment runner (`experiment`), the parallel multi-seed sweep
//! engine (`sweep`), and the event-driven service mode with admission
//! control (`serve`).

// `serve` is fully documented; the older modules still carry the
// missing_docs debt marker (see the crate-root docs ratchet note).
#[allow(missing_docs)]
pub mod driver;
#[allow(missing_docs)]
pub mod experiment;
#[allow(missing_docs)]
pub mod policy;
pub mod serve;
#[allow(missing_docs)]
pub mod sweep;
#[allow(missing_docs)]
pub mod sync;

pub use driver::{build_loaders, CurvePoint, Driver, DriverConfig, TrainResult};
pub use experiment::{
    run_experiment, seed_sweep, table2_seeds, Experiment, ExperimentBuilder, SeedSweep,
};
pub use policy::{
    optimal_two_cluster, two_cluster_static, AdaptiveQueuePolicy, DelayAdaptivePolicy,
    FenwickAdaptivePolicy, FenwickDelayAdaptivePolicy, PolicyCtx, PolicyRegistry, SamplingPolicy,
    StaticPolicy,
};
pub use serve::{decide_dispatch, Admission, ServeConfig, ServeReport, ServeSetup};
pub use sweep::{run_sweep, SweepMode, SweepReport, SweepSpec};
pub use sync::{run_favano, run_fedavg, DataOracle, SyncResult};
