//! L3 coordinator — the paper's system contribution: the asynchronous
//! central server (`driver`), synchronous baselines (`sync`), and the
//! multi-seed experiment runner (`experiment`).

pub mod driver;
pub mod experiment;
pub mod sync;

pub use driver::{build_loaders, rule_for, CurvePoint, Driver, DriverConfig, TrainResult};
pub use experiment::{run_experiment, seed_sweep, table2_seeds, ExperimentConfig, SeedSweep};
pub use sync::{run_favano, run_fedavg, DataOracle, SyncResult};
