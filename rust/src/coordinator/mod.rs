//! L3 coordinator — the paper's system contribution: the asynchronous
//! central server (`driver`), the open sampling-policy surface (`policy`),
//! synchronous round engines (`sync`), and the builder/scenario-based
//! experiment runner (`experiment`).

pub mod driver;
pub mod experiment;
pub mod policy;
pub mod sync;

pub use driver::{build_loaders, CurvePoint, Driver, DriverConfig, TrainResult};
pub use experiment::{
    run_experiment, seed_sweep, table2_seeds, Experiment, ExperimentBuilder, SeedSweep,
};
pub use policy::{
    optimal_two_cluster, AdaptiveQueuePolicy, PolicyCtx, PolicyRegistry, SamplingPolicy,
    StaticPolicy,
};
pub use sync::{run_favano, run_fedavg, DataOracle, SyncResult};
