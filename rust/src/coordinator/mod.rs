//! L3 coordinator — the paper's system contribution: the asynchronous
//! central server (`driver`), the open sampling-policy surface (`policy`),
//! synchronous round engines (`sync`), the builder/scenario-based
//! experiment runner (`experiment`), and the parallel multi-seed sweep
//! engine (`sweep`).

pub mod driver;
pub mod experiment;
pub mod policy;
pub mod sweep;
pub mod sync;

pub use driver::{build_loaders, CurvePoint, Driver, DriverConfig, TrainResult};
pub use experiment::{
    run_experiment, seed_sweep, table2_seeds, Experiment, ExperimentBuilder, SeedSweep,
};
pub use policy::{
    optimal_two_cluster, two_cluster_static, AdaptiveQueuePolicy, DelayAdaptivePolicy,
    FenwickAdaptivePolicy, FenwickDelayAdaptivePolicy, PolicyCtx, PolicyRegistry, SamplingPolicy,
    StaticPolicy,
};
pub use sweep::{run_sweep, SweepMode, SweepReport, SweepSpec};
pub use sync::{run_favano, run_fedavg, DataOracle, SyncResult};
