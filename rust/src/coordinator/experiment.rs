//! Experiment runner: the full §5 protocol — dataset, non-iid partition,
//! two-speed clients, strategy + sampling-policy selection through the
//! registries, multi-seed repetition with mean ± std reporting (Table 2),
//! and CSV curve dumps (Figs 6/7).
//!
//! An [`Experiment`] is assembled three ways, all equivalent:
//!   * the fluent [`Experiment::builder`] (programmatic use, examples),
//!   * a TOML scenario file via [`Experiment::from_scenario`]
//!     (`fedqueue train --scenario scenarios/fig6.toml`),
//!   * CLI flags layered over either (see `main.rs`).
//!
//! Algorithm and sampling-policy names resolve through
//! [`StrategyRegistry`] / [`PolicyRegistry`], so third-party strategies and
//! policies plug in without touching this file or the driver.

use super::driver::{build_loaders, Driver, DriverConfig, TrainResult};
use super::policy::{PolicyCtx, PolicyRegistry, SamplingPolicy};
use super::serve::ServeConfig;
use crate::data::{generate, EvalBatches, Partition, PartitionScheme, SynthSpec};
use crate::fl::{ServerStrategy, StrategyParams, StrategyRegistry};
use crate::queueing::{ClosedNetwork, MiEstimator};
use crate::runtime::{make_backend, BackendKind};
use crate::simulator::{ChurnConfig, InitPlacement, ServiceDist, ServiceFamily, SimConfig};
use crate::util::rng::Rng;
use crate::util::stats::Welford;
use crate::util::toml::Doc;
use std::path::Path;
use std::sync::Arc;

/// Everything needed to reproduce one DL experiment run.
#[derive(Clone, Debug)]
pub struct Experiment {
    /// "cifar" | "tiny" | "wide" | "tinyimg" (+ "_jnp" flavors) — must
    /// exist in the manifest for non-native backends
    pub variant: String,
    pub backend: BackendKind,
    /// server strategy, resolved via [`StrategyRegistry`]
    pub algo: String,
    /// sampling policy, resolved via [`PolicyRegistry`]
    pub policy: String,
    pub n_clients: usize,
    /// concurrency C (tasks in flight)
    pub concurrency: usize,
    /// total CS steps T
    pub steps: u64,
    pub eta: f64,
    pub fedbuff_z: usize,
    /// FedAvg round barrier (0 = auto: max(2, n/10))
    pub fedavg_s: usize,
    /// FAVANO slice length Δ in virtual time
    pub favano_interval: f64,
    /// fraction of clients that are slow (paper: half)
    pub slow_fraction: f64,
    /// fast service rate (slow is 1.0)
    pub mu_fast: f64,
    /// per-fast-node selection probability for the static policy;
    /// None = uniform base
    pub p_fast: Option<f64>,
    /// queue-pressure / delay-pressure strength for the adaptive and
    /// delay-adaptive policies
    pub gamma: f64,
    /// EWMA momentum β for the delay-adaptive policy's delay estimates
    pub beta: f64,
    /// staleness-damping strength κ for the genasync-damped strategy
    pub kappa: f64,
    /// dataset sizes
    pub n_train: usize,
    pub n_val: usize,
    /// non-iid classes per client (0 = IID)
    pub classes_per_client: usize,
    pub eval_every: u64,
    pub seed: u64,
    /// optional open-network node lifecycle (None = closed network)
    pub churn: Option<ChurnConfig>,
    /// optional admission-control knobs for `fedqueue serve` (None =
    /// serve-mode defaults)
    pub serve: Option<ServeConfig>,
}

/// Keys the `[experiment]` table accepts — the single list shared by the
/// parser below and the `docs/SCENARIOS.md` cross-check in
/// `tests/scenario_lint.rs`.
pub const EXPERIMENT_KEYS: &[&str] = &[
    "variant",
    "backend",
    "algo",
    "clients",
    "concurrency",
    "steps",
    "eta",
    "slow_fraction",
    "mu_fast",
    "n_train",
    "n_val",
    "classes_per_client",
    "eval_every",
    "seed",
];

/// Keys the `[policy]` table accepts (same contract as
/// [`EXPERIMENT_KEYS`]).
pub const POLICY_KEYS: &[&str] = &["kind", "p_fast", "gamma", "beta"];

/// Keys the `[strategy]` table accepts (same contract as
/// [`EXPERIMENT_KEYS`]).
pub const STRATEGY_KEYS: &[&str] = &["fedbuff_z", "fedavg_s", "favano_interval", "kappa"];

impl Experiment {
    /// Start from sane laptop-scale defaults (tiny variant, native backend)
    /// and override fluently.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder {
            exp: Experiment {
                variant: "tiny".into(),
                backend: BackendKind::Native,
                algo: "gasync".into(),
                policy: "static".into(),
                n_clients: 20,
                concurrency: 5,
                steps: 120,
                eta: 0.05,
                fedbuff_z: 10,
                fedavg_s: 0,
                favano_interval: 4.0,
                slow_fraction: 0.5,
                mu_fast: 4.0,
                p_fast: None,
                gamma: 0.5,
                beta: 0.9,
                kappa: 0.5,
                n_train: 2_000,
                n_val: 400,
                classes_per_client: 7,
                eval_every: 20,
                seed: 0,
                churn: None,
                serve: None,
            },
        }
    }

    /// The paper's Fig 6 protocol scaled to this testbed: n=100 clients,
    /// half slow, non-iid 7-of-10, 200 CS steps, batch from the manifest.
    /// Uses the jnp artifact flavor (same numerics as the Pallas flavor —
    /// verified in tests — but 8× faster on XLA:CPU, see §Perf); the
    /// Pallas flavor is exercised by examples/e2e_train.
    pub fn fig6(algo: &str) -> Experiment {
        let mut exp = Experiment::builder()
            .variant("cifar_jnp")
            .backend(BackendKind::Pjrt)
            .clients(100)
            .concurrency(10)
            .steps(200)
            .eta(0.1)
            .fedbuff_z(10)
            .slow_fraction(0.5)
            .mu_fast(4.0)
            .n_train(20_000)
            .n_val(2_000)
            .classes_per_client(7)
            .eval_every(20)
            .seed(0)
            .build()
            .expect("fig6 defaults are valid");
        // caller-supplied name: checked at run time through the registry
        // (like every other stringly entrypoint), not panicked on here
        exp.algo = algo.to_string();
        exp
    }

    /// Load an experiment from a TOML scenario file (tables `[experiment]`,
    /// `[policy]`, `[strategy]`; see `scenarios/*.toml`).
    pub fn from_scenario(path: &Path) -> Result<Experiment, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("scenario {}: {e}", path.display()))?;
        Experiment::from_toml(&text)
            .map_err(|e| format!("scenario {}: {e}", path.display()))
    }

    /// Parse a scenario from TOML text.
    pub fn from_toml(text: &str) -> Result<Experiment, String> {
        let doc = Doc::parse(text)?;
        // strict getters: a present key with the wrong type or a negative
        // count is a config error, not a silent fallback to the default
        let count = |table: &str, key: &str, default: i64| -> Result<i64, String> {
            match doc.get(table, key) {
                None => Ok(default),
                Some(v) => match v.as_i64() {
                    Some(i) if i >= 0 => Ok(i),
                    Some(i) => Err(format!("[{table}] {key} = {i} must be >= 0")),
                    None => Err(format!("[{table}] {key} must be a non-negative integer")),
                },
            }
        };
        let float = |table: &str, key: &str, default: f64| -> Result<f64, String> {
            match doc.get(table, key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("[{table}] {key} must be a number")),
            }
        };
        let string = |table: &str, key: &str, default: &str| -> Result<String, String> {
            match doc.get(table, key) {
                None => Ok(default.to_string()),
                Some(v) => v
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| format!("[{table}] {key} must be a string")),
            }
        };
        for (table, keys) in &doc.tables {
            let known: &[&str] = match table.as_str() {
                "" => &[],
                "experiment" => EXPERIMENT_KEYS,
                "policy" => POLICY_KEYS,
                "strategy" => STRATEGY_KEYS,
                // [churn]/[serve] keys are validated (strictly) by
                // ChurnConfig::from_toml_table / ServeConfig::
                // from_toml_table — one authority each, no drift
                "churn" | "serve" => continue,
                other => {
                    return Err(format!(
                        "unknown table [{other}] (experiment|policy|strategy|churn|serve)"
                    ))
                }
            };
            for k in keys.keys() {
                if !known.contains(&k.as_str()) {
                    return Err(format!(
                        "unknown key '{k}' in [{table}] (known: {})",
                        known.join(", ")
                    ));
                }
            }
        }
        let mut b = Experiment::builder();
        let e = "experiment";
        b = b
            .variant(&string(e, "variant", "tiny")?)
            .algo(&string(e, "algo", "gasync")?)
            .clients(count(e, "clients", 20)? as usize)
            .concurrency(count(e, "concurrency", 5)? as usize)
            .steps(count(e, "steps", 120)? as u64)
            .eta(float(e, "eta", 0.05)?)
            .slow_fraction(float(e, "slow_fraction", 0.5)?)
            .mu_fast(float(e, "mu_fast", 4.0)?)
            .n_train(count(e, "n_train", 2_000)? as usize)
            .n_val(count(e, "n_val", 400)? as usize)
            .classes_per_client(count(e, "classes_per_client", 7)? as usize)
            .eval_every(count(e, "eval_every", 20)? as u64)
            .seed(count(e, "seed", 0)? as u64)
            .backend(string(e, "backend", "native")?.parse::<BackendKind>()?)
            .policy(&string("policy", "kind", "static")?)
            .adaptive_gamma(float("policy", "gamma", 0.5)?)
            .delay_beta(float("policy", "beta", 0.9)?)
            .fedbuff_z(count("strategy", "fedbuff_z", 10)? as usize)
            .fedavg_s(count("strategy", "fedavg_s", 0)? as usize)
            .favano_interval(float("strategy", "favano_interval", 4.0)?)
            .damping_kappa(float("strategy", "kappa", 0.5)?);
        if doc.get("policy", "p_fast").is_some() {
            b = b.p_fast(float("policy", "p_fast", 0.0)?);
        }
        if let Some(tbl) = doc.tables.get("churn") {
            b = b.churn(ChurnConfig::from_toml_table(tbl)?);
        }
        if let Some(tbl) = doc.tables.get("serve") {
            b = b.serve(ServeConfig::from_toml_table(tbl)?);
        }
        b.build()
    }

    /// Service rates: fast first, then slow (rate 1).
    pub fn rates(&self) -> Vec<f64> {
        two_cluster_rates(self.n_clients, self.slow_fraction, self.mu_fast)
    }

    pub fn n_fast(&self) -> usize {
        two_cluster_n_fast(self.n_clients, self.slow_fraction)
    }

    /// Base sampling probabilities (p_fast for fast nodes, complement for
    /// slow) — the static policy's distribution.
    pub fn p_vec(&self) -> Vec<f64> {
        two_cluster_p(self.n_clients, self.slow_fraction, self.p_fast)
    }

    pub fn synth_spec(&self) -> SynthSpec {
        // "_jnp" artifact flavors share the base variant's geometry
        match self.variant.trim_end_matches("_jnp") {
            "tinyimg" => SynthSpec::tiny_imagenet_like(),
            "tiny" => SynthSpec::tiny_test(),
            _ => SynthSpec::cifar_like(),
        }
    }

    /// Shape handed to policy constructors.
    pub fn policy_ctx(&self) -> PolicyCtx {
        PolicyCtx {
            n: self.n_clients,
            base_p: self.p_vec(),
            gamma: self.gamma,
            beta: self.beta,
            n_fast: self.n_fast(),
            mu_fast: self.mu_fast,
            mu_slow: 1.0,
            concurrency: self.concurrency,
            steps: self.steps,
        }
    }

    /// Knobs handed to strategy constructors, given the distribution the
    /// resolved policy starts from.
    pub fn strategy_params(&self, p: &[f64]) -> StrategyParams {
        StrategyParams {
            eta: self.eta,
            p: p.to_vec(),
            fedbuff_z: self.fedbuff_z,
            fedavg_s: self.fedavg_s,
            favano_interval: self.favano_interval,
            kappa: self.kappa,
        }
    }

    /// The bound-optimal per-fast-node probability for this experiment's
    /// two-cluster shape — exactly what the `optimal` policy will use.
    pub fn optimal_p_fast(&self) -> Result<f64, String> {
        let pol = super::policy::optimal_two_cluster(&self.policy_ctx())?;
        Ok(pol.probs()[0])
    }

    /// Structural validation (builder `build()` calls this; call it again
    /// after mutating fields directly).
    pub fn validate(&self) -> Result<(), String> {
        self.validate_shapes_only()?;
        if !(self.eta > 0.0) || !self.eta.is_finite() {
            return Err(format!("eta {} must be positive", self.eta));
        }
        if !(0.0..=1.0).contains(&self.slow_fraction) {
            return Err(format!("slow_fraction {} must be in [0,1]", self.slow_fraction));
        }
        if !(self.mu_fast > 0.0) {
            return Err(format!("mu_fast {} must be positive", self.mu_fast));
        }
        if !(0.0..1.0).contains(&self.beta) {
            return Err(format!("beta {} must be in [0, 1)", self.beta));
        }
        if !(self.kappa >= 0.0) || !self.kappa.is_finite() {
            return Err(format!("kappa {} must be finite and >= 0", self.kappa));
        }
        if let Some(pf) = self.p_fast {
            let nf = self.n_fast();
            if nf == 0 || nf >= self.n_clients {
                return Err("p_fast needs a two-cluster population".into());
            }
            let q = (1.0 - nf as f64 * pf) / (self.n_clients - nf) as f64;
            if !(pf > 0.0) || q <= 0.0 {
                return Err(format!(
                    "p_fast {pf} leaves no probability mass for slow nodes (q = {q})"
                ));
            }
        }
        let strategies = StrategyRegistry::builtin();
        if !strategies.contains(&self.algo) {
            return Err(format!(
                "unknown algorithm '{}' (available: {})",
                self.algo,
                strategies.names().join("|")
            ));
        }
        let policies = PolicyRegistry::builtin();
        if !policies.contains(&self.policy) {
            return Err(format!(
                "unknown sampling policy '{}' (available: {})",
                self.policy,
                policies.names().join("|")
            ));
        }
        if let Some(churn) = &self.churn {
            churn.validate(self.n_clients)?;
        }
        if let Some(serve) = &self.serve {
            serve.validate()?;
        }
        Ok(())
    }

    /// Resolve the configured policy through the registry.
    pub fn build_policy(&self) -> Result<Box<dyn SamplingPolicy>, String> {
        PolicyRegistry::builtin().build(&self.policy, &self.policy_ctx())
    }

    /// Run end to end with registry-resolved strategy and policy.
    pub fn run(&self) -> Result<TrainResult, String> {
        let policy = self.build_policy()?;
        let strategy = StrategyRegistry::builtin()
            .build(&self.algo, &self.strategy_params(&policy.probs()))?;
        self.run_with(strategy, policy)
    }

    /// Run with explicit trait objects — the escape hatch for strategies
    /// and policies that are not (yet) registered.
    pub fn run_with(
        &self,
        strategy: Box<dyn ServerStrategy>,
        policy: Box<dyn SamplingPolicy>,
    ) -> Result<TrainResult, String> {
        self.validate_shapes_only()?;
        let sspec = self.synth_spec();
        let mut backend = make_backend(self.backend, &self.variant, None)?;
        let bspec = backend.spec().clone();
        if bspec.input_dim != sspec.dim() || bspec.classes != sspec.classes {
            return Err(format!(
                "variant {} expects {}→{} but dataset is {}→{}",
                self.variant,
                bspec.input_dim,
                bspec.classes,
                sspec.dim(),
                sspec.classes
            ));
        }
        // the DATASET is fixed across seeds (as CIFAR-10 is in the paper);
        // self.seed varies the partition, init, loaders and queueing
        // dynamics.
        let train = Arc::new(generate(&sspec, self.n_train, 0xDA7A));
        let val = generate(&sspec, self.n_val, 0x7A11);
        let scheme = if self.classes_per_client == 0 {
            PartitionScheme::Iid
        } else {
            PartitionScheme::ClassSubset { classes_per_client: self.classes_per_client }
        };
        let partition = Partition::build(&train, self.n_clients, scheme, self.seed ^ 0x9A47)?;
        let loaders =
            build_loaders(train, &partition, bspec.train_batch, true, self.seed ^ 0x10AD)?;
        let val_batches = EvalBatches::new(&val, bspec.eval_batch);
        let sim = SimConfig {
            seed: self.seed ^ 0x51AA,
            init: InitPlacement::Routed,
            churn: self.churn.clone(),
            ..SimConfig::new(
                policy.probs(),
                ServiceDist::from_rates(&self.rates(), ServiceFamily::Exponential),
                self.concurrency,
                self.steps,
            )
        };
        let mut model = bspec.init_model(self.seed ^ 0x1417);
        let mut driver = Driver::new(backend.as_mut(), loaders, val_batches);
        driver.run(
            DriverConfig {
                sim,
                strategy,
                policy,
                eval_every: self.eval_every,
                loss_window: 20,
            },
            &mut model,
        )
    }

    /// The subset of `validate` that does not consult the registries —
    /// `run_with` accepts unregistered trait objects.
    fn validate_shapes_only(&self) -> Result<(), String> {
        if self.n_clients < 2 {
            return Err(format!("n_clients {} must be >= 2", self.n_clients));
        }
        if self.concurrency == 0 {
            return Err("concurrency C must be >= 1".into());
        }
        if self.steps == 0 {
            return Err("steps T must be >= 1".into());
        }
        Ok(())
    }
}

/// Fluent builder returned by [`Experiment::builder`].
#[derive(Clone, Debug)]
pub struct ExperimentBuilder {
    exp: Experiment,
}

impl ExperimentBuilder {
    pub fn variant(mut self, v: &str) -> Self {
        self.exp.variant = v.to_string();
        self
    }

    pub fn backend(mut self, b: BackendKind) -> Self {
        self.exp.backend = b;
        self
    }

    pub fn algo(mut self, a: &str) -> Self {
        self.exp.algo = a.to_string();
        self
    }

    pub fn policy(mut self, p: &str) -> Self {
        self.exp.policy = p.to_string();
        self
    }

    pub fn clients(mut self, n: usize) -> Self {
        self.exp.n_clients = n;
        self
    }

    pub fn concurrency(mut self, c: usize) -> Self {
        self.exp.concurrency = c;
        self
    }

    pub fn steps(mut self, t: u64) -> Self {
        self.exp.steps = t;
        self
    }

    pub fn eta(mut self, e: f64) -> Self {
        self.exp.eta = e;
        self
    }

    pub fn fedbuff_z(mut self, z: usize) -> Self {
        self.exp.fedbuff_z = z;
        self
    }

    pub fn fedavg_s(mut self, s: usize) -> Self {
        self.exp.fedavg_s = s;
        self
    }

    pub fn favano_interval(mut self, d: f64) -> Self {
        self.exp.favano_interval = d;
        self
    }

    pub fn slow_fraction(mut self, f: f64) -> Self {
        self.exp.slow_fraction = f;
        self
    }

    pub fn mu_fast(mut self, mu: f64) -> Self {
        self.exp.mu_fast = mu;
        self
    }

    pub fn p_fast(mut self, pf: f64) -> Self {
        self.exp.p_fast = Some(pf);
        self
    }

    pub fn adaptive_gamma(mut self, g: f64) -> Self {
        self.exp.gamma = g;
        self
    }

    /// EWMA momentum β for the delay-adaptive policy.
    pub fn delay_beta(mut self, b: f64) -> Self {
        self.exp.beta = b;
        self
    }

    /// Staleness-damping strength κ for the genasync-damped strategy.
    pub fn damping_kappa(mut self, k: f64) -> Self {
        self.exp.kappa = k;
        self
    }

    pub fn n_train(mut self, n: usize) -> Self {
        self.exp.n_train = n;
        self
    }

    pub fn n_val(mut self, n: usize) -> Self {
        self.exp.n_val = n;
        self
    }

    pub fn classes_per_client(mut self, k: usize) -> Self {
        self.exp.classes_per_client = k;
        self
    }

    pub fn eval_every(mut self, e: u64) -> Self {
        self.exp.eval_every = e;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.exp.seed = s;
        self
    }

    /// Open-network node lifecycle for the queueing substrate.
    pub fn churn(mut self, c: ChurnConfig) -> Self {
        self.exp.churn = Some(c);
        self
    }

    /// Admission-control knobs for `fedqueue serve`.
    pub fn serve(mut self, c: ServeConfig) -> Self {
        self.exp.serve = Some(c);
        self
    }

    /// Validate and produce the experiment.
    pub fn build(self) -> Result<Experiment, String> {
        self.exp.validate()?;
        Ok(self.exp)
    }
}

// ---------------------------------------------------------------------------
// Two-cluster shape helpers — the single source of the fast/slow split,
// shared by the experiment runner and the sweep grid (fast nodes come
// first; the slow service rate is 1).
// ---------------------------------------------------------------------------

pub fn two_cluster_n_fast(clients: usize, slow_fraction: f64) -> usize {
    clients - (clients as f64 * slow_fraction).round() as usize
}

pub fn two_cluster_rates(clients: usize, slow_fraction: f64, mu_fast: f64) -> Vec<f64> {
    let nf = two_cluster_n_fast(clients, slow_fraction);
    (0..clients)
        .map(|i| if i < nf { mu_fast } else { 1.0 })
        .collect()
}

/// Routing distribution: uniform, or the `p_fast` tilt with the leftover
/// mass spread evenly over the slow cluster.  Callers validate the shape
/// (two clusters, positive leftover mass) before relying on the result.
pub fn two_cluster_p(clients: usize, slow_fraction: f64, p_fast: Option<f64>) -> Vec<f64> {
    match p_fast {
        None => vec![1.0 / clients as f64; clients],
        Some(pf) => {
            let nf = two_cluster_n_fast(clients, slow_fraction);
            let q = (1.0 - nf as f64 * pf) / (clients - nf) as f64;
            (0..clients)
                .map(|i| if i < nf { pf } else { q })
                .collect()
        }
    }
}

/// Run one experiment end to end.  Returns the training result.
pub fn run_experiment(cfg: &Experiment) -> Result<TrainResult, String> {
    cfg.run()
}

/// Table-2 style multi-seed aggregate.
#[derive(Clone, Debug)]
pub struct SeedSweep {
    pub accuracies: Vec<f64>,
    pub mean: f64,
    pub std: f64,
}

pub fn seed_sweep(base: &Experiment, seeds: &[u64]) -> Result<SeedSweep, String> {
    let mut acc = Vec::with_capacity(seeds.len());
    let mut w = Welford::new();
    for &s in seeds {
        let mut cfg = base.clone();
        cfg.seed = s;
        let res = cfg.run()?;
        acc.push(res.final_accuracy);
        w.push(res.final_accuracy);
    }
    Ok(SeedSweep { accuracies: acc, mean: w.mean(), std: w.std() })
}

/// Theory-side summary printed alongside experiments: expected delays and
/// step rate for the experiment's network under its *resolved* policy
/// (sanity anchor for the curves; the adaptive policy is summarized at its
/// base distribution).
pub fn theory_summary(cfg: &Experiment) -> Result<(Vec<f64>, f64), String> {
    let policy = cfg.build_policy()?;
    theory_summary_with(cfg, &policy.probs())
}

/// Same summary for an already-resolved distribution — lets callers that
/// hold the policy (CLI, examples) avoid rebuilding it, which matters for
/// `optimal` (each construction is a full bound-optimizer sweep).
pub fn theory_summary_with(cfg: &Experiment, probs: &[f64]) -> Result<(Vec<f64>, f64), String> {
    let net = ClosedNetwork::new(probs.to_vec(), cfg.rates())?;
    let an = net.mi_analysis(cfg.concurrency, MiEstimator::Throughput);
    Ok((an.m, an.cs_rate))
}

/// Deterministic seed list for Table 2.
pub fn table2_seeds(n: usize) -> Vec<u64> {
    // lint-allow(R4): intentional fixed stream — the paper's Table 2 seed
    // list must be identical across machines and releases
    let mut rng = Rng::new(0x7AB1E_2);
    (0..n).map(|_| rng.next_u64() >> 1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates() {
        assert!(Experiment::builder().build().is_ok());
        assert!(Experiment::builder().clients(1).build().is_err());
        assert!(Experiment::builder().steps(0).build().is_err());
        assert!(Experiment::builder().eta(0.0).build().is_err());
        assert!(Experiment::builder().algo("sync-sgd").build().is_err());
        assert!(Experiment::builder().policy("zipf").build().is_err());
        assert!(Experiment::builder().p_fast(0.9).build().is_err());
        assert!(Experiment::builder().delay_beta(1.0).build().is_err());
        assert!(Experiment::builder().delay_beta(-0.1).build().is_err());
        assert!(Experiment::builder().damping_kappa(-1.0).build().is_err());
        assert!(Experiment::builder()
            .policy("delay-adaptive")
            .algo("genasync-damped")
            .delay_beta(0.0)
            .damping_kappa(0.0)
            .build()
            .is_ok());
    }

    #[test]
    fn scenario_round_trip() {
        let text = r#"
[experiment]
variant = "tiny"
backend = "native"
algo = "fedbuff"
clients = 12
concurrency = 4
steps = 50
eta = 0.08
slow_fraction = 0.5
mu_fast = 6.0
n_train = 1000
n_val = 200
classes_per_client = 0
eval_every = 10
seed = 9

[policy]
kind = "adaptive"
gamma = 0.8
beta = 0.7

[strategy]
fedbuff_z = 5
kappa = 0.25
"#;
        let exp = Experiment::from_toml(text).unwrap();
        assert_eq!(exp.variant, "tiny");
        assert_eq!(exp.backend, BackendKind::Native);
        assert_eq!(exp.algo, "fedbuff");
        assert_eq!(exp.policy, "adaptive");
        assert_eq!(exp.n_clients, 12);
        assert_eq!(exp.concurrency, 4);
        assert_eq!(exp.steps, 50);
        assert_eq!(exp.fedbuff_z, 5);
        assert_eq!(exp.gamma, 0.8);
        assert_eq!(exp.beta, 0.7);
        assert_eq!(exp.kappa, 0.25);
        assert_eq!(exp.seed, 9);
    }

    #[test]
    fn scenario_churn_block_round_trips_and_validates() {
        let text = r#"
[experiment]
clients = 12

[churn]
arrival_rate = 0.5
mean_lifetime = 2.0
initial_active = 10
"#;
        let exp = Experiment::from_toml(text).unwrap();
        let churn = exp.churn.as_ref().expect("[churn] table parsed");
        assert_eq!(churn.arrival_rate, 0.5);
        assert_eq!(churn.mean_lifetime, 2.0);
        assert_eq!(churn.initial_active, 10);
        // no [churn] table -> closed network (the historical default)
        assert!(Experiment::builder().build().unwrap().churn.is_none());
        // strict keys inside the table, validation against the client count
        let err = Experiment::from_toml("[churn]\nbogus = 1.0").unwrap_err();
        assert!(err.contains("bogus"), "{err}");
        let err = Experiment::from_toml("[churn]\ninitial_active = 25").unwrap_err();
        assert!(err.contains("initial_active"), "{err}");
    }

    #[test]
    fn scenario_rejects_unknown_keys_and_tables() {
        let err = Experiment::from_toml("[experiment]\nclinets = 10").unwrap_err();
        assert!(err.contains("clinets"), "{err}");
        let err = Experiment::from_toml("[expermient]\nclients = 10").unwrap_err();
        assert!(err.contains("expermient"), "{err}");
        let err = Experiment::from_toml("[policy]\nkind = \"no-such-policy\"").unwrap_err();
        assert!(err.contains("no-such-policy"), "{err}");
    }

    #[test]
    fn scenario_rejects_negative_and_mistyped_values() {
        // negative counts must not wrap through `as usize`
        let err = Experiment::from_toml("[experiment]\nclients = -1").unwrap_err();
        assert!(err.contains("clients"), "{err}");
        let err = Experiment::from_toml("[experiment]\nsteps = -5").unwrap_err();
        assert!(err.contains("steps"), "{err}");
        // wrong TOML type must error, not silently fall back to defaults
        let err = Experiment::from_toml("[experiment]\nsteps = \"200\"").unwrap_err();
        assert!(err.contains("steps"), "{err}");
        let err = Experiment::from_toml("[experiment]\nvariant = 3").unwrap_err();
        assert!(err.contains("variant"), "{err}");
        let err = Experiment::from_toml("[policy]\ngamma = \"big\"").unwrap_err();
        assert!(err.contains("gamma"), "{err}");
    }

    #[test]
    fn p_vec_tilts_two_clusters() {
        let exp = Experiment::builder().clients(10).p_fast(0.05).build().unwrap();
        let p = exp.p_vec();
        assert_eq!(p.len(), 10);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(p[0] < p[9]);
    }
}
