//! Experiment runner: the full §5 protocol — dataset, non-iid partition,
//! two-speed clients, algorithm selection, multi-seed repetition with
//! mean ± std reporting (Table 2), and CSV curve dumps (Figs 6/7).

use super::driver::{build_loaders, rule_for, Driver, DriverConfig, TrainResult};
use crate::data::{generate, EvalBatches, Partition, PartitionScheme, SynthSpec};
use crate::queueing::{ClosedNetwork, MiEstimator};
use crate::runtime::{make_backend, BackendKind};
use crate::simulator::{InitPlacement, ServiceDist, ServiceFamily, SimConfig};
use crate::util::rng::Rng;
use crate::util::stats::Welford;
use std::sync::Arc;

/// Everything needed to reproduce one DL experiment run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// "cifar" | "tiny" | "wide" | "tinyimg" — must exist in the manifest
    pub variant: String,
    pub backend: BackendKind,
    /// "gasync" | "async" | "fedbuff"
    pub algo: String,
    pub n_clients: usize,
    /// concurrency C (tasks in flight)
    pub concurrency: usize,
    /// total CS steps T
    pub steps: u64,
    pub eta: f64,
    pub fedbuff_z: usize,
    /// fraction of clients that are slow (paper: half)
    pub slow_fraction: f64,
    /// fast service rate (slow is 1.0)
    pub mu_fast: f64,
    /// per-fast-node selection probability; None = uniform
    pub p_fast: Option<f64>,
    /// dataset sizes
    pub n_train: usize,
    pub n_val: usize,
    /// non-iid classes per client (0 = IID)
    pub classes_per_client: usize,
    pub eval_every: u64,
    pub seed: u64,
}

impl ExperimentConfig {
    /// The paper's Fig 6 protocol scaled to this testbed: n=100 clients,
    /// half slow, non-iid 7-of-10, 200 CS steps, batch from the manifest.
    /// Uses the jnp artifact flavor (same numerics as the Pallas flavor —
    /// verified in tests — but 8× faster on XLA:CPU, see §Perf); the
    /// Pallas flavor is exercised by examples/e2e_train.
    pub fn fig6(algo: &str) -> ExperimentConfig {
        ExperimentConfig {
            variant: "cifar_jnp".into(),
            backend: BackendKind::Pjrt,
            algo: algo.into(),
            n_clients: 100,
            concurrency: 10,
            steps: 200,
            eta: 0.1,
            fedbuff_z: 10,
            slow_fraction: 0.5,
            mu_fast: 4.0,
            p_fast: None,
            n_train: 20_000,
            n_val: 2_000,
            classes_per_client: 7,
            eval_every: 20,
            seed: 0,
        }
    }

    /// Service rates: fast first, then slow (rate 1).
    pub fn rates(&self) -> Vec<f64> {
        let n_slow = (self.n_clients as f64 * self.slow_fraction).round() as usize;
        let n_fast = self.n_clients - n_slow;
        (0..self.n_clients)
            .map(|i| if i < n_fast { self.mu_fast } else { 1.0 })
            .collect()
    }

    pub fn n_fast(&self) -> usize {
        self.n_clients - (self.n_clients as f64 * self.slow_fraction).round() as usize
    }

    /// Sampling probabilities (p_fast for fast nodes, complement for slow).
    pub fn p_vec(&self) -> Vec<f64> {
        match self.p_fast {
            None => vec![1.0 / self.n_clients as f64; self.n_clients],
            Some(pf) => {
                let nf = self.n_fast();
                let q = (1.0 - nf as f64 * pf) / (self.n_clients - nf) as f64;
                (0..self.n_clients)
                    .map(|i| if i < nf { pf } else { q })
                    .collect()
            }
        }
    }

    pub fn synth_spec(&self) -> SynthSpec {
        // "_jnp" artifact flavors share the base variant's geometry
        match self.variant.trim_end_matches("_jnp") {
            "tinyimg" => SynthSpec::tiny_imagenet_like(),
            "tiny" => SynthSpec::tiny_test(),
            _ => SynthSpec::cifar_like(),
        }
    }

    /// Pick the bound-optimal p_fast via the Theorem-1 optimizer.
    pub fn with_optimal_p(mut self) -> Result<ExperimentConfig, String> {
        use crate::bound::{BoundParams, MiSource, TwoClusterStudy};
        let study = TwoClusterStudy {
            params: BoundParams {
                a: 100.0,
                b: 20.0,
                l: 1.0,
                c: self.concurrency,
                t: self.steps,
                n: self.n_clients,
            },
            n_fast: self.n_fast(),
            mu_fast: self.mu_fast,
            mu_slow: 1.0,
            source: MiSource::default(),
        };
        let (best, _) = study.optimize_p(50)?;
        self.p_fast = Some(best.p_fast);
        Ok(self)
    }
}

/// Run one experiment end to end.  Returns the training result.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<TrainResult, String> {
    let sspec = cfg.synth_spec();
    let mut backend = make_backend(cfg.backend, &cfg.variant, None)?;
    let bspec = backend.spec().clone();
    if bspec.input_dim != sspec.dim() || bspec.classes != sspec.classes {
        return Err(format!(
            "variant {} expects {}→{} but dataset is {}→{}",
            cfg.variant,
            bspec.input_dim,
            bspec.classes,
            sspec.dim(),
            sspec.classes
        ));
    }
    // the DATASET is fixed across seeds (as CIFAR-10 is in the paper);
    // cfg.seed varies the partition, init, loaders and queueing dynamics.
    let train = Arc::new(generate(&sspec, cfg.n_train, 0xDA7A));
    let val = generate(&sspec, cfg.n_val, 0x7A11);
    let scheme = if cfg.classes_per_client == 0 {
        PartitionScheme::Iid
    } else {
        PartitionScheme::ClassSubset { classes_per_client: cfg.classes_per_client }
    };
    let partition = Partition::build(&train, cfg.n_clients, scheme, cfg.seed ^ 0x9A47)?;
    let loaders = build_loaders(train, &partition, bspec.train_batch, true, cfg.seed ^ 0x10AD)?;
    let val_batches = EvalBatches::new(&val, bspec.eval_batch);
    let p = cfg.p_vec();
    let sim = SimConfig {
        seed: cfg.seed ^ 0x51AA,
        init: InitPlacement::Routed,
        ..SimConfig::new(
            p.clone(),
            ServiceDist::from_rates(&cfg.rates(), ServiceFamily::Exponential),
            cfg.concurrency,
            cfg.steps,
        )
    };
    let rule = rule_for(&cfg.algo, cfg.eta, &p, cfg.fedbuff_z)?;
    let mut model = bspec.init_model(cfg.seed ^ 0x1417);
    let mut driver = Driver::new(backend.as_mut(), loaders, val_batches);
    driver.run(
        DriverConfig { sim, rule, eval_every: cfg.eval_every, loss_window: 20 },
        &mut model,
    )
}

/// Table-2 style multi-seed aggregate.
#[derive(Clone, Debug)]
pub struct SeedSweep {
    pub accuracies: Vec<f64>,
    pub mean: f64,
    pub std: f64,
}

pub fn seed_sweep(base: &ExperimentConfig, seeds: &[u64]) -> Result<SeedSweep, String> {
    let mut acc = Vec::with_capacity(seeds.len());
    let mut w = Welford::new();
    for &s in seeds {
        let mut cfg = base.clone();
        cfg.seed = s;
        let res = run_experiment(&cfg)?;
        acc.push(res.final_accuracy);
        w.push(res.final_accuracy);
    }
    Ok(SeedSweep { accuracies: acc, mean: w.mean(), std: w.std() })
}

/// Theory-side summary printed alongside experiments: expected delays and
/// step rate for the experiment's network (sanity anchor for the curves).
pub fn theory_summary(cfg: &ExperimentConfig) -> Result<(Vec<f64>, f64), String> {
    let net = ClosedNetwork::new(cfg.p_vec(), cfg.rates())?;
    let an = net.mi_analysis(cfg.concurrency, MiEstimator::Throughput);
    Ok((an.m, an.cs_rate))
}

/// Deterministic seed list for Table 2.
pub fn table2_seeds(n: usize) -> Vec<u64> {
    let mut rng = Rng::new(0x7AB1E_2);
    (0..n).map(|_| rng.next_u64() >> 1).collect()
}
