//! Synchronous / semi-synchronous round engines (FedAvg, FAVANO) over real
//! data + backends — used by the Fig 7 comparison where the x-axis is
//! *virtual time*, making the straggler penalty of synchronous rounds
//! visible.
//!
//! These are the faithful round-based formulations with their own virtual
//! clock.  For running FedAvg/FAVANO *inside* the asynchronous event loop
//! (`fedqueue train --algo fedavg|favano`), see the event-stream
//! adaptations behind the [`crate::fl::ServerStrategy`] registry —
//! `fl::strategy::{FedAvgStrategy, FavanoStrategy}`.

use super::driver::CurvePoint;
use crate::data::{ClientLoader, EvalBatches};
use crate::fl::{Favano, FavanoConfig, FedAvg, FedAvgConfig, GradOracle, ModelState};
use crate::runtime::Backend;
use crate::simulator::ServiceDist;

/// GradOracle over a backend + per-client loaders (each call consumes the
/// client's next mini-batch).
pub struct DataOracle<'a> {
    pub backend: &'a mut dyn Backend,
    pub loaders: &'a mut [ClientLoader],
}

impl<'a> GradOracle for DataOracle<'a> {
    fn grad(&mut self, client: usize, model: &ModelState) -> (f64, Vec<Vec<f32>>) {
        let batch = self.loaders[client].next_batch();
        self.backend
            .train_step(model, &batch)
            .unwrap_or_else(|e| panic!("backend failure for client {client}: {e}"))
    }

    fn n_clients(&self) -> usize {
        self.loaders.len()
    }
}

pub struct SyncResult {
    pub curve: Vec<CurvePoint>,
    pub final_accuracy: f64,
    pub total_virtual_time: f64,
    pub rounds: u64,
}

/// Run FedAvg until the virtual-time budget is exhausted.
#[allow(clippy::too_many_arguments)]
pub fn run_fedavg(
    backend: &mut dyn Backend,
    loaders: &mut [ClientLoader],
    val: &EvalBatches,
    model: &mut ModelState,
    cfg: FedAvgConfig,
    service: &[ServiceDist],
    time_budget: f64,
    eval_every_rounds: u64,
    seed: u64,
) -> Result<SyncResult, String> {
    let mut fa = FedAvg::new(cfg, seed);
    let mut t = 0.0;
    let mut rounds = 0u64;
    let mut curve = Vec::new();
    while t < time_budget {
        let out = {
            let mut oracle = DataOracle { backend, loaders };
            fa.round(model, &mut oracle, service)
        };
        t += out.duration;
        rounds += 1;
        if rounds % eval_every_rounds.max(1) == 0 || t >= time_budget {
            let ev = backend.evaluate(model, val)?;
            curve.push(CurvePoint {
                step: rounds,
                virtual_time: t,
                train_loss: out.mean_loss,
                val_loss: ev.mean_loss,
                val_accuracy: ev.accuracy,
            });
        }
        if rounds > 1_000_000 {
            return Err("fedavg round runaway".into());
        }
    }
    let last = curve.last().ok_or("no rounds completed")?;
    Ok(SyncResult {
        final_accuracy: last.val_accuracy,
        total_virtual_time: t,
        rounds,
        curve,
    })
}

/// Run FAVANO until the virtual-time budget is exhausted.
#[allow(clippy::too_many_arguments)]
pub fn run_favano(
    backend: &mut dyn Backend,
    loaders: &mut [ClientLoader],
    val: &EvalBatches,
    model: &mut ModelState,
    cfg: FavanoConfig,
    service: &[ServiceDist],
    time_budget: f64,
    eval_every_rounds: u64,
    seed: u64,
) -> Result<SyncResult, String> {
    let n = loaders.len();
    let mut fv = Favano::new(cfg, model, n, seed);
    let mut t = 0.0;
    let mut rounds = 0u64;
    let mut curve = Vec::new();
    while t < time_budget {
        let out = {
            let mut oracle = DataOracle { backend, loaders };
            fv.round(model, &mut oracle, service)
        };
        t += out.duration;
        rounds += 1;
        if rounds % eval_every_rounds.max(1) == 0 || t >= time_budget {
            let ev = backend.evaluate(model, val)?;
            curve.push(CurvePoint {
                step: rounds,
                virtual_time: t,
                train_loss: out.mean_loss,
                val_loss: ev.mean_loss,
                val_accuracy: ev.accuracy,
            });
        }
        if rounds > 1_000_000 {
            return Err("favano round runaway".into());
        }
    }
    let last = curve.last().ok_or("no rounds completed")?;
    Ok(SyncResult {
        final_accuracy: last.val_accuracy,
        total_virtual_time: t,
        rounds,
        curve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::build_loaders;
    use crate::data::{generate, Partition, PartitionScheme, SynthSpec};
    use crate::runtime::{Backend, NativeBackend};
    use crate::simulator::ServiceFamily;
    use std::sync::Arc;

    fn setup(n: usize) -> (NativeBackend, Vec<ClientLoader>, EvalBatches, ModelState) {
        let spec = SynthSpec::tiny_test();
        let train = Arc::new(generate(&spec, 600, 31));
        let val = generate(&spec, 150, 32);
        let part = Partition::build(&train, n, PartitionScheme::Iid, 33).unwrap();
        let backend = NativeBackend::tiny();
        let loaders = build_loaders(train, &part, backend.spec().train_batch, false, 34).unwrap();
        let val_b = EvalBatches::new(&val, backend.spec().eval_batch);
        let model = backend.spec().init_model(35);
        (backend, loaders, val_b, model)
    }

    #[test]
    fn fedavg_learns() {
        let (mut be, mut loaders, val, mut model) = setup(6);
        let service = ServiceDist::from_rates(&vec![1.0; 6], ServiceFamily::Exponential);
        let res = run_fedavg(
            &mut be,
            &mut loaders,
            &val,
            &mut model,
            FedAvgConfig { s: 4, k_local: 3, eta_local: 0.08 },
            &service,
            120.0,
            5,
            36,
        )
        .unwrap();
        assert!(res.rounds > 5);
        assert!(res.final_accuracy > 0.25, "acc {}", res.final_accuracy);
        assert!(res.total_virtual_time >= 120.0);
    }

    #[test]
    fn favano_learns() {
        let (mut be, mut loaders, val, mut model) = setup(6);
        let service = ServiceDist::from_rates(&vec![1.5; 6], ServiceFamily::Exponential);
        let res = run_favano(
            &mut be,
            &mut loaders,
            &val,
            &mut model,
            FavanoConfig { interval: 3.0, k_max: 4, eta_local: 0.05 },
            &service,
            90.0,
            5,
            37,
        )
        .unwrap();
        assert!(res.rounds == 30);
        assert!(res.final_accuracy > 0.25, "acc {}", res.final_accuracy);
    }

    #[test]
    fn fedavg_time_dominated_by_stragglers() {
        let (mut be, mut loaders, val, mut model) = setup(6);
        // one node 100x slower: with s=n every round waits for it
        let mut rates = vec![10.0; 6];
        rates[5] = 0.1;
        let service = ServiceDist::from_rates(&rates, ServiceFamily::Deterministic);
        let res = run_fedavg(
            &mut be,
            &mut loaders,
            &val,
            &mut model,
            FedAvgConfig { s: 6, k_local: 1, eta_local: 0.05 },
            &service,
            50.0,
            1,
            38,
        )
        .unwrap();
        // each round costs exactly 10 time units (the straggler)
        assert_eq!(res.rounds, 5);
    }
}
