//! Closed Jackson network theory (paper §4) — exact product-form analysis
//! (`jackson`) and heavy-traffic scaling closed forms (`scaling`).

pub mod jackson;
pub mod scaling;

pub use jackson::{ClosedNetwork, MiAnalysis, MiEstimator};
pub use scaling::{gamma_ratio, ThreeCluster, TwoCluster};
