//! Exact stationary analysis of the paper's closed Jackson network (§4).
//!
//! The network: `n` single-server FIFO nodes, `C` circulating tasks, routing
//! probabilities `p_i` (the dispatcher), exponential service rates `μ_i`.
//! Proposition 2 gives the product-form stationary law
//! `π_C(x) = H_C^{-1} Π θ_i^{x_i}` with `θ_i = p_i / μ_i`.
//!
//! This module computes everything downstream of that law *exactly*:
//! normalization constants via **Buzen's convolution algorithm** (O(nC)),
//! marginal queue-length distributions, expected queue lengths, node
//! utilizations, network throughput (= CS step rate), and the paper's key
//! delay quantity `m_i` (Prop 3) through the arrival theorem (Thm 11):
//! an arriving task sees the network in state `π_{C-1}`, so its sojourn is
//! `E^{C-1}[X_i] + 1` services at rate `μ_i`, during which CS steps accrue
//! at (at most) the total departure rate.
//!
//! Numerical care: θ is rescaled by its maximum before convolution (the
//! paper does the same — it only changes the normalization constant), and
//! the normalization table is **held in log space** (`log_g[c]`): even
//! after rescaling, `g[c] ≈ binom(n+c-1, c)` grows past f64 range once
//! n ≥ ~10^5 with c in the hundreds — exactly the regime the sharded
//! engine's million-node regression tests compare against.  Small/medium
//! networks still pay only the cheap linear recurrence (see
//! [`ClosedNetwork::buzen`]); the logaddexp path is the overflow fallback.
//! The scale factor re-enters only in the (rate-valued) throughput.

use crate::util::stats::Welford;

/// log(e^a + e^b) without leaving log space.
#[inline]
fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

#[derive(Clone, Debug)]
pub struct ClosedNetwork {
    /// routing probabilities (visit ratios), sum to 1
    pub p: Vec<f64>,
    /// exponential service rates
    pub mu: Vec<f64>,
}

/// Precomputed Buzen table for one (network, C): g[c] = Σ_{|x|=c} Π θ'^x
/// with θ' = θ / max θ, held as `log_g[c] = ln g[c]` so the table stays
/// representable at n ≥ 10^5 with skewed rates.
#[derive(Clone, Debug)]
pub struct Buzen {
    /// ln θ'_i of the scaled loads θ'_i = θ_i / θ_max  (−inf for
    /// zero-probability nodes; the single source of truth for marginals)
    pub log_theta: Vec<f64>,
    /// scale factor s = max_i θ_i
    pub scale: f64,
    /// ln g[c] for populations 0..=C (over ALL nodes)
    pub log_g: Vec<f64>,
}

impl ClosedNetwork {
    pub fn new(p: Vec<f64>, mu: Vec<f64>) -> Result<Self, String> {
        if p.len() != mu.len() || p.is_empty() {
            return Err("p and mu must be equal-length, non-empty".into());
        }
        // lint-allow(R8): validation sum over the user-supplied p vector in
        // its given order — a fixed-order check, not a cross-engine digest
        let sum: f64 = p.iter().sum();
        if (sum - 1.0).abs() > 1e-9 {
            return Err(format!("routing probabilities sum to {sum}, expected 1"));
        }
        if p.iter().any(|&x| x < 0.0) || mu.iter().any(|&m| m <= 0.0) {
            return Err("p must be >= 0 and mu must be > 0".into());
        }
        Ok(ClosedNetwork { p, mu })
    }

    pub fn n(&self) -> usize {
        self.p.len()
    }

    /// θ_i = p_i / μ_i  (unscaled traffic loads).
    pub fn theta(&self) -> Vec<f64> {
        self.p.iter().zip(&self.mu).map(|(p, m)| p / m).collect()
    }

    /// Total service capacity λ = Σ_j μ_j (the paper's λ in Prop 5).
    pub fn lambda_total(&self) -> f64 {
        self.mu.iter().sum()
    }

    /// Buzen convolution up to population C.  The table is *held* in log
    /// space, but computed by the cheap linear recurrence whenever that
    /// stays in f64 range (all small/medium networks — fused mul-adds, no
    /// transcendentals); only when the linear pass overflows (n ≥ ~10^5
    /// with c in the hundreds) does it rerun as a logaddexp recurrence.
    pub fn buzen(&self, c: usize) -> Buzen {
        let theta = self.theta();
        let scale = theta.iter().cloned().fold(f64::MIN, f64::max);
        let th: Vec<f64> = theta.iter().map(|t| t / scale).collect();
        let log_theta: Vec<f64> = th.iter().map(|t| t.ln()).collect();
        // fast path: the historical linear convolution.  With θ'_max = 1
        // the final g[pop] ≥ 1 (the max-load node alone contributes 1 per
        // population), so the table can only fail by OVERflow, which is
        // sticky in a sum of positives — one finiteness check at the end
        // suffices.  (Transient underflow of tiny-θ' contributions loses
        // only ≤ ~1e-300 relative mass, exactly as the pre-log code did.)
        let mut g = vec![0.0f64; c + 1];
        g[0] = 1.0;
        for &t in &th {
            for pop in 1..=c {
                g[pop] += t * g[pop - 1];
            }
        }
        if g.iter().all(|x| x.is_finite()) {
            let log_g = g.iter().map(|x| x.ln()).collect();
            return Buzen { log_theta, scale, log_g };
        }
        // slow path: the normalization constant exceeds f64 range
        let mut log_g = vec![f64::NEG_INFINITY; c + 1];
        log_g[0] = 0.0;
        for &lt in &log_theta {
            if lt == f64::NEG_INFINITY {
                continue; // zero-load node contributes nothing
            }
            for pop in 1..=c {
                log_g[pop] = logaddexp(log_g[pop], lt + log_g[pop - 1]);
            }
        }
        Buzen { log_theta, scale, log_g }
    }
}

impl Buzen {
    pub fn population(&self) -> usize {
        self.log_g.len() - 1
    }

    /// P(X_i >= k) at population c:  θ'^k g(c-k)/g(c)   (scale-free).
    pub fn tail(&self, i: usize, k: usize, c: usize) -> f64 {
        if k > c {
            return 0.0;
        }
        // k = 0 must short-circuit: 0·(−inf) is NaN for zero-load nodes
        let lt = if k == 0 { 0.0 } else { k as f64 * self.log_theta[i] };
        (lt + self.log_g[c - k] - self.log_g[c]).exp()
    }

    /// P(X_i = k) at population c, as the stable tail difference
    /// P(X_i >= k) − P(X_i >= k+1).
    pub fn pmf(&self, i: usize, k: usize, c: usize) -> f64 {
        if k > c {
            return 0.0;
        }
        (self.tail(i, k, c) - self.tail(i, k + 1, c)).max(0.0)
    }

    /// E[X_i] at population c: Σ_{k=1..c} P(X_i >= k).
    pub fn mean_queue(&self, i: usize, c: usize) -> f64 {
        (1..=c).map(|k| self.tail(i, k, c)).sum()
    }

    /// Utilization ρ_i = P(X_i > 0) at population c.
    pub fn utilization(&self, i: usize, c: usize) -> f64 {
        self.tail(i, 1, c)
    }

    /// Network throughput Λ(c) = Σ_i λ_i(c) = G(c-1)/G(c) in *unscaled*
    /// units (this is the CS step rate; visit ratios sum to 1).
    pub fn throughput(&self, c: usize) -> f64 {
        assert!(c >= 1);
        (1.0 / self.scale) * (self.log_g[c - 1] - self.log_g[c]).exp()
    }

    /// Node-i throughput p_i Λ(c).
    pub fn node_throughput(&self, net: &ClosedNetwork, i: usize, c: usize) -> f64 {
        net.p[i] * self.throughput(c)
    }
}

/// The three estimators of the paper's delay-in-CS-steps `m_i`
/// (number of server steps between dispatch to node i and completion).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MiEstimator {
    /// Prop 5 upper bound: λ_total · E^{C-1}[S_i]
    UpperBound,
    /// Throughput refinement: Λ(C) · E^{C-1}[S_i]  (CS steps accrue at the
    /// stationary step rate rather than the maximal service capacity)
    Throughput,
}

#[derive(Clone, Debug)]
pub struct MiAnalysis {
    /// E^{C-1}[X_i]: queue length seen on arrival (arrival theorem)
    pub arrival_queue: Vec<f64>,
    /// E^{C-1}[S_i] = (E^{C-1}[X_i] + 1) / μ_i: expected sojourn (time)
    pub sojourn: Vec<f64>,
    /// m_i estimate (CS steps)
    pub m: Vec<f64>,
    /// the stationary CS step rate Λ(C)
    pub cs_rate: f64,
}

impl ClosedNetwork {
    /// Exact-arrival-theorem analysis of `m_i` for all nodes at population C.
    ///
    /// The arrival theorem needs the distribution seen by a job arriving at
    /// node i, which for a closed network is the stationary law of the
    /// *whole* network at population C-1 (Theorem 11 / MUSTA). The sojourn
    /// S_i is then (X_i + 1) exponential(μ_i) services (FIFO + memoryless),
    /// and m_i = E[∫_0^{S_i} Σ_j μ_j 1(X_j>0) ds] is bounded (resp.
    /// approximated) by λ_total·E[S_i] (resp. Λ(C)·E[S_i]).
    pub fn mi_analysis(&self, c: usize, est: MiEstimator) -> MiAnalysis {
        assert!(c >= 1, "need at least one task");
        let b = self.buzen(c);
        let n = self.n();
        let mut arrival_queue = Vec::with_capacity(n);
        let mut sojourn = Vec::with_capacity(n);
        let cs_rate = b.throughput(c);
        let rate = match est {
            MiEstimator::UpperBound => self.lambda_total(),
            MiEstimator::Throughput => cs_rate,
        };
        let mut m = Vec::with_capacity(n);
        for i in 0..n {
            let q = b.mean_queue(i, c - 1);
            let s = (q + 1.0) / self.mu[i];
            arrival_queue.push(q);
            sojourn.push(s);
            m.push(rate * s);
        }
        MiAnalysis { arrival_queue, sojourn, m, cs_rate }
    }

    /// m_k^T := Σ_i m_i / (n² p_i²)  (the step-size-controlling quantity of
    /// Theorem 1, in its stationary limit).
    pub fn m_bar(&self, mi: &[f64]) -> f64 {
        let n = self.n() as f64;
        mi.iter()
            .zip(&self.p)
            .map(|(m, p)| m / (n * n * p * p))
            .sum()
    }

    /// Exact π_C by state enumeration — O(states); for validation only.
    pub fn enumerate_stationary(&self, c: usize) -> Vec<(Vec<usize>, f64)> {
        let theta = self.theta();
        let scale = theta.iter().cloned().fold(f64::MIN, f64::max);
        let th: Vec<f64> = theta.iter().map(|t| t / scale).collect();
        let mut states = Vec::new();
        let mut x = vec![0usize; self.n()];
        enumerate_comps(c, 0, &mut x, &mut states, &th);
        // lint-allow(R8): normalization over the lexicographic state
        // enumeration — order is fixed by construction, validation-only path
        let z: f64 = states.iter().map(|(_, w)| *w).sum();
        states.iter_mut().for_each(|(_, w)| *w /= z);
        states
    }
}

fn enumerate_comps(
    rem: usize,
    i: usize,
    x: &mut Vec<usize>,
    out: &mut Vec<(Vec<usize>, f64)>,
    th: &[f64],
) {
    if i == x.len() - 1 {
        x[i] = rem;
        let w: f64 = x.iter().zip(th).map(|(&k, t)| t.powi(k as i32)).product();
        out.push((x.clone(), w));
        return;
    }
    for k in 0..=rem {
        x[i] = k;
        enumerate_comps(rem - k, i + 1, x, out, th);
    }
}

/// Summarize a set of per-node values into (fast cluster, slow cluster)
/// means given the cluster boundary — convenience for 2-cluster studies.
pub fn cluster_means(values: &[f64], n_fast: usize) -> (f64, f64) {
    let mut fast = Welford::new();
    let mut slow = Welford::new();
    for (i, &v) in values.iter().enumerate() {
        if i < n_fast {
            fast.push(v);
        } else {
            slow.push(v);
        }
    }
    (fast.mean(), slow.mean())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_net(n: usize, mu: Vec<f64>) -> ClosedNetwork {
        ClosedNetwork::new(vec![1.0 / n as f64; n], mu).unwrap()
    }

    #[test]
    fn rejects_invalid_networks() {
        assert!(ClosedNetwork::new(vec![0.5, 0.6], vec![1.0, 1.0]).is_err());
        assert!(ClosedNetwork::new(vec![1.0], vec![0.0]).is_err());
        assert!(ClosedNetwork::new(vec![], vec![]).is_err());
        assert!(ClosedNetwork::new(vec![0.5, 0.5], vec![1.0]).is_err());
        assert!(ClosedNetwork::new(vec![1.1, -0.1], vec![1.0, 1.0]).is_err());
    }

    #[test]
    fn buzen_matches_enumeration_small() {
        let net = ClosedNetwork::new(vec![0.3, 0.25, 0.45], vec![1.0, 2.0, 0.7]).unwrap();
        let c = 6;
        let b = net.buzen(c);
        let states = net.enumerate_stationary(c);
        for i in 0..3 {
            for k in 0..=c {
                let exact: f64 = states
                    .iter()
                    .filter(|(x, _)| x[i] == k)
                    .map(|(_, w)| *w)
                    .sum();
                let got = b.pmf(i, k, c);
                assert!(
                    (exact - got).abs() < 1e-10,
                    "node {i} k={k}: exact {exact} vs buzen {got}"
                );
            }
            let exact_mean: f64 = states.iter().map(|(x, w)| x[i] as f64 * w).sum();
            assert!((b.mean_queue(i, c) - exact_mean).abs() < 1e-10);
        }
    }

    #[test]
    fn queue_lengths_sum_to_population() {
        let net = uniform_net(5, vec![1.0, 1.0, 2.0, 0.5, 3.0]);
        for &c in &[1usize, 3, 10, 50] {
            let b = net.buzen(c);
            let total: f64 = (0..5).map(|i| b.mean_queue(i, c)).sum();
            assert!((total - c as f64).abs() < 1e-8, "C={c}: total {total}");
        }
    }

    #[test]
    fn pmf_normalizes() {
        let net = uniform_net(4, vec![2.0, 1.0, 1.0, 0.25]);
        let c = 12;
        let b = net.buzen(c);
        for i in 0..4 {
            let total: f64 = (0..=c).map(|k| b.pmf(i, k, c)).sum();
            assert!((total - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn single_node_holds_everything() {
        let net = ClosedNetwork::new(vec![1.0], vec![3.0]).unwrap();
        let b = net.buzen(7);
        assert!((b.mean_queue(0, 7) - 7.0).abs() < 1e-12);
        assert!((b.utilization(0, 7) - 1.0).abs() < 1e-12);
        // throughput of a single always-busy node is its service rate
        assert!((b.throughput(7) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn symmetric_network_is_balanced() {
        let net = uniform_net(4, vec![1.5; 4]);
        let b = net.buzen(8);
        let q0 = b.mean_queue(0, 8);
        for i in 1..4 {
            assert!((b.mean_queue(i, 8) - q0).abs() < 1e-12);
        }
        assert!((q0 - 2.0).abs() < 1e-12); // C/n by symmetry
    }

    #[test]
    fn throughput_saturates_at_bottleneck() {
        // node 1 is a severe bottleneck: as C grows, Λ → μ_bottleneck / p_b
        // capped by bottleneck: λ_1 = p_1 Λ <= μ_1 → Λ <= μ_1/p_1 = 0.2/0.5
        let net = ClosedNetwork::new(vec![0.5, 0.5], vec![10.0, 0.2]).unwrap();
        let b = net.buzen(200);
        let lam = b.throughput(200);
        assert!((lam - 0.4).abs() < 1e-6, "Λ={lam}");
    }

    #[test]
    fn throughput_scale_invariance() {
        // identical network expressed with different absolute θ scale must
        // produce identical distributions and the same physical throughput
        let a = ClosedNetwork::new(vec![0.5, 0.5], vec![1.0, 2.0]).unwrap();
        let ba = a.buzen(10);
        // tail probabilities are scale-free by construction
        assert!(ba.tail(0, 3, 10) > 0.0);
        let thr = ba.throughput(10);
        assert!(thr > 0.0 && thr < a.lambda_total());
    }

    #[test]
    fn mi_upper_bound_dominates_throughput_estimate() {
        let net = uniform_net(10, vec![1.2, 1.2, 1.2, 1.2, 1.2, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let ub = net.mi_analysis(50, MiEstimator::UpperBound);
        let th = net.mi_analysis(50, MiEstimator::Throughput);
        for i in 0..10 {
            assert!(ub.m[i] >= th.m[i]);
            assert!(th.m[i] > 0.0);
        }
    }

    #[test]
    fn arrival_theorem_uses_population_c_minus_1() {
        let net = uniform_net(2, vec![1.0, 1.0]);
        let an = net.mi_analysis(1, MiEstimator::UpperBound);
        // with C=1, an arriving task sees an empty network: E^{0}[X_i] = 0
        assert!((an.arrival_queue[0] - 0.0).abs() < 1e-12);
        assert!((an.sojourn[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slow_nodes_have_longer_queues_and_delays() {
        // half fast (μ=2), half slow (μ=1), uniform routing
        let mu: Vec<f64> = (0..10).map(|i| if i < 5 { 2.0 } else { 1.0 }).collect();
        let net = uniform_net(10, mu);
        let b = net.buzen(100);
        assert!(b.mean_queue(0, 100) < b.mean_queue(9, 100));
        let an = net.mi_analysis(100, MiEstimator::Throughput);
        assert!(an.m[0] < an.m[9]);
    }

    #[test]
    fn fig5_configuration_delay_scale() {
        // Paper App F: n=10, μ_f=1.2, μ_s=1, C=1000 uniform ⇒ empirical
        // delays ≈ 59 (fast) / 1938 (slow); the Prop-5 upper bound evaluates
        // to ≈ 55 / 2145 (the paper's own closed form gives 45.8 / 2145 —
        // it drops the "+1" sojourn term and a (μ_f+μ_s)/2μ_s factor in the
        // "≈195n" shorthand).  Check we land in that envelope.
        let mu: Vec<f64> = (0..10).map(|i| if i < 5 { 1.2 } else { 1.0 }).collect();
        let net = uniform_net(10, mu);
        let an = net.mi_analysis(1000, MiEstimator::UpperBound);
        let (mf, ms) = cluster_means(&an.m, 5);
        assert!((40.0..70.0).contains(&mf), "fast delay bound {mf}, want ≈50");
        assert!((1900.0..2300.0).contains(&ms), "slow delay bound {ms}, want ≈2000");
    }

    #[test]
    fn m_bar_uniform_formula() {
        // uniform p: m̄ = Σ m_i / n²p_i² = Σ m_i
        let net = uniform_net(4, vec![1.0; 4]);
        let mi = vec![2.0, 3.0, 4.0, 5.0];
        assert!((net.m_bar(&mi) - 14.0).abs() < 1e-12);
    }

    #[test]
    fn buzen_insensitive_to_node_order() {
        // convolution order must not matter
        let a = ClosedNetwork::new(vec![0.2, 0.3, 0.5], vec![1.0, 0.5, 2.0]).unwrap();
        let b = ClosedNetwork::new(vec![0.5, 0.3, 0.2], vec![2.0, 0.5, 1.0]).unwrap();
        let ba = a.buzen(15);
        let bb = b.buzen(15);
        for c in 0..=15 {
            assert!((ba.log_g[c] - bb.log_g[c]).abs() < 1e-9);
        }
        assert!((ba.throughput(15) - bb.throughput(15)).abs() < 1e-10);
    }

    #[test]
    fn log_space_survives_hundred_thousand_node_loads() {
        // n = 50_000 heterogeneous nodes at C = 120: the (rescaled) linear
        // normalization constant is ≳ binom(n/2+C-1, C) ≈ e^760 — past f64
        // range, so the pre-log-space table returned inf and every marginal
        // was NaN.  The log-space table keeps every downstream quantity
        // finite and consistent.
        let n = 50_000usize;
        let c = 120usize;
        let mu: Vec<f64> = (0..n).map(|i| if i < n / 2 { 4.0 } else { 1.0 }).collect();
        let net = uniform_net(n, mu);
        let b = net.buzen(c);
        assert!(
            b.log_g[c] > 709.0,
            "log_g[C] = {} must exceed ln(f64::MAX) ≈ 709.8 for this test \
             to witness the old overflow",
            b.log_g[c]
        );
        let q_fast = b.mean_queue(0, c);
        let q_slow = b.mean_queue(n - 1, c);
        assert!(q_fast.is_finite() && q_slow.is_finite());
        assert!(q_slow > q_fast, "slow nodes hold longer queues");
        let lam = b.throughput(c);
        assert!(lam.is_finite() && lam > 0.0, "throughput {lam}");
        // spot-check normalization on a marginal: Σ_k P(X_i = k) = 1
        let total: f64 = (0..=c).map(|k| b.pmf(n - 1, k, c)).sum();
        assert!((total - 1.0).abs() < 1e-8, "pmf total {total}");
        // population conservation: Σ_i E[X_i] = C, sampled per cluster by
        // symmetry (all fast nodes are exchangeable, likewise slow)
        let total_q = q_fast * (n / 2) as f64 + q_slow * (n - n / 2) as f64;
        assert!(
            (total_q - c as f64).abs() < 1e-6 * c as f64,
            "ΣE[X_i] = {total_q}, want {c}"
        );
    }

    #[test]
    fn extreme_heterogeneity_stays_finite() {
        let net = ClosedNetwork::new(vec![0.5, 0.5], vec![1000.0, 0.001]).unwrap();
        let b = net.buzen(1000);
        let q = b.mean_queue(1, 1000);
        assert!(q.is_finite() && q > 999.0);
        let an = net.mi_analysis(1000, MiEstimator::Throughput);
        assert!(an.m.iter().all(|m| m.is_finite()));
    }
}
