//! Saturation scaling regimes (paper §4 "Scaling regime", Props 4/5,
//! App F 2-cluster and App G 3-cluster closed forms).
//!
//! These give the *closed-form* delay/queue-length estimates that Generalized
//! AsyncSGD uses to pick sampling probabilities without running a simulation:
//! under heavy traffic (C ≫ n) the saturated-node queue lengths concentrate
//! via Van Kreveld et al. (2021), with the Γ-ratio correction
//! `Γ(c) = P(n_f+2, c)/P(n_f+1, c)` of Erlang CDFs.

use crate::util::stats::erlang_cdf;

/// Γ(c) = P(F+2, c) / P(F+1, c) — the conditional-mean correction of
/// Proposition 4 (`F` = number of fast nodes).  Γ → 1 as c → ∞.
pub fn gamma_ratio(n_fast: usize, c: f64) -> f64 {
    if c <= 0.0 {
        return 0.0;
    }
    let num = erlang_cdf(n_fast as u64 + 2, c);
    let den = erlang_cdf(n_fast as u64 + 1, c);
    if den <= 0.0 {
        // deep in the tail both CDFs vanish; the ratio limit is
        // c/(F+2) → use the leading-order term ratio instead.
        return c / (n_fast as f64 + 2.0);
    }
    num / den
}

/// A 2-cluster network specification (fast/slow), the paper's workhorse.
#[derive(Clone, Copy, Debug)]
pub struct TwoCluster {
    pub n: usize,
    pub n_fast: usize,
    pub mu_fast: f64,
    pub mu_slow: f64,
    /// probability of selecting EACH fast node
    pub p_fast: f64,
    /// total number of circulating tasks
    pub c: usize,
}

impl TwoCluster {
    pub fn uniform(n: usize, n_fast: usize, mu_fast: f64, mu_slow: f64, c: usize) -> Self {
        TwoCluster { n, n_fast, mu_fast, mu_slow, p_fast: 1.0 / n as f64, c }
    }

    /// probability of selecting EACH slow node:
    /// q = (1 - n_f p) / (n - n_f)
    pub fn p_slow(&self) -> f64 {
        (1.0 - self.n_fast as f64 * self.p_fast) / (self.n - self.n_fast) as f64
    }

    /// Validity: all probabilities positive and the *slow* cluster must be
    /// the saturated one (θ_s > θ_f) for the scaling regime to apply.
    pub fn valid(&self) -> Result<(), String> {
        if self.n_fast == 0 || self.n_fast >= self.n {
            return Err("need 0 < n_fast < n".into());
        }
        let q = self.p_slow();
        if self.p_fast <= 0.0 || q <= 0.0 {
            return Err(format!("probabilities out of range: p={}, q={q}", self.p_fast));
        }
        if self.mu_fast <= 0.0 || self.mu_slow <= 0.0 {
            return Err("rates must be positive".into());
        }
        Ok(())
    }

    pub fn theta_fast(&self) -> f64 {
        self.p_fast / self.mu_fast
    }

    pub fn theta_slow(&self) -> f64 {
        self.p_slow() / self.mu_slow
    }

    /// γ_f = θ_s / θ_f  (scaled intensity of the non-saturated cluster).
    pub fn gamma_fast(&self) -> f64 {
        self.theta_slow() / self.theta_fast()
    }

    /// c_f β = (γ_f − 1)(C + 1): the argument of the Γ-ratio under the
    /// identification γ_f = 1 + c_f ι^{α−1}, β ι^{1−α} = C + 1.
    pub fn cf_beta(&self) -> f64 {
        (self.gamma_fast() - 1.0) * (self.c as f64 + 1.0)
    }

    /// λ = Σ_i μ_i.
    pub fn lambda_total(&self) -> f64 {
        self.n_fast as f64 * self.mu_fast + (self.n - self.n_fast) as f64 * self.mu_slow
    }

    /// Scaling-limit expected queue lengths (Prop 4):
    ///   E[X_fast] ≈ Γ(c_f β)/(γ_f − 1)
    ///   E[X_slow] ≈ (C − n_f E[X_fast]) / (n − n_f)
    /// Returns (fast, slow).
    pub fn queue_lengths(&self) -> (f64, f64) {
        let g = self.gamma_fast();
        let xf = if g > 1.0 {
            gamma_ratio(self.n_fast, self.cf_beta()) / (g - 1.0)
        } else {
            // no separation: fall back to even split
            self.c as f64 / self.n as f64
        };
        let xf = xf.min(self.c as f64 / self.n_fast as f64);
        let xs = (self.c as f64 - self.n_fast as f64 * xf) / (self.n - self.n_fast) as f64;
        (xf, xs)
    }

    /// Prop 5 delay bounds in CS steps, (fast, slow):
    ///   m_i ≤ (λ/μ_i)(E[X_i] + 1).
    pub fn delay_bounds(&self) -> (f64, f64) {
        let lam = self.lambda_total();
        let (xf, xs) = self.queue_lengths();
        (lam / self.mu_fast * (xf + 1.0), lam / self.mu_slow * (xs + 1.0))
    }

    /// App F closed forms for the uniform, n_f = n/2, Γ≈1 special case:
    ///   m_f ≤ n(μ_f+μ_s) / (2 μ_f (μ_f/μ_s − 1))
    ///   m_s ≤ (2C/n − 1/(μ_f/μ_s − 1)) · n(μ_f+μ_s) / (2 μ_s)
    pub fn delay_closed_form_uniform(&self) -> (f64, f64) {
        let n = self.n as f64;
        let (mf, ms) = (self.mu_fast, self.mu_slow);
        let ratio = mf / ms - 1.0;
        let fast = n * (mf + ms) / (2.0 * mf * ratio);
        let slow = (2.0 * self.c as f64 / n - 1.0 / ratio) * n * (mf + ms) / (2.0 * ms);
        (fast, slow)
    }

    /// Per-node probability vector [p_fast × n_f, p_slow × (n−n_f)].
    pub fn p_vec(&self) -> Vec<f64> {
        let q = self.p_slow();
        (0..self.n)
            .map(|i| if i < self.n_fast { self.p_fast } else { q })
            .collect()
    }

    /// Per-node service-rate vector.
    pub fn mu_vec(&self) -> Vec<f64> {
        (0..self.n)
            .map(|i| if i < self.n_fast { self.mu_fast } else { self.mu_slow })
            .collect()
    }
}

/// 3-cluster saturation regime (App G): fast queues degenerate to 0,
/// medium saturates at rate c_m, slow carries the rest.
#[derive(Clone, Copy, Debug)]
pub struct ThreeCluster {
    pub n: usize,
    pub n_fast: usize,
    pub n_medium: usize, // cumulative boundary: nodes [n_fast, n_medium)
    pub mu_fast: f64,
    pub mu_medium: f64,
    pub mu_slow: f64,
    pub c: usize,
}

impl ThreeCluster {
    pub fn sizes(&self) -> (usize, usize, usize) {
        (self.n_fast, self.n_medium - self.n_fast, self.n - self.n_medium)
    }

    /// P(X_fast > 0) in the degenerate regime: uniform routing forces equal
    /// node throughputs λ_i = Λ/n; slow nodes saturate (ρ_s ≈ 1) so
    /// Λ ≈ n μ_s and ρ_f = Λ/(n μ_f) = μ_s/μ_f.
    pub fn p_fast_busy(&self) -> f64 {
        (self.mu_slow / self.mu_fast).min(1.0)
    }

    /// Effective λ of App G: fast nodes contribute only when busy.
    pub fn lambda_effective(&self) -> f64 {
        let (nf, nm, ns) = self.sizes();
        nf as f64 * self.p_fast_busy() * self.mu_fast
            + nm as f64 * self.mu_medium
            + ns as f64 * self.mu_slow
    }

    /// Closed-form delay estimates (fast, medium, slow) in CS steps:
    ///   m_f ≤ λ/μ_f · P(X_f>0 correction folded in λ)
    ///   m_m ≤ (λ/μ_m) / (μ_m/μ_s − 1)
    ///   m_s ≤ (λ/μ_s)(3C/n − 1/(μ_m/μ_s − 1))
    pub fn delay_estimates(&self) -> (f64, f64, f64) {
        let lam = self.lambda_effective();
        let sep = self.mu_medium / self.mu_slow - 1.0;
        let m_f = lam / self.mu_fast;
        let m_m = lam / self.mu_medium / sep;
        let m_s = lam / self.mu_slow
            * (3.0 * self.c as f64 / self.n as f64 - 1.0 / sep);
        (m_f, m_m, m_s)
    }

    /// Expected queue lengths (fast, medium, slow) in the scaling limit
    /// (Prop 12): fast → 0, medium → Γ/(γ_m −1), slow absorbs the rest.
    pub fn queue_lengths(&self) -> (f64, f64, f64) {
        let (nf, nm, ns) = self.sizes();
        let gamma_m = self.mu_medium / self.mu_slow; // θ_s/θ_m under uniform p
        let cm_beta = (gamma_m - 1.0) * (self.c as f64 + 1.0);
        let xm = gamma_ratio(nf + nm, cm_beta) / (gamma_m - 1.0);
        let xs = (self.c as f64 - nm as f64 * xm) / ns as f64;
        (0.0, xm, xs)
    }

    pub fn mu_vec(&self) -> Vec<f64> {
        (0..self.n)
            .map(|i| {
                if i < self.n_fast {
                    self.mu_fast
                } else if i < self.n_medium {
                    self.mu_medium
                } else {
                    self.mu_slow
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_ratio_limits() {
        // Γ → 1 for large c
        assert!((gamma_ratio(5, 500.0) - 1.0).abs() < 1e-6);
        // Γ ≤ 1 always (P(k+1,c) ≤ P(k,c); equality only at fp saturation)
        for &c in &[0.5, 2.0, 10.0, 50.0] {
            let g = gamma_ratio(3, c);
            assert!(g > 0.0 && g <= 1.0, "c={c} g={g}");
        }
        assert!(gamma_ratio(3, 2.0) < 1.0);
        // small-c limit ~ c/(F+2)
        let g = gamma_ratio(2, 0.01);
        assert!((g - 0.01 / 4.0).abs() < 1e-3, "g={g}");
        assert_eq!(gamma_ratio(2, 0.0), 0.0);
    }

    #[test]
    fn gamma_ratio_deep_tail_does_not_nan() {
        let g = gamma_ratio(90, 1e-8);
        assert!(g.is_finite() && g >= 0.0);
    }

    fn paper_fig5_cluster() -> TwoCluster {
        TwoCluster::uniform(10, 5, 1.2, 1.0, 1000)
    }

    #[test]
    fn two_cluster_validity() {
        assert!(paper_fig5_cluster().valid().is_ok());
        let mut bad = paper_fig5_cluster();
        bad.p_fast = 0.21; // q would go negative (n=10, n_f=5)
        assert!(bad.valid().is_err());
        bad = paper_fig5_cluster();
        bad.n_fast = 10;
        assert!(bad.valid().is_err());
    }

    #[test]
    fn p_slow_complement() {
        let tc = TwoCluster { p_fast: 0.0073, ..paper_fig5_cluster() };
        let q = tc.p_slow();
        assert!((5.0 * 0.0073 + 5.0 * q - 1.0).abs() < 1e-12);
    }

    #[test]
    fn app_f_worked_example_numbers() {
        // Paper App F: n=10, μ_f=1.2, μ_s=1, C=1000, uniform:
        //   m_f ≲ n/(μ_f/μ_s − 1) = 5n = 50
        //   m_s ≲ (2C/n − 5) n ≈ 195n = 1950
        let tc = paper_fig5_cluster();
        let (mf, ms) = tc.delay_closed_form_uniform();
        // closed form: fast = 10*2.2/(2*1.2*0.2) = 22/0.48 ≈ 45.8  (≈ 5n)
        assert!((mf - 45.83).abs() < 0.1, "mf={mf}");
        // slow = (200 − 5) * 10*2.2/2 = 195 * 11 = 2145 (≈ 195n·(1+μ_f/μ_s)/2)
        assert!((ms - 2145.0).abs() < 1.0, "ms={ms}");
    }

    #[test]
    fn scaling_queue_lengths_conserve_population() {
        let tc = paper_fig5_cluster();
        let (xf, xs) = tc.queue_lengths();
        let total = 5.0 * xf + 5.0 * xs;
        assert!((total - 1000.0).abs() < 1e-9);
        // fast queues short, slow queues long
        assert!(xf < 10.0, "xf={xf}");
        assert!(xs > 190.0, "xs={xs}");
    }

    #[test]
    fn two_cluster_delay_bounds_match_closed_form_regime() {
        let tc = paper_fig5_cluster();
        let (bf, bs) = tc.delay_bounds();
        let (cf, cs) = tc.delay_closed_form_uniform();
        // Γ ≈ 1 here; the closed form additionally drops the "+1" sojourn
        // term (X_f ≈ 5 ⇒ ~20% gap on the fast side), so allow 25%.
        assert!((bf / cf - 1.0).abs() < 0.25, "bf={bf} cf={cf}");
        assert!((bs / cs - 1.0).abs() < 0.05, "bs={bs} cs={cs}");
    }

    #[test]
    fn lower_p_fast_reduces_fast_delay() {
        // the paper's core effect: sampling fast nodes LESS reduces delays
        let uni = paper_fig5_cluster();
        let opt = TwoCluster { p_fast: 0.0075, ..uni };
        let (du, _) = uni.delay_bounds();
        let (do_, _) = opt.delay_bounds();
        assert!(
            do_ < du / 3.0,
            "optimal sampling should slash fast delay: {do_} vs {du}"
        );
    }

    #[test]
    fn three_cluster_app_g_numbers() {
        // Paper App G: n=9, thirds, μ=(10, 1.2, 1), C=1000:
        //   P(X_f>0) = 0.1, λ ≈ 9.6, m_f ≈ λ/μ_f ≈ 1, m_m ≈ 5λ/1.2 ≈ 40,
        //   m_s ≈ λ(3C/n − 5) ≈ 9.6 * (333.3 − 5) ≈ 3152
        let t3 = ThreeCluster {
            n: 9,
            n_fast: 3,
            n_medium: 6,
            mu_fast: 10.0,
            mu_medium: 1.2,
            mu_slow: 1.0,
            c: 1000,
        };
        assert!((t3.p_fast_busy() - 0.1).abs() < 1e-12);
        let lam = t3.lambda_effective();
        assert!((lam - 9.6).abs() < 1e-9, "λ={lam}");
        let (mf, mm, ms) = t3.delay_estimates();
        assert!((mf - 0.96).abs() < 0.01, "mf={mf}");
        assert!((mm - 40.0).abs() < 0.5, "mm={mm}");
        assert!((ms - 3152.0).abs() < 20.0, "ms={ms}");
    }

    #[test]
    fn three_cluster_population_conservation() {
        let t3 = ThreeCluster {
            n: 9,
            n_fast: 3,
            n_medium: 6,
            mu_fast: 10.0,
            mu_medium: 1.2,
            mu_slow: 1.0,
            c: 1000,
        };
        let (xf, xm, xs) = t3.queue_lengths();
        assert_eq!(xf, 0.0);
        assert!((3.0 * xm + 3.0 * xs - 1000.0).abs() < 1.0);
        assert!(xm < xs);
    }
}
