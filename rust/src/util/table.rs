//! CSV writing and aligned ASCII table rendering for figures/tables output.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Column-oriented series container: one figure = one `Series` = one CSV.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Series {
    pub fn new(columns: &[&str]) -> Self {
        Series { columns: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", self.columns.join(","))?;
        for row in &self.rows {
            let line: Vec<String> = row.iter().map(|v| format_num(*v)).collect();
            writeln!(w, "{}", line.join(","))?;
        }
        Ok(())
    }

    /// Aligned preview for terminal output (first `limit` rows).
    pub fn ascii(&self, limit: usize) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let shown: Vec<Vec<String>> = self
            .rows
            .iter()
            .take(limit)
            .map(|r| r.iter().map(|v| format_num(*v)).collect())
            .collect();
        for row in &shown {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        for (i, c) in self.columns.iter().enumerate() {
            out.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        out.push('\n');
        for row in &shown {
            for (i, cell) in row.iter().enumerate() {
                out.push_str(&format!("{:>w$}  ", cell, w = widths[i]));
            }
            out.push('\n');
        }
        if self.rows.len() > limit {
            out.push_str(&format!("... ({} rows total)\n", self.rows.len()));
        }
        out
    }
}

pub fn format_num(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        return format!("{}", v as i64);
    }
    let a = v.abs();
    if a >= 1e5 || a < 1e-4 {
        format!("{v:.6e}")
    } else {
        let s = format!("{v:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

/// Minimal string-cell table (for Table 1 / Table 2 style output).
#[derive(Clone, Debug, Default)]
pub struct TextTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len());
        self.rows.push(row);
    }

    pub fn ascii(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(w, "{}", row.join(","))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_csv_roundtrip_text() {
        let mut s = Series::new(&["k", "m_ik"]);
        s.push(vec![0.0, 1.25]);
        s.push(vec![1.0, 130000.0]);
        let dir = std::env::temp_dir().join("fedqueue_test_csv");
        let p = dir.join("s.csv");
        s.write_csv(&p).unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        assert!(txt.starts_with("k,m_ik\n"));
        assert!(txt.contains("0,1.25"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn series_arity_checked() {
        let mut s = Series::new(&["a", "b"]);
        s.push(vec![1.0]);
    }

    #[test]
    fn format_num_cases() {
        assert_eq!(format_num(0.0), "0");
        assert_eq!(format_num(3.0), "3");
        assert_eq!(format_num(0.5), "0.5");
        assert!(format_num(1.0e-7).contains('e'));
        assert!(format_num(1.23e16).contains('e'));
        assert_eq!(format_num(12300000.0), "12300000"); // integral stays exact
    }

    #[test]
    fn text_table_renders() {
        let mut t = TextTable::new(&["Method", "Acc"]);
        t.push(vec!["FedBuff".into(), "49.9 ± 0.8".into()]);
        let a = t.ascii();
        assert!(a.contains("FedBuff"));
        assert!(a.contains("Method"));
    }

    #[test]
    fn series_ascii_truncates() {
        let mut s = Series::new(&["x"]);
        for i in 0..20 {
            s.push(vec![i as f64]);
        }
        let a = s.ascii(5);
        assert!(a.contains("20 rows total"));
    }
}
