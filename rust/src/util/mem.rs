//! Process-memory introspection for the sweep's BENCH trajectories.
//!
//! Linux-only (reads `/proc/self/status`); other platforms report `None`
//! and the sweep **omits the `peak_rss_mib` field** from its `perf` block
//! — a macOS/Windows runner must never see a fake 0 (or a poisoned NaN)
//! where a measurement belongs.  Note the high-water mark is
//! **process-wide and monotone**: a replication's value is the peak of
//! everything the process has run up to and including it, so in a
//! mixed-size sweep a small cell that runs after (or concurrently with) a
//! big one inherits the big cell's peak.  Read it as an upper bound on
//! "memory needed to run the sweep up to here" — for a per-cell footprint,
//! run the cell in its own sweep/process.

/// Peak resident set size (VmHWM) in bytes, if the platform exposes it.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Peak resident set size in MiB, `None` off-Linux (or when `/proc` is
/// unreadable).  Callers skip the metric entirely when absent rather than
/// recording a placeholder value.
pub fn peak_rss_mib() -> Option<f64> {
    peak_rss_bytes().map(|b| b as f64 / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_is_positive_when_available() {
        if let Some(b) = peak_rss_bytes() {
            assert!(b > 0);
            // a running test binary resides in at least a megabyte
            assert!(b > 1 << 20, "VmHWM {b} bytes is implausibly small");
        }
    }

    #[test]
    fn mib_mirrors_bytes_exactly_including_absence() {
        match (peak_rss_bytes(), peak_rss_mib()) {
            (Some(b), Some(mib)) => {
                assert!(mib > 0.0 && mib.is_finite());
                assert_eq!(mib.to_bits(), (b as f64 / (1024.0 * 1024.0)).to_bits());
            }
            (None, None) => {} // off-Linux: no value, never a fake 0/NaN
            (b, m) => panic!("probe disagreement: bytes {b:?} vs mib {m:?}"),
        }
    }

    #[test]
    fn peak_rss_is_monotone() {
        let before = peak_rss_bytes();
        let v: Vec<u8> = vec![1; 8 << 20];
        std::hint::black_box(&v);
        let after = peak_rss_bytes();
        if let (Some(a), Some(b)) = (before, after) {
            assert!(b >= a, "high-water mark went backwards: {a} -> {b}");
        }
    }
}
